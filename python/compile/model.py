"""L2 — JAX model zoo + PEFT parameterizations + in-graph AdamW.

Everything here exists only on the compile path: `aot.py` jit-lowers the
train/eval/pretrain step functions to HLO text, and the rust coordinator
executes them through PJRT.  The C3A delta is computed by the L1 Pallas
kernel (`kernels.c3a`), so it lowers into the same HLO module.

Models (all from scratch, functional):
  * encoder  — RoBERTa-sim: token+pos embeddings, bidirectional MHA, GELU
               MLP, layernorm, first-token pooled head (cls or reg).
               `vec` input mode turns it into a ViT-sim (patch vectors).
  * decoder  — LLaMA-sim: causal MHA, RMSNorm, SwiGLU, tied LM head.
  * mlp      — 3-layer MLP for the paper's Fig. 4 expressiveness study.

PEFT methods (paper §4 baselines + C3A): full, head, bitfit, ia3, lora,
dora, vera, boft, c3a.  Adapters attach to the q and v attention
projections (LoRA convention; the paper's GLUE setup), or to the middle
layer of the MLP.

Parameter handling: a model is a flat ``{name: array}`` dict.  Each PEFT
method induces a (trainable, frozen, frozen_random) split; the AdamW update
runs in-graph over the trainable leaves only.  `aot.py` records the exact
flattening order in the artifact manifest so rust can map buffers by name.
"""

import math
from dataclasses import dataclass, field, replace

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import c3a as c3a_kernel

# --------------------------------------------------------------------------
# Configs
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelCfg:
    kind: str  # encoder | decoder | mlp
    vocab: int = 512
    d: int = 128
    layers: int = 4
    heads: int = 4
    seq: int = 32
    n_out: int = 2  # classifier width (encoder) / classes (mlp)
    head_kind: str = "cls"  # cls | reg | lm
    input_mode: str = "tokens"  # tokens | vec (ViT-sim patch vectors)
    patch_dim: int = 16  # vec mode: per-patch feature width
    mlp_hidden: int = 128  # mlp kind: hidden width
    mlp_in: int = 2  # mlp kind: input width

    @property
    def ffn(self) -> int:
        return 4 * self.d if self.kind == "encoder" else 2 * self.d


@dataclass(frozen=True)
class PeftCfg:
    method: str = "c3a"  # full|head|bitfit|ia3|lora|dora|vera|boft|c3a
    block: int = 0  # c3a block size b (0 => d, i.e. "b=d/1")
    rank: int = 8  # lora/dora rank r
    alpha: float = 16.0  # lora scaling numerator (alpha/r)
    r_v: int = 256  # vera intermediate rank
    boft_block: int = 8  # boft orthogonal block size
    mlp_mid: str = "dense"  # mlp kind: dense | lora | c3a (fig 4)


# Named presets used by aot.py / the experiment harness.
MODEL_PRESETS = {
    "enc_tiny": ModelCfg("encoder", vocab=64, d=32, layers=2, heads=2, seq=16),
    "enc_base": ModelCfg("encoder", vocab=512, d=128, layers=4, heads=4, seq=32),
    "enc_large": ModelCfg("encoder", vocab=512, d=256, layers=6, heads=8, seq=32),
    "dec_small": ModelCfg("decoder", vocab=512, d=192, layers=4, heads=4, seq=48, head_kind="lm"),
    "dec_large": ModelCfg("decoder", vocab=512, d=320, layers=6, heads=8, seq=48, head_kind="lm"),
    "vit_base": ModelCfg("encoder", d=128, layers=4, heads=4, seq=16, n_out=200,
                         input_mode="vec", patch_dim=16),
    "vit_large": ModelCfg("encoder", d=256, layers=6, heads=8, seq=16, n_out=200,
                          input_mode="vec", patch_dim=16),
    "mlp": ModelCfg("mlp", n_out=8, head_kind="cls"),
}

ADAPTED_PROJS = ("q", "v")  # projections carrying a delta adapter


def c3a_block(cfg: ModelCfg, peft: PeftCfg) -> int:
    b = peft.block if peft.block > 0 else cfg.d
    if cfg.d % b != 0:
        raise ValueError(f"c3a block {b} must divide d={cfg.d}")
    return b


# --------------------------------------------------------------------------
# Parameter specs + init
# --------------------------------------------------------------------------


def base_param_shapes(cfg: ModelCfg):
    """Backbone (pre-trained) parameter shapes, ordered."""
    p = {}
    if cfg.kind == "mlp":
        h = cfg.mlp_hidden
        p["mlp.w0"] = (cfg.mlp_in, h)
        p["mlp.b0"] = (h,)
        p["mlp.w1"] = (h, h)  # the replaceable middle layer
        p["mlp.b1"] = (h,)
        p["mlp.w2"] = (h, cfg.n_out)
        p["mlp.b2"] = (cfg.n_out,)
        return p
    if cfg.input_mode == "vec":
        p["embed.patch"] = (cfg.patch_dim, cfg.d)
    else:
        p["embed.tok"] = (cfg.vocab, cfg.d)
    p["embed.pos"] = (cfg.seq, cfg.d)
    enc = cfg.kind == "encoder"
    for i in range(cfg.layers):
        L = f"L{i}"
        for proj in ("q", "k", "v", "o"):
            p[f"{L}.attn.w{proj}"] = (cfg.d, cfg.d)
            if enc:
                p[f"{L}.attn.b{proj}"] = (cfg.d,)
        if enc:
            p[f"{L}.ln1.g"] = (cfg.d,)
            p[f"{L}.ln1.b"] = (cfg.d,)
            p[f"{L}.mlp.w1"] = (cfg.d, cfg.ffn)
            p[f"{L}.mlp.b1"] = (cfg.ffn,)
            p[f"{L}.mlp.w2"] = (cfg.ffn, cfg.d)
            p[f"{L}.mlp.b2"] = (cfg.d,)
            p[f"{L}.ln2.g"] = (cfg.d,)
            p[f"{L}.ln2.b"] = (cfg.d,)
        else:
            p[f"{L}.rms1.g"] = (cfg.d,)
            p[f"{L}.mlp.wg"] = (cfg.d, cfg.ffn)
            p[f"{L}.mlp.wu"] = (cfg.d, cfg.ffn)
            p[f"{L}.mlp.wd"] = (cfg.ffn, cfg.d)
            p[f"{L}.rms2.g"] = (cfg.d,)
    if enc:
        p["final_ln.g"] = (cfg.d,)
        p["final_ln.b"] = (cfg.d,)
        p["head.w"] = (cfg.d, cfg.n_out)
        p["head.b"] = (cfg.n_out,)
    else:
        p["final_rms.g"] = (cfg.d,)  # lm head tied to embed.tok
    return p


def adapter_param_shapes(cfg: ModelCfg, peft: PeftCfg):
    """Adapter parameter shapes for the chosen method: (trainable, frozen_random)."""
    t, fr = {}, {}
    m = peft.method
    if cfg.kind == "mlp":
        h = cfg.mlp_hidden
        if peft.mlp_mid == "lora":
            t["mlp.mid.lora.A"] = (peft.rank, h)
            t["mlp.mid.lora.B"] = (h, peft.rank)
        elif peft.mlp_mid == "c3a":
            b = peft.block if peft.block > 0 else h
            t["mlp.mid.c3a.w"] = (h // b, h // b, b)
        return t, fr
    if m in ("full", "head", "bitfit"):
        return t, fr
    d = cfg.d
    if m == "ia3":
        for i in range(cfg.layers):
            t[f"L{i}.ia3.lk"] = (d,)
            t[f"L{i}.ia3.lv"] = (d,)
            t[f"L{i}.ia3.lff"] = (cfg.ffn,)
        return t, fr
    if m == "vera":
        fr["vera.A"] = (peft.r_v, d)
        fr["vera.B"] = (d, peft.r_v)
    for i in range(cfg.layers):
        for proj in ADAPTED_PROJS:
            k = f"L{i}.attn.{proj}"
            if m in ("lora", "dora"):
                t[f"{k}.lora.A"] = (peft.rank, d)
                t[f"{k}.lora.B"] = (d, peft.rank)
                if m == "dora":
                    t[f"{k}.dora.mag"] = (d,)
            elif m == "vera":
                t[f"{k}.vera.ld"] = (peft.r_v,)
                t[f"{k}.vera.lb"] = (d,)
            elif m == "boft":
                bb = peft.boft_block
                assert d % bb == 0
                t[f"{k}.boft.skew"] = (d // bb, bb, bb)
            elif m == "c3a":
                b = c3a_block(cfg, peft)
                t[f"{k}.c3a.w"] = (d // b, d // b, b)
            else:
                raise ValueError(f"unknown method {m}")
    return t, fr


def split_roles(cfg: ModelCfg, peft: PeftCfg):
    """Full parameter split: ordered dicts of shapes by role.

    Returns (trainable, frozen, frozen_random).  The classifier head is
    always trainable (paper: every method gets the same head; its count is
    excluded from "# Params").
    """
    base = base_param_shapes(cfg)
    adapt_t, adapt_fr = adapter_param_shapes(cfg, peft)
    m = peft.method
    trainable, frozen = {}, {}
    head_names = {"head.w", "head.b"}
    if cfg.kind == "mlp":
        for k, v in base.items():
            mid = k in ("mlp.w1", "mlp.b1")
            if mid and peft.mlp_mid != "dense":
                continue  # middle layer replaced by the adapter op
            trainable[k] = v
        trainable.update(adapt_t)
        return trainable, frozen, adapt_fr
    for k, v in base.items():
        if m == "full":
            trainable[k] = v
        elif k in head_names:
            trainable[k] = v
        elif m == "bitfit" and (k.endswith(".b") or ".attn.b" in k or k.endswith(".b1") or k.endswith(".b2")):
            trainable[k] = v
        else:
            frozen[k] = v
    trainable.update(adapt_t)
    return trainable, frozen, adapt_fr


def trainable_param_count(cfg: ModelCfg, peft: PeftCfg, include_head=False):
    """#Params as the paper reports it (classifier head excluded)."""
    t, _, _ = split_roles(cfg, peft)
    total = 0
    for k, shp in t.items():
        if not include_head and k in ("head.w", "head.b"):
            continue
        total += int(np.prod(shp)) if shp else 1
    return total


def init_base_params(cfg: ModelCfg, seed: int = 0):
    """Backbone init (the 'pre-pretraining' starting point)."""
    rng = np.random.RandomState(seed)
    out = {}
    for k, shp in base_param_shapes(cfg).items():
        if k.endswith(".g"):
            out[k] = np.ones(shp, np.float32)
        elif k.endswith(".b") or k.startswith("L") and ".attn.b" in k or k.endswith(".b1") or k.endswith(".b2") or k.endswith(".b0"):
            out[k] = np.zeros(shp, np.float32)
        elif k == "embed.pos":
            out[k] = (0.02 * rng.randn(*shp)).astype(np.float32)
        else:
            fan_in = shp[0] if len(shp) > 1 else shp[0]
            std = 1.0 / math.sqrt(max(1, fan_in))
            out[k] = (std * rng.randn(*shp)).astype(np.float32)
    return out


def init_adapter_params(cfg: ModelCfg, peft: PeftCfg, seed: int = 0, scheme: str = "default"):
    """Adapter init.  `scheme` feeds the paper's Fig. 3 ablation:
    default | zero | gaussian | kaiming | xavier (C3A kernels only).
    """
    rng = np.random.RandomState(seed + 7)
    t, fr = adapter_param_shapes(cfg, peft)
    out = {}
    for k, shp in t.items():
        if ".lora.A" in k:
            out[k] = (rng.randn(*shp) / math.sqrt(shp[1])).astype(np.float32)
        elif ".lora.B" in k:
            out[k] = np.zeros(shp, np.float32)
        elif ".dora.mag" in k or ".vera.lb" in k:
            out[k] = np.ones(shp, np.float32)
        elif ".vera.ld" in k:
            out[k] = np.full(shp, 0.1, np.float32)
        elif ".ia3." in k:
            out[k] = np.ones(shp, np.float32)
        elif ".boft.skew" in k:
            out[k] = np.zeros(shp, np.float32)
        elif ".c3a.w" in k:
            m_, n_, b_ = shp
            fan = n_ * b_
            if scheme in ("default", "xavier"):
                lim = math.sqrt(6.0 / (m_ * b_ + fan))
                out[k] = rng.uniform(-lim, lim, shp).astype(np.float32)
            elif scheme == "zero":
                out[k] = np.zeros(shp, np.float32)
            elif scheme == "gaussian":
                out[k] = (0.02 * rng.randn(*shp)).astype(np.float32)
            elif scheme == "kaiming":
                lim = math.sqrt(3.0 / fan) * math.sqrt(2.0)
                out[k] = rng.uniform(-lim, lim, shp).astype(np.float32)
            else:
                raise ValueError(f"unknown init scheme {scheme}")
        else:
            out[k] = np.zeros(shp, np.float32)
    rng_fr = np.random.RandomState(1234)  # fixed seed: VeRA shares frozen projections
    for k, shp in fr.items():
        out[k] = (rng_fr.randn(*shp) / math.sqrt(shp[-1])).astype(np.float32)
    return out


# --------------------------------------------------------------------------
# PEFT delta application
# --------------------------------------------------------------------------


def _adapted_linear(params, key, x, w0, b0, peft: PeftCfg):
    """y = x @ w0 (+b0) + delta(x) for the q/v projections.

    x: [..., d_in]; w0: [d_in, d_out].
    """
    y = x @ w0
    m = peft.method
    if m in ("lora", "dora"):
        A = params[f"{key}.lora.A"]  # [r, d_in]
        B = params[f"{key}.lora.B"]  # [d_out, r]
        scale = peft.alpha / peft.rank
        if m == "lora":
            y = y + scale * ((x @ A.T) @ B.T)
        else:
            # DoRA: magnitude * column-normalized (W0 + scale*BA)
            w = w0 + scale * (B @ A).T  # [d_in, d_out]
            norm = jnp.sqrt(jnp.sum(w * w, axis=0, keepdims=True) + 1e-6)
            mag = params[f"{key}.dora.mag"]  # [d_out]
            y = x @ (w / norm * mag[None, :])
    elif m == "vera":
        A = params["vera.A"]  # [r_v, d_in] frozen
        B = params["vera.B"]  # [d_out, r_v] frozen
        ld = params[f"{key}.vera.ld"]
        lb = params[f"{key}.vera.lb"]
        y = y + ((x @ A.T) * ld[None, :]) @ B.T * lb[None, :]
    elif m == "boft":
        S = params[f"{key}.boft.skew"]  # [nb, bb, bb]
        skew = 0.5 * (S - jnp.swapaxes(S, -1, -2))
        # Orthogonal-ish rotation via a truncated matrix exponential of the
        # skew part (order 4).  The exact Cayley transform needs a matrix
        # solve, which lowers to a typed-FFI LAPACK custom call that the
        # pinned xla_extension 0.5.1 runtime cannot execute (see DESIGN.md
        # §substitutions); exp(skew) is solve-free, exactly identity at
        # init, and orthogonal to O(||S||^5).
        eye = jnp.eye(S.shape[-1], dtype=S.dtype)[None]
        s2 = skew @ skew
        R = eye + skew + s2 / 2.0 + (s2 @ skew) / 6.0 + (s2 @ s2) / 24.0
        d_out = y.shape[-1]
        yb = y.reshape(y.shape[:-1] + (S.shape[0], S.shape[-1]))
        yb = jnp.einsum("...nb,nbc->...nc", yb, R)
        y = yb.reshape(y.shape[:-1] + (d_out,))
    elif m == "c3a":
        w = params[f"{key}.c3a.w"]  # [m, n, b] — the L1 Pallas kernel
        y = y + c3a_kernel.c3a_matvec(x, w)
    if b0 is not None:
        y = y + b0
    return y


# --------------------------------------------------------------------------
# Model forward passes
# --------------------------------------------------------------------------


def _layernorm(x, g, b):
    mu = jnp.mean(x, -1, keepdims=True)
    var = jnp.var(x, -1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-5) * g + b


def _rmsnorm(x, g):
    return x / jnp.sqrt(jnp.mean(x * x, -1, keepdims=True) + 1e-6) * g


def _attention(params, cfg: ModelCfg, peft: PeftCfg, i: int, x, mask):
    """MHA with PEFT deltas on q/v (and IA3 rescales on k/v)."""
    L = f"L{i}"
    d, H = cfg.d, cfg.heads
    hd = d // H
    enc = cfg.kind == "encoder"
    bias = (lambda p: params[f"{L}.attn.b{p}"]) if enc else (lambda p: None)
    q = _adapted_linear(params, f"{L}.attn.q", x, params[f"{L}.attn.wq"], bias("q"), peft)
    k = x @ params[f"{L}.attn.wk"]
    if enc:
        k = k + bias("k")
    v = _adapted_linear(params, f"{L}.attn.v", x, params[f"{L}.attn.wv"], bias("v"), peft)
    if peft.method == "ia3":
        k = k * params[f"{L}.ia3.lk"][None, None, :]
        v = v * params[f"{L}.ia3.lv"][None, None, :]
    B, S = x.shape[0], x.shape[1]

    def heads(t):
        return t.reshape(B, S, H, hd).transpose(0, 2, 1, 3)

    qh, kh, vh = heads(q), heads(k), heads(v)
    att = jnp.einsum("bhqd,bhkd->bhqk", qh, kh) / math.sqrt(hd)
    att = att + mask
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", att, vh).transpose(0, 2, 1, 3).reshape(B, S, d)
    out = out @ params[f"{L}.attn.wo"]
    if enc:
        out = out + params[f"{L}.attn.bo"]
    return out


def _ffn(params, cfg, peft, i, x):
    L = f"L{i}"
    if cfg.kind == "encoder":
        h = jax.nn.gelu(x @ params[f"{L}.mlp.w1"] + params[f"{L}.mlp.b1"])
        if peft.method == "ia3":
            h = h * params[f"{L}.ia3.lff"][None, None, :]
        return h @ params[f"{L}.mlp.w2"] + params[f"{L}.mlp.b2"]
    g = jax.nn.silu(x @ params[f"{L}.mlp.wg"])
    u = x @ params[f"{L}.mlp.wu"]
    h = g * u
    if peft.method == "ia3":
        h = h * params[f"{L}.ia3.lff"][None, None, :]
    return h @ params[f"{L}.mlp.wd"]


def encoder_fwd(params, cfg: ModelCfg, peft: PeftCfg, tokens_or_vecs):
    """Returns (pooled logits [B, n_out], final hidden [B,S,d])."""
    if cfg.input_mode == "vec":
        x = tokens_or_vecs @ params["embed.patch"]
        pad = jnp.zeros(tokens_or_vecs.shape[:2], bool)
    else:
        tokens = tokens_or_vecs
        x = params["embed.tok"][tokens]
        pad = tokens == 0
    S = x.shape[1]
    x = x + params["embed.pos"][None, :S, :]
    mask = jnp.where(pad[:, None, None, :], -1e9, 0.0)
    for i in range(cfg.layers):
        x = _layernorm(x + _attention(params, cfg, peft, i, x, mask),
                       params[f"L{i}.ln1.g"], params[f"L{i}.ln1.b"])
        x = _layernorm(x + _ffn(params, cfg, peft, i, x),
                       params[f"L{i}.ln2.g"], params[f"L{i}.ln2.b"])
    x = _layernorm(x, params["final_ln.g"], params["final_ln.b"])
    pooled = x[:, 0, :]
    logits = pooled @ params["head.w"] + params["head.b"]
    return logits, x


def decoder_fwd(params, cfg: ModelCfg, peft: PeftCfg, tokens):
    """Returns token logits [B, S, V] (tied LM head)."""
    x = params["embed.tok"][tokens]
    S = x.shape[1]
    x = x + params["embed.pos"][None, :S, :]
    causal = jnp.triu(jnp.full((S, S), -1e9), k=1)[None, None]
    pad = (tokens == 0)
    mask = causal + jnp.where(pad[:, None, None, :], -1e9, 0.0)
    for i in range(cfg.layers):
        x = x + _attention(params, cfg, peft, i, _rmsnorm(x, params[f"L{i}.rms1.g"]), mask)
        x = x + _ffn(params, cfg, peft, i, _rmsnorm(x, params[f"L{i}.rms2.g"]))
    x = _rmsnorm(x, params["final_rms.g"])
    return x @ params["embed.tok"].T


def mlp_fwd(params, cfg: ModelCfg, peft: PeftCfg, x):
    """Fig. 4 network: in -> h -> (middle op) -> h -> classes."""
    h = jax.nn.relu(x @ params["mlp.w0"] + params["mlp.b0"])
    if peft.mlp_mid == "dense":
        h2 = h @ params["mlp.w1"] + params["mlp.b1"]
    elif peft.mlp_mid == "lora":
        A = params["mlp.mid.lora.A"]
        B = params["mlp.mid.lora.B"]
        h2 = (h @ A.T) @ B.T
    elif peft.mlp_mid == "c3a":
        h2 = c3a_kernel.c3a_matvec(h, params["mlp.mid.c3a.w"])
    else:
        raise ValueError(peft.mlp_mid)
    h2 = jax.nn.relu(h2)
    return h2 @ params["mlp.w2"] + params["mlp.b2"]


# --------------------------------------------------------------------------
# Losses + steps
# --------------------------------------------------------------------------


def _ce(logits, labels):
    lp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]


def task_loss(cfg: ModelCfg, peft: PeftCfg, params, batch):
    """Returns (loss, metric_numerator) for one batch."""
    if cfg.kind == "mlp":
        logits = mlp_fwd(params, cfg, peft, batch["x"])
        loss = jnp.mean(_ce(logits, batch["y"]))
        correct = jnp.sum((jnp.argmax(logits, -1) == batch["y"]).astype(jnp.float32))
        return loss, correct
    if cfg.kind == "decoder":
        logits = decoder_fwd(params, cfg, peft, batch["tokens"])
        targets = jnp.concatenate(
            [batch["tokens"][:, 1:], jnp.zeros_like(batch["tokens"][:, :1])], axis=1)
        ce = _ce(logits, targets)
        m = batch["loss_mask"]
        loss = jnp.sum(ce * m) / jnp.maximum(jnp.sum(m), 1.0)
        pred = jnp.argmax(logits, -1)
        correct = jnp.sum((pred == targets).astype(jnp.float32) * m)
        return loss, correct
    # encoder
    inp = batch["x"] if cfg.input_mode == "vec" else batch["tokens"]
    logits, hidden = encoder_fwd(params, cfg, peft, inp)
    if cfg.head_kind == "reg":
        pred = logits[:, 0]
        loss = jnp.mean((pred - batch["y"]) ** 2)
        return loss, jnp.sum(pred)  # numerator unused for reg; PCC computed in rust
    if cfg.head_kind == "mlm":
        # masked-token pretraining: predict original token at masked slots
        voc_logits = hidden @ params["embed.tok"].T
        ce = _ce(voc_logits, batch["targets"])
        m = batch["loss_mask"]
        loss = jnp.sum(ce * m) / jnp.maximum(jnp.sum(m), 1.0)
        correct = jnp.sum((jnp.argmax(voc_logits, -1) == batch["targets"]).astype(jnp.float32) * m)
        return loss, correct
    loss = jnp.mean(_ce(logits, batch["y"]))
    correct = jnp.sum((jnp.argmax(logits, -1) == batch["y"]).astype(jnp.float32))
    return loss, correct


def adamw_update(t_params, grads, m, v, step, lr, wd,
                 beta1=0.9, beta2=0.999, eps=1e-8):
    """Standard AdamW (decoupled decay) over the trainable dict."""
    new_p, new_m, new_v = {}, {}, {}
    bc1 = 1.0 - beta1 ** step
    bc2 = 1.0 - beta2 ** step
    for k in t_params:
        g = grads[k]
        nm = beta1 * m[k] + (1 - beta1) * g
        nv = beta2 * v[k] + (1 - beta2) * (g * g)
        upd = (nm / bc1) / (jnp.sqrt(nv / bc2) + eps)
        decay = 0.0 if k.endswith((".b", ".g", ".mag", ".lb", ".ld")) else wd
        new_p[k] = t_params[k] - lr * (upd + decay * t_params[k])
        new_m[k] = nm
        new_v[k] = nv
    return new_p, new_m, new_v


def make_train_step(cfg: ModelCfg, peft: PeftCfg, data_keys):
    """Builds train_step(t_params, m, v, frozen, batch, step, lr, wd).

    Returns (new_t, new_m, new_v, loss, metric).  `data_keys` fixes the
    batch dict layout so the flattened signature is stable.
    """

    def step_fn(t_params, m, v, frozen, batch, step, lr, wd):
        def loss_fn(tp):
            params = dict(frozen)
            params.update(tp)
            return task_loss(cfg, peft, params, batch)

        (loss, metric), grads = jax.value_and_grad(loss_fn, has_aux=True)(t_params)
        new_p, new_m, new_v = adamw_update(t_params, grads, m, v, step, lr, wd)
        return new_p, new_m, new_v, loss, metric

    return step_fn


def make_eval_step(cfg: ModelCfg, peft: PeftCfg):
    """eval_step(params, batch) -> logits (encoder/mlp) or token logits (decoder)."""

    def eval_fn(params, batch):
        if cfg.kind == "mlp":
            return mlp_fwd(params, cfg, peft, batch["x"])
        if cfg.kind == "decoder":
            return decoder_fwd(params, cfg, peft, batch["tokens"])
        inp = batch["x"] if cfg.input_mode == "vec" else batch["tokens"]
        logits, _ = encoder_fwd(params, cfg, peft, inp)
        return logits

    return eval_fn
