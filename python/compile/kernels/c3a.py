"""L1 — Pallas kernels for C3A block-circular convolution.

The paper computes ``Δz_i = Σ_j Δw_ij ⋆ x_j`` with cuFFT.  TPUs have no FFT
unit, so the kernel expresses the DFT as matmuls against cos/sin Fourier
bases (see DESIGN.md §Hardware-Adaptation): every step of the operator —
forward transform, frequency-domain block aggregation, inverse transform —
is a (batched) matmul and therefore maps onto the MXU systolic array.  The
frequency-domain aggregation preserves the paper's core asymptotic win:
``O(d1·d2/b)`` instead of ``O(d1·d2)`` multiply-accumulates.

All kernels run under ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); the BlockSpec grid over the batch dimension is still what a
real TPU lowering would use, and the VMEM accounting in
:func:`vmem_footprint` is derived from it.

Backward passes follow the paper §3.3: both ``∂L/∂x`` and ``∂L/∂w`` are
again block-circular convolutions with time-reversed kernels, so the same
Pallas kernel is reused with swapped/reversed operands via
``jax.custom_vjp`` (interpret-mode Pallas has no built-in autodiff).
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "block_circular_conv",
    "c3a_matvec",
    "block_circular_conv_time",
    "dft_bases",
    "time_reverse",
    "materialize_delta",
    "vmem_footprint",
]


def dft_bases(b: int):
    """Real DFT bases: C[k,n]=cos(2πkn/b), S[k,n]=sin(2πkn/b).

    Built from ``iota`` so the lowered HLO contains no O(b²) constant blob —
    XLA folds them at compile time, and the AOT text artifacts stay small.
    """
    k = jax.lax.iota(jnp.float32, b)
    ang = (2.0 * jnp.pi / b) * (k[:, None] * k[None, :])
    return jnp.cos(ang), jnp.sin(ang)


def _c3a_kernel(x_ref, w_ref, cos_ref, sin_ref, o_ref):
    """One grid step: a batch tile of the frequency-domain operator.

    x: [Bt, n, b]   w: [m, n, b]   cos/sin: [b, b]   o: [Bt, m, b]
    """
    x = x_ref[...]
    w = w_ref[...]
    cb = cos_ref[...]
    sb = sin_ref[...]
    b = x.shape[-1]
    # Forward DFT of activations and kernels (MXU matmuls; C, S symmetric).
    xre = jnp.einsum("Bnb,kb->Bnk", x, cb)
    xim = -jnp.einsum("Bnb,kb->Bnk", x, sb)
    wre = jnp.einsum("mnb,kb->mnk", w, cb)
    wim = -jnp.einsum("mnb,kb->mnk", w, sb)
    # Frequency-domain aggregation over input blocks (the paper's O(d1*d2/b)).
    zre = jnp.einsum("Bnk,mnk->Bmk", xre, wre) - jnp.einsum("Bnk,mnk->Bmk", xim, wim)
    zim = jnp.einsum("Bnk,mnk->Bmk", xre, wim) + jnp.einsum("Bnk,mnk->Bmk", xim, wre)
    # Inverse DFT, real part.
    o_ref[...] = (
        jnp.einsum("Bmk,kb->Bmb", zre, cb) - jnp.einsum("Bmk,kb->Bmb", zim, sb)
    ) / b


def _batch_tile(batch: int) -> int:
    """Pick a batch tile: largest divisor of ``batch`` not above 128."""
    for t in range(min(batch, 128), 0, -1):
        if batch % t == 0:
            return t
    return 1


@partial(jax.custom_vjp, nondiff_argnums=())
def block_circular_conv(xb, w):
    """``z[B,m,b] = Σ_j w[m,j] ⋆ x[B,j]`` — block-circular convolution.

    Args:
      xb: activations, shape [B, n, b] (already split into n blocks).
      w:  kernels, shape [m, n, b].
    Returns:
      [B, m, b].
    """
    return _pallas_conv(xb, w)


def _pallas_conv(xb, w):
    B, n, b = xb.shape
    m = w.shape[0]
    assert w.shape == (m, n, b), (xb.shape, w.shape)
    cos_b, sin_b = dft_bases(b)
    bt = _batch_tile(B)
    grid = (B // bt,)
    return pl.pallas_call(
        _c3a_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, n, b), lambda i: (i, 0, 0)),
            pl.BlockSpec((m, n, b), lambda i: (0, 0, 0)),
            pl.BlockSpec((b, b), lambda i: (0, 0)),
            pl.BlockSpec((b, b), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, m, b), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, m, b), xb.dtype),
        interpret=True,
    )(xb, w, cos_b, sin_b)


def time_reverse(a):
    """w̃[t] = w[(-t) mod b] on the last axis — adjoint kernel (paper §3.3)."""
    return jnp.roll(jnp.flip(a, axis=-1), 1, axis=-1)


def _conv_fwd(xb, w):
    return _pallas_conv(xb, w), (xb, w)


def _conv_bwd(res, g):
    xb, w = res
    # ∂L/∂x[B,n] = Σ_m w̃[m,n] ⋆ g[B,m]  (adjoint of C(w) is C(w̃))
    wt = time_reverse(jnp.swapaxes(w, 0, 1))  # [n, m, b]
    dx = _pallas_conv(g, wt)
    # ∂L/∂w[m,n] = Σ_B g[B,m] ⋆ x̃[B,n]  (batch is the reduction axis)
    xt = time_reverse(jnp.swapaxes(xb, 0, 1))  # [n, B, b]
    dw = _pallas_conv(jnp.swapaxes(g, 0, 1), xt)
    return dx, dw


block_circular_conv.defvjp(_conv_fwd, _conv_bwd)


def c3a_matvec(x, w):
    """Flat-vector convenience: x [..., n*b], w [m, n, b] -> [..., m*b].

    Collapses all leading axes into the kernel's batch dimension.
    """
    m, n, b = w.shape
    lead = x.shape[:-1]
    xb = x.reshape((-1, n, b))
    out = block_circular_conv(xb, w)
    return out.reshape(lead + (m * b,))


def _c3a_time_kernel(x_ref, w_ref, idx_ref, o_ref):
    """Time-domain ablation kernel: materialized circulant blocks + matmul.

    idx[b,b] holds (r - c) mod b so that C(w)[r, c] = w[idx[r, c]]; the gather
    plus dot is the 'mechanical port' baseline the DFT-matmul kernel is
    compared against (O(d1*d2) MACs instead of O(d1*d2/b)).
    """
    x = x_ref[...]  # [Bt, n, b]
    w = w_ref[...]  # [m, n, b]
    idx = idx_ref[...]  # [b, b] int32
    circ = w[..., idx]  # [m, n, b, b]; circ[m,n,r,c] = C(w_mn)[r,c]
    o_ref[...] = jnp.einsum("Bnc,mnrc->Bmr", x, circ)


def block_circular_conv_time(xb, w):
    """Time-domain variant of :func:`block_circular_conv` (ablation only)."""
    B, n, b = xb.shape
    m = w.shape[0]
    r = jax.lax.iota(jnp.int32, b)
    idx = jnp.mod(r[:, None] - r[None, :], b)
    bt = _batch_tile(B)
    return pl.pallas_call(
        _c3a_time_kernel,
        grid=(B // bt,),
        in_specs=[
            pl.BlockSpec((bt, n, b), lambda i: (i, 0, 0)),
            pl.BlockSpec((m, n, b), lambda i: (0, 0, 0)),
            pl.BlockSpec((b, b), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, m, b), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, m, b), xb.dtype),
        interpret=True,
    )(xb, w, idx)


def materialize_delta(w):
    """ΔW = C_blk(Δw) via the paper's Algorithm A2 (convolve identity columns).

    Returns the dense [m*b, n*b] delta matrix.  Used by merge tests; the
    rust coordinator has its own FFT-based implementation for deployment.
    """
    m, n, b = w.shape
    eye = jnp.eye(n * b, dtype=w.dtype)  # columns e_i
    cols = c3a_matvec(eye, w)  # row i = C_blk(w) e_i  => transpose
    return cols.T


def vmem_footprint(batch_tile: int, m: int, n: int, b: int, bytes_per=4):
    """Estimated VMEM bytes per grid step of the DFT-matmul kernel.

    x-tile + w + two bases + out-tile + the four frequency intermediates.
    Used by DESIGN/EXPERIMENTS for the TPU feasibility estimate.
    """
    x_t = batch_tile * n * b
    w_t = m * n * b
    bases = 2 * b * b
    out_t = batch_tile * m * b
    freq = 2 * batch_tile * n * b + 2 * m * n * b + 2 * batch_tile * m * b
    return (x_t + w_t + bases + out_t + freq) * bytes_per
