"""Pure-jnp correctness oracles for the C3A operator.

Two independent references:

* :func:`conv_fft` — the paper's Eq. (1): FFT → frequency-domain Hadamard /
  block aggregation → inverse FFT (complex arithmetic via ``jnp.fft``).
* :func:`conv_dense` — the literal definition: materialize every circulant
  block ``C(Δw_ij)`` (Eq. 4) and multiply.

The Pallas kernel must agree with both; the two must agree with each other.
Also hosts small reference utilities used by the pytest suite (circulant
construction, rank via DFT eigenvalues).
"""

import jax.numpy as jnp
import numpy as np

__all__ = [
    "conv_fft",
    "conv_dense",
    "circulant",
    "block_circulant",
    "circulant_rank",
]


def conv_fft(xb, w):
    """Block-circular convolution via jnp.fft: xb [B,n,b], w [m,n,b] -> [B,m,b]."""
    Xf = jnp.fft.fft(xb, axis=-1)
    Wf = jnp.fft.fft(w, axis=-1)
    Zf = jnp.einsum("Bnk,mnk->Bmk", Xf, Wf)
    return jnp.fft.ifft(Zf, axis=-1).real.astype(xb.dtype)


def circulant(w):
    """Circulant matrix of the convolution ``z = w ⋆ x``: C[r, c] = w[(r-c) mod b].

    Note on conventions: the paper (§3.2) writes C(w) with *first row* w,
    which corresponds to circular **correlation**; its FFT identity (Eq. 1)
    and reference implementation (Alg. A1) compute the standard circular
    **convolution**.  The two are related by the time-reversal
    reparameterization w ↔ w̃ (w̃[t] = w[(-t) mod b]) which the optimizer
    absorbs; rank properties are identical (DFT zero patterns are conjugate
    reflections).  This repo standardizes on the convolution convention:
    first *column* is w.
    """
    w = np.asarray(w)
    b = w.shape[-1]
    r = np.arange(b)
    idx = (r[:, None] - r[None, :]) % b
    return w[idx]


def block_circulant(w):
    """C_blk(w) per paper Eq. (4): dense [m*b, n*b] from kernels [m,n,b]."""
    w = np.asarray(w)
    m, n, b = w.shape
    out = np.zeros((m * b, n * b), dtype=w.dtype)
    for i in range(m):
        for j in range(n):
            out[i * b : (i + 1) * b, j * b : (j + 1) * b] = circulant(w[i, j])
    return out


def conv_dense(xb, w):
    """Oracle via the materialized block-circulant matrix."""
    B, n, b = xb.shape
    m = w.shape[0]
    mat = block_circulant(np.asarray(w))
    flat = np.asarray(xb).reshape(B, n * b)
    return (flat @ mat.T).reshape(B, m, b)


def circulant_rank(w, tol=1e-6):
    """rank C(w) = #nonzero DFT coefficients (paper §3.2 / Ingleton 1956)."""
    eig = np.fft.fft(np.asarray(w, dtype=np.float64))
    scale = max(1.0, float(np.max(np.abs(eig))))
    return int(np.sum(np.abs(eig) > tol * scale))
