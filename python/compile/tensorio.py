"""C3AT tensor container — the interchange/checkpoint binary format.

Written by the build path (initial model parameters) and by the rust
coordinator (checkpoints); read by both.  Layout (little-endian):

    magic   b"C3AT"
    u32     version (1)
    u32     tensor count
    per tensor:
        u32   name length, then name bytes (utf-8)
        u8    dtype: 0 = f32, 1 = i32
        u32   ndim, then ndim × u64 dims
        raw   data (product(dims) × 4 bytes, LE)
"""

import struct

import numpy as np

MAGIC = b"C3AT"
_DTYPES = {0: np.float32, 1: np.int32}
_CODES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1}


def save(path, tensors):
    """Write an ordered ``{name: np.ndarray}`` mapping."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<II", 1, len(tensors)))
        for name, arr in tensors.items():
            shape = np.asarray(arr).shape
            # NB: ascontiguousarray promotes 0-d arrays to 1-d; keep the
            # recorded shape authoritative
            arr = np.ascontiguousarray(arr)
            if arr.dtype not in _CODES:
                raise ValueError(f"{name}: unsupported dtype {arr.dtype}")
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<B", _CODES[arr.dtype]))
            f.write(struct.pack("<I", len(shape)))
            for d in shape:
                f.write(struct.pack("<Q", d))
            f.write(arr.tobytes())


def load(path):
    """Read back a ``{name: np.ndarray}`` dict (insertion-ordered)."""
    out = {}
    with open(path, "rb") as f:
        if f.read(4) != MAGIC:
            raise ValueError(f"{path}: bad magic")
        version, count = struct.unpack("<II", f.read(8))
        if version != 1:
            raise ValueError(f"{path}: unsupported version {version}")
        for _ in range(count):
            (nlen,) = struct.unpack("<I", f.read(4))
            name = f.read(nlen).decode("utf-8")
            (code,) = struct.unpack("<B", f.read(1))
            (ndim,) = struct.unpack("<I", f.read(4))
            dims = tuple(struct.unpack(f"<{ndim}Q", f.read(8 * ndim))) if ndim else ()
            dt = _DTYPES[code]
            n = int(np.prod(dims)) if dims else 1
            data = np.frombuffer(f.read(4 * n), dtype=dt).reshape(dims)
            out[name] = data.copy()
    return out
