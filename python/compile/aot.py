"""AOT lowering: JAX train/eval steps -> HLO *text* artifacts + manifest.

HLO text (not ``HloModuleProto.serialize()``) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the published ``xla`` rust crate) rejects; the
text parser reassigns ids and round-trips cleanly.

For every (model, PEFT method, head, kind) combination this emits
``artifacts/<name>.hlo.txt`` plus one ``artifacts/manifest.json`` that the
rust coordinator uses to map named parameters onto positional PJRT inputs
and to initialize adapters (init specs are declarative; rust owns the RNG).

Run via ``make artifacts``; incremental — artifacts are skipped when
already present unless ``--force``.
"""

import argparse
import hashlib
import json
import os
import sys
import time
from dataclasses import asdict, replace

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import tensorio
from .model import (
    MODEL_PRESETS,
    ModelCfg,
    PeftCfg,
    adapter_param_shapes,
    base_param_shapes,
    init_base_params,
    make_eval_step,
    make_train_step,
    split_roles,
    trainable_param_count,
)

# ---------------------------------------------------------------------------
# Experiment inventory: which artifacts exist (DESIGN.md §4 drives this).
# ---------------------------------------------------------------------------

ENC_METHODS = {
    "full": PeftCfg("full"),
    "bitfit": PeftCfg("bitfit"),
    "ia3": PeftCfg("ia3"),
    "lora": PeftCfg("lora", rank=8, alpha=16.0),
    "vera": PeftCfg("vera"),  # r_v resolved per model (2d)
    "boft": PeftCfg("boft", boft_block=8),
    "c3a_d1": PeftCfg("c3a", block=0),  # block = d ("b = d/1")
    "c3a_d8": PeftCfg("c3a"),  # block = d/8, resolved per model
}

DEC_METHODS = {
    "lora": PeftCfg("lora", rank=32, alpha=64.0),
    "vera": PeftCfg("vera"),  # r_v = 4d
    "dora": PeftCfg("dora", rank=32, alpha=64.0),
    "c3a": PeftCfg("c3a"),  # block = d/32
}

VIT_METHODS = {
    "head": PeftCfg("head"),
    "full": PeftCfg("full"),
    "lora": PeftCfg("lora", rank=16, alpha=32.0),
    "c3a": PeftCfg("c3a"),  # block = d/8
}

MLP_VARIANTS = {
    "dense": PeftCfg("full", mlp_mid="dense"),
    "lora": PeftCfg("full", rank=1, mlp_mid="lora"),
    "c3a": PeftCfg("full", block=64, mlp_mid="c3a"),
}

TRAIN_BATCH = {"encoder": 32, "decoder": 16, "mlp": 64}


def resolve_peft(model_name: str, cfg: ModelCfg, method_name: str, peft: PeftCfg) -> PeftCfg:
    """Fill model-dependent hyperparameters (block sizes, r_v)."""
    if peft.method == "c3a" and peft.mlp_mid != "c3a":
        if method_name == "c3a_d1":
            return replace(peft, block=cfg.d)
        if method_name == "c3a_d8":
            return replace(peft, block=cfg.d // 8)
        if cfg.kind == "decoder":
            return replace(peft, block=cfg.d // 32)
        return replace(peft, block=max(cfg.d // 8, 2))
    if peft.method == "vera":
        rv = 4 * cfg.d if cfg.kind == "decoder" else 2 * cfg.d
        return replace(peft, r_v=rv)
    return peft


# ---------------------------------------------------------------------------
# Init specs (declarative; rust owns the RNG)
# ---------------------------------------------------------------------------


def init_spec(name: str, shape):
    if ".lora.A" in name or ".dora.A" in name:
        return {"kind": "normal_fanin", "fan": shape[1]}
    if ".lora.B" in name or ".boft.skew" in name:
        return {"kind": "zeros"}
    if ".dora.mag" in name or ".vera.lb" in name or ".ia3." in name:
        return {"kind": "ones"}
    if ".vera.ld" in name:
        return {"kind": "const", "value": 0.1}
    if ".c3a.w" in name:
        m_, n_, b_ = shape
        return {"kind": "c3a", "fan_in": n_ * b_, "fan_out": m_ * b_}
    if name in ("vera.A", "vera.B"):
        return {"kind": "normal_fanin", "fan": shape[-1], "seed": 1234}
    return {"kind": "zeros"}


# ---------------------------------------------------------------------------
# Data input layouts per (kind, head)
# ---------------------------------------------------------------------------


def data_inputs(cfg: ModelCfg, head: str, batch: int, kind: str = "train"):
    """Data input layout.  Eval artifacts carry only the model inputs —
    labels/masks are unused by the forward pass and XLA would DCE the
    parameters away, breaking the positional input contract."""
    S = cfg.seq
    if cfg.kind == "mlp":
        items = [("data.x", (batch, cfg.mlp_in), "f32"), ("data.y", (batch,), "i32")]
        return items[:1] if kind == "eval" else items
    if cfg.kind == "decoder":
        items = [("data.tokens", (batch, S), "i32"), ("data.loss_mask", (batch, S), "f32")]
        return items[:1] if kind == "eval" else items
    if head == "mlm":
        return [
            ("data.tokens", (batch, S), "i32"),
            ("data.targets", (batch, S), "i32"),
            ("data.loss_mask", (batch, S), "f32"),
        ]
    items = []
    if cfg.input_mode == "vec":
        items.append(("data.x", (batch, S, cfg.patch_dim), "f32"))
    else:
        items.append(("data.tokens", (batch, S), "i32"))
    if kind != "eval":
        items.append(("data.y", (batch,), "f32" if head == "reg" else "i32"))
    return items


def batch_from_leaves(cfg: ModelCfg, head: str, names, leaves):
    return {n.split("data.", 1)[1]: v for n, v in zip(names, leaves)}


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.int32 if dtype == "i32" else jnp.float32)


def build_artifact(out_dir, name, model_name, cfg, method_name, peft, head, kind, force):
    """Lower one artifact; returns its manifest entry."""
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    eff_cfg = cfg
    if head in ("cls", "reg", "mlm", "lm", "vec"):
        hk = {"vec": "cls"}.get(head, head)
        eff_cfg = replace(cfg, head_kind=hk)
    t_shapes, f_shapes, fr_shapes = split_roles(eff_cfg, peft)
    batch = TRAIN_BATCH[cfg.kind]
    d_inputs = data_inputs(eff_cfg, head, batch, kind)
    d_names = [n for n, _, _ in d_inputs]

    t_names = list(t_shapes)
    f_names = list(f_shapes) + list(fr_shapes)
    all_f_shapes = {**f_shapes, **fr_shapes}

    inputs = []
    for n in t_names:
        inputs.append({"name": n, "shape": list(t_shapes[n]), "dtype": "f32", "role": "trainable",
                       "init": init_spec(n, t_shapes[n])})
    if kind == "train":
        for role in ("opt_m", "opt_v"):
            for n in t_names:
                inputs.append({"name": f"{role}:{n}", "shape": list(t_shapes[n]),
                               "dtype": "f32", "role": role, "init": {"kind": "zeros"}})
    for n in f_names:
        role = "frozen_random" if n in fr_shapes else "frozen"
        inputs.append({"name": n, "shape": list(all_f_shapes[n]), "dtype": "f32",
                       "role": role, "init": init_spec(n, all_f_shapes[n])})
    for n, shp, dt in d_inputs:
        inputs.append({"name": n, "shape": list(shp), "dtype": dt, "role": "data"})
    if kind == "train":
        # `wd` is DCE'd from the lowered HLO when no trainable receives
        # decoupled decay (e.g. decoder VeRA: all λ params are exempt) —
        # emit it only when used so the positional contract holds.
        uses_wd = any(
            not n.endswith((".b", ".g", ".mag", ".lb", ".ld")) for n in t_names
        )
        scalars = ("step", "lr", "wd") if uses_wd else ("step", "lr")
        for n in scalars:
            inputs.append({"name": n, "shape": [], "dtype": "f32", "role": "scalar"})

    if kind == "train":
        outputs = (
            [{"name": n, "role": "new_trainable"} for n in t_names]
            + [{"name": f"opt_m:{n}", "role": "new_opt_m"} for n in t_names]
            + [{"name": f"opt_v:{n}", "role": "new_opt_v"} for n in t_names]
            + [{"name": "loss", "role": "loss"}, {"name": "metric", "role": "metric"}]
        )
    else:
        outputs = [{"name": "logits", "role": "logits"}]

    entry = {
        "name": name,
        "path": f"{name}.hlo.txt",
        "model": model_name,
        "method": method_name,
        "peft": asdict(peft),
        "kind": kind,
        "head": head,
        "batch": batch,
        "seq": eff_cfg.seq,
        "n_params": trainable_param_count(eff_cfg, peft),
        "inputs": inputs,
        "outputs": outputs,
    }
    if os.path.exists(path) and not force:
        return entry

    nt = len(t_names)
    nf = len(f_names)
    nd = len(d_names)

    if kind == "train":
        step_fn = make_train_step(eff_cfg, peft, d_names)

        def flat_fn(*args):
            i = 0
            tp = dict(zip(t_names, args[i : i + nt])); i += nt
            m = dict(zip(t_names, args[i : i + nt])); i += nt
            v = dict(zip(t_names, args[i : i + nt])); i += nt
            fr = dict(zip(f_names, args[i : i + nf])); i += nf
            batch_d = batch_from_leaves(eff_cfg, head, d_names, args[i : i + nd]); i += nd
            scal = args[i:]
            step, lr = scal[0], scal[1]
            wd = scal[2] if len(scal) > 2 else jnp.float32(0.0)
            new_p, new_m, new_v, loss, metric = step_fn(tp, m, v, fr, batch_d, step, lr, wd)
            return (
                tuple(new_p[n] for n in t_names)
                + tuple(new_m[n] for n in t_names)
                + tuple(new_v[n] for n in t_names)
                + (loss, metric)
            )
    else:
        eval_fn = make_eval_step(eff_cfg, peft)

        def flat_fn(*args):
            i = 0
            tp = dict(zip(t_names, args[i : i + nt])); i += nt
            fr = dict(zip(f_names, args[i : i + nf])); i += nf
            params = {**fr, **tp}
            batch_d = batch_from_leaves(eff_cfg, head, d_names, args[i : i + nd])
            return (eval_fn(params, batch_d),)

    specs = [_spec(tuple(e["shape"]), e["dtype"]) for e in inputs]
    t0 = time.time()
    lowered = jax.jit(flat_fn).lower(*specs)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    print(f"  {name}: {len(text)/1e6:.2f} MB HLO in {time.time()-t0:.1f}s", flush=True)
    return entry


# ---------------------------------------------------------------------------
# Inventory assembly
# ---------------------------------------------------------------------------


def inventory():
    """Yields (model_name, method_name, peft, head, kind)."""
    jobs = []
    # encoder GLUE-sim suites (+ tiny for tests)
    for model in ("enc_tiny", "enc_base", "enc_large"):
        cfg = MODEL_PRESETS[model]
        methods = ENC_METHODS
        heads = ("cls", "reg")
        for mn, p in methods.items():
            for head in heads:
                jobs.append((model, mn, p, head, "train"))
                jobs.append((model, mn, p, head, "eval"))
        # MLM pretrain (full-parameter)
        jobs.append((model, "full", ENC_METHODS["full"], "mlm", "train"))
    # decoder instruction suites
    for model in ("dec_small", "dec_large"):
        for mn, p in DEC_METHODS.items():
            jobs.append((model, mn, p, "lm", "train"))
            jobs.append((model, mn, p, "lm", "eval"))
        jobs.append((model, "full", PeftCfg("full"), "lm", "train"))  # LM pretrain
    # ViT-sim suites
    for model in ("vit_base", "vit_large"):
        for mn, p in VIT_METHODS.items():
            jobs.append((model, mn, p, "vec", "train"))
            jobs.append((model, mn, p, "vec", "eval"))
    # Fig-4 MLP variants
    for mn, p in MLP_VARIANTS.items():
        jobs.append(("mlp", f"mlp_{mn}", p, "cls", "train"))
        jobs.append(("mlp", f"mlp_{mn}", p, "cls", "eval"))
    return jobs


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--only", default="", help="substring filter on artifact names")
    args = ap.parse_args()
    out_dir = os.path.abspath(args.out)
    os.makedirs(out_dir, exist_ok=True)

    entries = []
    models_meta = {}
    jobs = inventory()
    print(f"lowering {len(jobs)} artifacts -> {out_dir}")
    for model, mn, peft0, head, kind in jobs:
        cfg = MODEL_PRESETS[model]
        peft = resolve_peft(model, cfg, mn, peft0)
        name = f"{model}__{mn}__{head}__{kind}"
        if args.only and args.only not in name:
            continue
        entries.append(build_artifact(out_dir, name, model, cfg, mn, peft, head, kind, args.force))
        if model not in models_meta:
            init_path = os.path.join(out_dir, f"{model}_init.bin")
            if not os.path.exists(init_path) or args.force:
                tensorio.save(init_path, init_base_params(cfg, seed=0))
            models_meta[model] = {
                "cfg": asdict(cfg),
                "init": f"{model}_init.bin",
                "base_params": {k: list(v) for k, v in base_param_shapes(cfg).items()},
            }

    manifest = {"version": 1, "models": models_meta, "artifacts": entries}
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(entries)} artifacts, {len(models_meta)} models")


if __name__ == "__main__":
    main()
