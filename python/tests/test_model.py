"""L2 model tests: PEFT parameterizations, param counts, training dynamics."""

import math
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    MODEL_PRESETS,
    ModelCfg,
    PeftCfg,
    adamw_update,
    adapter_param_shapes,
    base_param_shapes,
    decoder_fwd,
    encoder_fwd,
    init_adapter_params,
    init_base_params,
    make_eval_step,
    make_train_step,
    mlp_fwd,
    split_roles,
    trainable_param_count,
)

TINY = MODEL_PRESETS["enc_tiny"]
METHODS = ["full", "head", "bitfit", "ia3", "lora", "dora", "vera", "boft", "c3a"]


def full_params(cfg, peft, seed=0):
    p = init_base_params(cfg, seed)
    p.update(init_adapter_params(cfg, peft, seed))
    return {k: jnp.asarray(v) for k, v in p.items()}


def tiny_batch(cfg, rng, head="cls"):
    B = 8
    tokens = rng.randint(1, cfg.vocab, (B, cfg.seq)).astype(np.int32)
    if head == "reg":
        y = rng.randn(B).astype(np.float32)
    else:
        y = rng.randint(0, cfg.n_out, (B,)).astype(np.int32)
    return {"tokens": jnp.asarray(tokens), "y": jnp.asarray(y)}


# ------------------------- parameter accounting -------------------------


def test_c3a_param_count_formula():
    """#params = d1*d2/b per adapted matrix (paper §3.4)."""
    cfg = replace(MODEL_PRESETS["enc_base"], layers=3)
    for b in (128, 16, 8):
        peft = PeftCfg("c3a", block=b)
        n = trainable_param_count(cfg, peft)
        assert n == 3 * 2 * (cfg.d * cfg.d // b)


def test_lora_param_count_formula():
    cfg = replace(MODEL_PRESETS["enc_base"], layers=2)
    peft = PeftCfg("lora", rank=8)
    assert trainable_param_count(cfg, peft) == 2 * 2 * 8 * (cfg.d + cfg.d)


def test_vera_param_count_small():
    """VeRA trainables are r_v + d per adapted matrix — tiny vs LoRA."""
    cfg = MODEL_PRESETS["enc_base"]
    vera = trainable_param_count(cfg, PeftCfg("vera", r_v=2 * cfg.d))
    lora = trainable_param_count(cfg, PeftCfg("lora", rank=8))
    assert vera < lora


def test_c3a_half_of_lora_at_d8():
    """The paper's headline: C3A b=d/8 uses half of LoRA r=8's params."""
    cfg = MODEL_PRESETS["enc_base"]
    c = trainable_param_count(cfg, PeftCfg("c3a", block=cfg.d // 8))
    l = trainable_param_count(cfg, PeftCfg("lora", rank=8))
    assert c * 2 == l


def test_paper_roberta_base_param_count():
    """Sanity against the paper's Table 2 numbers at real RoBERTa dims:
    C3A b=768/1 over 12 layers x2 matrices = 18,432 ≈ 0.018M."""
    cfg = ModelCfg("encoder", vocab=50265, d=768, layers=12, heads=12, seq=512)
    n = trainable_param_count(cfg, PeftCfg("c3a", block=768))
    assert n == 18432
    n8 = trainable_param_count(cfg, PeftCfg("lora", rank=8))
    assert n8 == 294912  # 0.295M, matches Table 2


@pytest.mark.parametrize("method", METHODS)
def test_split_roles_partition(method):
    peft = PeftCfg(method, block=8, rank=2, r_v=16, boft_block=8)
    t, f, fr = split_roles(TINY, peft)
    base = set(base_param_shapes(TINY))
    at, afr = adapter_param_shapes(TINY, peft)
    assert set(t) | set(f) == base | set(at)
    assert not (set(t) & set(f))
    assert set(fr) == set(afr)
    # head is always trainable
    assert "head.w" in t and "head.b" in t


# ------------------------- forward shapes -------------------------


@pytest.mark.parametrize("method", METHODS)
def test_encoder_forward_shapes(method):
    peft = PeftCfg(method, block=8, rank=2, r_v=16, boft_block=8)
    params = full_params(TINY, peft)
    rng = np.random.RandomState(0)
    batch = tiny_batch(TINY, rng)
    logits, hidden = encoder_fwd(params, TINY, peft, batch["tokens"])
    assert logits.shape == (8, TINY.n_out)
    assert hidden.shape == (8, TINY.seq, TINY.d)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_decoder_forward_shapes():
    cfg = replace(MODEL_PRESETS["dec_small"], d=32, layers=2, heads=2, seq=12, vocab=64)
    peft = PeftCfg("c3a", block=8)
    params = full_params(cfg, peft)
    tokens = jnp.asarray(np.random.RandomState(0).randint(1, 64, (4, 12)), jnp.int32)
    logits = decoder_fwd(params, cfg, peft, tokens)
    assert logits.shape == (4, 12, 64)


def test_decoder_causality():
    """Changing a future token must not change past logits."""
    cfg = replace(MODEL_PRESETS["dec_small"], d=32, layers=2, heads=2, seq=10, vocab=64)
    peft = PeftCfg("lora", rank=2)
    params = full_params(cfg, peft)
    rng = np.random.RandomState(1)
    t1 = rng.randint(1, 64, (2, 10)).astype(np.int32)
    t2 = t1.copy()
    t2[:, 7:] = rng.randint(1, 64, (2, 3))
    l1 = decoder_fwd(params, cfg, peft, jnp.asarray(t1))
    l2 = decoder_fwd(params, cfg, peft, jnp.asarray(t2))
    np.testing.assert_allclose(l1[:, :7], l2[:, :7], atol=1e-5)


def test_zero_adapter_matches_base_c3a_lora():
    """Zero-initialized additive adapters leave the function unchanged."""
    rng = np.random.RandomState(2)
    batch = tiny_batch(TINY, rng)
    base_logits = None
    for method, extra in (("lora", {}), ("c3a", {"block": 8})):
        peft = PeftCfg(method, rank=2, **extra)
        params = full_params(TINY, peft)
        # zero the additive pieces
        for k in list(params):
            if ".lora.B" in k:
                params[k] = jnp.zeros_like(params[k])
            if ".c3a.w" in k:
                params[k] = jnp.zeros_like(params[k])
        logits, _ = encoder_fwd(params, TINY, peft, batch["tokens"])
        if base_logits is None:
            ref_params = full_params(TINY, PeftCfg("head"))
            base_logits, _ = encoder_fwd(ref_params, TINY, PeftCfg("head"), batch["tokens"])
        np.testing.assert_allclose(logits, base_logits, atol=1e-4)


def test_boft_orthogonality_preserves_norm_at_init():
    """BOFT at zero skew is the identity rotation."""
    peft = PeftCfg("boft", boft_block=8)
    params = full_params(TINY, peft)
    rng = np.random.RandomState(3)
    batch = tiny_batch(TINY, rng)
    l1, _ = encoder_fwd(params, TINY, peft, batch["tokens"])
    base = full_params(TINY, PeftCfg("head"))
    l2, _ = encoder_fwd(base, TINY, PeftCfg("head"), batch["tokens"])
    np.testing.assert_allclose(l1, l2, atol=1e-4)


# ------------------------- training dynamics -------------------------


@pytest.mark.parametrize("method", ["lora", "c3a", "vera", "bitfit", "ia3"])
def test_loss_decreases(method):
    peft = PeftCfg(method, block=8, rank=2, r_v=16)
    t_shapes, f_shapes, fr_shapes = split_roles(TINY, peft)
    params = full_params(TINY, peft)
    tp = {k: params[k] for k in t_shapes}
    fz = {k: params[k] for k in list(f_shapes) + list(fr_shapes)}
    m = {k: jnp.zeros_like(v) for k, v in tp.items()}
    v = {k: jnp.zeros_like(v_) for k, v_ in tp.items()}
    rng = np.random.RandomState(4)
    batch = tiny_batch(TINY, rng)
    step_fn = jax.jit(make_train_step(TINY, peft, ["tokens", "y"]))
    losses = []
    for i in range(30):
        tp, m, v, loss, _ = step_fn(tp, m, v, fz, batch, jnp.float32(i + 1),
                                    jnp.float32(2e-2), jnp.float32(0.0))
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.7, losses[:3] + losses[-3:]


def test_frozen_params_stay_frozen():
    peft = PeftCfg("c3a", block=8)
    t_shapes, f_shapes, fr_shapes = split_roles(TINY, peft)
    params = full_params(TINY, peft)
    tp = {k: params[k] for k in t_shapes}
    fz = {k: params[k] for k in list(f_shapes) + list(fr_shapes)}
    m = {k: jnp.zeros_like(x) for k, x in tp.items()}
    v = {k: jnp.zeros_like(x) for k, x in tp.items()}
    rng = np.random.RandomState(5)
    batch = tiny_batch(TINY, rng)
    step_fn = make_train_step(TINY, peft, ["tokens", "y"])
    new_tp, _, _, _, _ = step_fn(tp, m, v, fz, batch, jnp.float32(1),
                                 jnp.float32(1e-2), jnp.float32(0.0))
    # trainables moved, frozen dict untouched by construction (pure fn)
    moved = any(float(jnp.max(jnp.abs(new_tp[k] - tp[k]))) > 0 for k in tp)
    assert moved


def test_adamw_matches_reference_implementation():
    rng = np.random.RandomState(6)
    p = {"w": jnp.asarray(rng.randn(4, 3), jnp.float32)}
    g = {"w": jnp.asarray(rng.randn(4, 3), jnp.float32)}
    m = {"w": jnp.asarray(rng.randn(4, 3), jnp.float32) * 0.1}
    v = {"w": jnp.asarray(np.abs(rng.randn(4, 3)), jnp.float32) * 0.1}
    lr, wd, t = 1e-2, 0.1, 3.0
    new_p, new_m, new_v = adamw_update(p, g, m, v, jnp.float32(t), lr, wd)
    # reference
    b1, b2, eps = 0.9, 0.999, 1e-8
    nm = b1 * np.asarray(m["w"]) + (1 - b1) * np.asarray(g["w"])
    nv = b2 * np.asarray(v["w"]) + (1 - b2) * np.asarray(g["w"]) ** 2
    upd = (nm / (1 - b1**t)) / (np.sqrt(nv / (1 - b2**t)) + eps)
    want = np.asarray(p["w"]) - lr * (upd + wd * np.asarray(p["w"]))
    np.testing.assert_allclose(new_p["w"], want, atol=1e-6)


def test_weight_decay_skips_gains_and_biases():
    p = {"x.g": jnp.ones((3,)), "x.w": jnp.ones((3,))}
    g = {k: jnp.zeros((3,)) for k in p}
    m = {k: jnp.zeros((3,)) for k in p}
    v = {k: jnp.zeros((3,)) for k in p}
    new_p, _, _ = adamw_update(p, g, m, v, jnp.float32(1), 0.1, 0.5)
    assert float(new_p["x.g"][0]) == 1.0  # no decay on gains
    assert float(new_p["x.w"][0]) < 1.0  # decayed


def test_mlm_pretrain_step_runs():
    cfg = replace(TINY, head_kind="mlm")
    peft = PeftCfg("full")
    t_shapes, f_shapes, fr_shapes = split_roles(cfg, peft)
    params = full_params(cfg, peft)
    tp = {k: params[k] for k in t_shapes}
    fz = {}
    m = {k: jnp.zeros_like(x) for k, x in tp.items()}
    v = {k: jnp.zeros_like(x) for k, x in tp.items()}
    rng = np.random.RandomState(7)
    B = 8
    tokens = rng.randint(1, cfg.vocab, (B, cfg.seq)).astype(np.int32)
    batch = {
        "tokens": jnp.asarray(tokens),
        "targets": jnp.asarray(tokens),
        "loss_mask": jnp.asarray((rng.rand(B, cfg.seq) < 0.15).astype(np.float32)),
    }
    step_fn = make_train_step(cfg, peft, list(batch))
    tp2, _, _, loss, _ = step_fn(tp, m, v, fz, batch, jnp.float32(1),
                                 jnp.float32(1e-3), jnp.float32(0.0))
    assert np.isfinite(float(loss))


def test_mlp_variants_forward():
    cfg = MODEL_PRESETS["mlp"]
    rng = np.random.RandomState(8)
    x = jnp.asarray(rng.randn(16, 2), jnp.float32)
    for mid, extra in (("dense", {}), ("lora", {"rank": 1}), ("c3a", {"block": 64})):
        peft = PeftCfg("full", mlp_mid=mid, **extra)
        params = full_params(cfg, peft)
        logits = mlp_fwd(params, cfg, peft, x)
        assert logits.shape == (16, 8)


def test_eval_step_logits():
    peft = PeftCfg("lora", rank=2)
    params = full_params(TINY, peft)
    rng = np.random.RandomState(9)
    batch = tiny_batch(TINY, rng)
    logits = make_eval_step(TINY, peft)(params, batch)
    assert logits.shape == (8, TINY.n_out)
