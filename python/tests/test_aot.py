"""AOT layer tests: manifest contract, tensor container interop, init
specs — validated against the real artifacts/ directory when present."""

import json
import os

import numpy as np
import pytest

from compile import tensorio
from compile.aot import (
    DEC_METHODS,
    ENC_METHODS,
    data_inputs,
    init_spec,
    inventory,
    resolve_peft,
)
from compile.model import MODEL_PRESETS, PeftCfg, split_roles

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART, "manifest.json")

needs_artifacts = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="run `make artifacts` first"
)


def test_inventory_names_unique():
    jobs = inventory()
    names = [f"{m}__{mn}__{h}__{k}" for m, mn, _, h, k in jobs]
    assert len(names) == len(set(names))
    assert len(names) > 100  # the full suite


def test_resolve_peft_blocks_divide():
    for model, cfg in MODEL_PRESETS.items():
        if cfg.kind != "encoder":
            continue
        for mn, p in ENC_METHODS.items():
            r = resolve_peft(model, cfg, mn, p)
            if r.method == "c3a":
                assert cfg.d % r.block == 0, (model, mn, r.block)
    for model in ("dec_small", "dec_large"):
        cfg = MODEL_PRESETS[model]
        r = resolve_peft(model, cfg, "c3a", DEC_METHODS["c3a"])
        assert cfg.d % r.block == 0
        assert r.block == cfg.d // 32  # the paper's b = d/32 setting


def test_eval_inputs_drop_labels():
    cfg = MODEL_PRESETS["enc_base"]
    train = data_inputs(cfg, "cls", 32, "train")
    ev = data_inputs(cfg, "cls", 32, "eval")
    assert [n for n, _, _ in train] == ["data.tokens", "data.y"]
    assert [n for n, _, _ in ev] == ["data.tokens"]
    dec = MODEL_PRESETS["dec_small"]
    assert len(data_inputs(dec, "lm", 16, "eval")) == 1


def test_init_specs_cover_all_adapter_params():
    for method in ("lora", "dora", "vera", "boft", "ia3", "c3a"):
        peft = PeftCfg(method, block=16, rank=4, r_v=32)
        t, f, fr = split_roles(MODEL_PRESETS["enc_base"], peft)
        for name, shape in {**t, **fr}.items():
            spec = init_spec(name, shape)
            assert "kind" in spec, name


def test_tensorio_roundtrip_matches_numpy():
    path = "/tmp/c3a_tio_test.bin"
    data = {
        "a": np.arange(12, dtype=np.float32).reshape(3, 4),
        "ids": np.array([1, -2, 3], dtype=np.int32),
        "scalar": np.float32(7.5).reshape(()),
    }
    tensorio.save(path, data)
    back = tensorio.load(path)
    assert list(back) == sorted(data) or list(back) == list(data)
    np.testing.assert_array_equal(back["a"], data["a"])
    np.testing.assert_array_equal(back["ids"], data["ids"])
    assert back["scalar"].shape == ()


@needs_artifacts
def test_manifest_contract():
    m = json.load(open(MANIFEST))
    assert m["version"] == 1
    assert set(m["models"]) >= {"enc_tiny", "enc_base", "dec_small", "mlp"}
    by_name = {a["name"]: a for a in m["artifacts"]}
    # train/eval pairing
    for a in m["artifacts"]:
        if a["kind"] == "train" and a["head"] != "mlm":
            twin = a["name"].replace("__train", "__eval")
            if a["method"] == "full" and a["head"] == "lm":
                continue  # decoder pretrain has no eval twin
            assert twin in by_name, twin
    # positional contract: roles appear in fixed block order
    order = ["trainable", "opt_m", "opt_v", "frozen", "frozen_random", "data", "scalar"]
    for a in m["artifacts"]:
        roles = [i["role"] for i in a["inputs"]]
        idx = [order.index(r) for r in roles]
        assert idx == sorted(idx), a["name"]


@needs_artifacts
def test_init_bins_match_declared_shapes():
    m = json.load(open(MANIFEST))
    for name, meta in m["models"].items():
        bin_path = os.path.join(ART, meta["init"])
        tensors = tensorio.load(bin_path)
        for pname, shape in meta["base_params"].items():
            assert pname in tensors, (name, pname)
            assert list(tensors[pname].shape) == shape
            assert np.all(np.isfinite(tensors[pname]))


@needs_artifacts
def test_artifact_files_exist_and_nonempty():
    m = json.load(open(MANIFEST))
    for a in m["artifacts"]:
        p = os.path.join(ART, a["path"])
        assert os.path.exists(p), a["name"]
        assert os.path.getsize(p) > 1000
