"""L1 kernel correctness: Pallas block-circular conv vs two oracles.

The core correctness signal of the whole stack — every HLO artifact embeds
this kernel, so any disagreement here poisons everything downstream.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import c3a, ref

ATOL = 2e-4


def rand(rng, *shape):
    return jnp.asarray(rng.randn(*shape), jnp.float32)


@pytest.mark.parametrize(
    "B,m,n,b",
    [
        (1, 1, 1, 1),
        (2, 1, 1, 8),
        (4, 2, 2, 16),
        (8, 3, 5, 12),  # non-square block grid, non-pow2 b
        (16, 4, 4, 32),
        (3, 2, 2, 7),  # prime b
        (128, 1, 1, 64),  # batch > tile
    ],
)
def test_pallas_vs_oracles(B, m, n, b):
    rng = np.random.RandomState(B * 1000 + m * 100 + n * 10 + b)
    xb = rand(rng, B, n, b)
    w = rand(rng, m, n, b)
    got = c3a.block_circular_conv(xb, w)
    want_fft = ref.conv_fft(xb, w)
    want_dense = jnp.asarray(ref.conv_dense(xb, w))
    np.testing.assert_allclose(got, want_fft, atol=ATOL)
    np.testing.assert_allclose(got, want_dense, atol=ATOL)
    np.testing.assert_allclose(want_fft, want_dense, atol=ATOL)


@settings(max_examples=30, deadline=None)
@given(
    B=st.integers(1, 9),
    m=st.integers(1, 4),
    n=st.integers(1, 4),
    b=st.sampled_from([1, 2, 3, 4, 5, 8, 11, 16]),
    seed=st.integers(0, 2**16),
)
def test_pallas_vs_fft_hypothesis(B, m, n, b, seed):
    rng = np.random.RandomState(seed)
    xb = rand(rng, B, n, b)
    w = rand(rng, m, n, b)
    got = c3a.block_circular_conv(xb, w)
    want = ref.conv_fft(xb, w)
    np.testing.assert_allclose(got, want, atol=ATOL)


def test_time_domain_variant_matches():
    rng = np.random.RandomState(0)
    xb = rand(rng, 8, 3, 16)
    w = rand(rng, 2, 3, 16)
    np.testing.assert_allclose(
        c3a.block_circular_conv_time(xb, w), ref.conv_fft(xb, w), atol=ATOL
    )


def test_single_block_equals_plain_circular_conv():
    """m = n = 1 degenerates to the paper's §3.2 square case."""
    rng = np.random.RandomState(3)
    x = rand(rng, 5, 1, 24)
    w = rand(rng, 1, 1, 24)
    got = np.asarray(c3a.block_circular_conv(x, w))[:, 0]
    C = ref.circulant(np.asarray(w)[0, 0])
    want = np.asarray(x)[:, 0] @ C.T
    np.testing.assert_allclose(got, want, atol=ATOL)


def test_commutativity():
    """w ⋆ x = x ⋆ w (paper §3.3 uses this for the backward pass)."""
    rng = np.random.RandomState(4)
    a = rand(rng, 1, 1, 32)
    b_ = rand(rng, 1, 1, 32)
    z1 = c3a.block_circular_conv(a, b_)  # [1,1,32] is both a batch and a kernel
    z2 = c3a.block_circular_conv(b_, a)
    np.testing.assert_allclose(z1, z2, atol=ATOL)


def test_linearity_in_both_args():
    rng = np.random.RandomState(5)
    x1, x2 = rand(rng, 4, 2, 8), rand(rng, 4, 2, 8)
    w = rand(rng, 3, 2, 8)
    lhs = c3a.block_circular_conv(x1 + 2.0 * x2, w)
    rhs = c3a.block_circular_conv(x1, w) + 2.0 * c3a.block_circular_conv(x2, w)
    np.testing.assert_allclose(lhs, rhs, atol=ATOL)


def test_identity_kernel_is_noop():
    """w = e_0 in every diagonal block, zero off-diagonal -> z = x."""
    rng = np.random.RandomState(6)
    n, b = 3, 16
    x = rand(rng, 4, n, b)
    w = np.zeros((n, n, b), np.float32)
    for i in range(n):
        w[i, i, 0] = 1.0
    np.testing.assert_allclose(c3a.block_circular_conv(x, jnp.asarray(w)), x, atol=ATOL)


def test_shift_kernel_rolls():
    """w = e_1 circularly shifts each block by one (convolution direction)."""
    rng = np.random.RandomState(7)
    b = 8
    x = rand(rng, 2, 1, b)
    w = np.zeros((1, 1, b), np.float32)
    w[0, 0, 1] = 1.0
    got = np.asarray(c3a.block_circular_conv(x, jnp.asarray(w)))
    want = np.roll(np.asarray(x), 1, axis=-1)
    np.testing.assert_allclose(got, want, atol=ATOL)


# --------------------------- gradients ---------------------------


def test_custom_vjp_matches_fft_autodiff():
    rng = np.random.RandomState(8)
    B, m, n, b = 6, 3, 2, 12
    xb, w = rand(rng, B, n, b), rand(rng, m, n, b)
    t = rand(rng, B, m, b)

    def loss_k(w_, x_):
        return jnp.mean((c3a.block_circular_conv(x_, w_) - t) ** 2)

    def loss_r(w_, x_):
        return jnp.mean((ref.conv_fft(x_, w_) - t) ** 2)

    gk = jax.grad(loss_k, argnums=(0, 1))(w, xb)
    gr = jax.grad(loss_r, argnums=(0, 1))(w, xb)
    np.testing.assert_allclose(gk[0], gr[0], atol=ATOL)
    np.testing.assert_allclose(gk[1], gr[1], atol=ATOL)


def test_grad_numerical():
    """Finite-difference check on a tiny case."""
    rng = np.random.RandomState(9)
    xb, w = rand(rng, 2, 1, 4), rand(rng, 1, 1, 4)

    def f(w_):
        return float(jnp.sum(c3a.block_circular_conv(xb, w_) ** 3))

    g = jax.grad(lambda w_: jnp.sum(c3a.block_circular_conv(xb, w_) ** 3))(w)
    eps = 1e-3
    for i in range(4):
        wp = np.asarray(w).copy()
        wp[0, 0, i] += eps
        wm = np.asarray(w).copy()
        wm[0, 0, i] -= eps
        num = (f(jnp.asarray(wp)) - f(jnp.asarray(wm))) / (2 * eps)
        assert abs(num - float(g[0, 0, i])) < 5e-2, (i, num, float(g[0, 0, i]))


def test_grad_through_second_order_not_required_but_jit_safe():
    """jit(grad(...)) of the kernel lowers and executes."""
    rng = np.random.RandomState(10)
    xb, w = rand(rng, 4, 2, 8), rand(rng, 2, 2, 8)
    g = jax.jit(jax.grad(lambda w_: jnp.sum(c3a.block_circular_conv(xb, w_) ** 2)))(w)
    assert g.shape == w.shape and bool(jnp.all(jnp.isfinite(g)))


# --------------------------- structure ---------------------------


def test_materialize_delta_matches_block_circulant():
    rng = np.random.RandomState(11)
    w = rand(rng, 3, 2, 8)
    np.testing.assert_allclose(
        np.asarray(c3a.materialize_delta(w)), ref.block_circulant(np.asarray(w)), atol=ATOL
    )


def test_matvec_flattening_equivalence():
    rng = np.random.RandomState(12)
    w = rand(rng, 2, 3, 8)
    x = rand(rng, 5, 3 * 8)
    y1 = np.asarray(c3a.c3a_matvec(x, w))
    y2 = np.asarray(x) @ ref.block_circulant(np.asarray(w)).T
    np.testing.assert_allclose(y1, y2, atol=ATOL)


def test_matvec_leading_axes():
    rng = np.random.RandomState(13)
    w = rand(rng, 2, 2, 8)
    x = rand(rng, 3, 5, 16)
    y = c3a.c3a_matvec(x, w)
    assert y.shape == (3, 5, 16)
    y2 = c3a.c3a_matvec(x.reshape(15, 16), w).reshape(3, 5, 16)
    np.testing.assert_allclose(y, y2, atol=ATOL)


def test_rank_full_for_generic_kernel():
    rng = np.random.RandomState(14)
    w = rng.randn(64)
    assert ref.circulant_rank(w) == 64


def test_rank_deficient_kernels():
    # constant kernel -> rank 1 (only DC coefficient nonzero)
    assert ref.circulant_rank(np.ones(16)) == 1
    # zero-mean kernel kills the DC coefficient
    w = np.random.RandomState(15).randn(16)
    w -= w.mean()
    assert ref.circulant_rank(w) == 15
    # alternating +1/-1 -> single nonzero bin at Nyquist
    alt = np.array([1.0, -1.0] * 8)
    assert ref.circulant_rank(alt) == 1


def test_vmem_footprint_fits_budget():
    """The DESIGN.md TPU feasibility estimate: base config fits 16 MiB VMEM."""
    # enc_base c3a_d8: d=128, b=16, m=n=8, batch tile 32
    assert c3a.vmem_footprint(32, 8, 8, 16) < 16 * 2**20
    # dec_large c3a: d=320, b=10, m=n=32
    assert c3a.vmem_footprint(16, 32, 32, 10) < 16 * 2**20
