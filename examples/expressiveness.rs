//! Figure 4 as a standalone example: the expressiveness gap between
//! LoRA r=1 and C3A b=128/2 at an equal parameter budget.
//!
//!     cargo run --release --example expressiveness

use c3a::coordinator::lr::Schedule;
use c3a::coordinator::run::{self, Ctx};
use c3a::coordinator::TrainCfg;

fn main() -> anyhow::Result<()> {
    let ctx = Ctx::open("artifacts")?;
    let cfg = TrainCfg {
        steps: 400,
        lr: 2e-2,
        weight_decay: 0.0,
        schedule: Schedule::Constant,
        eval_every: 100,
        patience: 0,
        verbose: false,
    };
    println!("{:<10} {:>10} {:>12} {:>12}", "mid-op", "params", "final loss", "train acc");
    for variant in ["mlp_dense", "mlp_lora", "mlp_c3a"] {
        let r = run::mlp_run(&ctx, variant, 0, &cfg)?;
        println!(
            "{:<10} {:>10} {:>12.4} {:>12.3}",
            variant.trim_start_matches("mlp_"),
            match variant {
                "mlp_dense" => 128 * 128,
                "mlp_lora" => 2 * 128,
                _ => 128 * 128 / 64,
            },
            r.losses.last().unwrap(),
            r.metric
        );
    }
    println!("\nLoRA r=1 and C3A b=128/2 use the same 256-parameter budget for the");
    println!("middle layer; only C3A reaches the dense layer's accuracy (paper Fig 4).");
    Ok(())
}
