//! Serving demo — a thin CLI over `c3a::serving`: fine-tune one adapter,
//! derive N tenant variants, serve batched classification requests through
//! the bounded scheduler queue (dynamic batching + `try_submit`
//! backpressure), hot-swap one tenant mid-stream, and report
//! latency/throughput percentiles plus per-tenant upload counts.  Writes
//! `BENCH_serve.json` (override with `C3A_BENCH_SERVE_OUT`) so CI can
//! archive the smoke run.
//!
//!     cargo run --release --example serve -- \
//!         [--requests 256] [--tenants 3] [--pretrain-steps 200]

use c3a::coordinator::run::{self, Ctx};
use c3a::data::glue_sim::GlueTask;
use c3a::peft::init::C3aScheme;
use c3a::runtime::manifest::Manifest;
use c3a::runtime::session::build_init;
use c3a::serving::{
    AdapterRegistry, Scheduler, SchedulerCfg, SubmitError, perturb_c3a_kernels as perturb,
};
use c3a::substrate::prng::Rng;
use c3a::substrate::tensor::TensorMap;
use std::time::{Duration, Instant};

fn flag(args: &[String], name: &str) -> Option<usize> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).and_then(|v| v.parse().ok())
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n_requests = flag(&args, "--requests").unwrap_or(128);
    let n_tenants = flag(&args, "--tenants").unwrap_or(3).max(1);

    let (model, method, task) = ("enc_tiny", "c3a_d8", GlueTask::Sst2);

    // fine-tune one adapter (pretrain cached), then derive tenant variants
    eprintln!("preparing adapter ({model}/{method})...");
    let mut ctx = Ctx::open("artifacts")?;
    // demo default: a short pretrain budget keeps the smoke run fast; the
    // checkpoint is cached under a budget-keyed name, so a later full run
    // is unaffected
    ctx.pretrain_steps = Some(flag(&args, "--pretrain-steps").unwrap_or(200));
    let cfg = run::default_cfg(method, 60);
    let run_out = run::glue_run(&ctx, model, method, task, 0, &cfg, C3aScheme::Xavier)?;
    eprintln!("adapter ready (test metric {:.3})", run_out.metric);

    let meta = ctx.manifest.model(model)?.clone();
    let eval_name = Manifest::artifact_name(model, method, task.head(), "eval");
    let backbone = run::ensure_pretrained(&ctx, model)?;
    let adapters: Vec<(String, TensorMap)> = (0..n_tenants)
        .map(|i| {
            let params = if i == 0 {
                run_out.trainable.clone()
            } else {
                perturb(&run_out.trainable, i as u64, 0.05)
            };
            (format!("tenant{i}"), params)
        })
        .collect();

    // the registry lives on the scheduler thread (sessions are not Send);
    // the builder gets plain tensors and opens its own Ctx over the cached
    // artifacts
    let sched_cfg =
        SchedulerCfg { queue_cap: 64, max_batch: 0, max_wait: Duration::from_millis(2) };
    let sched = Scheduler::spawn(sched_cfg, {
        let adapters = adapters.clone();
        let eval_name = eval_name.clone();
        move || {
            let ctx = Ctx::open("artifacts")?;
            let spec = ctx.manifest.artifact(&eval_name)?.clone();
            let mut rng = Rng::seed(1);
            let init = build_init(&spec, &backbone, None, &mut rng, C3aScheme::Xavier)?;
            let mut registry = AdapterRegistry::new(&ctx.engine, &spec, &init)?;
            for (name, params) in adapters {
                registry.register(&name, params)?;
            }
            Ok(registry)
        }
    })?;
    let handle = sched.handle();

    let splits = task.splits(meta.vocab, meta.seq, 99);
    let tokens = &splits.test.tokens;
    let t_start = Instant::now();
    let mut tickets = Vec::with_capacity(n_requests);
    let mut shed_retries = 0usize;
    for i in 0..n_requests {
        let tenant = format!("tenant{}", i % n_tenants);
        // mid-stream hot swap: tenant0 gets a new adapter version half-way
        if i == n_requests / 2 {
            let v = handle.hot_swap("tenant0", perturb(&adapters[0].1, 7, 0.02))?;
            eprintln!("hot-swapped tenant0 -> v{v}");
        }
        let toks = tokens[i % tokens.len()].clone();
        loop {
            match handle.try_submit(&tenant, toks.clone()) {
                Ok(t) => {
                    tickets.push(t);
                    break;
                }
                Err(SubmitError::QueueFull) => {
                    // backpressure: the demo retries; a real frontend
                    // would shed or 429
                    shed_retries += 1;
                    std::thread::sleep(Duration::from_micros(200));
                }
                Err(e) => return Err(e.into()),
            }
        }
    }
    let mut correct = 0usize;
    for (i, t) in tickets.into_iter().enumerate() {
        let r = t.wait()?;
        if r.pred == splits.test.labels[i % splits.test.len()] as usize {
            correct += 1;
        }
    }
    let total_s = t_start.elapsed().as_secs_f64();
    drop(handle);
    let stats = sched.finish()?;
    let lat = stats.latency();
    let req_per_s = n_requests as f64 / total_s;

    println!("\n=== serve report ===");
    println!("requests      : {n_requests}  ({n_tenants} tenants)");
    println!("accuracy      : {:.3}", correct as f64 / n_requests as f64);
    println!("throughput    : {req_per_s:.1} req/s");
    println!("threads       : {}", c3a::substrate::parallel::threads());
    println!("mean batch    : {:.1}", stats.mean_batch());
    println!("shed retries  : {shed_retries}");
    println!("latency p50   : {:.1} ms", lat.p50_ms);
    println!("latency p95   : {:.1} ms", lat.p95_ms);
    println!("latency p99   : {:.1} ms", lat.p99_ms);
    // one upload per adapter version: tenant0 was swapped once mid-stream
    // (2 versions), every other tenant served its whole stream on 1
    for t in &stats.tenants {
        println!(
            "tenant {:<9}: {:>4} reqs  v{}  uploads={}  spectra {}h/{}m",
            t.name, t.requests, t.version, t.uploads, t.spectra_hits, t.spectra_misses
        );
    }

    let uploads: Vec<String> =
        stats.tenants.iter().map(|t| format!("\"{}\": {}", t.name, t.uploads)).collect();
    let json = format!(
        "{{\n  \"bench\": \"serve_example\",\n  \"requests\": {n_requests},\n  \"tenants\": {n_tenants},\n  \"threads\": {},\n  \"req_per_s\": {req_per_s:.1},\n  \"accuracy\": {:.4},\n  \"mean_batch\": {:.2},\n  \"shed_retries\": {shed_retries},\n  \"p50_ms\": {:.3},\n  \"p95_ms\": {:.3},\n  \"p99_ms\": {:.3},\n  \"uploads\": {{ {} }}\n}}\n",
        c3a::substrate::parallel::threads(),
        correct as f64 / n_requests as f64,
        stats.mean_batch(),
        lat.p50_ms,
        lat.p95_ms,
        lat.p99_ms,
        uploads.join(", ")
    );
    let out = std::env::var("C3A_BENCH_SERVE_OUT").unwrap_or_else(|_| "BENCH_serve.json".into());
    std::fs::write(&out, &json)?;
    println!("\nwrote {out}");
    Ok(())
}
