//! Serving demo: fine-tune an adapter, then serve batched classification
//! requests from a producer thread through an in-process request queue
//! (std mpsc; tokio unavailable offline) with dynamic batching, and report
//! latency/throughput percentiles.
//!
//!     cargo run --release --example serve [-- --requests 256]

use c3a::coordinator::run::{self, Ctx};
use c3a::data::glue_sim::GlueTask;
use c3a::peft::init::C3aScheme;
use c3a::runtime::manifest::Manifest;
use c3a::runtime::session::{build_init, EvalSession};
use c3a::substrate::prng::Rng;
use c3a::substrate::tensor::Tensor;
use std::sync::mpsc;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n_requests: usize = args
        .iter()
        .position(|a| a == "--requests")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(128);

    let ctx = Ctx::open("artifacts")?;
    let (model, method, task) = ("enc_tiny", "c3a_d8", GlueTask::Sst2);

    // fine-tune quickly (pretrain is cached) to obtain an adapter to serve
    eprintln!("preparing adapter ({model}/{method})...");
    let cfg = run::default_cfg(method, 60);
    let run_out = run::glue_run(&ctx, model, method, task, 0, &cfg, C3aScheme::Xavier)?;
    eprintln!("adapter ready (test metric {:.3})", run_out.metric);

    // build the serving session around the *trained* adapter snapshot
    let meta = ctx.manifest.model(model)?.clone();
    let eval_spec = ctx
        .manifest
        .artifact(&Manifest::artifact_name(model, method, task.head(), "eval"))?
        .clone();
    let backbone = run::ensure_pretrained(&ctx, model)?;
    let mut rng = Rng::seed(1);
    let init = build_init(&eval_spec, &backbone, Some(&run_out.trainable), &mut rng, C3aScheme::Xavier)?;
    let session = EvalSession::new(&ctx.engine, &eval_spec, &init)?;
    let served_params = run_out.trainable;

    // producer thread enqueues single requests; the server drains the
    // queue into dynamic batches of up to the artifact batch size.
    let (tx, rx) = mpsc::channel::<(usize, Vec<i32>, Instant)>();
    let splits = task.splits(meta.vocab, meta.seq, 99);
    let producer = std::thread::spawn({
        let tokens = splits.test.tokens.clone();
        move || {
            for i in 0..n_requests {
                let t = tokens[i % tokens.len()].clone();
                if tx.send((i, t, Instant::now())).is_err() {
                    return;
                }
                if i % 16 == 0 {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                }
            }
        }
    });

    let b = eval_spec.batch;
    let s = eval_spec.seq;
    let t_start = Instant::now();
    let mut latencies = Vec::with_capacity(n_requests);
    let mut batch_sizes = Vec::new();
    let mut correct = 0usize;
    let mut served = 0usize;
    let mut queue: Vec<(usize, Vec<i32>, Instant)> = Vec::new();
    while served < n_requests {
        if queue.is_empty() {
            // block for the first request instead of burning a core, then
            // opportunistically drain whatever else arrived (dynamic batch)
            match rx.recv_timeout(std::time::Duration::from_millis(5)) {
                Ok(item) => queue.push(item),
                Err(mpsc::RecvTimeoutError::Timeout) => continue,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        while queue.len() < b {
            match rx.try_recv() {
                Ok(item) => queue.push(item),
                Err(_) => break,
            }
        }
        let take = queue.len().min(b);
        let batch_items: Vec<_> = queue.drain(..take).collect();
        let mut toks = vec![0i32; b * s];
        for (slot, (_, t, _)) in batch_items.iter().enumerate() {
            let n = t.len().min(s);
            toks[slot * s..slot * s + n].copy_from_slice(&t[..n]);
        }
        let batch = vec![Tensor::from_i32(vec![b, s], &toks)];
        let (logits, shape) = session.logits(&served_params, &batch)?;
        let width = shape[1];
        let now = Instant::now();
        for (slot, (req_id, _, t0)) in batch_items.iter().enumerate() {
            let pred = c3a::substrate::linalg::argmax(&logits[slot * width..(slot + 1) * width]);
            if pred == splits.test.labels[req_id % splits.test.len()] as usize {
                correct += 1;
            }
            latencies.push(now.duration_since(*t0).as_secs_f64() * 1e3);
        }
        batch_sizes.push(batch_items.len());
        served += batch_items.len();
    }
    producer.join().unwrap();

    if latencies.is_empty() {
        println!("\n=== serve report ===\nno requests served");
        return Ok(());
    }
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let total_s = t_start.elapsed().as_secs_f64();
    let pct = |p: f64| latencies[(p * (latencies.len() - 1) as f64) as usize];
    println!("\n=== serve report ===");
    println!("requests      : {n_requests}");
    println!("accuracy      : {:.3}", correct as f64 / n_requests as f64);
    println!("throughput    : {:.1} req/s", n_requests as f64 / total_s);
    println!("threads       : {}", c3a::substrate::parallel::threads());
    // the session caches the adapter upload + frozen parse + kernel
    // spectra: a fixed adapter must upload exactly once however many
    // batches were served
    println!("uploads       : {} (adapter reuse)", session.upload_count());
    println!("mean batch    : {:.1}", batch_sizes.iter().sum::<usize>() as f64 / batch_sizes.len() as f64);
    println!("latency p50   : {:.1} ms", pct(0.50));
    println!("latency p95   : {:.1} ms", pct(0.95));
    println!("latency p99   : {:.1} ms", pct(0.99));
    Ok(())
}
