//! Serving demo — a thin CLI over `c3a::serving`: fine-tune one adapter,
//! derive N tenant variants, and replay a seeded Zipf traffic storm
//! (bursty arrivals, a mid-storm hot-swap of a Zipf-hot tenant) against
//! the sharded scheduler.  `--shards N` spreads the tenants over N
//! tenant-affine workers (each parses its own frozen backbone); shed
//! backpressure is handled with bounded exponential backoff — never a hot
//! spin — and every shed/drop is reported.  `--max-resident K` caps each
//! shard's resident sessions: the rest of the tenants live as checksummed
//! snapshots in the adapter store (`--store-dir`, default
//! `artifacts/adapter_store`) and reload bit-identically through the
//! measured cold-start path.  Writes `BENCH_serve.json` (override with
//! `C3A_BENCH_SERVE_OUT`) so CI can archive the smoke run.
//!
//!     cargo run --release --example serve -- \
//!         [--requests 128] [--tenants 3] [--shards 1] [--pretrain-steps 200] \
//!         [--max-resident 2] [--store-dir artifacts/adapter_store]

use c3a::coordinator::run::{self, Ctx};
use c3a::data::glue_sim::GlueTask;
use c3a::peft::init::C3aScheme;
use c3a::runtime::manifest::Manifest;
use c3a::runtime::session::build_init;
use c3a::serving::{
    perturb_c3a_kernels as perturb, run_replay, tenant_name, AdapterRegistry, AdapterStore,
    ReplayCfg, ResidentPolicy, Scheduler, SchedulerCfg, ShardCtx,
};
use c3a::substrate::env;
use c3a::substrate::prng::Rng;
use c3a::substrate::tensor::TensorMap;
use std::path::PathBuf;
use std::time::Duration;

fn flag(args: &[String], name: &str) -> Option<usize> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).and_then(|v| v.parse().ok())
}

fn str_flag(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).cloned()
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n_requests = flag(&args, "--requests").unwrap_or(128);
    let n_tenants = flag(&args, "--tenants").unwrap_or(3).max(1);
    let n_shards = flag(&args, "--shards").unwrap_or(1).max(1);
    // 0 (default) keeps every tenant resident; K > 0 caps each shard's
    // resident sessions and spills the rest to the adapter store
    let max_resident = flag(&args, "--max-resident").unwrap_or(0);
    let store_dir = PathBuf::from(
        str_flag(&args, "--store-dir").unwrap_or_else(|| "artifacts/adapter_store".into()),
    );

    let (model, method, task) = ("enc_tiny", "c3a_d8", GlueTask::Sst2);

    // fine-tune one adapter (pretrain cached), then derive tenant variants
    eprintln!("preparing adapter ({model}/{method})...");
    let mut ctx = Ctx::open("artifacts")?;
    // demo default: a short pretrain budget keeps the smoke run fast; the
    // checkpoint is cached under a budget-keyed name, so a later full run
    // is unaffected
    ctx.pretrain_steps = Some(flag(&args, "--pretrain-steps").unwrap_or(200));
    let cfg = run::default_cfg(method, 60);
    let run_out = run::glue_run(&ctx, model, method, task, 0, &cfg, C3aScheme::Xavier)?;
    eprintln!("adapter ready (test metric {:.3})", run_out.metric);

    let meta = ctx.manifest.model(model)?.clone();
    let eval_name = Manifest::artifact_name(model, method, task.head(), "eval");
    let backbone = run::ensure_pretrained(&ctx, model)?;
    let adapters: Vec<(String, TensorMap)> = (0..n_tenants)
        .map(|i| {
            let params = if i == 0 {
                run_out.trainable.clone()
            } else {
                perturb(&run_out.trainable, i as u64, 0.05)
            };
            (tenant_name(i), params)
        })
        .collect();

    // registries live on the shard worker threads (sessions are not Send);
    // the builder runs once per shard, opens its own Ctx over the cached
    // artifacts, and registers only the tenants that hash to its shard
    let sched_cfg = SchedulerCfg {
        shards: n_shards,
        queue_cap: 64,
        max_batch: 0,
        max_wait: Duration::from_millis(2),
    };
    if max_resident > 0 {
        // start from an empty store so every snapshot in it is this run's
        let _ = std::fs::remove_dir_all(&store_dir);
    }
    let sched = Scheduler::spawn(sched_cfg, {
        let adapters = adapters.clone();
        let eval_name = eval_name.clone();
        let store_dir = store_dir.clone();
        move |shard: &ShardCtx| {
            let ctx = Ctx::open("artifacts")?;
            let spec = ctx.manifest.artifact(&eval_name)?.clone();
            let mut rng = Rng::seed(1);
            let init = build_init(&spec, &backbone, None, &mut rng, C3aScheme::Xavier)?;
            let mut registry = AdapterRegistry::new(&ctx.engine, &spec, &init)?;
            // residency before registration: tenants then start as store
            // snapshots and materialize through the cold-start path.  All
            // shards share one dir — tenant routing is a partition, so
            // their files never collide.
            if max_resident > 0 {
                registry.set_residency(
                    ResidentPolicy::max_resident(max_resident),
                    AdapterStore::open(&store_dir)?,
                )?;
            }
            for (name, params) in &adapters {
                if shard.owns(name) {
                    registry.register(name, params.clone())?;
                }
            }
            Ok(registry)
        }
    })?;
    let handle = sched.handle();

    let splits = task.splits(meta.vocab, meta.seq, 99);
    let tokens = splits.test.tokens.clone();
    let replay_cfg = ReplayCfg {
        seed: 42,
        requests: n_requests,
        tenants: n_tenants,
        zipf_exponent: 1.1,
        burst: 16,
        burst_gap: Duration::from_micros(200),
        // one hot-swap lands mid-storm, on a Zipf-hot tenant
        swap_every: (n_requests / 2).max(1),
        ..ReplayCfg::default()
    };
    let swap_base = run_out.trainable.clone();
    let report = run_replay(
        &handle,
        &replay_cfg,
        |i, _rank| tokens[i % tokens.len()].clone(),
        move |swap_idx, _rank| perturb(&swap_base, 7 + swap_idx, 0.02),
    )?;
    drop(handle);
    let stats = sched.finish()?;

    let labels = &splits.test.labels;
    let correct = report
        .preds
        .iter()
        .enumerate()
        .filter(|(i, p)| **p == Some(labels[i % labels.len()] as usize))
        .count();
    let accuracy = correct as f64 / n_requests as f64;
    let lat = stats.latency();
    let cold = stats.cold_start_latency();
    let req_per_s = report.req_per_s();
    let resident_now = stats.resident_now();
    let evicted_now = n_tenants.saturating_sub(resident_now);

    println!("\n=== serve report ===");
    println!("requests      : {n_requests}  ({n_tenants} Zipf tenants, {n_shards} shards)");
    println!("accuracy      : {accuracy:.3}");
    println!("throughput    : {req_per_s:.1} req/s");
    println!("threads       : {}", c3a::substrate::parallel::threads());
    println!("mean batch    : {:.1}", stats.mean_batch());
    println!("swaps         : {}", report.swaps);
    println!("sheds/dropped : {} / {}", report.sheds, report.dropped);
    println!("latency p50   : {:.1} ms", lat.p50_ms);
    println!("latency p95   : {:.1} ms", lat.p95_ms);
    println!("latency p99   : {:.1} ms", lat.p99_ms);
    if max_resident > 0 {
        println!(
            "resident      : {resident_now} now / {evicted_now} evicted  (hwm {}, cap {max_resident}/shard)",
            stats.resident_hwm()
        );
        println!("evictions     : {}", stats.evictions);
        println!(
            "cold starts   : {}  (p50 {:.1} ms, p95 {:.1} ms)",
            stats.cold_starts, cold.p50_ms, cold.p95_ms
        );
    }
    for sh in &stats.shards {
        println!(
            "shard {}      : {:>4} served  {:>2} batches  depth hwm {:>3}  sheds {}",
            sh.shard, sh.served, sh.batches, sh.queue_depth_hwm, sh.sheds
        );
    }
    // uploads track adapter versions plus tier churn: the swapped tenant
    // gains a version mid-storm, and every cold start re-uploads the
    // reloaded snapshot; a never-swapped, never-evicted tenant serves its
    // whole stream on 1
    for t in &stats.tenants {
        println!(
            "tenant {:<9}: {:>4} reqs  shard {}  v{}  uploads={}  spectra {}h/{}m  sheds {}  {}",
            t.name,
            t.requests,
            t.shard,
            t.version,
            t.uploads,
            t.spectra_hits,
            t.spectra_misses,
            t.sheds,
            if t.resident {
                "resident".to_string()
            } else {
                format!("evicted (cold starts {})", t.cold_starts)
            }
        );
    }

    let uploads: Vec<String> =
        stats.tenants.iter().map(|t| format!("\"{}\": {}", t.name, t.uploads)).collect();
    let per_shard: Vec<String> = stats
        .shards
        .iter()
        .map(|sh| {
            format!(
                "{{ \"shard\": {}, \"served\": {}, \"queue_depth_hwm\": {}, \"sheds\": {} }}",
                sh.shard, sh.served, sh.queue_depth_hwm, sh.sheds
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"serve_example\",\n  \"requests\": {n_requests},\n  \"tenants\": {n_tenants},\n  \"shards\": {n_shards},\n  \"max_resident\": {max_resident},\n  \"threads\": {},\n  \"trace_hash\": \"{:#018x}\",\n  \"req_per_s\": {req_per_s:.1},\n  \"accuracy\": {accuracy:.4},\n  \"mean_batch\": {:.2},\n  \"swaps\": {},\n  \"sheds\": {},\n  \"dropped\": {},\n  \"p50_ms\": {:.3},\n  \"p95_ms\": {:.3},\n  \"p99_ms\": {:.3},\n  \"resident_now\": {resident_now},\n  \"resident_hwm\": {},\n  \"evictions\": {},\n  \"cold_starts\": {},\n  \"cold_start_ms_p95\": {:.3},\n  \"per_shard\": [{}],\n  \"uploads\": {{ {} }}\n}}\n",
        c3a::substrate::parallel::threads(),
        report.trace_hash,
        stats.mean_batch(),
        report.swaps,
        report.sheds,
        report.dropped,
        lat.p50_ms,
        lat.p95_ms,
        lat.p99_ms,
        stats.resident_hwm(),
        stats.evictions,
        stats.cold_starts,
        cold.p95_ms,
        per_shard.join(", "),
        uploads.join(", ")
    );
    let out = env::bench_serve_out();
    std::fs::write(&out, &json)?;
    println!("\nwrote {out}");
    Ok(())
}
