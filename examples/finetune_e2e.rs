//! End-to-end driver (DESIGN.md §5): pretrain a real (small) transformer
//! from scratch on the procedural corpus, fine-tune it with LoRA and C3A
//! on a GLUE-sim task, log the loss curves, evaluate, and verify the
//! merge path — proving all three layers compose.
//!
//!     cargo run --release --example finetune_e2e [-- --model enc_base --steps 120]

use c3a::coordinator::run::{self, Ctx};
use c3a::data::glue_sim::GlueTask;
use c3a::peft::init::C3aScheme;
use c3a::peft::merge;
use c3a::substrate::prng::Rng;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let get = |k: &str, dflt: &str| -> String {
        args.iter()
            .position(|a| a == k)
            .and_then(|i| args.get(i + 1))
            .cloned()
            .unwrap_or_else(|| dflt.to_string())
    };
    // enc_base learns too, but needs --steps well over 100 on one core
    let model = get("--model", "enc_tiny");
    let steps: usize = get("--steps", "250").parse()?;
    let methods = ["lora", "c3a_d8"];

    let mut ctx = Ctx::open("artifacts")?;
    ctx.verbose = true;

    // Phase 1: pretraining (cached across runs)
    eprintln!("--- phase 1: pretrain {model} ---");
    let backbone = run::ensure_pretrained(&ctx, &model)?;
    eprintln!("backbone: {} tensors", backbone.len());

    // Phase 2: fine-tune each method, logging loss curves
    let task = GlueTask::Mrpc;
    let mut best: Option<(String, run::RunResult)> = None;
    for method in methods {
        eprintln!("\n--- phase 2: fine-tune {method} on {} ({steps} steps) ---", task.name());
        let mut cfg = run::default_cfg(method, steps);
        cfg.verbose = true;
        let r = run::glue_run(&ctx, &model, method, task, 0, &cfg, C3aScheme::Xavier)?;
        eprintln!(
            "{method}: test {:.3}  (#params {}, {:.0} ms/step)",
            r.metric, r.n_params, r.step_ms
        );
        let n = r.losses.len();
        let curve: Vec<String> = (0..12)
            .map(|i| format!("{:.3}", r.losses[(i * (n - 1)) / 11]))
            .collect();
        eprintln!("loss curve: {}", curve.join(" "));
        if best.as_ref().map(|(_, b)| r.metric > b.metric).unwrap_or(true) {
            best = Some((method.to_string(), r));
        }
    }
    let (best_method, best_r) = best.unwrap();
    println!("\nwinner: {best_method} at test metric {:.3}", best_r.metric);

    // Phase 3: merge demo — fold a block-circulant delta into a dense W
    // and verify zero-overhead inference parity (rust substrate path).
    eprintln!("\n--- phase 3: merge parity check ---");
    let mut rng = Rng::seed(42);
    let (m, n, b) = (4usize, 4usize, 16usize);
    let (d_in, d_out) = (n * b, m * b);
    let w0: Vec<f32> = (0..d_in * d_out).map(|_| rng.normal() as f32 * 0.05).collect();
    let k: Vec<f32> = (0..m * n * b).map(|_| rng.normal() as f32 * 0.05).collect();
    let x: Vec<f32> = (0..d_in).map(|_| rng.normal() as f32).collect();
    let merged = merge::merge_c3a(&w0, d_in, d_out, &k, m, n, b);
    let y_merged = merge::dense_forward(&merged, d_in, d_out, &x);
    let y_adapter = merge::c3a_forward_unmerged(&w0, d_in, d_out, &k, m, n, b, &x);
    let err = y_merged.iter().zip(&y_adapter).map(|(a, b)| (a - b).abs()).fold(0f32, f32::max);
    println!("merge parity max err: {err:.2e} (zero inference overhead after merge)");
    assert!(err < 1e-3);
    println!("e2e OK");
    Ok(())
}
