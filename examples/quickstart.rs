//! Quickstart: fine-tune a tiny pretrained encoder with C3A on a
//! sentiment task, inspect the learned adapter's rank, and merge it.
//!
//!     make artifacts && cargo run --release --example quickstart

use c3a::coordinator::run::{self, Ctx};
use c3a::data::glue_sim::GlueTask;
use c3a::peft::init::C3aScheme;

fn main() -> anyhow::Result<()> {
    // 1. open the artifact registry (python/jax ran once at build time)
    let mut ctx = Ctx::open("artifacts")?;
    ctx.verbose = true;

    // 2. one call: pretrain (cached) -> fine-tune -> evaluate
    let cfg = run::default_cfg("c3a_d8", 80);
    let result =
        run::glue_run(&ctx, "enc_tiny", "c3a_d8", GlueTask::Sst2, 0, &cfg, C3aScheme::Xavier)?;

    println!("\n=== quickstart result ===");
    println!("test accuracy : {:.3}", result.metric);
    println!("trainable     : {} params (adapter only)", result.n_params);
    println!("step latency  : {:.1} ms", result.step_ms);
    if let Some((frac, mean, dim)) = result.rank {
        println!("delta ranks   : {:.0}% full-rank, mean {:.1}/{}", frac * 100.0, mean, dim);
    }
    let first_loss = result.losses.first().unwrap();
    let last_loss = result.losses.last().unwrap();
    println!(
        "loss curve    : {first_loss:.3} -> {last_loss:.3} over {} steps",
        result.losses.len()
    );
    Ok(())
}
