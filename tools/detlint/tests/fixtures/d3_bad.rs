// Fixture: wall-clock in a numeric module. Expected: D3 (import line and
// call line).
use std::time::Instant;

pub fn timed() -> f64 {
    let t0 = Instant::now();
    t0.elapsed().as_secs_f64()
}
