// Fixture: the accepted comment placements for unsafe. Expected: clean.
pub fn read_first(v: &[u8]) -> u8 {
    assert!(!v.is_empty());
    // SAFETY: the assert above guarantees index 0 is in bounds.
    unsafe { *v.get_unchecked(0) }
}

/// # Safety
/// Caller must guarantee `v` is non-empty.
pub unsafe fn read_first_unchecked(v: &[u8]) -> u8 {
    // SAFETY: forwarded to the caller via this fn's own contract.
    unsafe { *v.get_unchecked(0) }
}

pub fn wrapped(v: &[u8]) -> u8 {
    assert!(!v.is_empty());
    // SAFETY: bounds asserted above; the comment sits on the statement
    // start while rustfmt wraps `unsafe` onto a continuation line.
    let first: u8 =
        unsafe { *v.get_unchecked(0) };
    first
}
