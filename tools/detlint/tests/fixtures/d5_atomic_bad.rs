// Fixture: atomic op without an ordering-rationale comment. Expected: D5
// on the fetch_add line (the commented load is fine).
use std::sync::atomic::{AtomicUsize, Ordering};

pub static N: AtomicUsize = AtomicUsize::new(0);

pub fn bump() -> usize {
    N.fetch_add(1, Ordering::Relaxed)
}

pub fn peek() -> usize {
    // Relaxed: monotonic counter, no ordering needed.
    N.load(Ordering::Relaxed)
}
