// Fixture: the deterministic spelling of the same update. Expected: clean.
pub fn axpy(a: f32, x: f32, y: f32) -> f32 {
    a * x + y
}
