// Fixture: the same hash container, allowlisted with a reason. Expected:
// clean — the directive suppresses D2 on the line it precedes / shares.
// detlint: allow(D2) keyed lookups only; this map is never iterated
use std::collections::HashMap;

pub struct Cache {
    // detlint: allow(D2) keyed lookups only; this map is never iterated
    entries: HashMap<String, Vec<f32>>,
}
