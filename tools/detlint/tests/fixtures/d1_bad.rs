// Fixture: FMA contraction inside a numeric module (linted under a
// pseudo-path in rust/src/substrate/). Expected: D1 on the mul_add line.
pub fn axpy(a: f32, x: f32, y: f32) -> f32 {
    a.mul_add(x, y)
}
