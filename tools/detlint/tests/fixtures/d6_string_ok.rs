// Fixture: the long line is a string literal spanning column 100 —
// rustfmt cannot split string tokens, so detlint exempts it. Expected:
// clean.
pub fn template() -> &'static str {
    "{\n  \"bench\": \"fixture\",\n  \"requests\": 0,\n  \"tenants\": 0,\n  \"threads\": null,\n  \"p50\": 0.0\n}"
}
