// Fixture: raw C3A_* env access outside substrate/env.rs. Expected: D4 on
// both reads (var and set_var); the non-C3A read is out of scope.
pub fn threads() -> usize {
    std::env::set_var("C3A_PLAN", "0");
    let home = std::env::var("HOME");
    drop(home);
    std::env::var("C3A_THREADS").ok().and_then(|v| v.parse().ok()).unwrap_or(1)
}
