// Fixture: malformed allow directives. Expected: A0 for the missing
// reason, A0 for the unknown rule id, and the D2 findings still fire
// because neither directive is accepted.
// detlint: allow(D2)
use std::collections::HashMap;

pub struct Cache {
    // detlint: allow(D9) not a real rule
    entries: HashMap<String, Vec<f32>>,
}
