// Fixture: the rustfmt ordering detlint models — self/super/crate ranks,
// snake_case < CamelCase, brace lists after named segments. Expected:
// clean when linted at a crate-root pseudo-path.
use crate::alpha::zeta;
use crate::beta::Gamma;
use anyhow::{bail, Context, Result};
use std::path::Path;
use std::sync::mpsc;
use std::sync::Arc;

pub fn f(_: &Path, _: Arc<u8>, _: mpsc::Sender<u8>) -> Result<()> {
    let _ = (zeta, Gamma, bail!("x")).1;
    Context::custom()
}
