// Fixture: import block violating rustfmt order (std::sync before
// std::path, CamelCase before snake_case). Expected: D6 on each line that
// sorts before its predecessor.
use std::sync::Arc;
use std::path::Path;
use std::sync::mpsc;

pub fn f(_: Arc<u8>, _: &Path, _: mpsc::Sender<u8>) {}
