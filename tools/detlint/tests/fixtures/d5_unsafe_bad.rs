// Fixture: unsafe without a SAFETY justification. Expected: D5.
pub fn read_first(v: &[u8]) -> u8 {
    unsafe { *v.get_unchecked(0) }
}
