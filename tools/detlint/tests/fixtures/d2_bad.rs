// Fixture: hash containers in a determinism-scoped path. Expected: D2 on
// both the import line and the field line.
use std::collections::HashMap;

pub struct Cache {
    entries: HashMap<String, Vec<f32>>,
}
