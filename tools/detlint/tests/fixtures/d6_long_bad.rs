// Fixture: a code line past 100 columns with no string crossing the
// boundary. Expected: D6 on the long line.
pub fn total(values: &[u64]) -> u64 {
    values.iter().copied().fold(0u64, |accumulator, element| accumulator.wrapping_add(element).wrapping_add(1))
}
