//! Fixture corpus: every bad fixture must produce exactly the expected
//! `(line, rule)` findings when linted under its scoped pseudo-path, and
//! every good fixture must come back clean.  The fixtures live as real
//! `.rs` files (never compiled — cargo only builds top-level files in
//! tests/) so the corpus is readable and greppable.

use detlint::lint_source;

fn check(rel: &str, src: &str, want: &[(usize, &str)]) {
    let findings = lint_source(rel, src);
    let got: Vec<(usize, &str)> = findings.iter().map(|f| (f.line, f.rule)).collect();
    assert_eq!(got, want, "fixture {rel}");
}

#[test]
fn d1_flags_fma_in_numeric_scope() {
    check("rust/src/substrate/fx.rs", include_str!("fixtures/d1_bad.rs"), &[(4, "D1")]);
}

#[test]
fn d1_accepts_plain_mul_add_spelling() {
    check("rust/src/substrate/fx.rs", include_str!("fixtures/d1_good.rs"), &[]);
}

#[test]
fn d1_is_scoped_to_numeric_modules() {
    // the same source outside the numeric scope is not D1's business
    check("rust/src/serving/fx.rs", include_str!("fixtures/d1_bad.rs"), &[]);
}

#[test]
fn d2_flags_hash_containers_on_import_and_use() {
    check(
        "rust/src/runtime/interp/fx.rs",
        include_str!("fixtures/d2_bad.rs"),
        &[(3, "D2"), (6, "D2")],
    );
}

#[test]
fn d2_respects_reasoned_allow_directives() {
    check("rust/src/runtime/interp/fx.rs", include_str!("fixtures/d2_allowed.rs"), &[]);
}

#[test]
fn d2_covers_the_serialization_extra_scope() {
    check("rust/src/serving/store.rs", include_str!("fixtures/d2_bad.rs"), &[(3, "D2"), (6, "D2")]);
}

#[test]
fn d3_flags_wall_clock_in_numeric_scope() {
    check("rust/src/substrate/fx.rs", include_str!("fixtures/d3_bad.rs"), &[(3, "D3"), (6, "D3")]);
}

#[test]
fn d4_flags_raw_c3a_env_access_only() {
    // line 4: set_var("C3A_PLAN"); line 7: var("C3A_THREADS"); the HOME
    // read between them is out of scope
    check("rust/src/serving/fx.rs", include_str!("fixtures/d4_bad.rs"), &[(4, "D4"), (7, "D4")]);
}

#[test]
fn d4_exempts_the_env_module_itself() {
    check("rust/src/substrate/env.rs", include_str!("fixtures/d4_bad.rs"), &[]);
}

#[test]
fn d5_flags_unsafe_without_safety_comment() {
    check("rust/src/serving/fx.rs", include_str!("fixtures/d5_unsafe_bad.rs"), &[(3, "D5")]);
}

#[test]
fn d5_accepts_all_safety_comment_placements() {
    check("rust/src/serving/fx.rs", include_str!("fixtures/d5_unsafe_good.rs"), &[]);
}

#[test]
fn d5_flags_uncommented_atomic_orderings() {
    check("rust/src/serving/fx.rs", include_str!("fixtures/d5_atomic_bad.rs"), &[(8, "D5")]);
}

#[test]
fn d6_flags_long_code_lines() {
    check("rust/src/serving/fx.rs", include_str!("fixtures/d6_long_bad.rs"), &[(4, "D6")]);
}

#[test]
fn d6_exempts_string_literals_spanning_the_limit() {
    check("rust/src/serving/fx.rs", include_str!("fixtures/d6_string_ok.rs"), &[]);
}

#[test]
fn d6_flags_misordered_imports() {
    check("rust/src/serving/fx.rs", include_str!("fixtures/d6_import_bad.rs"), &[(5, "D6")]);
}

#[test]
fn d6_accepts_rustfmt_import_order() {
    check("rust/src/fx.rs", include_str!("fixtures/d6_import_good.rs"), &[]);
}

#[test]
fn a0_flags_bad_directives_and_keeps_findings() {
    // line 4: reasonless allow; line 8: unknown rule id — neither
    // suppresses, so the D2s on lines 5 and 9 still fire
    check(
        "rust/src/runtime/interp/fx.rs",
        include_str!("fixtures/a0_bad.rs"),
        &[(4, "A0"), (5, "D2"), (8, "A0"), (9, "D2")],
    );
}
