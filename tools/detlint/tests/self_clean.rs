//! detlint lints itself: its own sources sit inside the gate set that
//! scripts/lint.sh passes, so they must hold to the same contract they
//! enforce.

use detlint::lint_source;

#[test]
fn detlint_sources_are_clean() {
    for (rel, src) in [
        ("tools/detlint/src/lexer.rs", include_str!("../src/lexer.rs")),
        ("tools/detlint/src/lib.rs", include_str!("../src/lib.rs")),
        ("tools/detlint/src/main.rs", include_str!("../src/main.rs")),
        ("tools/detlint/src/rules.rs", include_str!("../src/rules.rs")),
    ] {
        let findings = lint_source(rel, src);
        assert!(
            findings.is_empty(),
            "{rel} has lint findings:\n{}",
            findings
                .iter()
                .map(|f| format!("{rel}:{}: {} {}", f.line, f.rule, f.msg))
                .collect::<Vec<_>>()
                .join("\n")
        );
    }
}
