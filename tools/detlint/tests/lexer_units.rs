//! Targeted lexer cases the rules depend on: comment/string/char
//! disambiguation and the exact column spans D6 uses for its
//! string-literal exemption.

use detlint::lexer::{lex, TokKind};

fn kinds(src: &str) -> Vec<(TokKind, String)> {
    lex(src).toks.into_iter().map(|t| (t.kind, t.text)).collect()
}

#[test]
fn raw_strings_keep_embedded_quotes() {
    let toks = kinds("let s = r#\"a \" b\"#;");
    assert!(toks.contains(&(TokKind::Str, "a \" b".to_string())), "{toks:?}");
}

#[test]
fn lifetimes_are_not_char_literals() {
    let toks = kinds("fn f<'a>(x: &'a str) -> &'a str { x }");
    let lifetimes = toks.iter().filter(|(k, _)| *k == TokKind::Lifetime).count();
    let chars = toks.iter().filter(|(k, _)| *k == TokKind::Char).count();
    assert_eq!((lifetimes, chars), (3, 0), "{toks:?}");
}

#[test]
fn char_literals_cover_escapes_and_punctuation() {
    let toks = kinds("let a = 'x'; let b = '\\n'; let c = '('; let d = '\\'';");
    let chars = toks.iter().filter(|(k, _)| *k == TokKind::Char).count();
    assert_eq!(chars, 4, "{toks:?}");
}

#[test]
fn block_comments_nest() {
    let lexed = lex("/* a /* b */ c */ let x = 1;");
    assert_eq!(lexed.comments.len(), 1);
    assert_eq!(lexed.comments[0].text, "/* a /* b */ c */");
    assert!(lexed.toks.iter().any(|t| t.kind == TokKind::Ident && t.text == "let"));
}

#[test]
fn comment_markers_inside_strings_are_inert() {
    let lexed = lex("let s = \"// not a comment\";\n// real comment\n");
    assert_eq!(lexed.comments.len(), 1);
    assert_eq!(lexed.comments[0].text, "// real comment");
    assert!(lexed.toks.iter().any(|t| t.kind == TokKind::Str && t.text == "// not a comment"));
}

#[test]
fn own_line_flag_distinguishes_tail_comments() {
    let lexed = lex("let x = 1; // tail\n    // own\nlet y = 2;\n");
    assert_eq!(lexed.comments.len(), 2);
    assert!(!lexed.comments[0].own_line);
    assert!(lexed.comments[1].own_line);
}

#[test]
fn string_span_columns_are_exact() {
    // "let s = " is 8 chars, so the opening quote sits at column 9 and
    // the 102-char token (quote + 100 + quote) ends just past column 110
    let src = format!("let s = \"{}\";\n", "x".repeat(100));
    let lexed = lex(&src);
    let s = lexed.toks.iter().find(|t| t.kind == TokKind::Str).unwrap();
    assert_eq!((s.line, s.col), (1, 9));
    assert_eq!((s.end_line, s.end_col), (1, 9 + 102));
}

#[test]
fn unterminated_literals_do_not_panic() {
    for src in ["let s = \"abc", "let s = r#\"abc", "/* open", "let c = '"] {
        let _ = lex(src);
    }
}
