//! The whole gated tree must lint clean.  This runs as part of plain
//! `cargo test` at the workspace root, so the determinism contract is
//! enforced even on machines that never invoke scripts/lint.sh.

use detlint::{collect_rs_files, lint_source};
use std::path::Path;

#[test]
fn gated_tree_is_lint_clean() {
    let repo = Path::new(env!("CARGO_MANIFEST_DIR")).join("../..");
    let mut files = Vec::new();
    for root in ["rust/src", "rust/tests", "rust/benches", "examples"] {
        collect_rs_files(&repo.join(root), &mut files)
            .unwrap_or_else(|e| panic!("walking {root}: {e}"));
    }
    assert!(files.len() > 40, "suspiciously few files found: {}", files.len());

    let mut report = String::new();
    let mut count = 0usize;
    for file in &files {
        let rel = file
            .strip_prefix(&repo)
            .expect("walked file outside repo root")
            .to_string_lossy()
            .replace('\\', "/");
        let src = std::fs::read_to_string(file).unwrap();
        for f in lint_source(&rel, &src) {
            report.push_str(&format!("{rel}:{}: {} {}\n", f.line, f.rule, f.msg));
            count += 1;
        }
    }
    assert!(count == 0, "tree has {count} lint finding(s):\n{report}");
}
