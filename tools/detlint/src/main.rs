//! CLI entry point: `detlint [path ...]` lints every `.rs` file under
//! the given paths (files or directories, repo-relative) and prints one
//! `path:line: RULE message` finding per line.  With no arguments it
//! lints the default gate set — the same list scripts/lint.sh passes.
//!
//! Exit codes: 0 clean, 1 findings, 2 usage/IO error.

use detlint::{collect_rs_files, lint_source};
use std::path::Path;
use std::process::ExitCode;

const DEFAULT_ROOTS: &[&str] =
    &["rust/src", "rust/tests", "rust/benches", "examples", "tools/detlint/src"];

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let roots: Vec<String> = if args.is_empty() {
        DEFAULT_ROOTS.iter().map(|s| s.to_string()).collect()
    } else {
        args
    };

    let mut files = Vec::new();
    for root in &roots {
        let path = Path::new(root);
        if !path.exists() {
            eprintln!("detlint: path not found: {root} (run from the repo root)");
            return ExitCode::from(2);
        }
        if let Err(err) = collect_rs_files(path, &mut files) {
            eprintln!("detlint: walking {root}: {err}");
            return ExitCode::from(2);
        }
    }

    let mut findings = 0usize;
    for file in &files {
        let rel = file.to_string_lossy();
        let src = match std::fs::read_to_string(file) {
            Ok(src) => src,
            Err(err) => {
                eprintln!("detlint: reading {rel}: {err}");
                return ExitCode::from(2);
            }
        };
        for f in lint_source(&rel, &src) {
            println!("{rel}:{}: {} {}", f.line, f.rule, f.msg);
            findings += 1;
        }
    }

    if findings == 0 {
        println!("detlint: clean ({} files)", files.len());
        ExitCode::SUCCESS
    } else {
        println!("detlint: {findings} finding(s)");
        ExitCode::from(1)
    }
}
