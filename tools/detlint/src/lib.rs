//! detlint — the determinism linter for this tree.
//!
//! Machine-enforces the contract in docs/DETERMINISM.md: rules D1–D6
//! over `rust/src`, `rust/tests`, `rust/benches`, `examples`, and its
//! own sources.  Zero dependencies by design — the lexer is hand-rolled
//! in [`lexer`], the rules live in [`rules`], and the binary in
//! `main.rs` is a thin directory walk over [`lint_source`].
//!
//! See docs/LINTING.md for the runbook (running locally, the allowlist
//! syntax, and how to add a rule).

pub mod lexer;
pub mod rules;

pub use rules::{lint_source, Finding, RULE_IDS};

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// Recursively collect every `.rs` file under `root` (or `root` itself
/// when it is a file), appending to `out` in sorted order so lint
/// output — and therefore CI logs — are byte-stable across runs.
pub fn collect_rs_files(root: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    if root.is_file() {
        if root.extension().is_some_and(|e| e == "rs") {
            out.push(root.to_path_buf());
        }
        return Ok(());
    }
    let mut entries: Vec<PathBuf> = Vec::new();
    for entry in fs::read_dir(root)? {
        entries.push(entry?.path());
    }
    entries.sort();
    for path in entries {
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}
