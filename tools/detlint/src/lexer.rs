//! A minimal hand-rolled Rust lexer — just enough token structure for the
//! determinism rules: identifiers, string/char literals with exact
//! line/column spans, comments (with nesting and own-line tracking), and
//! single-char punctuation.  It does not build an AST and it does not
//! need to: every rule in [`crate::rules`] works on the token stream plus
//! per-line comment metadata.
//!
//! Deliberate simplifications (documented so the rules stay honest):
//! raw identifiers (`r#fn`) lex as `r` + `#` + ident, and multi-char
//! operators arrive as individual punct tokens.  Neither shape affects
//! any rule.

/// Token classification.  `Str` carries the literal's *contents* (no
/// quotes/prefix) so rules can match on payloads such as `C3A_*`.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum TokKind {
    /// Identifier or keyword (the lexer does not distinguish).
    Ident,
    /// Numeric literal, including suffixes (`1_000u64`, `1e-3`).
    Num,
    /// String literal contents: plain, raw, byte, or raw-byte.
    Str,
    /// Char literal (contents not needed by any rule).
    Char,
    /// Lifetime (`'a`) — kept distinct so it never looks like a char.
    Lifetime,
    /// Single punctuation character.
    Punct,
}

/// One lexed token with an inclusive start and exclusive end position
/// (1-based lines and columns, measured in chars).
#[derive(Clone, Debug)]
pub struct Tok {
    /// Classification of this token.
    pub kind: TokKind,
    /// Token text (for `Str`: the unquoted contents).
    pub text: String,
    /// 1-based line of the first char.
    pub line: usize,
    /// 1-based column of the first char.
    pub col: usize,
    /// 1-based line just past the last char.
    pub end_line: usize,
    /// 1-based column just past the last char.
    pub end_col: usize,
}

/// One comment (line or block), with the span it covers and whether it
/// starts its own line — rules only accept own-line comments (or attrs)
/// when walking upward from a flagged line.
#[derive(Clone, Debug)]
pub struct Comment {
    /// Full comment text including the `//` / `/*` markers.
    pub text: String,
    /// 1-based first line.
    pub line: usize,
    /// 1-based last line.
    pub end_line: usize,
    /// True when nothing but whitespace precedes it on its first line.
    pub own_line: bool,
}

/// Lexer output: code tokens and comments, each in source order.
pub struct Lexed {
    /// All non-comment tokens.
    pub toks: Vec<Tok>,
    /// All comments.
    pub comments: Vec<Comment>,
}

struct Cursor {
    s: Vec<char>,
    i: usize,
    line: usize,
    col: usize,
}

impl Cursor {
    fn at(&self, k: usize) -> Option<char> {
        self.s.get(self.i + k).copied()
    }

    /// Advance `n` chars, maintaining the 1-based line/col counters.
    fn adv(&mut self, n: usize) {
        for _ in 0..n {
            if self.s[self.i] == '\n' {
                self.line += 1;
                self.col = 1;
            } else {
                self.col += 1;
            }
            self.i += 1;
        }
    }

    fn text(&self, a: usize, b: usize) -> String {
        self.s[a..b].iter().collect()
    }
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_cont(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Lex `src` into tokens + comments.  Never panics on malformed input
/// (unterminated literals/comments consume to end of file) — the linter
/// must degrade gracefully on files rustc itself would reject.
pub fn lex(src: &str) -> Lexed {
    let mut cur = Cursor { s: src.chars().collect(), i: 0, line: 1, col: 1 };
    let n = cur.s.len();
    let mut toks: Vec<Tok> = Vec::new();
    let mut comments: Vec<Comment> = Vec::new();
    // true until the first non-whitespace char of the current line
    let mut line_start_ws = true;

    while cur.i < n {
        let c = cur.s[cur.i];
        if c == '\n' {
            cur.adv(1);
            line_start_ws = true;
            continue;
        }
        if c == ' ' || c == '\t' || c == '\r' {
            cur.adv(1);
            continue;
        }
        // line comment
        if c == '/' && cur.at(1) == Some('/') {
            let l0 = cur.line;
            let own = line_start_ws;
            let start = cur.i;
            let mut end = cur.i;
            while end < n && cur.s[end] != '\n' {
                end += 1;
            }
            let text = cur.text(start, end);
            cur.adv(end - start);
            comments.push(Comment { text, line: l0, end_line: l0, own_line: own });
            continue;
        }
        // block comment (nesting per Rust semantics)
        if c == '/' && cur.at(1) == Some('*') {
            let l0 = cur.line;
            let own = line_start_ws;
            let start = cur.i;
            let mut depth = 0usize;
            let mut end = cur.i;
            while end < n {
                if cur.s[end] == '/' && cur.s.get(end + 1) == Some(&'*') {
                    depth += 1;
                    end += 2;
                } else if cur.s[end] == '*' && cur.s.get(end + 1) == Some(&'/') {
                    depth -= 1;
                    end += 2;
                    if depth == 0 {
                        break;
                    }
                } else {
                    end += 1;
                }
            }
            let text = cur.text(start, end);
            cur.adv(end - start);
            comments.push(Comment { text, line: l0, end_line: cur.line, own_line: own });
            line_start_ws = false;
            continue;
        }
        line_start_ws = false;
        // plain string literal
        if c == '"' {
            lex_quoted(&mut cur, n, 1, &mut toks);
            continue;
        }
        // raw / byte string prefixes: r" r#" br" b" (otherwise ident)
        if c == 'r' || c == 'b' {
            let mut k = 1usize;
            let mut raw = c == 'r';
            if c == 'b' && cur.at(1) == Some('r') {
                raw = true;
                k = 2;
            }
            if raw {
                let mut hashes = 0usize;
                while cur.at(k + hashes) == Some('#') {
                    hashes += 1;
                }
                if cur.at(k + hashes) == Some('"') {
                    lex_raw_string(&mut cur, n, k + hashes + 1, hashes, &mut toks);
                    continue;
                }
            }
            if c == 'b' && cur.at(1) == Some('"') {
                lex_quoted(&mut cur, n, 2, &mut toks);
                continue;
            }
            // fall through: identifier starting with r/b
        }
        // char literal vs lifetime
        if c == '\'' {
            lex_tick(&mut cur, n, &mut toks);
            continue;
        }
        if is_ident_start(c) {
            let (l0, c0) = (cur.line, cur.col);
            let start = cur.i;
            let mut end = cur.i;
            while end < n && is_ident_cont(cur.s[end]) {
                end += 1;
            }
            let text = cur.text(start, end);
            cur.adv(end - start);
            toks.push(Tok {
                kind: TokKind::Ident,
                text,
                line: l0,
                col: c0,
                end_line: cur.line,
                end_col: cur.col,
            });
            continue;
        }
        if c.is_ascii_digit() {
            let (l0, c0) = (cur.line, cur.col);
            let start = cur.i;
            let mut end = cur.i;
            while end < n {
                let ch = cur.s[end];
                if ch.is_alphanumeric() || ch == '_' {
                    end += 1;
                } else if ch == '.' && cur.s.get(end + 1).is_some_and(|d| d.is_ascii_digit()) {
                    end += 1;
                } else if (ch == '+' || ch == '-')
                    && end > start
                    && matches!(cur.s[end - 1], 'e' | 'E')
                {
                    end += 1;
                } else {
                    break;
                }
            }
            let text = cur.text(start, end);
            cur.adv(end - start);
            toks.push(Tok {
                kind: TokKind::Num,
                text,
                line: l0,
                col: c0,
                end_line: cur.line,
                end_col: cur.col,
            });
            continue;
        }
        let (l0, c0) = (cur.line, cur.col);
        cur.adv(1);
        toks.push(Tok {
            kind: TokKind::Punct,
            text: c.to_string(),
            line: l0,
            col: c0,
            end_line: cur.line,
            end_col: cur.col,
        });
    }
    Lexed { toks, comments }
}

/// Lex a `"..."`-style literal whose opening delimiter (including any
/// `b` prefix) is `skip` chars long; backslash escapes are honored.
fn lex_quoted(cur: &mut Cursor, n: usize, skip: usize, toks: &mut Vec<Tok>) {
    let (l0, c0) = (cur.line, cur.col);
    let start = cur.i;
    let mut end = cur.i + skip;
    while end < n {
        if cur.s[end] == '\\' {
            end += 2;
            continue;
        }
        if cur.s[end] == '"' {
            end += 1;
            break;
        }
        end += 1;
    }
    let end = end.min(n);
    // contents: strip the prefix+quote and (when present) the close quote
    let close = usize::from(end > start + skip && cur.s[end - 1] == '"');
    let text = cur.text(start + skip, end - close);
    cur.adv(end - start);
    toks.push(Tok {
        kind: TokKind::Str,
        text,
        line: l0,
        col: c0,
        end_line: cur.line,
        end_col: cur.col,
    });
}

/// Lex a raw string whose opening `r##"` span is `open` chars and whose
/// closing delimiter is `"` followed by `hashes` `#`s.
fn lex_raw_string(cur: &mut Cursor, n: usize, open: usize, hashes: usize, toks: &mut Vec<Tok>) {
    let (l0, c0) = (cur.line, cur.col);
    let start = cur.i;
    let body = cur.i + open;
    let mut end = body;
    let mut content_end = n;
    while end < n {
        if cur.s[end] == '"' && (1..=hashes).all(|h| cur.s.get(end + h) == Some(&'#')) {
            content_end = end;
            end += 1 + hashes;
            break;
        }
        end += 1;
    }
    let end = end.min(n);
    let text = cur.text(body, content_end.min(end));
    cur.adv(end - start);
    toks.push(Tok {
        kind: TokKind::Str,
        text,
        line: l0,
        col: c0,
        end_line: cur.line,
        end_col: cur.col,
    });
}

/// Disambiguate `'x'` / `'\n'` (char literals) from `'a` (lifetimes).
fn lex_tick(cur: &mut Cursor, n: usize, toks: &mut Vec<Tok>) {
    let (l0, c0) = (cur.line, cur.col);
    let start = cur.i;
    if cur.at(1) == Some('\\') {
        // escaped char literal: consume through the closing quote
        let mut end = (start + 3).min(n);
        while end < n && cur.s[end] != '\'' {
            end += 1;
        }
        let end = (end + 1).min(n);
        cur.adv(end - start);
        push_mark(toks, TokKind::Char, l0, c0, cur);
        return;
    }
    if cur.at(1).is_some_and(is_ident_start) {
        let mut end = start + 1;
        while end < n && is_ident_cont(cur.s[end]) {
            end += 1;
        }
        if cur.s.get(end) == Some(&'\'') {
            cur.adv(end + 1 - start);
            push_mark(toks, TokKind::Char, l0, c0, cur);
        } else {
            let text = cur.text(start + 1, end);
            cur.adv(end - start);
            toks.push(Tok {
                kind: TokKind::Lifetime,
                text,
                line: l0,
                col: c0,
                end_line: cur.line,
                end_col: cur.col,
            });
        }
        return;
    }
    if cur.at(1).is_some() && cur.at(2) == Some('\'') {
        // non-ident char like '(' or '+'
        cur.adv(3);
        push_mark(toks, TokKind::Char, l0, c0, cur);
        return;
    }
    // stray quote: consume it alone and move on
    cur.adv(1);
}

fn push_mark(toks: &mut Vec<Tok>, kind: TokKind, l0: usize, c0: usize, cur: &Cursor) {
    toks.push(Tok {
        kind,
        text: String::new(),
        line: l0,
        col: c0,
        end_line: cur.line,
        end_col: cur.col,
    });
}
