//! The determinism rules (D1–D6) and the allowlist machinery.  Every
//! rule is a pass over the token stream from [`crate::lexer`] plus
//! per-line comment metadata; scoping is by repo-relative path, so the
//! same engine lints real files and fixture snippets alike.
//!
//! Rule catalogue (mirrored in docs/DETERMINISM.md §6 and
//! docs/LINTING.md):
//!
//! - **D1** — no FMA/fast-math contraction (`mul_add`, `fma`,
//!   `f*_fast`, `f*_algebraic`) in numeric modules.
//! - **D2** — no `HashMap`/`HashSet` in determinism-scoped paths
//!   (numeric modules plus serialization/stats files): iteration order
//!   is seeded per-process.
//! - **D3** — no wall-clock (`std::time`, `Instant`, `SystemTime`) in
//!   numeric modules; timing belongs to benches and serving stats.
//! - **D4** — every `C3A_*` env access goes through `substrate::env`.
//! - **D5** — every `unsafe` carries a `SAFETY` comment and every
//!   atomic `Ordering::*` operation a rationale comment.
//! - **D6** — 100-column limit (string-literal spans exempt: rustfmt
//!   cannot split them) and rustfmt import order.
//!
//! Suppression: `// detlint: allow(D2) <reason>` on (or on the own-line
//! comment directly above) the offending line.  A missing reason or an
//! unknown rule id is itself a finding (**A0**), so the allowlist stays
//! auditable.

use crate::lexer::{lex, Tok, TokKind};
use std::collections::{BTreeMap, BTreeSet};

/// One lint finding, anchored to a 1-based line of the linted file.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Finding {
    /// 1-based line the finding anchors to.
    pub line: usize,
    /// Rule id: `"D1"`..`"D6"`, or `"A0"` for a bad allow directive.
    pub rule: &'static str,
    /// Human-readable explanation.
    pub msg: String,
}

/// The suppressible rule ids, in catalogue order.
pub const RULE_IDS: &[&str] = &["D1", "D2", "D3", "D4", "D5", "D6"];

const D1_IDENTS: &[&str] = &[
    "mul_add",
    "fma",
    "fadd_fast",
    "fmul_fast",
    "fsub_fast",
    "fdiv_fast",
    "fadd_algebraic",
    "fmul_algebraic",
    "fsub_algebraic",
];
const ENV_FNS: &[&str] = &["var", "var_os", "set_var", "remove_var"];
const ORDERINGS: &[&str] = &["Relaxed", "Acquire", "Release", "AcqRel", "SeqCst"];

/// Modules under the scalar-reference determinism contract (D1/D3, and
/// the core of D2).
const NUMERIC_PREFIXES: &[&str] = &[
    "rust/src/substrate/",
    "rust/src/runtime/interp/",
    "rust/src/runtime/plan/",
    "rust/src/runtime/refbackend/",
];
/// Extra D2 scope: files whose output must be byte-stable across runs.
const D2_EXTRA: &[&str] = &["rust/src/serving/store.rs", "rust/src/serving/stats.rs"];
/// The one module allowed to touch `C3A_*` env vars directly.
const ENV_MODULE: &str = "rust/src/substrate/env.rs";

/// Lint one file.  `rel` is its repo-relative path (used for rule
/// scoping); `src` is the file contents.  Findings come back sorted by
/// (line, rule, message).
pub fn lint_source(rel: &str, src: &str) -> Vec<Finding> {
    let rel = rel.replace('\\', "/");
    let rel = rel.strip_prefix("./").unwrap_or(&rel).to_string();
    let lexed = lex(src);
    let toks = &lexed.toks;
    let n_toks = toks.len();

    let numeric = NUMERIC_PREFIXES.iter().any(|p| rel.starts_with(p));
    let d2 = numeric || D2_EXTRA.contains(&rel.as_str());
    let is_env = rel == ENV_MODULE;

    // ---- per-line comment / attribute metadata --------------------------
    let mut comment_text_by_line: BTreeMap<usize, String> = BTreeMap::new();
    let mut own_comment_lines: BTreeSet<usize> = BTreeSet::new();
    for c in &lexed.comments {
        for line in c.line..=c.end_line {
            comment_text_by_line.entry(line).or_default().push_str(&c.text);
        }
        if c.own_line {
            own_comment_lines.extend(c.line..=c.end_line);
        }
    }
    let mut attr_lines: BTreeSet<usize> = BTreeSet::new();
    for (idx, t) in toks.iter().enumerate() {
        if t.kind != TokKind::Punct || t.text != "#" {
            continue;
        }
        let Some(next) = toks.get(idx + 1) else { continue };
        if next.kind != TokKind::Punct || (next.text != "[" && next.text != "!") {
            continue;
        }
        attr_lines.insert(t.line);
        let mut depth = 0i32;
        let mut j = idx + 1;
        if toks[j].text == "!" {
            j += 1;
        }
        while j < n_toks {
            let tj = &toks[j];
            if tj.kind == TokKind::Punct && tj.text == "[" {
                depth += 1;
            } else if tj.kind == TokKind::Punct && tj.text == "]" {
                depth -= 1;
                if depth == 0 {
                    attr_lines.extend(t.line..=tj.line);
                    break;
                }
            }
            j += 1;
        }
    }

    // ---- allow directives ----------------------------------------------
    let mut findings: Vec<Finding> = Vec::new();
    let mut allows: BTreeMap<usize, BTreeSet<String>> = BTreeMap::new();
    let tok_lines: Vec<usize> = {
        let set: BTreeSet<usize> = toks.iter().map(|t| t.line).collect();
        set.into_iter().collect()
    };
    for c in &lexed.comments {
        let Some(pos) = c.text.find("detlint:") else { continue };
        let rest = c.text[pos + "detlint:".len()..].trim_start();
        let Some(list) = rest.strip_prefix("allow(") else {
            findings.push(Finding {
                line: c.line,
                rule: "A0",
                msg: "malformed detlint directive (expected `detlint: allow(Dn) reason`)".into(),
            });
            continue;
        };
        let Some(close) = list.find(')') else {
            findings.push(Finding {
                line: c.line,
                rule: "A0",
                msg: "malformed detlint directive (unclosed allow list)".into(),
            });
            continue;
        };
        let ids: Vec<&str> =
            list[..close].split(',').map(str::trim).filter(|s| !s.is_empty()).collect();
        let reason = list[close + 1..].trim();
        if let Some(bad) = ids.iter().find(|id| !RULE_IDS.contains(id)) {
            findings.push(Finding {
                line: c.line,
                rule: "A0",
                msg: format!("unknown rule id {bad} in allow directive"),
            });
            continue;
        }
        if reason.is_empty() {
            findings.push(Finding {
                line: c.line,
                rule: "A0",
                msg: "allow directive requires a reason: `// detlint: allow(Dn) <why>`".into(),
            });
            continue;
        }
        // an own-line directive covers the next code line; an inline one
        // covers its own line
        let target = if c.own_line {
            match tok_lines.binary_search(&c.end_line) {
                Ok(i) => tok_lines.get(i + 1).copied(),
                Err(i) => tok_lines.get(i).copied(),
            }
        } else {
            Some(c.line)
        };
        if let Some(target) = target {
            allows.entry(target).or_default().extend(ids.iter().map(|s| s.to_string()));
        }
    }

    let mut emit = |findings: &mut Vec<Finding>, line: usize, rule: &'static str, msg: String| {
        if allows.get(&line).is_some_and(|set| set.contains(rule)) {
            return;
        }
        findings.push(Finding { line, rule, msg });
    };

    // anchor lines whose comments can justify a finding on `line`: the
    // line itself, plus the contiguous run of own-line comments and
    // attributes directly above it
    let cov_lines = |line: usize| -> Vec<usize> {
        let mut out = Vec::new();
        if comment_text_by_line.contains_key(&line) {
            out.push(line);
        }
        let mut l = line.saturating_sub(1);
        while l >= 1 && (own_comment_lines.contains(&l) || attr_lines.contains(&l)) {
            if own_comment_lines.contains(&l) {
                out.push(l);
            }
            l -= 1;
        }
        out
    };
    let has_marker = |line: usize, markers: &[&str]| -> bool {
        cov_lines(line).iter().any(|l| {
            let txt = comment_text_by_line.get(l).map(String::as_str).unwrap_or("");
            markers.iter().any(|m| txt.contains(m))
        })
    };

    let d1_msg = |name: &str| {
        format!("`{name}`: FMA/fast-math contraction is forbidden in numeric modules")
    };
    let d2_msg = |name: &str| {
        format!(
            "`{name}` in a determinism-scoped path (iteration order is nondeterministic); \
             use BTreeMap/BTreeSet or allowlist with proof it is never iterated"
        )
    };
    let d3_msg = |name: &str| {
        format!("`{name}`: wall-clock inside a numeric module (timing belongs to benches)")
    };

    // ---- token walk: D1–D5 + use-statement collection -------------------
    struct UseStmt {
        start_line: usize,
        end_line: usize,
        depth: usize,
        segs: Vec<String>,
    }
    let mut use_stmts: Vec<UseStmt> = Vec::new();
    let mut depth = 0usize;
    // last significant char: ';' '{' '}' ']' etc., 'x' for non-punct
    let mut prev_sig: Option<char> = None;
    let mut idx = 0usize;
    while idx < n_toks {
        let t = &toks[idx];
        if t.kind == TokKind::Punct {
            match t.text.as_str() {
                "{" => depth += 1,
                "}" => depth = depth.saturating_sub(1),
                _ => {}
            }
        }
        let is_ident = t.kind == TokKind::Ident;
        if numeric && is_ident && D1_IDENTS.contains(&t.text.as_str()) {
            emit(&mut findings, t.line, "D1", d1_msg(&t.text));
        }
        if d2 && is_ident && (t.text == "HashMap" || t.text == "HashSet") {
            emit(&mut findings, t.line, "D2", d2_msg(&t.text));
        }
        if numeric && is_ident && (t.text == "Instant" || t.text == "SystemTime") {
            emit(&mut findings, t.line, "D3", d3_msg(&t.text));
        }
        if numeric && is_ident && t.text == "std" && path_next(toks, idx, "time") {
            emit(&mut findings, t.line, "D3", "`std::time` inside a numeric module".into());
        }
        if !is_env && is_ident && ENV_FNS.contains(&t.text.as_str()) {
            let call = toks.get(idx + 1).is_some_and(|p| p.kind == TokKind::Punct && p.text == "(");
            let c3a = toks.get(idx + 2).is_some_and(|a| {
                a.kind == TokKind::Str && a.text.starts_with("C3A_")
            });
            if call && c3a {
                emit(
                    &mut findings,
                    t.line,
                    "D4",
                    format!(
                        "raw `{}(\"{}\")` outside substrate/env.rs — use the substrate::env \
                         accessors/constants",
                        t.text,
                        toks[idx + 2].text
                    ),
                );
            }
        }
        if is_ident && t.text == "unsafe" {
            // rustfmt may wrap a statement so `unsafe` lands on a
            // continuation line; also accept a SAFETY comment above the
            // statement start (the token after the last ';'/'{'/'}')
            let mut j = idx;
            while j > 0 {
                let tj = &toks[j - 1];
                if tj.kind == TokKind::Punct && matches!(tj.text.as_str(), ";" | "{" | "}") {
                    break;
                }
                j -= 1;
            }
            let stmt_line = toks[j].line;
            if !has_marker(t.line, &["SAFETY", "Safety"])
                && !has_marker(stmt_line, &["SAFETY", "Safety"])
            {
                emit(
                    &mut findings,
                    t.line,
                    "D5",
                    "`unsafe` without a `// SAFETY:` justification".into(),
                );
            }
        }
        if is_ident && t.text == "Ordering" {
            let which = toks
                .get(idx + 3)
                .filter(|o| {
                    o.kind == TokKind::Ident
                        && ORDERINGS.contains(&o.text.as_str())
                        && toks[idx + 1].text == ":"
                        && toks[idx + 2].text == ":"
                })
                .map(|o| o.text.clone());
            if let Some(which) = which {
                if cov_lines(t.line).is_empty() {
                    emit(
                        &mut findings,
                        t.line,
                        "D5",
                        format!(
                            "atomic `Ordering::{which}` without a rationale comment on or \
                             above this line"
                        ),
                    );
                }
            }
        }
        // use statements: collect path segments for D6 import order
        let starts_stmt = matches!(prev_sig, None | Some(';' | '{' | '}' | ']'));
        if is_ident && t.text == "use" && starts_stmt {
            let start_line = t.line;
            let mut end_line = t.line;
            let mut segs: Vec<String> = Vec::new();
            let mut sdepth = 0usize;
            let mut j = idx + 1;
            while j < n_toks {
                let tj = &toks[j];
                // imported idents still face the token rules (catches
                // `use std::collections::HashMap as Map;` aliasing)
                if tj.kind == TokKind::Ident {
                    if numeric && D1_IDENTS.contains(&tj.text.as_str()) {
                        emit(&mut findings, tj.line, "D1", d1_msg(&tj.text));
                    }
                    if d2 && (tj.text == "HashMap" || tj.text == "HashSet") {
                        emit(&mut findings, tj.line, "D2", d2_msg(&tj.text));
                    }
                    if numeric && (tj.text == "Instant" || tj.text == "SystemTime") {
                        emit(&mut findings, tj.line, "D3", d3_msg(&tj.text));
                    }
                }
                if tj.kind == TokKind::Punct && tj.text == "{" {
                    if sdepth == 0 {
                        segs.push("{".into());
                    }
                    sdepth += 1;
                } else if tj.kind == TokKind::Punct && tj.text == "}" {
                    sdepth = sdepth.saturating_sub(1);
                } else if tj.kind == TokKind::Punct && tj.text == ";" && sdepth == 0 {
                    end_line = tj.line;
                    break;
                } else if sdepth == 0 && tj.kind == TokKind::Ident {
                    if tj.text == "as" {
                        j += 1; // skip the alias ident
                    } else {
                        segs.push(tj.text.clone());
                    }
                }
                j += 1;
            }
            if !segs.is_empty() {
                use_stmts.push(UseStmt { start_line, end_line, depth, segs });
            }
            idx = j + 1;
            prev_sig = Some(';');
            continue;
        }
        if is_ident && t.text == "pub" {
            // transparent: `pub use` still starts a statement
            idx += 1;
            continue;
        }
        prev_sig = if t.kind == TokKind::Punct { t.text.chars().next() } else { Some('x') };
        idx += 1;
    }

    // ---- D6: line length (string spans exempt) --------------------------
    for (ln0, text) in src.split('\n').enumerate() {
        let ln = ln0 + 1;
        let width = text.chars().count();
        if width <= 100 {
            continue;
        }
        let exempt = toks.iter().any(|t| {
            t.kind == TokKind::Str
                && t.line <= ln
                && ln <= t.end_line
                && (t.line < ln || t.end_line > ln || t.end_col > 100)
        });
        if !exempt {
            emit(&mut findings, ln, "D6", format!("line exceeds 100 columns ({width})"));
        }
    }

    // ---- D6: import order within contiguous use groups ------------------
    let mut group: Vec<&UseStmt> = Vec::new();
    let mut flush = |group: &mut Vec<&UseStmt>, findings: &mut Vec<Finding>| {
        for pair in group.windows(2) {
            let (a, b) = (pair[0], pair[1]);
            let ka: Vec<_> = a.segs.iter().map(|s| seg_key(s)).collect();
            let kb: Vec<_> = b.segs.iter().map(|s| seg_key(s)).collect();
            if kb < ka {
                emit(
                    findings,
                    b.start_line,
                    "D6",
                    format!(
                        "import out of order: `{}` sorts before `{}`",
                        b.segs.join("::"),
                        a.segs.join("::")
                    ),
                );
            }
        }
        group.clear();
    };
    for st in &use_stmts {
        let adjacent = group
            .last()
            .is_some_and(|prev| st.depth == prev.depth && st.start_line == prev.end_line + 1);
        if !group.is_empty() && !adjacent {
            flush(&mut group, &mut findings);
        }
        group.push(st);
    }
    flush(&mut group, &mut findings);

    findings.sort_by(|a, b| (a.line, a.rule, &a.msg).cmp(&(b.line, b.rule, &b.msg)));
    findings
}

/// True when `toks[idx]` is followed by `::ident` matching `name`.
fn path_next(toks: &[Tok], idx: usize, name: &str) -> bool {
    toks.get(idx + 1).is_some_and(|t| t.text == ":")
        && toks.get(idx + 2).is_some_and(|t| t.text == ":")
        && toks.get(idx + 3).is_some_and(|t| t.kind == TokKind::Ident && t.text == name)
}

/// rustfmt's import-segment ordering, validated against the tree:
/// `self < super < crate <` everything else; within plain identifiers
/// `snake_case < CamelCase < UPPER_SNAKE_CASE`, plain ASCII inside each
/// class; a brace list sorts after any named segment.
fn seg_key(seg: &str) -> (u8, u8, String) {
    match seg {
        "self" => (0, 0, String::new()),
        "super" => (1, 0, String::new()),
        "crate" => (2, 0, String::new()),
        "{" => (4, 0, String::new()),
        _ => {
            let upper_snake =
                seg.chars().all(|c| c.is_ascii_uppercase() || c.is_ascii_digit() || c == '_');
            let case = if upper_snake {
                2
            } else if seg.starts_with(|c: char| c.is_ascii_uppercase()) {
                1
            } else {
                0
            };
            (3, case, seg.to_string())
        }
    }
}
