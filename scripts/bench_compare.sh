#!/usr/bin/env bash
# Diff the just-measured BENCH_interp.json / BENCH_serve.json against the
# checked-in baselines (the committed versions of the same files, i.e.
# `git show HEAD:BENCH_*.json`).  Advisory by default: prints per-metric
# ratios and warns beyond 1.15x.  Hard-fails ONLY on a >2x step-time
# regression (step_ms_cached_threaded / eval_ms_replay) against a
# *measured* baseline — a baseline stamped `"provenance": "unmeasured..."`
# (committed before any toolchain-equipped run) never fails the build.
#
# To update the baselines: run scripts/bench.sh and commit the rewritten
# BENCH_*.json.
#
# Usage: scripts/bench_compare.sh

set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v python3 >/dev/null 2>&1; then
  echo "bench_compare: python3 unavailable; skipping (advisory pass)"
  exit 0
fi
if ! command -v git >/dev/null 2>&1 || ! git rev-parse HEAD >/dev/null 2>&1; then
  echo "bench_compare: not a git checkout; skipping (advisory pass)"
  exit 0
fi

status=0

compare() {
  local file="$1"
  if [ ! -f "$file" ]; then
    echo "bench_compare: $file not present (run scripts/bench.sh first); skipping"
    return
  fi
  if ! git cat-file -e "HEAD:$file" 2>/dev/null; then
    echo "bench_compare: no committed baseline for $file (advisory pass)"
    return
  fi
  local tmp
  tmp="$(mktemp)"
  git show "HEAD:$file" >"$tmp"
  local rc=0
  python3 - "$file" "$tmp" <<'PY' || rc=$?
import json
import sys

cur_path, base_path = sys.argv[1], sys.argv[2]
cur = json.load(open(cur_path))
base = json.load(open(base_path))
prov = str(base.get("provenance", ""))
if prov.startswith("unmeasured"):
    print(f"bench_compare: {cur_path}: baseline is unmeasured; advisory pass")
    print(f"               ({prov})")
    sys.exit(0)

import os

WARN, FAIL = 1.15, 2.0
# The hard gate compares wall-clock across runs, which is only
# meaningful like-for-like: it stays advisory unless the thread counts
# AND the compiled feature set match (a SIMD build must never be
# hard-gated against a scalar baseline or vice versa), and
# C3A_BENCH_NO_HARD=1 disarms it entirely (e.g. when the committed
# baseline came from a different machine class — baselines should be
# refreshed from the CI bench artifacts, not from dev boxes).
no_hard = os.environ.get("C3A_BENCH_NO_HARD") == "1"
threads_match = base.get("threads") == cur.get("threads")
features_match = base.get("features") == cur.get("features")
hard_armed = not no_hard and threads_match and features_match
if not hard_armed:
    why = (
        "C3A_BENCH_NO_HARD=1"
        if no_hard
        else "thread counts differ" if not threads_match else "feature sets differ"
    )
    print(f"bench_compare: {cur_path}: hard gate advisory-only ({why})")

# lower-is-better step-time metrics; `hard` carries the >2x gate
hard = ["step_ms_cached_threaded", "eval_ms_replay"]
soft = [
    "step_ms_stateless_single",
    "eval_ms_rebuild",
    "p50_ms",
    "p95_ms",
    "p99_ms",
    "cold_start_ms_p95",
]
rc = 0
for key in hard + soft:
    b, c = base.get(key), cur.get(key)
    if not isinstance(b, (int, float)) or not isinstance(c, (int, float)) or b <= 0:
        continue
    ratio = c / b
    tag = "ok"
    if ratio > FAIL and key in hard and hard_armed:
        tag = "FAIL (>2x step-time regression)"
        rc = 2
    elif ratio > WARN:
        tag = "warn (slower)"
    print(f"bench_compare: {cur_path}: {key}: {b:.3f} -> {c:.3f} ({ratio:.2f}x) {tag}")

# capacity gauges; advisory, direction-free (a changed residency policy
# moves these legitimately — they are printed so drift is visible)
for key in ["resident_hwm", "cold_starts", "evictions"]:
    b, c = base.get(key), cur.get(key)
    if not isinstance(b, (int, float)) or not isinstance(c, (int, float)):
        continue
    print(f"bench_compare: {cur_path}: {key}: {b} -> {c} (gauge)")

# higher-is-better throughput metrics; advisory only
for key in ["serve_req_per_s", "req_per_s", "c3a_matvec_ops_per_s", "plan_replay_speedup"]:
    b, c = base.get(key), cur.get(key)
    if not isinstance(b, (int, float)) or not isinstance(c, (int, float)) or b <= 0:
        continue
    ratio = c / b
    tag = "ok" if ratio >= 1 / WARN else "warn (slower)"
    print(f"bench_compare: {cur_path}: {key}: {b:.1f} -> {c:.1f} ({ratio:.2f}x) {tag}")
sys.exit(rc)
PY
  rm -f "$tmp"
  # exit 0 = pass/advisory, exit 2 = >2x regression; anything else means
  # the comparison itself broke (corrupt JSON, truncated baseline) — that
  # must fail too, or the hard gate silently disarms itself.
  if [ "$rc" -eq 2 ]; then
    status=1
  elif [ "$rc" -ne 0 ]; then
    echo "bench_compare: comparison for $file errored (exit $rc) — failing loudly"
    status=1
  fi
}

compare BENCH_interp.json
compare BENCH_serve.json

if [ "$status" -ne 0 ]; then
  echo "bench_compare: HARD FAILURE — >2x step-time regression against a measured baseline," \
    "or the comparison itself errored (see above)"
  exit 1
fi
echo "bench_compare: done"
