#!/usr/bin/env bash
# Perf tracking: the Table-1 operator bench, the interp train/serve bench
# (stateless-single-thread vs cached-multi-thread), and the sharded
# serving bench (the same seeded Zipf replay storm at shards=1 vs 4).
# Emits BENCH_interp.json + BENCH_serve.json at the repo root so CI can
# follow the perf trajectory.
#
# Usage: scripts/bench.sh [--smoke]
#   --smoke   reduced dims/step counts for CI

set -euo pipefail
cd "$(dirname "$0")/.."

SMOKE_ARG=""
for arg in "$@"; do
  case "$arg" in
    --smoke) SMOKE_ARG="--smoke" ;;
    *) echo "unknown flag: $arg" >&2; exit 2 ;;
  esac
done

export CARGO_NET_OFFLINE=true
export C3A_BENCH_OUT="$PWD/BENCH_interp.json"
export C3A_BENCH_SERVE_OUT="$PWD/BENCH_serve.json"

echo "== bench_operator =="
# shellcheck disable=SC2086
cargo bench --bench bench_operator -- $SMOKE_ARG

echo "== bench_interp =="
# shellcheck disable=SC2086
cargo bench --bench bench_interp -- $SMOKE_ARG

echo "== bench_serve =="
# shellcheck disable=SC2086
cargo bench --bench bench_serve -- $SMOKE_ARG

echo "== BENCH_interp.json =="
cat BENCH_interp.json

echo "== BENCH_serve.json =="
cat BENCH_serve.json

echo "(compare against the committed baselines with scripts/bench_compare.sh)"
