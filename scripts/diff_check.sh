#!/usr/bin/env bash
# Differential oracle rig: run every substrate-vs-reference cross-check
# (rust/tests/differential.rs) — forward logits, losses, gradients +
# finite differences, 5-step train trajectories, and the serving path.
# Divergences are appended to DIFF_REPORT.txt (override with
# C3A_DIFF_REPORT), naming the artifact / tensor / first diverging
# element; CI uploads the report as an artifact on failure.
#
# Usage: scripts/diff_check.sh [--full]
#   --full   add every artifact of the remaining small models (enc_base,
#            vit_base, dec_small); without it only the enc_tiny + mlp
#            slice runs (C3A_DIFF_FULL is explicitly cleared).
#
# Thread counts: the harness honors C3A_THREADS like everything else;
# CI runs it at C3A_THREADS=1 and =4.

set -euo pipefail
cd "$(dirname "$0")/.."

export CARGO_NET_OFFLINE=true
export C3A_DIFF_REPORT="${C3A_DIFF_REPORT:-$PWD/DIFF_REPORT.txt}"

# a stale `export C3A_DIFF_FULL=...` must not silently trigger the
# multi-minute sweep: only --full enables it
unset C3A_DIFF_FULL
for arg in "$@"; do
  case "$arg" in
    --full) export C3A_DIFF_FULL=1 ;;
    *) echo "unknown flag: $arg" >&2; exit 2 ;;
  esac
done

rm -f "$C3A_DIFF_REPORT"

echo "== differential: substrate vs reference oracle (C3A_THREADS=${C3A_THREADS:-auto}, full=${C3A_DIFF_FULL:-0}) =="
cargo test --release --test differential -- --nocapture

echo "differential OK (no divergences)"
