#!/usr/bin/env bash
# Determinism lint gate: build and run tools/detlint over the gate set
# (rust/src, rust/tests, rust/benches, examples, and detlint's own
# sources).  See docs/LINTING.md for the rules and allowlist syntax.
#
# Usage: scripts/lint.sh [extra detlint args...]
#   With args, they replace the default path list (e.g.
#   `scripts/lint.sh rust/src/substrate` to lint one subtree).

set -euo pipefail
cd "$(dirname "$0")/.."

export CARGO_NET_OFFLINE=true

cargo run --release -q -p detlint -- "$@"
