#!/usr/bin/env bash
# Tier-1 verification: build + test fully offline with default features
# (pure-Rust substrate fallback backend; no network, no system XLA, no
# python).  Also compiles every example and bench target so the whole
# workspace stays green.
#
# Usage: scripts/verify.sh [--quick]
#   --quick   skip the quickstart example run (build/test only)

set -euo pipefail
cd "$(dirname "$0")/.."

QUICK=0
for arg in "$@"; do
  case "$arg" in
    --quick) QUICK=1 ;;
    *) echo "unknown flag: $arg" >&2; exit 2 ;;
  esac
done

export CARGO_NET_OFFLINE=true

echo "== determinism lint: scripts/lint.sh =="
scripts/lint.sh

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

echo "== compile examples + benches =="
cargo build --release --examples --benches

echo "== doc gate: cargo doc --no-deps, warnings denied =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

# SIMD leg: the vector microkernels need std::simd (nightly).  Prefer an
# installed nightly toolchain; fall back to RUSTC_BOOTSTRAP=1 on the
# default toolchain so the leg still runs in single-toolchain containers.
# The scalar build above stays the tier-1 reference either way.
echo "== simd feature: build + bitwise-parity tests =="
(
  if cargo +nightly --version >/dev/null 2>&1; then
    SIMD_TOOLCHAIN="+nightly"
  else
    echo "   (no nightly toolchain; using RUSTC_BOOTSTRAP=1)"
    export RUSTC_BOOTSTRAP=1
    SIMD_TOOLCHAIN=""
  fi
  cargo $SIMD_TOOLCHAIN build --release --features simd
  cargo $SIMD_TOOLCHAIN test -q --features simd
)

if [ "$QUICK" -eq 0 ]; then
  echo "== quickstart on the fallback backend =="
  cargo run --release --example quickstart
fi

echo "verify OK"
