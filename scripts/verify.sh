#!/usr/bin/env bash
# Tier-1 verification: build + test fully offline with default features
# (pure-Rust substrate fallback backend; no network, no system XLA, no
# python).  Also compiles every example and bench target so the whole
# workspace stays green.
#
# Usage: scripts/verify.sh [--quick]
#   --quick   skip the quickstart example run (build/test only)

set -euo pipefail
cd "$(dirname "$0")/.."

QUICK=0
for arg in "$@"; do
  case "$arg" in
    --quick) QUICK=1 ;;
    *) echo "unknown flag: $arg" >&2; exit 2 ;;
  esac
done

export CARGO_NET_OFFLINE=true

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

echo "== compile examples + benches =="
cargo build --release --examples --benches

if [ "$QUICK" -eq 0 ]; then
  echo "== quickstart on the fallback backend =="
  cargo run --release --example quickstart
fi

echo "verify OK"
