//! `xla-shim` — a pure-Rust implementation of the subset of the `xla`
//! (PJRT binding) crate API that the c3a runtime uses.
//!
//! The shim has two halves:
//!
//! * **Literals** (fully functional): shaped host values in row-major
//!   layout, the data currency between the coordinator and any execution
//!   backend.  `Literal` intentionally mirrors `substrate::tensor::Tensor`
//!   semantics (row-major, f32/i32, reshape preserves element order).
//! * **PJRT handles** (structural): `PjRtClient`, `PjRtBuffer`,
//!   `HloModuleProto`, `XlaComputation`, `PjRtLoadedExecutable` exist so
//!   HLO-path code compiles unchanged, but compiling/executing HLO returns
//!   a descriptive error until real bindings are vendored (the `pjrt`
//!   feature marks that seam).  The default execution path never touches
//!   them: the c3a runtime routes artifacts through its substrate
//!   interpreter backend instead.

use anyhow::{bail, Result};

// ---------------------------------------------------------------------------
// Element types
// ---------------------------------------------------------------------------

/// Scalar element types a [`Literal`] can hold.
pub trait Element: Copy + 'static {
    fn wrap_vec(v: Vec<Self>) -> LitData;
    /// Extract (with numeric conversion) from literal storage.
    fn unwrap_vec(data: &LitData) -> Result<Vec<Self>>;
}

impl Element for f32 {
    fn wrap_vec(v: Vec<f32>) -> LitData {
        LitData::F32(v)
    }

    fn unwrap_vec(data: &LitData) -> Result<Vec<f32>> {
        match data {
            LitData::F32(v) => Ok(v.clone()),
            LitData::I32(v) => Ok(v.iter().map(|&x| x as f32).collect()),
            LitData::Tuple(_) => bail!("cannot read a tuple literal as f32"),
        }
    }
}

impl Element for i32 {
    fn wrap_vec(v: Vec<i32>) -> LitData {
        LitData::I32(v)
    }

    fn unwrap_vec(data: &LitData) -> Result<Vec<i32>> {
        match data {
            LitData::I32(v) => Ok(v.clone()),
            LitData::F32(v) => Ok(v.iter().map(|&x| x as i32).collect()),
            LitData::Tuple(_) => bail!("cannot read a tuple literal as i32"),
        }
    }
}

// ---------------------------------------------------------------------------
// Literal
// ---------------------------------------------------------------------------

/// Storage of a literal: flat row-major payload or a tuple of literals.
#[derive(Clone, Debug)]
pub enum LitData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// A shaped host value (row-major).  Scalars have an empty shape.
#[derive(Clone, Debug)]
pub struct Literal {
    shape: Vec<i64>,
    data: LitData,
}

/// Array shape descriptor (mirrors the binding crate's accessor).
#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> Vec<i64> {
        self.dims.clone()
    }
}

impl Literal {
    /// Rank-0 literal.
    pub fn scalar<T: Element>(v: T) -> Literal {
        Literal { shape: Vec::new(), data: T::wrap_vec(vec![v]) }
    }

    /// Rank-1 literal.
    pub fn vec1<T: Element>(v: &[T]) -> Literal {
        Literal { shape: vec![v.len() as i64], data: T::wrap_vec(v.to_vec()) }
    }

    /// Build directly from shape + f32 payload (shim-native constructor).
    pub fn from_f32(shape: &[usize], data: Vec<f32>) -> Literal {
        assert_eq!(shape.iter().product::<usize>().max(1), data.len());
        Literal { shape: shape.iter().map(|&d| d as i64).collect(), data: LitData::F32(data) }
    }

    /// Build directly from shape + i32 payload (shim-native constructor).
    pub fn from_i32(shape: &[usize], data: Vec<i32>) -> Literal {
        assert_eq!(shape.iter().product::<usize>().max(1), data.len());
        Literal { shape: shape.iter().map(|&d| d as i64).collect(), data: LitData::I32(data) }
    }

    /// Tuple literal.
    pub fn tuple(elems: Vec<Literal>) -> Literal {
        Literal { shape: Vec::new(), data: LitData::Tuple(elems) }
    }

    /// Number of payload elements (1 for scalars).
    pub fn element_count(&self) -> usize {
        match &self.data {
            LitData::F32(v) => v.len(),
            LitData::I32(v) => v.len(),
            LitData::Tuple(v) => v.len(),
        }
    }

    /// Row-major reshape: element order is preserved, counts must match.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n < 0 {
            bail!("reshape to negative dims {dims:?}");
        }
        if matches!(self.data, LitData::Tuple(_)) {
            bail!("cannot reshape a tuple literal");
        }
        if n as usize != self.element_count() {
            bail!("reshape {:?} -> {dims:?}: element count {} != {n}", self.shape, self.element_count());
        }
        Ok(Literal { shape: dims.to_vec(), data: self.data.clone() })
    }

    /// Flat row-major payload (numeric dtypes convert).
    pub fn to_vec<T: Element>(&self) -> Result<Vec<T>> {
        T::unwrap_vec(&self.data)
    }

    /// First element of the payload.
    pub fn get_first_element<T: Element>(&self) -> Result<T> {
        let v = T::unwrap_vec(&self.data)?;
        match v.first() {
            Some(&x) => Ok(x),
            None => bail!("empty literal has no first element"),
        }
    }

    /// Flatten a tuple literal; a non-tuple flattens to itself.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.data {
            LitData::Tuple(v) => Ok(v),
            _ => Ok(vec![self]),
        }
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        if matches!(self.data, LitData::Tuple(_)) {
            bail!("tuple literal has no array shape");
        }
        Ok(ArrayShape { dims: self.shape.clone() })
    }

    /// Shape as usize dims (shim-native accessor).
    pub fn shape_usize(&self) -> Vec<usize> {
        self.shape.iter().map(|&d| d as usize).collect()
    }

    /// True when the payload is i32.
    pub fn is_i32(&self) -> bool {
        matches!(self.data, LitData::I32(_))
    }

    /// Zero-copy f32 payload view (shim-native; errors on i32/tuple).
    pub fn f32_slice(&self) -> Result<&[f32]> {
        match &self.data {
            LitData::F32(v) => Ok(v),
            _ => bail!("literal is not f32"),
        }
    }

    /// Zero-copy i32 payload view (shim-native; errors on f32/tuple).
    pub fn i32_slice(&self) -> Result<&[i32]> {
        match &self.data {
            LitData::I32(v) => Ok(v),
            _ => bail!("literal is not i32"),
        }
    }

    /// Consume the literal and take its f32 payload without copying
    /// (i32 converts; tuples error).  The owning counterpart of
    /// [`Literal::to_vec`] — eval outputs move through here instead of
    /// cloning a full logits buffer per request.
    pub fn into_vec_f32(self) -> Result<Vec<f32>> {
        match self.data {
            LitData::F32(v) => Ok(v),
            LitData::I32(v) => Ok(v.into_iter().map(|x| x as f32).collect()),
            LitData::Tuple(_) => bail!("cannot read a tuple literal as f32"),
        }
    }
}

// ---------------------------------------------------------------------------
// PJRT handles (structural; HLO execution requires real vendored bindings)
// ---------------------------------------------------------------------------

const PJRT_UNAVAILABLE: &str = "HLO/PJRT execution is unavailable: the in-tree xla-shim only \
     executes through the substrate fallback backend. Vendor real `xla` \
     PJRT bindings and build with `--features pjrt` (see rust/README.md)";

/// PJRT client handle.
#[derive(Clone, Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        bail!("{PJRT_UNAVAILABLE}");
    }

    /// Upload a host literal to a (host-resident, in the shim) buffer.
    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        literal: &Literal,
    ) -> Result<PjRtBuffer> {
        Ok(PjRtBuffer { literal: literal.clone() })
    }
}

/// Parsed HLO module handle.  Parsing HLO text needs the real bindings.
#[derive(Clone, Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        bail!("{PJRT_UNAVAILABLE}");
    }
}

/// Computation handle built from an HLO module.
#[derive(Clone, Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Compiled executable handle.  Unreachable without real bindings (the
/// only constructor, `PjRtClient::compile`, errors first).
#[derive(Clone, Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _inputs: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        bail!("{PJRT_UNAVAILABLE}");
    }

    pub fn execute_b<B: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _inputs: &[B],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        bail!("{PJRT_UNAVAILABLE}");
    }
}

/// Device buffer.  In the shim this is a host literal wrapper, which is
/// exactly what the fallback backend needs for the `run_b` path.
#[derive(Clone, Debug)]
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    pub fn from_literal(literal: Literal) -> PjRtBuffer {
        PjRtBuffer { literal }
    }

    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_roundtrip() {
        let l = Literal::scalar(3.5f32);
        assert_eq!(l.get_first_element::<f32>().unwrap(), 3.5);
        assert!(l.array_shape().unwrap().dims().is_empty());
    }

    #[test]
    fn vec_reshape_preserves_row_major_order() {
        let l = Literal::vec1(&[1f32, 2.0, 3.0, 4.0, 5.0, 6.0]).reshape(&[2, 3]).unwrap();
        assert_eq!(l.array_shape().unwrap().dims(), vec![2, 3]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn reshape_count_mismatch_rejected() {
        assert!(Literal::vec1(&[1f32, 2.0]).reshape(&[3]).is_err());
    }

    #[test]
    fn i32_literals_and_conversion() {
        let l = Literal::vec1(&[1i32, -2, 3]);
        assert!(l.is_i32());
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![1, -2, 3]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, -2.0, 3.0]);
        assert_eq!(l.i32_slice().unwrap(), &[1, -2, 3]);
        assert!(l.f32_slice().is_err());
    }

    #[test]
    fn into_vec_f32_moves_payload() {
        let l = Literal::from_f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(l.into_vec_f32().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        let i = Literal::vec1(&[5i32, 6]);
        assert_eq!(i.into_vec_f32().unwrap(), vec![5.0, 6.0]);
        assert!(Literal::tuple(vec![]).into_vec_f32().is_err());
    }

    #[test]
    fn tuple_flattening() {
        let t = Literal::tuple(vec![Literal::scalar(1f32), Literal::scalar(2f32)]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        let single = Literal::scalar(9f32);
        assert_eq!(single.to_tuple().unwrap().len(), 1);
    }

    #[test]
    fn hlo_path_reports_unavailable() {
        assert!(HloModuleProto::from_text_file("/nope.hlo.txt").is_err());
        let c = PjRtClient::cpu().unwrap();
        assert!(c.compile(&XlaComputation).is_err());
    }

    #[test]
    fn buffers_wrap_literals() {
        let c = PjRtClient::cpu().unwrap();
        let b = c
            .buffer_from_host_literal(None, &Literal::vec1(&[1f32, 2.0]).reshape(&[2]).unwrap())
            .unwrap();
        assert_eq!(b.to_literal_sync().unwrap().to_vec::<f32>().unwrap(), vec![1.0, 2.0]);
    }
}
