//! End-to-end bench: train-step latency + eval throughput for each PEFT
//! method on the real AOT artifacts — the measured backing for the
//! paper's Table 2/3 efficiency columns.  `harness = false`.
//!
//! Skips gracefully when artifacts haven't been built.

use c3a::coordinator::lr::Schedule;
use c3a::coordinator::run::{self, Ctx};
use c3a::coordinator::TrainCfg;
use c3a::data::glue_sim::GlueTask;
use c3a::peft::init::C3aScheme;

fn main() -> anyhow::Result<()> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping bench_tables: run `make artifacts` first");
        return Ok(());
    }
    let ctx = Ctx::open("artifacts")?;
    let steps = 12;
    println!("== bench_tables: train-step latency (enc_base, {steps} steps each) ==");
    println!("{:<10} {:>10} {:>12} {:>12}", "method", "#params", "ms/step", "vs lora");
    let cfg = TrainCfg {
        steps,
        lr: 1e-3,
        weight_decay: 0.0,
        schedule: Schedule::Constant,
        eval_every: 0,
        patience: 0,
        verbose: false,
    };
    let mut lora_ms = None;
    for method in ["lora", "vera", "boft", "c3a_d1", "c3a_d8", "bitfit", "ia3", "full"] {
        let r =
            run::glue_run(&ctx, "enc_base", method, GlueTask::Sst2, 0, &cfg, C3aScheme::Xavier)?;
        if method == "lora" {
            lora_ms = Some(r.step_ms);
        }
        println!(
            "{:<10} {:>10} {:>12.1} {:>12.2}",
            method,
            r.n_params,
            r.step_ms,
            r.step_ms / lora_ms.unwrap_or(r.step_ms)
        );
    }
    println!("\npaper shape: c3a within ~1.2x of lora; vera/boft/full slower.");
    Ok(())
}
