//! Operator-level benchmark (paper Table 1, measured half): dense matvec
//! vs LoRA vs VeRA vs C3A block-circulant FFT matvec across dimensions.
//! `harness = false` (criterion unavailable offline) — a seeded, warmup +
//! repeated-timing harness with median-of-runs reporting.

use c3a::substrate::circulant::BlockCirculant;
use c3a::substrate::fft::Plan;
use c3a::substrate::linalg::{matvec_into, LoRaDelta, VeraDelta};
use c3a::substrate::prng::Rng;
use std::time::Instant;

fn bench(name: &str, iters: usize, mut f: impl FnMut()) -> f64 {
    for _ in 0..3 {
        f(); // warmup
    }
    let mut times = Vec::with_capacity(5);
    for _ in 0..5 {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        times.push(t0.elapsed().as_secs_f64() * 1e6 / iters as f64);
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let med = times[2];
    println!("{name:<38} {med:>12.2} us/op");
    med
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let dims: &[usize] = if smoke { &[256, 1024] } else { &[256, 1024, 4096] };
    println!(
        "== bench_operator: Table 1 measured ({} threads) ==",
        c3a::substrate::parallel::threads()
    );
    for &d in dims {
        let mut rng = Rng::seed(d as u64);
        let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        println!("\n-- d = {d} --");

        // dense d x d matvec (the merged-weight upper bound)
        let w: Vec<f64> = (0..d * d).map(|_| rng.normal()).collect();
        let mut y = vec![0.0; d];
        let dense = bench(&format!("dense {d}x{d}"), 20, || matvec_into(&w, d, d, &x, &mut y));

        // lora r=8
        let r = 8;
        let lora = LoRaDelta {
            a: (0..r * d).map(|_| rng.normal()).collect(),
            b: (0..d * r).map(|_| rng.normal()).collect(),
            r,
            d_in: d,
            d_out: d,
            scale: 1.0,
        };
        let mut h = vec![0.0; r];
        let lora_us = bench("lora r=8 delta", 100, || lora.matvec_into(&x, &mut h, &mut y));

        // c3a at b = d/8 (same param budget as 2x lora) and b = d
        for div in [1usize, 8] {
            let b = d / div;
            assert!(b > 0 && d % b == 0, "block size {b} must divide d={d}");
            let m = d / b;
            let bc = BlockCirculant::new(m, m, b, (0..m * m * b).map(|_| rng.normal()).collect());
            let p = bc.prepared();
            let mut out = vec![0.0; d];
            bench(&format!("c3a b=d/{div} ({} params)", bc.param_count()), 50, || {
                p.matvec_into(&x, &mut out)
            });
        }

        // vera r_v = d
        let rv = d;
        let vera = VeraDelta {
            a: (0..rv * d).map(|_| rng.normal()).collect(),
            b: (0..d * rv).map(|_| rng.normal()).collect(),
            ld: vec![0.1; rv],
            lb: vec![1.0; d],
            r_v: rv,
            d_in: d,
            d_out: d,
        };
        let vera_us = bench(&format!("vera r_v={rv} delta"), 10, || {
            let _ = vera.matvec(&x);
        });

        // raw FFT throughput at the block size the paper favours
        let b = d / 8;
        let plan = Plan::new(b);
        let sig: Vec<f64> = (0..b).map(|_| rng.normal()).collect();
        bench(&format!("fft len {b}"), 200, || {
            let _ = c3a::substrate::fft::rfft(&plan, &sig);
        });

        println!(
            "ratios: vera/lora = {:.1}x, dense/lora = {:.1}x  (paper: vera >> lora ~ c3a)",
            vera_us / lora_us,
            dense / lora_us
        );
    }

    // -- dense O(b²) vs FFT block-circulant matvec across block sizes:
    // the measured basis for BlockCirculant::DENSE_CROSSOVER_B (the
    // matvec_auto heuristic).  Fixed total dim, b sweeps the divisors;
    // both paths are deterministic but round differently, so this is a
    // speed table, not a parity check (docs/DETERMINISM.md §3).
    let d = if smoke { 256 } else { 512 };
    println!(
        "\n== dense-vs-FFT crossover (d = {d}, auto switches at b <= {}) ==",
        BlockCirculant::DENSE_CROSSOVER_B
    );
    println!(
        "{:<8} {:>12} {:>12} {:>10}  {}",
        "b",
        "dense us/op",
        "fft us/op",
        "dense/fft",
        "auto"
    );
    for b in [4usize, 8, 16, 32, 64, 128] {
        if b > d {
            break;
        }
        let m = d / b;
        let mut rng = Rng::seed(b as u64);
        let bc = BlockCirculant::new(m, m, b, (0..m * m * b).map(|_| rng.normal()).collect());
        let p = bc.prepared();
        let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let (mut yd, mut yf) = (vec![0.0; d], vec![0.0; d]);
        let iters = if smoke { 20 } else { 50 };
        let mut quiet_med = |f: &mut dyn FnMut()| -> f64 {
            for _ in 0..3 {
                f();
            }
            let mut times = Vec::with_capacity(5);
            for _ in 0..5 {
                let t0 = Instant::now();
                for _ in 0..iters {
                    f();
                }
                times.push(t0.elapsed().as_secs_f64() * 1e6 / iters as f64);
            }
            times.sort_by(|a, b| a.partial_cmp(b).unwrap());
            times[2]
        };
        let dense_us = quiet_med(&mut || bc.matvec_dense_into(&x, &mut yd));
        let fft_us = quiet_med(&mut || p.matvec_into(&x, &mut yf));
        let auto = if b <= BlockCirculant::DENSE_CROSSOVER_B { "dense" } else { "fft" };
        println!("{b:<8} {dense_us:>12.2} {fft_us:>12.2} {:>9.2}x  {auto}", dense_us / fft_us);
    }
}
