//! Interp train/serve benchmark tracking the tentpole speedups: the
//! cached + multi-threaded session path vs the stateless single-threaded
//! interpreter (the pre-seam behavior), plus a spectra-cached C3A matvec
//! ops/s figure and a short serve-style `EvalSession::logits` loop.
//!
//! Emits `BENCH_interp.json` in the working directory so CI can track the
//! perf trajectory.  `harness = false`; pass `--smoke` for the quick CI
//! run, `C3A_THREADS` to pin the pool size.
//!
//!     cargo bench --bench bench_interp [-- --smoke]

use c3a::peft::init::C3aScheme;
use c3a::runtime::catalog;
use c3a::runtime::interp::InterpExecutable;
use c3a::runtime::manifest::ArtifactSpec;
use c3a::runtime::session::{build_init, EvalSession, TrainSession};
use c3a::runtime::Engine;
use c3a::substrate::circulant::BlockCirculant;
use c3a::substrate::env;
use c3a::substrate::parallel;
use c3a::substrate::prng::Rng;
use c3a::substrate::simd;
use c3a::substrate::tensor::Tensor;
use c3a::xla;
use std::time::Instant;

/// Data batch (tensors in `data_order`) for session-driven steps.
fn build_batch(spec: &ArtifactSpec) -> Vec<Tensor> {
    let mut batch = Vec::new();
    for name in &spec.data_order {
        let inp = spec.inputs.iter().find(|i| &i.name == name).unwrap();
        let n: usize = inp.shape.iter().product::<usize>().max(1);
        if inp.i32_dtype {
            let vals: Vec<i32> = if inp.name == "data.y" {
                (0..n).map(|i| (i % 2) as i32).collect()
            } else {
                (0..n).map(|i| if i % 7 == 0 { 1 } else { 4 + (i as i32 % 50) }).collect()
            };
            batch.push(Tensor::from_i32(inp.shape.clone(), &vals));
        } else {
            batch.push(Tensor::from_f32(inp.shape.clone(), &vec![1.0f32; n]));
        }
    }
    batch
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let steps = if smoke { 8 } else { 40 };
    let serve_calls = if smoke { 16 } else { 100 };
    let max_threads = parallel::threads();

    let dir = std::env::temp_dir().join("c3a_bench_interp");
    let manifest = catalog::synthesize(&dir)?;
    let spec = manifest.artifact("enc_tiny__c3a_d8__cls__train")?.clone();
    let eval_spec = manifest.artifact("enc_tiny__c3a_d8__cls__eval")?.clone();
    let meta = manifest.model("enc_tiny")?.clone();

    println!("== bench_interp: enc_tiny/c3a_d8, {steps} steps, threads={max_threads} ==");

    // -- baseline: stateless + single-threaded (pre-seam behavior).  A
    // fresh executable per step guarantees no cache survives.
    let lits = catalog::synth_inputs(&spec, &meta);
    let refs: Vec<&xla::Literal> = lits.iter().collect();
    parallel::set_threads(1);
    {
        // warmup
        let exe = InterpExecutable::new(&spec, &meta)?;
        exe.execute(&refs)?;
    }
    let t0 = Instant::now();
    for _ in 0..steps {
        let exe = InterpExecutable::new(&spec, &meta)?;
        exe.execute(&refs)?;
    }
    let step_ms_single = t0.elapsed().as_secs_f64() * 1e3 / steps as f64;
    println!("stateless single-thread : {step_ms_single:>8.2} ms/step");

    // -- tentpole path: persistent session state + thread pool
    parallel::set_threads(max_threads);
    let engine = Engine::for_manifest(&manifest)?;
    let mut rng = Rng::seed(1);
    let base = catalog::init_base_params(&meta);
    let init = build_init(&spec, &base, None, &mut rng, C3aScheme::Xavier)?;
    let mut session = TrainSession::new(&engine, &spec, &init)?;
    let batch = build_batch(&spec);
    session.step(&batch, 0.01, 0.0)?; // warmup
    let t1 = Instant::now();
    for _ in 0..steps {
        session.step(&batch, 0.01, 0.0)?;
    }
    let step_ms_cached = t1.elapsed().as_secs_f64() * 1e3 / steps as f64;
    let speedup = step_ms_single / step_ms_cached;
    println!("cached  multi-thread    : {step_ms_cached:>8.2} ms/step  ({speedup:.2}x)");

    // -- scalar vs SIMD: the same cached session with the vector kernels
    // forced off (only meaningful when built with --features simd; the
    // kernels are bitwise identical to scalar, so this measures pure
    // throughput — docs/DETERMINISM.md § SIMD).
    let (step_ms_scalar, simd_step_speedup) = if simd::available() && simd::enabled() {
        let _g = simd::override_lock();
        simd::set_enabled(false);
        session.step(&batch, 0.01, 0.0)?; // warmup scalar path
        let ts = Instant::now();
        for _ in 0..steps {
            session.step(&batch, 0.01, 0.0)?;
        }
        simd::set_enabled(true);
        let ms = ts.elapsed().as_secs_f64() * 1e3 / steps as f64;
        let sx = ms / step_ms_cached;
        println!("scalar  (C3A_SIMD=0)    : {ms:>8.2} ms/step  (simd {sx:.2}x vs scalar)");
        (format!("{ms:.3}"), format!("{sx:.3}"))
    } else {
        ("null".into(), "null".into())
    };

    // -- serve-style loop: repeated EvalSession::logits with a fixed
    // adapter (trainable upload + frozen parse + spectra + execution plan
    // all reused)
    let eval_init = build_init(&eval_spec, &base, None, &mut Rng::seed(2), C3aScheme::Xavier)?;
    let eval_session = EvalSession::new(&engine, &eval_spec, &eval_init)?;
    let adapter = session.trainable_tensors()?;
    let (b, s) = (eval_spec.batch, eval_spec.seq);
    let toks: Vec<i32> =
        (0..b * s).map(|i| if i % 5 == 0 { 1 } else { 3 + (i as i32 % 40) }).collect();
    let eval_batch = vec![Tensor::from_i32(vec![b, s], &toks)];
    eval_session.logits(&adapter, &eval_batch)?; // warmup
    let t2 = Instant::now();
    for _ in 0..serve_calls {
        eval_session.logits(&adapter, &eval_batch)?;
    }
    let serve_req_s = (serve_calls * b) as f64 / t2.elapsed().as_secs_f64();
    let uploads = eval_session.upload_count();
    println!("serve loop              : {serve_req_s:>8.1} req/s  (uploads={uploads})");

    // -- plan replay vs rebuild: the same steady-state eval loop with the
    // execution plan disabled (C3A_PLAN=0 rebuilds the tape per request)
    // vs enabled (record once, replay into the arena).  Sessions are
    // built while the env var is set; it only gates state construction.
    let rebuild_session = {
        let _plan_off = env::ScopedSet::set(env::PLAN, "0");
        EvalSession::new(&engine, &eval_spec, &eval_init)?
    };
    let replay_session = EvalSession::new(&engine, &eval_spec, &eval_init)?;
    for _ in 0..2 {
        rebuild_session.logits(&adapter, &eval_batch)?;
        replay_session.logits(&adapter, &eval_batch)?;
    }
    let t_rebuild = Instant::now();
    for _ in 0..serve_calls {
        rebuild_session.logits(&adapter, &eval_batch)?;
    }
    let eval_ms_rebuild = t_rebuild.elapsed().as_secs_f64() * 1e3 / serve_calls as f64;
    let t_replay = Instant::now();
    for _ in 0..serve_calls {
        replay_session.logits(&adapter, &eval_batch)?;
    }
    let eval_ms_replay = t_replay.elapsed().as_secs_f64() * 1e3 / serve_calls as f64;
    let plan_speedup = eval_ms_rebuild / eval_ms_replay;
    // under an operator-set C3A_PLAN=0 the "replay" session is a second
    // rebuild session: report honestly instead of panicking
    let pstats = replay_session.plan_stats().unwrap_or_default();
    if pstats.ops == 0 {
        println!("plan replay             : DISABLED (C3A_PLAN=0) — rebuild-vs-rebuild shown");
    }
    println!(
        "plan replay             : {eval_ms_replay:>8.3} ms/req vs rebuild \
         {eval_ms_rebuild:.3} ms/req ({plan_speedup:.2}x; {} ops, {} shared bufs)",
        pstats.ops, pstats.shared_buffers
    );

    // -- hoist-on vs hoist-off cached eval step: C3A keeps its adapter
    // math on the request side, so the hoisting pass is measured on a
    // BOFT artifact (its rotation construction depends only on the
    // adapter version and is the hoisted prefix).  One session, both
    // configs: `C3A_HOIST` is re-read per replay, so the kill switch
    // flips the same recorded plan between skipping and full recompute.
    let hoist_spec = manifest.artifact("enc_tiny__boft__cls__eval")?.clone();
    let hoist_init = build_init(&hoist_spec, &base, None, &mut Rng::seed(5), C3aScheme::Xavier)?;
    let hoist_session = EvalSession::new(&engine, &hoist_spec, &hoist_init)?;
    let hoist_adapter = hoist_init.trainable.clone();
    for _ in 0..3 {
        hoist_session.logits(&hoist_adapter, &eval_batch)?; // record + settle
    }
    let t_hoist_on = Instant::now();
    for _ in 0..serve_calls {
        hoist_session.logits(&hoist_adapter, &eval_batch)?;
    }
    let eval_ms_hoist_on = t_hoist_on.elapsed().as_secs_f64() * 1e3 / serve_calls as f64;
    let eval_ms_hoist_off = {
        let _hoist_off = env::ScopedSet::set(env::HOIST, "0");
        hoist_session.logits(&hoist_adapter, &eval_batch)?; // warmup full replay
        let t = Instant::now();
        for _ in 0..serve_calls {
            hoist_session.logits(&hoist_adapter, &eval_batch)?;
        }
        t.elapsed().as_secs_f64() * 1e3 / serve_calls as f64
    };
    let hoist_speedup = eval_ms_hoist_off / eval_ms_hoist_on;
    let hstats = hoist_session.plan_stats().unwrap_or_default();
    if hstats.hoisted_ops == 0 {
        println!("hoisted replay          : DISABLED (C3A_PLAN=0 or C3A_HOIST=0 at record)");
    }
    println!(
        "hoisted replay (boft)   : {eval_ms_hoist_on:>8.3} ms/req vs full \
         {eval_ms_hoist_off:.3} ms/req ({hoist_speedup:.2}x; {} of {} ops hoisted, \
         {} skips)",
        hstats.hoisted_ops, hstats.ops, hstats.hoist_skips
    );

    // -- spectra-cached C3A matvec ops/s (production inference operator)
    let d = 1024usize;
    let blk = d / 8;
    let m = d / blk;
    let mut brng = Rng::seed(d as u64);
    let bc =
        BlockCirculant::new(m, m, blk, (0..m * m * blk).map(|_| brng.normal()).collect());
    let prepared = bc.prepared();
    let x: Vec<f64> = (0..d).map(|_| brng.normal()).collect();
    let mut out = vec![0.0; d];
    let iters = if smoke { 200 } else { 2000 };
    prepared.matvec_into(&x, &mut out); // warmup
    let t3 = Instant::now();
    for _ in 0..iters {
        prepared.matvec_into(&x, &mut out);
    }
    let ops_per_s = iters as f64 / t3.elapsed().as_secs_f64();
    println!("c3a matvec d={d} b={blk}  : {ops_per_s:>8.0} ops/s");

    // -- JSON report (no serde offline; fields are flat and numeric).
    // `features` + `c3a_threads` stamp the measurement config so
    // bench_compare never hard-gates across unlike configurations
    // (docs/BENCHMARKS.md).
    let plan_ops = pstats.ops;
    let plan_shared = pstats.shared_buffers;
    let plan_hoisted = hstats.hoisted_ops;
    let features = if simd::available() { "simd" } else { "default" };
    let c3a_threads = match env::raw(env::THREADS) {
        Some(v) => format!("\"{v}\""),
        None => "null".into(),
    };
    let json = format!(
        "{{\n  \"bench\": \"interp\",\n  \"model\": \"enc_tiny/c3a_d8\",\n  \"smoke\": {smoke},\n  \"threads\": {max_threads},\n  \"c3a_threads\": {c3a_threads},\n  \"features\": \"{features}\",\n  \"steps\": {steps},\n  \"step_ms_stateless_single\": {step_ms_single:.3},\n  \"step_ms_cached_threaded\": {step_ms_cached:.3},\n  \"speedup\": {speedup:.3},\n  \"step_ms_cached_scalar\": {step_ms_scalar},\n  \"simd_step_speedup\": {simd_step_speedup},\n  \"serve_req_per_s\": {serve_req_s:.1},\n  \"serve_uploads\": {uploads},\n  \"eval_ms_rebuild\": {eval_ms_rebuild:.3},\n  \"eval_ms_replay\": {eval_ms_replay:.3},\n  \"plan_replay_speedup\": {plan_speedup:.3},\n  \"plan_ops\": {plan_ops},\n  \"plan_shared_buffers\": {plan_shared},\n  \"eval_ms_hoist_on\": {eval_ms_hoist_on:.3},\n  \"eval_ms_hoist_off\": {eval_ms_hoist_off:.3},\n  \"hoist_step_speedup\": {hoist_speedup:.3},\n  \"plan_hoisted_ops\": {plan_hoisted},\n  \"c3a_matvec_ops_per_s\": {ops_per_s:.0}\n}}\n"
    );
    // cargo bench runs with the package dir as cwd; the bench script sets
    // C3A_BENCH_OUT to pin the report to the repo root
    let out = env::bench_out();
    std::fs::write(&out, &json)?;
    println!("\nwrote {out}:\n{json}");
    Ok(())
}
