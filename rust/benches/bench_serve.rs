//! Multi-adapter serving benchmark: the scheduler + registry over one
//! shared frozen-backbone parse, at 1 adapter vs N adapters.  Emits
//! `BENCH_serve.json` (req/s, p50/p95/p99, mean dynamic batch, per-tenant
//! upload counts) so CI tracks the serving trajectory next to
//! `BENCH_interp.json`.  `harness = false`; pass `--smoke` for the quick
//! CI run.
//!
//!     cargo bench --bench bench_serve [-- --smoke]

use c3a::peft::init::C3aScheme;
use c3a::runtime::catalog;
use c3a::runtime::session::build_init;
use c3a::runtime::Engine;
use c3a::serving::{
    AdapterRegistry, LatencySummary, Scheduler, SchedulerCfg, ServeStats,
    perturb_c3a_kernels as perturb,
};
use c3a::substrate::prng::Rng;
use c3a::substrate::tensor::TensorMap;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

const EVAL: &str = "enc_tiny__c3a_d8__cls__eval";

/// Adapter template + (batch, seq) from the synthesized catalog.
fn template(dir: &Path) -> anyhow::Result<(TensorMap, usize)> {
    let manifest = catalog::synthesize(dir)?;
    let spec = manifest.artifact(EVAL)?.clone();
    let meta = manifest.model("enc_tiny")?.clone();
    let base = catalog::init_base_params(&meta);
    let init = build_init(&spec, &base, None, &mut Rng::seed(1), C3aScheme::Xavier)?;
    Ok((init.trainable, spec.seq))
}

/// Serve `n_requests` round-robin over `n_tenants`; returns (req/s, stats).
fn run_phase(
    dir: &Path,
    adapter: &TensorMap,
    s: usize,
    n_tenants: usize,
    n_requests: usize,
) -> anyhow::Result<(f64, ServeStats)> {
    let adapters: Vec<(String, TensorMap)> = (0..n_tenants)
        .map(|i| (format!("tenant{i}"), perturb(adapter, i as u64, 0.05)))
        .collect();
    let dir: PathBuf = dir.to_path_buf();
    let cfg = SchedulerCfg { queue_cap: 128, max_batch: 0, max_wait: Duration::from_millis(1) };
    let sched = Scheduler::spawn(cfg, move || {
        let manifest = catalog::synthesize(&dir)?;
        let spec = manifest.artifact(EVAL)?.clone();
        let meta = manifest.model("enc_tiny")?.clone();
        let engine = Engine::for_manifest(&manifest)?;
        let base = catalog::init_base_params(&meta);
        let init = build_init(&spec, &base, None, &mut Rng::seed(1), C3aScheme::Xavier)?;
        let mut registry = AdapterRegistry::new(&engine, &spec, &init)?;
        for (name, params) in adapters {
            registry.register(&name, params)?;
        }
        Ok(registry)
    })?;
    let handle = sched.handle();
    let t0 = Instant::now();
    let mut tickets = Vec::with_capacity(n_requests);
    for i in 0..n_requests {
        let tenant = format!("tenant{}", i % n_tenants);
        let toks: Vec<i32> = (0..s as i32)
            .map(|j| if j == 0 { 1 } else { 4 + ((i as i32 * 13 + j * 7) % 40) })
            .collect();
        tickets.push(handle.submit(&tenant, toks).map_err(anyhow::Error::from)?);
    }
    for t in tickets {
        t.wait()?;
    }
    let req_per_s = n_requests as f64 / t0.elapsed().as_secs_f64();
    drop(handle);
    let stats = sched.finish()?;
    Ok((req_per_s, stats))
}

fn phase_json(req_per_s: f64, stats: &ServeStats) -> String {
    let lat: LatencySummary = stats.latency();
    let mean_batch = stats.mean_batch();
    format!(
        "{{ \"req_per_s\": {req_per_s:.1}, \"p50_ms\": {:.3}, \"p95_ms\": {:.3}, \"p99_ms\": {:.3}, \"mean_batch\": {mean_batch:.2} }}",
        lat.p50_ms,
        lat.p95_ms,
        lat.p99_ms
    )
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let n_requests = if smoke { 64 } else { 512 };
    let n_tenants = 4;
    let threads = c3a::substrate::parallel::threads();
    let dir = std::env::temp_dir().join("c3a_bench_serve");
    let (adapter, s) = template(&dir)?;

    println!("== bench_serve: {EVAL}, {n_requests} requests, threads={threads} ==");

    let (rps1, stats1) = run_phase(&dir, &adapter, s, 1, n_requests)?;
    let l1 = stats1.latency();
    println!(
        "1 adapter   : {rps1:>8.1} req/s  p50 {:.2} ms  p95 {:.2} ms  mean batch {:.1}",
        l1.p50_ms,
        l1.p95_ms,
        stats1.mean_batch()
    );

    let (rpsn, statsn) = run_phase(&dir, &adapter, s, n_tenants, n_requests)?;
    let ln = statsn.latency();
    println!(
        "{n_tenants} adapters  : {rpsn:>8.1} req/s  p50 {:.2} ms  p95 {:.2} ms  mean batch {:.1}",
        ln.p50_ms,
        ln.p95_ms,
        statsn.mean_batch()
    );
    for t in &statsn.tenants {
        println!(
            "  tenant {:<8}: {:>4} reqs  uploads={}  spectra {}h/{}m",
            t.name, t.requests, t.uploads, t.spectra_hits, t.spectra_misses
        );
        assert_eq!(t.uploads, 1, "fixed adapter must upload exactly once");
    }

    let uploads: Vec<String> = statsn.tenants.iter().map(|t| t.uploads.to_string()).collect();
    let json = format!(
        "{{\n  \"bench\": \"serve\",\n  \"model\": \"{EVAL}\",\n  \"smoke\": {smoke},\n  \"threads\": {threads},\n  \"requests\": {n_requests},\n  \"tenants\": {n_tenants},\n  \"one_adapter\": {},\n  \"multi_adapter\": {},\n  \"uploads_per_tenant\": [{}]\n}}\n",
        phase_json(rps1, &stats1),
        phase_json(rpsn, &statsn),
        uploads.join(", ")
    );
    let out = std::env::var("C3A_BENCH_SERVE_OUT").unwrap_or_else(|_| "BENCH_serve.json".into());
    std::fs::write(&out, &json)?;
    println!("\nwrote {out}:\n{json}");
    Ok(())
}
