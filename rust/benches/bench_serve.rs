//! Sharded-serving benchmark: the same seeded Zipf traffic storm (bursty
//! arrivals, mid-storm hot-swaps, thousands of adapters in the full run)
//! replayed at `shards = 1` vs `shards = 4`, so the shard speedup and the
//! tail under skew are measured against an identical request sequence
//! ([`ReplayReport::trace_hash`] pins that the two phases really saw the
//! same storm).  Tenancy is tiered: every phase runs under a
//! `ResidentPolicy` (full run: 2000 registered tenants over
//! `max_resident = 64` per shard), so Zipf-hot tenants stay resident and
//! warm-replay while the tail churns through the adapter store — the
//! report splits warm latency from the measured cold-start path.  Emits
//! `BENCH_serve.json`: the top-level
//! `req_per_s`/`p50_ms`/`p95_ms`/`p99_ms`/`cold_start_ms_p95`/
//! `resident_hwm` keys are the sharded headline (what
//! `scripts/bench_compare.sh` tracks), with per-phase and per-shard
//! detail nested under `shards1`/`shards4`.  Latency percentiles are
//! always computed over the pooled cross-shard windows — never by
//! averaging per-shard percentiles.  `harness = false`; pass `--smoke`
//! for the quick CI run.
//!
//!     cargo bench --bench bench_serve [-- --smoke]

use c3a::peft::init::C3aScheme;
use c3a::runtime::catalog;
use c3a::runtime::session::build_init;
use c3a::runtime::Engine;
use c3a::serving::{
    perturb_c3a_kernels as perturb, run_replay, tenant_name, AdapterRegistry, AdapterStore,
    ReplayCfg, ReplayReport, ResidentPolicy, Scheduler, SchedulerCfg, ServeStats, ShardCtx,
};
use c3a::substrate::env;
use c3a::substrate::prng::Rng;
use c3a::substrate::tensor::TensorMap;
use std::path::{Path, PathBuf};
use std::time::Duration;

const EVAL: &str = "enc_tiny__c3a_d8__cls__eval";

/// Adapter template + seq from the synthesized catalog.
fn template(dir: &Path) -> anyhow::Result<(TensorMap, usize)> {
    let manifest = catalog::synthesize(dir)?;
    let spec = manifest.artifact(EVAL)?.clone();
    let meta = manifest.model("enc_tiny")?.clone();
    let base = catalog::init_base_params(&meta);
    let init = build_init(&spec, &base, None, &mut Rng::seed(1), C3aScheme::Xavier)?;
    Ok((init.trainable, spec.seq))
}

/// Replay the seeded storm against a fresh `shards`-worker scheduler.
fn run_phase(
    dir: &Path,
    adapter: &TensorMap,
    s: usize,
    shards: usize,
    max_resident: usize,
    replay: &ReplayCfg,
) -> anyhow::Result<(ReplayReport, ServeStats)> {
    let adapters: Vec<(String, TensorMap)> = (0..replay.tenants)
        .map(|i| (tenant_name(i), perturb(adapter, i as u64, 0.05)))
        .collect();
    // one store dir per phase, shared by all of its shard workers —
    // tenant→shard routing is a partition, so files never collide
    let store_dir = dir.join(format!("store_shards{shards}"));
    let _ = std::fs::remove_dir_all(&store_dir);
    let dir: PathBuf = dir.to_path_buf();
    let cfg = SchedulerCfg {
        shards,
        queue_cap: 128,
        max_batch: 0,
        max_wait: Duration::from_millis(1),
    };
    let sched = Scheduler::spawn(cfg, move |ctx: &ShardCtx| {
        let manifest = catalog::synthesize(&dir)?;
        let spec = manifest.artifact(EVAL)?.clone();
        let meta = manifest.model("enc_tiny")?.clone();
        let engine = Engine::for_manifest(&manifest)?;
        let base = catalog::init_base_params(&meta);
        let init = build_init(&spec, &base, None, &mut Rng::seed(1), C3aScheme::Xavier)?;
        let mut registry = AdapterRegistry::new(&engine, &spec, &init)?;
        // residency first, so registration is a snapshot write (lazy
        // session): registering thousands of tenants stays cheap
        registry.set_residency(
            ResidentPolicy::max_resident(max_resident),
            AdapterStore::open(&store_dir)?,
        )?;
        // each shard parses its own backbone and registers only the
        // tenants that hash to it
        for (name, params) in &adapters {
            if ctx.owns(name) {
                registry.register(name, params.clone())?;
            }
        }
        Ok(registry)
    })?;
    let handle = sched.handle();
    let base_adapter = adapter.clone();
    let report = run_replay(
        &handle,
        replay,
        |i, _rank| {
            (0..s as i32)
                .map(|j| if j == 0 { 1 } else { 4 + ((i as i32 * 13 + j * 7) % 40) })
                .collect()
        },
        move |swap_idx, _rank| perturb(&base_adapter, 500 + swap_idx, 0.1),
    )?;
    drop(handle);
    let stats = sched.finish()?;
    Ok((report, stats))
}

fn phase_json(report: &ReplayReport, stats: &ServeStats) -> String {
    let lat = stats.latency();
    let cold = stats.cold_start_latency();
    let per_shard: Vec<String> = stats
        .shards
        .iter()
        .map(|sh| {
            let l = sh.latency();
            let rps =
                if report.wall_s > 0.0 { sh.served as f64 / report.wall_s } else { 0.0 };
            format!(
                "{{ \"shard\": {}, \"served\": {}, \"req_per_s\": {rps:.1}, \
                 \"p50_ms\": {:.3}, \"p99_ms\": {:.3}, \"queue_depth_hwm\": {}, \
                 \"sheds\": {}, \"resident_hwm\": {}, \"cold_starts\": {} }}",
                sh.shard,
                sh.served,
                l.p50_ms,
                l.p99_ms,
                sh.queue_depth_hwm,
                sh.sheds,
                sh.resident_hwm,
                sh.cold_starts
            )
        })
        .collect();
    format!(
        "{{\n    \"req_per_s\": {:.1},\n    \"p50_ms\": {:.3},\n    \"p95_ms\": {:.3},\n    \"p99_ms\": {:.3},\n    \"mean_batch\": {:.2},\n    \"active_shards\": {},\n    \"sheds\": {},\n    \"dropped\": {},\n    \"swaps\": {},\n    \"resident_now\": {},\n    \"resident_hwm\": {},\n    \"evictions\": {},\n    \"cold_starts\": {},\n    \"cold_start_ms_p50\": {:.3},\n    \"cold_start_ms_p95\": {:.3},\n    \"per_shard\": [{}]\n  }}",
        report.req_per_s(),
        lat.p50_ms,
        lat.p95_ms,
        lat.p99_ms,
        stats.mean_batch(),
        stats.active_shards(),
        stats.sheds,
        report.dropped,
        report.swaps,
        stats.resident_now(),
        stats.resident_hwm(),
        stats.evictions,
        stats.cold_starts,
        cold.p50_ms,
        cold.p95_ms,
        per_shard.join(", ")
    )
}

fn print_phase(label: &str, report: &ReplayReport, stats: &ServeStats) {
    let lat = stats.latency();
    let cold = stats.cold_start_latency();
    println!(
        "{label}: {:>8.1} req/s  p50 {:.2} ms  p95 {:.2} ms  p99 {:.2} ms  \
         mean batch {:.1}  sheds {}  dropped {}",
        report.req_per_s(),
        lat.p50_ms,
        lat.p95_ms,
        lat.p99_ms,
        stats.mean_batch(),
        stats.sheds,
        report.dropped
    );
    println!(
        "  tiering: resident {} (hwm {})  evictions {}  cold starts {}  \
         cold p50 {:.2} ms  cold p95 {:.2} ms",
        stats.resident_now(),
        stats.resident_hwm(),
        stats.evictions,
        stats.cold_starts,
        cold.p50_ms,
        cold.p95_ms
    );
    for sh in &stats.shards {
        println!(
            "  shard {}: {:>5} served  depth hwm {:>3}  sheds {:>3}  p99 {:.2} ms  \
             resident hwm {:>3}  cold {:>4}",
            sh.shard,
            sh.served,
            sh.queue_depth_hwm,
            sh.sheds,
            sh.latency().p99_ms,
            sh.resident_hwm,
            sh.cold_starts
        );
    }
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    // full run: 2000 registered adapters churning through a 64-resident
    // tier under a long storm; smoke keeps CI fast
    let (n_requests, n_tenants, max_resident) =
        if smoke { (96, 24, 8) } else { (1024, 2000, 64) };
    let replay = ReplayCfg {
        seed: 42,
        requests: n_requests,
        tenants: n_tenants,
        zipf_exponent: 1.1,
        burst: 16,
        burst_gap: Duration::from_micros(200),
        // mid-storm hot-swaps land on Zipf-hot tenants
        swap_every: (n_requests / 8).max(1),
        ..ReplayCfg::default()
    };
    let threads = c3a::substrate::parallel::threads();
    let dir = std::env::temp_dir().join("c3a_bench_serve");
    let (adapter, s) = template(&dir)?;

    println!(
        "== bench_serve: {EVAL}, {n_requests} requests over {n_tenants} Zipf tenants, \
         max_resident={max_resident}/shard, threads={threads} =="
    );

    let (r1, s1) = run_phase(&dir, &adapter, s, 1, max_resident, &replay)?;
    print_phase("shards=1", &r1, &s1);
    let (r4, s4) = run_phase(&dir, &adapter, s, 4, max_resident, &replay)?;
    print_phase("shards=4", &r4, &s4);

    // both phases must have replayed the identical storm
    assert_eq!(r1.trace_hash, r4.trace_hash, "phases must see the same seeded storm");
    assert!(
        s4.active_shards() >= 2,
        "Zipf tenants must spread over the shards (got {} active)",
        s4.active_shards()
    );
    for stats in [&s1, &s4] {
        let per_shard: u64 = stats.shards.iter().map(|sh| sh.served).sum();
        assert_eq!(per_shard, stats.served, "per-shard served must sum to the aggregate");
        for sh in &stats.shards {
            assert!(
                sh.resident_hwm <= max_resident,
                "shard {}: resident hwm {} exceeds policy {max_resident}",
                sh.shard,
                sh.resident_hwm
            );
        }
        assert_eq!(
            stats.cold_start_ms.len() as u64,
            stats.cold_starts,
            "every cold start must land one sample in the pooled window"
        );
        for t in &stats.tenants {
            assert!(
                (t.uploads as u64) <= 1 + r1.swaps + t.cold_starts,
                "{}: {} uploads exceeds 1 + {} swaps + {} cold starts",
                t.name,
                t.uploads,
                r1.swaps,
                t.cold_starts
            );
        }
    }

    // headline keys (tracked by scripts/bench_compare.sh) come from the
    // sharded phase; shards=1 rides along as the degradation baseline
    let l4 = s4.latency();
    let c4 = s4.cold_start_latency();
    let features = if c3a::substrate::simd::available() { "simd" } else { "default" };
    let c3a_threads = match env::raw(env::THREADS) {
        Some(v) => format!("\"{v}\""),
        None => "null".into(),
    };
    let json = format!(
        "{{\n  \"bench\": \"serve\",\n  \"model\": \"{EVAL}\",\n  \"smoke\": {smoke},\n  \"threads\": {threads},\n  \"c3a_threads\": {c3a_threads},\n  \"features\": \"{features}\",\n  \"requests\": {n_requests},\n  \"tenants\": {n_tenants},\n  \"max_resident\": {max_resident},\n  \"zipf_exponent\": {},\n  \"swap_every\": {},\n  \"trace_hash\": \"{:#018x}\",\n  \"req_per_s\": {:.1},\n  \"p50_ms\": {:.3},\n  \"p95_ms\": {:.3},\n  \"p99_ms\": {:.3},\n  \"cold_start_ms_p95\": {:.3},\n  \"resident_hwm\": {},\n  \"cold_starts\": {},\n  \"evictions\": {},\n  \"shards1\": {},\n  \"shards4\": {}\n}}\n",
        replay.zipf_exponent,
        replay.swap_every,
        r1.trace_hash,
        r4.req_per_s(),
        l4.p50_ms,
        l4.p95_ms,
        l4.p99_ms,
        c4.p95_ms,
        s4.resident_hwm(),
        s4.cold_starts,
        s4.evictions,
        phase_json(&r1, &s1),
        phase_json(&r4, &s4)
    );
    let out = env::bench_serve_out();
    std::fs::write(&out, &json)?;
    println!("\nwrote {out}:\n{json}");
    Ok(())
}
