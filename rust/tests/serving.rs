//! Serving-subsystem regression tests at `shards = 1` — the
//! degradation/kill-switch path that must stay bit-identical to the
//! pre-sharding single-thread scheduler: one shared frozen-backbone
//! parse under many adapters, per-tenant cache isolation across
//! hot-swaps, and the scheduler's dynamic-batching / backpressure
//! contract.  Cross-shard behavior is pinned in `serving_sharded.rs`.

use c3a::peft::init::C3aScheme;
use c3a::runtime::catalog;
use c3a::runtime::session::build_init;
use c3a::runtime::Engine;
use c3a::serving::{
    perturb_c3a_kernels as perturb, AdapterRegistry, Scheduler, SchedulerCfg, ShardCtx,
    SubmitError,
};
use c3a::substrate::prng::Rng;
use c3a::substrate::tensor::{Tensor, TensorMap};
use std::path::Path;
use std::sync::Mutex;
use std::time::Duration;

const EVAL: &str = "enc_tiny__c3a_d8__cls__eval";

/// Adapter template + (batch, seq) from the synthesized catalog.
fn template(dir: &Path) -> (TensorMap, usize, usize) {
    let manifest = catalog::synthesize(dir).unwrap();
    let spec = manifest.artifact(EVAL).unwrap().clone();
    let meta = manifest.model("enc_tiny").unwrap().clone();
    let base = catalog::init_base_params(&meta);
    let init = build_init(&spec, &base, None, &mut Rng::seed(1), C3aScheme::Xavier).unwrap();
    (init.trainable, spec.batch, spec.seq)
}

fn build_registry(
    dir: &Path,
    adapters: Vec<(String, TensorMap)>,
) -> anyhow::Result<AdapterRegistry> {
    let manifest = catalog::synthesize(dir)?;
    let spec = manifest.artifact(EVAL)?.clone();
    let meta = manifest.model("enc_tiny")?.clone();
    let engine = Engine::for_manifest(&manifest)?;
    let base = catalog::init_base_params(&meta);
    let init = build_init(&spec, &base, None, &mut Rng::seed(1), C3aScheme::Xavier)?;
    let mut registry = AdapterRegistry::new(&engine, &spec, &init)?;
    for (name, params) in adapters {
        registry.register(&name, params)?;
    }
    Ok(registry)
}

fn toks(seed: i32, s: usize) -> Vec<i32> {
    (0..s as i32).map(|j| if j == 0 { 1 } else { 4 + ((seed * 13 + j * 7) % 40) }).collect()
}

/// Full [b, s] batch tensor with one real row (rest PAD).
fn one_row_batch(tokens: &[i32], b: usize, s: usize) -> Vec<Tensor> {
    let mut t = vec![0i32; b * s];
    let n = tokens.len().min(s);
    t[..n].copy_from_slice(&tokens[..n]);
    vec![Tensor::from_i32(vec![b, s], &t)]
}

#[test]
fn registry_shares_one_frozen_parse_across_tenants() {
    let dir = std::env::temp_dir().join("c3a_serving_registry");
    let (adapter, b, s) = template(&dir);
    let adapters: Vec<(String, TensorMap)> =
        (0..3u64).map(|i| (format!("t{i}"), perturb(&adapter, i, 0.05))).collect();
    let mut registry = build_registry(&dir, adapters).unwrap();
    assert_eq!(registry.len(), 3);
    // the acceptance invariant: 3 tenant states + the backbone handle all
    // sit on ONE parse of the frozen backbone
    assert_eq!(registry.shared_parse_refs(), 4, "tenants must share one frozen parse");
    let batch = one_row_batch(&toks(1, s), b, s);
    for name in registry.tenant_names() {
        let (logits, shape, v) = registry.infer(&name, &batch).unwrap();
        assert_eq!(v, 1);
        assert_eq!(shape[0], b);
        assert!(logits.iter().all(|x| x.is_finite()), "{name}: non-finite logits");
        let _ = registry.infer(&name, &batch).unwrap();
        assert_eq!(registry.upload_count(&name), Some(1), "{name}: fixed adapter re-uploaded");
        let cs = registry.cache_stats(&name).unwrap();
        assert!(cs.spectra_hits > 0, "{name}: second request must hit the spectra cache");
    }
}

#[test]
fn hot_swap_invalidates_only_the_swapped_tenant() {
    let dir = std::env::temp_dir().join("c3a_serving_swap");
    let (adapter, b, s) = template(&dir);
    let adapters =
        vec![("t0".to_string(), adapter.clone()), ("t1".to_string(), adapter.clone())];
    let mut registry = build_registry(&dir, adapters).unwrap();
    let batch = one_row_batch(&toks(3, s), b, s);

    let (l0a, _, _) = registry.infer("t0", &batch).unwrap();
    let (l1a, _, _) = registry.infer("t1", &batch).unwrap();
    assert_eq!(l0a, l1a, "identical adapters over one backbone must agree bitwise");
    let s0 = registry.cache_stats("t0").unwrap();
    let s1 = registry.cache_stats("t1").unwrap();

    let v = registry.hot_swap("t1", perturb(&adapter, 9, 0.5)).unwrap();
    assert_eq!(v, 2);
    assert!(registry.hot_swap("nope", adapter.clone()).is_err(), "unknown tenant must fail");

    let (l0b, _, _) = registry.infer("t0", &batch).unwrap();
    let (l1b, _, v1b) = registry.infer("t1", &batch).unwrap();
    assert_eq!(v1b, 2);
    assert_eq!(l0a, l0b, "untouched tenant's logits must be bitwise identical");
    assert_ne!(l1a, l1b, "swapped tenant must serve the new adapter");

    assert_eq!(registry.upload_count("t0"), Some(1));
    assert_eq!(registry.upload_count("t1"), Some(2), "one upload per adapter version");
    let s0b = registry.cache_stats("t0").unwrap();
    let s1b = registry.cache_stats("t1").unwrap();
    assert_eq!(s0b.spectra_misses, s0.spectra_misses, "t0 spectra must stay cached");
    assert!(s0b.spectra_hits > s0.spectra_hits);
    assert!(s1b.spectra_misses > s1.spectra_misses, "t1 spectra must recompute after swap");
}

#[test]
fn scheduler_drains_partial_batches_under_slow_producer() {
    let dir = std::env::temp_dir().join("c3a_serving_partial");
    let (adapter, _b, s) = template(&dir);
    let cfg = SchedulerCfg {
        shards: 1,
        queue_cap: 16,
        max_batch: 8,
        max_wait: Duration::from_millis(5),
    };
    let sched = Scheduler::spawn(cfg, {
        let dir = dir.clone();
        move |_: &ShardCtx| build_registry(&dir, vec![("t0".to_string(), adapter.clone())])
    })
    .unwrap();
    let handle = sched.handle();
    // a slow producer: each request waits for its reply before the next is
    // submitted, so the max-wait deadline must close every batch at size 1
    for i in 0..4 {
        let t = handle.submit("t0", toks(i, s)).unwrap();
        let r = t.wait().unwrap();
        assert_eq!(r.batch_size, 1, "slow producer must not stall for a full batch");
        assert_eq!(r.tenant_version, 1);
    }
    drop(handle);
    let stats = sched.finish().unwrap();
    assert_eq!(stats.served, 4);
    assert_eq!(stats.batches, 4);
    assert_eq!(stats.failed, 0);
    assert_eq!(stats.sheds, 0);
    assert_eq!(stats.shards.len(), 1, "shards=1 must report exactly one shard");
}

#[test]
fn try_submit_backpressure_then_queued_requests_drain_as_one_batch() {
    let dir = std::env::temp_dir().join("c3a_serving_backpressure");
    let (adapter, _b, s) = template(&dir);
    // gate the registry build so the worker cannot drain while we fill the
    // bounded queue — makes the backpressure assertion deterministic
    let (gate_tx, gate_rx) = std::sync::mpsc::channel::<()>();
    let gate_rx = Mutex::new(gate_rx);
    let cfg = SchedulerCfg {
        shards: 1,
        queue_cap: 4,
        max_batch: 4,
        max_wait: Duration::from_millis(1),
    };
    let sched = Scheduler::spawn(cfg, {
        let dir = dir.clone();
        move |_: &ShardCtx| {
            let _ = gate_rx.lock().unwrap().recv();
            build_registry(&dir, vec![("t0".to_string(), adapter.clone())])
        }
    })
    .unwrap();
    let handle = sched.handle();
    let mut tickets = Vec::new();
    for i in 0..4 {
        tickets.push(handle.try_submit("t0", toks(i, s)).expect("queue has room"));
    }
    match handle.try_submit("t0", toks(9, s)) {
        Err(SubmitError::QueueFull) => {}
        other => panic!("expected QueueFull, got {other:?}"),
    }
    gate_tx.send(()).unwrap();
    for t in tickets {
        let r = t.wait().unwrap();
        assert_eq!(r.batch_size, 4, "queued requests must drain as one dynamic batch");
    }
    drop(handle);
    let stats = sched.finish().unwrap();
    assert_eq!(stats.served, 4);
    assert_eq!(stats.batches, 1);
    // the shed and the depth high-water mark are on the books
    assert_eq!(stats.sheds, 1, "the QueueFull rejection must be counted");
    assert_eq!(stats.tenant("t0").unwrap().sheds, 1, "…and attributed to its tenant");
    assert_eq!(stats.shards[0].queue_depth_hwm, 4, "hwm must reflect the full queue");
}

#[test]
fn hot_swap_mid_stream_changes_predictions_for_exactly_the_swapped_tenant() {
    let dir = std::env::temp_dir().join("c3a_serving_midstream");
    let (adapter, _b, s) = template(&dir);
    let names = ["ta", "tb", "tc"];
    let adapters: Vec<(String, TensorMap)> =
        names.iter().map(|n| (n.to_string(), adapter.clone())).collect();
    let cfg = SchedulerCfg {
        shards: 1,
        queue_cap: 16,
        max_batch: 4,
        max_wait: Duration::from_millis(1),
    };
    let sched = Scheduler::spawn(cfg, {
        let dir = dir.clone();
        move |_: &ShardCtx| build_registry(&dir, adapters.clone())
    })
    .unwrap();
    let handle = sched.handle();
    let q = toks(5, s);

    let ask = |name: &str| handle.submit(name, q.clone()).unwrap().wait().unwrap();
    let before: Vec<_> = names.iter().map(|n| ask(n)).collect();

    let v = handle.hot_swap("tb", perturb(&adapter, 11, 0.5)).unwrap();
    assert_eq!(v, 2);

    let after: Vec<_> = names.iter().map(|n| ask(n)).collect();
    assert_eq!(before[0].logits, after[0].logits, "ta must be untouched");
    assert_eq!(before[2].logits, after[2].logits, "tc must be untouched");
    assert_ne!(before[1].logits, after[1].logits, "tb must serve the swapped adapter");
    assert_eq!(before[1].tenant_version, 1);
    assert_eq!(after[1].tenant_version, 2);

    drop(handle);
    let stats = sched.finish().unwrap();
    let t = |n: &str| stats.tenant(n).unwrap();
    assert_eq!(t("ta").uploads, 1);
    assert_eq!(t("tc").uploads, 1);
    assert_eq!(t("tb").uploads, 2, "one upload per adapter version");
    assert_eq!(t("tb").version, 2);
}

#[test]
fn three_tenants_interleaved_keep_one_upload_each() {
    let dir = std::env::temp_dir().join("c3a_serving_interleave");
    let (adapter, _b, s) = template(&dir);
    let adapters: Vec<(String, TensorMap)> =
        (0..3u64).map(|i| (format!("t{i}"), perturb(&adapter, i, 0.05))).collect();
    let sched = Scheduler::spawn(SchedulerCfg::default(), {
        let dir = dir.clone();
        move |_: &ShardCtx| build_registry(&dir, adapters.clone())
    })
    .unwrap();
    let handle = sched.handle();
    let mut tickets = Vec::new();
    // interleave tenants so every request lands on a "cold" session slot —
    // the adapter upload and spectra caches must still hold per tenant
    for i in 0..30 {
        let tenant = format!("t{}", i % 3);
        tickets.push(handle.submit(&tenant, toks(i, s)).unwrap());
    }
    for t in tickets {
        assert!(t.wait().unwrap().logits.iter().all(|x| x.is_finite()));
    }
    drop(handle);
    let stats = sched.finish().unwrap();
    assert_eq!(stats.served, 30);
    assert_eq!(stats.tenants.len(), 3);
    for t in &stats.tenants {
        assert_eq!(t.requests, 10, "{}: round-robin must serve 10 each", t.name);
        assert_eq!(t.uploads, 1, "{}: interleaving must not evict the upload", t.name);
        assert!(t.spectra_hits > 0, "{}: spectra cache must hit across requests", t.name);
        assert_eq!(t.shard, 0, "shards=1 puts every tenant on shard 0");
    }
}

/// FIFO under the carry slot: queue [A, A, B, A₃, swap(A), A₄].  The B
/// message closes the first A batch via the carry slot; A₃ then sits in
/// the carry slot when the swap for the SAME tenant is next in the queue.
/// The batch must close, A₃ must still serve under the old version (it
/// was submitted before the swap), and the swap must ack afterwards, in
/// order — a drain that applied the swap before serving the carried
/// message would give A₃ the new version.
#[test]
fn hot_swap_behind_carried_same_tenant_message_stays_fifo() {
    let dir = std::env::temp_dir().join("c3a_serving_carry_swap");
    let (adapter, _b, s) = template(&dir);
    let adapters =
        vec![("ta".to_string(), adapter.clone()), ("tb".to_string(), adapter.clone())];
    // gate the registry build so the whole queue fills before the worker
    // drains anything — makes the batch/carry decomposition deterministic
    let (gate_tx, gate_rx) = std::sync::mpsc::channel::<()>();
    let gate_rx = Mutex::new(gate_rx);
    let cfg = SchedulerCfg {
        shards: 1,
        queue_cap: 8,
        max_batch: 4,
        max_wait: Duration::from_millis(5),
    };
    let sched = Scheduler::spawn(cfg, {
        let dir = dir.clone();
        move |_: &ShardCtx| {
            let _ = gate_rx.lock().unwrap().recv();
            build_registry(&dir, adapters.clone())
        }
    })
    .unwrap();
    let handle = sched.handle();
    let a1 = handle.try_submit("ta", toks(1, s)).expect("queue has room");
    let a2 = handle.try_submit("ta", toks(2, s)).expect("queue has room");
    let b1 = handle.try_submit("tb", toks(3, s)).expect("queue has room");
    let a3 = handle.try_submit("ta", toks(4, s)).expect("queue has room");
    // hot_swap blocks until the serving thread acks, so it must run on a
    // helper thread; its ack can only arrive after the gate opens
    let swapper = {
        let handle = handle.clone();
        let params = perturb(&adapter, 9, 0.5);
        std::thread::spawn(move || {
            let v = handle.hot_swap("ta", params).expect("swap acked");
            // submitted strictly after the ack -> must see the new version
            let after = handle.submit("ta", toks(5, s)).unwrap().wait().unwrap();
            (v, after)
        })
    };
    // let the swap message land in the queue behind [A, A, B, A₃]
    std::thread::sleep(Duration::from_millis(100));
    gate_tx.send(()).unwrap();

    let (ra1, ra2, rb1, ra3) =
        (a1.wait().unwrap(), a2.wait().unwrap(), b1.wait().unwrap(), a3.wait().unwrap());
    assert_eq!(ra1.tenant_version, 1, "pre-swap request must serve the old adapter");
    assert_eq!(ra2.tenant_version, 1);
    assert_eq!(ra1.batch_size, 2, "tb message must close the first ta batch via the carry");
    assert_eq!(rb1.tenant_version, 1);
    assert_eq!(
        ra3.tenant_version, 1,
        "carried same-tenant request was submitted before the swap and must stay v1"
    );
    let (v, ra4) = swapper.join().unwrap();
    assert_eq!(v, 2, "swap must ack with the new version");
    assert_eq!(ra4.tenant_version, 2, "post-ack request must serve the swapped adapter");
    assert_ne!(ra3.logits, ra4.logits, "the swap must actually change ta's serving adapter");

    drop(handle);
    let stats = sched.finish().unwrap();
    assert_eq!(stats.served, 5);
    assert_eq!(stats.failed, 0);
    let t = stats.tenant("ta").unwrap();
    assert_eq!(t.version, 2);
    assert_eq!(t.uploads, 2, "one upload per adapter version");
}

#[test]
fn unknown_tenant_gets_an_error_reply_not_a_hang() {
    let dir = std::env::temp_dir().join("c3a_serving_unknown");
    let (adapter, _b, s) = template(&dir);
    let sched = Scheduler::spawn(SchedulerCfg::default(), {
        let dir = dir.clone();
        move |_: &ShardCtx| build_registry(&dir, vec![("t0".to_string(), adapter.clone())])
    })
    .unwrap();
    let handle = sched.handle();
    let err = handle.submit("ghost", toks(1, s)).unwrap().wait();
    assert!(err.is_err(), "unknown tenant must surface an error");
    let ok = handle.submit("t0", toks(1, s)).unwrap().wait();
    assert!(ok.is_ok(), "the scheduler must keep serving after a failed request");
    drop(handle);
    let stats = sched.finish().unwrap();
    assert_eq!(stats.failed, 1);
    assert_eq!(stats.served, 1);
}

/// The shards=1 scheduler must add no numeric behavior over the bare
/// registry: the same tenants, adapters, and token rows served through
/// the queue yield bitwise-identical logits and versions to direct
/// `AdapterRegistry::infer` calls — the scheduler is pure plumbing.
#[test]
fn shards1_scheduler_matches_direct_registry_bitwise() {
    let dir = std::env::temp_dir().join("c3a_serving_plumbing");
    let (adapter, b, s) = template(&dir);
    let adapters: Vec<(String, TensorMap)> =
        (0..3u64).map(|i| (format!("t{i}"), perturb(&adapter, i, 0.05))).collect();
    let sched = Scheduler::spawn(SchedulerCfg::default(), {
        let dir = dir.clone();
        let adapters = adapters.clone();
        move |_: &ShardCtx| build_registry(&dir, adapters.clone())
    })
    .unwrap();
    let handle = sched.handle();
    // slow producer: every batch has size 1, so each reply's logits row is
    // directly comparable to a one-row direct inference
    let mut via_scheduler = Vec::new();
    for i in 0..6 {
        let tenant = format!("t{}", i % 3);
        let r = handle.submit(&tenant, toks(i, s)).unwrap().wait().unwrap();
        via_scheduler.push((tenant, i, r));
    }
    drop(handle);
    sched.finish().unwrap();

    let mut registry = build_registry(&dir, adapters).unwrap();
    for (tenant, i, reply) in via_scheduler {
        let (logits, _, version) =
            registry.infer(&tenant, &one_row_batch(&toks(i, s), b, s)).unwrap();
        let row_w = logits.len() / b;
        assert_eq!(
            reply.logits,
            &logits[..row_w],
            "{tenant} req {i}: scheduler logits must match direct inference bitwise"
        );
        assert_eq!(reply.tenant_version, version);
    }
}
