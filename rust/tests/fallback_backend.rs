//! End-to-end coverage for the substrate fallback backend: a full
//! `glue_run` round-trip (pretrain -> fine-tune -> eval) without any HLO
//! artifacts or python, plus unit pins of the shim `Literal`
//! layout/reshape semantics against `substrate::tensor`.

use c3a::coordinator::lr::Schedule;
use c3a::coordinator::run::{self, Ctx};
use c3a::coordinator::TrainCfg;
use c3a::data::glue_sim::GlueTask;
use c3a::peft::init::C3aScheme;
use c3a::runtime::session::{build_init, literal_to_tensor, tensor_to_literal, TrainSession};
use c3a::substrate::prng::Rng;
use c3a::substrate::tensor::Tensor;

fn quick_cfg(steps: usize) -> TrainCfg {
    TrainCfg {
        steps,
        lr: 5e-2,
        weight_decay: 0.0,
        schedule: Schedule::Constant,
        eval_every: 0,
        patience: 0,
        verbose: false,
    }
}

/// Fresh synthesized-artifact context in a temp dir.
fn temp_ctx(tag: &str) -> Ctx {
    let dir = std::env::temp_dir().join(format!("c3a_fallback_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    let mut ctx = Ctx::open(dir.to_str().unwrap()).unwrap();
    ctx.pretrain_steps = Some(8); // keep the cached backbone build cheap
    ctx
}

#[test]
fn glue_run_roundtrip_on_fallback() {
    let ctx = temp_ctx("glue");
    assert_eq!(ctx.engine.backend_name(), "substrate");
    let r = run::glue_run(
        &ctx,
        "enc_tiny",
        "c3a_d8",
        GlueTask::Sst2,
        0,
        &quick_cfg(3),
        C3aScheme::Xavier,
    )
    .unwrap();
    assert_eq!(r.losses.len(), 3);
    assert!(r.losses.iter().all(|l| l.is_finite()), "losses {:?}", r.losses);
    assert!(r.metric.is_finite() && (0.0..=1.0).contains(&r.metric), "metric {}", r.metric);
    assert!(r.n_params > 0);
    // the deployable snapshot contains the C3A kernels + the head
    assert!(r.trainable.keys().any(|k| k.contains(".c3a.w")));
    assert!(r.trainable.contains_key("head.w"));
    // rank summary exists for c3a runs
    let (_frac, mean_rank, dim) = r.rank.expect("rank summary");
    assert!(dim > 0 && mean_rank > 0.0);
}

#[test]
fn train_step_updates_trainable_state() {
    let ctx = temp_ctx("step");
    let spec = ctx.manifest.artifact("enc_tiny__c3a_d8__cls__train").unwrap().clone();
    let meta = ctx.manifest.model("enc_tiny").unwrap().clone();
    let backbone = ctx.manifest.init_params("enc_tiny").unwrap();
    let mut rng = Rng::seed(5);
    let init = build_init(&spec, &backbone, None, &mut rng, C3aScheme::Xavier).unwrap();
    let before = init.trainable.clone();
    let mut session = TrainSession::new(&ctx.engine, &spec, &init).unwrap();

    let splits = GlueTask::Sst2.splits(meta.vocab, meta.seq, 0);
    let idx: Vec<usize> = (0..spec.batch).collect();
    let batch = splits.train.batch(&idx, spec.batch, spec.seq);
    let (loss1, metric) = session.step(&batch, 5e-2, 0.0).unwrap();
    assert!(loss1.is_finite() && loss1 > 0.0);
    assert!(metric >= 0.0);
    let after = session.trainable_tensors().unwrap();
    // every c3a kernel and the head must have moved
    let mut moved = 0;
    for (name, t0) in &before {
        let t1 = &after[name];
        assert_eq!(t0.shape, t1.shape);
        if t0.as_f32() != t1.as_f32() {
            moved += 1;
        }
    }
    assert!(moved > 0, "no trainable tensor changed after a step");
    // a second step keeps the state finite and moving
    let (loss2, _) = session.step(&batch, 5e-2, 0.0).unwrap();
    assert!(loss2.is_finite());
    assert_eq!(session.steps_done, 2);
}

#[test]
fn fallback_is_deterministic() {
    let ctx = temp_ctx("det");
    let cfg = quick_cfg(2);
    let a = run::glue_run(&ctx, "enc_tiny", "c3a_d8", GlueTask::Sst2, 3, &cfg, C3aScheme::Xavier)
        .unwrap();
    let b = run::glue_run(&ctx, "enc_tiny", "c3a_d8", GlueTask::Sst2, 3, &cfg, C3aScheme::Xavier)
        .unwrap();
    assert_eq!(a.losses, b.losses);
    assert_eq!(a.metric, b.metric);
}

#[test]
fn literal_layout_matches_substrate_tensor() {
    // tensor -> literal -> tensor roundtrip preserves shape + row-major data
    let t = Tensor::from_f32(vec![2, 3], &[1.0, -2.0, 3.5, 0.25, 0.0, 7.0]);
    let lit = tensor_to_literal(&t).unwrap();
    let dims: Vec<i64> = lit.array_shape().unwrap().dims();
    assert_eq!(dims, vec![2, 3]);
    let back = literal_to_tensor(&lit, &[2, 3]).unwrap();
    assert_eq!(back.shape, t.shape);
    assert_eq!(back.as_f32(), t.as_f32());

    // i32 tensors keep their values through the literal path
    let ti = Tensor::from_i32(vec![4], &[0, 1, -5, 1 << 20]);
    let li = tensor_to_literal(&ti).unwrap();
    assert_eq!(li.to_vec::<i32>().unwrap(), vec![0, 1, -5, 1 << 20]);

    // scalars have an empty shape
    let ts = Tensor::from_f32(vec![], &[42.0]);
    let ls = tensor_to_literal(&ts).unwrap();
    assert!(ls.array_shape().unwrap().dims().is_empty());
    assert_eq!(ls.get_first_element::<f32>().unwrap(), 42.0);
}

#[test]
fn literal_reshape_semantics() {
    use c3a::xla::Literal;
    // row-major reshape preserves element order
    let l = Literal::vec1(&[1f32, 2.0, 3.0, 4.0, 5.0, 6.0]).reshape(&[3, 2]).unwrap();
    assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    assert_eq!(l.array_shape().unwrap().dims(), vec![3, 2]);
    // count mismatches are rejected, matching Tensor::from_f32 invariants
    assert!(Literal::vec1(&[1f32, 2.0]).reshape(&[3, 1]).is_err());
    // tuple flattening used by the run path
    let t = Literal::tuple(vec![Literal::scalar(1f32), Literal::scalar(2f32)]);
    assert_eq!(t.to_tuple().unwrap().len(), 2);
}
