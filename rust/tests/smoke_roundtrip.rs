//! Smoke: load the /tmp/smoke train-step HLO, execute, check outputs.
//! (Temporary — replaced by artifact-based integration tests.)
use c3a::runtime::Engine;

#[test]
fn roundtrip_step() -> anyhow::Result<()> {
    let path = "/tmp/smoke/step.hlo.txt";
    if !std::path::Path::new(path).exists() {
        eprintln!("skipping: {path} missing");
        return Ok(());
    }
    let eng = Engine::cpu()?;
    let exe = eng.load_hlo_text(path)?;

    // inputs: x [4, 32] f32, w [3,2,16] f32, lr scalar
    let x: Vec<f32> = (0..4 * 32).map(|i| (i as f32) * 0.01 - 0.5).collect();
    let w: Vec<f32> = (0..3 * 2 * 16).map(|i| ((i * 37 % 17) as f32) * 0.1 - 0.8).collect();
    let xl = xla::Literal::vec1(&x).reshape(&[4, 32])?;
    let wl = xla::Literal::vec1(&w).reshape(&[3, 2, 16])?;
    let lr = xla::Literal::scalar(0.05f32);

    let outs = exe.run(&[xl, wl, lr])?;
    eprintln!("n outputs = {}", outs.len());
    assert_eq!(outs.len(), 2);
    let nw = outs[0].to_vec::<f32>()?;
    assert_eq!(nw.len(), 3 * 2 * 16);
    let loss = outs[1].get_first_element::<f32>()?;
    eprintln!("loss = {loss}");
    assert!(loss.is_finite() && loss > 0.0);

    // buffer path: feed literals as buffers, keep result on device
    let c = eng.client();
    let xb = c.buffer_from_host_literal(None, &xla::Literal::vec1(&x).reshape(&[4, 32])?)?;
    let wb = c.buffer_from_host_literal(None, &xla::Literal::vec1(&w).reshape(&[3, 2, 16])?)?;
    let lrb = c.buffer_from_host_literal(None, &xla::Literal::scalar(0.05f32))?;
    let outs_b = exe.run_b(&[xb, wb, lrb])?;
    eprintln!("n buffer outputs = {}", outs_b.len());
    let lit = outs_b[0].to_literal_sync()?;
    let t = lit.to_tuple()?;
    eprintln!("tuple len via buffer = {}", t.len());
    Ok(())
}
