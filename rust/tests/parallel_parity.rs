//! Tentpole regression tests: the threaded substrate must be bit-for-bit
//! identical to the single-threaded path (deterministic chunked
//! reductions), and the stateful session caches (parsed frozen params,
//! kernel spectra, trainable uploads) must never change numerics.

use c3a::peft::init::C3aScheme;
use c3a::runtime::catalog;
use c3a::runtime::interp::InterpExecutable;
use c3a::runtime::manifest::Role;
use c3a::runtime::session::{build_init, EvalSession};
use c3a::runtime::Engine;
use c3a::substrate::circulant::BlockCirculant;
use c3a::substrate::linalg;
use c3a::substrate::parallel;
use c3a::substrate::prng::Rng;
use c3a::substrate::tensor::Tensor;
use c3a::xla;

fn lits_to_f32(outs: &[xla::Literal]) -> Vec<Vec<f32>> {
    outs.iter().map(|l| l.to_vec::<f32>().unwrap()).collect()
}

#[test]
fn block_circulant_matvec_threaded_parity() {
    let _lock = parallel::thread_override_lock();
    let mut rng = Rng::seed(3);
    // big enough to cross the circulant PAR_MIN_WORK floor
    let (m, n, b) = (8usize, 8usize, 512usize);
    let bc = BlockCirculant::new(m, n, b, (0..m * n * b).map(|_| rng.normal()).collect());
    let x: Vec<f64> = (0..n * b).map(|_| rng.normal()).collect();
    let prev = parallel::threads();
    parallel::set_threads(1);
    let y1 = bc.matvec(&x);
    let p1 = bc.prepared().matvec(&x);
    parallel::set_threads(4);
    let y4 = bc.matvec(&x);
    let p4 = bc.prepared().matvec(&x);
    parallel::set_threads(prev);
    assert_eq!(y1, y4, "BlockCirculant::matvec must be bit-for-bit across thread counts");
    assert_eq!(p1, p4, "PreparedBlockCirculant must be bit-for-bit across thread counts");
}

#[test]
fn matmul_threaded_parity_large() {
    let _lock = parallel::thread_override_lock();
    let mut rng = Rng::seed(5);
    let (m, k, n) = (128usize, 64, 96);
    let a: Vec<f64> = (0..m * k).map(|_| rng.normal()).collect();
    let b: Vec<f64> = (0..k * n).map(|_| rng.normal()).collect();
    let prev = parallel::threads();
    parallel::set_threads(1);
    let c1 = linalg::matmul(&a, &b, m, k, n);
    parallel::set_threads(4);
    let c4 = linalg::matmul(&a, &b, m, k, n);
    parallel::set_threads(prev);
    assert_eq!(c1, c4);
}

/// One full interp train step must produce identical literals at any
/// thread count — this covers the forward matmuls, the C3A FFT operator,
/// every backward pass, and the chunk-deterministic kernel-grad reduction.
#[test]
fn interp_train_step_threaded_parity() {
    let _lock = parallel::thread_override_lock();
    let dir = std::env::temp_dir().join("c3a_parity_test");
    let manifest = catalog::synthesize(&dir).unwrap();
    let spec = manifest.artifact("enc_tiny__c3a_d8__cls__train").unwrap().clone();
    let meta = manifest.model("enc_tiny").unwrap().clone();
    let lits = catalog::synth_inputs(&spec, &meta);
    let refs: Vec<&xla::Literal> = lits.iter().collect();

    let prev = parallel::threads();
    parallel::set_threads(1);
    let exe1 = InterpExecutable::new(&spec, &meta).unwrap();
    let o1 = lits_to_f32(&exe1.execute(&refs).unwrap());
    parallel::set_threads(4);
    let exe4 = InterpExecutable::new(&spec, &meta).unwrap();
    let o4 = lits_to_f32(&exe4.execute(&refs).unwrap());
    parallel::set_threads(prev);
    assert_eq!(o1, o4, "train step must be bit-for-bit across thread counts");
}

/// Stateful execution (frozen params parsed once, session spectra cache)
/// must return exactly what the stateless path returns — across several
/// steps with evolving trainables (exercising spectra invalidation).
#[test]
fn stateful_session_matches_stateless() {
    let dir = std::env::temp_dir().join("c3a_stateful_test");
    let manifest = catalog::synthesize(&dir).unwrap();
    let spec = manifest.artifact("enc_tiny__c3a_d8__cls__train").unwrap().clone();
    let meta = manifest.model("enc_tiny").unwrap().clone();
    let exe = InterpExecutable::new(&spec, &meta).unwrap();
    let mut lits = catalog::synth_inputs(&spec, &meta);

    // frozen literals in frozen_order, as TrainSession uploads them
    let frozen: Vec<xla::Literal> = spec
        .frozen_order
        .iter()
        .map(|name| {
            let idx = spec.inputs.iter().position(|i| &i.name == name).unwrap();
            lits[idx].clone()
        })
        .collect();
    let mut state = exe.prepare(&frozen).unwrap();

    let nt = spec.trainable_order.len();
    let t_indices: Vec<usize> = (0..spec.inputs.len())
        .filter(|&i| matches!(spec.inputs[i].role, Role::Trainable))
        .collect();
    let m_indices: Vec<usize> = (0..spec.inputs.len())
        .filter(|&i| matches!(spec.inputs[i].role, Role::OptM))
        .collect();
    let v_indices: Vec<usize> = (0..spec.inputs.len())
        .filter(|&i| matches!(spec.inputs[i].role, Role::OptV))
        .collect();

    for step in 0..3 {
        let refs: Vec<&xla::Literal> = lits.iter().collect();
        let stateless = exe.execute(&refs).unwrap();
        let stateful = exe.execute_stateful(&mut state, &refs).unwrap();
        assert_eq!(
            lits_to_f32(&stateless),
            lits_to_f32(&stateful),
            "stateful output diverged at step {step}"
        );
        // feed the updated trainable/opt state back in for the next step
        for (k, &idx) in t_indices.iter().enumerate() {
            lits[idx] = stateless[k].clone();
        }
        for (k, &idx) in m_indices.iter().enumerate() {
            lits[idx] = stateless[nt + k].clone();
        }
        for (k, &idx) in v_indices.iter().enumerate() {
            lits[idx] = stateless[2 * nt + k].clone();
        }
    }
    // repeated execution with an *unchanged* kernel (the serving pattern)
    // must hit the session spectra cache instead of re-running kernel FFTs
    let refs: Vec<&xla::Literal> = lits.iter().collect();
    let a = exe.execute_stateful(&mut state, &refs).unwrap();
    let before = state.cache_stats();
    let b = exe.execute_stateful(&mut state, &refs).unwrap();
    let after = state.cache_stats();
    assert_eq!(lits_to_f32(&a), lits_to_f32(&b), "repeat execution must be deterministic");
    assert!(
        after.spectra_hits > before.spectra_hits,
        "unchanged kernel must hit the spectra cache: {before:?} -> {after:?}"
    );
    assert_eq!(
        after.spectra_misses, before.spectra_misses,
        "unchanged kernel must not recompute spectra"
    );
}

/// Serve-style repeated `EvalSession::logits` calls with an unchanged
/// adapter must reuse the uploaded trainable literals (and return
/// identical logits); changing the adapter must re-upload.
#[test]
fn eval_session_reuses_trainable_upload() {
    let dir = std::env::temp_dir().join("c3a_evalcache_test");
    let manifest = catalog::synthesize(&dir).unwrap();
    let engine = Engine::for_manifest(&manifest).unwrap();
    let spec = manifest.artifact("enc_tiny__c3a_d8__cls__eval").unwrap().clone();
    let meta = manifest.model("enc_tiny").unwrap().clone();
    let mut rng = Rng::seed(7);
    let base = catalog::init_base_params(&meta);
    let init = build_init(&spec, &base, None, &mut rng, C3aScheme::Xavier).unwrap();
    let session = EvalSession::new(&engine, &spec, &init).unwrap();

    let (b, s) = (spec.batch, spec.seq);
    let toks: Vec<i32> =
        (0..b * s).map(|i| if i % 5 == 0 { 1 } else { 3 + (i as i32 % 40) }).collect();
    let batch = vec![Tensor::from_i32(vec![b, s], &toks)];

    let mut trainable = init.trainable.clone();
    assert_eq!(session.upload_count(), 0);
    let (l1, shape1) = session.logits(&trainable, &batch).unwrap();
    assert_eq!(session.upload_count(), 1);
    let (l2, shape2) = session.logits(&trainable, &batch).unwrap();
    let (l3, _) = session.logits(&trainable, &batch).unwrap();
    assert_eq!(session.upload_count(), 1, "unchanged adapter must not re-upload");
    assert_eq!(shape1, shape2);
    assert_eq!(l1, l2);
    assert_eq!(l1, l3);

    // perturb one trainable parameter -> re-upload + different logits
    let name = spec.trainable_order[0].clone();
    let t = trainable.get(&name).unwrap();
    let mut vals = t.as_f32();
    for v in vals.iter_mut() {
        *v += 0.25;
    }
    let shape = t.shape.clone();
    trainable.insert(name, Tensor::from_f32(shape, &vals));
    let (l4, _) = session.logits(&trainable, &batch).unwrap();
    assert_eq!(session.upload_count(), 2, "changed adapter must re-upload");
    assert_ne!(l1, l4, "perturbed adapter should change logits");
}
