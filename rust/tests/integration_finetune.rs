//! End-to-end integration over real artifacts: pretrain -> fine-tune ->
//! eval -> merge parity.  Skipped when `make artifacts` hasn't run.

use c3a::coordinator::lr::Schedule;
use c3a::coordinator::run::{self, Ctx};
use c3a::coordinator::TrainCfg;
use c3a::data::glue_sim::GlueTask;
use c3a::peft::init::C3aScheme;
use std::path::Path;

fn artifacts_dir() -> Option<String> {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if p.join("manifest.json").exists() {
        Some(p.to_string_lossy().into_owned())
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

fn quick_cfg(lr: f64, steps: usize) -> TrainCfg {
    TrainCfg {
        steps,
        lr,
        weight_decay: 0.0,
        schedule: Schedule::LinearWarmup { warmup_frac: 0.1 },
        eval_every: steps / 2,
        patience: 0,
        verbose: false,
    }
}

#[test]
fn tiny_c3a_finetune_learns() {
    let Some(dir) = artifacts_dir() else { return };
    let ctx = Ctx::open(&dir).unwrap();
    let r = run::glue_run(&ctx, "enc_tiny", "c3a_d8", GlueTask::Sst2, 0,
                          &quick_cfg(5e-2, 60), C3aScheme::Xavier).unwrap();
    // loss must drop and the metric must beat chance
    let first = r.losses[..5].iter().sum::<f32>() / 5.0;
    let last = r.losses[r.losses.len() - 5..].iter().sum::<f32>() / 5.0;
    assert!(last < first, "loss did not decrease: {first} -> {last}");
    assert!(r.metric > 0.55, "metric {}", r.metric);
    assert!(r.n_params > 0);
    // C3A rank measurement present and high (the paper's §4.1 claim)
    let (full_frac, mean_rank, dim) = r.rank.expect("rank summary");
    assert!(dim > 0 && mean_rank > 0.0);
    assert!(full_frac >= 0.5, "full-rank fraction {full_frac}");
}

#[test]
fn tiny_lora_finetune_learns() {
    let Some(dir) = artifacts_dir() else { return };
    let ctx = Ctx::open(&dir).unwrap();
    let r = run::glue_run(&ctx, "enc_tiny", "lora", GlueTask::Sst2, 0,
                          &quick_cfg(5e-3, 60), C3aScheme::Xavier).unwrap();
    let first = r.losses[..5].iter().sum::<f32>() / 5.0;
    let last = r.losses[r.losses.len() - 5..].iter().sum::<f32>() / 5.0;
    assert!(last < first, "loss did not decrease: {first} -> {last}");
    assert!(r.rank.is_none()); // no c3a kernels in a lora run
}

#[test]
fn pretraining_is_cached_and_reduces_loss() {
    let Some(dir) = artifacts_dir() else { return };
    let ctx = Ctx::open(&dir).unwrap();
    let t0 = std::time::Instant::now();
    let m1 = run::ensure_pretrained(&ctx, "enc_tiny").unwrap();
    let first_ms = t0.elapsed().as_millis();
    let t1 = std::time::Instant::now();
    let m2 = run::ensure_pretrained(&ctx, "enc_tiny").unwrap();
    let second_ms = t1.elapsed().as_millis();
    assert_eq!(m1.len(), m2.len());
    // cached path must be much faster than (re)training
    assert!(second_ms < first_ms.max(10), "{second_ms} !< {first_ms}");
    assert!(m1.contains_key("embed.tok"));
}

#[test]
fn deterministic_given_seed() {
    let Some(dir) = artifacts_dir() else { return };
    let ctx = Ctx::open(&dir).unwrap();
    let cfg = quick_cfg(5e-2, 10);
    let a = run::glue_run(&ctx, "enc_tiny", "c3a_d8", GlueTask::Sst2, 3, &cfg, C3aScheme::Xavier)
        .unwrap();
    let b = run::glue_run(&ctx, "enc_tiny", "c3a_d8", GlueTask::Sst2, 3, &cfg, C3aScheme::Xavier)
        .unwrap();
    assert_eq!(a.losses, b.losses);
    assert_eq!(a.metric, b.metric);
    let c = run::glue_run(&ctx, "enc_tiny", "c3a_d8", GlueTask::Sst2, 4, &cfg, C3aScheme::Xavier)
        .unwrap();
    assert_ne!(a.losses, c.losses);
}
