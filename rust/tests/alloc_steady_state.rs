//! Steady-state allocation accounting for the plan-replay hot path: after
//! warmup, a replayed `EvalSession::logits` call must perform (near-)zero
//! heap allocations — the plan's arena owns every op buffer, the FFT
//! scratch is thread-local, and the logits move out instead of copying.
//! Measured under a counting `#[global_allocator]` at one substrate
//! thread (`parallel::set_threads(1)`), as the tentpole requires.
//!
//! Single `#[test]` on purpose: the counters are process-global, so a
//! concurrent sibling test would pollute the deltas.

use c3a::peft::init::C3aScheme;
use c3a::runtime::catalog;
use c3a::runtime::session::{build_init, EvalSession, TrainSession};
use c3a::runtime::Engine;
use c3a::substrate::env;
use c3a::substrate::parallel;
use c3a::substrate::prng::Rng;
use c3a::substrate::simd;
use c3a::substrate::tensor::{Tensor, TensorMap};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static BYTES: AtomicU64 = AtomicU64::new(0);

/// Count one allocation event of `bytes` bytes.
fn count(bytes: u64) {
    // Relaxed: monotonic tallies; the test reads them on the same thread
    // that allocates (set_threads(1)), so no ordering is needed.
    ALLOCS.fetch_add(1, Ordering::Relaxed);
    // Relaxed: as above — the two counters need no mutual ordering.
    BYTES.fetch_add(bytes, Ordering::Relaxed);
}

// SAFETY: pure pass-through to `System` (which upholds the GlobalAlloc
// contract); the added counting never touches the returned memory.
unsafe impl GlobalAlloc for CountingAlloc {
    // SAFETY: caller upholds the GlobalAlloc contract; delegated as-is.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        count(layout.size() as u64);
        System.alloc(layout)
    }

    // SAFETY: caller upholds the GlobalAlloc contract; delegated as-is.
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        count(layout.size() as u64);
        System.alloc_zeroed(layout)
    }

    // SAFETY: caller upholds the GlobalAlloc contract; delegated as-is.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        count(new_size as u64);
        System.realloc(ptr, layout, new_size)
    }

    // SAFETY: caller upholds the GlobalAlloc contract; delegated as-is.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

fn snapshot() -> (u64, u64) {
    // Relaxed: monotonic tallies read for deltas on the measuring thread
    // itself (set_threads(1)); no cross-thread publication rides on them.
    (ALLOCS.load(Ordering::Relaxed), BYTES.load(Ordering::Relaxed))
}

fn delta(before: (u64, u64)) -> (u64, u64) {
    let now = snapshot();
    (now.0 - before.0, now.1 - before.1)
}

/// Per-call allocation ceiling for a replayed eval step.  The residue is
/// the unavoidable per-request skin: the batch-tensor -> literal
/// conversion, the output literal + shape vectors, and the one logits
/// buffer that is re-allocated because the previous call moved it out to
/// the caller.  The arena, FFT scratch, spectra and plan structure
/// allocate nothing.
const EVAL_ALLOCS_PER_CALL: u64 = 64;
const EVAL_BYTES_PER_CALL: u64 = 64 * 1024;

#[test]
fn replayed_calls_are_near_allocation_free() {
    let _lock = parallel::thread_override_lock();
    let prev_threads = parallel::threads();
    parallel::set_threads(1);

    let dir = std::env::temp_dir().join("c3a_alloc_steady");
    let manifest = catalog::synthesize(&dir).unwrap();
    let engine = Engine::for_manifest(&manifest).unwrap();
    let meta = manifest.model("enc_tiny").unwrap().clone();
    let base = catalog::init_base_params(&meta);

    // ---- eval: plan replay must be near-zero ----------------------------
    let spec = manifest.artifact("enc_tiny__c3a_d8__cls__eval").unwrap().clone();
    let init = build_init(&spec, &base, None, &mut Rng::seed(3), C3aScheme::Xavier).unwrap();
    let session = EvalSession::new(&engine, &spec, &init).unwrap();
    let adapter = init.trainable.clone();
    let (b, s) = (spec.batch, spec.seq);
    let toks: Vec<i32> =
        (0..b * s).map(|i| if i % 5 == 0 { 1 } else { 3 + (i as i32 % 40) }).collect();
    let batch = vec![Tensor::from_i32(vec![b, s], &toks)];

    // warmup: record + two replays (settles arena chains and scratch
    // capacities at their steady-state sizes)
    for _ in 0..3 {
        session.logits(&adapter, &batch).unwrap();
    }
    let n = 16u64;
    let before = snapshot();
    for _ in 0..n {
        session.logits(&adapter, &batch).unwrap();
    }
    let (allocs, bytes) = delta(before);
    let (per_call, bytes_per_call) = (allocs / n, bytes / n);
    println!("eval replay: {per_call} allocs/call, {bytes_per_call} bytes/call");
    assert!(
        per_call <= EVAL_ALLOCS_PER_CALL,
        "replayed eval step allocates too much: {per_call} allocs/call \
         (budget {EVAL_ALLOCS_PER_CALL})"
    );
    assert!(
        bytes_per_call <= EVAL_BYTES_PER_CALL,
        "replayed eval step allocates too much: {bytes_per_call} bytes/call \
         (budget {EVAL_BYTES_PER_CALL})"
    );

    // ---- simd: the vector kernels must add ZERO steady-state allocs ------
    // (they work lane-wise in the same preallocated buffers; the only
    // scratch they touch is the thread-local dense-circulant buffer,
    // which reaches steady capacity during warmup)
    if simd::available() {
        let _simd_lock = simd::override_lock();
        let prev = simd::enabled();
        let mut per_config = [0u64; 2];
        for (slot, on) in [(0usize, false), (1usize, true)] {
            simd::set_enabled(on);
            for _ in 0..2 {
                session.logits(&adapter, &batch).unwrap(); // settle scratch
            }
            let before = snapshot();
            for _ in 0..n {
                session.logits(&adapter, &batch).unwrap();
            }
            per_config[slot] = delta(before).0 / n;
        }
        simd::set_enabled(prev);
        let [scalar_pc, simd_pc] = per_config;
        println!("eval replay: scalar {scalar_pc} vs simd {simd_pc} allocs/call");
        assert!(
            simd_pc <= scalar_pc,
            "SIMD kernels must not allocate in steady state: \
             {simd_pc} allocs/call vs scalar {scalar_pc}"
        );
    }

    // ---- hoisting: skipped prefixes must add ZERO steady-state allocs ----
    // BOFT is the hoist-rich method (its rotation chain reads only
    // adapter + frozen leaves).  After the first post-invalidation replay
    // the skipping path must sit inside the same per-call budget, and
    // skipping must never allocate more than recomputing: both paths work
    // entirely in the plan's retained arena slots.
    {
        let _hoist_on = env::ScopedSet::set(env::HOIST, "1");
        let hspec = manifest.artifact("enc_tiny__boft__cls__eval").unwrap().clone();
        let hinit =
            build_init(&hspec, &base, None, &mut Rng::seed(5), C3aScheme::Xavier).unwrap();
        let hsession = EvalSession::new(&engine, &hspec, &hinit).unwrap();
        let mut swapped = TensorMap::new();
        for (name, t) in &hinit.trainable {
            let mut vals = t.as_f32();
            for (e, v) in vals.iter_mut().enumerate() {
                *v += 0.01 * ((e + 1) as f32).sin();
            }
            swapped.insert(name.clone(), Tensor::from_f32(t.shape.clone(), &vals));
        }
        for _ in 0..3 {
            hsession.logits(&hinit.trainable, &batch).unwrap(); // record + skips
        }
        hsession.logits(&swapped, &batch).unwrap(); // invalidation: full recompute
        hsession.logits(&swapped, &batch).unwrap(); // first skip: settle upload
        let before = snapshot();
        for _ in 0..n {
            hsession.logits(&swapped, &batch).unwrap();
        }
        let hoist_pc = delta(before).0 / n;
        // same session, same plan: disable skipping at replay time only
        let full_pc = {
            let _hoist_off = env::ScopedSet::set(env::HOIST, "0");
            for _ in 0..2 {
                hsession.logits(&swapped, &batch).unwrap();
            }
            let before = snapshot();
            for _ in 0..n {
                hsession.logits(&swapped, &batch).unwrap();
            }
            delta(before).0 / n
        };
        let stats = hsession.plan_stats().unwrap();
        assert!(stats.hoisted_ops > 0, "boft eval plan must hoist ops: {stats:?}");
        assert!(stats.hoist_invalidations >= 1, "adapter change must invalidate: {stats:?}");
        println!("eval replay (boft): hoist-on {hoist_pc} vs hoist-off {full_pc} allocs/call");
        assert!(
            hoist_pc <= EVAL_ALLOCS_PER_CALL,
            "hoisted replay allocates too much after the invalidation settles: \
             {hoist_pc} allocs/call (budget {EVAL_ALLOCS_PER_CALL})"
        );
        assert!(
            hoist_pc <= full_pc,
            "skipping the hoisted prefix must not allocate more than recomputing it: \
             {hoist_pc} vs {full_pc} allocs/call"
        );
    }

    // ---- eval: the rebuild path must be >= 5x heavier --------------------
    let legacy = {
        let _plan_off = env::ScopedSet::set(env::PLAN, "0");
        EvalSession::new(&engine, &spec, &init).unwrap()
    };
    for _ in 0..3 {
        legacy.logits(&adapter, &batch).unwrap();
    }
    let before = snapshot();
    for _ in 0..n {
        legacy.logits(&adapter, &batch).unwrap();
    }
    let (legacy_allocs, _) = delta(before);
    let legacy_per_call = legacy_allocs / n;
    println!("eval rebuild: {legacy_per_call} allocs/call");
    assert!(
        per_call * 5 <= legacy_per_call,
        "plan replay must allocate at least 5x less than the rebuild path: \
         {per_call} vs {legacy_per_call} allocs/call"
    );

    // ---- train: replayed steps must beat the recording step --------------
    let tspec = manifest.artifact("enc_tiny__c3a_d8__cls__train").unwrap().clone();
    let tinit = build_init(&tspec, &base, None, &mut Rng::seed(4), C3aScheme::Xavier).unwrap();
    let mut train = TrainSession::new(&engine, &tspec, &tinit).unwrap();
    // data batch sourced from the canonical synthetic-input recipe
    // (catalog::synth_inputs) rather than a hand-rolled copy of it
    let tlits = catalog::synth_inputs(&tspec, &meta);
    let tbatch: Vec<Tensor> = tspec
        .data_order
        .iter()
        .map(|name| {
            let idx = tspec.inputs.iter().position(|i| &i.name == name).unwrap();
            let inp = &tspec.inputs[idx];
            if inp.i32_dtype {
                Tensor::from_i32(inp.shape.clone(), &tlits[idx].to_vec::<i32>().unwrap())
            } else {
                Tensor::from_f32(inp.shape.clone(), &tlits[idx].to_vec::<f32>().unwrap())
            }
        })
        .collect();

    let before = snapshot();
    train.step(&tbatch, 0.01, 0.0).unwrap(); // records the plan
    let (record_allocs, _) = delta(before);
    for _ in 0..2 {
        train.step(&tbatch, 0.01, 0.0).unwrap(); // warmup replays
    }
    let steps = 8u64;
    let before = snapshot();
    for _ in 0..steps {
        train.step(&tbatch, 0.01, 0.0).unwrap();
    }
    let (steady_allocs, _) = delta(before);
    let steady_per_step = steady_allocs / steps;
    println!("train: record step {record_allocs} allocs, steady {steady_per_step} allocs/step");
    assert!(
        steady_per_step * 2 < record_allocs,
        "replayed train step must allocate well under half of the recording step: \
         {steady_per_step} vs {record_allocs}"
    );

    parallel::set_threads(prev_threads);
}
