//! Tiered adapter lifecycle: the disk-backed [`AdapterStore`], LRU
//! eviction under [`ResidentPolicy`], and the measured cold-start path.
//!
//! The invariants with teeth:
//! * store round-trips are bitwise over every tiny-catalog adapter;
//! * evict→reload logits are bitwise-identical to the never-evicted
//!   path (spectra and plans are deterministic functions of kernel bits);
//! * `shared_parse_refs` falls on eviction and recovers on reload;
//! * the resident set never exceeds `max_resident` (hwm ≤ policy);
//! * shard-disjoint tenants can share one store dir concurrently.

use c3a::peft::init::C3aScheme;
use c3a::runtime::catalog;
use c3a::runtime::session::build_init;
use c3a::runtime::Engine;
use c3a::serving::{
    perturb_c3a_kernels as perturb, shard_of, AdapterRegistry, AdapterStore, ResidentPolicy,
    Scheduler, SchedulerCfg, ShardCtx,
};
use c3a::substrate::prng::Rng;
use c3a::substrate::tensor::{Tensor, TensorMap};
use std::path::{Path, PathBuf};
use std::time::Duration;

const EVAL: &str = "enc_tiny__c3a_d8__cls__eval";

fn tmp(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("c3a_tiered_{tag}"));
    let _ = std::fs::remove_dir_all(dir.join("store"));
    dir
}

/// Adapter template + (batch, seq) from the synthesized catalog.
fn template(dir: &Path) -> (TensorMap, usize, usize) {
    let manifest = catalog::synthesize(dir).unwrap();
    let spec = manifest.artifact(EVAL).unwrap().clone();
    let meta = manifest.model("enc_tiny").unwrap().clone();
    let base = catalog::init_base_params(&meta);
    let init = build_init(&spec, &base, None, &mut Rng::seed(1), C3aScheme::Xavier).unwrap();
    (init.trainable, spec.batch, spec.seq)
}

/// Registry with residency installed BEFORE registration, so every
/// tenant starts evicted and the first request is a measured cold start.
fn build_tiered(
    dir: &Path,
    policy: ResidentPolicy,
    adapters: Vec<(String, TensorMap)>,
) -> anyhow::Result<AdapterRegistry> {
    let manifest = catalog::synthesize(dir)?;
    let spec = manifest.artifact(EVAL)?.clone();
    let meta = manifest.model("enc_tiny")?.clone();
    let engine = Engine::for_manifest(&manifest)?;
    let base = catalog::init_base_params(&meta);
    let init = build_init(&spec, &base, None, &mut Rng::seed(1), C3aScheme::Xavier)?;
    let mut registry = AdapterRegistry::new(&engine, &spec, &init)?;
    registry.set_residency(policy, AdapterStore::open(dir.join("store"))?)?;
    for (name, params) in adapters {
        registry.register(&name, params)?;
    }
    Ok(registry)
}

fn one_row_batch(seed: i32, b: usize, s: usize) -> Vec<Tensor> {
    let mut t = vec![0i32; b * s];
    for j in 0..s as i32 {
        t[j as usize] = if j == 0 { 1 } else { 4 + ((seed * 13 + j * 7) % 40) };
    }
    vec![Tensor::from_i32(vec![b, s], &t)]
}

/// Every adapter the tiny catalog can synthesize must survive the store
/// bitwise — not just C3A kernels: every method's trainable map.
#[test]
fn store_roundtrips_every_tiny_catalog_adapter_bitwise() {
    let dir = tmp("catalog_rt");
    let manifest = catalog::synthesize(&dir).unwrap();
    let store = AdapterStore::open(dir.join("store")).unwrap();
    let mut n = 0usize;
    for (name, spec) in &manifest.artifacts {
        if spec.model != "enc_tiny" && spec.model != "mlp" {
            continue;
        }
        let meta = manifest.model(&spec.model).unwrap().clone();
        let base = catalog::init_base_params(&meta);
        let init = build_init(spec, &base, None, &mut Rng::seed(7), C3aScheme::Xavier).unwrap();
        store.save(name, (n + 1) as u64, &init.trainable).unwrap();
        let (back, version) = store.load(name).unwrap();
        assert_eq!(version, (n + 1) as u64);
        assert_eq!(back.len(), init.trainable.len(), "{name}: tensor count");
        for (tname, t) in &init.trainable {
            assert_eq!(back[tname].shape, t.shape, "{name}/{tname}: shape");
            assert_eq!(back[tname].bits(), t.bits(), "{name}/{tname}: payload bits");
        }
        assert_eq!(back, init.trainable, "{name}: bitwise map equality");
        n += 1;
    }
    assert!(n >= 4, "tiny catalog should expose several adapters, saw {n}");
}

/// The tentpole invariant: serve → evict → reload → serve is bitwise
/// identical to the never-evicted path, and the shared parse ref count
/// falls on eviction and recovers on reload.
#[test]
fn evict_reload_is_bitwise_identical_and_releases_the_parse_ref() {
    let dir = tmp("evict_reload");
    let (adapter, b, s) = template(&dir);
    let adapters: Vec<(String, TensorMap)> =
        (0..2u64).map(|i| (format!("t{i}"), perturb(&adapter, i, 0.05))).collect();
    let mut registry = build_tiered(&dir, ResidentPolicy::unlimited(), adapters).unwrap();
    let batch = one_row_batch(3, b, s);

    // lazily registered: nothing resident, only the backbone holds the parse
    assert_eq!(registry.resident_now(), 0);
    assert_eq!(registry.shared_parse_refs(), 1);

    let (warm, _, v) = registry.infer("t0", &batch).unwrap();
    assert_eq!(v, 1);
    assert_eq!(registry.cold_starts("t0"), Some(1), "first request pays the cold start");
    assert_eq!(registry.is_resident("t0"), Some(true));
    assert_eq!(registry.shared_parse_refs(), 2);
    let (again, _, _) = registry.infer("t0", &batch).unwrap();
    assert_eq!(warm, again, "warm replay must be deterministic");
    assert_eq!(registry.upload_count("t0"), Some(1), "warm requests reuse the upload");
    assert_eq!(registry.cold_start_window().len(), 1);

    registry.evict("t0").unwrap();
    assert_eq!(registry.is_resident("t0"), Some(false));
    assert_eq!(registry.evictions("t0"), Some(1));
    assert_eq!(registry.resident_now(), 0);
    assert_eq!(registry.shared_parse_refs(), 1, "eviction must drop the session's parse ref");
    assert!(registry.evict("t0").is_err(), "evicting an evicted tenant must fail");

    let (cold, _, vc) = registry.infer("t0", &batch).unwrap();
    assert_eq!(vc, 1);
    assert_eq!(cold, warm, "evict→reload logits must be bitwise-identical");
    assert_eq!(registry.shared_parse_refs(), 2, "reload must recover the parse ref");
    assert_eq!(registry.cold_starts("t0"), Some(2));
    assert_eq!(registry.upload_count("t0"), Some(2), "a cold start re-uploads once");
    assert_eq!(registry.cold_start_window().len(), 2);
}

/// `max_resident` is a hard bound enforced before admission, and the
/// victim is always the least-recently-served resident.
#[test]
fn lru_eviction_keeps_the_resident_set_at_policy() {
    let dir = tmp("lru");
    let (adapter, b, s) = template(&dir);
    let adapters: Vec<(String, TensorMap)> =
        (0..4u64).map(|i| (format!("t{i}"), perturb(&adapter, i, 0.05))).collect();
    let mut registry = build_tiered(&dir, ResidentPolicy::max_resident(2), adapters).unwrap();
    let batch = one_row_batch(5, b, s);

    registry.infer("t0", &batch).unwrap();
    registry.infer("t1", &batch).unwrap();
    assert_eq!(registry.resident_now(), 2);
    registry.infer("t2", &batch).unwrap(); // t0 is LRU → evicted
    assert_eq!(registry.is_resident("t0"), Some(false), "LRU victim must be t0");
    assert_eq!(registry.is_resident("t1"), Some(true));
    assert_eq!(registry.is_resident("t2"), Some(true));
    registry.infer("t0", &batch).unwrap(); // t1 is now LRU → evicted
    assert_eq!(registry.is_resident("t1"), Some(false), "LRU victim must be t1");
    assert_eq!(registry.is_resident("t2"), Some(true));
    registry.infer("t3", &batch).unwrap();
    assert_eq!(registry.resident_now(), 2);
    assert_eq!(registry.resident_hwm(), 2, "resident set must never exceed max_resident");
    assert_eq!(registry.evictions_total(), 3);
    assert_eq!(registry.cold_starts_total(), 5);
    // a serving-sized window of cold starts is on the books
    assert_eq!(registry.cold_start_window().len(), 5);
    assert!(registry.cold_start_window().iter().all(|&ms| ms >= 0.0));
}

/// Hot-swapping an evicted tenant writes the new snapshot straight to
/// the store; the tenant cold-starts at the swapped version and serves
/// the swapped adapter — bit-stably across a further evict/reload.
#[test]
fn hot_swap_on_evicted_tenant_lands_in_the_store() {
    let dir = tmp("swap_evicted");
    let (adapter, b, s) = template(&dir);
    let adapters = vec![
        ("t0".to_string(), adapter.clone()),
        ("t1".to_string(), adapter.clone()),
    ];
    let mut registry = build_tiered(&dir, ResidentPolicy::max_resident(1), adapters).unwrap();
    let batch = one_row_batch(7, b, s);

    let (plain, _, _) = registry.infer("t1", &batch).unwrap();
    registry.infer("t0", &batch).unwrap(); // evicts t1 (max_resident = 1)
    assert_eq!(registry.is_resident("t1"), Some(false));

    let v = registry.hot_swap("t1", perturb(&adapter, 42, 0.5)).unwrap();
    assert_eq!(v, 2, "swap on an evicted tenant must still bump the version");
    let (swapped, _, vs) = registry.infer("t1", &batch).unwrap();
    assert_eq!(vs, 2);
    assert_ne!(plain, swapped, "reloaded tenant must serve the swapped adapter");

    // and the swapped state survives another evict/reload bitwise
    registry.infer("t0", &batch).unwrap();
    assert_eq!(registry.is_resident("t1"), Some(false));
    let (reloaded, _, vr) = registry.infer("t1", &batch).unwrap();
    assert_eq!(vr, 2);
    assert_eq!(swapped, reloaded, "swapped snapshot must round-trip bitwise");
}

/// A tiny `bytes_budget` forces eviction as soon as a session's arena +
/// upload bytes are on the books; the just-served tenant is protected.
#[test]
fn bytes_budget_evicts_down_to_the_protected_tenant() {
    let dir = tmp("bytes");
    let (adapter, b, s) = template(&dir);
    let adapters: Vec<(String, TensorMap)> =
        (0..3u64).map(|i| (format!("t{i}"), perturb(&adapter, i, 0.05))).collect();
    let policy = ResidentPolicy { max_resident: 0, bytes_budget: 1 };
    let mut registry = build_tiered(&dir, policy, adapters).unwrap();
    let batch = one_row_batch(2, b, s);
    for name in ["t0", "t1", "t2"] {
        let (logits, _, _) = registry.infer(name, &batch).unwrap();
        assert!(logits.iter().all(|x| x.is_finite()));
        assert!(
            registry.resident_now() <= 1,
            "a 1-byte budget must evict everyone but the protected tenant"
        );
        assert_eq!(registry.is_resident(name), Some(true), "{name} was just served");
    }
    assert!(registry.resident_bytes() > 0, "the survivor's bytes estimate must be non-zero");
}

/// `EvalSession::resident_bytes` must grow once a request has recorded a
/// plan arena + cached an upload — it is what makes the budget honest.
#[test]
fn resident_bytes_estimate_grows_after_first_request() {
    let dir = tmp("bytes_estimate");
    let (adapter, b, s) = template(&dir);
    let mut registry =
        build_tiered(&dir, ResidentPolicy::unlimited(), vec![("t0".into(), adapter)]).unwrap();
    assert_eq!(registry.resident_bytes(), 0, "nothing resident → nothing counted");
    registry.infer("t0", &one_row_batch(1, b, s)).unwrap();
    let after = registry.resident_bytes();
    // at minimum the uploaded adapter literals + params are counted
    assert!(after > 0, "resident bytes must be visible after a request, got {after}");
}

/// Shard workers share one store dir: tenant→shard routing is a
/// partition, so concurrent per-shard saves can never collide on a file.
#[test]
fn concurrent_shard_disjoint_stores_share_one_dir() {
    let dir = tmp("concurrent");
    let store_dir = dir.join("store");
    const SHARDS: usize = 4;
    const TENANTS: usize = 64;
    let handles: Vec<_> = (0..SHARDS)
        .map(|shard| {
            let store_dir = store_dir.clone();
            std::thread::spawn(move || {
                let store = AdapterStore::open(&store_dir).unwrap();
                for i in 0..TENANTS {
                    let name = format!("tenant{i}");
                    if shard_of(&name, SHARDS) != shard {
                        continue;
                    }
                    let mut m = TensorMap::new();
                    m.insert("w".into(), Tensor::from_f32(vec![8], &[i as f32; 8]));
                    store.save(&name, i as u64 + 1, &m).unwrap();
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let store = AdapterStore::open(&store_dir).unwrap();
    for i in 0..TENANTS {
        let name = format!("tenant{i}");
        let (m, version) = store.load(&name).unwrap();
        assert_eq!(version, i as u64 + 1, "{name}: version");
        assert_eq!(m["w"].as_f32(), vec![i as f32; 8], "{name}: payload");
    }
}

/// Full stack under a deliberately tiny policy: the scheduler serves 6
/// tenants over `max_resident = 2`, so eviction churn happens mid-storm;
/// the drained stats must carry the residency accounting and the bound.
#[test]
fn scheduler_reports_residency_and_cold_starts_under_churn() {
    let dir = tmp("sched");
    let (adapter, _b, s) = template(&dir);
    let adapters: Vec<(String, TensorMap)> =
        (0..6u64).map(|i| (format!("t{i}"), perturb(&adapter, i, 0.05))).collect();
    let cfg = SchedulerCfg {
        shards: 1,
        queue_cap: 64,
        max_batch: 2,
        max_wait: Duration::from_millis(1),
    };
    let sched = Scheduler::spawn(cfg, {
        let dir = dir.clone();
        let adapters = adapters.clone();
        move |_: &ShardCtx| build_tiered(&dir, ResidentPolicy::max_resident(2), adapters.clone())
    })
    .unwrap();
    let handle = sched.handle();
    let mut tickets = Vec::new();
    for _round in 0..3 {
        for i in 0..6 {
            let toks: Vec<i32> = (0..s as i32).map(|j| 1 + ((i as i32 + j) % 40)).collect();
            tickets.push(handle.submit(&format!("t{i}"), toks).unwrap());
        }
    }
    for t in tickets {
        assert!(t.wait().unwrap().logits.iter().all(|x| x.is_finite()));
    }
    drop(handle);
    let stats = sched.finish().unwrap();
    assert_eq!(stats.served, 18);
    assert_eq!(stats.failed, 0);
    assert!(stats.resident_hwm() <= 2, "hwm {} must respect max_resident", stats.resident_hwm());
    assert!(stats.resident_now() <= 2);
    assert!(stats.cold_starts >= 6, "every tenant pays at least one cold start");
    assert!(stats.evictions >= 4, "6 tenants over 2 slots must churn");
    assert_eq!(stats.cold_start_ms.len() as u64, stats.cold_starts);
    assert!(stats.cold_start_latency().p95_ms >= 0.0);
    let resident: usize = stats.tenants.iter().filter(|t| t.resident).count();
    assert!(resident <= 2, "at most max_resident tenants can drain resident");
    for t in &stats.tenants {
        assert_eq!(t.requests, 3);
        assert!(t.cold_starts >= 1, "{}: must have cold-started", t.name);
        assert!(
            (t.uploads as u64) <= 1 + t.cold_starts,
            "{}: uploads {} vs cold starts {}",
            t.name,
            t.uploads,
            t.cold_starts
        );
    }
}
