//! Differential-testing harness: runs catalog artifacts through the
//! substrate interpreter AND the naive reference oracle
//! (`runtime::refbackend`) and compares — forward logits, losses, every
//! parameter gradient (the substrate's recovered from its AdamW first
//! moment, so the probe is backend-agnostic and will work unchanged
//! against real PJRT), central finite differences of the oracle's f64
//! loss, multi-step train trajectories, and the serving path
//! (`AdapterRegistry` over the oracle vs the substrate, across
//! hot-swaps).
//!
//! Error budgets are constants below and documented in rust/README.md
//! § Differential testing.  Any divergence appends to the report file
//! (`C3A_DIFF_REPORT`, default `DIFF_REPORT.txt`) naming the artifact,
//! tensor, and first diverging element, then fails the test.
//!
//! The default run covers every `enc_tiny` + `mlp` artifact (all PEFT
//! methods, all heads, train + eval).  `C3A_DIFF_FULL=1` adds every
//! artifact of the remaining small models (enc_base, vit_base,
//! dec_small) — CI runs that in release under C3A_THREADS=1 and =4 via
//! scripts/diff_check.sh --full.

use c3a::peft::init::C3aScheme;
use c3a::runtime::catalog;
use c3a::runtime::interp::InterpExecutable;
use c3a::runtime::manifest::{ArtifactSpec, Manifest, Role};
use c3a::runtime::refbackend::{RefBackend, RefExecutable};
use c3a::runtime::session::build_init;
use c3a::runtime::Engine;
use c3a::serving::{perturb_c3a_kernels as perturb, AdapterRegistry, AdapterStore, ResidentPolicy};
use c3a::substrate::env;
use c3a::substrate::prng::Rng;
use c3a::substrate::tensor::Tensor;
use c3a::xla;
use std::collections::BTreeMap;

// ---------------------------------------------------------------------------
// Error budgets (see rust/README.md § Differential testing)
// ---------------------------------------------------------------------------

/// Forward logits: per-element |Δ| ≤ LOGITS_REL · max(1, ‖logits_ref‖∞).
/// The substrate runs f32 with FFT circulants; the oracle runs f64 with
/// dense convolution — the budget is the substrate's own rounding head-room.
const LOGITS_REL: f64 = 1e-3;
/// Scalar loss: relative |Δ| (both sides accumulate the loss in f64).
const LOSS_REL: f64 = 5e-4;
/// Count-type metrics (correct@1 sums): slack for argmax tie-flips where
/// the top-2 logit gap sits inside the cross-backend rounding band.
const METRIC_ABS: f64 = 2.0;
/// Per-tensor gradient: relative L2 between substrate-recovered and
/// oracle-analytic gradients.
const GRAD_L2_REL: f64 = 3e-3;
/// Finite differences vs analytic, per sampled element (scaled; central
/// differences of the f64 oracle loss with eps = 1e-3 on f32 params).
const FD_REL: f64 = 5e-2;
/// Train trajectory length for the multi-step cross-check.
const TRAJ_STEPS: usize = 5;
/// Final-parameter budget after TRAJ_STEPS, per tensor: elements within
/// TRAJ_ABS are the conforming bulk and must also satisfy a relative L2
/// of TRAJ_L2_REL.
const TRAJ_L2_REL: f64 = 5e-3;
const TRAJ_ABS: f64 = 2e-3;
/// AdamW normalizes gradients (update ≈ m̂/√v̂ ∈ ±1), so an element whose
/// true gradient sits at the two backends' noise floor (~1e-7) can take
/// *opposite-sign* near-unit updates — a legitimate ±lr·steps divergence
/// that says nothing about correctness.  Allow a small count of such
/// outliers per tensor (≤ max(2, 0.5%)), each hard-capped at the maximum
/// reachable AdamW displacement `TRAJ_HARD_CAP_LR_STEPS · lr · steps`.
const TRAJ_OUTLIER_FRAC: f64 = 0.005;
const TRAJ_HARD_CAP_LR_STEPS: f64 = 3.0;

// ---------------------------------------------------------------------------
// Divergence report
// ---------------------------------------------------------------------------

struct Report {
    context: String,
    lines: Vec<String>,
}

fn report_path() -> String {
    env::diff_report_path()
}

impl Report {
    fn new(context: &str) -> Report {
        Report { context: context.to_string(), lines: Vec::new() }
    }

    /// Record a divergence — flushed to the report file immediately, so a
    /// later panic mid-sweep cannot lose what was already found.
    fn diverge(&mut self, line: String) {
        eprintln!("DIVERGENCE: {line}");
        use std::io::Write;
        if let Ok(mut f) =
            std::fs::OpenOptions::new().create(true).append(true).open(report_path())
        {
            let _ = writeln!(f, "{}: {line}", self.context);
        }
        self.lines.push(line);
    }

    fn check(&mut self, ok: bool, msg: impl FnOnce() -> String) {
        if !ok {
            self.diverge(msg());
        }
    }

    /// Fail if anything diverged (the lines are already on disk).
    fn finish(self) {
        if self.lines.is_empty() {
            return;
        }
        panic!(
            "{}: {} divergence(s); first: {} (report: {})",
            self.context,
            self.lines.len(),
            self.lines[0],
            report_path()
        );
    }
}

// ---------------------------------------------------------------------------
// Harness plumbing
// ---------------------------------------------------------------------------

fn manifest_dir() -> std::path::PathBuf {
    std::env::temp_dir().join("c3a_differential")
}

struct Pair {
    spec: ArtifactSpec,
    sub: InterpExecutable,
    oracle: RefExecutable,
    lits: Vec<xla::Literal>,
}

fn pair(manifest: &Manifest, name: &str) -> Pair {
    let spec = manifest.artifact(name).unwrap().clone();
    let meta = manifest.model(&spec.model).unwrap().clone();
    let sub = InterpExecutable::new(&spec, &meta).unwrap();
    let oracle = RefExecutable::new(&spec, &meta).unwrap();
    let lits = catalog::synth_inputs(&spec, &meta);
    Pair { spec, sub, oracle, lits }
}

fn refs(lits: &[xla::Literal]) -> Vec<&xla::Literal> {
    lits.iter().collect()
}

/// Frozen literals in frozen_order, extracted from a synthesized input
/// set (used by the stateful/plan-replay tests).
fn frozen_of(spec: &ArtifactSpec, lits: &[xla::Literal]) -> Vec<xla::Literal> {
    spec.frozen_order
        .iter()
        .map(|fname| {
            let idx = spec.inputs.iter().position(|i| &i.name == fname).unwrap();
            lits[idx].clone()
        })
        .collect()
}

fn input_indices(spec: &ArtifactSpec, role: Role) -> Vec<usize> {
    (0..spec.inputs.len()).filter(|&i| spec.inputs[i].role == role).collect()
}

/// The substrate's AdamW first moment at step 1 with m₀ = 0 is
/// `(1 − β1)·g` in f32, so the gradient is recovered by dividing by the
/// f32-rounded `1 − β1`.  Backend-agnostic: works for any executor that
/// honors the train contract, including future PJRT.
fn recovered_grads(spec: &ArtifactSpec, outs: &[xla::Literal]) -> BTreeMap<String, Vec<f64>> {
    let nt = spec.trainable_order.len();
    let inv = 1.0 / ((1.0f32 - 0.9f32) as f64);
    let mut g = BTreeMap::new();
    for (k, name) in spec.trainable_order.iter().enumerate() {
        let m = outs[nt + k].to_vec::<f32>().unwrap();
        g.insert(name.clone(), m.iter().map(|&v| v as f64 * inv).collect());
    }
    g
}

/// First element of `sub` outside the budget vs `oracle`, if any.
fn first_divergent(sub: &[f32], oracle: &[f32], rel: f64) -> Option<(usize, f64, f64, f64)> {
    assert_eq!(sub.len(), oracle.len());
    let scale = oracle.iter().fold(1.0f64, |a, &v| a.max((v as f64).abs()));
    let tol = rel * scale;
    for (i, (&a, &b)) in sub.iter().zip(oracle.iter()).enumerate() {
        let d = (a as f64 - b as f64).abs();
        if d > tol {
            return Some((i, a as f64, b as f64, tol));
        }
    }
    None
}

fn rel_close(a: f64, b: f64, rel: f64) -> bool {
    (a - b).abs() <= rel * a.abs().max(b.abs()).max(1.0)
}

fn metric_ok(head: &str, sub: f64, oracle: f64) -> bool {
    if head == "reg" {
        // pred-sum metric: relative
        (sub - oracle).abs() <= 1e-3 * oracle.abs().max(1.0)
    } else {
        (sub - oracle).abs() <= METRIC_ABS
    }
}

/// Eval artifact: forward logits must agree across backends.
fn check_eval(manifest: &Manifest, name: &str, report: &mut Report) {
    let p = pair(manifest, name);
    let sub = p.sub.execute(&refs(&p.lits)).unwrap();
    let oracle = p.oracle.execute(&refs(&p.lits)).unwrap();
    assert_eq!(sub.len(), 1);
    assert_eq!(oracle.len(), 1);
    let (ls, lo) = (sub[0].to_vec::<f32>().unwrap(), oracle[0].to_vec::<f32>().unwrap());
    if ls.len() != lo.len() {
        report.diverge(format!("{name}: logits arity {} vs {}", ls.len(), lo.len()));
        return;
    }
    if let Some((i, a, b, tol)) = first_divergent(&ls, &lo, LOGITS_REL) {
        report.diverge(format!(
            "{name}: logits[{i}]: substrate {a:.6e} vs oracle {b:.6e} (tol {tol:.2e})"
        ));
    }
}

/// Train artifact: loss, metric, and every parameter gradient must agree.
fn check_train(manifest: &Manifest, name: &str, report: &mut Report) {
    let p = pair(manifest, name);
    let nt = p.spec.trainable_order.len();
    let sub_outs = p.sub.execute(&refs(&p.lits)).unwrap();
    assert_eq!(sub_outs.len(), 3 * nt + 2);
    let sub_loss = sub_outs[3 * nt].get_first_element::<f32>().unwrap() as f64;
    let sub_metric = sub_outs[3 * nt + 1].get_first_element::<f32>().unwrap() as f64;
    let (o_loss, o_metric, o_grads) = p.oracle.loss_and_grads(&refs(&p.lits)).unwrap();

    report.check(rel_close(sub_loss, o_loss, LOSS_REL), || {
        format!("{name}: loss: substrate {sub_loss:.8} vs oracle {o_loss:.8}")
    });
    report.check(metric_ok(&p.spec.head, sub_metric, o_metric), || {
        format!("{name}: metric: substrate {sub_metric} vs oracle {o_metric}")
    });

    let sub_grads = recovered_grads(&p.spec, &sub_outs);
    for tname in &p.spec.trainable_order {
        let gs = &sub_grads[tname];
        let go = &o_grads[tname];
        let mut d2 = 0.0;
        let mut n2 = 0.0;
        for (a, b) in gs.iter().zip(go.iter()) {
            d2 += (a - b) * (a - b);
            n2 += b * b;
        }
        let (dn, on) = (d2.sqrt(), n2.sqrt());
        if on < 1e-12 && dn < 1e-9 {
            continue; // both zero (e.g. a genuinely unused parameter)
        }
        let rel = dn / on.max(1e-9);
        report.check(rel <= GRAD_L2_REL, || {
            format!("{name}: grad {tname}: rel L2 {rel:.3e} > {GRAD_L2_REL:.0e} (‖g‖={on:.3e})")
        });
    }
}

// ---------------------------------------------------------------------------
// Tests
// ---------------------------------------------------------------------------

/// Every enc_tiny + mlp artifact (all PEFT methods × heads × kinds):
/// forward logits for eval artifacts; loss + metric + all gradients for
/// train artifacts.
#[test]
fn tiny_catalog_cross_check() {
    let manifest = catalog::synthesize(&manifest_dir()).unwrap();
    let mut report = Report::new("tiny_catalog_cross_check");
    let mut n = 0;
    for (name, spec) in &manifest.artifacts {
        if spec.model != "enc_tiny" && spec.model != "mlp" {
            continue;
        }
        if spec.kind == "eval" {
            check_eval(&manifest, name, &mut report);
        } else {
            check_train(&manifest, name, &mut report);
        }
        n += 1;
    }
    assert!(n >= 39, "expected the full enc_tiny+mlp slice, got {n}");
    eprintln!("tiny catalog: {n} artifacts cross-checked");
    report.finish();
}

/// Central finite differences of the oracle's f64 loss validate BOTH
/// backends' analytic gradients on sampled elements of every trainable.
#[test]
fn finite_differences_validate_both_backends() {
    let manifest = catalog::synthesize(&manifest_dir()).unwrap();
    let mut report = Report::new("finite_differences");
    for name in
        ["enc_tiny__c3a_d8__cls__train", "enc_tiny__lora__cls__train", "mlp__mlp_c3a__cls__train"]
    {
        let p = pair(&manifest, name);
        let (_l, _m, o_grads) = p.oracle.loss_and_grads(&refs(&p.lits)).unwrap();
        let sub_outs = p.sub.execute(&refs(&p.lits)).unwrap();
        let sub_grads = recovered_grads(&p.spec, &sub_outs);
        let t_idx = input_indices(&p.spec, Role::Trainable);
        for (k, tname) in p.spec.trainable_order.iter().enumerate() {
            let li = t_idx[k];
            let base = p.lits[li].to_vec::<f32>().unwrap();
            let shape = p.spec.inputs[li].shape.clone();
            let go = &o_grads[tname];
            let gmax = go.iter().fold(0.0f64, |a, &v| a.max(v.abs()));
            let n = base.len();
            let mut samples = vec![0usize, n / 3, (2 * n) / 3, n - 1];
            samples.dedup();
            for &e in &samples {
                let eps = 1e-3f32;
                let mut vp = base.clone();
                vp[e] += eps;
                let mut vm = base.clone();
                vm[e] -= eps;
                // the f32 perturbation rounds; use the realized step width
                let span = (vp[e] as f64) - (vm[e] as f64);
                let mut lp_lits = p.lits.clone();
                lp_lits[li] = xla::Literal::from_f32(&shape, vp);
                let mut lm_lits = p.lits.clone();
                lm_lits[li] = xla::Literal::from_f32(&shape, vm);
                let lp = p.oracle.loss_f64(&refs(&lp_lits)).unwrap();
                let lm = p.oracle.loss_f64(&refs(&lm_lits)).unwrap();
                let fd = (lp - lm) / span;
                let scale = fd.abs().max(1e-3 * gmax).max(1e-6);
                let an_o = go[e];
                report.check((fd - an_o).abs() / scale.max(an_o.abs()) <= FD_REL, || {
                    format!("{name}: {tname}[{e}]: fd {fd:.4e} vs oracle grad {an_o:.4e}")
                });
                let an_s = sub_grads[tname][e];
                report.check((fd - an_s).abs() / scale.max(an_s.abs()) <= FD_REL, || {
                    format!("{name}: {tname}[{e}]: fd {fd:.4e} vs substrate grad {an_s:.4e}")
                });
            }
        }
    }
    report.finish();
}

/// Both backends run TRAJ_STEPS optimizer steps independently (each fed
/// its own outputs); per-step losses and final parameters must agree.
#[test]
fn train_trajectory_cross_check() {
    let manifest = catalog::synthesize(&manifest_dir()).unwrap();
    let mut report = Report::new("train_trajectory");
    for name in ["enc_tiny__c3a_d8__cls__train", "mlp__mlp_c3a__cls__train"] {
        let p = pair(&manifest, name);
        let nt = p.spec.trainable_order.len();
        let t_idx = input_indices(&p.spec, Role::Trainable);
        let m_idx = input_indices(&p.spec, Role::OptM);
        let v_idx = input_indices(&p.spec, Role::OptV);
        let step_idx = p.spec.inputs.iter().position(|i| i.name == "step").unwrap();
        let mut sub_lits = p.lits.clone();
        let mut orc_lits = p.lits.clone();
        let mut sub_outs = Vec::new();
        let mut orc_outs = Vec::new();
        for step in 0..TRAJ_STEPS {
            sub_lits[step_idx] = xla::Literal::scalar((step + 1) as f32);
            orc_lits[step_idx] = xla::Literal::scalar((step + 1) as f32);
            sub_outs = p.sub.execute(&refs(&sub_lits)).unwrap();
            orc_outs = p.oracle.execute(&refs(&orc_lits)).unwrap();
            let ls = sub_outs[3 * nt].get_first_element::<f32>().unwrap() as f64;
            let lo = orc_outs[3 * nt].get_first_element::<f32>().unwrap() as f64;
            // drift compounds: widen the per-step loss budget linearly
            let budget = LOSS_REL * (1.0 + 2.0 * step as f64);
            report.check(rel_close(ls, lo, budget), || {
                format!("{name}: step {step}: loss substrate {ls:.8} vs oracle {lo:.8}")
            });
            for (k, &i) in t_idx.iter().enumerate() {
                sub_lits[i] = sub_outs[k].clone();
                orc_lits[i] = orc_outs[k].clone();
            }
            for (k, &i) in m_idx.iter().enumerate() {
                sub_lits[i] = sub_outs[nt + k].clone();
                orc_lits[i] = orc_outs[nt + k].clone();
            }
            for (k, &i) in v_idx.iter().enumerate() {
                sub_lits[i] = sub_outs[2 * nt + k].clone();
                orc_lits[i] = orc_outs[2 * nt + k].clone();
            }
        }
        let lr_idx = p.spec.inputs.iter().position(|i| i.name == "lr").unwrap();
        let lr = p.lits[lr_idx].get_first_element::<f32>().unwrap() as f64;
        let hard_cap = TRAJ_HARD_CAP_LR_STEPS * lr * TRAJ_STEPS as f64;
        for (k, tname) in p.spec.trainable_order.iter().enumerate() {
            let ps: Vec<f32> = sub_outs[k].to_vec().unwrap();
            let po: Vec<f32> = orc_outs[k].to_vec().unwrap();
            let mut d2 = 0.0f64;
            let mut n2 = 0.0f64;
            let mut outliers = 0usize;
            let mut over_cap = 0usize;
            for (&a, &b) in ps.iter().zip(po.iter()) {
                let d = (a as f64 - b as f64).abs();
                n2 += (b as f64) * (b as f64);
                if d > hard_cap {
                    over_cap += 1;
                } else if d > TRAJ_ABS {
                    outliers += 1; // AdamW noise-floor sign flip; bounded
                } else {
                    d2 += d * d;
                }
            }
            let allowed = 2usize.max((ps.len() as f64 * TRAJ_OUTLIER_FRAC) as usize);
            let rel = d2.sqrt() / n2.sqrt().max(1e-9);
            report.check(over_cap == 0 && outliers <= allowed && rel <= TRAJ_L2_REL, || {
                format!(
                    "{name}: after {TRAJ_STEPS} steps {tname}: bulk rel L2 {rel:.3e} \
                     (budget {TRAJ_L2_REL:.0e}), {outliers} outliers (allowed {allowed}), \
                     {over_cap} beyond the AdamW hard cap {hard_cap:.2e}"
                )
            });
        }
    }
    report.finish();
}

/// Serving path: an `AdapterRegistry` built over the reference backend
/// must reproduce substrate logits (to the forward budget) across
/// hot-swaps, with identical version bookkeeping.
#[test]
fn serving_registry_oracle_matches_substrate_across_hot_swaps() {
    let manifest = catalog::synthesize(&manifest_dir()).unwrap();
    let spec = manifest.artifact("enc_tiny__c3a_d8__cls__eval").unwrap().clone();
    let meta = manifest.model("enc_tiny").unwrap().clone();
    let base = catalog::init_base_params(&meta);
    let init = build_init(&spec, &base, None, &mut Rng::seed(1), C3aScheme::Xavier).unwrap();
    let engine_sub = Engine::for_manifest(&manifest).unwrap();
    let engine_orc =
        Engine::for_manifest_with_backend(&manifest, Box::new(RefBackend)).unwrap();
    assert_eq!(engine_orc.backend_name(), "reference");

    let mut reg_sub = AdapterRegistry::new(&engine_sub, &spec, &init).unwrap();
    let mut reg_orc = AdapterRegistry::new(&engine_orc, &spec, &init).unwrap();
    for i in 0..2u64 {
        let params = perturb(&init.trainable, i, 0.05);
        reg_sub.register(&format!("t{i}"), params.clone()).unwrap();
        reg_orc.register(&format!("t{i}"), params).unwrap();
    }
    let (b, s) = (spec.batch, spec.seq);
    let toks: Vec<i32> =
        (0..b * s).map(|i| if i % 5 == 0 { 1 } else { 3 + (i as i32 % 40) }).collect();
    let batch = vec![Tensor::from_i32(vec![b, s], &toks)];

    let mut report = Report::new("serving_registry_oracle");
    let compare = |report: &mut Report,
                   tag: &str,
                   reg_sub: &mut AdapterRegistry,
                   reg_orc: &mut AdapterRegistry| {
        for t in ["t0", "t1"] {
            let (ls, _, vs) = reg_sub.infer(t, &batch).unwrap();
            let (lo, _, vo) = reg_orc.infer(t, &batch).unwrap();
            report.check(vs == vo, || format!("{tag}/{t}: version {vs} vs {vo}"));
            if let Some((i, a, b, tol)) = first_divergent(&ls, &lo, LOGITS_REL) {
                report.diverge(format!(
                    "{tag}/{t}: logits[{i}]: substrate {a:.6e} vs oracle {b:.6e} (tol {tol:.2e})"
                ));
            }
        }
    };
    compare(&mut report, "pre-swap", &mut reg_sub, &mut reg_orc);

    let swapped = perturb(&init.trainable, 99, 0.5);
    let vs = reg_sub.hot_swap("t1", swapped.clone()).unwrap();
    let vo = reg_orc.hot_swap("t1", swapped).unwrap();
    assert_eq!(vs, 2);
    assert_eq!(vo, 2);
    compare(&mut report, "post-swap", &mut reg_sub, &mut reg_orc);
    // substrate-side cache bookkeeping still holds next to the oracle
    assert_eq!(reg_sub.upload_count("t1"), Some(2));
    assert_eq!(reg_sub.upload_count("t0"), Some(1));

    // tiered leg: serve → evict → reload → serve on the substrate side
    // must be bitwise-identical to the warm path AND still match the f64
    // oracle's never-evicted registry within the forward budget
    let store_dir = std::env::temp_dir().join("c3a_diff_tier_store");
    let _ = std::fs::remove_dir_all(&store_dir);
    reg_sub
        .set_residency(ResidentPolicy::unlimited(), AdapterStore::open(&store_dir).unwrap())
        .unwrap();
    let (warm, _, _) = reg_sub.infer("t0", &batch).unwrap();
    let refs = reg_sub.shared_parse_refs();
    reg_sub.evict("t0").unwrap();
    assert_eq!(reg_sub.is_resident("t0"), Some(false));
    assert_eq!(reg_sub.shared_parse_refs(), refs - 1, "eviction must release the parse ref");
    let (cold, _, vc) = reg_sub.infer("t0", &batch).unwrap();
    assert_eq!(vc, 1);
    assert_eq!(warm, cold, "evict→reload must serve bitwise-identical logits");
    assert_eq!(reg_sub.shared_parse_refs(), refs, "reload must recover the parse ref");
    assert_eq!(reg_sub.cold_starts("t0"), Some(1));
    compare(&mut report, "post-evict-reload", &mut reg_sub, &mut reg_orc);
    report.finish();
}

/// Perturb every trainable tensor (not just c3a kernels): the hoisting
/// leg runs on BOFT, whose adapter is a skew bank.
fn nudge_all(adapter: &BTreeMap<String, Tensor>, seed: u64, eps: f32) -> BTreeMap<String, Tensor> {
    let mut rng = Rng::seed(0xB0F7_5EED ^ seed);
    let mut out = BTreeMap::new();
    for (name, t) in adapter {
        let mut vals = t.as_f32();
        for v in vals.iter_mut() {
            *v += eps * rng.normal() as f32;
        }
        out.insert(name.clone(), Tensor::from_f32(t.shape.clone(), &vals));
    }
    out
}

/// Hoisting leg: a hoisted eval → hot-swap → eval sequence on a BOFT
/// tenant (the hoist-rich method: its rotation prefix reads only adapter
/// + frozen leaves) must stay inside the forward budget against the f64
/// oracle — which rebuilds from scratch every call and hoists nothing —
/// and the substrate must replay bitwise-deterministically while its
/// skip/invalidation counters confirm the prefix was actually skipped,
/// then recomputed after each swap.
#[test]
fn hoisted_replay_matches_oracle_across_hot_swaps() {
    let manifest = catalog::synthesize(&manifest_dir()).unwrap();
    let spec = manifest.artifact("enc_tiny__boft__cls__eval").unwrap().clone();
    let meta = manifest.model("enc_tiny").unwrap().clone();
    let base = catalog::init_base_params(&meta);
    let init = build_init(&spec, &base, None, &mut Rng::seed(21), C3aScheme::Xavier).unwrap();
    let engine_sub = Engine::for_manifest(&manifest).unwrap();
    let engine_orc =
        Engine::for_manifest_with_backend(&manifest, Box::new(RefBackend)).unwrap();
    let mut reg_sub = AdapterRegistry::new(&engine_sub, &spec, &init).unwrap();
    let mut reg_orc = AdapterRegistry::new(&engine_orc, &spec, &init).unwrap();
    reg_sub.register("t", init.trainable.clone()).unwrap();
    reg_orc.register("t", init.trainable.clone()).unwrap();
    let (b, s) = (spec.batch, spec.seq);
    let toks: Vec<i32> =
        (0..b * s).map(|i| if i % 7 == 0 { 1 } else { 2 + (i as i32 % 38) }).collect();
    let batch = vec![Tensor::from_i32(vec![b, s], &toks)];

    let mut report = Report::new("hoisted_replay_oracle");
    let check = |report: &mut Report,
                 tag: &str,
                 reg_sub: &mut AdapterRegistry,
                 reg_orc: &mut AdapterRegistry| {
        let (l0, _, _) = reg_sub.infer("t", &batch).unwrap();
        let (l1, _, _) = reg_sub.infer("t", &batch).unwrap();
        assert_eq!(l0, l1, "{tag}: hoisted replay must be bitwise deterministic");
        let (lo, _, _) = reg_orc.infer("t", &batch).unwrap();
        if let Some((i, a, b, tol)) = first_divergent(&l0, &lo, LOGITS_REL) {
            report.diverge(format!(
                "{tag}: logits[{i}]: substrate {a:.6e} vs oracle {b:.6e} (tol {tol:.2e})"
            ));
        }
    };
    check(&mut report, "pre-swap", &mut reg_sub, &mut reg_orc);

    let swapped = nudge_all(&init.trainable, 1, 0.05);
    assert_eq!(reg_sub.hot_swap("t", swapped.clone()).unwrap(), 2);
    assert_eq!(reg_orc.hot_swap("t", swapped).unwrap(), 2);
    check(&mut report, "post-swap", &mut reg_sub, &mut reg_orc);

    // swap back: the original version's bits must recompute, not be
    // served from a stale retained prefix
    assert_eq!(reg_sub.hot_swap("t", init.trainable.clone()).unwrap(), 3);
    assert_eq!(reg_orc.hot_swap("t", init.trainable.clone()).unwrap(), 3);
    check(&mut report, "swap-back", &mut reg_sub, &mut reg_orc);

    // counter pins only apply when the ambient env has hoisting on (the
    // CI hoist-off cross runs this leg purely as an equivalence check)
    if env::hoist_enabled() {
        let (hoisted, skips, invals) = reg_sub.hoist_stats("t");
        assert!(hoisted > 0, "boft eval plan must hoist its rotation prefix");
        // per phase: first infer records or invalidates, second skips
        assert_eq!(skips, 3 * hoisted as u64, "three skipping replays expected");
        assert_eq!(invals, 2, "each hot-swap must invalidate the prefix once");
    }
    report.finish();
}

/// Widened sweep over every artifact of the small models — run with
/// `C3A_DIFF_FULL=1` (CI does, in release, at C3A_THREADS=1 and 4).
#[test]
fn full_catalog_sweep_when_enabled() {
    if !env::diff_full() {
        eprintln!("skipping full catalog sweep (C3A_DIFF_FULL=1 / scripts/diff_check.sh --full)");
        return;
    }
    let manifest = catalog::synthesize(&manifest_dir()).unwrap();
    let mut report = Report::new("full_catalog_sweep");
    // enc_tiny/mlp are already covered unconditionally by
    // tiny_catalog_cross_check in this same binary; enc_large / dec_large /
    // vit_large are structural clones of their smaller siblings and the
    // naive O(b²)/O(n³) oracle makes them wall-clock prohibitive.  Both
    // exclusions are EXPLICIT here, not silent.
    const MODELS: [&str; 3] = ["enc_base", "vit_base", "dec_small"];
    let (mut n, mut excluded) = (0usize, 0usize);
    for (name, spec) in &manifest.artifacts {
        if !MODELS.contains(&spec.model.as_str()) {
            excluded += 1;
            continue;
        }
        if spec.kind == "eval" {
            check_eval(&manifest, name, &mut report);
        } else {
            check_train(&manifest, name, &mut report);
        }
        n += 1;
        eprintln!("  [{n}] {name} ok-so-far ({} divergences)", report.lines.len());
    }
    eprintln!(
        "full sweep: {n} artifacts cross-checked; {excluded} excluded (enc_tiny/mlp covered by \
         the tiny slice; enc_large/dec_large/vit_large are structural clones of checked presets)"
    );
    report.finish();
}

/// The plan-replay path (stateful sessions record on call 1 and replay
/// every later call into the preallocated arena) must stay inside the
/// same oracle budgets as the rebuild path — and be bit-identical to it —
/// on every enc_tiny + mlp eval artifact.  The replayed *train* path is
/// covered by `train_replay_trajectory_is_bit_identical_to_rebuild`
/// below (note `train_trajectory_cross_check` itself drives the
/// stateless `execute()`, which never records a plan).
#[test]
fn plan_replay_matches_oracle_on_tiny_eval_sweep() {
    let manifest = catalog::synthesize(&manifest_dir()).unwrap();
    let mut report = Report::new("plan_replay_eval_sweep");
    let mut n = 0;
    for (name, spec) in &manifest.artifacts {
        if spec.kind != "eval" || (spec.model != "enc_tiny" && spec.model != "mlp") {
            continue;
        }
        let p = pair(&manifest, name);
        let frozen = frozen_of(&p.spec, &p.lits);
        let rebuilt = p.sub.execute(&refs(&p.lits)).unwrap()[0].to_vec::<f32>().unwrap();
        let oracle = p.oracle.execute(&refs(&p.lits)).unwrap()[0].to_vec::<f32>().unwrap();
        let mut state = p.sub.prepare(&frozen).unwrap();
        for call in 0..3 {
            let outs = p.sub.execute_stateful(&mut state, &refs(&p.lits)).unwrap();
            let replayed = outs[0].to_vec::<f32>().unwrap();
            if replayed != rebuilt {
                report.diverge(format!("{name}: call {call} not bit-identical to rebuild"));
                continue;
            }
            if let Some((i, a, b, tol)) = first_divergent(&replayed, &oracle, LOGITS_REL) {
                report.diverge(format!(
                    "{name}: replay {call} logits[{i}]: {a:.6e} vs oracle {b:.6e} (tol {tol:.2e})"
                ));
            }
        }
        let stats = state.plan_stats().expect("plan recorded");
        if stats.replays != 2 {
            report.diverge(format!("{name}: expected 2 replays, saw {}", stats.replays));
        }
        n += 1;
    }
    assert!(n >= 13, "expected the eval slice of enc_tiny+mlp, got {n}");
    eprintln!("plan replay: {n} eval artifacts cross-checked against the oracle");
    report.finish();
}

/// Replayed train steps with *evolving* trainable/optimizer state: a
/// 4-step stateful trajectory (step 1 records the plan, steps 2-4
/// replay it) must be bit-identical, at every step, to the same
/// trajectory driven through the stateless rebuild path.  Together with
/// `train_trajectory_cross_check` (stateless vs the f64 oracle), this
/// transitively pins the replayed train path against the oracle —
/// including bias-corrected AdamW under an advancing `step` scalar and
/// spectra re-FFTs as the kernels move.
#[test]
fn train_replay_trajectory_is_bit_identical_to_rebuild() {
    let manifest = catalog::synthesize(&manifest_dir()).unwrap();
    for name in [
        "enc_tiny__c3a_d8__cls__train",
        "enc_tiny__full__mlm__train",
        "mlp__mlp_c3a__cls__train",
    ] {
        let p = pair(&manifest, name);
        let frozen = frozen_of(&p.spec, &p.lits);
        let mut state = p.sub.prepare(&frozen).unwrap();
        let t_idx = input_indices(&p.spec, Role::Trainable);
        let m_idx = input_indices(&p.spec, Role::OptM);
        let v_idx = input_indices(&p.spec, Role::OptV);
        let step_idx = p
            .spec
            .inputs
            .iter()
            .position(|i| i.role == Role::Scalar && i.name == "step")
            .unwrap();
        let nt = t_idx.len();
        let mut lits = p.lits.clone();
        for step in 0..4usize {
            lits[step_idx] = xla::Literal::scalar((step + 1) as f32);
            let r = refs(&lits);
            let rebuilt = p.sub.execute(&r).unwrap();
            let replayed = p.sub.execute_stateful(&mut state, &r).unwrap();
            for (k, (a, b)) in rebuilt.iter().zip(replayed.iter()).enumerate() {
                assert_eq!(
                    a.to_vec::<f32>().unwrap(),
                    b.to_vec::<f32>().unwrap(),
                    "{name}: step {step} output {k} diverged between rebuild and replay"
                );
            }
            // feed the evolved state back for the next step
            for (k, &idx) in t_idx.iter().enumerate() {
                lits[idx] = rebuilt[k].clone();
            }
            for (k, &idx) in m_idx.iter().enumerate() {
                lits[idx] = rebuilt[nt + k].clone();
            }
            for (k, &idx) in v_idx.iter().enumerate() {
                lits[idx] = rebuilt[2 * nt + k].clone();
            }
        }
        assert_eq!(state.plan_stats().unwrap().replays, 3, "{name}: replay count");
    }
}
