//! Tentpole regression tests for the execution-plan subsystem: a replayed
//! step must be bit-for-bit identical to the legacy rebuild path — for
//! every enc_tiny/mlp artifact, across repeated calls, across adapter
//! swaps mid-stream, and at any thread count (the C3A_THREADS=1/4 CI
//! matrix runs this whole file, additionally crossed with C3A_HOIST=0/1).
//! The hoisting tests pin `C3A_HOIST` skip/invalidation semantics against
//! the full-replay path for every eval artifact of the same slice.

use c3a::peft::init::C3aScheme;
use c3a::runtime::catalog;
use c3a::runtime::interp::InterpExecutable;
use c3a::runtime::manifest::{Manifest, Role};
use c3a::runtime::session::{build_init, EvalSession, TrainSession};
use c3a::runtime::Engine;
use c3a::substrate::env;
use c3a::substrate::prng::Rng;
use c3a::substrate::tensor::{Tensor, TensorMap};
use c3a::xla;

/// Serializes the tests in this binary: the kill-switch test toggles the
/// process-wide `C3A_PLAN` env var, which must not race a concurrent
/// `prepare` in a sibling test.
fn env_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::OnceLock<std::sync::Mutex<()>> = std::sync::OnceLock::new();
    LOCK.get_or_init(|| std::sync::Mutex::new(())).lock().unwrap_or_else(|e| e.into_inner())
}

fn manifest() -> Manifest {
    let dir = std::env::temp_dir().join("c3a_plan_parity");
    catalog::synthesize(&dir).unwrap()
}

fn lits_to_f32(outs: &[xla::Literal]) -> Vec<Vec<f32>> {
    outs.iter().map(|l| l.to_vec::<f32>().unwrap()).collect()
}

/// Frozen literals in frozen_order, extracted from a synthesized input set.
fn frozen_lits(
    spec: &c3a::runtime::manifest::ArtifactSpec,
    lits: &[xla::Literal],
) -> Vec<xla::Literal> {
    spec.frozen_order
        .iter()
        .map(|name| {
            let idx = spec.inputs.iter().position(|i| &i.name == name).unwrap();
            lits[idx].clone()
        })
        .collect()
}

/// Every enc_tiny + mlp + dec_small + vit_base artifact (train and eval,
/// every PEFT method and head): the recording call and three replays must
/// all be bit-identical to the stateless rebuild.  This is the plan
/// subsystem's acceptance pin.  dec_small covers the causal-mask
/// recomputation + decoder train replay (shifted-token targets) and
/// vit_base the vec-mode `data.x` leaf + constant mask — paths the
/// enc_tiny slice alone would leave untested (the differential oracle
/// sweep excludes these models only because the *naive oracle* is slow;
/// this test is substrate-vs-substrate and stays cheap).
#[test]
fn plan_replay_is_bit_identical_to_rebuild_across_tiny_catalog() {
    let _env = env_lock();
    let manifest = manifest();
    const MODELS: [&str; 4] = ["enc_tiny", "mlp", "dec_small", "vit_base"];
    let mut covered = 0usize;
    for (name, spec) in &manifest.artifacts {
        if !MODELS.contains(&spec.model.as_str()) {
            continue;
        }
        let meta = manifest.model(&spec.model).unwrap().clone();
        let exe = InterpExecutable::new(spec, &meta).unwrap();
        let lits = catalog::synth_inputs(spec, &meta);
        let refs: Vec<&xla::Literal> = lits.iter().collect();
        let want = lits_to_f32(&exe.execute(&refs).unwrap());

        let mut state = exe.prepare(&frozen_lits(spec, &lits)).unwrap();
        // call 1 records the plan, calls 2..4 replay it
        for call in 0..4 {
            let got = lits_to_f32(&exe.execute_stateful(&mut state, &refs).unwrap());
            assert_eq!(got, want, "{name}: call {call} diverged from the rebuild path");
        }
        let stats = state.plan_stats().expect("plan must be recorded after the first call");
        assert!(stats.ops > 0, "{name}: empty plan");
        assert_eq!(stats.replays, 3, "{name}: replay count");
        covered += 1;
    }
    // 39 enc_tiny+mlp + 9 dec_small + 8 vit_base
    assert!(covered >= 56, "expected the widened artifact slice, got {covered}");
}

/// Replays must track *changing* inputs: new tokens re-id the embedding
/// gathers and recompute the attention masks, new kernels re-FFT the
/// spectra.  Each variation is checked against a fresh stateless run.
#[test]
fn plan_replay_tracks_new_tokens_and_kernels() {
    let _env = env_lock();
    let manifest = manifest();
    let spec = manifest.artifact("enc_tiny__c3a_d8__cls__train").unwrap().clone();
    let meta = manifest.model("enc_tiny").unwrap().clone();
    let exe = InterpExecutable::new(&spec, &meta).unwrap();
    let mut lits = catalog::synth_inputs(&spec, &meta);
    let mut state = exe.prepare(&frozen_lits(&spec, &lits)).unwrap();
    {
        let refs: Vec<&xla::Literal> = lits.iter().collect();
        exe.execute_stateful(&mut state, &refs).unwrap(); // record
    }

    let (b, s) = (spec.batch, spec.seq);
    let tok_idx = spec.inputs.iter().position(|i| i.name == "data.tokens").unwrap();
    let kern_idx = spec
        .inputs
        .iter()
        .position(|i| i.role == Role::Trainable && i.name.contains(".c3a.w"))
        .unwrap();

    for variant in 0..3 {
        // new tokens (with fresh pad positions) + a perturbed kernel
        let toks: Vec<i32> = (0..b * s)
            .map(|i| if (i + variant) % 5 == 0 { 0 } else { 2 + ((i * 7 + variant) as i32 % 40) })
            .collect();
        lits[tok_idx] = xla::Literal::from_i32(&[b, s], toks);
        let kshape = spec.inputs[kern_idx].shape.clone();
        let mut kern = lits[kern_idx].to_vec::<f32>().unwrap();
        for (e, v) in kern.iter_mut().enumerate() {
            *v += 0.01 * ((e + variant) as f32).sin();
        }
        lits[kern_idx] = xla::Literal::from_f32(&kshape, kern);

        let refs: Vec<&xla::Literal> = lits.iter().collect();
        let want = lits_to_f32(&exe.execute(&refs).unwrap());
        let got = lits_to_f32(&exe.execute_stateful(&mut state, &refs).unwrap());
        assert_eq!(got, want, "variant {variant} diverged after token/kernel change");
    }
}

/// Eval plans run liveness analysis and recycle dead buffers into later
/// same-size nodes; train plans must not (backward reads every value).
#[test]
fn eval_plans_share_arena_buffers_train_plans_do_not() {
    let _env = env_lock();
    let manifest = manifest();
    let engine = Engine::for_manifest(&manifest).unwrap();
    let spec = manifest.artifact("enc_tiny__c3a_d8__cls__eval").unwrap().clone();
    let base = catalog::init_base_params(manifest.model("enc_tiny").unwrap());
    let init =
        build_init(&spec, &base, None, &mut Rng::seed(7), C3aScheme::Xavier).unwrap();
    let session = EvalSession::new(&engine, &spec, &init).unwrap();
    let (b, s) = (spec.batch, spec.seq);
    let toks: Vec<i32> =
        (0..b * s).map(|i| if i % 6 == 0 { 1 } else { 3 + (i as i32 % 37) }).collect();
    let batch = vec![Tensor::from_i32(vec![b, s], &toks)];
    let adapter = init.trainable.clone();
    assert!(session.plan_stats().is_none(), "no plan before the first call");
    let (l0, _) = session.logits(&adapter, &batch).unwrap();
    let (l1, _) = session.logits(&adapter, &batch).unwrap();
    assert_eq!(l0, l1, "replay must reproduce the recorded logits bitwise");
    let stats = session.plan_stats().unwrap();
    assert!(
        stats.shared_buffers > 0,
        "encoder eval plan found no recyclable buffers: {stats:?}"
    );
    assert!(stats.arena_bytes > 0);

    // train plan over the same model: sharing disabled
    let tspec = manifest.artifact("enc_tiny__c3a_d8__cls__train").unwrap().clone();
    let meta = manifest.model("enc_tiny").unwrap().clone();
    let exe = InterpExecutable::new(&tspec, &meta).unwrap();
    let lits = catalog::synth_inputs(&tspec, &meta);
    let refs: Vec<&xla::Literal> = lits.iter().collect();
    let mut state = exe.prepare(&frozen_lits(&tspec, &lits)).unwrap();
    exe.execute_stateful(&mut state, &refs).unwrap();
    let tstats = state.plan_stats().unwrap();
    assert_eq!(tstats.shared_buffers, 0, "train plans must retain every buffer");
}

/// `C3A_PLAN=0` disables recording: stateful execution stays on the
/// legacy rebuild path (and stays correct).
#[test]
fn plan_kill_switch_falls_back_to_rebuild() {
    let _env = env_lock();
    let manifest = manifest();
    let spec = manifest.artifact("mlp__mlp_c3a__cls__eval").unwrap().clone();
    let meta = manifest.model("mlp").unwrap().clone();
    let exe = InterpExecutable::new(&spec, &meta).unwrap();
    let lits = catalog::synth_inputs(&spec, &meta);
    let refs: Vec<&xla::Literal> = lits.iter().collect();
    let want = lits_to_f32(&exe.execute(&refs).unwrap());

    let mut state = {
        let _plan_off = env::ScopedSet::set(env::PLAN, "0");
        exe.prepare(&frozen_lits(&spec, &lits)).unwrap()
    };
    for _ in 0..2 {
        let got = lits_to_f32(&exe.execute_stateful(&mut state, &refs).unwrap());
        assert_eq!(got, want);
    }
    assert!(state.plan_stats().is_none(), "C3A_PLAN=0 must not record a plan");
}

/// Hoisting (`C3A_HOIST`, default on): version-invariant prefix ops are
/// computed on the first replay after a (re)record or adapter change and
/// skipped on later eval replays.  For every eval artifact of the tiny
/// slice, a hoist-on state must stay bit-identical to a `C3A_HOIST=0`
/// full-replay state — on the recording call, across replays, after an
/// adapter perturbation (the invalidation must recompute), and after
/// reverting to the original adapter.  BOFT (rotation built from
/// `boft.skew` + a constant eye) and DoRA (normalized-weight chain) must
/// actually hoist ops; methods that keep `x` inside every adapter op
/// hoist none and must say so.
#[test]
fn hoisted_eval_replay_matches_full_replay_across_tiny_catalog() {
    let _env = env_lock();
    // explicit, not ambient: CI crosses this binary with C3A_HOIST=0,
    // and this test is specifically about the hoist-on/off pair
    let _hoist_on = env::ScopedSet::set(env::HOIST, "1");
    let manifest = manifest();
    const MODELS: [&str; 4] = ["enc_tiny", "mlp", "dec_small", "vit_base"];
    let mut covered = 0usize;
    let mut hoist_rich = 0usize;
    for (name, spec) in &manifest.artifacts {
        if spec.kind != "eval" || !MODELS.contains(&spec.model.as_str()) {
            continue;
        }
        let meta = manifest.model(&spec.model).unwrap().clone();
        let exe = InterpExecutable::new(spec, &meta).unwrap();
        let mut lits = catalog::synth_inputs(spec, &meta);
        let frozen = frozen_lits(spec, &lits);
        let mut on = exe.prepare(&frozen).unwrap();
        let mut off = exe.prepare(&frozen).unwrap();
        let t_idx: Vec<usize> =
            (0..spec.inputs.len()).filter(|&i| spec.inputs[i].role == Role::Trainable).collect();
        let orig: Vec<xla::Literal> = t_idx.iter().map(|&i| lits[i].clone()).collect();
        // three adapter epochs: init bits, perturbed bits (a hot-swap /
        // post-train-step version), then back to the init bits
        for epoch in 0..3usize {
            match epoch {
                1 => {
                    for &i in &t_idx {
                        let shape = spec.inputs[i].shape.clone();
                        let mut v = lits[i].to_vec::<f32>().unwrap();
                        for (e, x) in v.iter_mut().enumerate() {
                            *x += 0.02 * ((e + 1) as f32).sin();
                        }
                        lits[i] = xla::Literal::from_f32(&shape, v);
                    }
                }
                2 => {
                    for (k, &i) in t_idx.iter().enumerate() {
                        lits[i] = orig[k].clone();
                    }
                }
                _ => {}
            }
            let refs: Vec<&xla::Literal> = lits.iter().collect();
            for call in 0..3 {
                let got = lits_to_f32(&exe.execute_stateful(&mut on, &refs).unwrap());
                let want = {
                    let _off = env::ScopedSet::set(env::HOIST, "0");
                    lits_to_f32(&exe.execute_stateful(&mut off, &refs).unwrap())
                };
                assert_eq!(got, want, "{name}: epoch {epoch} call {call} hoist-on vs hoist-off");
            }
        }
        let stats = on.plan_stats().expect("plan recorded");
        assert_eq!(stats.replays, 8, "{name}: replay count");
        assert_eq!(
            off.plan_stats().unwrap().hoisted_ops,
            0,
            "{name}: C3A_HOIST=0 at record time must hoist nothing"
        );
        if stats.hoisted_ops > 0 {
            // per epoch: call 0 records or invalidates, calls 1-2 skip
            assert_eq!(
                stats.hoist_skips,
                6 * stats.hoisted_ops as u64,
                "{name}: six skipping replays expected: {stats:?}"
            );
            assert_eq!(
                stats.hoist_invalidations, 2,
                "{name}: each adapter-bit change must invalidate once: {stats:?}"
            );
            hoist_rich += 1;
        } else {
            assert_eq!(stats.hoist_skips, 0, "{name}: skips without hoisted ops");
        }
        covered += 1;
    }
    // 16 enc_tiny + mlp slice + 4 dec_small + 4 vit_base eval artifacts
    assert!(covered >= 25, "expected the eval slice of the tiny catalog, got {covered}");
    // boft carries a hoistable rotation prefix on both enc_tiny heads and
    // dora a normalized-weight chain on dec_small
    assert!(hoist_rich >= 3, "expected boft/dora to hoist ops, got {hoist_rich} artifacts");
}

/// A real train step between eval calls must invalidate the hoisted
/// prefix: serving freshly-trained BOFT weights recomputes the rotation
/// exactly once, later replays with the same snapshot skip again, and a
/// swap back to the original adapter invalidates once more — all
/// bit-identical to a `C3A_HOIST=0` session fed the same snapshots.
#[test]
fn hoist_invalidation_recomputes_after_train_steps_and_swaps() {
    let _env = env_lock();
    let _hoist_on = env::ScopedSet::set(env::HOIST, "1");
    let manifest = manifest();
    let engine = Engine::for_manifest(&manifest).unwrap();
    let spec = manifest.artifact("enc_tiny__boft__cls__eval").unwrap().clone();
    let meta = manifest.model("enc_tiny").unwrap().clone();
    let base = catalog::init_base_params(&meta);
    let init = build_init(&spec, &base, None, &mut Rng::seed(11), C3aScheme::Xavier).unwrap();
    let on = EvalSession::new(&engine, &spec, &init).unwrap();
    let off = EvalSession::new(&engine, &spec, &init).unwrap();
    let (b, s) = (spec.batch, spec.seq);
    let toks: Vec<i32> =
        (0..b * s).map(|i| if i % 4 == 0 { 1 } else { 2 + (i as i32 % 41) }).collect();
    let batch = vec![Tensor::from_i32(vec![b, s], &toks)];

    // the off session records its plan under C3A_HOIST=0 (build-time
    // gate), so every later off call is a full replay regardless of env
    let check = |tag: &str, adapter: &TensorMap| {
        let (got, _) = on.logits(adapter, &batch).unwrap();
        let want = {
            let _off = env::ScopedSet::set(env::HOIST, "0");
            off.logits(adapter, &batch).unwrap().0
        };
        assert_eq!(got, want, "{tag}: hoist-on diverged from the full replay");
    };

    let a0 = init.trainable.clone();
    check("record", &a0);
    check("replay-1", &a0);
    check("replay-2", &a0);

    // drive real optimizer steps on the matching train artifact and serve
    // each snapshot: new bits must recompute the rotation exactly once
    let tspec = manifest.artifact("enc_tiny__boft__cls__train").unwrap().clone();
    let tinit = build_init(&tspec, &base, None, &mut Rng::seed(12), C3aScheme::Xavier).unwrap();
    let mut train = TrainSession::new(&engine, &tspec, &tinit).unwrap();
    let tlits = catalog::synth_inputs(&tspec, &meta);
    let tbatch: Vec<Tensor> = tspec
        .data_order
        .iter()
        .map(|name| {
            let idx = tspec.inputs.iter().position(|i| &i.name == name).unwrap();
            let inp = &tspec.inputs[idx];
            if inp.i32_dtype {
                Tensor::from_i32(inp.shape.clone(), &tlits[idx].to_vec::<i32>().unwrap())
            } else {
                Tensor::from_f32(inp.shape.clone(), &tlits[idx].to_vec::<f32>().unwrap())
            }
        })
        .collect();
    train.step(&tbatch, 0.05, 0.0).unwrap();
    let t1 = train.trainable_tensors().unwrap();
    check("post-step-1", &t1);
    check("post-step-1-replay", &t1);
    train.step(&tbatch, 0.05, 0.0).unwrap();
    let t2 = train.trainable_tensors().unwrap();
    check("post-step-2", &t2);
    // hot-swap back to the original adapter mid-stream
    check("swap-back", &a0);
    check("swap-back-replay", &a0);

    let stats = on.plan_stats().unwrap();
    assert!(stats.hoisted_ops > 0, "boft eval plan must hoist its rotation prefix: {stats:?}");
    assert_eq!(stats.replays, 7, "replay count: {stats:?}");
    assert_eq!(
        stats.hoist_invalidations, 3,
        "t1, t2 and the swap back must each invalidate once: {stats:?}"
    );
    assert_eq!(
        stats.hoist_skips,
        4 * stats.hoisted_ops as u64,
        "four skipping replays expected: {stats:?}"
    );
    assert_eq!(off.plan_stats().unwrap().hoisted_ops, 0, "off session must not hoist");
}
