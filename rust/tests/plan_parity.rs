//! Tentpole regression tests for the execution-plan subsystem: a replayed
//! step must be bit-for-bit identical to the legacy rebuild path — for
//! every enc_tiny/mlp artifact, across repeated calls, across adapter
//! swaps mid-stream, and at any thread count (the C3A_THREADS=1/4 CI
//! matrix runs this whole file).

use c3a::peft::init::C3aScheme;
use c3a::runtime::catalog;
use c3a::runtime::interp::InterpExecutable;
use c3a::runtime::manifest::{Manifest, Role};
use c3a::runtime::session::{build_init, EvalSession};
use c3a::runtime::Engine;
use c3a::substrate::env;
use c3a::substrate::prng::Rng;
use c3a::substrate::tensor::Tensor;
use c3a::xla;

/// Serializes the tests in this binary: the kill-switch test toggles the
/// process-wide `C3A_PLAN` env var, which must not race a concurrent
/// `prepare` in a sibling test.
fn env_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::OnceLock<std::sync::Mutex<()>> = std::sync::OnceLock::new();
    LOCK.get_or_init(|| std::sync::Mutex::new(())).lock().unwrap_or_else(|e| e.into_inner())
}

fn manifest() -> Manifest {
    let dir = std::env::temp_dir().join("c3a_plan_parity");
    catalog::synthesize(&dir).unwrap()
}

fn lits_to_f32(outs: &[xla::Literal]) -> Vec<Vec<f32>> {
    outs.iter().map(|l| l.to_vec::<f32>().unwrap()).collect()
}

/// Frozen literals in frozen_order, extracted from a synthesized input set.
fn frozen_lits(
    spec: &c3a::runtime::manifest::ArtifactSpec,
    lits: &[xla::Literal],
) -> Vec<xla::Literal> {
    spec.frozen_order
        .iter()
        .map(|name| {
            let idx = spec.inputs.iter().position(|i| &i.name == name).unwrap();
            lits[idx].clone()
        })
        .collect()
}

/// Every enc_tiny + mlp + dec_small + vit_base artifact (train and eval,
/// every PEFT method and head): the recording call and three replays must
/// all be bit-identical to the stateless rebuild.  This is the plan
/// subsystem's acceptance pin.  dec_small covers the causal-mask
/// recomputation + decoder train replay (shifted-token targets) and
/// vit_base the vec-mode `data.x` leaf + constant mask — paths the
/// enc_tiny slice alone would leave untested (the differential oracle
/// sweep excludes these models only because the *naive oracle* is slow;
/// this test is substrate-vs-substrate and stays cheap).
#[test]
fn plan_replay_is_bit_identical_to_rebuild_across_tiny_catalog() {
    let _env = env_lock();
    let manifest = manifest();
    const MODELS: [&str; 4] = ["enc_tiny", "mlp", "dec_small", "vit_base"];
    let mut covered = 0usize;
    for (name, spec) in &manifest.artifacts {
        if !MODELS.contains(&spec.model.as_str()) {
            continue;
        }
        let meta = manifest.model(&spec.model).unwrap().clone();
        let exe = InterpExecutable::new(spec, &meta).unwrap();
        let lits = catalog::synth_inputs(spec, &meta);
        let refs: Vec<&xla::Literal> = lits.iter().collect();
        let want = lits_to_f32(&exe.execute(&refs).unwrap());

        let mut state = exe.prepare(&frozen_lits(spec, &lits)).unwrap();
        // call 1 records the plan, calls 2..4 replay it
        for call in 0..4 {
            let got = lits_to_f32(&exe.execute_stateful(&mut state, &refs).unwrap());
            assert_eq!(got, want, "{name}: call {call} diverged from the rebuild path");
        }
        let stats = state.plan_stats().expect("plan must be recorded after the first call");
        assert!(stats.ops > 0, "{name}: empty plan");
        assert_eq!(stats.replays, 3, "{name}: replay count");
        covered += 1;
    }
    // 39 enc_tiny+mlp + 9 dec_small + 8 vit_base
    assert!(covered >= 56, "expected the widened artifact slice, got {covered}");
}

/// Replays must track *changing* inputs: new tokens re-id the embedding
/// gathers and recompute the attention masks, new kernels re-FFT the
/// spectra.  Each variation is checked against a fresh stateless run.
#[test]
fn plan_replay_tracks_new_tokens_and_kernels() {
    let _env = env_lock();
    let manifest = manifest();
    let spec = manifest.artifact("enc_tiny__c3a_d8__cls__train").unwrap().clone();
    let meta = manifest.model("enc_tiny").unwrap().clone();
    let exe = InterpExecutable::new(&spec, &meta).unwrap();
    let mut lits = catalog::synth_inputs(&spec, &meta);
    let mut state = exe.prepare(&frozen_lits(&spec, &lits)).unwrap();
    {
        let refs: Vec<&xla::Literal> = lits.iter().collect();
        exe.execute_stateful(&mut state, &refs).unwrap(); // record
    }

    let (b, s) = (spec.batch, spec.seq);
    let tok_idx = spec.inputs.iter().position(|i| i.name == "data.tokens").unwrap();
    let kern_idx = spec
        .inputs
        .iter()
        .position(|i| i.role == Role::Trainable && i.name.contains(".c3a.w"))
        .unwrap();

    for variant in 0..3 {
        // new tokens (with fresh pad positions) + a perturbed kernel
        let toks: Vec<i32> = (0..b * s)
            .map(|i| if (i + variant) % 5 == 0 { 0 } else { 2 + ((i * 7 + variant) as i32 % 40) })
            .collect();
        lits[tok_idx] = xla::Literal::from_i32(&[b, s], toks);
        let kshape = spec.inputs[kern_idx].shape.clone();
        let mut kern = lits[kern_idx].to_vec::<f32>().unwrap();
        for (e, v) in kern.iter_mut().enumerate() {
            *v += 0.01 * ((e + variant) as f32).sin();
        }
        lits[kern_idx] = xla::Literal::from_f32(&kshape, kern);

        let refs: Vec<&xla::Literal> = lits.iter().collect();
        let want = lits_to_f32(&exe.execute(&refs).unwrap());
        let got = lits_to_f32(&exe.execute_stateful(&mut state, &refs).unwrap());
        assert_eq!(got, want, "variant {variant} diverged after token/kernel change");
    }
}

/// Eval plans run liveness analysis and recycle dead buffers into later
/// same-size nodes; train plans must not (backward reads every value).
#[test]
fn eval_plans_share_arena_buffers_train_plans_do_not() {
    let _env = env_lock();
    let manifest = manifest();
    let engine = Engine::for_manifest(&manifest).unwrap();
    let spec = manifest.artifact("enc_tiny__c3a_d8__cls__eval").unwrap().clone();
    let base = catalog::init_base_params(manifest.model("enc_tiny").unwrap());
    let init =
        build_init(&spec, &base, None, &mut Rng::seed(7), C3aScheme::Xavier).unwrap();
    let session = EvalSession::new(&engine, &spec, &init).unwrap();
    let (b, s) = (spec.batch, spec.seq);
    let toks: Vec<i32> =
        (0..b * s).map(|i| if i % 6 == 0 { 1 } else { 3 + (i as i32 % 37) }).collect();
    let batch = vec![Tensor::from_i32(vec![b, s], &toks)];
    let adapter = init.trainable.clone();
    assert!(session.plan_stats().is_none(), "no plan before the first call");
    let (l0, _) = session.logits(&adapter, &batch).unwrap();
    let (l1, _) = session.logits(&adapter, &batch).unwrap();
    assert_eq!(l0, l1, "replay must reproduce the recorded logits bitwise");
    let stats = session.plan_stats().unwrap();
    assert!(
        stats.shared_buffers > 0,
        "encoder eval plan found no recyclable buffers: {stats:?}"
    );
    assert!(stats.arena_bytes > 0);

    // train plan over the same model: sharing disabled
    let tspec = manifest.artifact("enc_tiny__c3a_d8__cls__train").unwrap().clone();
    let meta = manifest.model("enc_tiny").unwrap().clone();
    let exe = InterpExecutable::new(&tspec, &meta).unwrap();
    let lits = catalog::synth_inputs(&tspec, &meta);
    let refs: Vec<&xla::Literal> = lits.iter().collect();
    let mut state = exe.prepare(&frozen_lits(&tspec, &lits)).unwrap();
    exe.execute_stateful(&mut state, &refs).unwrap();
    let tstats = state.plan_stats().unwrap();
    assert_eq!(tstats.shared_buffers, 0, "train plans must retain every buffer");
}

/// `C3A_PLAN=0` disables recording: stateful execution stays on the
/// legacy rebuild path (and stays correct).
#[test]
fn plan_kill_switch_falls_back_to_rebuild() {
    let _env = env_lock();
    let manifest = manifest();
    let spec = manifest.artifact("mlp__mlp_c3a__cls__eval").unwrap().clone();
    let meta = manifest.model("mlp").unwrap().clone();
    let exe = InterpExecutable::new(&spec, &meta).unwrap();
    let lits = catalog::synth_inputs(&spec, &meta);
    let refs: Vec<&xla::Literal> = lits.iter().collect();
    let want = lits_to_f32(&exe.execute(&refs).unwrap());

    let mut state = {
        let _plan_off = env::ScopedSet::set(env::PLAN, "0");
        exe.prepare(&frozen_lits(&spec, &lits)).unwrap()
    };
    for _ in 0..2 {
        let got = lits_to_f32(&exe.execute_stateful(&mut state, &refs).unwrap());
        assert_eq!(got, want);
    }
    assert!(state.plan_stats().is_none(), "C3A_PLAN=0 must not record a plan");
}
