//! Cross-shard serving tests: deterministic tenant→shard routing,
//! disjoint per-shard registries, per-tenant FIFO across hot-swaps while
//! *other* shards keep serving, per-shard backpressure accounting, and
//! seeded Zipf replay reproducibility against a live sharded scheduler.
//! The `shards = 1` contract lives in `serving.rs`.

use c3a::peft::init::C3aScheme;
use c3a::runtime::catalog;
use c3a::runtime::session::build_init;
use c3a::runtime::Engine;
use c3a::serving::{
    perturb_c3a_kernels as perturb, run_replay, shard_of, tenant_name, AdapterRegistry,
    ReplayCfg, Scheduler, SchedulerCfg, ShardCtx, SubmitError,
};
use c3a::substrate::prng::Rng;
use c3a::substrate::tensor::TensorMap;
use std::path::Path;
use std::sync::Mutex;
use std::time::Duration;

const EVAL: &str = "enc_tiny__c3a_d8__cls__eval";

fn template(dir: &Path) -> (TensorMap, usize) {
    let manifest = catalog::synthesize(dir).unwrap();
    let spec = manifest.artifact(EVAL).unwrap().clone();
    let meta = manifest.model("enc_tiny").unwrap().clone();
    let base = catalog::init_base_params(&meta);
    let init = build_init(&spec, &base, None, &mut Rng::seed(1), C3aScheme::Xavier).unwrap();
    (init.trainable, spec.seq)
}

/// Build a shard's registry, registering only the tenants the shard owns
/// (the scheduler rejects anything else at startup).
fn build_shard_registry(
    dir: &Path,
    adapters: &[(String, TensorMap)],
    ctx: &ShardCtx,
) -> anyhow::Result<AdapterRegistry> {
    let manifest = catalog::synthesize(dir)?;
    let spec = manifest.artifact(EVAL)?.clone();
    let meta = manifest.model("enc_tiny")?.clone();
    let engine = Engine::for_manifest(&manifest)?;
    let base = catalog::init_base_params(&meta);
    let init = build_init(&spec, &base, None, &mut Rng::seed(1), C3aScheme::Xavier)?;
    let mut registry = AdapterRegistry::new(&engine, &spec, &init)?;
    for (name, params) in adapters {
        if ctx.owns(name) {
            registry.register(name, params.clone())?;
        }
    }
    Ok(registry)
}

fn toks(seed: i32, s: usize) -> Vec<i32> {
    (0..s as i32).map(|j| if j == 0 { 1 } else { 4 + ((seed * 13 + j * 7) % 40) }).collect()
}

/// Routing is a pure, platform-independent function of the tenant name:
/// FNV-1a mod shards.  The exact assignments below are pinned — a routing
/// change would silently strand every tenant's sessions on the wrong
/// shard across a rolling restart, so it must show up as a test diff.
#[test]
fn routing_is_deterministic_and_pinned() {
    for (name, two, four) in
        [("ta", 0, 2), ("tb", 1, 3), ("tc", 0, 0), ("tenant0", 1, 3), ("tenant1", 0, 0)]
    {
        assert_eq!(shard_of(name, 2), two, "{name} % 2");
        assert_eq!(shard_of(name, 4), four, "{name} % 4");
    }
    assert_eq!(shard_of("tenant2", 4), 1);
    assert_eq!(shard_of("tenant3", 4), 2);
    // shards <= 1 routes everything to shard 0
    for name in ["ta", "tenant0", ""] {
        assert_eq!(shard_of(name, 1), 0);
        assert_eq!(shard_of(name, 0), 0);
    }
    // the replay tenant population spreads: no shard is empty and none
    // takes more than half under the canonical tenant{i} naming
    let mut counts = [0usize; 4];
    for i in 0..200 {
        counts[shard_of(&tenant_name(i), 4)] += 1;
    }
    assert_eq!(counts.iter().sum::<usize>(), 200);
    assert_eq!(counts, [51, 49, 49, 51], "FNV-1a spread over tenant0..199 is pinned");
}

/// Four shards, four tenants, one per shard: every tenant serves from
/// exactly the shard its name hashes to, each shard builds its own
/// registry, and the merged stats tie per-shard counters to the totals.
#[test]
fn shards_serve_disjoint_tenant_sets() {
    let dir = std::env::temp_dir().join("c3a_sharded_disjoint");
    let (adapter, s) = template(&dir);
    let adapters: Vec<(String, TensorMap)> =
        (0..4).map(|i| (format!("tenant{i}"), perturb(&adapter, i as u64, 0.05))).collect();
    let cfg = SchedulerCfg { shards: 4, ..SchedulerCfg::default() };
    let sched = Scheduler::spawn(cfg, {
        let dir = dir.clone();
        move |ctx: &ShardCtx| build_shard_registry(&dir, &adapters, ctx)
    })
    .unwrap();
    let handle = sched.handle();
    assert_eq!(handle.shards(), 4);
    let mut tickets = Vec::new();
    for i in 0..12 {
        let tenant = format!("tenant{}", i % 4);
        let ticket = handle.submit(&tenant, toks(i, s)).unwrap();
        tickets.push((tenant, ticket));
    }
    for (tenant, t) in tickets {
        assert_eq!(t.wait().unwrap().tenant, tenant);
    }
    drop(handle);
    let stats = sched.finish().unwrap();
    assert_eq!(stats.served, 12);
    assert_eq!(stats.shards.len(), 4);
    assert_eq!(stats.active_shards(), 4, "one tenant per shard must light up every shard");
    // pinned assignments: tenant0→3, tenant1→0, tenant2→1, tenant3→2
    for (name, shard) in [("tenant0", 3), ("tenant1", 0), ("tenant2", 1), ("tenant3", 2)] {
        let t = stats.tenant(name).unwrap();
        assert_eq!(t.shard, shard, "{name} must be affine to shard {shard}");
        assert_eq!(t.requests, 3);
        assert_eq!(t.uploads, 1);
        // this shard served exactly this tenant's requests
        assert_eq!(stats.shards[shard].served, 3);
    }
    let per_shard: u64 = stats.shards.iter().map(|sh| sh.served).sum();
    assert_eq!(per_shard, stats.served, "per-shard served must sum to the aggregate");
}

/// A registry containing a tenant that routes to a *different* shard is a
/// deployment bug (that tenant could never receive a request), so the
/// worker must reject it loudly at startup instead of serving a silent
/// black hole.
#[test]
fn mis_sharded_tenant_is_rejected_at_startup() {
    let dir = std::env::temp_dir().join("c3a_sharded_missharded");
    let (adapter, _s) = template(&dir);
    // every shard registers BOTH tenants — each then holds one foreigner
    let adapters =
        vec![("ta".to_string(), adapter.clone()), ("tb".to_string(), adapter.clone())];
    let cfg = SchedulerCfg { shards: 2, ..SchedulerCfg::default() };
    let sched = Scheduler::spawn(cfg, {
        let dir = dir.clone();
        move |_ctx: &ShardCtx| {
            // deliberately ignore ctx.owns
            let manifest = catalog::synthesize(&dir)?;
            let spec = manifest.artifact(EVAL)?.clone();
            let meta = manifest.model("enc_tiny")?.clone();
            let engine = Engine::for_manifest(&manifest)?;
            let base = catalog::init_base_params(&meta);
            let init =
                build_init(&spec, &base, None, &mut Rng::seed(1), C3aScheme::Xavier)?;
            let mut registry = AdapterRegistry::new(&engine, &spec, &init)?;
            for (name, params) in &adapters {
                registry.register(name, params.clone())?;
            }
            Ok(registry)
        }
    })
    .unwrap();
    let err = sched.finish().expect_err("a mis-sharded registry must fail startup");
    let msg = format!("{err:#}");
    assert!(msg.contains("routes to"), "error must name the routing violation: {msg}");
}

/// The tentpole FIFO invariant under load: gate the shard that owns `ta`
/// so its queue backs up with [req, req, swap, req], and meanwhile prove
/// the *other* shard keeps serving `tb` to completion.  When the gate
/// opens, ta's pre-swap requests must serve v1, the swap must ack v2, and
/// the post-swap request must serve v2 — FIFO per tenant, with zero
/// cross-shard coordination.
#[test]
fn fifo_across_hot_swap_on_a_loaded_shard_while_other_shards_serve() {
    let dir = std::env::temp_dir().join("c3a_sharded_fifo_swap");
    let (adapter, s) = template(&dir);
    let adapters =
        vec![("ta".to_string(), adapter.clone()), ("tb".to_string(), adapter.clone())];
    // gate ONLY shard 0 (ta's shard under shards=2); shard 1 builds free
    let (gate_tx, gate_rx) = std::sync::mpsc::channel::<()>();
    let gate_rx = Mutex::new(gate_rx);
    let cfg = SchedulerCfg {
        shards: 2,
        queue_cap: 8,
        max_batch: 4,
        max_wait: Duration::from_millis(5),
    };
    let sched = Scheduler::spawn(cfg, {
        let dir = dir.clone();
        move |ctx: &ShardCtx| {
            if ctx.shard() == 0 {
                let _ = gate_rx.lock().unwrap().recv();
            }
            build_shard_registry(&dir, &adapters, ctx)
        }
    })
    .unwrap();
    let handle = sched.handle();
    assert_eq!(handle.shard_for("ta"), 0);
    assert_eq!(handle.shard_for("tb"), 1);

    let a1 = handle.try_submit("ta", toks(1, s)).expect("shard 0 queue has room");
    let a2 = handle.try_submit("ta", toks(2, s)).expect("shard 0 queue has room");
    // the swap blocks for its ack, so it needs a helper thread; it lands
    // in shard 0's queue strictly after a1/a2
    let swapper = {
        let handle = handle.clone();
        let params = perturb(&adapter, 7, 0.5);
        std::thread::spawn(move || handle.hot_swap("ta", params).expect("swap acked"))
    };
    std::thread::sleep(Duration::from_millis(100));
    // submitted after the swap message: must serve the NEW version
    let a3 = handle.submit("ta", toks(3, s)).unwrap();

    // shard 1 serves tb to completion WHILE shard 0 is still gated — this
    // wait returning before gate_tx fires is the cross-shard liveness proof
    let rb = handle.submit("tb", toks(4, s)).unwrap().wait().unwrap();
    assert_eq!(rb.tenant_version, 1);
    assert_eq!(rb.tenant, "tb");

    gate_tx.send(()).unwrap();
    assert_eq!(a1.wait().unwrap().tenant_version, 1, "pre-swap request must serve v1");
    assert_eq!(a2.wait().unwrap().tenant_version, 1, "pre-swap request must serve v1");
    assert_eq!(swapper.join().unwrap(), 2, "swap must ack with the bumped version");
    assert_eq!(a3.wait().unwrap().tenant_version, 2, "post-swap request must serve v2");

    drop(handle);
    let stats = sched.finish().unwrap();
    assert_eq!(stats.served, 4);
    assert_eq!(stats.failed, 0);
    let ta = stats.tenant("ta").unwrap();
    let tb = stats.tenant("tb").unwrap();
    assert_eq!((ta.shard, ta.version, ta.uploads), (0, 2, 2));
    assert_eq!((tb.shard, tb.version, tb.uploads), (1, 1, 1));
}

/// Backpressure is per shard: filling the gated shard's queue sheds new
/// `try_submit`s with exact per-shard/per-tenant accounting, while the
/// other shard's queue stays open for business.
#[test]
fn sheds_and_depth_are_accounted_per_shard() {
    let dir = std::env::temp_dir().join("c3a_sharded_sheds");
    let (adapter, s) = template(&dir);
    let adapters =
        vec![("ta".to_string(), adapter.clone()), ("tb".to_string(), adapter.clone())];
    let (gate_tx, gate_rx) = std::sync::mpsc::channel::<()>();
    let gate_rx = Mutex::new(gate_rx);
    let cfg = SchedulerCfg {
        shards: 2,
        queue_cap: 4,
        max_batch: 4,
        max_wait: Duration::from_millis(1),
    };
    let sched = Scheduler::spawn(cfg, {
        let dir = dir.clone();
        move |ctx: &ShardCtx| {
            if ctx.shard() == 0 {
                let _ = gate_rx.lock().unwrap().recv();
            }
            build_shard_registry(&dir, &adapters, ctx)
        }
    })
    .unwrap();
    let handle = sched.handle();
    let mut tickets = Vec::new();
    for i in 0..4 {
        tickets.push(handle.try_submit("ta", toks(i, s)).expect("queue has room"));
    }
    for _ in 0..2 {
        match handle.try_submit("ta", toks(9, s)) {
            Err(SubmitError::QueueFull) => {}
            other => panic!("expected QueueFull on the gated shard, got {other:?}"),
        }
    }
    // shard 1's queue is untouched: tb admits (and serves) immediately
    tickets.push(handle.try_submit("tb", toks(5, s)).expect("other shard must admit"));
    gate_tx.send(()).unwrap();
    for t in tickets {
        t.wait().unwrap();
    }
    drop(handle);
    let stats = sched.finish().unwrap();
    assert_eq!(stats.served, 5);
    assert_eq!(stats.sheds, 2);
    assert_eq!(stats.shards[0].sheds, 2, "both sheds hit the gated shard");
    assert_eq!(stats.shards[1].sheds, 0);
    assert_eq!(stats.shards[0].queue_depth_hwm, 4, "hwm must reflect the full queue");
    assert!(stats.shards[1].queue_depth_hwm >= 1);
    assert_eq!(stats.tenant("ta").unwrap().sheds, 2, "sheds must attribute to the tenant");
    assert_eq!(stats.tenant("tb").unwrap().sheds, 0);
}

/// Sharding must not change what is served: the same slow-producer request
/// sequence through shards=1 and shards=4 yields bitwise-identical logits,
/// predictions, and versions per request — each shard's own backbone
/// parse is built from the same seeded init, and row math is independent
/// of which worker runs it.
#[test]
fn shards1_and_shards4_serve_bitwise_identical_replies() {
    let dir = std::env::temp_dir().join("c3a_sharded_bitwise");
    let (adapter, s) = template(&dir);
    let adapters: Vec<(String, TensorMap)> =
        (0..4).map(|i| (format!("tenant{i}"), perturb(&adapter, i as u64, 0.05))).collect();
    let serve = |shards: usize| {
        let cfg = SchedulerCfg { shards, ..SchedulerCfg::default() };
        let sched = Scheduler::spawn(cfg, {
            let dir = dir.clone();
            let adapters = adapters.clone();
            move |ctx: &ShardCtx| build_shard_registry(&dir, &adapters, ctx)
        })
        .unwrap();
        let handle = sched.handle();
        // slow producer: one reply in hand before the next submit, so the
        // request→batch decomposition is identical under any shard count
        let mut replies = Vec::new();
        for i in 0..8 {
            let tenant = format!("tenant{}", i % 4);
            replies.push(handle.submit(&tenant, toks(i, s)).unwrap().wait().unwrap());
        }
        drop(handle);
        sched.finish().unwrap();
        replies
    };
    let one = serve(1);
    let four = serve(4);
    for (a, b) in one.iter().zip(&four) {
        assert_eq!(a.logits, b.logits, "{}: logits must be bitwise identical", a.tenant);
        assert_eq!(a.pred, b.pred);
        assert_eq!(a.tenant_version, b.tenant_version);
        assert_eq!(a.batch_size, b.batch_size);
    }
}

/// The replay driver against a live sharded scheduler: the storm (tenant
/// sequence, swap points) is a pure function of the seed, so two fresh
/// scheduler runs must report the same trace hash, per-tenant arrivals,
/// swap count, and — because swaps are FIFO per tenant — the same
/// predictions request-for-request.
#[test]
fn zipf_replay_is_reproducible_against_a_live_scheduler() {
    let dir = std::env::temp_dir().join("c3a_sharded_replay");
    let (adapter, s) = template(&dir);
    let n_tenants = 6usize;
    let adapters: Vec<(String, TensorMap)> = (0..n_tenants)
        .map(|i| (tenant_name(i), perturb(&adapter, i as u64, 0.05)))
        .collect();
    let replay_cfg = ReplayCfg {
        seed: 42,
        requests: 64,
        tenants: n_tenants,
        zipf_exponent: 1.1,
        burst: 8,
        burst_gap: Duration::from_micros(100),
        swap_every: 24,
        ..ReplayCfg::default()
    };
    let run = || {
        let cfg =
            SchedulerCfg { shards: 2, queue_cap: 64, ..SchedulerCfg::default() };
        let sched = Scheduler::spawn(cfg, {
            let dir = dir.clone();
            let adapters = adapters.clone();
            move |ctx: &ShardCtx| build_shard_registry(&dir, &adapters, ctx)
        })
        .unwrap();
        let handle = sched.handle();
        let adapter = adapter.clone();
        let report = run_replay(
            &handle,
            &replay_cfg,
            |i, _rank| toks(i as i32, s),
            move |swap_idx, _rank| perturb(&adapter, 1000 + swap_idx, 0.3),
        )
        .unwrap();
        drop(handle);
        (report, sched.finish().unwrap())
    };
    let (r1, s1) = run();
    let (r2, s2) = run();
    assert_ne!(r1.trace_hash, 0);
    assert_eq!(r1.trace_hash, r2.trace_hash, "same seed must replay the same storm");
    assert_eq!(r1.per_tenant, r2.per_tenant);
    assert_eq!(r1.per_tenant.iter().sum::<u64>(), 64);
    assert!(
        r1.per_tenant[0] > r1.per_tenant[n_tenants - 1],
        "Zipf rank 0 must out-draw the coldest rank: {:?}",
        r1.per_tenant
    );
    assert_eq!(r1.swaps, 2, "i = 24 and i = 48 fire mid-storm swaps");
    assert_eq!(r1.swaps, r2.swaps);
    // per-tenant FIFO makes version assignment deterministic, so the
    // predictions must agree request-for-request across runs
    assert_eq!(r1.preds, r2.preds, "replay predictions must be reproducible");
    assert_eq!(r1.completed + r1.failed + r1.dropped, 64);
    assert_eq!(r1.failed, 0);
    for stats in [&s1, &s2] {
        assert_eq!(stats.served + stats.failed, (r1.completed + r1.failed) as u64);
        let per_shard: u64 = stats.shards.iter().map(|sh| sh.served).sum();
        assert_eq!(per_shard, stats.served);
        // uploads are bounded by 1 + this tenant's swaps
        for t in &stats.tenants {
            assert!(
                t.uploads as u64 <= 1 + r1.swaps,
                "{}: {} uploads exceeds 1 + {} swaps",
                t.name,
                t.uploads,
                r1.swaps
            );
        }
    }
}
