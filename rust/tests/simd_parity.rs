//! SIMD parity: with `--features simd`, the vector microkernels must be
//! **bitwise** identical to the scalar reference — logits, loss, and
//! gradients, across the tiny catalog, crossed with thread counts
//! (docs/DETERMINISM.md §3).  Built without the feature, every test is a
//! trivial pass (there is nothing to compare), so this file runs in both
//! CI configurations unchanged.

use c3a::runtime::catalog;
use c3a::runtime::interp::InterpExecutable;
use c3a::substrate::parallel;
use c3a::substrate::simd;
use c3a::xla;

fn manifest_dir() -> std::path::PathBuf {
    std::env::temp_dir().join("c3a_simd_parity")
}

fn lits_to_f32(outs: &[xla::Literal]) -> Vec<Vec<f32>> {
    outs.iter().map(|l| l.to_vec::<f32>().unwrap()).collect()
}

/// Run one artifact with the given SIMD setting and thread count; the
/// caller holds both override locks.
fn run_config(
    spec: &c3a::runtime::manifest::ArtifactSpec,
    meta: &c3a::runtime::manifest::ModelMeta,
    lits: &[xla::Literal],
    simd_on: bool,
    threads: usize,
) -> Vec<Vec<f32>> {
    simd::set_enabled(simd_on);
    parallel::set_threads(threads);
    let refs: Vec<&xla::Literal> = lits.iter().collect();
    let exe = InterpExecutable::new(spec, meta).unwrap();
    let outs = exe.execute(&refs).unwrap();
    lits_to_f32(&outs)
}

/// Every enc_tiny + mlp artifact: the scalar single-thread run is the
/// reference; SIMD on/off × threads 1/4 must all reproduce its exact
/// bits.  Eval artifacts pin logits; train artifacts pin the full output
/// contract (updated params, opt state, loss, metric) — which covers
/// every forward matmul, the C3A spectral accumulates, the backward
/// passes, and the kernel-gradient reduction.
#[test]
fn tiny_catalog_simd_bitwise_parity() {
    if !simd::available() {
        eprintln!("simd_parity: built without --features simd; trivially passing");
        return;
    }
    let _simd_lock = simd::override_lock();
    let _thread_lock = parallel::thread_override_lock();
    let prev_threads = parallel::threads();
    let prev_simd = simd::enabled();

    let manifest = catalog::synthesize(&manifest_dir()).unwrap();
    let mut n = 0;
    for (name, spec) in &manifest.artifacts {
        if spec.model != "enc_tiny" && spec.model != "mlp" {
            continue;
        }
        let meta = manifest.model(&spec.model).unwrap();
        let lits = catalog::synth_inputs(spec, meta);
        let reference = run_config(spec, meta, &lits, false, 1);
        for (simd_on, threads) in [(false, 4), (true, 1), (true, 4)] {
            let got = run_config(spec, meta, &lits, simd_on, threads);
            assert_eq!(
                reference, got,
                "{name}: simd={simd_on} threads={threads} diverged from scalar/1-thread"
            );
        }
        n += 1;
    }
    parallel::set_threads(prev_threads);
    simd::set_enabled(prev_simd);
    assert!(n >= 39, "expected the full enc_tiny+mlp slice, got {n}");
    eprintln!("simd parity: {n} artifacts bitwise-identical across simd x threads");
}

/// The runtime switch must be wired: with the feature compiled,
/// `set_enabled` toggles `enabled()` and the env default is on.
#[test]
fn runtime_switch_roundtrip() {
    if !simd::available() {
        assert!(!simd::enabled(), "enabled() must be const-false without the feature");
        return;
    }
    let _lock = simd::override_lock();
    let prev = simd::enabled();
    simd::set_enabled(false);
    assert!(!simd::enabled());
    simd::set_enabled(true);
    assert!(simd::enabled());
    simd::set_enabled(prev);
}
