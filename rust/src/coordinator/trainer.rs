//! Generic fine-tuning driver: step loop, LR schedule, periodic validation,
//! best-checkpoint tracking, early stopping, loss-curve logging.
//!
//! Task specifics (batch sampling, metric computation) are injected as
//! closures so one trainer serves GLUE-sim, instruction-sim, generation,
//! vision-sim, and the Fig-4 MLP.

use super::lr::Schedule;
use crate::runtime::session::{Batch, TrainSession};
use crate::substrate::tensor::TensorMap;
use anyhow::Result;
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct TrainCfg {
    pub steps: usize,
    pub lr: f64,
    pub weight_decay: f64,
    pub schedule: Schedule,
    /// evaluate every N steps (0 = only at the end)
    pub eval_every: usize,
    /// stop after this many evals without improvement (0 = never)
    pub patience: usize,
    /// print progress lines
    pub verbose: bool,
}

impl Default for TrainCfg {
    fn default() -> Self {
        Self {
            steps: 300,
            lr: 1e-2,
            weight_decay: 0.0,
            schedule: Schedule::LinearWarmup { warmup_frac: 0.06 },
            eval_every: 50,
            patience: 0,
            verbose: false,
        }
    }
}

#[derive(Clone, Debug)]
pub struct TrainOutcome {
    /// per-step training loss
    pub losses: Vec<f32>,
    /// (step, val metric) history
    pub evals: Vec<(usize, f64)>,
    pub best_metric: f64,
    pub best_step: usize,
    /// trainable snapshot at the best validation point
    pub best_trainable: TensorMap,
    pub steps_run: usize,
    pub wall_ms: u128,
    /// mean train-step latency (ms), excluding eval time
    pub step_ms: f64,
}

pub struct Trainer {
    pub cfg: TrainCfg,
}

impl Trainer {
    pub fn new(cfg: TrainCfg) -> Self {
        Self { cfg }
    }

    /// Run the loop.  `sample(step)` yields the next batch; `evaluate`
    /// scores the current trainables on validation data (higher = better).
    pub fn run(
        &self,
        session: &mut TrainSession,
        mut sample: impl FnMut(usize) -> Batch,
        mut evaluate: impl FnMut(&TensorMap) -> Result<f64>,
    ) -> Result<TrainOutcome> {
        let cfg = &self.cfg;
        let t0 = Instant::now();
        let mut losses = Vec::with_capacity(cfg.steps);
        let mut evals = Vec::new();
        let mut best_metric = f64::NEG_INFINITY;
        let mut best_step = 0;
        let mut best_trainable = session.trainable_tensors()?;
        let mut since_best = 0usize;
        let mut step_time_ms = 0.0f64;

        for step in 0..cfg.steps {
            let lr = (cfg.lr * cfg.schedule.factor(step, cfg.steps)) as f32;
            let batch = sample(step);
            let ts = Instant::now();
            let (loss, _metric) = session.step(&batch, lr, cfg.weight_decay as f32)?;
            step_time_ms += ts.elapsed().as_secs_f64() * 1e3;
            if !loss.is_finite() {
                anyhow::bail!("divergence at step {step}: loss={loss}");
            }
            losses.push(loss);

            let at_end = step + 1 == cfg.steps;
            if (cfg.eval_every > 0 && (step + 1) % cfg.eval_every == 0) || at_end {
                let snapshot = session.trainable_tensors()?;
                let metric = evaluate(&snapshot)?;
                evals.push((step + 1, metric));
                if cfg.verbose {
                    eprintln!(
                        "  step {:>5}  loss {:.4}  val {:.4}  lr {:.2e}",
                        step + 1,
                        loss,
                        metric,
                        lr
                    );
                }
                if metric > best_metric {
                    best_metric = metric;
                    best_step = step + 1;
                    best_trainable = snapshot;
                    since_best = 0;
                } else {
                    since_best += 1;
                    if cfg.patience > 0 && since_best >= cfg.patience {
                        if cfg.verbose {
                            eprintln!("  early stop at step {} (best {best_metric:.4})", step + 1);
                        }
                        break;
                    }
                }
            }
        }
        let steps_run = losses.len();
        Ok(TrainOutcome {
            losses,
            evals,
            best_metric,
            best_step,
            best_trainable,
            steps_run,
            wall_ms: t0.elapsed().as_millis(),
            step_ms: step_time_ms / steps_run.max(1) as f64,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cfg_defaults_sane() {
        let c = TrainCfg::default();
        assert!(c.steps > 0 && c.lr > 0.0);
    }

    #[test]
    fn first_step_lr_nonzero_for_default_schedule() {
        // regression for the zero-LR first step: the trainer drives
        // `schedule.factor(step, steps)` starting at step 0, which must
        // yield a usable LR under the default warmup schedule
        let c = TrainCfg::default();
        for steps in [10usize, 60, 300] {
            let lr0 = c.lr * c.schedule.factor(0, steps);
            assert!(lr0 > 0.0, "first-step lr is zero for steps={steps}");
        }
    }
}
