//! High-level run facade: one call = pretrain (cached) + fine-tune + eval.
//! This is the public API the CLI, examples, and experiment harness use.

use super::checkpoint;
use super::evals;
use super::lr::Schedule;
use super::trainer::{TrainCfg, TrainOutcome, Trainer};
use crate::data::gen_sim::{self, GenTask};
use crate::data::glue_sim::GlueTask;
use crate::data::instr_sim::{self, McTask};
use crate::data::vision_sim::{self, VisionTask};
use crate::data::{clusters, corpus::Corpus, BatchIter};
use crate::peft::init::C3aScheme;
use crate::runtime::manifest::Manifest;
use crate::runtime::session::{build_init, EvalSession, TrainSession};
use crate::runtime::Engine;
use crate::substrate::circulant::{dense_rank, BlockCirculant};
use crate::substrate::prng::Rng;
use crate::substrate::tensor::TensorMap;
use anyhow::Result;
use std::path::PathBuf;

/// Shared context for all runs.
pub struct Ctx {
    pub engine: Engine,
    pub manifest: Manifest,
    pub artifacts_dir: PathBuf,
    pub verbose: bool,
    /// Override the per-model pretraining budget (tests / quick runs).
    pub pretrain_steps: Option<usize>,
}

impl Ctx {
    /// Open the artifact registry.  With a python-built `manifest.json`
    /// the artifacts are loaded as-is; otherwise the same inventory is
    /// synthesized in Rust and executed on the substrate backend.
    pub fn open(artifacts_dir: &str) -> Result<Ctx> {
        let manifest = Manifest::load_or_synthesize(artifacts_dir)?;
        let engine = Engine::for_manifest(&manifest)?;
        Ok(Ctx {
            engine,
            manifest,
            artifacts_dir: PathBuf::from(artifacts_dir),
            verbose: false,
            pretrain_steps: None,
        })
    }
}

/// Result of one fine-tuning run.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub metric: f64,
    pub val_metric: f64,
    pub n_params: usize,
    pub losses: Vec<f32>,
    pub step_ms: f64,
    pub wall_ms: u128,
    /// (full-rank fraction, mean rank, dim) of learned C3A deltas
    pub rank: Option<(f64, f64, usize)>,
    /// best-checkpoint trainable snapshot (deployable adapter)
    pub trainable: TensorMap,
}

/// Default pretraining budgets (steps, lr).
pub fn pretrain_budget(model: &str) -> (usize, f64) {
    match model {
        m if m.starts_with("enc_tiny") => (800, 3e-3),
        m if m.starts_with("enc") => (500, 3e-3),
        m if m.starts_with("dec") => (350, 1e-3),
        m if m.starts_with("vit") => (250, 1e-3),
        _ => (200, 1e-3),
    }
}

/// Pretrain `model` (MLM for encoders, next-token LM for decoders,
/// classification for vit-sim) and cache the backbone checkpoint.
/// No-op when the checkpoint already exists.
pub fn ensure_pretrained(ctx: &Ctx, model: &str) -> Result<TensorMap> {
    let meta = ctx.manifest.model(model)?.clone();
    let (default_steps, lr) = pretrain_budget(model);
    let steps = ctx.pretrain_steps.unwrap_or(default_steps);
    // A non-default budget gets its own cache file so a short-budget
    // checkpoint never poisons later full-budget runs (and vice versa).
    let ckpt = if steps == default_steps {
        checkpoint::pretrained_path(&ctx.artifacts_dir, model)
    } else {
        ctx.artifacts_dir.join(format!("{model}_pretrained_s{steps}.bin"))
    };
    if ckpt.exists() {
        return checkpoint::load(&ckpt);
    }
    let (art_name, is_vit) = if meta.kind == "decoder" {
        (Manifest::artifact_name(model, "full", "lm", "train"), false)
    } else if model.starts_with("vit") {
        (Manifest::artifact_name(model, "full", "vec", "train"), true)
    } else {
        (Manifest::artifact_name(model, "full", "mlm", "train"), false)
    };
    let spec = ctx.manifest.artifact(&art_name)?.clone();
    let init_map = ctx.manifest.init_params(model)?;
    let mut rng = Rng::seed(0x9E7);
    let init = build_init(&spec, &init_map, None, &mut rng, C3aScheme::Xavier)?;
    let mut session = TrainSession::new(&ctx.engine, &spec, &init)?;

    if ctx.verbose {
        eprintln!("pretraining {model} for {steps} steps ({art_name})");
    }
    let cfg = TrainCfg {
        steps,
        lr,
        weight_decay: 0.01,
        schedule: Schedule::Cosine { warmup_frac: 0.05 },
        eval_every: 0,
        patience: 0,
        verbose: ctx.verbose,
    };
    let corpus = Corpus::new(meta.vocab.max(8), 4, 7);
    let mut data_rng = Rng::seed(0xDA7A);
    // vit-sim pretraining task: 200-class patch prototypes
    let vit_pre = if is_vit {
        Some(vision_sim::splits(VisionTask::Cars, meta.seq, 16, 0xFEED, 4096).train)
    } else {
        None
    };
    let b = spec.batch;
    let s = spec.seq;
    let outcome = Trainer::new(cfg).run(
        &mut session,
        |_| {
            if let Some(ds) = &vit_pre {
                let idx: Vec<usize> = (0..b).map(|_| data_rng.below(ds.len())).collect();
                ds.batch(&idx, b)
            } else if meta.kind == "decoder" {
                corpus.lm_batch(&mut data_rng, b, s)
            } else {
                corpus.mlm_batch(&mut data_rng, b, s)
            }
        },
        |_| Ok(0.0),
    )?;
    if ctx.verbose {
        let first = outcome.losses.first().copied().unwrap_or(0.0);
        let last = outcome.losses.last().copied().unwrap_or(0.0);
        eprintln!("pretrain {model}: loss {first:.3} -> {last:.3}");
    }
    let map = outcome.best_trainable;
    checkpoint::save(&ckpt, &map)?;
    Ok(map)
}

/// Measure the rank profile of learned C3A kernels in a trainable snapshot.
pub fn c3a_rank_summary(trainable: &TensorMap) -> Option<(f64, f64, usize)> {
    let mut full = 0usize;
    let mut total = 0usize;
    let mut rank_sum = 0f64;
    let mut dim = 0usize;
    for (name, t) in trainable {
        if !name.contains(".c3a.w") || t.shape.len() != 3 {
            continue;
        }
        let (m, n, b) = (t.shape[0], t.shape[1], t.shape[2]);
        let bc = BlockCirculant::new(m, n, b, t.as_f32().iter().map(|&v| v as f64).collect());
        let mat = bc.materialize();
        let d = m * b;
        dim = d.max(dim);
        let r = dense_rank(&mat, d, n * b, 1e-7 * (d as f64));
        rank_sum += r as f64;
        total += 1;
        if r == d.min(n * b) {
            full += 1;
        }
    }
    if total == 0 {
        None
    } else {
        Some((full as f64 / total as f64, rank_sum / total as f64, dim))
    }
}

fn finish(outcome: TrainOutcome, test_metric: f64, n_params: usize) -> RunResult {
    RunResult {
        metric: test_metric,
        val_metric: outcome.best_metric,
        n_params,
        step_ms: outcome.step_ms,
        wall_ms: outcome.wall_ms,
        rank: c3a_rank_summary(&outcome.best_trainable),
        losses: outcome.losses,
        trainable: outcome.best_trainable,
    }
}

/// Fine-tune `model`+`method` on a GLUE-sim task; returns the test metric.
pub fn glue_run(
    ctx: &Ctx,
    model: &str,
    method: &str,
    task: GlueTask,
    seed: u64,
    cfg: &TrainCfg,
    scheme: C3aScheme,
) -> Result<RunResult> {
    let meta = ctx.manifest.model(model)?.clone();
    let backbone = ensure_pretrained(ctx, model)?;
    let train_spec = ctx
        .manifest
        .artifact(&Manifest::artifact_name(model, method, task.head(), "train"))?
        .clone();
    let eval_spec = ctx
        .manifest
        .artifact(&Manifest::artifact_name(model, method, task.head(), "eval"))?
        .clone();

    let splits = task.splits(meta.vocab, meta.seq, seed);
    let mut rng = Rng::seed(seed.wrapping_mul(0x51ed) ^ 0xC3A);
    let init = build_init(&train_spec, &backbone, None, &mut rng, scheme)?;
    let mut session = TrainSession::new(&ctx.engine, &train_spec, &init)?;
    let eval_session = EvalSession::new(&ctx.engine, &eval_spec, &init)?;

    let (b, s) = (train_spec.batch, train_spec.seq);
    let mut it = BatchIter::new(splits.train.len(), b, seed ^ 0xBA7C);
    let train_ds = splits.train.clone();
    let val = splits.val.clone();
    let outcome = Trainer::new(cfg.clone()).run(
        &mut session,
        |_| train_ds.batch(&it.next_batch(), b, s),
        |t| evals::eval_glue(&eval_session, t, &val, task),
    )?;
    let test = evals::eval_glue(&eval_session, &outcome.best_trainable, &splits.test, task)?;
    Ok(finish(outcome, test, train_spec.n_params))
}

/// Fine-tune a decoder on one instruction-sim MC task.
pub fn mc_run(
    ctx: &Ctx,
    model: &str,
    method: &str,
    task: McTask,
    seed: u64,
    cfg: &TrainCfg,
    n_train: usize,
) -> Result<RunResult> {
    let meta = ctx.manifest.model(model)?.clone();
    let backbone = ensure_pretrained(ctx, model)?;
    let train_spec =
        ctx.manifest.artifact(&Manifest::artifact_name(model, method, "lm", "train"))?.clone();
    let eval_spec =
        ctx.manifest.artifact(&Manifest::artifact_name(model, method, "lm", "eval"))?.clone();
    let splits = instr_sim::splits(task, meta.vocab, meta.seq, seed, n_train);
    let mut rng = Rng::seed(seed.wrapping_mul(0x51ed) ^ 0x3C);
    let init = build_init(&train_spec, &backbone, None, &mut rng, C3aScheme::Xavier)?;
    let mut session = TrainSession::new(&ctx.engine, &train_spec, &init)?;
    let eval_session = EvalSession::new(&ctx.engine, &eval_spec, &init)?;

    let (b, s) = (train_spec.batch, train_spec.seq);
    let mut it = BatchIter::new(splits.train.len(), b, seed ^ 0xBA7C);
    let train_ds = splits.train.clone();
    let val = splits.val.clone();
    let outcome = Trainer::new(cfg.clone()).run(
        &mut session,
        |_| train_ds.batch(&it.next_batch(), b, s),
        |t| evals::eval_mc(&eval_session, t, &val),
    )?;
    let test = evals::eval_mc(&eval_session, &outcome.best_trainable, &splits.test)?;
    Ok(finish(outcome, test, train_spec.n_params))
}

/// Fine-tune a decoder on a generation task (math/code-sim, exact match).
pub fn gen_run(
    ctx: &Ctx,
    model: &str,
    method: &str,
    task: GenTask,
    seed: u64,
    cfg: &TrainCfg,
    n_train: usize,
) -> Result<RunResult> {
    let backbone = ensure_pretrained(ctx, model)?;
    let train_spec =
        ctx.manifest.artifact(&Manifest::artifact_name(model, method, "lm", "train"))?.clone();
    let eval_spec =
        ctx.manifest.artifact(&Manifest::artifact_name(model, method, "lm", "eval"))?.clone();
    let splits = gen_sim::splits(task, seed, n_train);
    let mut rng = Rng::seed(seed.wrapping_mul(0x51ed) ^ 0x93);
    let init = build_init(&train_spec, &backbone, None, &mut rng, C3aScheme::Xavier)?;
    let mut session = TrainSession::new(&ctx.engine, &train_spec, &init)?;
    let eval_session = EvalSession::new(&ctx.engine, &eval_spec, &init)?;

    let (b, s) = (train_spec.batch, train_spec.seq);
    let mut it = BatchIter::new(splits.train.len(), b, seed ^ 0xBA7C);
    let train_ds = splits.train.clone();
    let val = splits.val.clone();
    let outcome = Trainer::new(cfg.clone()).run(
        &mut session,
        |_| train_ds.batch(&it.next_batch(), b, s),
        |t| evals::eval_gen(&eval_session, t, &val),
    )?;
    let test = evals::eval_gen(&eval_session, &outcome.best_trainable, &splits.test)?;
    Ok(finish(outcome, test, train_spec.n_params))
}

/// Fine-tune a vit-sim encoder on one vision task.
pub fn vision_run(
    ctx: &Ctx,
    model: &str,
    method: &str,
    task: VisionTask,
    seed: u64,
    cfg: &TrainCfg,
) -> Result<RunResult> {
    let meta = ctx.manifest.model(model)?.clone();
    let backbone = ensure_pretrained(ctx, model)?;
    let train_spec =
        ctx.manifest.artifact(&Manifest::artifact_name(model, method, "vec", "train"))?.clone();
    let eval_spec =
        ctx.manifest.artifact(&Manifest::artifact_name(model, method, "vec", "eval"))?.clone();
    let splits = vision_sim::splits(task, meta.seq, 16, seed, 2048);
    let mut rng = Rng::seed(seed.wrapping_mul(0x51ed) ^ 0x71);
    let init = build_init(&train_spec, &backbone, None, &mut rng, C3aScheme::Xavier)?;
    let mut session = TrainSession::new(&ctx.engine, &train_spec, &init)?;
    let eval_session = EvalSession::new(&ctx.engine, &eval_spec, &init)?;

    let b = train_spec.batch;
    let mut it = BatchIter::new(splits.train.len(), b, seed ^ 0xBA7C);
    let train_ds = splits.train.clone();
    let val = splits.val.clone();
    let outcome = Trainer::new(cfg.clone()).run(
        &mut session,
        |_| train_ds.batch(&it.next_batch(), b),
        |t| evals::eval_vision(&eval_session, t, &val),
    )?;
    let test = evals::eval_vision(&eval_session, &outcome.best_trainable, &splits.test)?;
    Ok(finish(outcome, test, train_spec.n_params))
}

/// Fig-4 expressiveness run: train an MLP variant on the cluster data from
/// scratch; returns (losses, final train accuracy).
pub fn mlp_run(ctx: &Ctx, variant: &str, seed: u64, cfg: &TrainCfg) -> Result<RunResult> {
    let train_spec = ctx
        .manifest
        .artifact(&Manifest::artifact_name("mlp", variant, "cls", "train"))?
        .clone();
    let eval_spec = ctx
        .manifest
        .artifact(&Manifest::artifact_name("mlp", variant, "cls", "eval"))?
        .clone();
    let init_map = ctx.manifest.init_params("mlp")?;
    let mut rng = Rng::seed(seed.wrapping_mul(0x51ed) ^ 0xF16);
    let init = build_init(&train_spec, &init_map, None, &mut rng, C3aScheme::Xavier)?;
    let mut session = TrainSession::new(&ctx.engine, &train_spec, &init)?;
    let eval_session = EvalSession::new(&ctx.engine, &eval_spec, &init)?;

    let data = clusters::generate(seed);
    let b = train_spec.batch;
    let data2 = data.clone();
    let mut pos = 0usize;
    let outcome = Trainer::new(cfg.clone()).run(
        &mut session,
        |_| {
            let batch = data2.batch(pos, b);
            pos = (pos + b) % data2.len();
            batch
        },
        |t| {
            // train-set accuracy (the paper's Fig. 4 shows training curves)
            let mut correct = 0usize;
            let mut i = 0;
            while i < data.len() {
                let mut bt = data.batch(i, b);
                bt.truncate(1); // eval artifact takes x only
                let (logits, shape) = eval_session.logits(t, &bt)?;
                let w = shape[1];
                for slot in 0..b.min(data.len() - i) {
                    let pred = crate::substrate::linalg::argmax(&logits[slot * w..(slot + 1) * w]);
                    if pred == data.y[(i + slot) % data.len()] {
                        correct += 1;
                    }
                }
                i += b;
            }
            Ok(correct as f64 / data.len() as f64)
        },
    )?;
    let final_acc = outcome.best_metric;
    let n_params = train_spec.n_params;
    Ok(finish(outcome, final_acc, n_params))
}

/// Map a method name to the TrainCfg LR the paper's appendix would use.
/// (The paper sweeps per task; we use per-method defaults found stable.)
pub fn default_lr(method: &str) -> f64 {
    match method {
        "full" => 1e-3,
        "head" => 5e-3,
        "bitfit" => 5e-3,
        "ia3" => 1e-2,
        "lora" | "dora" => 5e-3,
        "vera" => 1e-2,
        "boft" => 5e-3,
        m if m.starts_with("c3a") => 5e-2, // paper: C3A uses ~10-100x LoRA's LR
        m if m.starts_with("mlp_") => 1e-2,
        _ => 5e-3,
    }
}

pub fn default_cfg(method: &str, steps: usize) -> TrainCfg {
    TrainCfg {
        steps,
        lr: default_lr(method),
        weight_decay: 0.01,
        schedule: Schedule::LinearWarmup { warmup_frac: 0.06 },
        eval_every: (steps / 5).max(25),
        patience: 0,
        verbose: false,
    }
}
