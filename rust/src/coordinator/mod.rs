//! L3 coordinator: the training/evaluation orchestrator that drives the
//! AOT artifacts through PJRT.  Python never runs here.

pub mod checkpoint;
pub mod evals;
pub mod lr;
pub mod run;
pub mod trainer;

pub use trainer::{TrainCfg, TrainOutcome, Trainer};
