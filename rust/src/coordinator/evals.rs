//! Task-family evaluation drivers: batch the test split through an
//! EvalSession and compute the paper's metric on the host.

use crate::data::gen_sim::GenDataset;
use crate::data::glue_sim::GlueTask;
use crate::data::instr_sim::{McDataset, OPT0};
use crate::data::vision_sim::VisionDataset;
use crate::data::{ClsDataset, PAD};
use crate::metrics;
use crate::runtime::session::EvalSession;
use crate::substrate::tensor::{Tensor, TensorMap};
use anyhow::Result;

/// Evaluate an encoder classification/regression dataset; returns the
/// task's paper metric (acc / MCC / PCC).
pub fn eval_glue(
    session: &EvalSession,
    trainable: &TensorMap,
    ds: &ClsDataset,
    task: GlueTask,
) -> Result<f64> {
    let b = session.spec().batch;
    let s = session.spec().seq;
    let n = ds.len();
    let mut preds_c = Vec::with_capacity(n);
    let mut preds_r = Vec::with_capacity(n);
    let mut start = 0;
    while start < n {
        let idx: Vec<usize> = (start..(start + b).min(n)).collect();
        let count = idx.len();
        let batch = ds.eval_batch(&idx, b, s);
        let (logits, shape) = session.logits(trainable, &batch)?;
        let width = shape[1];
        for slot in 0..count {
            let row = &logits[slot * width..(slot + 1) * width];
            if task.is_regression() {
                preds_r.push(row[0] as f64);
            } else {
                preds_c.push(crate::substrate::linalg::argmax(row));
            }
        }
        start += b;
    }
    let golds: Vec<usize> = ds.labels.iter().map(|&v| v as usize).collect();
    Ok(match task {
        GlueTask::Cola => metrics::mcc(&preds_c, &golds),
        GlueTask::Stsb => {
            let gold_f: Vec<f64> = ds.labels.iter().map(|&v| v as f64).collect();
            metrics::pearson(&preds_r, &gold_f)
        }
        _ => metrics::accuracy(&preds_c, &golds),
    })
}

/// Multiple-choice accuracy: score option-token logits at the answer slot.
pub fn eval_mc(session: &EvalSession, trainable: &TensorMap, ds: &McDataset) -> Result<f64> {
    let b = session.spec().batch;
    let s = session.spec().seq;
    let n = ds.len();
    let mut correct = 0usize;
    let mut start = 0;
    while start < n {
        let idx: Vec<usize> = (start..(start + b).min(n)).collect();
        let count = idx.len();
        let batch = ds.eval_batch(&idx, b, s);
        let (logits, shape) = session.logits(trainable, &batch)?;
        let (seq_len, vocab) = (shape[1], shape[2]);
        for (slot, &i) in idx.iter().enumerate().take(count) {
            let ex = &ds.examples[i];
            if ex.answer_pos == 0 || ex.answer_pos > seq_len {
                continue;
            }
            // logits at the position predicting the answer token
            let pos = ex.answer_pos - 1;
            let row = &logits[(slot * seq_len + pos) * vocab..(slot * seq_len + pos + 1) * vocab];
            let mut best = (f32::NEG_INFINITY, 0usize);
            for o in 0..ex.n_options {
                let v = row[(OPT0 as usize) + o];
                if v > best.0 {
                    best = (v, o);
                }
            }
            if best.1 == ex.gold {
                correct += 1;
            }
        }
        start += b;
    }
    Ok(correct as f64 / n as f64)
}

/// Greedy-decode exact match (math/code-sim Pass@1).  Decodes exactly
/// `answer.len()` tokens per example by iterative forward passes.
pub fn eval_gen(session: &EvalSession, trainable: &TensorMap, ds: &GenDataset) -> Result<f64> {
    let b = session.spec().batch;
    let s = session.spec().seq;
    let n = ds.len();
    let mut preds = Vec::with_capacity(n);
    let mut golds = Vec::with_capacity(n);
    let mut start = 0;
    while start < n {
        let idx: Vec<usize> = (start..(start + b).min(n)).collect();
        let count = idx.len();
        // working token buffer seeded with prompts only
        let mut toks = vec![PAD; b * s];
        let mut cursor = vec![0usize; b]; // next position to fill
        let mut remaining = vec![0usize; b];
        for (slot, &i) in idx.iter().enumerate() {
            let ex = &ds.examples[i];
            let np = ex.prompt.len().min(s);
            toks[slot * s..slot * s + np].copy_from_slice(&ex.prompt[..np]);
            cursor[slot] = np;
            remaining[slot] = ex.answer.len().min(s - np);
        }
        let max_steps = remaining.iter().copied().max().unwrap_or(0);
        let mut decoded: Vec<Vec<i32>> = vec![Vec::new(); b];
        for _ in 0..max_steps {
            let batch = vec![Tensor::from_i32(vec![b, s], &toks)];
            let (logits, shape) = session.logits(trainable, &batch)?;
            let (seq_len, vocab) = (shape[1], shape[2]);
            for slot in 0..count {
                if remaining[slot] == 0 {
                    continue;
                }
                let pos = cursor[slot] - 1; // predict token at cursor from pos
                let row =
                    &logits[(slot * seq_len + pos) * vocab..(slot * seq_len + pos + 1) * vocab];
                // never emit PAD/CLS: restrict to ids >= 4
                let mut best = (f32::NEG_INFINITY, 4usize);
                for (t, &v) in row.iter().enumerate().skip(4) {
                    if v > best.0 {
                        best = (v, t);
                    }
                }
                toks[slot * s + cursor[slot]] = best.1 as i32;
                decoded[slot].push(best.1 as i32);
                cursor[slot] += 1;
                remaining[slot] -= 1;
            }
        }
        for (slot, &i) in idx.iter().enumerate().take(count) {
            preds.push(decoded[slot].clone());
            golds.push(ds.examples[i].answer.clone());
        }
        start += b;
    }
    Ok(metrics::exact_match(&preds, &golds))
}

/// Vision-sim accuracy.
pub fn eval_vision(
    session: &EvalSession,
    trainable: &TensorMap,
    ds: &VisionDataset,
) -> Result<f64> {
    let b = session.spec().batch;
    let n = ds.len();
    let mut preds = Vec::with_capacity(n);
    let mut start = 0;
    while start < n {
        let idx: Vec<usize> = (start..(start + b).min(n)).collect();
        let count = idx.len();
        let batch = ds.eval_batch(&idx, b);
        let (logits, shape) = session.logits(trainable, &batch)?;
        let width = shape[1];
        for slot in 0..count {
            preds.push(crate::substrate::linalg::argmax(
                &logits[slot * width..slot * width + ds.n_classes],
            ));
        }
        start += b;
    }
    Ok(metrics::accuracy(&preds, &ds.labels))
}
