//! Checkpoint I/O: pretrained backbones and adapter snapshots, in the
//! C3AT container (substrate::tensor).  Atomic writes; versioned names.

use crate::substrate::tensor::{self, TensorMap};
use anyhow::Result;
use std::path::{Path, PathBuf};

/// Where a model's pretrained backbone checkpoint lives.
pub fn pretrained_path(artifacts_dir: &Path, model: &str) -> PathBuf {
    artifacts_dir.join(format!("{model}_pretrained.bin"))
}

pub fn save(path: &Path, tensors: &TensorMap) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    tensor::save(path, tensors)
}

pub fn load(path: &Path) -> Result<TensorMap> {
    tensor::load(path)
}

/// Load the pretrained backbone for `model`, falling back to the python
/// init bin when no pretraining run has happened yet.
pub fn load_backbone(
    artifacts_dir: &Path,
    model: &str,
    init_path: &Path,
) -> Result<(TensorMap, bool)> {
    let pre = pretrained_path(artifacts_dir, model);
    if pre.exists() {
        Ok((load(&pre)?, true))
    } else {
        Ok((load(init_path)?, false))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::tensor::Tensor;

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("c3a_ckpt_test");
        let p = dir.join("x.bin");
        let mut m = TensorMap::new();
        m.insert("w".into(), Tensor::from_f32(vec![2], &[1.0, 2.0]));
        save(&p, &m).unwrap();
        let back = load(&p).unwrap();
        assert_eq!(back["w"].as_f32(), vec![1.0, 2.0]);
    }

    #[test]
    fn pretrained_path_convention() {
        let p = pretrained_path(Path::new("artifacts"), "enc_base");
        assert_eq!(p, Path::new("artifacts/enc_base_pretrained.bin"));
    }
}
