//! Learning-rate schedules (computed host-side; the scalar is a step input
//! to the AOT train step, so one artifact serves every schedule).

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Schedule {
    Constant,
    /// linear warmup then linear decay to zero (paper's GLUE setup)
    LinearWarmup { warmup_frac: f64 },
    /// linear warmup then cosine decay (paper's instruction setup)
    Cosine { warmup_frac: f64 },
}

impl Schedule {
    pub fn parse(s: &str, warmup_frac: f64) -> Option<Schedule> {
        Some(match s {
            "constant" | "none" => Schedule::Constant,
            "linear" => Schedule::LinearWarmup { warmup_frac },
            "cosine" => Schedule::Cosine { warmup_frac },
            _ => return None,
        })
    }

    /// LR multiplier at `step` (0-based) of `total` steps.
    pub fn factor(&self, step: usize, total: usize) -> f64 {
        let total = total.max(1);
        let t = step as f64 / total as f64;
        match *self {
            Schedule::Constant => 1.0,
            Schedule::LinearWarmup { warmup_frac } => {
                if t < warmup_frac {
                    (t / warmup_frac.max(1e-9)).min(1.0)
                } else {
                    ((1.0 - t) / (1.0 - warmup_frac).max(1e-9)).max(0.0)
                }
            }
            Schedule::Cosine { warmup_frac } => {
                if t < warmup_frac {
                    (t / warmup_frac.max(1e-9)).min(1.0)
                } else {
                    let u = (t - warmup_frac) / (1.0 - warmup_frac).max(1e-9);
                    0.5 * (1.0 + (std::f64::consts::PI * u).cos())
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_one() {
        assert_eq!(Schedule::Constant.factor(5, 100), 1.0);
    }

    #[test]
    fn linear_warms_and_decays() {
        let s = Schedule::LinearWarmup { warmup_frac: 0.1 };
        assert!(s.factor(0, 100) < 0.05);
        assert!((s.factor(10, 100) - 1.0).abs() < 0.01);
        assert!(s.factor(99, 100) < 0.05);
        // monotone decay after warmup
        let mut prev = s.factor(10, 100);
        for step in 11..100 {
            let f = s.factor(step, 100);
            assert!(f <= prev + 1e-12);
            prev = f;
        }
    }

    #[test]
    fn cosine_ends_near_zero() {
        let s = Schedule::Cosine { warmup_frac: 0.05 };
        assert!(s.factor(99, 100) < 0.01);
        assert!((s.factor(5, 100) - 1.0).abs() < 0.02);
    }

    #[test]
    fn factors_bounded() {
        for sched in [
            Schedule::Constant,
            Schedule::LinearWarmup { warmup_frac: 0.06 },
            Schedule::Cosine { warmup_frac: 0.03 },
        ] {
            for step in 0..200 {
                let f = sched.factor(step, 200);
                assert!((0.0..=1.0 + 1e-12).contains(&f));
            }
        }
    }
}
