//! Learning-rate schedules (computed host-side; the scalar is a step input
//! to the AOT train step, so one artifact serves every schedule).

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Schedule {
    Constant,
    /// linear warmup then linear decay to zero (paper's GLUE setup)
    LinearWarmup { warmup_frac: f64 },
    /// linear warmup then cosine decay (paper's instruction setup)
    Cosine { warmup_frac: f64 },
}

impl Schedule {
    pub fn parse(s: &str, warmup_frac: f64) -> Option<Schedule> {
        Some(match s {
            "constant" | "none" => Schedule::Constant,
            "linear" => Schedule::LinearWarmup { warmup_frac },
            "cosine" => Schedule::Cosine { warmup_frac },
            _ => return None,
        })
    }

    /// LR multiplier at `step` (0-based) of `total` steps.
    ///
    /// Warmup ramps on `(step + 1) / warmup_steps` (the HF convention):
    /// the old `step / warmup_steps` form made `factor(0, n) == 0`, so the
    /// first optimizer step of every warmup run was a wasted lr=0 step —
    /// worst for short GLUE runs where it was a visible fraction of the
    /// budget.
    pub fn factor(&self, step: usize, total: usize) -> f64 {
        let total = total.max(1);
        let t = step as f64 / total as f64;
        let warmup = |warmup_frac: f64| -> Option<f64> {
            let warm_steps = warmup_frac * total as f64;
            if (step as f64) < warm_steps {
                Some(((step as f64 + 1.0) / warm_steps.max(1e-9)).min(1.0))
            } else {
                None
            }
        };
        match *self {
            Schedule::Constant => 1.0,
            Schedule::LinearWarmup { warmup_frac } => match warmup(warmup_frac) {
                Some(f) => f,
                None => ((1.0 - t) / (1.0 - warmup_frac).max(1e-9)).max(0.0),
            },
            Schedule::Cosine { warmup_frac } => match warmup(warmup_frac) {
                Some(f) => f,
                None => {
                    let u = (t - warmup_frac) / (1.0 - warmup_frac).max(1e-9);
                    0.5 * (1.0 + (std::f64::consts::PI * u).cos())
                }
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_one() {
        assert_eq!(Schedule::Constant.factor(5, 100), 1.0);
    }

    #[test]
    fn linear_warms_and_decays() {
        let s = Schedule::LinearWarmup { warmup_frac: 0.1 };
        assert!((s.factor(0, 100) - 0.1).abs() < 1e-12); // (0+1)/10 warmup steps
        assert!((s.factor(9, 100) - 1.0).abs() < 1e-12); // end of warmup
        assert!((s.factor(10, 100) - 1.0).abs() < 0.01);
        assert!(s.factor(99, 100) < 0.05);
        // monotone ramp through warmup
        let mut prev = 0.0;
        for step in 0..10 {
            let f = s.factor(step, 100);
            assert!(f > prev);
            prev = f;
        }
        // monotone decay after warmup
        let mut prev = s.factor(10, 100);
        for step in 11..100 {
            let f = s.factor(step, 100);
            assert!(f <= prev + 1e-12);
            prev = f;
        }
    }

    #[test]
    fn first_step_is_never_zero_lr() {
        // regression: warmup schedules used to return 0.0 at step 0,
        // wasting the first optimizer step of every run
        for sched in [
            Schedule::Constant,
            Schedule::LinearWarmup { warmup_frac: 0.06 },
            Schedule::LinearWarmup { warmup_frac: 0.5 },
            Schedule::Cosine { warmup_frac: 0.05 },
            Schedule::Cosine { warmup_frac: 0.0 },
        ] {
            for total in [1usize, 2, 10, 100, 10_000] {
                let f = sched.factor(0, total);
                assert!(f > 0.0, "{sched:?} factor(0, {total}) = {f}");
                assert!(f <= 1.0 + 1e-12);
            }
        }
    }

    #[test]
    fn cosine_ends_near_zero() {
        let s = Schedule::Cosine { warmup_frac: 0.05 };
        assert!(s.factor(99, 100) < 0.01);
        assert!((s.factor(5, 100) - 1.0).abs() < 0.02);
    }

    #[test]
    fn factors_bounded() {
        for sched in [
            Schedule::Constant,
            Schedule::LinearWarmup { warmup_frac: 0.06 },
            Schedule::Cosine { warmup_frac: 0.03 },
        ] {
            for step in 0..200 {
                let f = sched.factor(step, 200);
                assert!((0.0..=1.0 + 1e-12).contains(&f));
            }
        }
    }
}
