//! Evaluation metrics matching the paper's reporting: accuracy, F1,
//! Matthews correlation (CoLA), Pearson/Spearman (STS-B), exact match
//! (math/code-sim Pass@1).

/// Classification accuracy from predictions and gold labels.
pub fn accuracy(pred: &[usize], gold: &[usize]) -> f64 {
    assert_eq!(pred.len(), gold.len());
    if pred.is_empty() {
        return 0.0;
    }
    pred.iter().zip(gold).filter(|(p, g)| p == g).count() as f64 / pred.len() as f64
}

/// Binary F1 (positive class = 1).
pub fn f1_binary(pred: &[usize], gold: &[usize]) -> f64 {
    let mut tp = 0.0;
    let mut fp = 0.0;
    let mut fn_ = 0.0;
    for (&p, &g) in pred.iter().zip(gold) {
        match (p, g) {
            (1, 1) => tp += 1.0,
            (1, 0) => fp += 1.0,
            (0, 1) => fn_ += 1.0,
            _ => {}
        }
    }
    if tp == 0.0 {
        return 0.0;
    }
    let prec = tp / (tp + fp);
    let rec = tp / (tp + fn_);
    2.0 * prec * rec / (prec + rec)
}

/// Matthews correlation coefficient for binary labels (the paper's CoLA metric).
pub fn mcc(pred: &[usize], gold: &[usize]) -> f64 {
    let (mut tp, mut tn, mut fp, mut fn_) = (0f64, 0f64, 0f64, 0f64);
    for (&p, &g) in pred.iter().zip(gold) {
        match (p, g) {
            (1, 1) => tp += 1.0,
            (0, 0) => tn += 1.0,
            (1, 0) => fp += 1.0,
            (0, 1) => fn_ += 1.0,
            _ => panic!("mcc: labels must be 0/1"),
        }
    }
    let denom = ((tp + fp) * (tp + fn_) * (tn + fp) * (tn + fn_)).sqrt();
    if denom == 0.0 {
        0.0
    } else {
        (tp * tn - fp * fn_) / denom
    }
}

/// Pearson correlation coefficient (the paper's STS-B metric).
pub fn pearson(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len() as f64;
    if n < 2.0 {
        return 0.0;
    }
    let ma = a.iter().sum::<f64>() / n;
    let mb = b.iter().sum::<f64>() / n;
    let mut cov = 0.0;
    let mut va = 0.0;
    let mut vb = 0.0;
    for (&x, &y) in a.iter().zip(b) {
        cov += (x - ma) * (y - mb);
        va += (x - ma) * (x - ma);
        vb += (y - mb) * (y - mb);
    }
    if va == 0.0 || vb == 0.0 {
        0.0
    } else {
        cov / (va.sqrt() * vb.sqrt())
    }
}

/// Spearman rank correlation = Pearson of the rank transforms
/// (average ranks for ties).
pub fn spearman(a: &[f64], b: &[f64]) -> f64 {
    pearson(&ranks(a), &ranks(b))
}

fn ranks(xs: &[f64]) -> Vec<f64> {
    let n = xs.len();
    let mut idx: Vec<usize> = (0..n).collect();
    // total_cmp: NaNs sort after +inf instead of panicking — a diverged
    // run reports a (meaningless but finite) correlation instead of
    // crashing the whole experiment sweep inside Spearman.
    idx.sort_by(|&i, &j| xs[i].total_cmp(&xs[j]));
    let mut r = vec![0.0; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            r[idx[k]] = avg;
        }
        i = j + 1;
    }
    r
}

/// Exact-match rate over token sequences (math/code-sim Pass@1).
pub fn exact_match(pred: &[Vec<i32>], gold: &[Vec<i32>]) -> f64 {
    assert_eq!(pred.len(), gold.len());
    if pred.is_empty() {
        return 0.0;
    }
    pred.iter().zip(gold).filter(|(p, g)| p == g).count() as f64 / pred.len() as f64
}

/// Running mean/std accumulator (reported as mean ± std over seeds).
#[derive(Clone, Debug, Default)]
pub struct Stats {
    pub values: Vec<f64>,
}

impl Stats {
    pub fn push(&mut self, v: f64) {
        self.values.push(v);
    }

    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            return 0.0;
        }
        self.values.iter().sum::<f64>() / self.values.len() as f64
    }

    pub fn std(&self) -> f64 {
        let n = self.values.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.values.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / (n - 1) as f64).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_basic() {
        assert_eq!(accuracy(&[1, 0, 1], &[1, 1, 1]), 2.0 / 3.0);
        assert_eq!(accuracy(&[], &[]), 0.0);
    }

    #[test]
    fn f1_known_case() {
        // tp=2 fp=1 fn=1 -> p=2/3 r=2/3 f1=2/3
        let pred = [1, 1, 1, 0, 0];
        let gold = [1, 1, 0, 1, 0];
        assert!((f1_binary(&pred, &gold) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn mcc_perfect_and_inverse() {
        assert!((mcc(&[1, 0, 1, 0], &[1, 0, 1, 0]) - 1.0).abs() < 1e-12);
        assert!((mcc(&[0, 1, 0, 1], &[1, 0, 1, 0]) + 1.0).abs() < 1e-12);
        assert_eq!(mcc(&[1, 1, 1, 1], &[1, 0, 1, 0]), 0.0);
    }

    #[test]
    fn pearson_linear_and_anticorrelated() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&a, &b) - 1.0).abs() < 1e-12);
        let c = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&a, &c) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_monotone_nonlinear() {
        let a = [1.0, 2.0, 3.0, 4.0, 5.0];
        let b = [1.0, 8.0, 27.0, 64.0, 125.0]; // monotone, nonlinear
        assert!((spearman(&a, &b) - 1.0).abs() < 1e-12);
        assert!(pearson(&a, &b) < 1.0);
    }

    #[test]
    fn spearman_handles_ties() {
        let a = [1.0, 2.0, 2.0, 3.0];
        let b = [1.0, 2.0, 2.0, 3.0];
        assert!((spearman(&a, &b) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_survives_nan_predictions() {
        // regression: a diverged run's NaN predictions used to panic in
        // `ranks` (`partial_cmp().unwrap()`); they must instead produce a
        // finite (if meaningless) score so the sweep keeps going
        let pred = [0.3, f64::NAN, 0.7, f64::NAN, 0.1];
        let gold = [0.2, 0.9, 0.8, 0.4, 0.0];
        let r = spearman(&pred, &gold);
        assert!(r.is_finite(), "spearman with NaN input returned {r}");
        assert!((-1.0..=1.0).contains(&r));
        // all-NaN predictions degrade to a tie-everything ranking
        let all_nan = [f64::NAN; 5];
        assert!(spearman(&all_nan, &gold).is_finite());
    }

    #[test]
    fn exact_match_counts() {
        let p = vec![vec![1, 2], vec![3]];
        let g = vec![vec![1, 2], vec![4]];
        assert_eq!(exact_match(&p, &g), 0.5);
    }

    #[test]
    fn stats_mean_std() {
        let mut s = Stats::default();
        for v in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(v);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.std() - 2.138089935299395).abs() < 1e-9);
    }
}
