//! Disk tier of the adapter lifecycle: a versioned binary store holding
//! one serialized adapter snapshot per tenant.
//!
//! This is the source of truth for evicted tenants — the registry drops
//! their session/arena/params entirely and reloads from here on the next
//! request (the measured cold-start path).  Three properties carry the
//! serving contract onto disk:
//!
//! * **Bitwise round-trips.** Payloads are written as the tensor's raw
//!   little-endian words ([`Tensor::bits`]), so `load(save(m)) == m`
//!   bit-for-bit — which is what makes evict→reload logits bit-identical
//!   (spectra and plans are deterministic functions of the kernel bits).
//! * **Fail-closed loads.** Every file ends in an FNV-1a checksum over
//!   the full preceding contents; a flipped bit or truncated file makes
//!   `load` fail with an error naming the tenant — a corrupt snapshot is
//!   never served.
//! * **Crash-safe writes.** `save` writes a temp file in the same dir and
//!   renames it over the target, so a crash mid-write leaves either the
//!   old complete snapshot or a stray `.tmp` — never a torn file under
//!   the tenant's name.
//!
//! One file per tenant (name percent-escaped into the filename) means
//! shard workers sharing one store dir can never collide: tenant→shard
//! routing is a partition, so no two shards ever write the same tenant.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! magic "C3AS" | format u32 | adapter_version u64 | count u32
//! repeat count: name_len u32 | name | dtype u8 | ndim u32 | dims u64… | payload u32…
//! fnv1a-of-everything-above u64
//! ```

use crate::substrate::prng::fnv1a_bytes;
use crate::substrate::tensor::{DType, Tensor, TensorMap};
use anyhow::{bail, Context, Result};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::time::{Duration, SystemTime};

const STORE_MAGIC: &[u8; 4] = b"C3AS";
const STORE_FORMAT: u32 = 1;
/// magic + format + version + count + trailing checksum
const HEADER_BYTES: usize = 4 + 4 + 8 + 4;
const CHECKSUM_BYTES: usize = 8;

/// Percent-escape a tenant name into a filesystem-safe, injective
/// filename stem (`/`, `%`, and anything non-alphanumeric beyond `._-`
/// become `%XX`).
fn escape_tenant(name: &str) -> String {
    let mut out = String::with_capacity(name.len());
    for b in name.bytes() {
        match b {
            b'A'..=b'Z' | b'a'..=b'z' | b'0'..=b'9' | b'.' | b'_' | b'-' => out.push(b as char),
            _ => out.push_str(&format!("%{b:02X}")),
        }
    }
    out
}

/// A directory of per-tenant adapter snapshots.  Cheap to clone the
/// handle conceptually (it is just a path); every operation is stateless
/// against the filesystem.
#[derive(Clone, Debug)]
pub struct AdapterStore {
    dir: PathBuf,
}

impl AdapterStore {
    /// Open (creating if needed) a store rooted at `dir`.
    pub fn open<P: Into<PathBuf>>(dir: P) -> Result<AdapterStore> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("adapter store: creating {}", dir.display()))?;
        Ok(AdapterStore { dir })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// The snapshot file a tenant serializes to.
    pub fn path_for(&self, tenant: &str) -> PathBuf {
        self.dir.join(format!("{}.c3aa", escape_tenant(tenant)))
    }

    pub fn contains(&self, tenant: &str) -> bool {
        self.path_for(tenant).exists()
    }

    /// Persist `tenant`'s adapter at `version` (temp file + rename; see
    /// the module docs for the crash-safety contract).
    pub fn save(&self, tenant: &str, version: u64, params: &TensorMap) -> Result<()> {
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(STORE_MAGIC);
        buf.extend_from_slice(&STORE_FORMAT.to_le_bytes());
        buf.extend_from_slice(&version.to_le_bytes());
        buf.extend_from_slice(&(params.len() as u32).to_le_bytes());
        for (name, t) in params {
            let nb = name.as_bytes();
            buf.extend_from_slice(&(nb.len() as u32).to_le_bytes());
            buf.extend_from_slice(nb);
            buf.push(t.dtype.code());
            buf.extend_from_slice(&(t.shape.len() as u32).to_le_bytes());
            for &d in &t.shape {
                buf.extend_from_slice(&(d as u64).to_le_bytes());
            }
            for &w in t.bits() {
                buf.extend_from_slice(&w.to_le_bytes());
            }
        }
        let checksum = fnv1a_bytes(&buf);
        buf.extend_from_slice(&checksum.to_le_bytes());
        let path = self.path_for(tenant);
        let tmp = path.with_extension("tmp");
        let write = || -> std::io::Result<()> {
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(&buf)?;
            f.sync_all()?;
            std::fs::rename(&tmp, &path)
        };
        write().with_context(|| {
            format!("adapter store: persisting tenant {tenant} to {}", path.display())
        })
    }

    /// Load `tenant`'s snapshot; returns the bitwise-identical map and
    /// the adapter version it was persisted at.  Fails closed (naming the
    /// tenant) on missing files, bad magic, truncation, or a checksum
    /// mismatch.
    pub fn load(&self, tenant: &str) -> Result<(TensorMap, u64)> {
        let path = self.path_for(tenant);
        let bytes = std::fs::read(&path).with_context(|| {
            format!("adapter store: no snapshot for tenant {tenant} at {}", path.display())
        })?;
        if bytes.len() < HEADER_BYTES + CHECKSUM_BYTES {
            bail!("adapter store: tenant {tenant}: truncated snapshot ({} bytes)", bytes.len());
        }
        let (body, tail) = bytes.split_at(bytes.len() - CHECKSUM_BYTES);
        let expect = u64::from_le_bytes(tail.try_into().unwrap());
        let got = fnv1a_bytes(body);
        if got != expect {
            bail!(
                "adapter store: tenant {tenant}: checksum mismatch \
                 (stored {expect:016x}, computed {got:016x}) — refusing to serve"
            );
        }
        let mut pos = 0usize;
        let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
            if *pos + n > body.len() {
                bail!("adapter store: tenant {tenant}: truncated snapshot body");
            }
            let s = &body[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        if take(&mut pos, 4)? != STORE_MAGIC {
            bail!("adapter store: tenant {tenant}: bad magic");
        }
        let format = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
        if format != STORE_FORMAT {
            bail!("adapter store: tenant {tenant}: unsupported format {format}");
        }
        let version = u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap());
        let count = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
        let mut out = TensorMap::new();
        for _ in 0..count {
            let nlen = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
            let name = String::from_utf8(take(&mut pos, nlen)?.to_vec())
                .with_context(|| format!("adapter store: tenant {tenant}: bad tensor name"))?;
            let dtype = DType::from_code(take(&mut pos, 1)?[0])?;
            let ndim = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap()) as usize);
            }
            let n = shape.iter().product::<usize>().max(1);
            let raw = take(&mut pos, 4 * n)?;
            let vals: Vec<u32> =
                raw.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect();
            let t = match dtype {
                DType::F32 => Tensor::from_f32(
                    shape,
                    &vals.iter().map(|&b| f32::from_bits(b)).collect::<Vec<_>>(),
                ),
                DType::I32 => Tensor::from_i32(
                    shape,
                    &vals.iter().map(|&b| b as i32).collect::<Vec<_>>(),
                ),
            };
            out.insert(name, t);
        }
        if pos != body.len() {
            bail!("adapter store: tenant {tenant}: {} trailing bytes", body.len() - pos);
        }
        Ok((out, version))
    }

    /// Minimum orphan age for [`gc`](Self::gc): long enough that any
    /// `.tmp` this old cannot be a concurrent shard's in-flight save
    /// (saves are one buffered write + rename, milliseconds at most).
    pub const GC_MIN_AGE: Duration = Duration::from_secs(60);

    /// Sweep orphaned temp files at the default age guard
    /// ([`Self::GC_MIN_AGE`]); returns how many were removed.  A crash
    /// between temp-file create and rename leaks the temp forever —
    /// nothing else ever touches it — so the registry runs this sweep
    /// when a store is installed for tiering.
    pub fn gc(&self) -> Result<usize> {
        self.gc_older_than(Self::GC_MIN_AGE)
    }

    /// Sweep `.tmp` files in the store dir whose mtime is at least `age`
    /// old.  Snapshot files (`.c3aa`) are never touched; a temp younger
    /// than `age` is presumed to be another process's in-flight save and
    /// left alone.  Losing a remove race is fine (the other sweeper won).
    pub fn gc_older_than(&self, age: Duration) -> Result<usize> {
        let now = SystemTime::now();
        let mut swept = 0usize;
        let entries = std::fs::read_dir(&self.dir)
            .with_context(|| format!("adapter store: listing {}", self.dir.display()))?;
        for entry in entries {
            let entry = entry
                .with_context(|| format!("adapter store: reading {}", self.dir.display()))?;
            let path = entry.path();
            if path.extension().and_then(|e| e.to_str()) != Some("tmp") {
                continue;
            }
            let old_enough = entry
                .metadata()
                .and_then(|m| m.modified())
                .ok()
                .and_then(|mtime| now.duration_since(mtime).ok())
                .is_some_and(|elapsed| elapsed >= age);
            if !old_enough {
                continue;
            }
            match std::fs::remove_file(&path) {
                Ok(()) => swept += 1,
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
                Err(e) => {
                    return Err(e).with_context(|| {
                        format!("adapter store: sweeping orphan {}", path.display())
                    });
                }
            }
        }
        Ok(swept)
    }

    /// Delete `tenant`'s snapshot (missing is fine).
    pub fn remove(&self, tenant: &str) -> Result<()> {
        let path = self.path_for(tenant);
        match std::fs::remove_file(&path) {
            Ok(()) => Ok(()),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(()),
            Err(e) => Err(e).with_context(|| {
                format!("adapter store: removing tenant {tenant} at {}", path.display())
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_store(tag: &str) -> AdapterStore {
        let dir = std::env::temp_dir().join(format!("c3a_store_unit_{tag}"));
        let _ = std::fs::remove_dir_all(&dir);
        AdapterStore::open(dir).unwrap()
    }

    fn sample_map() -> TensorMap {
        let mut m = TensorMap::new();
        m.insert("l0.c3a.w".into(), Tensor::from_f32(vec![2, 4], &[0.5; 8]));
        m.insert("head.b".into(), Tensor::from_f32(vec![3], &[1.0, -0.0, f32::NAN]));
        m.insert("ids".into(), Tensor::from_i32(vec![2], &[7, -7]));
        m
    }

    #[test]
    fn roundtrip_is_bitwise_and_versioned() {
        let store = tmp_store("rt");
        let m = sample_map();
        store.save("t0", 3, &m).unwrap();
        assert!(store.contains("t0"));
        let (back, version) = store.load("t0").unwrap();
        assert_eq!(version, 3);
        assert_eq!(back, m, "store round-trip must be bitwise (incl. NaN and -0.0)");
        for (name, t) in &m {
            assert_eq!(back[name].bits(), t.bits());
        }
    }

    #[test]
    fn checksum_mismatch_fails_closed_naming_the_tenant() {
        let store = tmp_store("sum");
        store.save("victim", 1, &sample_map()).unwrap();
        let path = store.path_for("victim");
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        let err = format!("{:#}", store.load("victim").unwrap_err());
        assert!(err.contains("victim"), "error must name the tenant: {err}");
        assert!(err.contains("checksum"), "error must say why: {err}");
    }

    #[test]
    fn truncated_file_fails_closed_naming_the_tenant() {
        let store = tmp_store("trunc");
        store.save("short", 1, &sample_map()).unwrap();
        let path = store.path_for("short");
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..HEADER_BYTES + 2]).unwrap();
        let err = format!("{:#}", store.load("short").unwrap_err());
        assert!(err.contains("short"), "error must name the tenant: {err}");
    }

    #[test]
    fn missing_tenant_fails_closed() {
        let store = tmp_store("missing");
        let err = format!("{:#}", store.load("ghost").unwrap_err());
        assert!(err.contains("ghost"));
    }

    #[test]
    fn tenant_names_escape_injectively() {
        assert_eq!(escape_tenant("tenant0"), "tenant0");
        assert_eq!(escape_tenant("a/b"), "a%2Fb");
        assert_eq!(escape_tenant("a%2Fb"), "a%252Fb");
        assert_ne!(escape_tenant("a/b"), escape_tenant("a%2Fb"));
        let store = tmp_store("esc");
        store.save("a/b", 1, &sample_map()).unwrap();
        store.save("a%2Fb", 2, &sample_map()).unwrap();
        assert_eq!(store.load("a/b").unwrap().1, 1);
        assert_eq!(store.load("a%2Fb").unwrap().1, 2);
    }

    #[test]
    fn remove_is_idempotent() {
        let store = tmp_store("rm");
        store.save("gone", 1, &sample_map()).unwrap();
        store.remove("gone").unwrap();
        assert!(!store.contains("gone"));
        store.remove("gone").unwrap();
    }

    #[test]
    fn gc_sweeps_orphaned_temps_and_spares_snapshots() {
        let store = tmp_store("gc");
        store.save("alive", 4, &sample_map()).unwrap();
        // plant the artifact of a crash between create and rename
        let orphan = store.path_for("crashed").with_extension("tmp");
        std::fs::write(&orphan, b"partial write before the crash").unwrap();
        // zero age guard: sweep regardless of mtime
        assert_eq!(store.gc_older_than(Duration::ZERO).unwrap(), 1);
        assert!(!orphan.exists(), "orphaned temp must be swept");
        let (back, version) = store.load("alive").unwrap();
        assert_eq!(version, 4);
        assert_eq!(back, sample_map(), "snapshots must survive the sweep bitwise");
        // nothing left to sweep
        assert_eq!(store.gc_older_than(Duration::ZERO).unwrap(), 0);
    }

    #[test]
    fn gc_age_guard_protects_fresh_temps() {
        let store = tmp_store("gc_age");
        let fresh = store.path_for("inflight").with_extension("tmp");
        std::fs::write(&fresh, b"another shard is mid-save").unwrap();
        // the default guard treats a just-written temp as in-flight
        assert_eq!(store.gc().unwrap(), 0);
        assert!(fresh.exists(), "a fresh temp must be presumed in-flight");
    }
}
