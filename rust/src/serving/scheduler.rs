//! Bounded request queue + dynamic batching over an [`AdapterRegistry`].
//!
//! Sessions are not `Send` (they hold `Rc` executor state), so the
//! scheduler owns a dedicated serving thread: the registry is *built on
//! that thread* by the closure passed to [`Scheduler::spawn`], and
//! producers talk to it through a bounded `sync_channel` — `try_submit`
//! surfaces a full queue as [`SubmitError::QueueFull`] (backpressure),
//! `submit` blocks for space.  Requests for one tenant are drained into a
//! dynamic batch of up to `max_batch`, closed early by a `max_wait`
//! deadline, a message for a different tenant, or a hot-swap (FIFO order
//! is preserved: requests submitted before a swap serve under the old
//! adapter version).

use super::registry::AdapterRegistry;
use super::stats::LatencySummary;
use crate::substrate::tensor::{Tensor, TensorMap};
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Scheduler knobs (see `rust/README.md` § Serving).
#[derive(Clone, Debug)]
pub struct SchedulerCfg {
    /// Bounded queue capacity; `try_submit` sheds load beyond it.
    pub queue_cap: usize,
    /// Dynamic batch cap; 0 means "the artifact batch size".
    pub max_batch: usize,
    /// How long a batch may wait for more same-tenant requests after its
    /// first request is dequeued.
    pub max_wait: Duration,
}

impl Default for SchedulerCfg {
    fn default() -> Self {
        SchedulerCfg { queue_cap: 256, max_batch: 0, max_wait: Duration::from_millis(2) }
    }
}

/// One served request's outcome.
#[derive(Clone, Debug)]
pub struct Reply {
    pub tenant: String,
    /// adapter version the request was served under
    pub tenant_version: u64,
    /// this request's logits row (flattened per-example chunk)
    pub logits: Vec<f32>,
    /// argmax over the logits row (class id for pooled heads)
    pub pred: usize,
    /// dynamic batch size this request was served in
    pub batch_size: usize,
    /// submit-to-reply latency
    pub latency_ms: f64,
}

/// Submission failure.
#[derive(Debug)]
pub enum SubmitError {
    /// bounded queue at capacity — shed or retry (backpressure)
    QueueFull,
    /// scheduler shut down (or its builder failed)
    Closed,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull => write!(f, "request queue is full (backpressure)"),
            SubmitError::Closed => write!(f, "scheduler is shut down"),
        }
    }
}

impl std::error::Error for SubmitError {}

struct Request {
    tenant: String,
    tokens: Vec<i32>,
    submitted: Instant,
    reply: mpsc::Sender<std::result::Result<Reply, String>>,
}

enum Msg {
    Request(Request),
    Swap {
        tenant: String,
        params: TensorMap,
        ack: mpsc::Sender<std::result::Result<u64, String>>,
    },
}

/// Receipt for a submitted request; `wait` blocks for the reply.
#[derive(Debug)]
pub struct Ticket {
    rx: mpsc::Receiver<std::result::Result<Reply, String>>,
}

impl Ticket {
    pub fn wait(self) -> Result<Reply> {
        match self.rx.recv() {
            Ok(Ok(r)) => Ok(r),
            Ok(Err(e)) => Err(anyhow!("{e}")),
            Err(_) => Err(anyhow!("scheduler dropped the request (shutdown)")),
        }
    }
}

/// Cloneable producer handle.  Drop every handle (and call
/// [`Scheduler::finish`]) to let the serving thread drain and exit.
#[derive(Clone)]
pub struct SubmitHandle {
    tx: mpsc::SyncSender<Msg>,
}

impl SubmitHandle {
    fn request(&self, tenant: &str, tokens: Vec<i32>) -> (Msg, Ticket) {
        let (rtx, rrx) = mpsc::channel();
        let req = Request {
            tenant: tenant.to_string(),
            tokens,
            submitted: Instant::now(),
            reply: rtx,
        };
        (Msg::Request(req), Ticket { rx: rrx })
    }

    /// Non-blocking submit: `Err(QueueFull)` when the bounded queue is at
    /// capacity, `Err(Closed)` after shutdown.
    pub fn try_submit(
        &self,
        tenant: &str,
        tokens: Vec<i32>,
    ) -> std::result::Result<Ticket, SubmitError> {
        let (msg, ticket) = self.request(tenant, tokens);
        match self.tx.try_send(msg) {
            Ok(()) => Ok(ticket),
            Err(mpsc::TrySendError::Full(_)) => Err(SubmitError::QueueFull),
            Err(mpsc::TrySendError::Disconnected(_)) => Err(SubmitError::Closed),
        }
    }

    /// Blocking submit: waits for queue space instead of shedding.
    pub fn submit(
        &self,
        tenant: &str,
        tokens: Vec<i32>,
    ) -> std::result::Result<Ticket, SubmitError> {
        let (msg, ticket) = self.request(tenant, tokens);
        self.tx.send(msg).map(|()| ticket).map_err(|_| SubmitError::Closed)
    }

    /// Atomically replace `tenant`'s adapter, ordered with respect to the
    /// queue: every request submitted before the swap serves under the old
    /// version.  Blocks until the serving thread acks with the new version.
    pub fn hot_swap(&self, tenant: &str, params: TensorMap) -> Result<u64> {
        let (atx, arx) = mpsc::channel();
        let msg = Msg::Swap { tenant: tenant.to_string(), params, ack: atx };
        self.tx.send(msg).map_err(|_| anyhow!("scheduler is shut down"))?;
        match arx.recv() {
            Ok(Ok(v)) => Ok(v),
            Ok(Err(e)) => Err(anyhow!("{e}")),
            Err(_) => Err(anyhow!("scheduler closed before acking hot_swap")),
        }
    }
}

/// Cap on the per-request/per-batch sample windows ([`ServeStats`]): a
/// long-lived scheduler must not grow per-request state without bound, so
/// beyond this many samples the windows become ring buffers holding the
/// most recent entries (counters and sums stay exact forever).
const SAMPLE_CAP: usize = 65_536;

/// Push into a capped window: append until [`SAMPLE_CAP`], then overwrite
/// ring-buffer style using the caller's monotone event counter.
fn push_sample<T>(window: &mut Vec<T>, event_idx: u64, value: T) {
    if window.len() < SAMPLE_CAP {
        window.push(value);
    } else {
        window[(event_idx as usize) % SAMPLE_CAP] = value;
    }
}

/// Final per-tenant accounting, snapshotted when the scheduler drains.
#[derive(Clone, Debug)]
pub struct TenantStats {
    pub name: String,
    pub requests: u64,
    /// adapter uploads (1 per adapter version under the serving pattern)
    pub uploads: usize,
    pub version: u64,
    pub spectra_hits: u64,
    pub spectra_misses: u64,
    /// execution-plan replays by this tenant's session (requests minus
    /// the one recording call, under the steady-state serving pattern;
    /// 0 when plans are disabled via `C3A_PLAN=0`)
    pub plan_replays: u64,
}

/// What the serving thread hands back from [`Scheduler::finish`].
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    pub served: u64,
    pub batches: u64,
    /// requests refused because their tenant was unknown (or inference
    /// failed); each got an error reply
    pub failed: u64,
    /// exact running sum of dynamic batch sizes (drives [`ServeStats::mean_batch`])
    pub batch_size_sum: u64,
    /// most recent [`SAMPLE_CAP`] batch sizes (bounded window)
    pub batch_sizes: Vec<usize>,
    /// most recent [`SAMPLE_CAP`] request latencies (bounded window; the
    /// percentile report covers this window, not all-time)
    pub latencies_ms: Vec<f64>,
    pub tenants: Vec<TenantStats>,
}

impl ServeStats {
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batch_size_sum as f64 / self.batches as f64
        }
    }

    pub fn latency(&self) -> LatencySummary {
        LatencySummary::from_samples(&self.latencies_ms)
    }

    pub fn tenant(&self, name: &str) -> Option<&TenantStats> {
        self.tenants.iter().find(|t| t.name == name)
    }
}

/// The serving thread plus its queue.  Create with [`Scheduler::spawn`],
/// submit through [`Scheduler::handle`], and call [`Scheduler::finish`]
/// (after dropping every cloned handle) to drain and collect stats.
pub struct Scheduler {
    tx: Option<mpsc::SyncSender<Msg>>,
    worker: Option<std::thread::JoinHandle<Result<ServeStats>>>,
}

impl Scheduler {
    /// Spawn the serving thread.  `build` runs *on that thread* (sessions
    /// are not `Send`) and produces the registry the scheduler serves; if
    /// it fails, every submit sees `Closed` and `finish` returns the error.
    pub fn spawn<F>(cfg: SchedulerCfg, build: F) -> Result<Scheduler>
    where
        F: FnOnce() -> Result<AdapterRegistry> + Send + 'static,
    {
        let (tx, rx) = mpsc::sync_channel(cfg.queue_cap.max(1));
        let worker = std::thread::Builder::new()
            .name("c3a-serve".into())
            .spawn(move || serve_loop(cfg, build()?, rx))?;
        Ok(Scheduler { tx: Some(tx), worker: Some(worker) })
    }

    pub fn handle(&self) -> SubmitHandle {
        SubmitHandle { tx: self.tx.as_ref().expect("scheduler is live").clone() }
    }

    /// Drop this side of the queue, wait for the serving thread to drain
    /// every in-flight request, and return its accounting.  Cloned
    /// [`SubmitHandle`]s must be dropped first or this blocks forever.
    pub fn finish(mut self) -> Result<ServeStats> {
        self.tx = None;
        let worker = self.worker.take().expect("finish consumes the scheduler");
        match worker.join() {
            Ok(r) => r,
            Err(_) => Err(anyhow!("serving thread panicked")),
        }
    }
}

fn serve_loop(
    cfg: SchedulerCfg,
    mut registry: AdapterRegistry,
    rx: mpsc::Receiver<Msg>,
) -> Result<ServeStats> {
    let b = registry.spec().batch;
    let s = registry.spec().seq;
    let max_batch = if cfg.max_batch == 0 { b } else { cfg.max_batch.min(b) };
    let mut stats = ServeStats::default();
    let mut tenant_served: BTreeMap<String, u64> = BTreeMap::new();
    // a message that closed the previous batch; processed before recv so
    // queue order is never violated
    let mut carry: Option<Msg> = None;
    loop {
        let msg = match carry.take() {
            Some(m) => m,
            None => match rx.recv() {
                Ok(m) => m,
                Err(_) => break, // every handle dropped and queue drained
            },
        };
        match msg {
            Msg::Swap { tenant, params, ack } => {
                let _ = ack.send(registry.hot_swap(&tenant, params).map_err(|e| format!("{e:#}")));
            }
            Msg::Request(first) => {
                let tenant = first.tenant.clone();
                let deadline = Instant::now() + cfg.max_wait;
                let mut batch = vec![first];
                while batch.len() < max_batch {
                    let remaining = deadline.saturating_duration_since(Instant::now());
                    if remaining.is_zero() {
                        break;
                    }
                    match rx.recv_timeout(remaining) {
                        Ok(Msg::Request(r)) if r.tenant == tenant => batch.push(r),
                        // different tenant or a swap: close this batch and
                        // handle that message next (FIFO preserved)
                        Ok(other) => {
                            carry = Some(other);
                            break;
                        }
                        Err(mpsc::RecvTimeoutError::Timeout) => break,
                        Err(mpsc::RecvTimeoutError::Disconnected) => break,
                    }
                }
                run_batch(&registry, &mut stats, &mut tenant_served, b, s, batch);
            }
        }
    }
    for name in registry.tenant_names() {
        let cs = registry.cache_stats(&name).unwrap_or_default();
        stats.tenants.push(TenantStats {
            requests: tenant_served.get(&name).copied().unwrap_or(0),
            uploads: registry.upload_count(&name).unwrap_or(0),
            version: registry.version(&name).unwrap_or(0),
            spectra_hits: cs.spectra_hits,
            spectra_misses: cs.spectra_misses,
            plan_replays: registry.plan_stats(&name).map(|p| p.replays).unwrap_or(0),
            name,
        });
    }
    Ok(stats)
}

fn run_batch(
    registry: &AdapterRegistry,
    stats: &mut ServeStats,
    tenant_served: &mut BTreeMap<String, u64>,
    b: usize,
    s: usize,
    batch: Vec<Request>,
) {
    let tenant = batch[0].tenant.clone();
    // pad the dynamic batch up to the artifact batch with PAD rows
    let mut toks = vec![0i32; b * s];
    for (slot, r) in batch.iter().enumerate() {
        let n = r.tokens.len().min(s);
        toks[slot * s..slot * s + n].copy_from_slice(&r.tokens[..n]);
    }
    let data = vec![Tensor::from_i32(vec![b, s], &toks)];
    match registry.infer(&tenant, &data) {
        Ok((logits, _shape, version)) => {
            let row_w = logits.len() / b.max(1);
            let now = Instant::now();
            let n_batch = batch.len();
            push_sample(&mut stats.batch_sizes, stats.batches, n_batch);
            stats.batches += 1;
            stats.batch_size_sum += n_batch as u64;
            for (slot, r) in batch.into_iter().enumerate() {
                let row = logits[slot * row_w..(slot + 1) * row_w].to_vec();
                let pred = crate::substrate::linalg::argmax(&row);
                let latency_ms = now.duration_since(r.submitted).as_secs_f64() * 1e3;
                push_sample(&mut stats.latencies_ms, stats.served, latency_ms);
                stats.served += 1;
                *tenant_served.entry(tenant.clone()).or_insert(0) += 1;
                let reply = Reply {
                    tenant: tenant.clone(),
                    tenant_version: version,
                    logits: row,
                    pred,
                    batch_size: n_batch,
                    latency_ms,
                };
                let _ = r.reply.send(Ok(reply));
            }
        }
        Err(e) => {
            let msg = format!("{e:#}");
            stats.failed += batch.len() as u64;
            for r in batch {
                let _ = r.reply.send(Err(msg.clone()));
            }
        }
    }
}
