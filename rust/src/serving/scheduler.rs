//! The sharded serving runtime: N tenant-affine shard workers behind one
//! shared admission layer.
//!
//! Sessions are not `Send` (they hold `Rc` executor state), so each shard
//! worker *builds its own* [`AdapterRegistry`] — its own `SharedBackbone`
//! parse, its own sessions — on its own thread: the closure passed to
//! [`Scheduler::spawn`] runs once per shard with a
//! [`ShardCtx`](super::ShardCtx) and must register exactly the tenants
//! that shard [`owns`](super::ShardCtx::owns).  Producers talk to the
//! shards through per-shard bounded `sync_channel`s behind a
//! [`SubmitHandle`](super::SubmitHandle): `try_submit` surfaces a full
//! shard queue as [`SubmitError::QueueFull`](super::SubmitError)
//! (backpressure + shed accounting), `submit` blocks for space, and
//! `hot_swap` rides the tenant's own queue so per-tenant FIFO holds
//! across swaps with no cross-shard coordination.
//!
//! `shards = 1` (the default) is the degradation/kill-switch path: one
//! worker, one queue, bit-identical behavior to the pre-sharding
//! single-thread scheduler.

use super::admission::{Admission, Msg, SubmitHandle};
use super::registry::AdapterRegistry;
use super::stats::{ServeStats, ShardStats, TenantStats};
use super::worker::{shard_loop, ShardCtx};
use anyhow::{anyhow, bail, Result};
use std::sync::{mpsc, Arc};
use std::time::Duration;

/// Scheduler knobs (see `rust/README.md` § Serving).
#[derive(Clone, Debug)]
pub struct SchedulerCfg {
    /// Shard worker count; each worker owns the tenants that hash to it.
    /// 1 (the default) reproduces the single-thread scheduler exactly.
    pub shards: usize,
    /// Bounded queue capacity **per shard**; `try_submit` sheds load
    /// beyond it.
    pub queue_cap: usize,
    /// Dynamic batch cap; 0 means "the artifact batch size".
    pub max_batch: usize,
    /// How long a batch may wait for more same-tenant requests after its
    /// first request is dequeued.
    pub max_wait: Duration,
}

impl Default for SchedulerCfg {
    fn default() -> Self {
        SchedulerCfg {
            shards: 1,
            queue_cap: 256,
            max_batch: 0,
            max_wait: Duration::from_millis(2),
        }
    }
}

type ShardOutput = (ShardStats, Vec<TenantStats>);

/// The shard workers plus their queues.  Create with [`Scheduler::spawn`],
/// submit through [`Scheduler::handle`], and call [`Scheduler::finish`]
/// (after dropping every cloned handle) to drain and collect stats.
pub struct Scheduler {
    txs: Option<Arc<Vec<mpsc::SyncSender<Msg>>>>,
    workers: Vec<std::thread::JoinHandle<Result<ShardOutput>>>,
    adm: Arc<Admission>,
}

impl Scheduler {
    /// Spawn `cfg.shards` shard workers.  `build` runs *on each shard
    /// thread* (sessions are not `Send`) and produces that shard's
    /// registry; it must register exactly the tenants its
    /// [`ShardCtx::owns`] — a tenant registered on the wrong shard could
    /// never receive a request (routing is by name hash), so the worker
    /// rejects it at startup.  If a shard's build fails, submits routed
    /// to that shard see `Closed` and `finish` returns the error; other
    /// shards keep serving until drained.
    pub fn spawn<F>(cfg: SchedulerCfg, build: F) -> Result<Scheduler>
    where
        F: Fn(&ShardCtx) -> Result<AdapterRegistry> + Send + Sync + 'static,
    {
        let shards = cfg.shards.max(1);
        let build = Arc::new(build);
        let adm = Arc::new(Admission::new(shards));
        let mut txs = Vec::with_capacity(shards);
        let mut workers = Vec::with_capacity(shards);
        for shard in 0..shards {
            let (tx, rx) = mpsc::sync_channel(cfg.queue_cap.max(1));
            let build = build.clone();
            let cfg = cfg.clone();
            let adm = adm.clone();
            let worker = std::thread::Builder::new()
                .name(format!("c3a-serve-{shard}"))
                .spawn(move || -> Result<ShardOutput> {
                    let ctx = ShardCtx::new(shard, shards);
                    let registry = build(&ctx)?;
                    for name in registry.tenant_names() {
                        if !ctx.owns(&name) {
                            bail!(
                                "tenant {name} registered on shard {shard} but routes to \
                                 shard {} — register only tenants the ShardCtx owns",
                                super::admission::shard_of(&name, shards)
                            );
                        }
                    }
                    shard_loop(&cfg, shard, registry, rx, &adm.gauges[shard])
                })?;
            txs.push(tx);
            workers.push(worker);
        }
        Ok(Scheduler { txs: Some(Arc::new(txs)), workers, adm })
    }

    pub fn handle(&self) -> SubmitHandle {
        SubmitHandle::new(self.txs.as_ref().expect("scheduler is live").clone(), self.adm.clone())
    }

    /// Drop this side of every shard queue, wait for the workers to drain
    /// every in-flight request, and return the merged accounting (raw
    /// latency windows pooled across shards — see
    /// [`ServeStats::merge`]).  Cloned
    /// [`SubmitHandle`](super::SubmitHandle)s must be dropped first or
    /// this blocks forever.
    pub fn finish(mut self) -> Result<ServeStats> {
        self.txs = None;
        let mut outs: Vec<ShardOutput> = Vec::with_capacity(self.workers.len());
        let mut first_err: Option<anyhow::Error> = None;
        for (shard, worker) in self.workers.drain(..).enumerate() {
            match worker.join() {
                Ok(Ok(out)) => outs.push(out),
                Ok(Err(e)) => {
                    first_err.get_or_insert(e);
                }
                Err(_) => {
                    first_err.get_or_insert(anyhow!("serving shard {shard} panicked"));
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        // fold the admission-side accounting (sheds, depth high-water
        // marks) into the shard outputs before merging
        let tenant_sheds = self.adm.tenant_sheds();
        for (stats, tenants) in &mut outs {
            let gauge = &self.adm.gauges[stats.shard];
            stats.queue_depth_hwm = gauge.hwm();
            stats.sheds = gauge.sheds();
            for t in tenants.iter_mut() {
                t.sheds = tenant_sheds.get(&t.name).copied().unwrap_or(0);
            }
        }
        Ok(ServeStats::merge(outs))
    }
}
