//! Named adapter snapshots over one shared frozen-backbone parse.

use crate::runtime::interp::CacheStats;
use crate::runtime::manifest::ArtifactSpec;
use crate::runtime::plan::PlanStats;
use crate::runtime::session::{Batch, EvalSession, SessionInit, SharedBackbone};
use crate::runtime::Engine;
use crate::substrate::prng::Rng;
use crate::substrate::tensor::{Tensor, TensorMap};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// Derive an adapter variant by deterministically perturbing the C3A
/// kernels (seeded, `eps`-scaled noise).  Stands in for per-tenant
/// fine-tuning in the serve demo/bench/tests, and doubles as a
/// cache-invalidation probe: any kernel change must re-upload and
/// recompute exactly that tenant's spectra.
pub fn perturb_c3a_kernels(adapter: &TensorMap, seed: u64, eps: f32) -> TensorMap {
    let mut rng = Rng::seed(0xC3A0_5EED ^ seed);
    let mut out = adapter.clone();
    for (name, t) in adapter {
        if !name.contains(".c3a.w") {
            continue;
        }
        let mut vals = t.as_f32();
        for v in vals.iter_mut() {
            *v += eps * rng.normal() as f32;
        }
        out.insert(name.clone(), Tensor::from_f32(t.shape.clone(), &vals));
    }
    out
}

struct Tenant {
    session: EvalSession,
    params: TensorMap,
    version: u64,
}

/// Many named C3A adapters served over a *single* frozen-backbone parse:
/// one [`EvalSession`] — and therefore one private spectra cache and one
/// trainable-upload slot — per tenant, all sharing the backbone literals
/// and (on the substrate backend) the parsed frozen arrays.
///
/// Not `Send` by design (sessions hold `Rc` state): a registry lives on
/// exactly one shard worker thread, which builds it there via the
/// closure passed to [`super::scheduler::Scheduler::spawn`] and owns the
/// disjoint slice of tenants routing to that shard.
pub struct AdapterRegistry {
    backbone: SharedBackbone,
    tenants: BTreeMap<String, Tenant>,
}

impl AdapterRegistry {
    /// Build the shared backbone from an eval artifact + init.  Only the
    /// frozen half of `init` is used; it is uploaded and parsed once, for
    /// every tenant ever registered.
    pub fn new(
        engine: &Engine,
        spec: &ArtifactSpec,
        init: &SessionInit,
    ) -> Result<AdapterRegistry> {
        Ok(AdapterRegistry {
            backbone: SharedBackbone::new(engine, spec, init)?,
            tenants: BTreeMap::new(),
        })
    }

    pub fn spec(&self) -> &ArtifactSpec {
        self.backbone.spec()
    }

    /// Register a tenant with its adapter snapshot (version 1).
    pub fn register(&mut self, name: &str, params: TensorMap) -> Result<()> {
        if self.tenants.contains_key(name) {
            bail!("tenant {name} already registered");
        }
        let session = self.backbone.session()?;
        self.tenants.insert(name.to_string(), Tenant { session, params, version: 1 });
        Ok(())
    }

    /// Atomically replace `name`'s adapter; returns the new version.
    ///
    /// Invalidation is exact and tenant-local: the swapped tenant's next
    /// request re-uploads the snapshot (its `upload_count` rises by one)
    /// and its kernel spectra recompute via equality invalidation; every
    /// other tenant's caches keep hitting untouched.
    pub fn hot_swap(&mut self, name: &str, params: TensorMap) -> Result<u64> {
        let t = self.tenants.get_mut(name).with_context(|| format!("unknown tenant {name}"))?;
        t.params = params;
        t.version += 1;
        Ok(t.version)
    }

    /// Forward one batch through `name`'s adapter; returns (flat logits,
    /// shape, adapter version the batch was served under).
    pub fn infer(&self, name: &str, batch: &Batch) -> Result<(Vec<f32>, Vec<usize>, u64)> {
        let t = self.tenants.get(name).with_context(|| format!("unknown tenant {name}"))?;
        let (logits, shape) = t.session.logits(&t.params, batch)?;
        Ok((logits, shape, t.version))
    }

    /// How many times `name`'s adapter has been uploaded (1 per version
    /// under the serving pattern).
    pub fn upload_count(&self, name: &str) -> Option<usize> {
        self.tenants.get(name).map(|t| t.session.upload_count())
    }

    pub fn version(&self, name: &str) -> Option<u64> {
        self.tenants.get(name).map(|t| t.version)
    }

    /// Per-tenant spectra-cache accounting (substrate backend).
    pub fn cache_stats(&self, name: &str) -> Option<CacheStats> {
        self.tenants.get(name).and_then(|t| t.session.cache_stats())
    }

    /// Per-tenant execution-plan accounting (substrate backend): each
    /// tenant records its own plan + buffer arena on its first request
    /// and replays it afterwards.  None before the first request or when
    /// plans are disabled (`C3A_PLAN=0`).
    pub fn plan_stats(&self, name: &str) -> Option<PlanStats> {
        self.tenants.get(name).and_then(|t| t.session.plan_stats())
    }

    pub fn tenant_names(&self) -> Vec<String> {
        self.tenants.keys().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.tenants.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tenants.is_empty()
    }

    /// Executor states sharing the frozen parse, the backbone's own handle
    /// included: `n_tenants + 1` when every tenant shares one parse.
    pub fn shared_parse_refs(&self) -> usize {
        self.backbone.parse_refs()
    }
}
