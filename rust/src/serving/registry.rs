//! Named adapter snapshots over one shared frozen-backbone parse, with a
//! tiered tenant lifecycle: **resident** (live session + uploaded
//! literals + spectra + plan arena, warm replay) or **evicted** (only a
//! compact snapshot in the [`AdapterStore`]).
//!
//! Residency is governed by [`ResidentPolicy`]: when admitting a tenant
//! would exceed `max_resident`, the least-recently-served resident is
//! evicted first (session and arena dropped — `shared_parse_refs` falls —
//! and the snapshot persisted); when `bytes_budget` is exceeded after a
//! request, residents are evicted LRU-first until the estimate fits.  A
//! request for an evicted tenant takes the measured cold-start path:
//! load snapshot → new session → upload → spectra recompute → plan
//! re-record, all timed into the registry's cold-start window.  Reload is
//! bit-identical to never having evicted: the store round-trips kernel
//! bits exactly, and spectra/plans are deterministic functions of them.

use crate::runtime::interp::CacheStats;
use crate::runtime::manifest::ArtifactSpec;
use crate::runtime::plan::PlanStats;
use crate::runtime::session::{Batch, EvalSession, SessionInit, SharedBackbone};
use crate::runtime::Engine;
use crate::substrate::prng::Rng;
use crate::substrate::tensor::{Tensor, TensorMap};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::time::Instant;

use super::stats::push_sample;
use super::store::AdapterStore;

/// Derive an adapter variant by deterministically perturbing the C3A
/// kernels (seeded, `eps`-scaled noise).  Stands in for per-tenant
/// fine-tuning in the serve demo/bench/tests, and doubles as a
/// cache-invalidation probe: any kernel change must re-upload and
/// recompute exactly that tenant's spectra.
///
/// Only `.c3a.w` entries are rebuilt; every other tensor in the returned
/// map *shares storage* with the input (tensor payloads are `Arc`ed), so
/// deriving thousands of tenant variants costs kernels, not backbones.
pub fn perturb_c3a_kernels(adapter: &TensorMap, seed: u64, eps: f32) -> TensorMap {
    let mut rng = Rng::seed(0xC3A0_5EED ^ seed);
    let mut out = adapter.clone(); // shallow: payloads shared until perturbed
    for (name, t) in adapter {
        if !name.contains(".c3a.w") {
            continue;
        }
        let mut vals = t.as_f32();
        for v in vals.iter_mut() {
            *v += eps * rng.normal() as f32;
        }
        out.insert(name.clone(), Tensor::from_f32(t.shape.clone(), &vals));
    }
    out
}

/// Caps on the resident tenant set (0 = unlimited).  `max_resident` is
/// enforced *before* admission (the set never exceeds it); `bytes_budget`
/// is checked against [`AdapterRegistry::resident_bytes`] after each
/// request (plan arenas only exist after the first request).
#[derive(Clone, Copy, Debug, Default)]
pub struct ResidentPolicy {
    pub max_resident: usize,
    pub bytes_budget: usize,
}

impl ResidentPolicy {
    pub fn unlimited() -> ResidentPolicy {
        ResidentPolicy::default()
    }

    pub fn max_resident(n: usize) -> ResidentPolicy {
        ResidentPolicy { max_resident: n, bytes_budget: 0 }
    }

    fn bounded(&self) -> bool {
        self.max_resident > 0 || self.bytes_budget > 0
    }
}

enum TenantState {
    /// Warm: live session (uploaded literals, spectra cache, plan arena)
    /// plus the in-memory params the upload is checked against.
    Resident { session: EvalSession, params: TensorMap },
    /// Cold: nothing in memory — the adapter store holds the snapshot.
    Evicted,
}

/// Session counters survive eviction by accumulating here when the
/// session is dropped; accessors report `carried + live`.
#[derive(Default)]
struct CarriedCounters {
    uploads: usize,
    spectra_hits: u64,
    spectra_misses: u64,
    plan_replays: u64,
    hoist_skips: u64,
    hoist_invalidations: u64,
}

struct Tenant {
    state: TenantState,
    version: u64,
    /// version the store snapshot was last written at (0 = never)
    persisted_version: u64,
    /// registry clock tick of the last request — the LRU order
    last_served: u64,
    evictions: u64,
    cold_starts: u64,
    carried: CarriedCounters,
}

impl Tenant {
    fn session(&self) -> Option<&EvalSession> {
        match &self.state {
            TenantState::Resident { session, .. } => Some(session),
            TenantState::Evicted => None,
        }
    }

    fn is_resident(&self) -> bool {
        matches!(self.state, TenantState::Resident { .. })
    }
}

/// Many named C3A adapters served over a *single* frozen-backbone parse:
/// one [`EvalSession`] — and therefore one private spectra cache and one
/// trainable-upload slot — per **resident** tenant, all sharing the
/// backbone literals and (on the substrate backend) the parsed frozen
/// arrays.  Evicted tenants keep only a version + counters here; their
/// params live in the [`AdapterStore`].
///
/// Not `Send` by design (sessions hold `Rc` state): a registry lives on
/// exactly one shard worker thread, which builds it there via the
/// closure passed to [`super::scheduler::Scheduler::spawn`] and owns the
/// disjoint slice of tenants routing to that shard.
pub struct AdapterRegistry {
    backbone: SharedBackbone,
    tenants: BTreeMap<String, Tenant>,
    store: Option<AdapterStore>,
    policy: ResidentPolicy,
    /// monotone request clock driving the LRU order
    clock: u64,
    resident_now: usize,
    resident_hwm: usize,
    evictions_total: u64,
    cold_starts_total: u64,
    /// bounded window of cold-start wall times (ms), pooled across
    /// shards like the latency windows
    cold_start_ms: Vec<f64>,
}

impl AdapterRegistry {
    /// Build the shared backbone from an eval artifact + init.  Only the
    /// frozen half of `init` is used; it is uploaded and parsed once, for
    /// every tenant ever registered.  Every tenant stays resident until
    /// [`set_residency`](AdapterRegistry::set_residency) installs a store
    /// + policy.
    pub fn new(
        engine: &Engine,
        spec: &ArtifactSpec,
        init: &SessionInit,
    ) -> Result<AdapterRegistry> {
        Ok(AdapterRegistry {
            backbone: SharedBackbone::new(engine, spec, init)?,
            tenants: BTreeMap::new(),
            store: None,
            policy: ResidentPolicy::unlimited(),
            clock: 0,
            resident_now: 0,
            resident_hwm: 0,
            evictions_total: 0,
            cold_starts_total: 0,
            cold_start_ms: Vec::new(),
        })
    }

    pub fn spec(&self) -> &ArtifactSpec {
        self.backbone.spec()
    }

    /// Install the disk tier: snapshots persist to `store`, and `policy`
    /// bounds the resident set (enforced immediately against already-
    /// registered tenants, LRU first).  Tenants registered *after* this
    /// start evicted — their first request is a cold start — so
    /// registering far more tenants than `max_resident` is cheap.
    pub fn set_residency(&mut self, policy: ResidentPolicy, store: AdapterStore) -> Result<()> {
        // sweep temp files orphaned by a crash mid-save in a previous
        // incarnation of this store dir (age-guarded, so a concurrent
        // shard's in-flight save is never touched)
        store.gc()?;
        self.store = Some(store);
        self.policy = policy;
        // persist + evict down to policy (oldest first; all-zero
        // last_served falls back to BTreeMap name order)
        if self.policy.max_resident > 0 {
            self.evict_down_to(self.policy.max_resident, None)?;
        }
        self.enforce_bytes(None)
    }

    pub fn policy(&self) -> ResidentPolicy {
        self.policy
    }

    /// Register a tenant with its adapter snapshot (version 1).  With a
    /// store installed the snapshot is persisted and the tenant starts
    /// evicted (lazy session); without one it is immediately resident.
    pub fn register(&mut self, name: &str, params: TensorMap) -> Result<()> {
        if self.tenants.contains_key(name) {
            bail!("tenant {name} already registered");
        }
        let tenant = match &self.store {
            Some(store) => {
                store.save(name, 1, &params)?;
                Tenant {
                    state: TenantState::Evicted,
                    version: 1,
                    persisted_version: 1,
                    last_served: 0,
                    evictions: 0,
                    cold_starts: 0,
                    carried: CarriedCounters::default(),
                }
            }
            None => Tenant {
                state: TenantState::Resident { session: self.backbone.session()?, params },
                version: 1,
                persisted_version: 0,
                last_served: 0,
                evictions: 0,
                cold_starts: 0,
                carried: CarriedCounters::default(),
            },
        };
        if tenant.is_resident() {
            self.resident_now += 1;
            self.resident_hwm = self.resident_hwm.max(self.resident_now);
        }
        self.tenants.insert(name.to_string(), tenant);
        Ok(())
    }

    /// Atomically replace `name`'s adapter; returns the new version.
    ///
    /// Invalidation is exact and tenant-local: a resident tenant's next
    /// request re-uploads the snapshot (its `upload_count` rises by one)
    /// and its kernel spectra recompute via equality invalidation; every
    /// other tenant's caches keep hitting untouched.  An evicted tenant's
    /// new snapshot goes straight to the store — it becomes resident (and
    /// pays its cold start) only when traffic arrives.
    pub fn hot_swap(&mut self, name: &str, params: TensorMap) -> Result<u64> {
        let t = self.tenants.get_mut(name).with_context(|| format!("unknown tenant {name}"))?;
        t.version += 1;
        let version = t.version;
        match &mut t.state {
            TenantState::Resident { params: p, .. } => *p = params,
            TenantState::Evicted => {
                let store = self.store.as_ref().context("evicted tenant without a store")?;
                store.save(name, version, &params)?;
                t.persisted_version = version;
            }
        }
        Ok(version)
    }

    /// Forward one batch through `name`'s adapter; returns (flat logits,
    /// shape, adapter version the batch was served under).  An evicted
    /// tenant is cold-started first: snapshot loaded (checksum-verified),
    /// a fresh session registered, and the serve below re-uploads,
    /// recomputes spectra, and re-records the plan — the whole sequence
    /// timed into [`cold_start_window`](AdapterRegistry::cold_start_window).
    pub fn infer(&mut self, name: &str, batch: &Batch) -> Result<(Vec<f32>, Vec<usize>, u64)> {
        self.clock += 1;
        let tick = self.clock;
        let t = self.tenants.get(name).with_context(|| format!("unknown tenant {name}"))?;
        let cold = !t.is_resident();
        let t0 = Instant::now();
        if cold {
            // make room first so the resident set never exceeds policy
            self.make_room(Some(name))?;
            let store = self.store.as_ref().context("evicted tenant without a store")?;
            let (params, stored_version) = store.load(name)?;
            let t = self.tenants.get_mut(name).unwrap();
            if stored_version != t.version {
                bail!(
                    "tenant {name}: store snapshot at version {stored_version} \
                     but registry expects {version}",
                    version = t.version
                );
            }
            t.state = TenantState::Resident { session: self.backbone.session()?, params };
            t.cold_starts += 1;
            self.resident_now += 1;
            self.resident_hwm = self.resident_hwm.max(self.resident_now);
        }
        let t = self.tenants.get_mut(name).unwrap();
        t.last_served = tick;
        let version = t.version;
        let TenantState::Resident { session, params } = &t.state else { unreachable!() };
        let out = session.logits(params, batch)?;
        if cold {
            let ms = t0.elapsed().as_secs_f64() * 1e3;
            push_sample(&mut self.cold_start_ms, self.cold_starts_total, ms);
            self.cold_starts_total += 1;
        }
        if self.policy.bytes_budget > 0 {
            self.enforce_bytes(Some(name))?;
        }
        Ok((out.0, out.1, version))
    }

    /// Evict `name`: persist its snapshot (if the stored version is
    /// stale) and drop its session — uploaded literals, spectra cache,
    /// and plan arena all release, and the session's frozen-parse ref
    /// falls off [`shared_parse_refs`](AdapterRegistry::shared_parse_refs).
    /// Requires a store; errors if the tenant is unknown or not resident.
    pub fn evict(&mut self, name: &str) -> Result<()> {
        let store = self.store.as_ref().context("evict requires an adapter store")?;
        let t = self.tenants.get(name).with_context(|| format!("unknown tenant {name}"))?;
        match &t.state {
            TenantState::Resident { params, .. } => {
                if t.persisted_version != t.version {
                    store.save(name, t.version, params)?;
                }
            }
            TenantState::Evicted => bail!("tenant {name} is not resident"),
        }
        let t = self.tenants.get_mut(name).unwrap();
        t.persisted_version = t.version;
        if let TenantState::Resident { session, .. } =
            std::mem::replace(&mut t.state, TenantState::Evicted)
        {
            t.carried.uploads += session.upload_count();
            if let Some(cs) = session.cache_stats() {
                t.carried.spectra_hits += cs.spectra_hits;
                t.carried.spectra_misses += cs.spectra_misses;
            }
            if let Some(ps) = session.plan_stats() {
                t.carried.plan_replays += ps.replays;
                t.carried.hoist_skips += ps.hoist_skips;
                t.carried.hoist_invalidations += ps.hoist_invalidations;
            }
            // session drops here: arena, uploads, and the parse ref go
        }
        t.evictions += 1;
        self.evictions_total += 1;
        self.resident_now -= 1;
        Ok(())
    }

    /// Least-recently-served resident tenant (excluding `protect`).
    fn lru_victim(&self, protect: Option<&str>) -> Option<String> {
        self.tenants
            .iter()
            .filter(|(n, t)| t.is_resident() && Some(n.as_str()) != protect)
            .min_by_key(|(_, t)| t.last_served)
            .map(|(n, _)| n.clone())
    }

    /// Evict LRU-first until the resident count is at most `limit`.
    fn evict_down_to(&mut self, limit: usize, protect: Option<&str>) -> Result<()> {
        while self.resident_now > limit {
            match self.lru_victim(protect) {
                Some(v) => self.evict(&v)?,
                None => break,
            }
        }
        Ok(())
    }

    /// Evict LRU-first until one more tenant fits under `max_resident`.
    fn make_room(&mut self, protect: Option<&str>) -> Result<()> {
        if self.policy.max_resident == 0 || self.store.is_none() {
            return Ok(());
        }
        self.evict_down_to(self.policy.max_resident - 1, protect)
    }

    /// Evict LRU-first until the resident-bytes estimate fits the budget.
    fn enforce_bytes(&mut self, protect: Option<&str>) -> Result<()> {
        if self.policy.bytes_budget == 0 || self.store.is_none() {
            return Ok(());
        }
        while self.resident_bytes() > self.policy.bytes_budget {
            match self.lru_victim(protect) {
                Some(v) => self.evict(&v)?,
                None => break,
            }
        }
        Ok(())
    }

    /// How many times `name`'s adapter has been uploaded (1 per cold
    /// start or resident version change under the serving pattern) —
    /// carried across evictions.
    pub fn upload_count(&self, name: &str) -> Option<usize> {
        let t = self.tenants.get(name)?;
        Some(t.carried.uploads + t.session().map(|s| s.upload_count()).unwrap_or(0))
    }

    pub fn version(&self, name: &str) -> Option<u64> {
        self.tenants.get(name).map(|t| t.version)
    }

    /// Per-tenant spectra-cache accounting (substrate backend) — carried
    /// across evictions.
    pub fn cache_stats(&self, name: &str) -> Option<CacheStats> {
        let t = self.tenants.get(name)?;
        let mut cs = t.session().and_then(|s| s.cache_stats()).unwrap_or_default();
        cs.spectra_hits += t.carried.spectra_hits;
        cs.spectra_misses += t.carried.spectra_misses;
        Some(cs)
    }

    /// Per-tenant execution-plan accounting (substrate backend): each
    /// resident tenant records its own plan + buffer arena on its first
    /// request and replays it afterwards.  None before the first request
    /// or when plans are disabled (`C3A_PLAN=0`); replay counts from
    /// evicted incarnations are folded in.
    pub fn plan_stats(&self, name: &str) -> Option<PlanStats> {
        let t = self.tenants.get(name)?;
        let mut ps = t.session().and_then(|s| s.plan_stats())?;
        ps.replays += t.carried.plan_replays;
        ps.hoist_skips += t.carried.hoist_skips;
        ps.hoist_invalidations += t.carried.hoist_invalidations;
        Some(ps)
    }

    /// Hoisting accounting for `name` across all incarnations:
    /// `(hoisted_ops, hoist_skips, hoist_invalidations)`.  The op count
    /// is the live plan's (0 while evicted or before the first request);
    /// skips and invalidations are cumulative and survive eviction, like
    /// [`plan_replays`](Self::plan_replays).
    pub fn hoist_stats(&self, name: &str) -> (usize, u64, u64) {
        let t = match self.tenants.get(name) {
            Some(t) => t,
            None => return (0, 0, 0),
        };
        let live = t.session().and_then(|s| s.plan_stats()).unwrap_or_default();
        (
            live.hoisted_ops,
            t.carried.hoist_skips + live.hoist_skips,
            t.carried.hoist_invalidations + live.hoist_invalidations,
        )
    }

    /// Total plan replays for `name` across all incarnations (survives
    /// eviction even when the live session has no plan yet).
    pub fn plan_replays(&self, name: &str) -> u64 {
        let t = match self.tenants.get(name) {
            Some(t) => t,
            None => return 0,
        };
        t.carried.plan_replays
            + t.session().and_then(|s| s.plan_stats()).map(|p| p.replays).unwrap_or(0)
    }

    pub fn is_resident(&self, name: &str) -> Option<bool> {
        self.tenants.get(name).map(|t| t.is_resident())
    }

    pub fn evictions(&self, name: &str) -> Option<u64> {
        self.tenants.get(name).map(|t| t.evictions)
    }

    pub fn cold_starts(&self, name: &str) -> Option<u64> {
        self.tenants.get(name).map(|t| t.cold_starts)
    }

    /// Residents right now / the high-water mark since construction.
    pub fn resident_now(&self) -> usize {
        self.resident_now
    }

    pub fn resident_hwm(&self) -> usize {
        self.resident_hwm
    }

    pub fn evictions_total(&self) -> u64 {
        self.evictions_total
    }

    pub fn cold_starts_total(&self) -> u64 {
        self.cold_starts_total
    }

    /// Bounded window of cold-start wall times (ms).
    pub fn cold_start_window(&self) -> &[f64] {
        &self.cold_start_ms
    }

    /// Estimated bytes held by resident tenants: per-session plan-arena +
    /// uploaded-literal bytes ([`EvalSession::resident_bytes`]) plus the
    /// in-memory params payload.
    pub fn resident_bytes(&self) -> usize {
        self.tenants
            .values()
            .map(|t| match &t.state {
                TenantState::Resident { session, params } => {
                    session.resident_bytes()
                        + params.values().map(|p| p.len() * 4).sum::<usize>()
                }
                TenantState::Evicted => 0,
            })
            .sum()
    }

    pub fn tenant_names(&self) -> Vec<String> {
        self.tenants.keys().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.tenants.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tenants.is_empty()
    }

    /// Executor states sharing the frozen parse, the backbone's own handle
    /// included: `n_resident + 1` when every resident shares one parse —
    /// eviction makes this fall, reload makes it recover.
    pub fn shared_parse_refs(&self) -> usize {
        self.backbone.parse_refs()
    }
}
