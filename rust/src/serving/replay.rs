//! Seeded traffic-replay driver: Zipf tenant popularity, bursty
//! arrivals, mid-storm hot-swaps, and bounded shed backoff — the load
//! generator behind `benches/bench_serve.rs` and `examples/serve.rs`.
//!
//! The *arrival schedule* (which tenant each request hits, and when the
//! swaps fire) is a pure function of [`ReplayCfg::seed`]: the whole
//! tenant sequence is pre-sampled from one [`Rng`] stream, so two runs
//! with the same cfg replay the same storm against shards=1 and
//! shards=4 alike ([`ReplayReport::trace_hash`] pins it).  Only the
//! *outcome* side (sheds, latencies) is timing-dependent.
//!
//! On [`SubmitError::QueueFull`] the driver backs off with bounded
//! exponential sleep instead of spinning hot, counts every shed, and
//! gives a request up as `dropped` after `max_retries` — load-shedding
//! is reported, never silently retried away.

use super::admission::{SubmitError, SubmitHandle, Ticket};
use crate::substrate::prng::{fnv1a_fold, Rng, FNV1A_OFFSET};
use crate::substrate::tensor::TensorMap;
use anyhow::{bail, Context, Result};
use std::time::{Duration, Instant};

/// Canonical replay tenant naming: rank `i` in the Zipf popularity order
/// is named `tenant{i}` (rank 0 is the hottest).  Builders and the
/// driver must agree on names for routing to line up.
pub fn tenant_name(i: usize) -> String {
    format!("tenant{i}")
}

/// Zipf(s) sampler over ranks `0..n` by inverse CDF: rank k has weight
/// `1/(k+1)^s`.  `s = 0` degenerates to uniform; `s ≈ 1` is the classic
/// web-traffic skew ("a few tenants take most of the traffic").
#[derive(Clone, Debug)]
pub struct ZipfSampler {
    cdf: Vec<f64>,
}

impl ZipfSampler {
    pub fn new(n: usize, exponent: f64) -> ZipfSampler {
        assert!(n > 0, "ZipfSampler over an empty rank set");
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0f64;
        for k in 0..n {
            acc += ((k + 1) as f64).powf(exponent).recip();
            cdf.push(acc);
        }
        for c in &mut cdf {
            *c /= acc;
        }
        ZipfSampler { cdf }
    }

    /// Sample one rank (deterministic given the `rng` state).
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.uniform();
        // first rank whose cumulative mass exceeds u
        self.cdf.partition_point(|&c| c <= u).min(self.cdf.len() - 1)
    }
}

/// Replay knobs.  The defaults model a short bursty storm; the bench and
/// the serve example override sizes.
#[derive(Clone, Debug)]
pub struct ReplayCfg {
    /// seeds the arrival schedule (tenant sequence + swap targets)
    pub seed: u64,
    pub requests: usize,
    /// tenants, named [`tenant_name`]`(0..tenants)`
    pub tenants: usize,
    /// Zipf popularity exponent (0 = uniform)
    pub zipf_exponent: f64,
    /// requests submitted back-to-back before a `burst_gap` pause
    /// (0 = no pauses: one continuous storm)
    pub burst: usize,
    pub burst_gap: Duration,
    /// hot-swap the next sampled tenant every this many requests
    /// (0 = never) — swaps land mid-storm, on Zipf-hot tenants
    pub swap_every: usize,
    /// initial backoff sleep after a `QueueFull` shed…
    pub shed_backoff: Duration,
    /// …doubling up to this bound
    pub max_backoff: Duration,
    /// sheds tolerated per request before it is dropped
    pub max_retries: usize,
}

impl Default for ReplayCfg {
    fn default() -> Self {
        ReplayCfg {
            seed: 42,
            requests: 256,
            tenants: 8,
            zipf_exponent: 1.1,
            burst: 16,
            burst_gap: Duration::from_micros(200),
            swap_every: 0,
            shed_backoff: Duration::from_micros(100),
            max_backoff: Duration::from_millis(5),
            max_retries: 64,
        }
    }
}

/// What a replay run observed.
#[derive(Clone, Debug, Default)]
pub struct ReplayReport {
    /// requests that got a ticket (submitted = requests − dropped)
    pub submitted: usize,
    /// Ok replies
    pub completed: usize,
    /// error replies (unknown tenant / inference failure)
    pub failed: usize,
    /// requests abandoned after `max_retries` consecutive sheds
    pub dropped: usize,
    /// total `QueueFull` events the driver observed (≥ dropped)
    pub sheds: u64,
    /// acked hot-swaps
    pub swaps: u64,
    /// submit-to-last-reply wall clock
    pub wall_s: f64,
    /// deterministic arrivals per tenant rank (a function of the seed
    /// only — *sampled* arrivals, including any later dropped)
    pub per_tenant: Vec<u64>,
    /// FNV-1a over the sampled tenant sequence: two runs with the same
    /// cfg must report the same hash
    pub trace_hash: u64,
    /// per-request predictions in submission order (`None` when the
    /// request was dropped or failed)
    pub preds: Vec<Option<usize>>,
}

impl ReplayReport {
    pub fn req_per_s(&self) -> f64 {
        if self.wall_s <= 0.0 {
            0.0
        } else {
            self.completed as f64 / self.wall_s
        }
    }
}

/// Pre-sample the arrival schedule for `cfg`: the tenant rank hit by
/// each request.  Pure in the seed — exposed so tests can pin
/// reproducibility without running a scheduler.
pub fn arrival_schedule(cfg: &ReplayCfg) -> Vec<usize> {
    let mut rng = Rng::seed(cfg.seed);
    let zipf = ZipfSampler::new(cfg.tenants, cfg.zipf_exponent);
    (0..cfg.requests).map(|_| zipf.sample(&mut rng)).collect()
}

/// Replay the seeded storm against a live scheduler.  `tokens_for(req_idx,
/// tenant_rank)` produces each request's tokens; `swap_params(swap_idx,
/// tenant_rank)` produces the adapter snapshot for each mid-storm
/// hot-swap (called only when `cfg.swap_every > 0`).
pub fn run_replay(
    handle: &SubmitHandle,
    cfg: &ReplayCfg,
    mut tokens_for: impl FnMut(usize, usize) -> Vec<i32>,
    mut swap_params: impl FnMut(u64, usize) -> TensorMap,
) -> Result<ReplayReport> {
    let seq = arrival_schedule(cfg);
    let mut report = ReplayReport {
        per_tenant: vec![0u64; cfg.tenants],
        preds: Vec::with_capacity(cfg.requests),
        ..ReplayReport::default()
    };
    report.trace_hash = FNV1A_OFFSET;
    for &t in &seq {
        report.trace_hash = fnv1a_fold(report.trace_hash, &(t as u64).to_le_bytes());
        report.per_tenant[t] += 1;
    }

    let t0 = Instant::now();
    let mut tickets: Vec<Option<Ticket>> = Vec::with_capacity(cfg.requests);
    for (i, &rank) in seq.iter().enumerate() {
        if cfg.swap_every > 0 && i > 0 && i % cfg.swap_every == 0 {
            // mid-storm swap of the tenant about to be hit (Zipf-hot by
            // construction); blocks until its shard acks, which by the
            // per-tenant FIFO contract is after its queued prefix drains
            let params = swap_params(report.swaps, rank);
            handle
                .hot_swap(&tenant_name(rank), params)
                .with_context(|| format!("mid-storm hot-swap of tenant{rank}"))?;
            report.swaps += 1;
        }
        if cfg.burst > 0 && i > 0 && i % cfg.burst == 0 {
            std::thread::sleep(cfg.burst_gap);
        }
        let toks = tokens_for(i, rank);
        let name = tenant_name(rank);
        let mut backoff = cfg.shed_backoff;
        let mut tries = 0usize;
        let ticket = loop {
            match handle.try_submit(&name, toks.clone()) {
                Ok(t) => break Some(t),
                Err(SubmitError::QueueFull) => {
                    report.sheds += 1;
                    tries += 1;
                    if tries > cfg.max_retries {
                        report.dropped += 1;
                        break None;
                    }
                    // bounded exponential backoff — never a hot spin
                    std::thread::sleep(backoff);
                    backoff = (backoff * 2).min(cfg.max_backoff);
                }
                Err(SubmitError::Closed) => bail!("scheduler closed mid-replay (request {i})"),
            }
        };
        if ticket.is_some() {
            report.submitted += 1;
        }
        tickets.push(ticket);
    }
    for ticket in tickets {
        match ticket {
            Some(t) => match t.wait() {
                Ok(r) => {
                    report.completed += 1;
                    report.preds.push(Some(r.pred));
                }
                Err(_) => {
                    report.failed += 1;
                    report.preds.push(None);
                }
            },
            None => report.preds.push(None),
        }
    }
    report.wall_s = t0.elapsed().as_secs_f64();
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zipf_is_deterministic_and_skewed() {
        let z = ZipfSampler::new(16, 1.1);
        let draw = |seed: u64| -> Vec<usize> {
            let mut rng = Rng::seed(seed);
            (0..512).map(|_| z.sample(&mut rng)).collect()
        };
        assert_eq!(draw(7), draw(7), "same seed must replay the same sequence");
        assert_ne!(draw(7), draw(8));
        let seq = draw(7);
        assert!(seq.iter().all(|&r| r < 16));
        let hits = |r: usize| seq.iter().filter(|&&x| x == r).count();
        assert!(
            hits(0) > hits(15) + 10,
            "rank 0 must dominate rank 15 under s=1.1 ({} vs {})",
            hits(0),
            hits(15)
        );
        // every rank stays reachable
        let z0 = ZipfSampler::new(4, 0.0);
        let mut rng = Rng::seed(3);
        let mut seen = [false; 4];
        for _ in 0..256 {
            seen[z0.sample(&mut rng)] = true;
        }
        assert!(seen.iter().all(|&s| s), "uniform (s=0) must cover all ranks");
    }

    #[test]
    fn arrival_schedule_is_a_pure_function_of_the_seed() {
        let cfg = ReplayCfg { requests: 200, tenants: 12, ..ReplayCfg::default() };
        assert_eq!(arrival_schedule(&cfg), arrival_schedule(&cfg));
        let other = ReplayCfg { seed: cfg.seed + 1, ..cfg.clone() };
        assert_ne!(arrival_schedule(&cfg), arrival_schedule(&other));
    }
}
