//! Per-shard serving worker: one thread owning one [`AdapterRegistry`]
//! (its own `SharedBackbone` parse, its own sessions — nothing here is
//! ever `Send`) and draining its shard's bounded queue with dynamic
//! batching and the FIFO carry slot.
//!
//! Requests for one tenant are drained into a dynamic batch of up to
//! `max_batch`, closed early by a `max_wait` deadline, a message for a
//! different tenant, or a hot-swap.  FIFO order is preserved *per queue*
//! (and the admission layer routes each tenant to exactly one queue):
//! a message that closes a batch parks in the carry slot and is processed
//! before the next `recv`, so a swap can never overtake the requests
//! submitted ahead of it — including a carried same-tenant request.

use super::admission::{Msg, Request, ShardGauge};
use super::registry::AdapterRegistry;
use super::scheduler::SchedulerCfg;
use super::stats::{push_sample, ShardStats, TenantStats};
use crate::substrate::tensor::Tensor;
use anyhow::Result;
use std::collections::BTreeMap;
use std::sync::mpsc;
use std::time::Instant;

/// What a shard builder closure gets: which shard it is building, how
/// many shards exist, and the ownership predicate.  Register exactly the
/// tenants this shard [`owns`](ShardCtx::owns) — the scheduler rejects a
/// registry containing tenants that route elsewhere (they could never
/// receive a request).
#[derive(Clone, Copy, Debug)]
pub struct ShardCtx {
    shard: usize,
    shards: usize,
}

impl ShardCtx {
    pub(super) fn new(shard: usize, shards: usize) -> ShardCtx {
        ShardCtx { shard, shards }
    }

    /// This worker's shard id (0-based).
    pub fn shard(&self) -> usize {
        self.shard
    }

    /// Total shard worker count.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Whether `tenant` routes to this shard ([`super::shard_of`]).
    pub fn owns(&self, tenant: &str) -> bool {
        super::admission::shard_of(tenant, self.shards) == self.shard
    }
}

/// Drain the shard queue until every producer handle is dropped; returns
/// this shard's accounting plus its tenants' final stats.
pub(super) fn shard_loop(
    cfg: &SchedulerCfg,
    shard: usize,
    mut registry: AdapterRegistry,
    rx: mpsc::Receiver<Msg>,
    gauge: &ShardGauge,
) -> Result<(ShardStats, Vec<TenantStats>)> {
    let b = registry.spec().batch;
    let s = registry.spec().seq;
    let max_batch = if cfg.max_batch == 0 { b } else { cfg.max_batch.min(b) };
    let mut stats = ShardStats { shard, ..ShardStats::default() };
    let mut tenant_served: BTreeMap<String, u64> = BTreeMap::new();
    // a message that closed the previous batch; processed before recv so
    // queue order is never violated
    let mut carry: Option<Msg> = None;
    loop {
        let msg = match carry.take() {
            Some(m) => m,
            None => match rx.recv() {
                Ok(m) => {
                    gauge.on_dequeue();
                    m
                }
                Err(_) => break, // every handle dropped and queue drained
            },
        };
        match msg {
            Msg::Swap { tenant, params, ack } => {
                let _ = ack.send(registry.hot_swap(&tenant, params).map_err(|e| format!("{e:#}")));
            }
            Msg::Request(first) => {
                let tenant = first.tenant.clone();
                let deadline = Instant::now() + cfg.max_wait;
                let mut batch = vec![first];
                while batch.len() < max_batch {
                    let remaining = deadline.saturating_duration_since(Instant::now());
                    if remaining.is_zero() {
                        break;
                    }
                    match rx.recv_timeout(remaining) {
                        Ok(Msg::Request(r)) if r.tenant == tenant => {
                            gauge.on_dequeue();
                            batch.push(r);
                        }
                        // different tenant or a swap: close this batch and
                        // handle that message next (FIFO preserved)
                        Ok(other) => {
                            gauge.on_dequeue();
                            carry = Some(other);
                            break;
                        }
                        Err(mpsc::RecvTimeoutError::Timeout) => break,
                        Err(mpsc::RecvTimeoutError::Disconnected) => break,
                    }
                }
                run_batch(&mut registry, &mut stats, &mut tenant_served, b, s, batch);
            }
        }
    }
    // snapshot the registry's residency accounting into this shard's stats
    stats.resident_now = registry.resident_now();
    stats.resident_hwm = registry.resident_hwm();
    stats.evictions = registry.evictions_total();
    stats.cold_starts = registry.cold_starts_total();
    stats.cold_start_ms = registry.cold_start_window().to_vec();
    let mut tenants = Vec::new();
    for name in registry.tenant_names() {
        let cs = registry.cache_stats(&name).unwrap_or_default();
        let (hoisted_ops, hoist_skips, hoist_invalidations) = registry.hoist_stats(&name);
        tenants.push(TenantStats {
            shard,
            requests: tenant_served.get(&name).copied().unwrap_or(0),
            uploads: registry.upload_count(&name).unwrap_or(0),
            version: registry.version(&name).unwrap_or(0),
            spectra_hits: cs.spectra_hits,
            spectra_misses: cs.spectra_misses,
            plan_replays: registry.plan_replays(&name),
            hoisted_ops,
            hoist_skips,
            hoist_invalidations,
            sheds: 0, // admission-side count, filled in at merge
            resident: registry.is_resident(&name).unwrap_or(false),
            evictions: registry.evictions(&name).unwrap_or(0),
            cold_starts: registry.cold_starts(&name).unwrap_or(0),
            name,
        });
    }
    Ok((stats, tenants))
}

fn run_batch(
    registry: &mut AdapterRegistry,
    stats: &mut ShardStats,
    tenant_served: &mut BTreeMap<String, u64>,
    b: usize,
    s: usize,
    batch: Vec<Request>,
) {
    let tenant = batch[0].tenant.clone();
    // pad the dynamic batch up to the artifact batch with PAD rows
    let mut toks = vec![0i32; b * s];
    for (slot, r) in batch.iter().enumerate() {
        let n = r.tokens.len().min(s);
        toks[slot * s..slot * s + n].copy_from_slice(&r.tokens[..n]);
    }
    let data = vec![Tensor::from_i32(vec![b, s], &toks)];
    match registry.infer(&tenant, &data) {
        Ok((mut logits, _shape, version)) => {
            let row_w = logits.len() / b.max(1);
            let now = Instant::now();
            let n_batch = batch.len();
            push_sample(&mut stats.batch_sizes, stats.batches, n_batch);
            stats.batches += 1;
            stats.batch_size_sum += n_batch as u64;
            for (slot, r) in batch.into_iter().enumerate() {
                // earlier requests copy their row out; the final one is
                // handed the batch buffer itself, trimmed to its row, so
                // one reply per batch moves instead of copying
                let row = if slot + 1 == n_batch {
                    logits.truncate((slot + 1) * row_w);
                    if slot > 0 {
                        logits.drain(..slot * row_w);
                    }
                    std::mem::take(&mut logits)
                } else {
                    logits[slot * row_w..(slot + 1) * row_w].to_vec()
                };
                let pred = crate::substrate::linalg::argmax(&row);
                let latency_ms = now.duration_since(r.submitted).as_secs_f64() * 1e3;
                push_sample(&mut stats.latencies_ms, stats.served, latency_ms);
                stats.served += 1;
                *tenant_served.entry(tenant.clone()).or_insert(0) += 1;
                let reply = super::Reply {
                    tenant: tenant.clone(),
                    tenant_version: version,
                    logits: row,
                    pred,
                    batch_size: n_batch,
                    latency_ms,
                };
                let _ = r.reply.send(Ok(reply));
            }
        }
        Err(e) => {
            let msg = format!("{e:#}");
            stats.failed += batch.len() as u64;
            for r in batch {
                let _ = r.reply.send(Err(msg.clone()));
            }
        }
    }
}
