//! Shared admission layer: stable tenant→shard routing, per-shard bounded
//! queues, load-shedding accounting, and the cloneable producer handle.
//!
//! Routing is a pure function of the tenant name ([`shard_of`]: FNV-1a
//! mod shard count), so a tenant's every message — requests *and*
//! hot-swaps — lands on the same shard's queue in submission order.
//! Per-tenant FIFO therefore needs no cross-shard coordination at all:
//! it is inherited from the single `sync_channel` that carries the whole
//! tenant.  Backpressure is per shard: [`SubmitHandle::try_submit`]
//! surfaces that shard's full queue as [`SubmitError::QueueFull`]
//! (counted per shard and per tenant), while `submit` blocks for space.

use crate::substrate::prng::fnv1a;
use crate::substrate::tensor::TensorMap;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

/// Stable tenant→shard routing: FNV-1a of the tenant name mod the shard
/// count.  Deterministic across runs, processes, and platforms — the
/// replay bench and the routing tests pin exact values.
pub fn shard_of(tenant: &str, shards: usize) -> usize {
    if shards <= 1 {
        0
    } else {
        (fnv1a(tenant) % shards as u64) as usize
    }
}

/// One served request's outcome.
#[derive(Clone, Debug)]
pub struct Reply {
    pub tenant: String,
    /// adapter version the request was served under
    pub tenant_version: u64,
    /// this request's logits row (flattened per-example chunk)
    pub logits: Vec<f32>,
    /// argmax over the logits row (class id for pooled heads)
    pub pred: usize,
    /// dynamic batch size this request was served in
    pub batch_size: usize,
    /// submit-to-reply latency
    pub latency_ms: f64,
}

/// Submission failure.
#[derive(Debug)]
pub enum SubmitError {
    /// the tenant's shard queue is at capacity — shed or retry
    /// (backpressure; other shards' queues are unaffected)
    QueueFull,
    /// scheduler shut down (or the tenant's shard builder failed)
    Closed,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull => write!(f, "shard request queue is full (backpressure)"),
            SubmitError::Closed => write!(f, "scheduler is shut down"),
        }
    }
}

impl std::error::Error for SubmitError {}

pub(super) struct Request {
    pub tenant: String,
    pub tokens: Vec<i32>,
    pub submitted: Instant,
    pub reply: mpsc::Sender<std::result::Result<Reply, String>>,
}

pub(super) enum Msg {
    Request(Request),
    Swap {
        tenant: String,
        params: TensorMap,
        ack: mpsc::Sender<std::result::Result<u64, String>>,
    },
}

/// Receipt for a submitted request; `wait` blocks for the reply.
#[derive(Debug)]
pub struct Ticket {
    rx: mpsc::Receiver<std::result::Result<Reply, String>>,
}

impl Ticket {
    pub fn wait(self) -> Result<Reply> {
        match self.rx.recv() {
            Ok(Ok(r)) => Ok(r),
            Ok(Err(e)) => Err(anyhow!("{e}")),
            Err(_) => Err(anyhow!("scheduler dropped the request (shutdown)")),
        }
    }
}

/// One shard's admission-side gauges.  Depth is a signed count because
/// the worker's dequeue decrement can race slightly ahead of the
/// producer's post-send increment; the high-water mark only ever moves on
/// the producer side, after a send is known to have been admitted.
pub(super) struct ShardGauge {
    depth: AtomicI64,
    depth_hwm: AtomicI64,
    sheds: AtomicU64,
}

impl ShardGauge {
    fn new() -> ShardGauge {
        ShardGauge {
            depth: AtomicI64::new(0),
            depth_hwm: AtomicI64::new(0),
            sheds: AtomicU64::new(0),
        }
    }

    /// Producer side, after a successful send.
    fn on_admitted(&self) {
        // Relaxed: gauges are observability-only — nothing is published
        // through them (the queued message rides the channel, which has
        // its own synchronization), so cross-gauge ordering is free.
        let d = self.depth.fetch_add(1, Ordering::Relaxed) + 1;
        // Relaxed: monotonic max over this producer-side counter; racing
        // producers each fold in the depth *they* observed, and the hwm
        // only ever grows, so no ordering constraint tightens the bound.
        self.depth_hwm.fetch_max(d, Ordering::Relaxed);
    }

    /// Worker side, after each successful receive.
    pub(super) fn on_dequeue(&self) {
        // Relaxed: may transiently race ahead of the producer's increment
        // (depth is signed for exactly that reason); observability only.
        self.depth.fetch_sub(1, Ordering::Relaxed);
    }

    pub(super) fn hwm(&self) -> usize {
        // Relaxed: read at stats-collection time, after `finish` joined
        // the workers — already synchronized by the join.
        self.depth_hwm.load(Ordering::Relaxed).max(0) as usize
    }

    pub(super) fn sheds(&self) -> u64 {
        // Relaxed: observability counter, same argument as `hwm`.
        self.sheds.load(Ordering::Relaxed)
    }
}

/// Admission accounting shared between every [`SubmitHandle`] clone and
/// the shard workers.
pub(super) struct Admission {
    pub(super) gauges: Vec<ShardGauge>,
    /// tenant → `QueueFull` sheds (admission-side; includes tenants no
    /// shard has registered)
    tenant_sheds: Mutex<BTreeMap<String, u64>>,
}

impl Admission {
    pub(super) fn new(shards: usize) -> Admission {
        Admission {
            gauges: (0..shards).map(|_| ShardGauge::new()).collect(),
            tenant_sheds: Mutex::new(BTreeMap::new()),
        }
    }

    fn record_shed(&self, shard: usize, tenant: &str) {
        // Relaxed: shed tally (observability only; no memory published).
        self.gauges[shard].sheds.fetch_add(1, Ordering::Relaxed);
        let mut map = self.tenant_sheds.lock().unwrap();
        *map.entry(tenant.to_string()).or_insert(0) += 1;
    }

    pub(super) fn tenant_sheds(&self) -> BTreeMap<String, u64> {
        self.tenant_sheds.lock().unwrap().clone()
    }
}

/// Cloneable producer handle over every shard queue.  Drop every handle
/// (and call [`super::Scheduler::finish`]) to let the shard workers drain
/// and exit.
#[derive(Clone)]
pub struct SubmitHandle {
    txs: Arc<Vec<mpsc::SyncSender<Msg>>>,
    adm: Arc<Admission>,
}

impl SubmitHandle {
    pub(super) fn new(txs: Arc<Vec<mpsc::SyncSender<Msg>>>, adm: Arc<Admission>) -> SubmitHandle {
        SubmitHandle { txs, adm }
    }

    /// Shard worker count this handle routes over.
    pub fn shards(&self) -> usize {
        self.txs.len()
    }

    /// The shard `tenant`'s every message routes to.
    pub fn shard_for(&self, tenant: &str) -> usize {
        shard_of(tenant, self.txs.len())
    }

    fn request(&self, tenant: &str, tokens: Vec<i32>) -> (Msg, Ticket) {
        let (rtx, rrx) = mpsc::channel();
        let req = Request {
            tenant: tenant.to_string(),
            tokens,
            submitted: Instant::now(),
            reply: rtx,
        };
        (Msg::Request(req), Ticket { rx: rrx })
    }

    /// Non-blocking submit: `Err(QueueFull)` when the tenant's shard
    /// queue is at capacity (the shed is counted per shard and per
    /// tenant), `Err(Closed)` after shutdown.
    pub fn try_submit(
        &self,
        tenant: &str,
        tokens: Vec<i32>,
    ) -> std::result::Result<Ticket, SubmitError> {
        let sh = self.shard_for(tenant);
        let (msg, ticket) = self.request(tenant, tokens);
        match self.txs[sh].try_send(msg) {
            Ok(()) => {
                self.adm.gauges[sh].on_admitted();
                Ok(ticket)
            }
            Err(mpsc::TrySendError::Full(_)) => {
                self.adm.record_shed(sh, tenant);
                Err(SubmitError::QueueFull)
            }
            Err(mpsc::TrySendError::Disconnected(_)) => Err(SubmitError::Closed),
        }
    }

    /// Blocking submit: waits for space in the tenant's shard queue
    /// instead of shedding.
    pub fn submit(
        &self,
        tenant: &str,
        tokens: Vec<i32>,
    ) -> std::result::Result<Ticket, SubmitError> {
        let sh = self.shard_for(tenant);
        let (msg, ticket) = self.request(tenant, tokens);
        match self.txs[sh].send(msg) {
            Ok(()) => {
                self.adm.gauges[sh].on_admitted();
                Ok(ticket)
            }
            Err(_) => Err(SubmitError::Closed),
        }
    }

    /// Atomically replace `tenant`'s adapter, ordered with respect to its
    /// shard queue: every request for that tenant submitted before the
    /// swap serves under the old version (the swap rides the same
    /// per-shard FIFO as the tenant's requests, so no cross-shard
    /// coordination is needed).  Blocks until the shard worker acks with
    /// the new version.
    pub fn hot_swap(&self, tenant: &str, params: TensorMap) -> Result<u64> {
        let sh = self.shard_for(tenant);
        let (atx, arx) = mpsc::channel();
        let msg = Msg::Swap { tenant: tenant.to_string(), params, ack: atx };
        self.txs[sh].send(msg).map_err(|_| anyhow!("scheduler is shut down"))?;
        self.adm.gauges[sh].on_admitted();
        match arx.recv() {
            Ok(Ok(v)) => Ok(v),
            Ok(Err(e)) => Err(anyhow!("{e}")),
            Err(_) => Err(anyhow!("scheduler closed before acking hot_swap")),
        }
    }
}
