//! Latency accounting shared by the serve example, `bench_serve`, and the
//! serving tests.
//!
//! Samples are ordered with `f64::total_cmp`: a NaN latency (clock
//! weirdness, a poisoned measurement) sorts after +inf instead of
//! panicking the whole report — the same fix `metrics::ranks` applies to
//! Spearman inputs.

/// Percentile (p in [0, 1]) of an ascending-sorted sample, by truncated
/// index — the convention the serve report has always used.
pub fn percentile(sorted_ms: &[f64], p: f64) -> f64 {
    if sorted_ms.is_empty() {
        return 0.0;
    }
    let idx = (p.clamp(0.0, 1.0) * (sorted_ms.len() - 1) as f64) as usize;
    sorted_ms[idx.min(sorted_ms.len() - 1)]
}

/// p50/p95/p99 + mean of a latency sample.
#[derive(Clone, Copy, Debug, Default)]
pub struct LatencySummary {
    pub n: usize,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
}

impl LatencySummary {
    /// Summarize a sample (sorts a copy with `total_cmp`).
    pub fn from_samples(samples: &[f64]) -> LatencySummary {
        if samples.is_empty() {
            return LatencySummary::default();
        }
        let mut s = samples.to_vec();
        s.sort_by(f64::total_cmp);
        LatencySummary {
            n: s.len(),
            p50_ms: percentile(&s, 0.50),
            p95_ms: percentile(&s, 0.95),
            p99_ms: percentile(&s, 0.99),
            mean_ms: s.iter().sum::<f64>() / s.len() as f64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_of_known_sample() {
        let s: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let l = LatencySummary::from_samples(&s);
        assert_eq!(l.n, 100);
        assert_eq!(l.p50_ms, 50.0);
        assert_eq!(l.p95_ms, 95.0);
        assert_eq!(l.p99_ms, 99.0);
        assert!((l.mean_ms - 50.5).abs() < 1e-12);
    }

    #[test]
    fn nan_latency_does_not_panic_the_report() {
        // regression: the old serve report sorted with
        // `partial_cmp(..).unwrap()`, so one NaN latency panicked it
        let s = [3.0, f64::NAN, 1.0, 2.0];
        let l = LatencySummary::from_samples(&s);
        // sorted: [1, 2, 3, NaN]; truncated indices 1 and 2
        assert_eq!(l.p50_ms, 2.0);
        assert_eq!(l.p99_ms, 3.0);
        assert!(l.mean_ms.is_nan()); // the mean honestly reports the NaN
        // NaN sorts last, so it surfaces at the very top of the range
        let mut two = [1.0, f64::NAN];
        two.sort_by(f64::total_cmp);
        assert!(percentile(&two, 1.0).is_nan());
    }

    #[test]
    fn empty_sample_is_zeroed() {
        let l = LatencySummary::from_samples(&[]);
        assert_eq!(l.n, 0);
        assert_eq!(l.p50_ms, 0.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
    }
}
