//! Serving statistics: latency percentile accounting plus the per-shard
//! and per-tenant counters of the sharded runtime, and the cross-shard
//! merge rules.
//!
//! Two disciplines are load-bearing here:
//!
//! * Samples are ordered with `f64::total_cmp`: a NaN latency (clock
//!   weirdness, a poisoned measurement) sorts after +inf instead of
//!   panicking the whole report — the same fix `metrics::ranks` applies
//!   to Spearman inputs.
//! * Cross-shard aggregation merges the shards' **raw sample windows**
//!   and computes percentiles over the union.  Per-shard percentiles are
//!   *never* averaged: the p99 of a union is a rank statistic of the
//!   pooled samples, and averaging per-shard p99s under-reports the tail
//!   whenever load (or latency) is skewed across shards — which is the
//!   normal state under Zipf tenant popularity.
//!   [`merge_windows_are_pooled_not_averaged`] pins this.

/// Percentile (p in [0, 1]) of an ascending-sorted sample, nearest-rank:
/// the ⌈p·n⌉-th smallest value (p = 0 yields the minimum).
///
/// The old truncated-index form `(p · (n−1)) as usize` under-read small
/// windows: on a 2-sample window ⌊0.99·1⌋ = 0, so p99 returned the
/// *minimum* — a tail-latency report that hides the tail.  Nearest-rank
/// returns the single sample for n = 1 (never panics or reads out of
/// bounds) and the maximum for p99 of n = 2.
pub fn percentile(sorted_ms: &[f64], p: f64) -> f64 {
    if sorted_ms.is_empty() {
        return 0.0;
    }
    let n = sorted_ms.len();
    let rank = (p.clamp(0.0, 1.0) * n as f64).ceil() as usize;
    sorted_ms[rank.saturating_sub(1).min(n - 1)]
}

/// p50/p95/p99 + mean of a latency sample.
#[derive(Clone, Copy, Debug, Default)]
pub struct LatencySummary {
    pub n: usize,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
}

impl LatencySummary {
    /// Summarize a sample (sorts a copy with `total_cmp`).
    pub fn from_samples(samples: &[f64]) -> LatencySummary {
        if samples.is_empty() {
            return LatencySummary::default();
        }
        let mut s = samples.to_vec();
        s.sort_by(f64::total_cmp);
        LatencySummary {
            n: s.len(),
            p50_ms: percentile(&s, 0.50),
            p95_ms: percentile(&s, 0.95),
            p99_ms: percentile(&s, 0.99),
            mean_ms: s.iter().sum::<f64>() / s.len() as f64,
        }
    }
}

/// Cap on each shard's per-request/per-batch sample windows: a long-lived
/// worker must not grow per-request state without bound, so beyond this
/// many samples a window becomes a ring buffer holding the most recent
/// entries (counters and sums stay exact forever).
pub const SAMPLE_CAP: usize = 65_536;

/// Push into a capped window: append until [`SAMPLE_CAP`], then overwrite
/// ring-buffer style using the caller's monotone event counter.
pub(crate) fn push_sample<T>(window: &mut Vec<T>, event_idx: u64, value: T) {
    if window.len() < SAMPLE_CAP {
        window.push(value);
    } else {
        window[(event_idx as usize) % SAMPLE_CAP] = value;
    }
}

/// Final per-tenant accounting, snapshotted when the scheduler drains.
#[derive(Clone, Debug)]
pub struct TenantStats {
    pub name: String,
    /// shard worker this tenant is affine to (`shard_of(name, shards)`)
    pub shard: usize,
    pub requests: u64,
    /// adapter uploads (1 per adapter version under the serving pattern)
    pub uploads: usize,
    pub version: u64,
    pub spectra_hits: u64,
    pub spectra_misses: u64,
    /// execution-plan replays by this tenant's session (requests minus
    /// the one recording call, under the steady-state serving pattern;
    /// 0 when plans are disabled via `C3A_PLAN=0`)
    pub plan_replays: u64,
    /// plan ops classified version-invariant by the hoisting pass (0
    /// before the first request, with `C3A_HOIST=0` at record time, or
    /// for methods whose adapter math stays on the request side)
    pub hoisted_ops: usize,
    /// op recomputations skipped by hoisting across this tenant's
    /// replays (survives eviction)
    pub hoist_skips: u64,
    /// replays that recomputed the hoisted prefix because the adapter
    /// version fingerprint changed (hot-swap / cold-start re-upload)
    pub hoist_invalidations: u64,
    /// `try_submit` rejections for this tenant at the admission layer
    /// (its shard's bounded queue was full) — filled in at merge time
    pub sheds: u64,
    /// whether the tenant was resident (session + arena live) when the
    /// scheduler drained
    pub resident: bool,
    /// times this tenant's session was evicted to the adapter store
    pub evictions: u64,
    /// requests that found this tenant evicted and paid the cold-start
    /// path (load → session → upload → spectra → plan re-record)
    pub cold_starts: u64,
}

/// One shard worker's accounting: its own served/failed counters and its
/// own *raw* sample windows (kept raw so the cross-shard merge can pool
/// them — see the module docs).
#[derive(Clone, Debug, Default)]
pub struct ShardStats {
    pub shard: usize,
    pub served: u64,
    pub batches: u64,
    /// requests refused because their tenant was unknown (or inference
    /// failed); each got an error reply
    pub failed: u64,
    /// exact running sum of dynamic batch sizes
    pub batch_size_sum: u64,
    /// most recent [`SAMPLE_CAP`] batch sizes (bounded window)
    pub batch_sizes: Vec<usize>,
    /// most recent [`SAMPLE_CAP`] request latencies (bounded window)
    pub latencies_ms: Vec<f64>,
    /// high-water mark of this shard's queue depth (admitted messages,
    /// requests and swaps alike)
    pub queue_depth_hwm: usize,
    /// `try_submit` rejections against this shard's full queue
    pub sheds: u64,
    /// resident tenants when the shard drained / the high-water mark —
    /// the hwm must never exceed `ResidentPolicy::max_resident`
    pub resident_now: usize,
    pub resident_hwm: usize,
    /// total evictions / cold starts on this shard
    pub evictions: u64,
    pub cold_starts: u64,
    /// most recent [`SAMPLE_CAP`] cold-start wall times (bounded window,
    /// pooled across shards exactly like `latencies_ms`)
    pub cold_start_ms: Vec<f64>,
}

impl ShardStats {
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batch_size_sum as f64 / self.batches as f64
        }
    }

    /// This shard's own latency percentiles (over its raw window).
    pub fn latency(&self) -> LatencySummary {
        LatencySummary::from_samples(&self.latencies_ms)
    }

    /// This shard's own cold-start percentiles (over its raw window).
    pub fn cold_start_latency(&self) -> LatencySummary {
        LatencySummary::from_samples(&self.cold_start_ms)
    }
}

/// What [`super::Scheduler::finish`] hands back: the cross-shard
/// aggregate plus per-shard and per-tenant detail.
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    pub served: u64,
    pub batches: u64,
    pub failed: u64,
    /// exact running sum of dynamic batch sizes (drives [`ServeStats::mean_batch`])
    pub batch_size_sum: u64,
    /// union of the shards' batch-size windows (shard order)
    pub batch_sizes: Vec<usize>,
    /// union of the shards' raw latency windows (shard order); the
    /// percentile report covers this pooled window, not all-time
    pub latencies_ms: Vec<f64>,
    /// total `try_submit` rejections at the admission layer (includes
    /// sheds for tenants no shard knows about)
    pub sheds: u64,
    /// total evictions / cold starts across shards
    pub evictions: u64,
    pub cold_starts: u64,
    /// union of the shards' raw cold-start windows (shard order)
    pub cold_start_ms: Vec<f64>,
    /// every shard's tenants, sorted by name
    pub tenants: Vec<TenantStats>,
    /// per-shard detail, sorted by shard id
    pub shards: Vec<ShardStats>,
}

impl ServeStats {
    /// Pool the shard outputs into the aggregate view.  Counters add;
    /// sample windows concatenate in shard order and percentiles are
    /// computed over the pooled samples (never by averaging per-shard
    /// percentiles); tenants flatten into one name-sorted list.
    pub fn merge(mut outs: Vec<(ShardStats, Vec<TenantStats>)>) -> ServeStats {
        outs.sort_by_key(|(s, _)| s.shard);
        let mut m = ServeStats::default();
        for (shard, tenants) in outs {
            m.served += shard.served;
            m.batches += shard.batches;
            m.failed += shard.failed;
            m.batch_size_sum += shard.batch_size_sum;
            m.sheds += shard.sheds;
            m.evictions += shard.evictions;
            m.cold_starts += shard.cold_starts;
            m.batch_sizes.extend_from_slice(&shard.batch_sizes);
            m.latencies_ms.extend_from_slice(&shard.latencies_ms);
            m.cold_start_ms.extend_from_slice(&shard.cold_start_ms);
            m.tenants.extend(tenants);
            m.shards.push(shard);
        }
        m.tenants.sort_by(|a, b| a.name.cmp(&b.name));
        m
    }

    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batch_size_sum as f64 / self.batches as f64
        }
    }

    /// Aggregate latency percentiles over the pooled raw windows.
    pub fn latency(&self) -> LatencySummary {
        LatencySummary::from_samples(&self.latencies_ms)
    }

    /// Aggregate cold-start percentiles over the pooled raw windows
    /// (same discipline as [`latency`](ServeStats::latency): never an
    /// average of per-shard percentiles).
    pub fn cold_start_latency(&self) -> LatencySummary {
        LatencySummary::from_samples(&self.cold_start_ms)
    }

    /// Residents across shards when the scheduler drained.
    pub fn resident_now(&self) -> usize {
        self.shards.iter().map(|s| s.resident_now).sum()
    }

    /// Largest per-shard resident high-water mark — under a
    /// `max_resident` policy this must stay ≤ the per-shard cap.
    pub fn resident_hwm(&self) -> usize {
        self.shards.iter().map(|s| s.resident_hwm).max().unwrap_or(0)
    }

    pub fn tenant(&self, name: &str) -> Option<&TenantStats> {
        self.tenants.iter().find(|t| t.name == name)
    }

    /// Shards that actually served at least one request — the replay
    /// bench asserts load spread with this.
    pub fn active_shards(&self) -> usize {
        self.shards.iter().filter(|s| s.served > 0).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tenant(name: &str, shard: usize, requests: u64) -> TenantStats {
        TenantStats {
            name: name.to_string(),
            shard,
            requests,
            uploads: 1,
            version: 1,
            spectra_hits: 0,
            spectra_misses: 0,
            plan_replays: 0,
            hoisted_ops: 0,
            hoist_skips: 0,
            hoist_invalidations: 0,
            sheds: 0,
            resident: true,
            evictions: 0,
            cold_starts: 0,
        }
    }

    #[test]
    fn percentiles_of_known_sample() {
        let s: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let l = LatencySummary::from_samples(&s);
        assert_eq!(l.n, 100);
        assert_eq!(l.p50_ms, 50.0);
        assert_eq!(l.p95_ms, 95.0);
        assert_eq!(l.p99_ms, 99.0);
        assert!((l.mean_ms - 50.5).abs() < 1e-12);
    }

    #[test]
    fn nan_latency_does_not_panic_the_report() {
        // regression: the old serve report sorted with
        // `partial_cmp(..).unwrap()`, so one NaN latency panicked it
        let s = [3.0, f64::NAN, 1.0, 2.0];
        let l = LatencySummary::from_samples(&s);
        // sorted: [1, 2, 3, NaN]; nearest ranks ⌈.5·4⌉=2 and ⌈.99·4⌉=4
        assert_eq!(l.p50_ms, 2.0);
        assert!(l.p99_ms.is_nan(), "a NaN inside the top 1% must surface in p99");
        assert!(l.mean_ms.is_nan()); // the mean honestly reports the NaN
        // NaN sorts last, so it surfaces at the very top of the range
        let mut two = [1.0, f64::NAN];
        two.sort_by(f64::total_cmp);
        assert!(percentile(&two, 1.0).is_nan());
    }

    /// Regression for the 1-/2-sample windows: p99 of a single sample is
    /// that sample (no panic, no out-of-bounds), and p99 of two samples is
    /// the larger one — the old truncated index returned the *minimum*.
    #[test]
    fn tiny_window_percentiles() {
        let one = [7.5];
        for p in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(percentile(&one, p), 7.5, "p={p}");
        }
        let two = [1.0, 100.0];
        assert_eq!(percentile(&two, 0.0), 1.0);
        assert_eq!(percentile(&two, 0.5), 1.0); // ⌈.5·2⌉ = 1st smallest
        assert_eq!(percentile(&two, 0.95), 100.0);
        assert_eq!(percentile(&two, 0.99), 100.0, "p99 of 2 samples must report the tail");
        let l = LatencySummary::from_samples(&[100.0, 1.0]);
        assert_eq!(l.p99_ms, 100.0);
        assert_eq!(l.p50_ms, 1.0);
    }

    #[test]
    fn empty_sample_is_zeroed() {
        let l = LatencySummary::from_samples(&[]);
        assert_eq!(l.n, 0);
        assert_eq!(l.p50_ms, 0.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
    }

    /// The merge rule with teeth: a fast shard and a slow shard.  The
    /// pooled p99 must be a rank statistic of the union — clearly distinct
    /// from the mean of the two per-shard p99s, which under-reports the
    /// tail whenever load is skewed.
    #[test]
    fn merge_windows_are_pooled_not_averaged() {
        // fast shard: 99 samples at 1ms; slow shard: 99 samples at 100ms
        let fast = ShardStats {
            shard: 0,
            served: 99,
            batches: 99,
            batch_size_sum: 99,
            latencies_ms: vec![1.0; 99],
            batch_sizes: vec![1; 99],
            ..ShardStats::default()
        };
        let slow = ShardStats {
            shard: 1,
            served: 99,
            batches: 33,
            batch_size_sum: 99,
            latencies_ms: vec![100.0; 99],
            batch_sizes: vec![3; 33],
            ..ShardStats::default()
        };
        let p99_fast = fast.latency().p99_ms;
        let p99_slow = slow.latency().p99_ms;
        let m = ServeStats::merge(vec![(slow, vec![]), (fast, vec![])]);
        assert_eq!(m.served, 198);
        assert_eq!(m.batches, 132);
        assert_eq!(m.latencies_ms.len(), 198);
        // pooled nearest-rank p99 over 198 samples = 196th smallest = 100ms
        let pooled = m.latency();
        assert_eq!(pooled.p99_ms, 100.0);
        assert_ne!(
            pooled.p99_ms,
            (p99_fast + p99_slow) / 2.0,
            "pooled p99 must not equal the per-shard average"
        );
        // pooled p50 over [99×1ms, 99×100ms]: ⌈.5·198⌉ = 99th smallest = 1ms
        assert_eq!(pooled.p50_ms, 1.0);
        // shards come back sorted by id with their raw windows intact
        assert_eq!(m.shards[0].shard, 0);
        assert_eq!(m.shards[1].shard, 1);
        assert_eq!(m.shards[0].latency().p99_ms, p99_fast);
        assert_eq!(m.shards[1].latency().p99_ms, p99_slow);
        assert!((m.mean_batch() - 198.0 / 132.0).abs() < 1e-12);
    }

    #[test]
    fn merge_flattens_and_sorts_tenants() {
        let s0 = ShardStats { shard: 0, served: 3, ..ShardStats::default() };
        let s1 = ShardStats { shard: 1, served: 2, sheds: 4, ..ShardStats::default() };
        let m = ServeStats::merge(vec![
            (s1, vec![tenant("zeta", 1, 2)]),
            (s0, vec![tenant("alpha", 0, 1), tenant("mid", 0, 2)]),
        ]);
        let names: Vec<&str> = m.tenants.iter().map(|t| t.name.as_str()).collect();
        assert_eq!(names, ["alpha", "mid", "zeta"]);
        assert_eq!(m.tenant("zeta").unwrap().shard, 1);
        assert_eq!(m.sheds, 4);
        assert_eq!(m.active_shards(), 2);
    }

    /// Cold-start windows follow the same merge discipline as latencies:
    /// pooled raw samples, rank-statistic percentiles, counters additive,
    /// and the resident hwm is a max (a per-shard bound), never a sum.
    #[test]
    fn merge_pools_cold_start_windows_and_maxes_resident_hwm() {
        let warm = ShardStats {
            shard: 0,
            resident_now: 3,
            resident_hwm: 4,
            evictions: 10,
            cold_starts: 2,
            cold_start_ms: vec![5.0, 6.0],
            ..ShardStats::default()
        };
        let churny = ShardStats {
            shard: 1,
            resident_now: 2,
            resident_hwm: 2,
            evictions: 90,
            cold_starts: 99,
            cold_start_ms: vec![50.0; 99],
            ..ShardStats::default()
        };
        let m = ServeStats::merge(vec![(churny, vec![]), (warm, vec![])]);
        assert_eq!(m.evictions, 100);
        assert_eq!(m.cold_starts, 101);
        assert_eq!(m.cold_start_ms.len(), 101);
        assert_eq!(m.resident_now(), 5);
        assert_eq!(m.resident_hwm(), 4, "hwm is a per-shard bound: max, not sum");
        // pooled p50 over [5, 6, 50×99]: ⌈.5·101⌉ = 51st smallest = 50ms
        assert_eq!(m.cold_start_latency().p50_ms, 50.0);
        assert_eq!(m.shards[0].cold_start_latency().p99_ms, 6.0);
    }

    #[test]
    fn push_sample_caps_the_window() {
        let mut w = Vec::new();
        for i in 0..(SAMPLE_CAP as u64 + 10) {
            push_sample(&mut w, i, i);
        }
        assert_eq!(w.len(), SAMPLE_CAP);
        // the first 10 ring slots hold the overwrites
        assert_eq!(w[0], SAMPLE_CAP as u64);
        assert_eq!(w[9], SAMPLE_CAP as u64 + 9);
        assert_eq!(w[10], 10);
    }
}
