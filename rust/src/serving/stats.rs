//! Latency accounting shared by the serve example, `bench_serve`, and the
//! serving tests.
//!
//! Samples are ordered with `f64::total_cmp`: a NaN latency (clock
//! weirdness, a poisoned measurement) sorts after +inf instead of
//! panicking the whole report — the same fix `metrics::ranks` applies to
//! Spearman inputs.

/// Percentile (p in [0, 1]) of an ascending-sorted sample, nearest-rank:
/// the ⌈p·n⌉-th smallest value (p = 0 yields the minimum).
///
/// The old truncated-index form `(p · (n−1)) as usize` under-read small
/// windows: on a 2-sample window ⌊0.99·1⌋ = 0, so p99 returned the
/// *minimum* — a tail-latency report that hides the tail.  Nearest-rank
/// returns the single sample for n = 1 (never panics or reads out of
/// bounds) and the maximum for p99 of n = 2.
pub fn percentile(sorted_ms: &[f64], p: f64) -> f64 {
    if sorted_ms.is_empty() {
        return 0.0;
    }
    let n = sorted_ms.len();
    let rank = (p.clamp(0.0, 1.0) * n as f64).ceil() as usize;
    sorted_ms[rank.saturating_sub(1).min(n - 1)]
}

/// p50/p95/p99 + mean of a latency sample.
#[derive(Clone, Copy, Debug, Default)]
pub struct LatencySummary {
    pub n: usize,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
}

impl LatencySummary {
    /// Summarize a sample (sorts a copy with `total_cmp`).
    pub fn from_samples(samples: &[f64]) -> LatencySummary {
        if samples.is_empty() {
            return LatencySummary::default();
        }
        let mut s = samples.to_vec();
        s.sort_by(f64::total_cmp);
        LatencySummary {
            n: s.len(),
            p50_ms: percentile(&s, 0.50),
            p95_ms: percentile(&s, 0.95),
            p99_ms: percentile(&s, 0.99),
            mean_ms: s.iter().sum::<f64>() / s.len() as f64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_of_known_sample() {
        let s: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let l = LatencySummary::from_samples(&s);
        assert_eq!(l.n, 100);
        assert_eq!(l.p50_ms, 50.0);
        assert_eq!(l.p95_ms, 95.0);
        assert_eq!(l.p99_ms, 99.0);
        assert!((l.mean_ms - 50.5).abs() < 1e-12);
    }

    #[test]
    fn nan_latency_does_not_panic_the_report() {
        // regression: the old serve report sorted with
        // `partial_cmp(..).unwrap()`, so one NaN latency panicked it
        let s = [3.0, f64::NAN, 1.0, 2.0];
        let l = LatencySummary::from_samples(&s);
        // sorted: [1, 2, 3, NaN]; nearest ranks ⌈.5·4⌉=2 and ⌈.99·4⌉=4
        assert_eq!(l.p50_ms, 2.0);
        assert!(l.p99_ms.is_nan(), "a NaN inside the top 1% must surface in p99");
        assert!(l.mean_ms.is_nan()); // the mean honestly reports the NaN
        // NaN sorts last, so it surfaces at the very top of the range
        let mut two = [1.0, f64::NAN];
        two.sort_by(f64::total_cmp);
        assert!(percentile(&two, 1.0).is_nan());
    }

    /// Regression for the 1-/2-sample windows: p99 of a single sample is
    /// that sample (no panic, no out-of-bounds), and p99 of two samples is
    /// the larger one — the old truncated index returned the *minimum*.
    #[test]
    fn tiny_window_percentiles() {
        let one = [7.5];
        for p in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(percentile(&one, p), 7.5, "p={p}");
        }
        let two = [1.0, 100.0];
        assert_eq!(percentile(&two, 0.0), 1.0);
        assert_eq!(percentile(&two, 0.5), 1.0); // ⌈.5·2⌉ = 1st smallest
        assert_eq!(percentile(&two, 0.95), 100.0);
        assert_eq!(percentile(&two, 0.99), 100.0, "p99 of 2 samples must report the tail");
        let l = LatencySummary::from_samples(&[100.0, 1.0]);
        assert_eq!(l.p99_ms, 100.0);
        assert_eq!(l.p50_ms, 1.0);
    }

    #[test]
    fn empty_sample_is_zeroed() {
        let l = LatencySummary::from_samples(&[]);
        assert_eq!(l.n, 0);
        assert_eq!(l.p50_ms, 0.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
    }
}
