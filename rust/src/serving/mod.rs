//! Multi-tenant serving: many C3A adapters over frozen backbones, sharded
//! across N tenant-affine workers.
//!
//! This is the operational payoff of the paper's economics (§1): adapters
//! are tiny (d²/b params per projection), so a deployment serves frozen
//! backbones and swaps cheap per-tenant kernels in front of them.  The
//! subsystem has six layers:
//!
//! * [`stats`] — latency percentile accounting (`total_cmp`-ordered, so a
//!   NaN-poisoned sample can never panic a report) and the cross-shard
//!   merge rules: raw sample windows are pooled before percentiles are
//!   computed — per-shard percentiles are never averaged;
//! * [`store::AdapterStore`] — the disk tier: one versioned, checksummed
//!   snapshot file per tenant, bitwise round-trips, crash-safe temp+rename
//!   writes; the source of truth for evicted tenants;
//! * [`registry::AdapterRegistry`] — named adapter snapshots over a single
//!   shared frozen-backbone parse ([`crate::runtime::session::SharedBackbone`]):
//!   one `EvalSession` (and one private spectra cache / upload slot) per
//!   *resident* tenant, `hot_swap` to atomically replace a tenant's
//!   adapter, and a tiered lifecycle under [`registry::ResidentPolicy`] —
//!   LRU eviction to the store, measured cold-start reloads, bit-identical
//!   either way;
//! * [`admission`] — stable tenant→shard routing ([`shard_of`]: FNV-1a of
//!   the tenant name), per-shard bounded queues, `QueueFull` load-shedding
//!   with per-shard/per-tenant shed and depth accounting, and the
//!   cloneable [`SubmitHandle`];
//! * [`worker`] — one thread per shard owning that shard's registry (its
//!   own backbone parse; sessions stay thread-affine, so nothing is ever
//!   `Send`), with per-tenant dynamic batching and the FIFO carry slot;
//! * [`scheduler::Scheduler`] — spawns the shard workers and merges their
//!   stats on [`Scheduler::finish`].  `shards = 1` (the default) is
//!   bit-identical to the pre-sharding single-thread scheduler.
//!
//! [`replay`] drives it: a seeded traffic generator (Zipf tenant
//! popularity, bursty arrivals, mid-storm hot-swaps, bounded shed
//! backoff) whose arrival schedule is a pure function of its seed.
//!
//! Invariants pinned by `rust/tests/serving.rs` + `serving_sharded.rs`:
//! a hot-swap bumps only the target tenant's version (its next request
//! re-uploads and recomputes spectra tenant-locally); swaps never reorder
//! against the tenant's in-flight requests, even across the carry slot
//! and even while other shards keep serving; routing is deterministic
//! across runs.
//!
//! Shard workers compute concurrently on separate cores; inside one
//! request the substrate thread pool (`C3A_THREADS`) additionally shards
//! rows, and a pool busy with another shard's region degrades that
//! region to inline execution — never a deadlock, bit-identical results
//! (see `substrate/parallel.rs`).

pub mod admission;
pub mod registry;
pub mod replay;
pub mod scheduler;
pub mod stats;
pub mod store;
pub mod worker;

pub use admission::{shard_of, Reply, SubmitError, SubmitHandle, Ticket};
pub use registry::{perturb_c3a_kernels, AdapterRegistry, ResidentPolicy};
pub use replay::{
    arrival_schedule, run_replay, tenant_name, ReplayCfg, ReplayReport, ZipfSampler,
};
pub use scheduler::{Scheduler, SchedulerCfg};
pub use stats::{percentile, LatencySummary, ServeStats, ShardStats, TenantStats, SAMPLE_CAP};
pub use store::AdapterStore;
pub use worker::ShardCtx;
