//! Multi-tenant serving: many C3A adapters over one frozen backbone.
//!
//! This is the operational payoff of the paper's economics (§1): adapters
//! are tiny (d²/b params per projection), so a deployment serves one
//! frozen backbone and swaps cheap per-tenant kernels in front of it.
//! The subsystem has three layers:
//!
//! * [`stats`] — latency percentile accounting (`total_cmp`-ordered, so a
//!   NaN-poisoned sample can never panic a report);
//! * [`registry::AdapterRegistry`] — named adapter snapshots over a single
//!   shared frozen-backbone parse ([`crate::runtime::session::SharedBackbone`]):
//!   one `EvalSession` (and one private spectra cache / upload slot) per
//!   tenant, `hot_swap` to atomically replace a tenant's adapter;
//! * [`scheduler::Scheduler`] — a bounded request queue with dynamic
//!   batching (max-wait deadline), backpressure via `try_submit`, and
//!   ordered hot-swaps, running the registry on a dedicated thread
//!   (sessions are deliberately not `Send`; requests are).
//!
//! Invalidation contract: a hot-swap bumps only the target tenant's
//! version; its next request re-uploads the adapter (`upload_count` + 1)
//! and recomputes its kernel spectra, while every other tenant keeps
//! hitting its caches.  `rust/tests/serving.rs` pins all of this.

pub mod registry;
pub mod scheduler;
pub mod stats;

pub use registry::{AdapterRegistry, perturb_c3a_kernels};
pub use scheduler::{
    Reply, Scheduler, SchedulerCfg, ServeStats, SubmitError, SubmitHandle, TenantStats, Ticket,
};
pub use stats::{percentile, LatencySummary};
