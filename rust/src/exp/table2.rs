//! Table 2 — GLUE-sim: RoBERTa-sim Base/Large × PEFT methods × 6 tasks.
//! Also prints the §4.1 rank measurement ("most ΔW are full rank") and the
//! modeled memory column.

use super::{fmt_params, ExpOpt};
use crate::coordinator::run::{self, Ctx};
use crate::data::glue_sim::GlueTask;
use crate::metrics::Stats;
use crate::peft::accounting::{transformer_account, ProjSpec};
use crate::peft::init::C3aScheme;
use crate::substrate::json::{self, Json};
use anyhow::Result;

pub const METHODS: [&str; 8] =
    ["full", "bitfit", "ia3", "lora", "vera", "boft", "c3a_d1", "c3a_d8"];

fn mem_bytes(ctx: &Ctx, model: &str, method: &str) -> Result<usize> {
    let meta = ctx.manifest.model(model)?;
    let backbone: usize = 30 * meta.d * meta.d * meta.layers; // rough dense count
    let act = 32 * meta.seq * meta.d * meta.layers;
    let d = meta.d;
    let acc = transformer_account(meta.layers, d, backbone, act, |dd| match method {
        "lora" => ProjSpec::lora(dd, 8),
        "vera" => ProjSpec::vera(dd, 2 * dd),
        "c3a_d1" => ProjSpec::c3a(dd, dd),
        "c3a_d8" => ProjSpec::c3a(dd, dd / 8),
        "boft" => ProjSpec { method: crate::peft::Method::Boft, ..ProjSpec::lora(dd, 0) },
        "ia3" => ProjSpec { method: crate::peft::Method::Ia3, ..ProjSpec::lora(dd, 0) },
        "bitfit" => ProjSpec { method: crate::peft::Method::BitFit, ..ProjSpec::lora(dd, 0) },
        _ => ProjSpec { method: crate::peft::Method::Full, ..ProjSpec::lora(dd, 0) },
    });
    let mut bytes = acc.train_bytes();
    if method == "full" {
        bytes += 3 * 4 * backbone; // grads + adam states for the whole model
    }
    Ok(bytes)
}

pub fn run(ctx: &Ctx, opt: &ExpOpt) -> Result<()> {
    // fast mode uses the tiny encoder: the single-core budget cannot push
    // enc_base through enough steps for any method to move off majority
    // class (see EXPERIMENTS.md); the method *comparison* is preserved.
    let models: Vec<&str> = if opt.fast { vec!["enc_tiny"] } else { vec!["enc_base", "enc_large"] };
    let steps = opt.steps.unwrap_or(if opt.fast { 250 } else { 300 });
    let mut out_rows = Vec::new();
    for model in models {
        println!("\n== Table 2 ({model}): GLUE-sim, {steps} steps, {} seed(s) ==", opt.seeds);
        println!(
            "{:<8} {:>9} {:>9} | {:>7} {:>7} {:>7} {:>7} {:>7} {:>7} | {:>6} {:>9}",
            "method",
            "#params",
            "mem(MB)",
            "sst2",
            "mrpc",
            "cola",
            "qnli",
            "rte",
            "stsb",
            "avg",
            "fullrank%"
        );
        for method in METHODS {
            if !opt.keep(method) {
                continue;
            }
            let mut per_task = Vec::new();
            let mut n_params = 0usize;
            let mut rank_frac = None;
            for task in GlueTask::ALL {
                if !opt.keep(task.name())
                    && opt.filter.iter().any(|f| GlueTask::parse(f).is_some())
                {
                    per_task.push(f64::NAN);
                    continue;
                }
                let mut stats = Stats::default();
                for seed in 0..opt.seeds as u64 {
                    let cfg = run::default_cfg(method, steps);
                    let r = run::glue_run(ctx, model, method, task, seed, &cfg, C3aScheme::Xavier)?;
                    stats.push(r.metric);
                    n_params = r.n_params;
                    if let Some((f, _, _)) = r.rank {
                        rank_frac = Some(f);
                    }
                }
                per_task.push(stats.mean());
            }
            let valid: Vec<f64> = per_task.iter().copied().filter(|v| !v.is_nan()).collect();
            let avg = valid.iter().sum::<f64>() / valid.len().max(1) as f64;
            let mem = mem_bytes(ctx, model, method)? as f64 / 1e6;
            println!(
                "{:<8} {:>9} {:>9.1} | {:>7.3} {:>7.3} {:>7.3} {:>7.3} {:>7.3} {:>7.3} | {:>6.3} {:>9}",
                method,
                fmt_params(n_params),
                mem,
                per_task[0],
                per_task[1],
                per_task[2],
                per_task[3],
                per_task[4],
                per_task[5],
                avg,
                rank_frac.map(|f| format!("{:.0}%", 100.0 * f)).unwrap_or_else(|| "-".into()),
            );
            out_rows.push(json::obj(vec![
                ("model", json::s(model)),
                ("method", json::s(method)),
                ("params", json::num(n_params as f64)),
                ("mem_mb", json::num(mem)),
                ("scores", json::arr(per_task.iter().map(|&v| json::num(v)).collect())),
                ("avg", json::num(avg)),
                ("full_rank_frac", rank_frac.map(json::num).unwrap_or(Json::Null)),
            ]));
        }
    }
    println!("\npaper shape: c3a_d1 ≈ baselines with ~16x fewer params; c3a_d8 tops avg;");
    println!("mem: bitfit < c3a < lora < vera; most C3A deltas full-rank.");
    super::write_results(opt, "table2", &json::arr(out_rows))
}
