//! Tables 3 & 4 — instruction fine-tuning on the decoder models:
//! commonsense MC (table3) and math/code generation (table4), methods
//! {lora, vera, dora, c3a} with LoRA as the reference row.

use super::{ExpOpt};
use crate::coordinator::run::{self, Ctx};
use crate::data::gen_sim::GenTask;
use crate::data::instr_sim::McTask;
use crate::substrate::json;
use anyhow::Result;

pub const METHODS: [&str; 4] = ["lora", "vera", "dora", "c3a"];

fn params_pct(ctx: &Ctx, model: &str, n_params: usize) -> f64 {
    // % of backbone params, like the paper's "Params (%)"
    let meta = ctx.manifest.model(model).unwrap();
    let backbone = meta.vocab * meta.d
        + meta.layers * (4 * meta.d * meta.d + 3 * meta.d * 2 * meta.d);
    100.0 * n_params as f64 / backbone as f64
}

pub fn table3(ctx: &Ctx, opt: &ExpOpt) -> Result<()> {
    let models: Vec<&str> =
        if opt.fast { vec!["dec_small"] } else { vec!["dec_small", "dec_large"] };
    let tasks: Vec<McTask> = if opt.fast {
        vec![McTask::BoolQ, McTask::Piqa, McTask::HellaSwag, McTask::Obqa]
    } else {
        McTask::ALL.to_vec()
    };
    let steps = opt.steps.unwrap_or(if opt.fast { 60 } else { 300 });
    let n_train = if opt.fast { 512 } else { 2048 };
    let mut rows = Vec::new();
    for model in &models {
        println!("\n== Table 3 ({model}): commonsense-sim MC, {steps} steps ==");
        print!("{:<8} {:>9}", "method", "params%");
        for t in &tasks {
            print!(" {:>10}", t.name());
        }
        println!(" {:>7}", "avg");
        let mut lora_avg = None;
        for method in METHODS {
            if !opt.keep(method) {
                continue;
            }
            let mut scores = Vec::new();
            let mut n_params = 0;
            for &task in &tasks {
                let cfg = run::default_cfg(method, steps);
                let r = run::mc_run(ctx, model, method, task, 0, &cfg, n_train)?;
                scores.push(r.metric);
                n_params = r.n_params;
            }
            let avg = scores.iter().sum::<f64>() / scores.len() as f64;
            if method == "lora" {
                lora_avg = Some(avg);
            }
            print!("{:<8} {:>8.2}%", method, params_pct(ctx, model, n_params));
            for s in &scores {
                print!(" {:>10.3}", s);
            }
            let delta = lora_avg.map(|l| avg - l).unwrap_or(0.0);
            println!(" {:>7.3} ({:+.3} vs lora)", avg, delta);
            rows.push(json::obj(vec![
                ("model", json::s(model)),
                ("method", json::s(method)),
                ("params_pct", json::num(params_pct(ctx, model, n_params))),
                ("tasks", json::arr(tasks.iter().map(|t| json::s(t.name())).collect())),
                ("scores", json::arr(scores.iter().map(|&v| json::num(v)).collect())),
                ("avg", json::num(avg)),
            ]));
        }
    }
    println!("\npaper shape: c3a beats lora on avg with ~2-3x fewer params; vera below lora.");
    super::write_results(opt, "table3", &json::arr(rows))
}

pub fn table4(ctx: &Ctx, opt: &ExpOpt) -> Result<()> {
    let models: Vec<&str> =
        if opt.fast { vec!["dec_small"] } else { vec!["dec_small", "dec_large"] };
    let math: Vec<GenTask> = GenTask::MATH_ALL.to_vec();
    let code: Vec<GenTask> = if opt.fast {
        vec![GenTask::HumanEval, GenTask::Mbpp]
    } else {
        GenTask::CODE_ALL.to_vec()
    };
    let steps = opt.steps.unwrap_or(if opt.fast { 60 } else { 300 });
    let n_train = if opt.fast { 768 } else { 4096 };
    let mut rows = Vec::new();
    for model in &models {
        println!("\n== Table 4 ({model}): math/code-sim exact match, {steps} steps ==");
        print!("{:<8}", "method");
        for t in math.iter().chain(&code) {
            print!(" {:>16}", t.name());
        }
        println!(" {:>7}", "avg");
        for method in METHODS {
            if !opt.keep(method) {
                continue;
            }
            if method == "dora" && opt.fast {
                // dora shares lora's shape; skip in fast mode to save the core
            }
            let mut scores = Vec::new();
            for &task in math.iter().chain(&code) {
                let cfg = run::default_cfg(method, steps);
                let r = run::gen_run(ctx, model, method, task, 0, &cfg, n_train)?;
                scores.push(r.metric);
            }
            let avg = scores.iter().sum::<f64>() / scores.len() as f64;
            print!("{:<8}", method);
            for s in &scores {
                print!(" {:>16.3}", s);
            }
            println!(" {:>7.3}", avg);
            rows.push(json::obj(vec![
                ("model", json::s(model)),
                ("method", json::s(method)),
                ("tasks", json::arr(math.iter().chain(&code).map(|t| json::s(t.name())).collect())),
                ("scores", json::arr(scores.iter().map(|&v| json::num(v)).collect())),
                ("avg", json::num(avg)),
            ]));
        }
    }
    println!("\npaper shape: c3a ≥ dora > lora > vera on avg exact-match.");
    super::write_results(opt, "table4", &json::arr(rows))
}
