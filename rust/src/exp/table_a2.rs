//! Table A2 — vision-sim: ViT-sim Base/Large × {head, full, lora16, c3a}
//! on six patch-classification datasets.

use super::{fmt_params, ExpOpt};
use crate::coordinator::run::{self, Ctx};
use crate::data::vision_sim::VisionTask;
use crate::substrate::json;
use anyhow::Result;

pub const METHODS: [&str; 4] = ["head", "full", "lora", "c3a"];

pub fn run(ctx: &Ctx, opt: &ExpOpt) -> Result<()> {
    let models: Vec<&str> = if opt.fast { vec!["vit_base"] } else { vec!["vit_base", "vit_large"] };
    let tasks: Vec<VisionTask> = if opt.fast {
        vec![VisionTask::Pets, VisionTask::EuroSat, VisionTask::Cars]
    } else {
        VisionTask::ALL.to_vec()
    };
    let steps = opt.steps.unwrap_or(if opt.fast { 60 } else { 300 });
    let mut rows = Vec::new();
    for model in &models {
        println!("\n== Table A2 ({model}): vision-sim, {steps} steps ==");
        print!("{:<8} {:>9}", "method", "#params");
        for t in &tasks {
            print!(" {:>9}", t.name());
        }
        println!(" {:>7}", "avg");
        for method in METHODS {
            if !opt.keep(method) {
                continue;
            }
            let mut scores = Vec::new();
            let mut n_params = 0;
            for &task in &tasks {
                let cfg = run::default_cfg(method, steps);
                let r = run::vision_run(ctx, model, method, task, 0, &cfg)?;
                scores.push(r.metric);
                n_params = r.n_params;
            }
            let avg = scores.iter().sum::<f64>() / scores.len() as f64;
            print!("{:<8} {:>9}", method, fmt_params(n_params));
            for s in &scores {
                print!(" {:>9.3}", s);
            }
            println!(" {:>7.3}", avg);
            rows.push(json::obj(vec![
                ("model", json::s(model)),
                ("method", json::s(method)),
                ("params", json::num(n_params as f64)),
                ("tasks", json::arr(tasks.iter().map(|t| json::s(t.name())).collect())),
                ("scores", json::arr(scores.iter().map(|&v| json::num(v)).collect())),
                ("avg", json::num(avg)),
            ]));
        }
    }
    println!("\npaper shape: lora/c3a ≈ full >> head; c3a matches lora at half the params.");
    super::write_results(opt, "table_a2", &json::arr(rows))
}
