//! Table 1 — time & space complexity of LoRA / VeRA / C3A.
//!
//! Two halves: the paper's analytic columns (from `peft::accounting`) and
//! *measured* single-core operator timings from the rust substrates
//! (dense LoRA matvec vs FFT block-circulant matvec vs VeRA), sweeping d.
//! The measured half is what `cargo bench --bench bench_operator` also
//! runs; here we print a compact version.

use super::ExpOpt;
use crate::peft::accounting::ProjSpec;
use crate::substrate::circulant::BlockCirculant;
use crate::substrate::linalg::{LoRaDelta, VeraDelta};
use crate::substrate::{json, prng::Rng};
use anyhow::Result;
use std::time::Instant;

fn time_us(mut f: impl FnMut(), iters: usize) -> f64 {
    // warmup
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() * 1e6 / iters as f64
}

pub fn run(opt: &ExpOpt) -> Result<()> {
    println!("== Table 1: complexity (analytic + measured) ==");
    println!("{:<6} {:>10} {:>12} {:>12} | {:>12} {:>12} {:>12}",
             "d", "method", "#param", "#other", "MACs(model)", "us/matvec", "ratio_vs_lora");
    let mut rows = Vec::new();
    let dims: &[usize] =
        if opt.fast { &[256, 1024, 4096] } else { &[256, 512, 1024, 2048, 4096, 8192] };
    for &d in dims {
        let mut rng = Rng::seed(d as u64);
        let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let r = 8usize;
        let b = (d / 8).max(1);

        let lora_spec = ProjSpec::lora(d, r);
        let lora = LoRaDelta {
            a: (0..r * d).map(|_| rng.normal()).collect(),
            b: (0..d * r).map(|_| rng.normal()).collect(),
            r,
            d_in: d,
            d_out: d,
            scale: 1.0,
        };
        let mut hidden = vec![0.0; r];
        let mut y = vec![0.0; d];
        let lora_us = time_us(|| lora.matvec_into(&x, &mut hidden, &mut y), 50);

        let c3a_spec = ProjSpec::c3a(d, b);
        let m = d / b;
        let bc = BlockCirculant::new(m, m, b, (0..m * m * b).map(|_| rng.normal()).collect());
        let prepared = bc.prepared();
        let mut out = vec![0.0; d];
        let c3a_us = time_us(|| prepared.matvec_into(&x, &mut out), 50);

        let rv = d; // VeRA needs r_v >= d for high rank
        let vera_spec = ProjSpec::vera(d, rv);
        let vera = VeraDelta {
            a: (0..rv * d).map(|_| rng.normal()).collect(),
            b: (0..d * rv).map(|_| rng.normal()).collect(),
            ld: vec![0.1; rv],
            lb: vec![1.0; d],
            r_v: rv,
            d_in: d,
            d_out: d,
        };
        let vera_us = time_us(|| { let _ = vera.matvec(&x); }, 10);

        for (name, spec, us) in [
            ("lora", lora_spec, lora_us),
            ("vera", vera_spec, vera_us),
            ("c3a", c3a_spec, c3a_us),
        ] {
            println!(
                "{:<6} {:>10} {:>12} {:>12} | {:>12.0} {:>12.1} {:>12.2}",
                d, name, spec.params(), spec.aux_floats(), spec.time_macs(), us, us / lora_us
            );
            rows.push(json::obj(vec![
                ("d", json::num(d as f64)),
                ("method", json::s(name)),
                ("params", json::num(spec.params() as f64)),
                ("aux", json::num(spec.aux_floats() as f64)),
                ("macs", json::num(spec.time_macs())),
                ("us", json::num(us)),
            ]));
        }
    }
    println!("\npaper shape: C3A params ≈ d²/b (≪ dense), aux ≈ p·b (tiny);");
    println!("VeRA aux = r_v(d1+d2) and time ≫ LoRA — reproduced iff ratios above grow with d.");
    super::write_results(opt, "table1", &json::arr(rows))
}
