//! Figure 3 — initialization ablation: C3A under zero / gaussian /
//! kaiming / xavier kernels, several seeds × tasks.  Prints the violin
//! summary (mean ± std + min/max per scheme).

use super::ExpOpt;
use crate::coordinator::run::{self, Ctx};
use crate::data::glue_sim::GlueTask;
use crate::metrics::Stats;
use crate::peft::init::C3aScheme;
use crate::substrate::json;
use anyhow::Result;

pub fn run(ctx: &Ctx, opt: &ExpOpt) -> Result<()> {
    // The ablation is about *relative* sensitivity, so the tiny encoder is
    // the right tool on a single core; --full uses enc_base.
    let (model, method) = if opt.fast { ("enc_tiny", "c3a_d8") } else { ("enc_base", "c3a_d8") };
    let tasks: Vec<GlueTask> = if opt.fast {
        vec![GlueTask::Sst2, GlueTask::Mrpc, GlueTask::Cola, GlueTask::Qnli, GlueTask::Rte]
            .into_iter()
            .filter(|t| {
                // enc_tiny only has cls artifacts
                !t.is_regression()
            })
            .collect()
    } else {
        vec![GlueTask::Sst2, GlueTask::Mrpc, GlueTask::Cola, GlueTask::Qnli, GlueTask::Rte]
    };
    let steps = opt.steps.unwrap_or(if opt.fast { 60 } else { 200 });
    let seeds = opt.seeds.max(if opt.fast { 3 } else { 5 });

    println!("== Fig 3 ({model}): C3A init ablation, {} tasks x {} seeds ==", tasks.len(), seeds);
    println!("{:<10} {:>8} {:>8} {:>8} {:>8}", "init", "mean", "std", "min", "max");
    let mut rows = Vec::new();
    let mut means = Vec::new();
    for scheme in C3aScheme::ALL {
        let mut all = Stats::default();
        let mut per_run = Vec::new();
        for &task in &tasks {
            for seed in 0..seeds as u64 {
                let cfg = run::default_cfg(method, steps);
                let r = run::glue_run(ctx, model, method, task, seed, &cfg, scheme)?;
                all.push(r.metric);
                per_run.push(json::obj(vec![
                    ("task", json::s(task.name())),
                    ("seed", json::num(seed as f64)),
                    ("metric", json::num(r.metric)),
                ]));
            }
        }
        let (lo, hi) = (
            all.values.iter().cloned().fold(f64::MAX, f64::min),
            all.values.iter().cloned().fold(f64::MIN, f64::max),
        );
        println!(
            "{:<10} {:>8.4} {:>8.4} {:>8.4} {:>8.4}",
            scheme.name(),
            all.mean(),
            all.std(),
            lo,
            hi
        );
        means.push(all.mean());
        rows.push(json::obj(vec![
            ("scheme", json::s(scheme.name())),
            ("mean", json::num(all.mean())),
            ("std", json::num(all.std())),
            ("runs", json::arr(per_run)),
        ]));
    }
    let spread = means.iter().cloned().fold(f64::MIN, f64::max)
        - means.iter().cloned().fold(f64::MAX, f64::min);
    println!("\nmean spread across schemes: {spread:.4}");
    println!("paper shape: spread within run-to-run std — init choice doesn't matter.");
    super::write_results(opt, "fig3", &json::arr(rows))
}
