//! Figure 1 — relative-to-LoRA radar: accuracy (commonsense / math /
//! code), parameter efficiency, and memory efficiency, derived from the
//! table2/3/4 results files.

use super::ExpOpt;
use crate::substrate::json::{self, Json};
use anyhow::Result;

fn avg_of(rows: &Json, method: &str) -> Option<(f64, f64)> {
    // returns (avg score, params% — 0 when the table has no params column)
    for r in rows.as_arr()? {
        if r.get("method").and_then(|m| m.as_str()) == Some(method) {
            let avg = r.get("avg")?.as_f64()?;
            let p = r
                .get("params_pct")
                .and_then(|v| v.as_f64())
                .or_else(|| r.get("params").and_then(|v| v.as_f64()))
                .unwrap_or(0.0);
            return Some((avg, p));
        }
    }
    None
}

pub fn run(opt: &ExpOpt) -> Result<()> {
    let t3 = super::read_results(opt, "table3")?;
    let t4 = super::read_results(opt, "table4")?;
    let t2 = super::read_results(opt, "table2").ok();
    println!("== Fig 1: relative to LoRA (=1.0), higher is better ==");
    println!(
        "{:<8} {:>12} {:>12} {:>14} {:>14}",
        "method",
        "commonsense",
        "math+code",
        "param-eff",
        "mem-eff"
    );
    let (l3, lp3) = avg_of(&t3, "lora").ok_or_else(|| anyhow::anyhow!("no lora row in table3"))?;
    let (l4, _) = avg_of(&t4, "lora").ok_or_else(|| anyhow::anyhow!("no lora row in table4"))?;
    let lora_mem = t2
        .as_ref()
        .and_then(|t| {
            t.as_arr()?.iter().find(|r| r.get("method").and_then(|m| m.as_str()) == Some("lora"))
                .and_then(|r| r.get("mem_mb")?.as_f64())
        })
        .unwrap_or(1.0);
    let mut rows = Vec::new();
    for method in ["lora", "vera", "dora", "c3a"] {
        let Some((a3, p3)) = avg_of(&t3, method) else { continue };
        let Some((a4, _)) = avg_of(&t4, method) else { continue };
        let mem = t2
            .as_ref()
            .and_then(|t| {
                let name = if method == "c3a" { "c3a_d8" } else { method };
                t.as_arr()?.iter().find(|r| r.get("method").and_then(|m| m.as_str()) == Some(name))
                    .and_then(|r| r.get("mem_mb")?.as_f64())
            })
            .unwrap_or(lora_mem);
        let row = [a3 / l3, a4 / l4.max(1e-9), lp3 / p3.max(1e-9), lora_mem / mem.max(1e-9)];
        println!(
            "{:<8} {:>12.3} {:>12.3} {:>14.3} {:>14.3}",
            method,
            row[0],
            row[1],
            row[2],
            row[3]
        );
        rows.push(json::obj(vec![
            ("method", json::s(method)),
            ("commonsense", json::num(row[0])),
            ("math_code", json::num(row[1])),
            ("param_eff", json::num(row[2])),
            ("mem_eff", json::num(row[3])),
        ]));
    }
    println!("\npaper shape: c3a dominates lora on every axis (all > 1.0).");
    super::write_results(opt, "fig1", &json::arr(rows))
}
