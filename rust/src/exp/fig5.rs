//! Figure 5 — data & model scaling of C3A vs LoRA on math-sim:
//! left panel sweeps training-set size, right panel compares decoder sizes.

use super::ExpOpt;
use crate::coordinator::run::{self, Ctx};
use crate::data::gen_sim::GenTask;
use crate::substrate::json;
use anyhow::Result;

pub fn run(ctx: &Ctx, opt: &ExpOpt) -> Result<()> {
    let steps = opt.steps.unwrap_or(if opt.fast { 50 } else { 200 });
    let fractions: Vec<usize> =
        if opt.fast { vec![128, 512, 2048] } else { vec![128, 512, 2048, 8192] };
    println!("== Fig 5 (left): data scaling on math-sim (dec_small, {steps} steps) ==");
    println!("{:>8} {:>10} {:>10} {:>10}", "n_train", "lora", "c3a", "delta");
    let mut rows = Vec::new();
    for &n in &fractions {
        let mut scores = Vec::new();
        for method in ["lora", "c3a"] {
            let cfg = run::default_cfg(method, steps);
            let r = run::gen_run(ctx, "dec_small", method, GenTask::Gsm, 0, &cfg, n)?;
            scores.push(r.metric);
        }
        println!(
            "{:>8} {:>10.3} {:>10.3} {:>+10.3}",
            n,
            scores[0],
            scores[1],
            scores[1] - scores[0]
        );
        rows.push(json::obj(vec![
            ("panel", json::s("data")),
            ("n_train", json::num(n as f64)),
            ("lora", json::num(scores[0])),
            ("c3a", json::num(scores[1])),
        ]));
    }

    let models: Vec<&str> =
        if opt.fast { vec!["dec_small", "dec_large"] } else { vec!["dec_small", "dec_large"] };
    println!("\n== Fig 5 (right): model scaling (math-sim, n=512) ==");
    println!("{:>10} {:>10} {:>10} {:>10}", "model", "lora", "c3a", "delta");
    for model in models {
        let mut scores = Vec::new();
        for method in ["lora", "c3a"] {
            let cfg = run::default_cfg(method, steps);
            let r = run::gen_run(ctx, model, method, GenTask::Gsm, 0, &cfg, 512)?;
            scores.push(r.metric);
        }
        println!(
            "{:>10} {:>10.3} {:>10.3} {:>+10.3}",
            model,
            scores[0],
            scores[1],
            scores[1] - scores[0]
        );
        rows.push(json::obj(vec![
            ("panel", json::s("model")),
            ("model", json::s(model)),
            ("lora", json::num(scores[0])),
            ("c3a", json::num(scores[1])),
        ]));
    }
    println!("\npaper shape: c3a's margin over lora grows with data; holds at both scales.");
    super::write_results(opt, "fig5", &json::arr(rows))
}
