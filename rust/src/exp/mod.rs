//! Experiment harness — one module per table/figure of the paper
//! (DESIGN.md §4 maps ids to modules).  Every experiment prints the
//! paper-shaped rows and writes `results/<id>.json`.

pub mod fig1;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod table1;
pub mod table2;
pub mod table34;
pub mod table_a2;

use crate::substrate::json::Json;
use anyhow::Result;
use std::path::Path;

/// Options shared by all experiments.
#[derive(Clone, Debug)]
pub struct ExpOpt {
    /// fine-tuning steps per run (None = experiment default)
    pub steps: Option<usize>,
    /// seeds per cell
    pub seeds: usize,
    /// reduced grids for the single-core testbed (the default); `--full`
    /// restores the paper's full grid
    pub fast: bool,
    /// substring filters on method/task names
    pub filter: Vec<String>,
    pub results_dir: String,
}

impl Default for ExpOpt {
    fn default() -> Self {
        Self {
            steps: None,
            seeds: 1,
            fast: true,
            filter: Vec::new(),
            results_dir: "results".into(),
        }
    }
}

impl ExpOpt {
    pub fn keep(&self, name: &str) -> bool {
        self.filter.is_empty() || self.filter.iter().any(|f| name.contains(f.as_str()))
    }
}

/// Write `results/<id>.json`.
pub fn write_results(opt: &ExpOpt, id: &str, value: &Json) -> Result<()> {
    std::fs::create_dir_all(&opt.results_dir)?;
    let path = Path::new(&opt.results_dir).join(format!("{id}.json"));
    std::fs::write(&path, value.to_string_compact())?;
    eprintln!("wrote {}", path.display());
    Ok(())
}

/// Load a previously written results file (fig1 derives from tables 3/4).
pub fn read_results(opt: &ExpOpt, id: &str) -> Result<Json> {
    let path = Path::new(&opt.results_dir).join(format!("{id}.json"));
    let text = std::fs::read_to_string(&path)
        .map_err(|e| anyhow::anyhow!("{}: {e} (run `c3a exp {id}` first)", path.display()))?;
    Json::parse(&text)
}

/// Format a parameter count the way the paper does (0.018M style).
pub fn fmt_params(n: usize) -> String {
    format!("{:.3}M", n as f64 / 1e6)
}
