//! Figure 4 (+ Fig A1) — expressiveness on the 8-cluster synthetic set:
//! a 3-layer MLP whose middle layer is dense / LoRA(r=1) / C3A(b=128/2),
//! all with equal parameter budgets for the middle op (256 params for
//! LoRA r=1 and C3A b=64 at h=128).  Prints the training curves.

use super::ExpOpt;
use crate::coordinator::lr::Schedule;
use crate::coordinator::run::{self, Ctx};
use crate::coordinator::TrainCfg;
use crate::data::clusters;
use crate::substrate::json;
use anyhow::Result;

pub const VARIANTS: [&str; 3] = ["mlp_dense", "mlp_lora", "mlp_c3a"];

pub fn run(ctx: &Ctx, opt: &ExpOpt) -> Result<()> {
    let steps = opt.steps.unwrap_or(if opt.fast { 400 } else { 1200 });
    println!("== Fig 4: expressiveness (LoRA r=1 vs C3A b=128/2 vs linear) ==");
    let mut rows = Vec::new();
    for variant in VARIANTS {
        if !opt.keep(variant) {
            continue;
        }
        let cfg = TrainCfg {
            steps,
            lr: 2e-2,
            weight_decay: 0.0,
            schedule: Schedule::Constant,
            eval_every: (steps / 8).max(25),
            patience: 0,
            verbose: false,
        };
        let r = run::mlp_run(ctx, variant, 0, &cfg)?;
        // sample the loss curve at 10 points
        let curve: Vec<(usize, f32)> = (0..10)
            .map(|i| {
                let idx = (i * (r.losses.len() - 1)) / 9;
                (idx, r.losses[idx])
            })
            .collect();
        println!("\n{variant} (mid-op params: {}):", mid_params(variant));
        for (s, l) in &curve {
            println!("  step {:>5}  loss {:.4}", s, l);
        }
        println!("  final train acc: {:.3}", r.metric);
        rows.push(json::obj(vec![
            ("variant", json::s(variant)),
            ("mid_params", json::num(mid_params(variant) as f64)),
            ("final_acc", json::num(r.metric)),
            (
                "losses",
                json::arr(
                    r.losses
                        .iter()
                        .step_by((r.losses.len() / 50).max(1))
                        .map(|&v| json::num(v as f64))
                        .collect(),
                ),
            ),
        ]));
    }
    // Fig A1: dump the dataset scatter for plotting
    let data = clusters::generate(0);
    let pts: Vec<_> = data
        .x
        .iter()
        .zip(&data.y)
        .map(|(p, &c)| {
            json::arr(vec![json::num(p[0] as f64), json::num(p[1] as f64), json::num(c as f64)])
        })
        .collect();
    super::write_results(opt, "figA1_points", &json::arr(pts))?;
    println!(
        "\npaper shape: lora r=1 plateaus at high loss; c3a + dense reach ~0 and perfect acc."
    );
    super::write_results(opt, "fig4", &json::arr(rows))
}

fn mid_params(variant: &str) -> usize {
    match variant {
        "mlp_dense" => 128 * 128,
        "mlp_lora" => 2 * 128, // r = 1
        "mlp_c3a" => 128 * 128 / 64, // b = 64
        _ => 0,
    }
}
