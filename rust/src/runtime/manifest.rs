//! Artifact manifest (artifacts/manifest.json, written by python aot.py).
//!
//! The manifest is the single source of truth for how named model
//! parameters map onto the positional PJRT inputs/outputs of each AOT
//! artifact, and how adapters are initialized.

use crate::peft::init::InitSpec;
use crate::substrate::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    Trainable,
    OptM,
    OptV,
    Frozen,
    FrozenRandom,
    Data,
    Scalar,
}

impl Role {
    fn parse(s: &str) -> Result<Role> {
        Ok(match s {
            "trainable" => Role::Trainable,
            "opt_m" => Role::OptM,
            "opt_v" => Role::OptV,
            "frozen" => Role::Frozen,
            "frozen_random" => Role::FrozenRandom,
            "data" => Role::Data,
            "scalar" => Role::Scalar,
            other => bail!("unknown role {other}"),
        })
    }
}

#[derive(Clone, Debug)]
pub struct InputSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub i32_dtype: bool,
    pub role: Role,
    pub init: Option<InitSpec>,
}

#[derive(Clone, Debug)]
pub struct PeftParams {
    pub method: String,
    pub block: usize,
    pub rank: usize,
    pub r_v: usize,
    pub alpha: f64,
    pub boft_block: usize,
    pub mlp_mid: String,
}

impl Default for PeftParams {
    /// Mirrors python/compile/model.py `PeftCfg` defaults.
    fn default() -> Self {
        PeftParams {
            method: "c3a".to_string(),
            block: 0,
            rank: 8,
            r_v: 256,
            alpha: 16.0,
            boft_block: 8,
            mlp_mid: "dense".to_string(),
        }
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub path: PathBuf,
    pub model: String,
    pub method: String,
    pub peft: PeftParams,
    /// "train" or "eval"
    pub kind: String,
    /// cls | reg | lm | mlm | vec
    pub head: String,
    pub batch: usize,
    pub seq: usize,
    /// paper-style trainable count (head excluded)
    pub n_params: usize,
    pub inputs: Vec<InputSpec>,
    /// trainable names in positional order
    pub trainable_order: Vec<String>,
    pub frozen_order: Vec<String>,
    pub data_order: Vec<String>,
}

#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub name: String,
    pub init_path: PathBuf,
    pub d: usize,
    pub layers: usize,
    pub vocab: usize,
    pub seq: usize,
    pub n_out: usize,
    pub kind: String,
    /// attention heads (encoder/decoder)
    pub heads: usize,
    /// "tokens" | "vec" (ViT-sim patch vectors)
    pub input_mode: String,
    /// vec mode: per-patch feature width
    pub patch_dim: usize,
    /// mlp kind: hidden / input widths
    pub mlp_hidden: usize,
    pub mlp_in: usize,
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelMeta>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json")).with_context(|| {
            format!("reading {}/manifest.json (run `make artifacts`)", dir.display())
        })?;
        let root = Json::parse(&text).context("parsing manifest.json")?;

        let mut models = BTreeMap::new();
        for (name, m) in root.get("models").and_then(|v| v.as_obj()).context("manifest: models")? {
            let cfg = m.get("cfg").context("model cfg")?;
            let gi = |k: &str| cfg.get(k).and_then(|v| v.as_usize()).unwrap_or(0);
            let gs = |k: &str, dflt: &str| {
                cfg.get(k).and_then(|v| v.as_str()).unwrap_or(dflt).to_string()
            };
            models.insert(
                name.clone(),
                ModelMeta {
                    name: name.clone(),
                    init_path: dir
                        .join(m.get("init").and_then(|v| v.as_str()).context("init path")?),
                    d: gi("d"),
                    layers: gi("layers"),
                    vocab: gi("vocab"),
                    seq: gi("seq"),
                    n_out: gi("n_out"),
                    kind: gs("kind", ""),
                    heads: gi("heads").max(1),
                    input_mode: gs("input_mode", "tokens"),
                    patch_dim: gi("patch_dim").max(1),
                    mlp_hidden: gi("mlp_hidden").max(1),
                    mlp_in: gi("mlp_in").max(1),
                },
            );
        }

        let mut artifacts = BTreeMap::new();
        for a in root.get("artifacts").and_then(|v| v.as_arr()).context("manifest: artifacts")? {
            let spec = parse_artifact(&dir, a)?;
            artifacts.insert(spec.name.clone(), spec);
        }
        Ok(Manifest { dir, models, artifacts })
    }

    /// Load `<dir>/manifest.json` when present (python AOT build), or
    /// synthesize the same inventory in pure Rust so offline runs need no
    /// python/JAX at all (the substrate fallback backend ignores HLO
    /// artifact paths).
    pub fn load_or_synthesize<P: AsRef<Path>>(dir: P) -> Result<Manifest> {
        let dir = dir.as_ref();
        if dir.join("manifest.json").exists() {
            Manifest::load(dir)
        } else {
            // Visible notice: python-built artifacts are NOT being used.
            // A mistyped --artifacts path lands here too, so say where.
            eprintln!(
                "note: {}/manifest.json not found — synthesizing the artifact \
                 catalog in Rust (substrate backend; run `make artifacts` for \
                 python-built artifacts)",
                dir.display()
            );
            super::catalog::synthesize(dir)
        }
    }

    /// The model's initial (pre-pretraining) parameters.  Loads the
    /// python-written init bin when present; otherwise generates an
    /// equivalent init in Rust and caches it at `init_path`.
    pub fn init_params(&self, model: &str) -> Result<crate::substrate::tensor::TensorMap> {
        let meta = self.model(model)?;
        if meta.init_path.exists() {
            return crate::substrate::tensor::load(&meta.init_path);
        }
        let map = super::catalog::init_base_params(meta);
        if let Some(parent) = meta.init_path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        crate::substrate::tensor::save(&meta.init_path, &map)?;
        Ok(map)
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts.get(name).with_context(|| format!("artifact {name} not in manifest"))
    }

    pub fn model(&self, name: &str) -> Result<&ModelMeta> {
        self.models.get(name).with_context(|| format!("model {name} not in manifest"))
    }

    /// Conventional artifact name.
    pub fn artifact_name(model: &str, method: &str, head: &str, kind: &str) -> String {
        format!("{model}__{method}__{head}__{kind}")
    }
}

fn parse_artifact(dir: &Path, a: &Json) -> Result<ArtifactSpec> {
    let gets = |k: &str| -> Result<String> {
        Ok(a.get(k)
            .and_then(|v| v.as_str())
            .with_context(|| format!("artifact field {k}"))?
            .to_string())
    };
    let name = gets("name")?;
    let peft_j = a.get("peft").context("peft")?;
    let peft = PeftParams {
        method: peft_j.get("method").and_then(|v| v.as_str()).unwrap_or("").to_string(),
        block: peft_j.get("block").and_then(|v| v.as_usize()).unwrap_or(0),
        rank: peft_j.get("rank").and_then(|v| v.as_usize()).unwrap_or(0),
        r_v: peft_j.get("r_v").and_then(|v| v.as_usize()).unwrap_or(0),
        alpha: peft_j.get("alpha").and_then(|v| v.as_f64()).unwrap_or(0.0),
        boft_block: peft_j.get("boft_block").and_then(|v| v.as_usize()).unwrap_or(8),
        mlp_mid: peft_j.get("mlp_mid").and_then(|v| v.as_str()).unwrap_or("dense").to_string(),
    };
    let mut inputs = Vec::new();
    for inp in a.get("inputs").and_then(|v| v.as_arr()).context("inputs")? {
        let iname = inp.get("name").and_then(|v| v.as_str()).context("input name")?.to_string();
        let shape = inp
            .get("shape")
            .and_then(|v| v.as_arr())
            .context("input shape")?
            .iter()
            .map(|d| d.as_usize().unwrap_or(0))
            .collect();
        let role = Role::parse(inp.get("role").and_then(|v| v.as_str()).context("role")?)?;
        let init = match inp.get("init") {
            Some(j) => Some(InitSpec::from_json(j)?),
            None => None,
        };
        inputs.push(InputSpec {
            name: iname,
            shape,
            i32_dtype: inp.get("dtype").and_then(|v| v.as_str()) == Some("i32"),
            role,
            init,
        });
    }
    let order = |role: Role| {
        inputs.iter().filter(|i| i.role == role).map(|i| i.name.clone()).collect::<Vec<_>>()
    };
    let mut frozen_order = order(Role::Frozen);
    frozen_order.extend(order(Role::FrozenRandom));
    Ok(ArtifactSpec {
        path: dir.join(gets("path")?),
        model: gets("model")?,
        method: gets("method")?,
        kind: gets("kind")?,
        head: gets("head")?,
        batch: a.get("batch").and_then(|v| v.as_usize()).context("batch")?,
        seq: a.get("seq").and_then(|v| v.as_usize()).unwrap_or(0),
        n_params: a.get("n_params").and_then(|v| v.as_usize()).unwrap_or(0),
        trainable_order: order(Role::Trainable),
        data_order: order(Role::Data),
        frozen_order,
        peft,
        inputs,
        name,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_real_manifest() {
        let dir = manifest_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.models.contains_key("enc_tiny"));
        let a = m.artifact("enc_tiny__c3a_d8__cls__train").unwrap();
        assert_eq!(a.kind, "train");
        assert_eq!(a.head, "cls");
        assert!(a.n_params > 0);
        // input ordering invariant: trainable block comes first
        assert_eq!(a.inputs[0].role, Role::Trainable);
        // scalars last
        assert_eq!(a.inputs.last().unwrap().role, Role::Scalar);
        // every trainable has an init spec
        assert!(a.inputs.iter().filter(|i| i.role == Role::Trainable).all(|i| i.init.is_some()));
        // train artifact has matching m/v counts
        let nt = a.trainable_order.len();
        let nm = a.inputs.iter().filter(|i| i.role == Role::OptM).count();
        assert_eq!(nt, nm);
    }

    #[test]
    fn artifact_name_convention() {
        assert_eq!(
            Manifest::artifact_name("enc_base", "lora", "cls", "train"),
            "enc_base__lora__cls__train"
        );
    }
}
