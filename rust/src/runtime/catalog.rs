//! Artifact catalog — a pure-Rust port of the python AOT inventory
//! (python/compile/aot.py + model.py parameter bookkeeping).
//!
//! When `artifacts/manifest.json` exists (python/JAX ran at build time)
//! the runtime loads it for bit-compatible interop.  When it does not —
//! the offline default — this module synthesizes the *same* inventory:
//! model metadata, per-artifact input/output contracts, declarative init
//! specs, and paper-style parameter counts.  The substrate fallback
//! backend executes these specs directly, so no HLO files are needed.
//!
//! Ordering matters: `inputs` follows the python flattening contract
//! [trainable..., opt_m..., opt_v..., frozen..., frozen_random..., data...,
//! scalars...], which is what `TrainSession` / `EvalSession` feed
//! positionally.

use super::manifest::{ArtifactSpec, InputSpec, Manifest, ModelMeta, PeftParams, Role};
use crate::peft::init::InitSpec;
use crate::substrate::prng::Rng;
use crate::substrate::tensor::{Tensor, TensorMap};
use anyhow::Result;
use std::collections::BTreeMap;
use std::path::Path;

/// Model configuration (mirrors python ModelCfg).
#[derive(Clone, Debug)]
pub struct ModelCfg {
    pub kind: &'static str, // encoder | decoder | mlp
    pub vocab: usize,
    pub d: usize,
    pub layers: usize,
    pub heads: usize,
    pub seq: usize,
    pub n_out: usize,
    pub input_mode: &'static str, // tokens | vec
    pub patch_dim: usize,
    pub mlp_hidden: usize,
    pub mlp_in: usize,
}

impl ModelCfg {
    fn base(kind: &'static str) -> ModelCfg {
        ModelCfg {
            kind,
            vocab: 512,
            d: 128,
            layers: 4,
            heads: 4,
            seq: 32,
            n_out: 2,
            input_mode: "tokens",
            patch_dim: 16,
            mlp_hidden: 128,
            mlp_in: 2,
        }
    }

    pub fn ffn(&self) -> usize {
        if self.kind == "encoder" {
            4 * self.d
        } else {
            2 * self.d
        }
    }
}

/// The named presets of python MODEL_PRESETS, in declaration order.
pub fn model_presets() -> Vec<(&'static str, ModelCfg)> {
    let enc = |d, layers, heads, seq, vocab| ModelCfg {
        d,
        layers,
        heads,
        seq,
        vocab,
        ..ModelCfg::base("encoder")
    };
    let vit = |d, layers, heads| ModelCfg {
        d,
        layers,
        heads,
        seq: 16,
        n_out: 200,
        input_mode: "vec",
        ..ModelCfg::base("encoder")
    };
    let dec =
        |d, layers, heads| ModelCfg { d, layers, heads, seq: 48, ..ModelCfg::base("decoder") };
    vec![
        ("enc_tiny", enc(32, 2, 2, 16, 64)),
        ("enc_base", enc(128, 4, 4, 32, 512)),
        ("enc_large", enc(256, 6, 8, 32, 512)),
        ("dec_small", dec(192, 4, 4)),
        ("dec_large", dec(320, 6, 8)),
        ("vit_base", vit(128, 4, 4)),
        ("vit_large", vit(256, 6, 8)),
        ("mlp", ModelCfg { n_out: 8, ..ModelCfg::base("mlp") }),
    ]
}

pub fn preset(name: &str) -> Option<ModelCfg> {
    model_presets().into_iter().find(|(n, _)| *n == name).map(|(_, c)| c)
}

fn cfg_from_meta(meta: &ModelMeta) -> ModelCfg {
    // Presets carry 'static strs; metas loaded from JSON map onto the
    // same fields.  kind/input_mode are matched back to static names.
    let kind = match meta.kind.as_str() {
        "decoder" => "decoder",
        "mlp" => "mlp",
        _ => "encoder",
    };
    let input_mode = if meta.input_mode == "vec" { "vec" } else { "tokens" };
    ModelCfg {
        kind,
        vocab: meta.vocab,
        d: meta.d,
        layers: meta.layers,
        heads: meta.heads,
        seq: meta.seq,
        n_out: meta.n_out,
        input_mode,
        patch_dim: meta.patch_dim,
        mlp_hidden: meta.mlp_hidden,
        mlp_in: meta.mlp_in,
    }
}

// ---------------------------------------------------------------------------
// Parameter inventories (ordered — mirrors python dict insertion order)
// ---------------------------------------------------------------------------

pub type Shapes = Vec<(String, Vec<usize>)>;

/// Backbone (pre-trained) parameter shapes, ordered.
pub fn base_param_shapes(cfg: &ModelCfg) -> Shapes {
    let mut p: Shapes = Vec::new();
    let mut push = |k: String, v: Vec<usize>| p.push((k, v));
    if cfg.kind == "mlp" {
        let h = cfg.mlp_hidden;
        push("mlp.w0".into(), vec![cfg.mlp_in, h]);
        push("mlp.b0".into(), vec![h]);
        push("mlp.w1".into(), vec![h, h]);
        push("mlp.b1".into(), vec![h]);
        push("mlp.w2".into(), vec![h, cfg.n_out]);
        push("mlp.b2".into(), vec![cfg.n_out]);
        return p;
    }
    if cfg.input_mode == "vec" {
        push("embed.patch".into(), vec![cfg.patch_dim, cfg.d]);
    } else {
        push("embed.tok".into(), vec![cfg.vocab, cfg.d]);
    }
    push("embed.pos".into(), vec![cfg.seq, cfg.d]);
    let enc = cfg.kind == "encoder";
    for i in 0..cfg.layers {
        let l = format!("L{i}");
        for proj in ["q", "k", "v", "o"] {
            push(format!("{l}.attn.w{proj}"), vec![cfg.d, cfg.d]);
            if enc {
                push(format!("{l}.attn.b{proj}"), vec![cfg.d]);
            }
        }
        if enc {
            push(format!("{l}.ln1.g"), vec![cfg.d]);
            push(format!("{l}.ln1.b"), vec![cfg.d]);
            push(format!("{l}.mlp.w1"), vec![cfg.d, cfg.ffn()]);
            push(format!("{l}.mlp.b1"), vec![cfg.ffn()]);
            push(format!("{l}.mlp.w2"), vec![cfg.ffn(), cfg.d]);
            push(format!("{l}.mlp.b2"), vec![cfg.d]);
            push(format!("{l}.ln2.g"), vec![cfg.d]);
            push(format!("{l}.ln2.b"), vec![cfg.d]);
        } else {
            push(format!("{l}.rms1.g"), vec![cfg.d]);
            push(format!("{l}.mlp.wg"), vec![cfg.d, cfg.ffn()]);
            push(format!("{l}.mlp.wu"), vec![cfg.d, cfg.ffn()]);
            push(format!("{l}.mlp.wd"), vec![cfg.ffn(), cfg.d]);
            push(format!("{l}.rms2.g"), vec![cfg.d]);
        }
    }
    if enc {
        push("final_ln.g".into(), vec![cfg.d]);
        push("final_ln.b".into(), vec![cfg.d]);
        push("head.w".into(), vec![cfg.d, cfg.n_out]);
        push("head.b".into(), vec![cfg.n_out]);
    } else {
        push("final_rms.g".into(), vec![cfg.d]); // lm head tied to embed.tok
    }
    p
}

/// Adapter parameter shapes: (trainable, frozen_random), ordered.
pub fn adapter_param_shapes(cfg: &ModelCfg, peft: &PeftParams) -> (Shapes, Shapes) {
    let mut t: Shapes = Vec::new();
    let mut fr: Shapes = Vec::new();
    let m = peft.method.as_str();
    if cfg.kind == "mlp" {
        let h = cfg.mlp_hidden;
        if peft.mlp_mid == "lora" {
            t.push(("mlp.mid.lora.A".into(), vec![peft.rank, h]));
            t.push(("mlp.mid.lora.B".into(), vec![h, peft.rank]));
        } else if peft.mlp_mid == "c3a" {
            let b = if peft.block > 0 { peft.block } else { h };
            t.push(("mlp.mid.c3a.w".into(), vec![h / b, h / b, b]));
        }
        return (t, fr);
    }
    if matches!(m, "full" | "head" | "bitfit") {
        return (t, fr);
    }
    let d = cfg.d;
    if m == "ia3" {
        for i in 0..cfg.layers {
            t.push((format!("L{i}.ia3.lk"), vec![d]));
            t.push((format!("L{i}.ia3.lv"), vec![d]));
            t.push((format!("L{i}.ia3.lff"), vec![cfg.ffn()]));
        }
        return (t, fr);
    }
    if m == "vera" {
        fr.push(("vera.A".into(), vec![peft.r_v, d]));
        fr.push(("vera.B".into(), vec![d, peft.r_v]));
    }
    for i in 0..cfg.layers {
        for proj in ["q", "v"] {
            let k = format!("L{i}.attn.{proj}");
            match m {
                "lora" | "dora" => {
                    t.push((format!("{k}.lora.A"), vec![peft.rank, d]));
                    t.push((format!("{k}.lora.B"), vec![d, peft.rank]));
                    if m == "dora" {
                        t.push((format!("{k}.dora.mag"), vec![d]));
                    }
                }
                "vera" => {
                    t.push((format!("{k}.vera.ld"), vec![peft.r_v]));
                    t.push((format!("{k}.vera.lb"), vec![d]));
                }
                "boft" => {
                    let bb = peft.boft_block;
                    assert_eq!(d % bb, 0, "boft block {bb} must divide d={d}");
                    t.push((format!("{k}.boft.skew"), vec![d / bb, bb, bb]));
                }
                "c3a" => {
                    let b = if peft.block > 0 { peft.block } else { d };
                    assert_eq!(d % b, 0, "c3a block {b} must divide d={d}");
                    t.push((format!("{k}.c3a.w"), vec![d / b, d / b, b]));
                }
                other => panic!("unknown method {other}"),
            }
        }
    }
    (t, fr)
}

/// Full role split: (trainable, frozen, frozen_random), ordered.
pub fn split_roles(cfg: &ModelCfg, peft: &PeftParams) -> (Shapes, Shapes, Shapes) {
    let base = base_param_shapes(cfg);
    let (adapt_t, adapt_fr) = adapter_param_shapes(cfg, peft);
    let m = peft.method.as_str();
    let mut trainable: Shapes = Vec::new();
    let mut frozen: Shapes = Vec::new();
    if cfg.kind == "mlp" {
        for (k, v) in base {
            let mid = k == "mlp.w1" || k == "mlp.b1";
            if mid && peft.mlp_mid != "dense" {
                continue; // middle layer replaced by the adapter op
            }
            trainable.push((k, v));
        }
        trainable.extend(adapt_t);
        return (trainable, frozen, adapt_fr);
    }
    for (k, v) in base {
        let is_head = k == "head.w" || k == "head.b";
        let is_bias = k.ends_with(".b")
            || k.contains(".attn.b")
            || k.ends_with(".b1")
            || k.ends_with(".b2");
        if m == "full" || is_head || (m == "bitfit" && is_bias) {
            trainable.push((k, v));
        } else {
            frozen.push((k, v));
        }
    }
    trainable.extend(adapt_t);
    (trainable, frozen, adapt_fr)
}

/// #Params as the paper reports it (classifier head excluded).
pub fn trainable_param_count(cfg: &ModelCfg, peft: &PeftParams) -> usize {
    let (t, _, _) = split_roles(cfg, peft);
    t.iter()
        .filter(|(k, _)| k != "head.w" && k != "head.b")
        .map(|(_, s)| s.iter().product::<usize>().max(1))
        .sum()
}

/// Declarative init spec per parameter (mirrors aot.py `init_spec`).
pub fn init_spec(name: &str, shape: &[usize]) -> InitSpec {
    // DoRA reuses the `.lora.A/.lora.B` parameter names, so one arm
    // covers both (the python side's `.dora.A` clause is unreachable).
    if name.contains(".lora.A") {
        return InitSpec::NormalFanin { fan: shape[1], seed: None };
    }
    if name.contains(".lora.B") || name.contains(".boft.skew") {
        return InitSpec::Zeros;
    }
    if name.contains(".dora.mag") || name.contains(".vera.lb") || name.contains(".ia3.") {
        return InitSpec::Ones;
    }
    if name.contains(".vera.ld") {
        return InitSpec::Const(0.1);
    }
    if name.contains(".c3a.w") {
        let (m, n, b) = (shape[0], shape[1], shape[2]);
        return InitSpec::C3a { fan_in: n * b, fan_out: m * b };
    }
    if name == "vera.A" || name == "vera.B" {
        // python draws A then B from ONE RandomState(1234) stream, so the
        // two frozen projections are independent samples.  Seeding a fresh
        // per-tensor stream with the same constant (the old behavior) made
        // vera.B replay vera.A's stream bit for bit — the "independent"
        // projections were perfectly correlated (B ∝ reshape(A)), which
        // collapses the VeRA baseline's effective randomness.  Derive a
        // distinct deterministic seed per name instead.
        return InitSpec::NormalFanin {
            fan: *shape.last().unwrap_or(&1),
            seed: Some(1234 ^ crate::substrate::prng::fnv1a(name)),
        };
    }
    InitSpec::Zeros
}

/// Data input layout per (kind, head): (name, shape, is_i32).
pub fn data_inputs(
    cfg: &ModelCfg,
    head: &str,
    batch: usize,
    kind: &str,
) -> Vec<(String, Vec<usize>, bool)> {
    let s = cfg.seq;
    let mut items: Vec<(String, Vec<usize>, bool)> = Vec::new();
    if cfg.kind == "mlp" {
        items.push(("data.x".into(), vec![batch, cfg.mlp_in], false));
        items.push(("data.y".into(), vec![batch], true));
        if kind == "eval" {
            items.truncate(1);
        }
        return items;
    }
    if cfg.kind == "decoder" {
        items.push(("data.tokens".into(), vec![batch, s], true));
        items.push(("data.loss_mask".into(), vec![batch, s], false));
        if kind == "eval" {
            items.truncate(1);
        }
        return items;
    }
    if head == "mlm" {
        return vec![
            ("data.tokens".into(), vec![batch, s], true),
            ("data.targets".into(), vec![batch, s], true),
            ("data.loss_mask".into(), vec![batch, s], false),
        ];
    }
    if cfg.input_mode == "vec" {
        items.push(("data.x".into(), vec![batch, s, cfg.patch_dim], false));
    } else {
        items.push(("data.tokens".into(), vec![batch, s], true));
    }
    if kind != "eval" {
        // data.y: f32 score for regression, i32 class index otherwise
        items.push(("data.y".into(), vec![batch], head != "reg"));
    }
    items
}

/// Fill model-dependent hyperparameters (mirrors aot.py `resolve_peft`).
pub fn resolve_peft(cfg: &ModelCfg, method_name: &str, peft: &PeftParams) -> PeftParams {
    let mut p = peft.clone();
    if p.method == "c3a" && p.mlp_mid != "c3a" {
        p.block = if method_name == "c3a_d1" {
            cfg.d
        } else if method_name == "c3a_d8" {
            cfg.d / 8
        } else if cfg.kind == "decoder" {
            cfg.d / 32
        } else {
            (cfg.d / 8).max(2)
        };
    } else if p.method == "vera" {
        p.r_v = if cfg.kind == "decoder" { 4 * cfg.d } else { 2 * cfg.d };
    }
    p
}

// ---------------------------------------------------------------------------
// Method suites (mirrors aot.py dictionaries, in declaration order)
// ---------------------------------------------------------------------------

fn pp(method: &str) -> PeftParams {
    PeftParams { method: method.to_string(), ..PeftParams::default() }
}

fn enc_methods() -> Vec<(&'static str, PeftParams)> {
    vec![
        ("full", pp("full")),
        ("bitfit", pp("bitfit")),
        ("ia3", pp("ia3")),
        ("lora", PeftParams { rank: 8, alpha: 16.0, ..pp("lora") }),
        ("vera", pp("vera")),
        ("boft", PeftParams { boft_block: 8, ..pp("boft") }),
        ("c3a_d1", PeftParams { block: 0, ..pp("c3a") }),
        ("c3a_d8", pp("c3a")),
    ]
}

fn dec_methods() -> Vec<(&'static str, PeftParams)> {
    vec![
        ("lora", PeftParams { rank: 32, alpha: 64.0, ..pp("lora") }),
        ("vera", pp("vera")),
        ("dora", PeftParams { rank: 32, alpha: 64.0, ..pp("dora") }),
        ("c3a", pp("c3a")),
    ]
}

fn vit_methods() -> Vec<(&'static str, PeftParams)> {
    vec![
        ("head", pp("head")),
        ("full", pp("full")),
        ("lora", PeftParams { rank: 16, alpha: 32.0, ..pp("lora") }),
        ("c3a", pp("c3a")),
    ]
}

fn mlp_variants() -> Vec<(&'static str, PeftParams)> {
    vec![
        ("mlp_dense", PeftParams { mlp_mid: "dense".into(), ..pp("full") }),
        ("mlp_lora", PeftParams { rank: 1, mlp_mid: "lora".into(), ..pp("full") }),
        ("mlp_c3a", PeftParams { block: 64, mlp_mid: "c3a".into(), ..pp("full") }),
    ]
}

fn train_batch(kind: &str) -> usize {
    match kind {
        "encoder" => 32,
        "decoder" => 16,
        _ => 64,
    }
}

/// The full artifact inventory: (model, method_name, peft, head, kind).
pub fn inventory() -> Vec<(&'static str, String, PeftParams, &'static str, &'static str)> {
    let mut jobs = Vec::new();
    for model in ["enc_tiny", "enc_base", "enc_large"] {
        for (mn, p) in enc_methods() {
            for head in ["cls", "reg"] {
                jobs.push((model, mn.to_string(), p.clone(), head, "train"));
                jobs.push((model, mn.to_string(), p.clone(), head, "eval"));
            }
        }
        jobs.push((model, "full".to_string(), pp("full"), "mlm", "train"));
    }
    for model in ["dec_small", "dec_large"] {
        for (mn, p) in dec_methods() {
            jobs.push((model, mn.to_string(), p.clone(), "lm", "train"));
            jobs.push((model, mn.to_string(), p.clone(), "lm", "eval"));
        }
        jobs.push((model, "full".to_string(), pp("full"), "lm", "train"));
    }
    for model in ["vit_base", "vit_large"] {
        for (mn, p) in vit_methods() {
            jobs.push((model, mn.to_string(), p.clone(), "vec", "train"));
            jobs.push((model, mn.to_string(), p.clone(), "vec", "eval"));
        }
    }
    for (mn, p) in mlp_variants() {
        jobs.push(("mlp", mn.to_string(), p.clone(), "cls", "train"));
        jobs.push(("mlp", mn.to_string(), p.clone(), "cls", "eval"));
    }
    jobs
}

// ---------------------------------------------------------------------------
// Spec assembly
// ---------------------------------------------------------------------------

/// Build one artifact spec (mirrors aot.py `build_artifact` manifest entry).
pub fn build_spec(
    dir: &Path,
    model: &str,
    cfg: &ModelCfg,
    method_name: &str,
    peft: &PeftParams,
    head: &str,
    kind: &str,
) -> ArtifactSpec {
    let peft = resolve_peft(cfg, method_name, peft);
    let (t_shapes, f_shapes, fr_shapes) = split_roles(cfg, &peft);
    let batch = train_batch(cfg.kind);
    let d_inputs = data_inputs(cfg, head, batch, kind);

    let mut inputs: Vec<InputSpec> = Vec::new();
    for (n, s) in &t_shapes {
        inputs.push(InputSpec {
            name: n.clone(),
            shape: s.clone(),
            i32_dtype: false,
            role: Role::Trainable,
            init: Some(init_spec(n, s)),
        });
    }
    if kind == "train" {
        for (role, tag) in [(Role::OptM, "opt_m"), (Role::OptV, "opt_v")] {
            for (n, s) in &t_shapes {
                inputs.push(InputSpec {
                    name: format!("{tag}:{n}"),
                    shape: s.clone(),
                    i32_dtype: false,
                    role,
                    init: Some(InitSpec::Zeros),
                });
            }
        }
    }
    for (n, s) in &f_shapes {
        inputs.push(InputSpec {
            name: n.clone(),
            shape: s.clone(),
            i32_dtype: false,
            role: Role::Frozen,
            init: Some(init_spec(n, s)),
        });
    }
    for (n, s) in &fr_shapes {
        inputs.push(InputSpec {
            name: n.clone(),
            shape: s.clone(),
            i32_dtype: false,
            role: Role::FrozenRandom,
            init: Some(init_spec(n, s)),
        });
    }
    for (n, s, i32_dtype) in &d_inputs {
        inputs.push(InputSpec {
            name: n.clone(),
            shape: s.clone(),
            i32_dtype: *i32_dtype,
            role: Role::Data,
            init: None,
        });
    }
    if kind == "train" {
        // `wd` exists only when some trainable receives decoupled decay
        // (mirrors the python DCE note in aot.py).
        let uses_wd = t_shapes.iter().any(|(n, _)| {
            !(n.ends_with(".b")
                || n.ends_with(".g")
                || n.ends_with(".mag")
                || n.ends_with(".lb")
                || n.ends_with(".ld"))
        });
        let scalars: &[&str] = if uses_wd { &["step", "lr", "wd"] } else { &["step", "lr"] };
        for n in scalars {
            inputs.push(InputSpec {
                name: n.to_string(),
                shape: Vec::new(),
                i32_dtype: false,
                role: Role::Scalar,
                init: None,
            });
        }
    }

    let name = Manifest::artifact_name(model, method_name, head, kind);
    let trainable_order: Vec<String> = t_shapes.iter().map(|(n, _)| n.clone()).collect();
    let mut frozen_order: Vec<String> = f_shapes.iter().map(|(n, _)| n.clone()).collect();
    frozen_order.extend(fr_shapes.iter().map(|(n, _)| n.clone()));
    let data_order: Vec<String> = d_inputs.iter().map(|(n, _, _)| n.clone()).collect();
    ArtifactSpec {
        path: dir.join(format!("{name}.hlo.txt")),
        model: model.to_string(),
        method: method_name.to_string(),
        kind: kind.to_string(),
        head: head.to_string(),
        batch,
        seq: cfg.seq,
        n_params: trainable_param_count(cfg, &peft),
        trainable_order,
        data_order,
        frozen_order,
        peft,
        inputs,
        name,
    }
}

fn meta_of(dir: &Path, name: &str, cfg: &ModelCfg) -> ModelMeta {
    ModelMeta {
        name: name.to_string(),
        init_path: dir.join(format!("{name}_init.bin")),
        d: cfg.d,
        layers: cfg.layers,
        vocab: cfg.vocab,
        seq: cfg.seq,
        n_out: cfg.n_out,
        kind: cfg.kind.to_string(),
        heads: cfg.heads,
        input_mode: cfg.input_mode.to_string(),
        patch_dim: cfg.patch_dim,
        mlp_hidden: cfg.mlp_hidden,
        mlp_in: cfg.mlp_in,
    }
}

/// Synthesize the full manifest for `dir` without python.  Init bins are
/// generated lazily by `Manifest::init_params`, so this is cheap.
pub fn synthesize(dir: &Path) -> Result<Manifest> {
    std::fs::create_dir_all(dir)?;
    let mut models: BTreeMap<String, ModelMeta> = BTreeMap::new();
    let mut artifacts: BTreeMap<String, ArtifactSpec> = BTreeMap::new();
    for (model, method_name, peft, head, kind) in inventory() {
        let cfg = preset(model).expect("inventory model has a preset");
        let spec = build_spec(dir, model, &cfg, &method_name, &peft, head, kind);
        artifacts.insert(spec.name.clone(), spec);
        models.entry(model.to_string()).or_insert_with(|| meta_of(dir, model, &cfg));
    }
    Ok(Manifest { dir: dir.to_path_buf(), models, artifacts })
}

/// Materialize a full positional input list for an artifact with synthetic
/// data: backbone/init params, zeroed optimizer state, deterministic token
/// ids, in-range labels, unit scalars (`step`=1, `lr`=0.01, others 0).
/// Shared by the interp unit tests, the parity integration tests, and
/// `bench_interp` so the input recipe tracks the contract in one place.
pub fn synth_inputs(spec: &ArtifactSpec, meta: &ModelMeta) -> Vec<xla::Literal> {
    use crate::peft::init::C3aScheme;
    use crate::runtime::session::tensor_to_literal;
    let mut rng = Rng::seed(1);
    let base = init_base_params(meta);
    let mut lits: Vec<xla::Literal> = Vec::new();
    for inp in &spec.inputs {
        let n: usize = inp.shape.iter().product::<usize>().max(1);
        match inp.role {
            Role::Trainable | Role::Frozen | Role::FrozenRandom => {
                let t = if let Some(p) = base.get(&inp.name) {
                    p.clone()
                } else {
                    inp.init
                        .as_ref()
                        .expect("input without init")
                        .materialize(&inp.shape, &mut rng, C3aScheme::Xavier)
                };
                lits.push(tensor_to_literal(&t).expect("literal conversion"));
            }
            Role::OptM | Role::OptV => {
                lits.push(xla::Literal::from_f32(&inp.shape, vec![0.0; n]));
            }
            Role::Data => {
                if inp.i32_dtype {
                    let vals: Vec<i32> = if inp.name == "data.y" {
                        (0..n).map(|i| (i % 2) as i32).collect()
                    } else {
                        (0..n).map(|i| if i % 7 == 0 { 1 } else { 4 + (i as i32 % 50) }).collect()
                    };
                    lits.push(xla::Literal::from_i32(&inp.shape, vals));
                } else {
                    lits.push(xla::Literal::from_f32(&inp.shape, vec![1.0; n]));
                }
            }
            Role::Scalar => {
                let v = match inp.name.as_str() {
                    "step" => 1.0,
                    "lr" => 0.01,
                    _ => 0.0,
                };
                lits.push(xla::Literal::scalar(v));
            }
        }
    }
    lits
}

// ---------------------------------------------------------------------------
// Backbone initialization (mirrors model.py `init_base_params`)
// ---------------------------------------------------------------------------

/// The 'pre-pretraining' starting point for a model's backbone.
pub fn init_base_params(meta: &ModelMeta) -> TensorMap {
    let cfg = cfg_from_meta(meta);
    let mut rng = Rng::seed(crate::substrate::prng::fnv1a(&meta.name) ^ 0x1417_B005);
    let mut out = TensorMap::new();
    for (k, shp) in base_param_shapes(&cfg) {
        let n: usize = shp.iter().product::<usize>().max(1);
        let is_gain = k.ends_with(".g");
        let is_bias = k.ends_with(".b")
            || (k.starts_with('L') && k.contains(".attn.b"))
            || k.ends_with(".b1")
            || k.ends_with(".b2")
            || k.ends_with(".b0");
        let values: Vec<f32> = if is_gain {
            vec![1.0; n]
        } else if is_bias {
            vec![0.0; n]
        } else if k == "embed.pos" {
            rng.normal_vec(n, 0.02)
        } else {
            let fan_in = *shp.first().unwrap_or(&1);
            rng.normal_vec(n, 1.0 / (fan_in.max(1) as f64).sqrt())
        };
        out.insert(k, Tensor::from_f32(shp, &values));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inventory_matches_python_count() {
        // 3 encoders * (8 methods * 2 heads * 2 kinds + 1 mlm)
        // + 2 decoders * (4 methods * 2 + 1)
        // + 2 vits * (4 methods * 2) + 3 mlp variants * 2
        let want = 3 * (8 * 2 * 2 + 1) + 2 * (4 * 2 + 1) + 2 * (4 * 2) + 3 * 2;
        assert_eq!(inventory().len(), want);
    }

    #[test]
    fn synthesized_manifest_contract() {
        let dir = std::env::temp_dir().join("c3a_catalog_test");
        let m = synthesize(&dir).unwrap();
        assert!(m.models.contains_key("enc_tiny"));
        let a = m.artifact("enc_tiny__c3a_d8__cls__train").unwrap();
        assert_eq!(a.kind, "train");
        assert_eq!(a.head, "cls");
        assert!(a.n_params > 0);
        // input ordering invariant: trainable block first, scalars last
        assert_eq!(a.inputs[0].role, Role::Trainable);
        assert_eq!(a.inputs.last().unwrap().role, Role::Scalar);
        // every trainable has an init spec
        assert!(a.inputs.iter().filter(|i| i.role == Role::Trainable).all(|i| i.init.is_some()));
        // train artifact has matching m/v counts
        let nt = a.trainable_order.len();
        let nm = a.inputs.iter().filter(|i| i.role == Role::OptM).count();
        assert_eq!(nt, nm);
        // c3a_d8 on enc_tiny: block = d/8 = 4
        assert_eq!(a.peft.block, 4);
    }

    #[test]
    fn eval_artifacts_have_no_labels_or_scalars() {
        let dir = std::env::temp_dir().join("c3a_catalog_test2");
        let m = synthesize(&dir).unwrap();
        let e = m.artifact("enc_tiny__lora__cls__eval").unwrap();
        assert!(e.inputs.iter().all(|i| i.role != Role::Scalar && i.role != Role::OptM));
        assert_eq!(e.data_order, vec!["data.tokens".to_string()]);
    }

    #[test]
    fn wd_scalar_dce_mirrored() {
        // decoder VeRA: every trainable is decay-exempt except head-less
        // decoders have no head params; λd/λb end with .ld/.lb
        let dir = std::env::temp_dir().join("c3a_catalog_test3");
        let m = synthesize(&dir).unwrap();
        let vera = m.artifact("dec_small__vera__lm__train").unwrap();
        assert!(!vera.inputs.iter().any(|i| i.name == "wd"), "vera decoder should drop wd");
        let lora = m.artifact("dec_small__lora__lm__train").unwrap();
        assert!(lora.inputs.iter().any(|i| i.name == "wd"));
    }

    #[test]
    fn param_counts_match_paper_structure() {
        let cfg = preset("enc_base").unwrap();
        // c3a_d8: per adapted proj (q,v) per layer: (d/b)^2 * b = d^2/b
        let p = resolve_peft(&cfg, "c3a_d8", &pp("c3a"));
        let n = trainable_param_count(&cfg, &p);
        let b = cfg.d / 8;
        assert_eq!(n, cfg.layers * 2 * (cfg.d / b) * (cfg.d / b) * b);
        // lora: 2 * r * d per proj
        let lp = PeftParams { rank: 8, alpha: 16.0, ..pp("lora") };
        let nl = trainable_param_count(&cfg, &lp);
        assert_eq!(nl, cfg.layers * 2 * 2 * 8 * cfg.d);
    }

    /// Regression: vera.A and vera.B used to materialize from the SAME
    /// seeded stream (fresh Rng::seed(1234) each), so B was a bit-exact
    /// scaled replay of A.  The python reference draws both from one
    /// continuing RandomState(1234) stream — independent values.
    #[test]
    fn vera_frozen_projections_are_decorrelated() {
        use crate::peft::init::C3aScheme;
        let mut rng = Rng::seed(0);
        let (rv, d) = (64usize, 32usize);
        let a = init_spec("vera.A", &[rv, d]).materialize(&[rv, d], &mut rng, C3aScheme::Xavier);
        let b = init_spec("vera.B", &[d, rv]).materialize(&[d, rv], &mut rng, C3aScheme::Xavier);
        let (av, bv) = (a.as_f32(), b.as_f32());
        // identical streams are exactly proportional: a[0]/a[1] == b[0]/b[1]
        let (ra, rb) = (av[0] / av[1], bv[0] / bv[1]);
        assert!(
            (ra - rb).abs() > 1e-6,
            "vera.A/vera.B still share one random stream (ratio {ra} vs {rb})"
        );
    }

    #[test]
    fn init_base_params_is_deterministic_and_shaped() {
        let dir = std::env::temp_dir().join("c3a_catalog_test4");
        let m = synthesize(&dir).unwrap();
        let meta = m.model("enc_tiny").unwrap();
        let a = init_base_params(meta);
        let b = init_base_params(meta);
        assert_eq!(a["embed.tok"].as_f32(), b["embed.tok"].as_f32());
        assert_eq!(a["embed.tok"].shape, vec![64, 32]);
        assert!(a["L0.ln1.g"].as_f32().iter().all(|&v| v == 1.0));
        assert!(a["L0.attn.bq"].as_f32().iter().all(|&v| v == 0.0));
    }
}
