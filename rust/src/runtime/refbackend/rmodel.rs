//! Reference-oracle forward graphs — a second, independent Rust port of
//! python/compile/model.py on the naive f64 tape ([`super::rtape`]).
//!
//! Structurally mirrors `runtime::interp::model` (it must: both implement
//! the same paper models) but shares none of its numeric code: f64
//! throughout, dense circular convolution, no spectra cache, no threads.

use super::rtape::{RAct, RArr, RTape, RV};
use crate::runtime::manifest::{ModelMeta, PeftParams};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

const NEG: f64 = -1e9;

/// Model inputs for one batch (exactly one of `tokens` / `x` per kind).
pub struct RInput {
    /// [b*s] token ids (tokens mode / decoder)
    pub tokens: Option<Vec<i32>>,
    /// [b,s,patch] patch vectors (vec mode) or [b,in] mlp features
    pub x: Option<RArr>,
    pub b: usize,
    pub s: usize,
}

pub struct RGraph<'a> {
    pub tape: &'a mut RTape,
    pub params: &'a BTreeMap<String, RV>,
    pub meta: &'a ModelMeta,
    pub peft: &'a PeftParams,
}

impl<'a> RGraph<'a> {
    fn p(&self, name: &str) -> Result<RV> {
        self.params.get(name).copied().with_context(|| format!("missing parameter {name}"))
    }

    /// y = x @ w0 (+ bias) + delta(x) for the adapted q/v projections.
    fn adapted_linear(&mut self, key: &str, x: RV, w0: RV, bias: Option<RV>) -> Result<RV> {
        let method = self.peft.method.clone();
        let mut y = if method == "dora" {
            let a = self.p(&format!("{key}.lora.A"))?; // [r, d_in]
            let bmat = self.p(&format!("{key}.lora.B"))?; // [d_out, r]
            let scale = self.peft.alpha / self.peft.rank.max(1) as f64;
            let ba = self.tape.matmul(bmat, a, false); // [d_out, d_in]
            let bat = self.tape.transpose2(ba); // [d_in, d_out]
            let delta = self.tape.scale(bat, scale);
            let w = self.tape.add(w0, delta);
            let w2 = self.tape.mul(w, w);
            let colsum = self.tape.sum_axis0(w2); // [d_out]
            let inv = self.tape.rsqrt(colsum, 1e-6);
            let wn = self.tape.mul(w, inv);
            let mag = self.p(&format!("{key}.dora.mag"))?;
            let wm = self.tape.mul(wn, mag);
            self.tape.matmul(x, wm, false)
        } else {
            let mut y = self.tape.matmul(x, w0, false);
            match method.as_str() {
                "lora" => {
                    let a = self.p(&format!("{key}.lora.A"))?;
                    let bmat = self.p(&format!("{key}.lora.B"))?;
                    let scale = self.peft.alpha / self.peft.rank.max(1) as f64;
                    let xa = self.tape.matmul(x, a, true);
                    let xab = self.tape.matmul(xa, bmat, true);
                    let delta = self.tape.scale(xab, scale);
                    y = self.tape.add(y, delta);
                }
                "vera" => {
                    let a = self.p("vera.A")?;
                    let bmat = self.p("vera.B")?;
                    let ld = self.p(&format!("{key}.vera.ld"))?;
                    let lb = self.p(&format!("{key}.vera.lb"))?;
                    let xa = self.tape.matmul(x, a, true);
                    let xad = self.tape.mul(xa, ld);
                    let xb = self.tape.matmul(xad, bmat, true);
                    let delta = self.tape.mul(xb, lb);
                    y = self.tape.add(y, delta);
                }
                "boft" => {
                    // truncated exp(skew), order 4 (identity at init)
                    let s = self.p(&format!("{key}.boft.skew"))?; // [nb,bb,bb]
                    let bb = self.tape.val(s).shape[2];
                    let st = self.tape.transpose2(s);
                    let diff = self.tape.sub(s, st);
                    let skew = self.tape.scale(diff, 0.5);
                    let s2 = self.tape.matmul(skew, skew, false);
                    let s3 = self.tape.matmul(s2, skew, false);
                    let s4 = self.tape.matmul(s2, s2, false);
                    let mut eye = RArr::zeros(vec![1, bb, bb]);
                    for i in 0..bb {
                        eye.data[i * bb + i] = 1.0;
                    }
                    let eye = self.tape.leaf(eye, false);
                    let t2 = self.tape.scale(s2, 0.5);
                    let t3 = self.tape.scale(s3, 1.0 / 6.0);
                    let t4 = self.tape.scale(s4, 1.0 / 24.0);
                    let mut r = self.tape.add(eye, skew);
                    r = self.tape.add(r, t2);
                    r = self.tape.add(r, t3);
                    r = self.tape.add(r, t4);
                    y = self.tape.block_rotate(y, r);
                }
                "c3a" => {
                    let w = self.p(&format!("{key}.c3a.w"))?;
                    let delta = self.tape.circ_conv(x, w);
                    y = self.tape.add(y, delta);
                }
                "full" | "head" | "bitfit" | "ia3" => {}
                other => bail!("unsupported PEFT method {other} in reference backend"),
            }
            y
        };
        if let Some(b) = bias {
            y = self.tape.add(y, b);
        }
        Ok(y)
    }

    fn attention(&mut self, i: usize, x: RV, mask: RV) -> Result<RV> {
        let l = format!("L{i}");
        let enc = self.meta.kind != "decoder";
        let heads = self.meta.heads;
        let hd = self.meta.d / heads;
        let wq = self.p(&format!("{l}.attn.wq"))?;
        let wk = self.p(&format!("{l}.attn.wk"))?;
        let wv = self.p(&format!("{l}.attn.wv"))?;
        let wo = self.p(&format!("{l}.attn.wo"))?;
        let bias = |g: &Self, proj: &str| -> Result<Option<RV>> {
            if enc {
                Ok(Some(g.p(&format!("{l}.attn.b{proj}"))?))
            } else {
                Ok(None)
            }
        };
        let bq = bias(self, "q")?;
        let bv = bias(self, "v")?;
        let q = self.adapted_linear(&format!("{l}.attn.q"), x, wq, bq)?;
        let mut k = self.tape.matmul(x, wk, false);
        if enc {
            let bk = self.p(&format!("{l}.attn.bk"))?;
            k = self.tape.add(k, bk);
        }
        let mut v = self.adapted_linear(&format!("{l}.attn.v"), x, wv, bv)?;
        if self.peft.method == "ia3" {
            let lk = self.p(&format!("{l}.ia3.lk"))?;
            let lv = self.p(&format!("{l}.ia3.lv"))?;
            k = self.tape.mul(k, lk);
            v = self.tape.mul(v, lv);
        }
        let qh = self.tape.split_heads(q, heads);
        let kh = self.tape.split_heads(k, heads);
        let vh = self.tape.split_heads(v, heads);
        let att = self.tape.matmul(qh, kh, true);
        let att = self.tape.scale(att, 1.0 / (hd as f64).sqrt());
        let att = self.tape.add(att, mask);
        let att = self.tape.softmax_last(att);
        let out = self.tape.matmul(att, vh, false);
        let merged = self.tape.merge_heads(out);
        let mut o = self.tape.matmul(merged, wo, false);
        if enc {
            let bo = self.p(&format!("{l}.attn.bo"))?;
            o = self.tape.add(o, bo);
        }
        Ok(o)
    }

    fn ffn(&mut self, i: usize, x: RV) -> Result<RV> {
        let l = format!("L{i}");
        if self.meta.kind != "decoder" {
            let w1 = self.p(&format!("{l}.mlp.w1"))?;
            let b1 = self.p(&format!("{l}.mlp.b1"))?;
            let xw = self.tape.matmul(x, w1, false);
            let xb = self.tape.add(xw, b1);
            let mut h = self.tape.activation(xb, RAct::Gelu);
            if self.peft.method == "ia3" {
                let lff = self.p(&format!("{l}.ia3.lff"))?;
                h = self.tape.mul(h, lff);
            }
            let w2 = self.p(&format!("{l}.mlp.w2"))?;
            let b2 = self.p(&format!("{l}.mlp.b2"))?;
            let hw = self.tape.matmul(h, w2, false);
            Ok(self.tape.add(hw, b2))
        } else {
            let wg = self.p(&format!("{l}.mlp.wg"))?;
            let wu = self.p(&format!("{l}.mlp.wu"))?;
            let wd = self.p(&format!("{l}.mlp.wd"))?;
            let xg = self.tape.matmul(x, wg, false);
            let g = self.tape.activation(xg, RAct::Silu);
            let u = self.tape.matmul(x, wu, false);
            let mut h = self.tape.mul(g, u);
            if self.peft.method == "ia3" {
                let lff = self.p(&format!("{l}.ia3.lff"))?;
                h = self.tape.mul(h, lff);
            }
            Ok(self.tape.matmul(h, wd, false))
        }
    }

    fn encoder_fwd(&mut self, input: &RInput, voc_head: bool) -> Result<RV> {
        let (b, s) = (input.b, input.s);
        let mut pad = vec![false; b * s];
        let mut x = if self.meta.input_mode == "vec" {
            let xv = input.x.as_ref().context("vec-mode encoder needs data.x")?;
            let xleaf = self.tape.leaf(xv.clone(), false);
            let patch = self.p("embed.patch")?;
            self.tape.matmul(xleaf, patch, false)
        } else {
            let toks = input.tokens.as_ref().context("token encoder needs data.tokens")?;
            for (i, &t) in toks.iter().enumerate() {
                pad[i] = t == 0;
            }
            let ids: Vec<usize> = toks.iter().map(|&t| t.max(0) as usize).collect();
            let tok = self.p("embed.tok")?;
            self.tape.gather(tok, &ids, &[b, s])
        };
        let pos = self.p("embed.pos")?;
        x = self.tape.add(x, pos);
        let mut mask = RArr::zeros(vec![b, 1, 1, s]);
        for bi in 0..b {
            for si in 0..s {
                if pad[bi * s + si] {
                    mask.data[bi * s + si] = NEG;
                }
            }
        }
        let mask = self.tape.leaf(mask, false);
        for i in 0..self.meta.layers {
            let att = self.attention(i, x, mask)?;
            let res = self.tape.add(x, att);
            let g1 = self.p(&format!("L{i}.ln1.g"))?;
            let b1 = self.p(&format!("L{i}.ln1.b"))?;
            x = self.tape.layernorm(res, g1, b1);
            let ff = self.ffn(i, x)?;
            let res2 = self.tape.add(x, ff);
            let g2 = self.p(&format!("L{i}.ln2.g"))?;
            let b2 = self.p(&format!("L{i}.ln2.b"))?;
            x = self.tape.layernorm(res2, g2, b2);
        }
        let gf = self.p("final_ln.g")?;
        let bf = self.p("final_ln.b")?;
        x = self.tape.layernorm(x, gf, bf);
        if voc_head {
            let tok = self.p("embed.tok")?;
            Ok(self.tape.matmul(x, tok, true))
        } else {
            let pooled = self.tape.slice_first(x);
            let hw = self.p("head.w")?;
            let hb = self.p("head.b")?;
            let lw = self.tape.matmul(pooled, hw, false);
            Ok(self.tape.add(lw, hb))
        }
    }

    fn decoder_fwd(&mut self, input: &RInput) -> Result<RV> {
        let (b, s) = (input.b, input.s);
        let toks = input.tokens.as_ref().context("decoder needs data.tokens")?;
        let ids: Vec<usize> = toks.iter().map(|&t| t.max(0) as usize).collect();
        let tok = self.p("embed.tok")?;
        let mut x = self.tape.gather(tok, &ids, &[b, s]);
        let pos = self.p("embed.pos")?;
        x = self.tape.add(x, pos);
        let mut mask = RArr::zeros(vec![b, 1, s, s]);
        for bi in 0..b {
            for qi in 0..s {
                for ki in 0..s {
                    let mut v = 0.0;
                    if ki > qi {
                        v += NEG;
                    }
                    if toks[bi * s + ki] == 0 {
                        v += NEG;
                    }
                    mask.data[(bi * s + qi) * s + ki] = v;
                }
            }
        }
        let mask = self.tape.leaf(mask, false);
        for i in 0..self.meta.layers {
            let g1 = self.p(&format!("L{i}.rms1.g"))?;
            let h = self.tape.rmsnorm(x, g1);
            let att = self.attention(i, h, mask)?;
            x = self.tape.add(x, att);
            let g2 = self.p(&format!("L{i}.rms2.g"))?;
            let h2 = self.tape.rmsnorm(x, g2);
            let ff = self.ffn(i, h2)?;
            x = self.tape.add(x, ff);
        }
        let gf = self.p("final_rms.g")?;
        x = self.tape.rmsnorm(x, gf);
        Ok(self.tape.matmul(x, tok, true))
    }

    fn mlp_fwd(&mut self, input: &RInput) -> Result<RV> {
        let xv = input.x.as_ref().context("mlp needs data.x")?;
        let x = self.tape.leaf(xv.clone(), false);
        let w0 = self.p("mlp.w0")?;
        let b0 = self.p("mlp.b0")?;
        let xw = self.tape.matmul(x, w0, false);
        let xb = self.tape.add(xw, b0);
        let h = self.tape.activation(xb, RAct::Relu);
        let mid = match self.peft.mlp_mid.as_str() {
            "dense" => {
                let w1 = self.p("mlp.w1")?;
                let b1 = self.p("mlp.b1")?;
                let hw = self.tape.matmul(h, w1, false);
                self.tape.add(hw, b1)
            }
            "lora" => {
                let a = self.p("mlp.mid.lora.A")?;
                let bmat = self.p("mlp.mid.lora.B")?;
                let ha = self.tape.matmul(h, a, true);
                self.tape.matmul(ha, bmat, true)
            }
            "c3a" => {
                let w = self.p("mlp.mid.c3a.w")?;
                self.tape.circ_conv(h, w)
            }
            other => bail!("unknown mlp_mid {other}"),
        };
        let h2 = self.tape.activation(mid, RAct::Relu);
        let w2 = self.p("mlp.w2")?;
        let b2 = self.p("mlp.b2")?;
        let lw = self.tape.matmul(h2, w2, false);
        Ok(self.tape.add(lw, b2))
    }

    /// Dispatch on (model kind, artifact head); returns the logits node.
    pub fn forward(&mut self, head: &str, input: &RInput) -> Result<RV> {
        match self.meta.kind.as_str() {
            "mlp" => self.mlp_fwd(input),
            "decoder" => self.decoder_fwd(input),
            _ => self.encoder_fwd(input, head == "mlm"),
        }
    }
}
