//! Reference oracle backend — a second, deliberately naive implementation
//! of the [`Backend`](crate::runtime::backend::Backend) /
//! [`Executor`](crate::runtime::backend::Executor) contract used to
//! differentially test the substrate interpreter (and, once vendored, the
//! real PJRT path): same artifact specs, same positional PJRT flattening,
//! completely independent numerics.
//!
//! Everything the substrate optimizes, this backend refuses to: dense
//! O(b²) circular convolution instead of FFT, direct-indexed scalar-loop
//! matmuls, f64 end to end, straight-line AdamW, no kernel-spectra or
//! parse caches, no thread pool (stateful execution degrades to the
//! stateless path via the trait defaults).  `rust/tests/differential.rs`
//! runs every tiny-catalog artifact through both backends and compares
//! forward logits, losses, every recovered parameter gradient (plus
//! central finite differences through [`RefExecutable::loss_f64`]), and
//! multi-step train trajectories under documented error budgets.

pub mod rmodel;
pub mod rtape;

use self::rmodel::{RGraph, RInput};
use self::rtape::{RArr, RTape, RV};
use crate::runtime::backend::{Backend, Executor};
use crate::runtime::manifest::{ArtifactSpec, ModelMeta, Role};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

const BETA1: f64 = 0.9;
const BETA2: f64 = 0.999;
const EPS: f64 = 1e-8;

/// Loads artifact specs into naive reference executors.
pub struct RefBackend;

impl Backend for RefBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn load(&self, spec: &ArtifactSpec, meta: &ModelMeta) -> Result<Box<dyn Executor>> {
        Ok(Box::new(RefExecutable::new(spec, meta)?))
    }
}

/// A loaded artifact on the reference backend.  Pure function of its
/// positional inputs — nothing is cached between calls.
pub struct RefExecutable {
    spec: ArtifactSpec,
    meta: ModelMeta,
}

struct RefParsed {
    /// (name, value) in trainable_order
    trainable: Vec<(String, RArr)>,
    opt_m: Vec<RArr>,
    opt_v: Vec<RArr>,
    /// (name, value) for frozen + frozen_random
    frozen: Vec<(String, RArr)>,
    data_f64: BTreeMap<String, RArr>,
    data_i32: BTreeMap<String, Vec<i32>>,
    scalars: BTreeMap<String, f64>,
}

fn lit_to_rarr(lit: &xla::Literal, shape: &[usize]) -> Result<RArr> {
    let data = lit.to_vec::<f32>()?;
    if data.len() != shape.iter().product::<usize>().max(1) {
        bail!("literal has {} elements, manifest shape {shape:?}", data.len());
    }
    Ok(RArr::new(shape.to_vec(), data.into_iter().map(|v| v as f64).collect()))
}

fn rarr_to_lit(a: &RArr) -> xla::Literal {
    xla::Literal::from_f32(&a.shape, a.data.iter().map(|&v| v as f32).collect())
}

/// First strict maximum (naive; NaN entries never win).
fn argmax_f64(row: &[f64]) -> usize {
    let mut best = 0usize;
    let mut bv = f64::NEG_INFINITY;
    for (i, &v) in row.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best
}

impl RefExecutable {
    pub fn new(spec: &ArtifactSpec, meta: &ModelMeta) -> Result<RefExecutable> {
        match meta.kind.as_str() {
            "encoder" | "decoder" | "mlp" => {}
            other => bail!("{}: unsupported model kind {other}", spec.name),
        }
        match spec.peft.method.as_str() {
            "full" | "head" | "bitfit" | "ia3" | "lora" | "dora" | "vera" | "boft" | "c3a" => {}
            other => bail!("{}: unsupported PEFT method {other}", spec.name),
        }
        Ok(RefExecutable { spec: spec.clone(), meta: meta.clone() })
    }

    fn parse(&self, inputs: &[&xla::Literal]) -> Result<RefParsed> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: got {} inputs, manifest declares {}",
                self.spec.name,
                inputs.len(),
                self.spec.inputs.len()
            );
        }
        let mut p = RefParsed {
            trainable: Vec::new(),
            opt_m: Vec::new(),
            opt_v: Vec::new(),
            frozen: Vec::new(),
            data_f64: BTreeMap::new(),
            data_i32: BTreeMap::new(),
            scalars: BTreeMap::new(),
        };
        for (inp, lit) in self.spec.inputs.iter().zip(inputs.iter()) {
            match inp.role {
                Role::Trainable => {
                    p.trainable.push((inp.name.clone(), lit_to_rarr(lit, &inp.shape)?))
                }
                Role::OptM => p.opt_m.push(lit_to_rarr(lit, &inp.shape)?),
                Role::OptV => p.opt_v.push(lit_to_rarr(lit, &inp.shape)?),
                Role::Frozen | Role::FrozenRandom => {
                    p.frozen.push((inp.name.clone(), lit_to_rarr(lit, &inp.shape)?))
                }
                Role::Data => {
                    if inp.i32_dtype {
                        p.data_i32.insert(inp.name.clone(), lit.to_vec::<i32>()?);
                    } else {
                        p.data_f64.insert(inp.name.clone(), lit_to_rarr(lit, &inp.shape)?);
                    }
                }
                Role::Scalar => {
                    p.scalars.insert(inp.name.clone(), lit.get_first_element::<f32>()? as f64);
                }
            }
        }
        Ok(p)
    }

    /// Build tape leaves + the model input, run the forward pass.
    fn forward(&self, tape: &mut RTape, parsed: &RefParsed) -> Result<(RV, Vec<RV>, RInput)> {
        let mut params: BTreeMap<String, RV> = BTreeMap::new();
        let mut t_ids = Vec::with_capacity(parsed.trainable.len());
        for (name, arr) in &parsed.trainable {
            let id = tape.leaf(arr.clone(), true);
            t_ids.push(id);
            params.insert(name.clone(), id);
        }
        for (name, arr) in &parsed.frozen {
            let id = tape.leaf(arr.clone(), false);
            params.insert(name.clone(), id);
        }
        let (b, s) = (self.spec.batch, self.spec.seq);
        let input = RInput {
            tokens: parsed.data_i32.get("data.tokens").cloned(),
            x: parsed.data_f64.get("data.x").cloned(),
            b,
            s,
        };
        let mut graph = RGraph { tape, params: &params, meta: &self.meta, peft: &self.spec.peft };
        let logits = graph.forward(&self.spec.head, &input)?;
        Ok((logits, t_ids, input))
    }

    /// Compute (loss, metric, dL/dlogits) — mirrors python task_loss.
    fn loss_head(
        &self,
        tape: &RTape,
        logits: RV,
        parsed: &RefParsed,
        input: &RInput,
    ) -> Result<(f64, f64, Vec<f64>)> {
        let lv = tape.val(logits);
        let head = self.spec.head.as_str();
        let kind = self.meta.kind.as_str();
        let (b, s) = (input.b, input.s);

        if kind == "decoder" || head == "mlm" {
            let mask =
                parsed.data_f64.get("data.loss_mask").context("missing data.loss_mask")?;
            let targets: Vec<i32> = if head == "mlm" {
                parsed.data_i32.get("data.targets").context("missing data.targets")?.clone()
            } else {
                let toks = input.tokens.as_ref().context("missing data.tokens")?;
                let mut t = vec![0i32; b * s];
                for bi in 0..b {
                    for si in 0..s.saturating_sub(1) {
                        t[bi * s + si] = toks[bi * s + si + 1];
                    }
                }
                t
            };
            let vcb = *lv.shape.last().unwrap();
            let denom = mask.data.iter().sum::<f64>().max(1.0);
            let mut loss = 0.0;
            let mut correct = 0.0;
            let mut dl = vec![0.0; lv.len()];
            for pos in 0..b * s {
                let m = mask.data[pos];
                // masked (padding) positions skipped before target checks,
                // same contract as the substrate loss head
                if m == 0.0 {
                    continue;
                }
                let row = &lv.data[pos * vcb..(pos + 1) * vcb];
                let tgt = targets[pos].max(0) as usize;
                if tgt >= vcb {
                    bail!("target {tgt} out of vocab {vcb}");
                }
                let mx = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                let sum: f64 = row.iter().map(|&v| (v - mx).exp()).sum();
                let lse = mx + sum.ln();
                loss += m * (lse - row[tgt]);
                if argmax_f64(row) == tgt {
                    correct += m;
                }
                for j in 0..vcb {
                    let p = (row[j] - lse).exp();
                    let onehot = if j == tgt { 1.0 } else { 0.0 };
                    dl[pos * vcb + j] = m * (p - onehot) / denom;
                }
            }
            return Ok((loss / denom, correct, dl));
        }

        if head == "reg" {
            let y = parsed.data_f64.get("data.y").context("missing data.y")?;
            let w = lv.shape[1];
            let mut loss = 0.0;
            let mut pred_sum = 0.0;
            let mut dl = vec![0.0; lv.len()];
            for r in 0..b {
                let pred = lv.data[r * w];
                let diff = pred - y.data[r];
                loss += diff * diff;
                pred_sum += pred;
                dl[r * w] = 2.0 * diff / b as f64;
            }
            return Ok((loss / b as f64, pred_sum, dl));
        }

        // classification (cls / vec / mlp): mean CE over [b, n_out]
        let y = parsed.data_i32.get("data.y").context("missing data.y")?;
        let w = lv.shape[1];
        let mut loss = 0.0;
        let mut correct = 0.0;
        let mut dl = vec![0.0; lv.len()];
        for r in 0..b {
            let row = &lv.data[r * w..(r + 1) * w];
            let tgt = y[r].max(0) as usize;
            if tgt >= w {
                bail!("label {tgt} out of range {w}");
            }
            let mx = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let sum: f64 = row.iter().map(|&v| (v - mx).exp()).sum();
            let lse = mx + sum.ln();
            loss += lse - row[tgt];
            if argmax_f64(row) == tgt {
                correct += 1.0;
            }
            for j in 0..w {
                let p = (row[j] - lse).exp();
                let onehot = if j == tgt { 1.0 } else { 0.0 };
                dl[r * w + j] = (p - onehot) / b as f64;
            }
        }
        Ok((loss / b as f64, correct, dl))
    }

    /// Execute the artifact on host literals (train or eval contract).
    pub fn execute(&self, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let parsed = self.parse(inputs)?;
        if self.spec.kind == "train" {
            self.train_step(parsed)
        } else {
            let mut tape = RTape::new();
            let (logits, _t_ids, _input) = self.forward(&mut tape, &parsed)?;
            Ok(vec![rarr_to_lit(tape.val(logits))])
        }
    }

    fn train_step(&self, parsed: RefParsed) -> Result<Vec<xla::Literal>> {
        let mut tape = RTape::new();
        let (logits, t_ids, input) = self.forward(&mut tape, &parsed)?;
        let (loss, metric, dlogits) = self.loss_head(&tape, logits, &parsed, &input)?;
        let grads = tape.backward(logits, dlogits);

        let step = *parsed.scalars.get("step").context("missing scalar step")?;
        let lr = *parsed.scalars.get("lr").context("missing scalar lr")?;
        let wd = parsed.scalars.get("wd").copied().unwrap_or(0.0);
        let bc1 = 1.0 - BETA1.powf(step);
        let bc2 = 1.0 - BETA2.powf(step);

        let nt = parsed.trainable.len();
        let mut new_t = Vec::with_capacity(nt);
        let mut new_m = Vec::with_capacity(nt);
        let mut new_v = Vec::with_capacity(nt);
        for (i, (name, p)) in parsed.trainable.iter().enumerate() {
            let zero;
            let g: &Vec<f64> = match grads[t_ids[i]].as_ref() {
                Some(g) => g,
                None => {
                    zero = vec![0.0; p.len()];
                    &zero
                }
            };
            let exempt = name.ends_with(".b")
                || name.ends_with(".g")
                || name.ends_with(".mag")
                || name.ends_with(".lb")
                || name.ends_with(".ld");
            let decay = if exempt { 0.0 } else { wd };
            let m0 = &parsed.opt_m[i];
            let v0 = &parsed.opt_v[i];
            let mut pn = RArr::zeros(p.shape.clone());
            let mut mn = RArr::zeros(p.shape.clone());
            let mut vn = RArr::zeros(p.shape.clone());
            for e in 0..p.len() {
                let gv = g[e];
                let nm = BETA1 * m0.data[e] + (1.0 - BETA1) * gv;
                let nv = BETA2 * v0.data[e] + (1.0 - BETA2) * gv * gv;
                let upd = (nm / bc1) / ((nv / bc2).sqrt() + EPS);
                pn.data[e] = p.data[e] - lr * (upd + decay * p.data[e]);
                mn.data[e] = nm;
                vn.data[e] = nv;
            }
            new_t.push(rarr_to_lit(&pn));
            new_m.push(rarr_to_lit(&mn));
            new_v.push(rarr_to_lit(&vn));
        }
        let mut outs = new_t;
        outs.extend(new_m);
        outs.extend(new_v);
        outs.push(xla::Literal::scalar(loss as f32));
        outs.push(xla::Literal::scalar(metric as f32));
        Ok(outs)
    }

    /// Full-precision loss probe for finite-difference checks: runs the
    /// forward + loss head in f64 and returns the scalar loss without
    /// touching the optimizer (train artifacts only).
    pub fn loss_f64(&self, inputs: &[&xla::Literal]) -> Result<f64> {
        if self.spec.kind != "train" {
            bail!("{}: loss_f64 needs a train artifact", self.spec.name);
        }
        let parsed = self.parse(inputs)?;
        let mut tape = RTape::new();
        let (logits, _t_ids, input) = self.forward(&mut tape, &parsed)?;
        let (loss, _metric, _dl) = self.loss_head(&tape, logits, &parsed, &input)?;
        Ok(loss)
    }

    /// Full-precision analytic gradients: (loss, metric, grads by
    /// trainable name).  The differential harness compares these against
    /// the substrate's gradients (recovered from the AdamW first moment)
    /// and against central finite differences of [`RefExecutable::loss_f64`].
    pub fn loss_and_grads(
        &self,
        inputs: &[&xla::Literal],
    ) -> Result<(f64, f64, BTreeMap<String, Vec<f64>>)> {
        if self.spec.kind != "train" {
            bail!("{}: loss_and_grads needs a train artifact", self.spec.name);
        }
        let parsed = self.parse(inputs)?;
        let mut tape = RTape::new();
        let (logits, t_ids, input) = self.forward(&mut tape, &parsed)?;
        let (loss, metric, dlogits) = self.loss_head(&tape, logits, &parsed, &input)?;
        let grads = tape.backward(logits, dlogits);
        let mut out = BTreeMap::new();
        for (i, (name, p)) in parsed.trainable.iter().enumerate() {
            let g = match grads[t_ids[i]].as_ref() {
                Some(g) => g.clone(),
                None => vec![0.0; p.len()],
            };
            out.insert(name.clone(), g);
        }
        Ok((loss, metric, out))
    }
}

impl Executor for RefExecutable {
    fn execute(&self, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        RefExecutable::execute(self, inputs)
    }
    // prepare/parse_frozen/prepare_shared/execute_stateful use the trait
    // defaults: the oracle persists nothing, by design.
}
