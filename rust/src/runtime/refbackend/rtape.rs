//! Naive f64 reverse-mode tape — the reference oracle's numeric core.
//!
//! Deliberately the *opposite* of `runtime::interp::ad`: every op is a
//! textbook scalar loop in f64 — dense O(b²) circular convolution instead
//! of FFT, direct-indexed matmuls instead of blocked/threaded kernels, no
//! spectra caches, no thread pool, no zero-skipping fast paths.  Sharing
//! no hot-path code with the substrate is the point: a numerics bug has to
//! be made twice, independently, to survive the differential harness
//! (`rust/tests/differential.rs`).

/// Dense row-major f64 array.  Scalars have an empty shape.
#[derive(Clone, Debug)]
pub struct RArr {
    pub shape: Vec<usize>,
    pub data: Vec<f64>,
}

impl RArr {
    pub fn new(shape: Vec<usize>, data: Vec<f64>) -> RArr {
        assert_eq!(shape.iter().product::<usize>().max(1), data.len());
        RArr { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> RArr {
        let n = shape.iter().product::<usize>().max(1);
        RArr { shape, data: vec![0.0; n] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Product of all dims but the last (row count for last-dim ops).
    fn rows(&self) -> usize {
        let w = self.width();
        if w == 0 {
            0
        } else {
            self.data.len() / w
        }
    }

    /// Last dim.
    fn width(&self) -> usize {
        *self.shape.last().unwrap_or(&1)
    }
}

/// Node id on the reference tape.
pub type RV = usize;

#[derive(Clone, Copy, Debug)]
pub enum RAct {
    Gelu,
    Silu,
    Relu,
}

enum ROp {
    Leaf,
    Add(RV, RV),
    Mul(RV, RV),
    Scale(RV, f64),
    Matmul { a: RV, b: RV, trans_b: bool },
    Activation { x: RV, kind: RAct },
    SoftmaxLast(RV),
    LayerNorm { x: RV, g: RV, b: RV },
    RmsNorm { x: RV, g: RV },
    Gather { table: RV, ids: Vec<usize> },
    SliceFirst(RV),
    SplitHeads { x: RV, heads: usize },
    MergeHeads(RV),
    Transpose2(RV),
    SumAxis0(RV),
    Rsqrt { x: RV, eps: f64 },
    Reshape(RV),
    /// Block-circular convolution by the direct O(b²) definition.
    CircConv { x: RV, w: RV },
    BlockRotate { x: RV, r: RV },
}

struct RNode {
    val: RArr,
    op: ROp,
    needs: bool,
}

pub struct RTape {
    nodes: Vec<RNode>,
}

// ---------------------------------------------------------------------------
// Naive helpers
// ---------------------------------------------------------------------------

/// Numpy-style (align-right) broadcast shape of two shapes.
fn bshape(a: &[usize], b: &[usize]) -> Vec<usize> {
    let rank = a.len().max(b.len());
    let mut out = vec![0usize; rank];
    for i in 0..rank {
        let da = if i < rank - a.len() { 1 } else { a[i - (rank - a.len())] };
        let db = if i < rank - b.len() { 1 } else { b[i - (rank - b.len())] };
        assert!(da == db || da == 1 || db == 1, "broadcast mismatch {a:?} vs {b:?}");
        out[i] = da.max(db);
    }
    out
}

/// Source element index of `shape` for output linear index `o` of
/// `out_shape` (align-right; broadcast dims contribute 0).  Recomputed
/// per element by plain div/mod — slow and obviously correct.
fn src_idx(out_shape: &[usize], o: usize, shape: &[usize]) -> usize {
    let rank = out_shape.len();
    let off = rank - shape.len();
    let mut rem = o;
    let mut idx = 0usize;
    let mut stride = 1usize;
    for d in (0..rank).rev() {
        let c = rem % out_shape[d];
        rem /= out_shape[d];
        if d >= off {
            let sd = shape[d - off];
            if sd != 1 {
                idx += c * stride;
            }
            stride *= sd;
        }
    }
    idx
}

/// Visit every element of the broadcast result: f(out_idx, a_idx, b_idx).
fn bcast_each(
    out_shape: &[usize],
    a: &[usize],
    b: &[usize],
    mut f: impl FnMut(usize, usize, usize),
) {
    let n = out_shape.iter().product::<usize>().max(1);
    for o in 0..n {
        f(o, src_idx(out_shape, o, a), src_idx(out_shape, o, b));
    }
}

/// C[m,n] = A[m,k] · B_eff[k,n] where B_eff indexes `b` directly
/// (`trans_b`: b is stored [n,k]).  Triple scalar loop, no copies.
fn mm_naive(a: &[f64], b: &[f64], m: usize, k: usize, n: usize, trans_b: bool) -> Vec<f64> {
    let mut out = vec![0.0; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0;
            for p in 0..k {
                let bv = if trans_b { b[j * k + p] } else { b[p * n + j] };
                acc += a[i * k + p] * bv;
            }
            out[i * n + j] = acc;
        }
    }
    out
}

fn act_fwd(kind: RAct, x: f64) -> f64 {
    match kind {
        RAct::Relu => x.max(0.0),
        RAct::Silu => x / (1.0 + (-x).exp()),
        RAct::Gelu => {
            // tanh approximation (jax.nn.gelu default)
            let c = (2.0f64 / std::f64::consts::PI).sqrt();
            let u = c * (x + 0.044715 * x * x * x);
            0.5 * x * (1.0 + u.tanh())
        }
    }
}

fn act_bwd(kind: RAct, x: f64) -> f64 {
    match kind {
        RAct::Relu => {
            if x > 0.0 {
                1.0
            } else {
                0.0
            }
        }
        RAct::Silu => {
            let s = 1.0 / (1.0 + (-x).exp());
            s * (1.0 + x * (1.0 - s))
        }
        RAct::Gelu => {
            let c = (2.0f64 / std::f64::consts::PI).sqrt();
            let u = c * (x + 0.044715 * x * x * x);
            let t = u.tanh();
            let du = c * (1.0 + 3.0 * 0.044715 * x * x);
            0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * du
        }
    }
}

// ---------------------------------------------------------------------------
// Tape
// ---------------------------------------------------------------------------

impl Default for RTape {
    fn default() -> Self {
        Self::new()
    }
}

impl RTape {
    pub fn new() -> RTape {
        RTape { nodes: Vec::new() }
    }

    pub fn leaf(&mut self, arr: RArr, needs: bool) -> RV {
        self.nodes.push(RNode { val: arr, op: ROp::Leaf, needs });
        self.nodes.len() - 1
    }

    pub fn val(&self, v: RV) -> &RArr {
        &self.nodes[v].val
    }

    fn needs(&self, v: RV) -> bool {
        self.nodes[v].needs
    }

    fn push(&mut self, val: RArr, op: ROp, needs: bool) -> RV {
        self.nodes.push(RNode { val, op, needs });
        self.nodes.len() - 1
    }

    // -- binary broadcast ops ------------------------------------------------

    pub fn add(&mut self, a: RV, b: RV) -> RV {
        let out = {
            let (va, vb) = (self.val(a), self.val(b));
            let shape = bshape(&va.shape, &vb.shape);
            let mut out = RArr::zeros(shape.clone());
            bcast_each(&shape, &va.shape, &vb.shape, |o, ia, ib| {
                out.data[o] = va.data[ia] + vb.data[ib];
            });
            out
        };
        let needs = self.needs(a) || self.needs(b);
        self.push(out, ROp::Add(a, b), needs)
    }

    pub fn mul(&mut self, a: RV, b: RV) -> RV {
        let out = {
            let (va, vb) = (self.val(a), self.val(b));
            let shape = bshape(&va.shape, &vb.shape);
            let mut out = RArr::zeros(shape.clone());
            bcast_each(&shape, &va.shape, &vb.shape, |o, ia, ib| {
                out.data[o] = va.data[ia] * vb.data[ib];
            });
            out
        };
        let needs = self.needs(a) || self.needs(b);
        self.push(out, ROp::Mul(a, b), needs)
    }

    pub fn scale(&mut self, a: RV, c: f64) -> RV {
        let mut out = self.val(a).clone();
        for v in out.data.iter_mut() {
            *v *= c;
        }
        let needs = self.needs(a);
        self.push(out, ROp::Scale(a, c), needs)
    }

    /// a - b (broadcast).
    pub fn sub(&mut self, a: RV, b: RV) -> RV {
        let nb = self.scale(b, -1.0);
        self.add(a, nb)
    }

    // -- matmul --------------------------------------------------------------

    /// Batched matmul over the last two dims of `a` (same contract as the
    /// substrate tape: rank-2 rhs is a shared weight, higher-rank rhs is a
    /// per-batch matmul; `trans_b` means the rhs is stored transposed).
    pub fn matmul(&mut self, a: RV, b: RV, trans_b: bool) -> RV {
        let out = {
            let (va, vb) = (self.val(a), self.val(b));
            let ra = va.shape.len();
            assert!(ra >= 2, "matmul lhs rank {ra}");
            let k = va.shape[ra - 1];
            if vb.shape.len() == 2 {
                let (bk, bn) = if trans_b {
                    (vb.shape[1], vb.shape[0])
                } else {
                    (vb.shape[0], vb.shape[1])
                };
                assert_eq!(k, bk, "matmul inner dim {k} vs {bk}");
                let rows = va.data.len() / k;
                let data = mm_naive(&va.data, &vb.data, rows, k, bn, trans_b);
                let mut shape = va.shape.clone();
                *shape.last_mut().unwrap() = bn;
                RArr::new(shape, data)
            } else {
                assert_eq!(vb.shape.len(), ra, "batched matmul rank mismatch");
                assert_eq!(&vb.shape[..ra - 2], &va.shape[..ra - 2], "batch dims differ");
                let m = va.shape[ra - 2];
                let (bm, bn) = (vb.shape[ra - 2], vb.shape[ra - 1]);
                let (bk, n) = if trans_b { (bn, bm) } else { (bm, bn) };
                assert_eq!(k, bk, "batched matmul inner dim {k} vs {bk}");
                let batches: usize = va.shape[..ra - 2].iter().product();
                let mut data = vec![0.0; batches * m * n];
                for t in 0..batches {
                    let asl = &va.data[t * m * k..(t + 1) * m * k];
                    let bsl = &vb.data[t * bm * bn..(t + 1) * bm * bn];
                    let c = mm_naive(asl, bsl, m, k, n, trans_b);
                    data[t * m * n..(t + 1) * m * n].copy_from_slice(&c);
                }
                let mut shape = va.shape.clone();
                shape[ra - 1] = n;
                RArr::new(shape, data)
            }
        };
        let needs = self.needs(a) || self.needs(b);
        self.push(out, ROp::Matmul { a, b, trans_b }, needs)
    }

    // -- unary / fused ops ---------------------------------------------------

    pub fn activation(&mut self, x: RV, kind: RAct) -> RV {
        let vx = self.val(x);
        let data = vx.data.iter().map(|&v| act_fwd(kind, v)).collect();
        let out = RArr::new(vx.shape.clone(), data);
        let needs = self.needs(x);
        self.push(out, ROp::Activation { x, kind }, needs)
    }

    pub fn softmax_last(&mut self, x: RV) -> RV {
        let vx = self.val(x);
        let w = vx.width();
        let mut data = vx.data.clone();
        for row in data.chunks_mut(w) {
            let m = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let mut sum = 0.0;
            for v in row.iter_mut() {
                *v = (*v - m).exp();
                sum += *v;
            }
            for v in row.iter_mut() {
                *v /= sum;
            }
        }
        let out = RArr::new(vx.shape.clone(), data);
        let needs = self.needs(x);
        self.push(out, ROp::SoftmaxLast(x), needs)
    }

    pub fn layernorm(&mut self, x: RV, g: RV, b: RV) -> RV {
        let out = {
            let (vx, vg, vb) = (self.val(x), self.val(g), self.val(b));
            let d = vx.width();
            assert_eq!(vg.data.len(), d);
            assert_eq!(vb.data.len(), d);
            let mut data = vec![0.0; vx.data.len()];
            for (r, row) in vx.data.chunks(d).enumerate() {
                let mu = row.iter().sum::<f64>() / d as f64;
                let var = row.iter().map(|&v| (v - mu) * (v - mu)).sum::<f64>() / d as f64;
                let inv = 1.0 / (var + 1e-5).sqrt();
                for j in 0..d {
                    data[r * d + j] = (row[j] - mu) * inv * vg.data[j] + vb.data[j];
                }
            }
            RArr::new(vx.shape.clone(), data)
        };
        let needs = self.needs(x) || self.needs(g) || self.needs(b);
        self.push(out, ROp::LayerNorm { x, g, b }, needs)
    }

    pub fn rmsnorm(&mut self, x: RV, g: RV) -> RV {
        let out = {
            let (vx, vg) = (self.val(x), self.val(g));
            let d = vx.width();
            assert_eq!(vg.data.len(), d);
            let mut data = vec![0.0; vx.data.len()];
            for (r, row) in vx.data.chunks(d).enumerate() {
                let ms = row.iter().map(|&v| v * v).sum::<f64>() / d as f64;
                let inv = 1.0 / (ms + 1e-6).sqrt();
                for j in 0..d {
                    data[r * d + j] = row[j] * inv * vg.data[j];
                }
            }
            RArr::new(vx.shape.clone(), data)
        };
        let needs = self.needs(x) || self.needs(g);
        self.push(out, ROp::RmsNorm { x, g }, needs)
    }

    /// Row gather: out[r, :] = table[ids[r], :]; result [prefix.., cols].
    pub fn gather(&mut self, table: RV, ids: &[usize], prefix: &[usize]) -> RV {
        let out = {
            let vt = self.val(table);
            assert_eq!(vt.shape.len(), 2);
            assert_eq!(prefix.iter().product::<usize>().max(1), ids.len());
            let (rows_v, cols) = (vt.shape[0], vt.shape[1]);
            let mut data = vec![0.0; ids.len() * cols];
            for (r, &id) in ids.iter().enumerate() {
                assert!(id < rows_v, "gather id {id} out of range {rows_v}");
                for j in 0..cols {
                    data[r * cols + j] = vt.data[id * cols + j];
                }
            }
            let mut shape = prefix.to_vec();
            shape.push(cols);
            RArr::new(shape, data)
        };
        let needs = self.needs(table);
        self.push(out, ROp::Gather { table, ids: ids.to_vec() }, needs)
    }

    /// [B,S,D] -> [B,D] (token 0 pooling).
    pub fn slice_first(&mut self, x: RV) -> RV {
        let out = {
            let vx = self.val(x);
            assert_eq!(vx.shape.len(), 3);
            let (bsz, s, d) = (vx.shape[0], vx.shape[1], vx.shape[2]);
            let mut data = vec![0.0; bsz * d];
            for bi in 0..bsz {
                for j in 0..d {
                    data[bi * d + j] = vx.data[bi * s * d + j];
                }
            }
            RArr::new(vec![bsz, d], data)
        };
        let needs = self.needs(x);
        self.push(out, ROp::SliceFirst(x), needs)
    }

    /// [B,S,H*hd] -> [B,H,S,hd].
    pub fn split_heads(&mut self, x: RV, heads: usize) -> RV {
        let out = {
            let vx = self.val(x);
            assert_eq!(vx.shape.len(), 3);
            let (bsz, s, d) = (vx.shape[0], vx.shape[1], vx.shape[2]);
            assert_eq!(d % heads, 0);
            let hd = d / heads;
            let mut data = vec![0.0; vx.data.len()];
            for bi in 0..bsz {
                for si in 0..s {
                    for h in 0..heads {
                        for e in 0..hd {
                            data[((bi * heads + h) * s + si) * hd + e] =
                                vx.data[(bi * s + si) * d + h * hd + e];
                        }
                    }
                }
            }
            RArr::new(vec![bsz, heads, s, hd], data)
        };
        let needs = self.needs(x);
        self.push(out, ROp::SplitHeads { x, heads }, needs)
    }

    /// [B,H,S,hd] -> [B,S,H*hd].
    pub fn merge_heads(&mut self, x: RV) -> RV {
        let out = {
            let vx = self.val(x);
            assert_eq!(vx.shape.len(), 4);
            let (bsz, heads, s, hd) = (vx.shape[0], vx.shape[1], vx.shape[2], vx.shape[3]);
            let d = heads * hd;
            let mut data = vec![0.0; vx.data.len()];
            for bi in 0..bsz {
                for h in 0..heads {
                    for si in 0..s {
                        for e in 0..hd {
                            data[(bi * s + si) * d + h * hd + e] =
                                vx.data[((bi * heads + h) * s + si) * hd + e];
                        }
                    }
                }
            }
            RArr::new(vec![bsz, s, d], data)
        };
        let needs = self.needs(x);
        self.push(out, ROp::MergeHeads(x), needs)
    }

    /// Swap the last two dims (any leading batch).
    pub fn transpose2(&mut self, x: RV) -> RV {
        let out = {
            let vx = self.val(x);
            let rank = vx.shape.len();
            assert!(rank >= 2);
            let (r, c) = (vx.shape[rank - 2], vx.shape[rank - 1]);
            let batches: usize = vx.shape[..rank - 2].iter().product();
            let mut data = vec![0.0; vx.data.len()];
            for t in 0..batches {
                for i in 0..r {
                    for j in 0..c {
                        data[t * r * c + j * r + i] = vx.data[t * r * c + i * c + j];
                    }
                }
            }
            let mut shape = vx.shape.clone();
            shape.swap(rank - 2, rank - 1);
            RArr::new(shape, data)
        };
        let needs = self.needs(x);
        self.push(out, ROp::Transpose2(x), needs)
    }

    /// 2-D [r,c] -> [c] column sums.
    pub fn sum_axis0(&mut self, x: RV) -> RV {
        let out = {
            let vx = self.val(x);
            assert_eq!(vx.shape.len(), 2);
            let (r, c) = (vx.shape[0], vx.shape[1]);
            let mut data = vec![0.0; c];
            for i in 0..r {
                for j in 0..c {
                    data[j] += vx.data[i * c + j];
                }
            }
            RArr::new(vec![c], data)
        };
        let needs = self.needs(x);
        self.push(out, ROp::SumAxis0(x), needs)
    }

    /// 1/sqrt(x + eps), elementwise.
    pub fn rsqrt(&mut self, x: RV, eps: f64) -> RV {
        let vx = self.val(x);
        let data = vx.data.iter().map(|&v| 1.0 / (v + eps).sqrt()).collect();
        let out = RArr::new(vx.shape.clone(), data);
        let needs = self.needs(x);
        self.push(out, ROp::Rsqrt { x, eps }, needs)
    }

    pub fn reshape(&mut self, x: RV, shape: Vec<usize>) -> RV {
        let vx = self.val(x);
        assert_eq!(shape.iter().product::<usize>().max(1), vx.data.len());
        let out = RArr::new(shape, vx.data.clone());
        let needs = self.needs(x);
        self.push(out, ROp::Reshape(x), needs)
    }

    /// C3A block-circular conv by the direct definition (no FFT):
    /// y[.., i·b+k] = Σ_j Σ_t w[i,j,t] · x[.., j·b + (k−t mod b)]
    /// with x [..., n·b] and w [m,n,b] (same convention as the substrate's
    /// FFT operator and `substrate::circulant`).
    pub fn circ_conv(&mut self, x: RV, w: RV) -> RV {
        let out = {
            let (vx, vw) = (self.val(x), self.val(w));
            assert_eq!(vw.shape.len(), 3);
            let (m, n, b) = (vw.shape[0], vw.shape[1], vw.shape[2]);
            assert_eq!(vx.width(), n * b, "circ_conv input width");
            let rows = vx.rows();
            let mut data = vec![0.0; rows * m * b];
            for r in 0..rows {
                let xrow = &vx.data[r * n * b..(r + 1) * n * b];
                for i in 0..m {
                    for k in 0..b {
                        let mut acc = 0.0;
                        for j in 0..n {
                            let wij = &vw.data[(i * n + j) * b..(i * n + j + 1) * b];
                            for t in 0..b {
                                acc += wij[t] * xrow[j * b + (k + b - t) % b];
                            }
                        }
                        data[r * m * b + i * b + k] = acc;
                    }
                }
            }
            let mut shape = vx.shape.clone();
            *shape.last_mut().unwrap() = m * b;
            RArr::new(shape, data)
        };
        let needs = self.needs(x) || self.needs(w);
        self.push(out, ROp::CircConv { x, w }, needs)
    }

    /// BOFT rotation: out[.., nbi·bb+c] = Σ_bi x[.., nbi·bb+bi] · r[nbi,bi,c].
    pub fn block_rotate(&mut self, x: RV, r: RV) -> RV {
        let out = {
            let (vx, vr) = (self.val(x), self.val(r));
            assert_eq!(vr.shape.len(), 3);
            let (nb, bb, bb2) = (vr.shape[0], vr.shape[1], vr.shape[2]);
            assert_eq!(bb, bb2);
            assert_eq!(vx.width(), nb * bb, "block_rotate width");
            let rows = vx.rows();
            let mut data = vec![0.0; vx.data.len()];
            for row in 0..rows {
                for nbi in 0..nb {
                    for c in 0..bb {
                        let mut acc = 0.0;
                        for bi in 0..bb {
                            acc += vx.data[row * nb * bb + nbi * bb + bi]
                                * vr.data[(nbi * bb + bi) * bb + c];
                        }
                        data[row * nb * bb + nbi * bb + c] = acc;
                    }
                }
            }
            RArr::new(vx.shape.clone(), data)
        };
        let needs = self.needs(x) || self.needs(r);
        self.push(out, ROp::BlockRotate { x, r }, needs)
    }

    // -- backward ------------------------------------------------------------

    /// Reverse pass from `root` seeded with `seed`.  Returns per-node
    /// gradients (None where not needed / not reached).
    pub fn backward(&self, root: RV, seed: Vec<f64>) -> Vec<Option<Vec<f64>>> {
        assert_eq!(seed.len(), self.val(root).len());
        let mut grads: Vec<Option<Vec<f64>>> = vec![None; self.nodes.len()];
        grads[root] = Some(seed);
        for id in (0..self.nodes.len()).rev() {
            if grads[id].is_none() || !self.nodes[id].needs {
                continue;
            }
            let go = grads[id].take().unwrap();
            let contributions = self.op_backward(id, &go);
            grads[id] = Some(go);
            for (v, g) in contributions {
                if !self.nodes[v].needs {
                    continue;
                }
                match &mut grads[v] {
                    Some(acc) => {
                        for (a, b) in acc.iter_mut().zip(g.iter()) {
                            *a += b;
                        }
                    }
                    slot => *slot = Some(g),
                }
            }
        }
        grads
    }

    fn op_backward(&self, id: RV, go: &[f64]) -> Vec<(RV, Vec<f64>)> {
        let out_val = &self.nodes[id].val;
        match &self.nodes[id].op {
            ROp::Leaf => Vec::new(),
            ROp::Scale(a, c) => vec![(*a, go.iter().map(|&g| g * c).collect())],
            ROp::Add(a, b) => {
                let mut outs = Vec::new();
                for &v in &[*a, *b] {
                    if !self.nodes[v].needs {
                        continue;
                    }
                    let vs = &self.val(v).shape;
                    let mut g = vec![0.0; self.val(v).len()];
                    let n = out_val.len();
                    for o in 0..n {
                        g[src_idx(&out_val.shape, o, vs)] += go[o];
                    }
                    outs.push((v, g));
                }
                outs
            }
            ROp::Mul(a, b) => {
                let mut outs = Vec::new();
                for &(v, other) in &[(*a, *b), (*b, *a)] {
                    if !self.nodes[v].needs {
                        continue;
                    }
                    let vs = self.val(v).shape.clone();
                    let os = self.val(other).shape.clone();
                    let ov = &self.val(other).data;
                    let mut g = vec![0.0; self.val(v).len()];
                    bcast_each(&out_val.shape, &vs, &os, |o, iv, io| g[iv] += go[o] * ov[io]);
                    outs.push((v, g));
                }
                outs
            }
            ROp::Matmul { a, b, trans_b } => self.matmul_backward(*a, *b, *trans_b, go),
            ROp::Activation { x, kind } => {
                let vx = &self.val(*x).data;
                let g =
                    vx.iter().zip(go.iter()).map(|(&xv, &gv)| gv * act_bwd(*kind, xv)).collect();
                vec![(*x, g)]
            }
            ROp::SoftmaxLast(x) => {
                let y = &out_val.data;
                let w = out_val.width();
                let mut g = vec![0.0; y.len()];
                for r in 0..y.len() / w {
                    let yr = &y[r * w..(r + 1) * w];
                    let gr = &go[r * w..(r + 1) * w];
                    let dot: f64 = yr.iter().zip(gr.iter()).map(|(&a, &b)| a * b).sum();
                    for j in 0..w {
                        g[r * w + j] = yr[j] * (gr[j] - dot);
                    }
                }
                vec![(*x, g)]
            }
            ROp::LayerNorm { x, g, b } => self.layernorm_backward(*x, *g, *b, go),
            ROp::RmsNorm { x, g } => self.rmsnorm_backward(*x, *g, go),
            ROp::Gather { table, ids } => {
                let vt = self.val(*table);
                let cols = vt.shape[1];
                let mut g = vec![0.0; vt.len()];
                for (r, &idx) in ids.iter().enumerate() {
                    for j in 0..cols {
                        g[idx * cols + j] += go[r * cols + j];
                    }
                }
                vec![(*table, g)]
            }
            ROp::SliceFirst(x) => {
                let vx = self.val(*x);
                let (bsz, s, d) = (vx.shape[0], vx.shape[1], vx.shape[2]);
                let mut g = vec![0.0; vx.len()];
                for bi in 0..bsz {
                    for j in 0..d {
                        g[bi * s * d + j] = go[bi * d + j];
                    }
                }
                vec![(*x, g)]
            }
            ROp::SplitHeads { x, heads } => {
                let vx = self.val(*x);
                let (bsz, s, d) = (vx.shape[0], vx.shape[1], vx.shape[2]);
                let hd = d / heads;
                let mut g = vec![0.0; vx.len()];
                for bi in 0..bsz {
                    for si in 0..s {
                        for h in 0..*heads {
                            for e in 0..hd {
                                g[(bi * s + si) * d + h * hd + e] =
                                    go[((bi * heads + h) * s + si) * hd + e];
                            }
                        }
                    }
                }
                vec![(*x, g)]
            }
            ROp::MergeHeads(x) => {
                let vx = self.val(*x);
                let (bsz, heads, s, hd) = (vx.shape[0], vx.shape[1], vx.shape[2], vx.shape[3]);
                let d = heads * hd;
                let mut g = vec![0.0; vx.len()];
                for bi in 0..bsz {
                    for h in 0..heads {
                        for si in 0..s {
                            for e in 0..hd {
                                g[((bi * heads + h) * s + si) * hd + e] =
                                    go[(bi * s + si) * d + h * hd + e];
                            }
                        }
                    }
                }
                vec![(*x, g)]
            }
            ROp::Transpose2(x) => {
                let vx = self.val(*x);
                let rank = vx.shape.len();
                let (r, c) = (vx.shape[rank - 2], vx.shape[rank - 1]);
                let batches: usize = vx.shape[..rank - 2].iter().product();
                let mut g = vec![0.0; vx.len()];
                // out is [c,r] per batch; route each upstream element back
                for t in 0..batches {
                    for j in 0..c {
                        for i in 0..r {
                            g[t * r * c + i * c + j] = go[t * r * c + j * r + i];
                        }
                    }
                }
                vec![(*x, g)]
            }
            ROp::SumAxis0(x) => {
                let vx = self.val(*x);
                let (r, c) = (vx.shape[0], vx.shape[1]);
                let mut g = vec![0.0; r * c];
                for i in 0..r {
                    for j in 0..c {
                        g[i * c + j] = go[j];
                    }
                }
                vec![(*x, g)]
            }
            ROp::Rsqrt { x, eps: _ } => {
                let y = &out_val.data;
                let g =
                    y.iter().zip(go.iter()).map(|(&yv, &gv)| -0.5 * yv * yv * yv * gv).collect();
                vec![(*x, g)]
            }
            ROp::Reshape(x) => vec![(*x, go.to_vec())],
            ROp::CircConv { x, w } => self.circ_conv_backward(*x, *w, go),
            ROp::BlockRotate { x, r } => {
                let (vx, vr) = (self.val(*x), self.val(*r));
                let (nb, bb) = (vr.shape[0], vr.shape[1]);
                let rows = vx.rows();
                let mut outs = Vec::new();
                if self.nodes[*x].needs {
                    let mut gx = vec![0.0; vx.len()];
                    for row in 0..rows {
                        for nbi in 0..nb {
                            for bi in 0..bb {
                                let mut acc = 0.0;
                                for c in 0..bb {
                                    acc += go[row * nb * bb + nbi * bb + c]
                                        * vr.data[(nbi * bb + bi) * bb + c];
                                }
                                gx[row * nb * bb + nbi * bb + bi] = acc;
                            }
                        }
                    }
                    outs.push((*x, gx));
                }
                if self.nodes[*r].needs {
                    let mut gr = vec![0.0; vr.len()];
                    for row in 0..rows {
                        for nbi in 0..nb {
                            for bi in 0..bb {
                                for c in 0..bb {
                                    gr[(nbi * bb + bi) * bb + c] += vx.data
                                        [row * nb * bb + nbi * bb + bi]
                                        * go[row * nb * bb + nbi * bb + c];
                                }
                            }
                        }
                    }
                    outs.push((*r, gr));
                }
                outs
            }
        }
    }

    fn matmul_backward(&self, a: RV, b: RV, trans_b: bool, go: &[f64]) -> Vec<(RV, Vec<f64>)> {
        let (va, vb) = (self.val(a), self.val(b));
        let ra = va.shape.len();
        let k = va.shape[ra - 1];
        let mut outs = Vec::new();
        if vb.shape.len() == 2 {
            let (r0, c0) = (vb.shape[0], vb.shape[1]);
            let n = if trans_b { r0 } else { c0 };
            let rows = va.data.len() / k;
            if self.nodes[a].needs {
                // da[row,p] = Σ_j go[row,j] · B_eff[p,j]
                let mut da = vec![0.0; va.len()];
                for row in 0..rows {
                    for p in 0..k {
                        let mut acc = 0.0;
                        for j in 0..n {
                            let bv = if trans_b { vb.data[j * k + p] } else { vb.data[p * c0 + j] };
                            acc += go[row * n + j] * bv;
                        }
                        da[row * k + p] = acc;
                    }
                }
                outs.push((a, da));
            }
            if self.nodes[b].needs {
                // dB_eff[p,j] = Σ_row a[row,p] · go[row,j]
                let mut db = vec![0.0; vb.len()];
                for row in 0..rows {
                    for p in 0..k {
                        let av = va.data[row * k + p];
                        for j in 0..n {
                            let slot = if trans_b { j * k + p } else { p * c0 + j };
                            db[slot] += av * go[row * n + j];
                        }
                    }
                }
                outs.push((b, db));
            }
        } else {
            let m = va.shape[ra - 2];
            let (bm, bn) = (vb.shape[ra - 2], vb.shape[ra - 1]);
            let n = if trans_b { bm } else { bn };
            let batches: usize = va.shape[..ra - 2].iter().product();
            let mut da = vec![0.0; va.len()];
            let mut db = vec![0.0; vb.len()];
            for t in 0..batches {
                for row in 0..m {
                    for p in 0..k {
                        let mut acc = 0.0;
                        for j in 0..n {
                            let bv = if trans_b {
                                vb.data[t * bm * bn + j * bn + p]
                            } else {
                                vb.data[t * bm * bn + p * bn + j]
                            };
                            let gv = go[t * m * n + row * n + j];
                            acc += gv * bv;
                            let slot = if trans_b {
                                t * bm * bn + j * bn + p
                            } else {
                                t * bm * bn + p * bn + j
                            };
                            db[slot] += va.data[t * m * k + row * k + p] * gv;
                        }
                        da[t * m * k + row * k + p] = acc;
                    }
                }
            }
            if self.nodes[a].needs {
                outs.push((a, da));
            }
            if self.nodes[b].needs {
                outs.push((b, db));
            }
        }
        outs
    }

    fn layernorm_backward(&self, x: RV, g: RV, b: RV, go: &[f64]) -> Vec<(RV, Vec<f64>)> {
        let (vx, vg) = (self.val(x), self.val(g));
        let d = vx.width();
        let rows = vx.rows();
        let mut gx = vec![0.0; vx.len()];
        let mut gg = vec![0.0; d];
        let mut gb = vec![0.0; d];
        for r in 0..rows {
            let row = &vx.data[r * d..(r + 1) * d];
            let gor = &go[r * d..(r + 1) * d];
            let mu = row.iter().sum::<f64>() / d as f64;
            let var = row.iter().map(|&v| (v - mu) * (v - mu)).sum::<f64>() / d as f64;
            let inv = 1.0 / (var + 1e-5).sqrt();
            let mut mean_dyg = 0.0;
            let mut mean_dyg_xhat = 0.0;
            for j in 0..d {
                let xhat = (row[j] - mu) * inv;
                let dyg = gor[j] * vg.data[j];
                mean_dyg += dyg;
                mean_dyg_xhat += dyg * xhat;
                gg[j] += gor[j] * xhat;
                gb[j] += gor[j];
            }
            mean_dyg /= d as f64;
            mean_dyg_xhat /= d as f64;
            for j in 0..d {
                let xhat = (row[j] - mu) * inv;
                let dyg = gor[j] * vg.data[j];
                gx[r * d + j] = inv * (dyg - mean_dyg - xhat * mean_dyg_xhat);
            }
        }
        let mut outs = Vec::new();
        if self.nodes[x].needs {
            outs.push((x, gx));
        }
        if self.nodes[g].needs {
            outs.push((g, gg));
        }
        if self.nodes[b].needs {
            outs.push((b, gb));
        }
        outs
    }

    fn rmsnorm_backward(&self, x: RV, g: RV, go: &[f64]) -> Vec<(RV, Vec<f64>)> {
        let (vx, vg) = (self.val(x), self.val(g));
        let d = vx.width();
        let rows = vx.rows();
        let mut gx = vec![0.0; vx.len()];
        let mut gg = vec![0.0; d];
        for r in 0..rows {
            let row = &vx.data[r * d..(r + 1) * d];
            let gor = &go[r * d..(r + 1) * d];
            let ms = row.iter().map(|&v| v * v).sum::<f64>() / d as f64;
            let rms = (ms + 1e-6).sqrt();
            let inv = 1.0 / rms;
            let mut dot = 0.0;
            for j in 0..d {
                dot += row[j] * vg.data[j] * gor[j];
                gg[j] += gor[j] * row[j] * inv;
            }
            let c = dot / (d as f64 * rms * rms * rms);
            for j in 0..d {
                gx[r * d + j] = vg.data[j] * gor[j] * inv - row[j] * c;
            }
        }
        let mut outs = Vec::new();
        if self.nodes[x].needs {
            outs.push((x, gx));
        }
        if self.nodes[g].needs {
            outs.push((g, gg));
        }
        outs
    }

    /// Backward of the dense circular convolution, by the definition:
    /// dx[j,u] = Σ_i Σ_t w[i,j,t] · dy[i, (u+t) mod b]
    /// dw[i,j,t] = Σ_rows Σ_k dy[i,k] · x[j, (k−t) mod b]
    fn circ_conv_backward(&self, x: RV, w: RV, go: &[f64]) -> Vec<(RV, Vec<f64>)> {
        let (vx, vw) = (self.val(x), self.val(w));
        let (m, n, b) = (vw.shape[0], vw.shape[1], vw.shape[2]);
        let rows = vx.rows();
        let mut outs = Vec::new();
        if self.nodes[x].needs {
            let mut gx = vec![0.0; vx.len()];
            for r in 0..rows {
                for j in 0..n {
                    for u in 0..b {
                        let mut acc = 0.0;
                        for i in 0..m {
                            let wij = &vw.data[(i * n + j) * b..(i * n + j + 1) * b];
                            for t in 0..b {
                                acc += wij[t] * go[r * m * b + i * b + (u + t) % b];
                            }
                        }
                        gx[r * n * b + j * b + u] = acc;
                    }
                }
            }
            outs.push((x, gx));
        }
        if self.nodes[w].needs {
            let mut gw = vec![0.0; vw.len()];
            for r in 0..rows {
                let xrow = &vx.data[r * n * b..(r + 1) * n * b];
                for i in 0..m {
                    for j in 0..n {
                        for t in 0..b {
                            let mut acc = 0.0;
                            for k in 0..b {
                                acc += go[r * m * b + i * b + k] * xrow[j * b + (k + b - t) % b];
                            }
                            gw[(i * n + j) * b + t] += acc;
                        }
                    }
                }
            }
            outs.push((w, gw));
        }
        outs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tiny seeded generator (independent of `substrate::prng` on purpose).
    fn lcg(seed: u64) -> impl FnMut() -> f64 {
        let mut s = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
        move || {
            s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((s >> 11) as f64 / (1u64 << 53) as f64) - 0.5
        }
    }

    fn rand_arr(next: &mut impl FnMut() -> f64, shape: &[usize]) -> RArr {
        let n = shape.iter().product::<usize>().max(1);
        RArr::new(shape.to_vec(), (0..n).map(|_| next()).collect())
    }

    /// Central-difference gradient check of a tape-built graph.
    fn gradcheck(shapes: &[&[usize]], build: impl Fn(&mut RTape, &[RV]) -> RV) {
        let mut next = lcg(0xADC3A);
        let inputs: Vec<RArr> = shapes.iter().map(|s| rand_arr(&mut next, s)).collect();
        let mut tape = RTape::new();
        let ids: Vec<RV> = inputs.iter().map(|a| tape.leaf(a.clone(), true)).collect();
        let out = build(&mut tape, &ids);
        let wvec: Vec<f64> = (0..tape.val(out).len()).map(|_| next()).collect();
        let grads = tape.backward(out, wvec.clone());
        let loss = |vals: &[RArr]| -> f64 {
            let mut t = RTape::new();
            let ids: Vec<RV> = vals.iter().map(|a| t.leaf(a.clone(), false)).collect();
            let o = build(&mut t, &ids);
            t.val(o).data.iter().zip(wvec.iter()).map(|(&a, &b)| a * b).sum()
        };
        let eps = 1e-5;
        for (vi, id) in ids.iter().enumerate() {
            let g = grads[*id].as_ref().expect("input grad");
            for ei in 0..inputs[vi].len() {
                let mut plus = inputs.clone();
                plus[vi].data[ei] += eps;
                let mut minus = inputs.clone();
                minus[vi].data[ei] -= eps;
                let num = (loss(&plus) - loss(&minus)) / (2.0 * eps);
                let an = g[ei];
                let scale = 1.0f64.max(num.abs()).max(an.abs());
                assert!(
                    (num - an).abs() / scale < 1e-6,
                    "input {vi} elem {ei}: numeric {num} vs analytic {an}"
                );
            }
        }
    }

    #[test]
    fn rgrad_core_ops() {
        gradcheck(&[&[2, 3, 4], &[4]], |t, v| t.add(v[0], v[1]));
        gradcheck(&[&[2, 3, 4], &[1, 1, 4]], |t, v| t.mul(v[0], v[1]));
        gradcheck(&[&[2, 3, 4], &[4, 5]], |t, v| t.matmul(v[0], v[1], false));
        gradcheck(&[&[2, 3, 4], &[5, 4]], |t, v| t.matmul(v[0], v[1], true));
        gradcheck(&[&[2, 3, 4], &[2, 4, 5]], |t, v| t.matmul(v[0], v[1], false));
        gradcheck(&[&[2, 3, 4], &[2, 5, 4]], |t, v| t.matmul(v[0], v[1], true));
    }

    #[test]
    fn rgrad_fused_ops() {
        gradcheck(&[&[3, 6]], |t, v| t.softmax_last(v[0]));
        gradcheck(&[&[3, 6], &[6], &[6]], |t, v| t.layernorm(v[0], v[1], v[2]));
        gradcheck(&[&[3, 6], &[6]], |t, v| t.rmsnorm(v[0], v[1]));
        for kind in [RAct::Gelu, RAct::Silu] {
            gradcheck(&[&[3, 5]], |t, v| t.activation(v[0], kind));
        }
    }

    #[test]
    fn rgrad_structural_and_conv_ops() {
        gradcheck(&[&[2, 3, 4]], |t, v| t.slice_first(v[0]));
        gradcheck(&[&[2, 3, 4]], |t, v| {
            let h = t.split_heads(v[0], 2);
            t.merge_heads(h)
        });
        gradcheck(&[&[2, 3, 4]], |t, v| t.transpose2(v[0]));
        gradcheck(&[&[3, 4]], |t, v| t.sum_axis0(v[0]));
        gradcheck(&[&[3, 8], &[2, 2, 4]], |t, v| t.circ_conv(v[0], v[1]));
        gradcheck(&[&[2, 2, 6], &[3, 2, 3]], |t, v| t.circ_conv(v[0], v[1]));
        gradcheck(&[&[3, 8], &[2, 4, 4]], |t, v| t.block_rotate(v[0], v[1]));
    }

    /// The dense conv must agree with the substrate's FFT circulant.
    #[test]
    fn circ_conv_matches_fft_circulant() {
        use crate::substrate::circulant::BlockCirculant;
        let mut next = lcg(77);
        let (m, n, b) = (2usize, 3usize, 8usize);
        let w = rand_arr(&mut next, &[m, n, b]);
        let x = rand_arr(&mut next, &[1, n * b]);
        let mut tape = RTape::new();
        let xv = tape.leaf(x.clone(), false);
        let wv = tape.leaf(w.clone(), false);
        let out = tape.circ_conv(xv, wv);
        let bc = BlockCirculant::new(m, n, b, w.data.clone());
        let want = bc.matvec(&x.data);
        for (got, want) in tape.val(out).data.iter().zip(want.iter()) {
            assert!((got - want).abs() < 1e-9, "{got} vs {want}");
        }
    }
}
