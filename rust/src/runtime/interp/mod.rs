//! The substrate interpreter — executes train/eval artifacts on CPU by
//! routing through the tape autodiff + model zoo instead of compiled HLO.
//!
//! An [`InterpExecutable`] is a pure function of its positional inputs and
//! honors the exact PJRT flattening contract the sessions use:
//!
//! * train: inputs `[trainable..., opt_m..., opt_v..., frozen..., data...,
//!   scalars...]` -> outputs `[new_trainable..., new_m..., new_v..., loss,
//!   metric]`
//! * eval: inputs `[trainable..., frozen..., data...]` -> `[logits]`
//!
//! The AdamW update mirrors python/compile/model.py `adamw_update`
//! (decoupled decay, `.b/.g/.mag/.lb/.ld` exempt).

pub mod ad;
pub mod model;

use self::ad::{Arr, C3aSpectra, Tape, V};
use self::model::{Graph, ModelInput};
use crate::runtime::backend::ExecutorState;
use crate::runtime::manifest::{ArtifactSpec, ModelMeta, Role};
use crate::substrate::fft::Plan;
use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap};
use std::rc::Rc;

const BETA1: f32 = 0.9;
const BETA2: f32 = 0.999;
const EPS: f32 = 1e-8;

/// Cache hit/miss counters (observability for tests and the bench).
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheStats {
    pub spectra_hits: u64,
    pub spectra_misses: u64,
}

struct SpectraEntry {
    /// bit pattern of the kernel the spectra were computed from (validity
    /// check — training updates the kernel every step, serving keeps it
    /// fixed).  Stored as bits so the comparison is truly bitwise:
    /// f32 `==` would treat -0.0 as a stale hit and NaN as a forced miss.
    kernel_bits: Vec<u32>,
    spectra: Rc<C3aSpectra>,
}

/// Interior caches the interpreter keeps warm across calls: FFT plans per
/// block size and C3A kernel spectra per parameter name.  Spectra entries
/// are invalidated by exact kernel comparison, so a stale entry can cost
/// a recompute but never wrong numerics.
#[derive(Default)]
pub struct InterpCache {
    plans: HashMap<usize, Rc<Plan>>,
    spectra: HashMap<String, SpectraEntry>,
    stats: CacheStats,
}

impl InterpCache {
    pub fn plan(&mut self, b: usize) -> Rc<Plan> {
        self.plans.entry(b).or_insert_with(|| Rc::new(Plan::new(b))).clone()
    }

    /// Spectra of kernel `name` with current value `w`, reusing the cached
    /// transform when the kernel is bit-identical to the last call.
    pub fn spectra_for(&mut self, name: &str, w: &Arr) -> Rc<C3aSpectra> {
        if let Some(e) = self.spectra.get(name) {
            let same = e.kernel_bits.len() == w.data.len()
                && e.kernel_bits.iter().zip(&w.data).all(|(&bits, v)| bits == v.to_bits());
            if same {
                self.stats.spectra_hits += 1;
                return e.spectra.clone();
            }
        }
        self.stats.spectra_misses += 1;
        let plan = self.plan(w.shape[2]);
        let spectra = Rc::new(C3aSpectra::compute(plan, w));
        self.spectra.insert(
            name.to_string(),
            SpectraEntry {
                kernel_bits: w.data.iter().map(|v| v.to_bits()).collect(),
                spectra: spectra.clone(),
            },
        );
        spectra
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    /// Number of distinct kernels currently held in the spectra cache.
    pub fn spectra_entries(&self) -> usize {
        self.spectra.len()
    }
}

/// One parse of a frozen-backbone literal set: (name, value) pairs in
/// `frozen_order`.  Shareable across sessions — the multi-adapter serving
/// substrate parses the backbone once and hands every tenant state a clone
/// of this `Rc`.
pub type FrozenParse = Rc<Vec<(String, Rc<Arr>)>>;

/// Per-session interpreter state ([`crate::runtime::backend::ExecutorState`]
/// impl): frozen parameters parsed **once** at session build instead of per
/// step (and shared across sessions when built from a [`FrozenParse`]),
/// plus a private cache (plans + spectra) not shared with other sessions.
pub struct InterpState {
    /// (name, parsed value) in `frozen_order`
    frozen: FrozenParse,
    cache: RefCell<InterpCache>,
}

impl InterpState {
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.borrow().stats()
    }

    /// Distinct kernels in this state's private spectra cache.
    pub fn spectra_entries(&self) -> usize {
        self.cache.borrow().spectra_entries()
    }

    /// States (plus the originating handle, if any) sharing this state's
    /// frozen parse.
    pub fn frozen_parse_refs(&self) -> usize {
        Rc::strong_count(&self.frozen)
    }
}

impl ExecutorState for InterpState {
    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }
}

/// A loaded artifact on the substrate backend.
pub struct InterpExecutable {
    spec: ArtifactSpec,
    meta: ModelMeta,
    /// fallback cache for stateless `execute` calls (plans + spectra);
    /// shared across sessions of this executable, equality-verified
    cache: RefCell<InterpCache>,
}

struct ParsedInputs {
    /// (name, value) in trainable_order
    trainable: Vec<(String, Rc<Arr>)>,
    opt_m: Vec<Arr>,
    opt_v: Vec<Arr>,
    /// (name, value) for frozen + frozen_random
    frozen: Vec<(String, Rc<Arr>)>,
    data_f32: BTreeMap<String, Arr>,
    data_i32: BTreeMap<String, Vec<i32>>,
    scalars: BTreeMap<String, f32>,
}

impl InterpExecutable {
    pub fn new(spec: &ArtifactSpec, meta: &ModelMeta) -> Result<InterpExecutable> {
        match meta.kind.as_str() {
            "encoder" | "decoder" | "mlp" => {}
            other => bail!("{}: unsupported model kind {other}", spec.name),
        }
        match spec.peft.method.as_str() {
            "full" | "head" | "bitfit" | "ia3" | "lora" | "dora" | "vera" | "boft" | "c3a" => {}
            other => bail!("{}: unsupported PEFT method {other}", spec.name),
        }
        Ok(InterpExecutable {
            spec: spec.clone(),
            meta: meta.clone(),
            cache: RefCell::new(InterpCache::default()),
        })
    }

    /// Stateless execution: every input (including the frozen backbone) is
    /// parsed from the literals each call.  Plans/spectra still come from
    /// the executable-local cache (equality-verified).
    pub fn execute(&self, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let parsed = self.parse_inputs(inputs, None)?;
        self.run_parsed(parsed, &self.cache)
    }

    /// Parse a frozen literal set (in `frozen_order`) into a shareable
    /// handle.  One parse can back any number of session states (see
    /// [`InterpExecutable::prepare_from`]) — the multi-adapter serving
    /// pattern: one frozen backbone, one state per tenant.
    pub fn parse_frozen(&self, frozen: &[xla::Literal]) -> Result<FrozenParse> {
        if frozen.len() != self.spec.frozen_order.len() {
            bail!(
                "{}: parse_frozen got {} frozen literals, manifest declares {}",
                self.spec.name,
                frozen.len(),
                self.spec.frozen_order.len()
            );
        }
        let mut parsed = Vec::with_capacity(frozen.len());
        for (name, lit) in self.spec.frozen_order.iter().zip(frozen.iter()) {
            let inp = self
                .spec
                .inputs
                .iter()
                .find(|i| &i.name == name)
                .with_context(|| format!("{}: unknown frozen input {name}", self.spec.name))?;
            parsed.push((name.clone(), Rc::new(lit_to_arr(lit, &inp.shape)?)));
        }
        Ok(Rc::new(parsed))
    }

    /// Build per-session state: parse the frozen parameters once (they are
    /// constant for the life of a session) and give the session a private
    /// plan/spectra cache.
    pub fn prepare(&self, frozen: &[xla::Literal]) -> Result<InterpState> {
        Ok(InterpState {
            frozen: self.parse_frozen(frozen)?,
            cache: RefCell::new(InterpCache::default()),
        })
    }

    /// Build per-session state over an *existing* shared parse.  The caches
    /// stay private per state; only the parsed frozen arrays are shared.
    pub fn prepare_from(&self, parse: FrozenParse) -> Result<InterpState> {
        if parse.len() != self.spec.frozen_order.len() {
            bail!(
                "{}: shared parse has {} entries, manifest declares {}",
                self.spec.name,
                parse.len(),
                self.spec.frozen_order.len()
            );
        }
        for ((name, arr), want) in parse.iter().zip(self.spec.frozen_order.iter()) {
            if name != want {
                bail!("{}: shared parse names {name}, manifest declares {want}", self.spec.name);
            }
            let inp = self
                .spec
                .inputs
                .iter()
                .find(|i| &i.name == name)
                .with_context(|| format!("{}: unknown frozen input {name}", self.spec.name))?;
            if arr.shape != inp.shape {
                bail!("{name}: shared parse shape {:?} != manifest {:?}", arr.shape, inp.shape);
            }
        }
        Ok(InterpState { frozen: parse, cache: RefCell::new(InterpCache::default()) })
    }

    /// Stateful execution: frozen inputs are taken from `state` (the
    /// positional literals for them are arity-checked but not re-read).
    pub fn execute_stateful(
        &self,
        state: &mut InterpState,
        inputs: &[&xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let parsed = self.parse_inputs(inputs, Some(state))?;
        self.run_parsed(parsed, &state.cache)
    }

    fn run_parsed(
        &self,
        parsed: ParsedInputs,
        cache: &RefCell<InterpCache>,
    ) -> Result<Vec<xla::Literal>> {
        if self.spec.kind == "train" {
            self.train_step(parsed, cache)
        } else {
            self.eval_step(parsed, cache)
        }
    }

    fn parse_inputs(
        &self,
        inputs: &[&xla::Literal],
        state: Option<&InterpState>,
    ) -> Result<ParsedInputs> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: got {} inputs, manifest declares {}",
                self.spec.name,
                inputs.len(),
                self.spec.inputs.len()
            );
        }
        let mut p = ParsedInputs {
            trainable: Vec::new(),
            opt_m: Vec::new(),
            opt_v: Vec::new(),
            frozen: Vec::new(),
            data_f32: BTreeMap::new(),
            data_i32: BTreeMap::new(),
            scalars: BTreeMap::new(),
        };
        if let Some(s) = state {
            // session-cached parses, uploaded once in `prepare` (the Rc
            // clones are O(1); the name Strings are the only copies)
            p.frozen = s.frozen.as_ref().clone();
        }
        for (inp, lit) in self.spec.inputs.iter().zip(inputs.iter()) {
            match inp.role {
                Role::Trainable => {
                    p.trainable.push((inp.name.clone(), Rc::new(lit_to_arr(lit, &inp.shape)?)))
                }
                Role::OptM => p.opt_m.push(lit_to_arr(lit, &inp.shape)?),
                Role::OptV => p.opt_v.push(lit_to_arr(lit, &inp.shape)?),
                Role::Frozen | Role::FrozenRandom => {
                    if state.is_none() {
                        p.frozen.push((inp.name.clone(), Rc::new(lit_to_arr(lit, &inp.shape)?)));
                    }
                }
                Role::Data => {
                    if inp.i32_dtype {
                        p.data_i32.insert(inp.name.clone(), lit.to_vec::<i32>()?);
                    } else {
                        p.data_f32.insert(inp.name.clone(), lit_to_arr(lit, &inp.shape)?);
                    }
                }
                Role::Scalar => {
                    p.scalars.insert(inp.name.clone(), lit.get_first_element::<f32>()?);
                }
            }
        }
        Ok(p)
    }

    /// Build tape leaves + the shared model input, run the forward pass.
    /// Leaves are shared (`Rc`) with the parsed/cached arrays — no copies.
    fn forward<'t>(
        &self,
        tape: &'t mut Tape,
        parsed: &ParsedInputs,
        cache: &RefCell<InterpCache>,
    ) -> Result<(V, Vec<V>, ModelInput)> {
        let mut params: BTreeMap<String, V> = BTreeMap::new();
        let mut t_ids = Vec::with_capacity(parsed.trainable.len());
        for (name, arr) in &parsed.trainable {
            let id = tape.leaf_shared(arr.clone(), true);
            t_ids.push(id);
            params.insert(name.clone(), id);
        }
        for (name, arr) in &parsed.frozen {
            let id = tape.leaf_shared(arr.clone(), false);
            params.insert(name.clone(), id);
        }
        let (b, s) = (self.spec.batch, self.spec.seq);
        let input = ModelInput {
            tokens: parsed.data_i32.get("data.tokens").cloned(),
            x: parsed.data_f32.get("data.x").cloned(),
            b,
            s,
        };
        let mut graph = Graph {
            tape,
            params: &params,
            meta: &self.meta,
            peft: &self.spec.peft,
            cache: Some(cache),
        };
        let fwd = graph.forward(&self.spec.head, &input)?;
        Ok((fwd.logits, t_ids, input))
    }

    fn eval_step(
        &self,
        parsed: ParsedInputs,
        cache: &RefCell<InterpCache>,
    ) -> Result<Vec<xla::Literal>> {
        let mut tape = Tape::new();
        let (logits, _t_ids, _input) = self.forward(&mut tape, &parsed, cache)?;
        let out = tape.val(logits);
        Ok(vec![xla::Literal::from_f32(&out.shape, out.data.clone())])
    }

    fn train_step(
        &self,
        parsed: ParsedInputs,
        cache: &RefCell<InterpCache>,
    ) -> Result<Vec<xla::Literal>> {
        let mut tape = Tape::new();
        let (logits, t_ids, input) = self.forward(&mut tape, &parsed, cache)?;
        let (loss, metric, dlogits) = self.loss_head(&tape, logits, &parsed, &input)?;
        let grads = tape.backward(logits, dlogits);

        let step = *parsed.scalars.get("step").context("missing scalar step")?;
        let lr = *parsed.scalars.get("lr").context("missing scalar lr")?;
        let wd = parsed.scalars.get("wd").copied().unwrap_or(0.0);
        let bc1 = 1.0 - (BETA1 as f64).powf(step as f64);
        let bc2 = 1.0 - (BETA2 as f64).powf(step as f64);

        let nt = parsed.trainable.len();
        let mut new_t = Vec::with_capacity(nt);
        let mut new_m = Vec::with_capacity(nt);
        let mut new_v = Vec::with_capacity(nt);
        for (i, (name, p)) in parsed.trainable.iter().enumerate() {
            let zero;
            let g: &Vec<f32> = match grads[t_ids[i]].as_ref() {
                Some(g) => g,
                None => {
                    zero = vec![0f32; p.len()];
                    &zero
                }
            };
            let exempt = name.ends_with(".b")
                || name.ends_with(".g")
                || name.ends_with(".mag")
                || name.ends_with(".lb")
                || name.ends_with(".ld");
            let decay = if exempt { 0.0 } else { wd };
            let m0 = &parsed.opt_m[i];
            let v0 = &parsed.opt_v[i];
            let mut pn = vec![0f32; p.len()];
            let mut mn = vec![0f32; p.len()];
            let mut vn = vec![0f32; p.len()];
            for e in 0..p.len() {
                let gv = g[e];
                let nm = BETA1 * m0.data[e] + (1.0 - BETA1) * gv;
                let nv = BETA2 * v0.data[e] + (1.0 - BETA2) * gv * gv;
                let upd = (nm / bc1 as f32) / ((nv / bc2 as f32).sqrt() + EPS);
                pn[e] = p.data[e] - lr * (upd + decay * p.data[e]);
                mn[e] = nm;
                vn[e] = nv;
            }
            new_t.push(xla::Literal::from_f32(&p.shape, pn));
            new_m.push(xla::Literal::from_f32(&p.shape, mn));
            new_v.push(xla::Literal::from_f32(&p.shape, vn));
        }
        let mut outs = new_t;
        outs.extend(new_m);
        outs.extend(new_v);
        outs.push(xla::Literal::scalar(loss));
        outs.push(xla::Literal::scalar(metric));
        Ok(outs)
    }

    /// Compute (loss, metric, dL/dlogits) on the host, mirroring
    /// python task_loss.
    fn loss_head(
        &self,
        tape: &Tape,
        logits: V,
        parsed: &ParsedInputs,
        input: &ModelInput,
    ) -> Result<(f32, f32, Vec<f32>)> {
        let lv = tape.val(logits);
        let head = self.spec.head.as_str();
        let kind = self.meta.kind.as_str();
        let (b, s) = (input.b, input.s);

        if kind == "decoder" || head == "mlm" {
            // masked token-level cross-entropy over [b,s,V]
            let mask =
                parsed.data_f32.get("data.loss_mask").context("missing data.loss_mask")?;
            let targets: Vec<i32> = if head == "mlm" {
                parsed.data_i32.get("data.targets").context("missing data.targets")?.clone()
            } else {
                // next-token targets: shift left, pad last column with 0
                let toks = input.tokens.as_ref().context("missing data.tokens")?;
                let mut t = vec![0i32; b * s];
                for bi in 0..b {
                    for si in 0..s.saturating_sub(1) {
                        t[bi * s + si] = toks[bi * s + si + 1];
                    }
                }
                t
            };
            let vcb = *lv.shape.last().unwrap();
            let denom = mask.data.iter().sum::<f32>().max(1.0);
            let mut loss = 0f64;
            let mut correct = 0f64;
            let mut dl = vec![0f32; lv.len()];
            for pos in 0..b * s {
                let m = mask.data[pos];
                // masked (padding) positions are skipped *before* target
                // validation: garbage targets under mask 0 are legal and
                // must not abort training.
                if m == 0.0 {
                    continue;
                }
                let row = &lv.data[pos * vcb..(pos + 1) * vcb];
                let tgt = targets[pos].max(0) as usize;
                if tgt >= vcb {
                    bail!("target {tgt} out of vocab {vcb}");
                }
                let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let sum: f32 = row.iter().map(|&v| (v - mx).exp()).sum();
                let lse = mx + sum.ln();
                loss += (m * (lse - row[tgt])) as f64;
                let amax = crate::substrate::linalg::argmax(row);
                if amax == tgt {
                    correct += m as f64;
                }
                for j in 0..vcb {
                    let p = (row[j] - lse).exp();
                    let onehot = if j == tgt { 1.0 } else { 0.0 };
                    dl[pos * vcb + j] = m * (p - onehot) / denom;
                }
            }
            return Ok(((loss / denom as f64) as f32, correct as f32, dl));
        }

        if head == "reg" {
            let y = parsed.data_f32.get("data.y").context("missing data.y")?;
            let w = lv.shape[1];
            let mut loss = 0f64;
            let mut pred_sum = 0f64;
            let mut dl = vec![0f32; lv.len()];
            for r in 0..b {
                let pred = lv.data[r * w];
                let diff = pred - y.data[r];
                loss += (diff * diff) as f64;
                pred_sum += pred as f64;
                dl[r * w] = 2.0 * diff / b as f32;
            }
            return Ok(((loss / b as f64) as f32, pred_sum as f32, dl));
        }

        // classification (cls / vec / mlp): mean CE over [b, n_out]
        let y = parsed.data_i32.get("data.y").context("missing data.y")?;
        let w = lv.shape[1];
        let mut loss = 0f64;
        let mut correct = 0f64;
        let mut dl = vec![0f32; lv.len()];
        for r in 0..b {
            let row = &lv.data[r * w..(r + 1) * w];
            let tgt = y[r].max(0) as usize;
            if tgt >= w {
                bail!("label {tgt} out of range {w}");
            }
            let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let sum: f32 = row.iter().map(|&v| (v - mx).exp()).sum();
            let lse = mx + sum.ln();
            loss += (lse - row[tgt]) as f64;
            if crate::substrate::linalg::argmax(row) == tgt {
                correct += 1.0;
            }
            for j in 0..w {
                let p = (row[j] - lse).exp();
                let onehot = if j == tgt { 1.0 } else { 0.0 };
                dl[r * w + j] = (p - onehot) / b as f32;
            }
        }
        Ok(((loss / b as f64) as f32, correct as f32, dl))
    }
}

fn lit_to_arr(lit: &xla::Literal, shape: &[usize]) -> Result<Arr> {
    let data = lit.to_vec::<f32>()?;
    if data.len() != shape.iter().product::<usize>().max(1) {
        bail!("literal has {} elements, manifest shape {shape:?}", data.len());
    }
    Ok(Arr::new(shape.to_vec(), data))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::catalog;

    /// Drive one interpreted train step directly (no session machinery):
    /// asserts the positional output contract and that loss is finite.
    #[test]
    fn train_step_contract_c3a() {
        let dir = std::env::temp_dir().join("c3a_interp_test");
        let manifest = catalog::synthesize(&dir).unwrap();
        let spec = manifest.artifact("enc_tiny__c3a_d8__cls__train").unwrap().clone();
        let meta = manifest.model("enc_tiny").unwrap().clone();
        let exe = InterpExecutable::new(&spec, &meta).unwrap();

        let lits = catalog::synth_inputs(&spec, &meta);
        let refs: Vec<&xla::Literal> = lits.iter().collect();
        let outs = exe.execute(&refs).unwrap();
        let nt = spec.trainable_order.len();
        assert_eq!(outs.len(), 3 * nt + 2);
        let loss = outs[3 * nt].get_first_element::<f32>().unwrap();
        assert!(loss.is_finite() && loss > 0.0, "loss {loss}");
        // trainable c3a kernels must have moved (nonzero grads at init)
        let before_idx =
            spec.trainable_order.iter().position(|n| n.contains(".c3a.w")).unwrap();
        let before = &lits[before_idx];
        let after = &outs[before_idx];
        let b = before.to_vec::<f32>().unwrap();
        let a = after.to_vec::<f32>().unwrap();
        assert!(b.iter().zip(a.iter()).any(|(x, y)| x != y), "c3a kernel did not update");
    }

    /// Regression: an out-of-vocab target at a *masked* position must be
    /// skipped, not abort training (padding rows carry garbage targets).
    /// The same garbage at an unmasked position must still fail loudly.
    #[test]
    fn masked_garbage_targets_are_skipped() {
        let dir = std::env::temp_dir().join("c3a_interp_test_mlm");
        let manifest = catalog::synthesize(&dir).unwrap();
        let spec = manifest.artifact("enc_tiny__full__mlm__train").unwrap().clone();
        let meta = manifest.model("enc_tiny").unwrap().clone();
        let exe = InterpExecutable::new(&spec, &meta).unwrap();

        let mut lits = catalog::synth_inputs(&spec, &meta);
        let (b, s) = (spec.batch, spec.seq);
        let tgt_idx = spec.inputs.iter().position(|i| i.name == "data.targets").unwrap();
        let mask_idx = spec.inputs.iter().position(|i| i.name == "data.loss_mask").unwrap();
        // mask: even positions supervised, odd positions padding
        let mask: Vec<f32> = (0..b * s).map(|p| if p % 2 == 0 { 1.0 } else { 0.0 }).collect();
        // targets: valid ids where supervised, garbage where masked out
        let targets: Vec<i32> =
            (0..b * s).map(|p| if p % 2 == 0 { (p % 4) as i32 + 4 } else { 9_999_999 }).collect();
        lits[mask_idx] = xla::Literal::from_f32(&[b, s], mask.clone());
        lits[tgt_idx] = xla::Literal::from_i32(&[b, s], targets.clone());
        let refs: Vec<&xla::Literal> = lits.iter().collect();
        let outs = exe.execute(&refs).expect("masked garbage targets must not abort");
        let nt = spec.trainable_order.len();
        let loss = outs[3 * nt].get_first_element::<f32>().unwrap();
        assert!(loss.is_finite() && loss > 0.0, "loss {loss}");

        // garbage at a *supervised* position is real corruption: fail
        let mut bad = targets;
        bad[0] = 9_999_999;
        lits[tgt_idx] = xla::Literal::from_i32(&[b, s], bad);
        let refs: Vec<&xla::Literal> = lits.iter().collect();
        assert!(exe.execute(&refs).is_err(), "unmasked garbage target must error");
    }
}
