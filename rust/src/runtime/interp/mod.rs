//! The substrate interpreter — executes train/eval artifacts on CPU by
//! routing through the tape autodiff + model zoo instead of compiled HLO.
//!
//! An [`InterpExecutable`] is a pure function of its positional inputs and
//! honors the exact PJRT flattening contract the sessions use:
//!
//! * train: inputs `[trainable..., opt_m..., opt_v..., frozen..., data...,
//!   scalars...]` -> outputs `[new_trainable..., new_m..., new_v..., loss,
//!   metric]`
//! * eval: inputs `[trainable..., frozen..., data...]` -> `[logits]`
//!
//! The AdamW update mirrors python/compile/model.py `adamw_update`
//! (decoupled decay, `.b/.g/.mag/.lb/.ld` exempt).
//!
//! Execution has two regimes.  A *stateless* call (or the first stateful
//! call) records the step eagerly through the tape; stateful sessions
//! then promote that tape into a [`Plan`](crate::runtime::plan::Plan) and
//! every subsequent call *replays* it — leaves refilled in place, ops
//! recomputed into preallocated arena buffers, bit-for-bit identical to
//! the rebuild path.  `C3A_PLAN=0` disables the replay regime.

pub mod ad;
pub mod model;

use self::ad::{Arr, C3aSpectra, Tape, V};
use self::model::{Graph, ModelInput};
use crate::runtime::backend::ExecutorState;
use crate::runtime::manifest::{ArtifactSpec, ModelMeta, Role};
use crate::runtime::plan::{Plan, PlanStats};
use crate::substrate::fft::Plan as FftPlan;
use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;

const BETA1: f32 = 0.9;
const BETA2: f32 = 0.999;
const EPS: f32 = 1e-8;

/// Cache hit/miss counters (observability for tests and the bench).
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheStats {
    pub spectra_hits: u64,
    pub spectra_misses: u64,
}

struct SpectraEntry {
    /// bit pattern of the kernel the spectra were computed from (validity
    /// check — training updates the kernel every step, serving keeps it
    /// fixed).  Stored as bits so the comparison is truly bitwise:
    /// f32 `==` would treat -0.0 as a stale hit and NaN as a forced miss.
    kernel_bits: Vec<u32>,
    spectra: Rc<C3aSpectra>,
}

/// Interior caches the interpreter keeps warm across calls: FFT plans per
/// block size and C3A kernel spectra per parameter name.  Spectra entries
/// are invalidated by exact kernel comparison, so a stale entry can cost
/// a recompute but never wrong numerics.  `BTreeMap` (not `HashMap`) per
/// lint rule D2: numeric-path maps keep a deterministic iteration order
/// so no future traversal can depend on hash-seed ordering.
#[derive(Default)]
pub struct InterpCache {
    plans: BTreeMap<usize, Rc<FftPlan>>,
    spectra: BTreeMap<String, SpectraEntry>,
    stats: CacheStats,
}

impl InterpCache {
    pub fn plan(&mut self, b: usize) -> Rc<FftPlan> {
        self.plans.entry(b).or_insert_with(|| Rc::new(FftPlan::new(b))).clone()
    }

    /// Spectra of kernel `name` with current value `w`, reusing the cached
    /// transform when the kernel is bit-identical to the last call.
    pub fn spectra_for(&mut self, name: &str, w: &Arr) -> Rc<C3aSpectra> {
        if let Some(e) = self.spectra.get(name) {
            let same = e.kernel_bits.len() == w.data.len()
                && e.kernel_bits.iter().zip(&w.data).all(|(&bits, v)| bits == v.to_bits());
            if same {
                self.stats.spectra_hits += 1;
                return e.spectra.clone();
            }
        }
        self.stats.spectra_misses += 1;
        let plan = self.plan(w.shape[2]);
        let spectra = Rc::new(C3aSpectra::compute(plan, w));
        self.spectra.insert(
            name.to_string(),
            SpectraEntry {
                kernel_bits: w.data.iter().map(|v| v.to_bits()).collect(),
                spectra: spectra.clone(),
            },
        );
        spectra
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    /// Number of distinct kernels currently held in the spectra cache.
    pub fn spectra_entries(&self) -> usize {
        self.spectra.len()
    }
}

/// One parse of a frozen-backbone literal set: (name, value) pairs in
/// `frozen_order`.  Shareable across sessions — the multi-adapter serving
/// substrate parses the backbone once and hands every tenant state a clone
/// of this `Rc`.
pub type FrozenParse = Rc<Vec<(String, Rc<Arr>)>>;

/// Whether plan recording/replay is enabled (default yes; `C3A_PLAN=0`
/// falls back to the per-request rebuild — the bench uses this to measure
/// the rebuild-vs-replay gap, and it doubles as a kill switch).
fn plan_enabled_from_env() -> bool {
    crate::substrate::env::plan_enabled()
}

/// Per-session interpreter state ([`crate::runtime::backend::ExecutorState`]
/// impl): frozen parameters parsed **once** at session build instead of per
/// step (and shared across sessions when built from a [`FrozenParse`]),
/// a private cache (plans + spectra) not shared with other sessions, and —
/// after the first call — the session's recorded execution plan with its
/// buffer arena.
pub struct InterpState {
    /// (name, parsed value) in `frozen_order`
    frozen: FrozenParse,
    cache: RefCell<InterpCache>,
    /// recorded on the first stateful call; replayed afterwards
    plan: Option<Plan>,
    plan_enabled: bool,
    /// consecutive Plan::build failures; planning is disabled for the
    /// session after [`MAX_PLAN_FAILURES`] so a deterministic build
    /// error cannot levy a per-request classification tax forever
    build_failures: u32,
    /// consecutive replay-to-rebuild fallbacks (reset by any successful
    /// replay); capped like build failures so a deterministic replay
    /// error cannot levy a per-request validation+rebuild tax forever
    replay_failures: u32,
}

/// Give-up threshold for consecutive plan-build or replay failures (see
/// [`InterpState::build_failures`] / [`InterpState::replay_failures`]).
const MAX_PLAN_FAILURES: u32 = 3;

impl InterpState {
    fn over(frozen: FrozenParse) -> InterpState {
        InterpState {
            frozen,
            cache: RefCell::new(InterpCache::default()),
            plan: None,
            plan_enabled: plan_enabled_from_env(),
            build_failures: 0,
            replay_failures: 0,
        }
    }

    pub fn cache_stats(&self) -> CacheStats {
        self.cache.borrow().stats()
    }

    /// Distinct kernels in this state's private spectra cache.
    pub fn spectra_entries(&self) -> usize {
        self.cache.borrow().spectra_entries()
    }

    /// States (plus the originating handle, if any) sharing this state's
    /// frozen parse.
    pub fn frozen_parse_refs(&self) -> usize {
        Rc::strong_count(&self.frozen)
    }

    /// Stats of the recorded plan (None before the first call, or when
    /// disabled via `C3A_PLAN=0`).
    pub fn plan_stats(&self) -> Option<PlanStats> {
        self.plan.as_ref().map(|p| p.stats())
    }
}

impl ExecutorState for InterpState {
    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }

    fn plan_stats(&self) -> Option<PlanStats> {
        InterpState::plan_stats(self)
    }
}

/// A loaded artifact on the substrate backend.
pub struct InterpExecutable {
    spec: ArtifactSpec,
    meta: ModelMeta,
    /// fallback cache for stateless `execute` calls (plans + spectra);
    /// shared across sessions of this executable, equality-verified
    cache: RefCell<InterpCache>,
}

struct ParsedInputs {
    /// (name, value) in trainable_order
    trainable: Vec<(String, Rc<Arr>)>,
    opt_m: Vec<Arr>,
    opt_v: Vec<Arr>,
    /// (name, value) for frozen + frozen_random
    frozen: Vec<(String, Rc<Arr>)>,
    data_f32: BTreeMap<String, Arr>,
    data_i32: BTreeMap<String, Vec<i32>>,
    scalars: BTreeMap<String, f32>,
}

/// Everything a recorded forward pass exposes for plan promotion.
struct ForwardRecord {
    logits: V,
    /// trainable leaf ids in trainable_order
    t_ids: Vec<V>,
    /// frozen leaf ids in frozen_order
    f_ids: Vec<V>,
    input: ModelInput,
}

impl InterpExecutable {
    pub fn new(spec: &ArtifactSpec, meta: &ModelMeta) -> Result<InterpExecutable> {
        match meta.kind.as_str() {
            "encoder" | "decoder" | "mlp" => {}
            other => bail!("{}: unsupported model kind {other}", spec.name),
        }
        match spec.peft.method.as_str() {
            "full" | "head" | "bitfit" | "ia3" | "lora" | "dora" | "vera" | "boft" | "c3a" => {}
            other => bail!("{}: unsupported PEFT method {other}", spec.name),
        }
        Ok(InterpExecutable {
            spec: spec.clone(),
            meta: meta.clone(),
            cache: RefCell::new(InterpCache::default()),
        })
    }

    /// Stateless execution: every input (including the frozen backbone) is
    /// parsed from the literals each call and the graph is rebuilt.
    /// Plans/spectra still come from the executable-local cache
    /// (equality-verified).
    pub fn execute(&self, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let parsed = self.parse_inputs(inputs, None)?;
        let (outs, _) = self.run_parsed(parsed, &self.cache, false)?;
        Ok(outs)
    }

    /// Parse a frozen literal set (in `frozen_order`) into a shareable
    /// handle.  One parse can back any number of session states (see
    /// [`InterpExecutable::prepare_from`]) — the multi-adapter serving
    /// pattern: one frozen backbone, one state per tenant.
    pub fn parse_frozen(&self, frozen: &[xla::Literal]) -> Result<FrozenParse> {
        if frozen.len() != self.spec.frozen_order.len() {
            bail!(
                "{}: parse_frozen got {} frozen literals, manifest declares {}",
                self.spec.name,
                frozen.len(),
                self.spec.frozen_order.len()
            );
        }
        let mut parsed = Vec::with_capacity(frozen.len());
        for (name, lit) in self.spec.frozen_order.iter().zip(frozen.iter()) {
            let inp = self
                .spec
                .inputs
                .iter()
                .find(|i| &i.name == name)
                .with_context(|| format!("{}: unknown frozen input {name}", self.spec.name))?;
            parsed.push((name.clone(), Rc::new(lit_to_arr(lit, &inp.shape)?)));
        }
        Ok(Rc::new(parsed))
    }

    /// Build per-session state: parse the frozen parameters once (they are
    /// constant for the life of a session) and give the session a private
    /// plan/spectra cache plus an (initially empty) execution-plan slot.
    pub fn prepare(&self, frozen: &[xla::Literal]) -> Result<InterpState> {
        Ok(InterpState::over(self.parse_frozen(frozen)?))
    }

    /// Build per-session state over an *existing* shared parse.  The caches
    /// and the execution plan stay private per state; only the parsed
    /// frozen arrays are shared.
    pub fn prepare_from(&self, parse: FrozenParse) -> Result<InterpState> {
        if parse.len() != self.spec.frozen_order.len() {
            bail!(
                "{}: shared parse has {} entries, manifest declares {}",
                self.spec.name,
                parse.len(),
                self.spec.frozen_order.len()
            );
        }
        for ((name, arr), want) in parse.iter().zip(self.spec.frozen_order.iter()) {
            if name != want {
                bail!("{}: shared parse names {name}, manifest declares {want}", self.spec.name);
            }
            let inp = self
                .spec
                .inputs
                .iter()
                .find(|i| &i.name == name)
                .with_context(|| format!("{}: unknown frozen input {name}", self.spec.name))?;
            if arr.shape != inp.shape {
                bail!("{name}: shared parse shape {:?} != manifest {:?}", arr.shape, inp.shape);
            }
        }
        Ok(InterpState::over(parse))
    }

    /// Stateful execution: frozen inputs are taken from `state` (the
    /// positional literals for them are arity-checked but not re-read).
    /// The first call records the step into the state's plan; every later
    /// call replays that plan into its preallocated buffers.
    pub fn execute_stateful(
        &self,
        state: &mut InterpState,
        inputs: &[&xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let mut replay_failed = false;
        if state.plan_enabled {
            if let Some(plan) = state.plan.as_mut() {
                let replayed = if self.spec.kind == "train" {
                    plan.replay_train(&self.spec, &self.meta, &state.cache, inputs)
                } else {
                    plan.replay_eval(&self.spec, &state.cache, inputs).map(|l| vec![l])
                };
                match replayed {
                    Ok(outs) => {
                        state.replay_failures = 0;
                        return Ok(outs);
                    }
                    // Replay is stricter than the rebuild path in spots the
                    // shim is lenient (zero-copy slices reject cross-dtype
                    // literals the allocating conversions accept).  Per the
                    // ExecutorState contract — degrade, never error where
                    // stateless execution would succeed — fall back to the
                    // rebuild for this call, counted in
                    // PlanStats::replay_fallbacks.  The plan stays valid:
                    // every replay refills all variable state from scratch,
                    // so a partial fill cannot leak, and dtype mismatches
                    // bail in validate() before any forward work.
                    Err(_) => {
                        plan.note_fallback();
                        replay_failed = true;
                    }
                }
            } else {
                let parsed = self.parse_inputs(inputs, Some(state))?;
                let (outs, plan) = self.run_parsed(parsed, &state.cache, true)?;
                match plan {
                    Some(p) => state.plan = Some(p),
                    // build failed (outputs above are still the legacy
                    // path's): retry on later calls, but not forever
                    None => {
                        state.build_failures += 1;
                        if state.build_failures >= MAX_PLAN_FAILURES {
                            state.plan_enabled = false;
                        }
                    }
                }
                return Ok(outs);
            }
        }
        let parsed = self.parse_inputs(inputs, Some(state))?;
        let (outs, _) = self.run_parsed(parsed, &state.cache, false)?;
        if replay_failed {
            // the rebuild SUCCEEDED where replay failed — a genuine
            // replay-strictness gap, not a malformed request (those
            // error out above on both paths and never reach here).
            // Persistently gapped sessions stop paying the replay tax.
            state.replay_failures += 1;
            if state.replay_failures >= MAX_PLAN_FAILURES {
                state.plan_enabled = false;
            }
        }
        Ok(outs)
    }

    fn run_parsed(
        &self,
        parsed: ParsedInputs,
        cache: &RefCell<InterpCache>,
        record: bool,
    ) -> Result<(Vec<xla::Literal>, Option<Plan>)> {
        if self.spec.kind == "train" {
            self.train_step(parsed, cache, record)
        } else {
            self.eval_step(parsed, cache, record)
        }
    }

    fn parse_inputs(
        &self,
        inputs: &[&xla::Literal],
        state: Option<&InterpState>,
    ) -> Result<ParsedInputs> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: got {} inputs, manifest declares {}",
                self.spec.name,
                inputs.len(),
                self.spec.inputs.len()
            );
        }
        let mut p = ParsedInputs {
            trainable: Vec::new(),
            opt_m: Vec::new(),
            opt_v: Vec::new(),
            frozen: Vec::new(),
            data_f32: BTreeMap::new(),
            data_i32: BTreeMap::new(),
            scalars: BTreeMap::new(),
        };
        if let Some(s) = state {
            // session-cached parses, uploaded once in `prepare` (the Rc
            // clones are O(1); the name Strings are the only copies)
            p.frozen = s.frozen.as_ref().clone();
        }
        for (inp, lit) in self.spec.inputs.iter().zip(inputs.iter()) {
            match inp.role {
                Role::Trainable => {
                    p.trainable.push((inp.name.clone(), Rc::new(lit_to_arr(lit, &inp.shape)?)))
                }
                Role::OptM => p.opt_m.push(lit_to_arr(lit, &inp.shape)?),
                Role::OptV => p.opt_v.push(lit_to_arr(lit, &inp.shape)?),
                Role::Frozen | Role::FrozenRandom => {
                    if state.is_none() {
                        p.frozen.push((inp.name.clone(), Rc::new(lit_to_arr(lit, &inp.shape)?)));
                    }
                }
                Role::Data => {
                    if inp.i32_dtype {
                        p.data_i32.insert(inp.name.clone(), lit.to_vec::<i32>()?);
                    } else {
                        p.data_f32.insert(inp.name.clone(), lit_to_arr(lit, &inp.shape)?);
                    }
                }
                Role::Scalar => {
                    p.scalars.insert(inp.name.clone(), lit.get_first_element::<f32>()?);
                }
            }
        }
        Ok(p)
    }

    /// Build tape leaves + the shared model input, run the forward pass.
    /// Leaves are shared (`Rc`) with the parsed/cached arrays — no copies.
    fn forward(
        &self,
        tape: &mut Tape,
        parsed: &ParsedInputs,
        cache: &RefCell<InterpCache>,
    ) -> Result<ForwardRecord> {
        let mut params: BTreeMap<String, V> = BTreeMap::new();
        let mut t_ids = Vec::with_capacity(parsed.trainable.len());
        for (name, arr) in &parsed.trainable {
            let id = tape.leaf_shared(arr.clone(), true);
            t_ids.push(id);
            params.insert(name.clone(), id);
        }
        let mut f_ids = Vec::with_capacity(parsed.frozen.len());
        for (name, arr) in &parsed.frozen {
            let id = tape.leaf_shared(arr.clone(), false);
            f_ids.push(id);
            params.insert(name.clone(), id);
        }
        let (b, s) = (self.spec.batch, self.spec.seq);
        let input = ModelInput {
            tokens: parsed.data_i32.get("data.tokens").cloned(),
            x: parsed.data_f32.get("data.x").cloned(),
            b,
            s,
        };
        let mut graph = Graph {
            tape,
            params: &params,
            meta: &self.meta,
            peft: &self.spec.peft,
            cache: Some(cache),
        };
        let fwd = graph.forward(&self.spec.head, &input)?;
        Ok(ForwardRecord { logits: fwd.logits, t_ids, f_ids, input })
    }

    fn eval_step(
        &self,
        parsed: ParsedInputs,
        cache: &RefCell<InterpCache>,
        record: bool,
    ) -> Result<(Vec<xla::Literal>, Option<Plan>)> {
        let mut tape = Tape::new();
        let fwd = self.forward(&mut tape, &parsed, cache)?;
        // the logits buffer *moves* into the output literal (no clone);
        // a recorded plan reallocates that one slot on its first replay
        let out = tape.take_val(fwd.logits);
        // a build failure degrades to plan-less rebuilds — never an error
        // on a call whose outputs the legacy path already produced
        let plan = if record {
            Plan::build(
                tape,
                &self.spec,
                fwd.logits,
                &out.shape,
                &fwd.t_ids,
                &fwd.f_ids,
                fwd.input.tokens.as_deref(),
            )
            .ok()
        } else {
            None
        };
        Ok((vec![xla::Literal::from_f32(&out.shape, out.data)], plan))
    }

    fn train_step(
        &self,
        parsed: ParsedInputs,
        cache: &RefCell<InterpCache>,
        record: bool,
    ) -> Result<(Vec<xla::Literal>, Option<Plan>)> {
        let mut tape = Tape::new();
        let fwd = self.forward(&mut tape, &parsed, cache)?;
        let view = LossView {
            tokens: fwd.input.tokens.as_deref(),
            targets: parsed.data_i32.get("data.targets").map(|v| v.as_slice()),
            loss_mask: parsed.data_f32.get("data.loss_mask").map(|a| a.data.as_slice()),
            y_i32: parsed.data_i32.get("data.y").map(|v| v.as_slice()),
            y_f32: parsed.data_f32.get("data.y").map(|a| a.data.as_slice()),
        };
        let (loss, metric, dlogits) =
            loss_head_view(&self.spec, &self.meta, tape.val(fwd.logits), &view)?;
        let grads = tape.backward(fwd.logits, dlogits);

        let step = *parsed.scalars.get("step").context("missing scalar step")?;
        let lr = *parsed.scalars.get("lr").context("missing scalar lr")?;
        let wd = parsed.scalars.get("wd").copied().unwrap_or(0.0);

        let nt = parsed.trainable.len();
        let mut new_t = Vec::with_capacity(nt);
        let mut new_m = Vec::with_capacity(nt);
        let mut new_v = Vec::with_capacity(nt);
        for (i, (name, p)) in parsed.trainable.iter().enumerate() {
            let decay = if decay_exempt(name) { 0.0 } else { wd };
            let g = grads[fwd.t_ids[i]].as_deref();
            let (pn, mn, vn) = adamw_update(
                &p.data,
                g,
                &parsed.opt_m[i].data,
                &parsed.opt_v[i].data,
                step,
                lr,
                decay,
            );
            new_t.push(xla::Literal::from_f32(&p.shape, pn));
            new_m.push(xla::Literal::from_f32(&p.shape, mn));
            new_v.push(xla::Literal::from_f32(&p.shape, vn));
        }
        let mut outs = new_t;
        outs.extend(new_m);
        outs.extend(new_v);
        outs.push(xla::Literal::scalar(loss));
        outs.push(xla::Literal::scalar(metric));
        // build failure degrades to plan-less rebuilds (outputs above
        // were computed by the legacy path either way)
        let plan = if record {
            let lshape = tape.val(fwd.logits).shape.clone();
            Plan::build(
                tape,
                &self.spec,
                fwd.logits,
                &lshape,
                &fwd.t_ids,
                &fwd.f_ids,
                fwd.input.tokens.as_deref(),
            )
            .ok()
        } else {
            None
        };
        Ok((outs, plan))
    }
}

/// Borrowed views of the loss-head data inputs — built from the parsed
/// maps on the rebuild path and straight from the literal payloads on the
/// replay path, so both regimes share one loss implementation.
pub(crate) struct LossView<'a> {
    pub tokens: Option<&'a [i32]>,
    pub targets: Option<&'a [i32]>,
    pub loss_mask: Option<&'a [f32]>,
    pub y_i32: Option<&'a [i32]>,
    pub y_f32: Option<&'a [f32]>,
}

/// Compute (loss, metric, dL/dlogits) on the host, mirroring python
/// task_loss.  Shared verbatim by the rebuild and replay paths.
pub(crate) fn loss_head_view(
    spec: &ArtifactSpec,
    meta: &ModelMeta,
    lv: &Arr,
    view: &LossView,
) -> Result<(f32, f32, Vec<f32>)> {
    let head = spec.head.as_str();
    let kind = meta.kind.as_str();
    let (b, s) = (spec.batch, spec.seq);

    if kind == "decoder" || head == "mlm" {
        // masked token-level cross-entropy over [b,s,V]
        let mask = view.loss_mask.context("missing data.loss_mask")?;
        let shifted;
        let targets: &[i32] = if head == "mlm" {
            view.targets.context("missing data.targets")?
        } else {
            // next-token targets: shift left, pad last column with 0
            let toks = view.tokens.context("missing data.tokens")?;
            let mut t = vec![0i32; b * s];
            for bi in 0..b {
                for si in 0..s.saturating_sub(1) {
                    t[bi * s + si] = toks[bi * s + si + 1];
                }
            }
            shifted = t;
            &shifted
        };
        let vcb = *lv.shape.last().unwrap();
        let denom = mask.iter().sum::<f32>().max(1.0);
        let mut loss = 0f64;
        let mut correct = 0f64;
        let mut dl = vec![0f32; lv.len()];
        for pos in 0..b * s {
            let m = mask[pos];
            // masked (padding) positions are skipped *before* target
            // validation: garbage targets under mask 0 are legal and
            // must not abort training.
            if m == 0.0 {
                continue;
            }
            let row = &lv.data[pos * vcb..(pos + 1) * vcb];
            let tgt = targets[pos].max(0) as usize;
            if tgt >= vcb {
                bail!("target {tgt} out of vocab {vcb}");
            }
            let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let sum: f32 = row.iter().map(|&v| (v - mx).exp()).sum();
            let lse = mx + sum.ln();
            loss += (m * (lse - row[tgt])) as f64;
            let amax = crate::substrate::linalg::argmax(row);
            if amax == tgt {
                correct += m as f64;
            }
            for j in 0..vcb {
                let p = (row[j] - lse).exp();
                let onehot = if j == tgt { 1.0 } else { 0.0 };
                dl[pos * vcb + j] = m * (p - onehot) / denom;
            }
        }
        return Ok(((loss / denom as f64) as f32, correct as f32, dl));
    }

    if head == "reg" {
        let y = view.y_f32.context("missing data.y")?;
        let w = lv.shape[1];
        let mut loss = 0f64;
        let mut pred_sum = 0f64;
        let mut dl = vec![0f32; lv.len()];
        for r in 0..b {
            let pred = lv.data[r * w];
            let diff = pred - y[r];
            loss += (diff * diff) as f64;
            pred_sum += pred as f64;
            dl[r * w] = 2.0 * diff / b as f32;
        }
        return Ok(((loss / b as f64) as f32, pred_sum as f32, dl));
    }

    // classification (cls / vec / mlp): mean CE over [b, n_out]
    let y = view.y_i32.context("missing data.y")?;
    let w = lv.shape[1];
    let mut loss = 0f64;
    let mut correct = 0f64;
    let mut dl = vec![0f32; lv.len()];
    for r in 0..b {
        let row = &lv.data[r * w..(r + 1) * w];
        let tgt = y[r].max(0) as usize;
        if tgt >= w {
            bail!("label {tgt} out of range {w}");
        }
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let sum: f32 = row.iter().map(|&v| (v - mx).exp()).sum();
        let lse = mx + sum.ln();
        loss += (lse - row[tgt]) as f64;
        if crate::substrate::linalg::argmax(row) == tgt {
            correct += 1.0;
        }
        for j in 0..w {
            let p = (row[j] - lse).exp();
            let onehot = if j == tgt { 1.0 } else { 0.0 };
            dl[r * w + j] = (p - onehot) / b as f32;
        }
    }
    Ok(((loss / b as f64) as f32, correct as f32, dl))
}

/// Whether a trainable parameter is exempt from AdamW weight decay
/// (mirrors python adamw_update).  The single home of the suffix rule —
/// the rebuild path applies it per step and `Plan::build` precomputes it
/// per plan, so the two can never drift.
pub(crate) fn decay_exempt(name: &str) -> bool {
    name.ends_with(".b")
        || name.ends_with(".g")
        || name.ends_with(".mag")
        || name.ends_with(".lb")
        || name.ends_with(".ld")
}

/// One AdamW parameter update (decoupled decay), shared verbatim by the
/// rebuild and replay paths.  `g = None` means a zero gradient (the
/// parameter is disconnected from the loss).
pub(crate) fn adamw_update(
    p: &[f32],
    g: Option<&[f32]>,
    m0: &[f32],
    v0: &[f32],
    step: f32,
    lr: f32,
    decay: f32,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let bc1 = 1.0 - (BETA1 as f64).powf(step as f64);
    let bc2 = 1.0 - (BETA2 as f64).powf(step as f64);
    let mut pn = vec![0f32; p.len()];
    let mut mn = vec![0f32; p.len()];
    let mut vn = vec![0f32; p.len()];
    for e in 0..p.len() {
        let gv = g.map_or(0.0, |g| g[e]);
        let nm = BETA1 * m0[e] + (1.0 - BETA1) * gv;
        let nv = BETA2 * v0[e] + (1.0 - BETA2) * gv * gv;
        let upd = (nm / bc1 as f32) / ((nv / bc2 as f32).sqrt() + EPS);
        pn[e] = p[e] - lr * (upd + decay * p[e]);
        mn[e] = nm;
        vn[e] = nv;
    }
    (pn, mn, vn)
}

fn lit_to_arr(lit: &xla::Literal, shape: &[usize]) -> Result<Arr> {
    let data = lit.to_vec::<f32>()?;
    if data.len() != shape.iter().product::<usize>().max(1) {
        bail!("literal has {} elements, manifest shape {shape:?}", data.len());
    }
    Ok(Arr::new(shape.to_vec(), data))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::catalog;

    /// Drive one interpreted train step directly (no session machinery):
    /// asserts the positional output contract and that loss is finite.
    #[test]
    fn train_step_contract_c3a() {
        let dir = std::env::temp_dir().join("c3a_interp_test");
        let manifest = catalog::synthesize(&dir).unwrap();
        let spec = manifest.artifact("enc_tiny__c3a_d8__cls__train").unwrap().clone();
        let meta = manifest.model("enc_tiny").unwrap().clone();
        let exe = InterpExecutable::new(&spec, &meta).unwrap();

        let lits = catalog::synth_inputs(&spec, &meta);
        let refs: Vec<&xla::Literal> = lits.iter().collect();
        let outs = exe.execute(&refs).unwrap();
        let nt = spec.trainable_order.len();
        assert_eq!(outs.len(), 3 * nt + 2);
        let loss = outs[3 * nt].get_first_element::<f32>().unwrap();
        assert!(loss.is_finite() && loss > 0.0, "loss {loss}");
        // trainable c3a kernels must have moved (nonzero grads at init)
        let before_idx =
            spec.trainable_order.iter().position(|n| n.contains(".c3a.w")).unwrap();
        let before = &lits[before_idx];
        let after = &outs[before_idx];
        let b = before.to_vec::<f32>().unwrap();
        let a = after.to_vec::<f32>().unwrap();
        assert!(b.iter().zip(a.iter()).any(|(x, y)| x != y), "c3a kernel did not update");
    }

    /// Regression: an out-of-vocab target at a *masked* position must be
    /// skipped, not abort training (padding rows carry garbage targets).
    /// The same garbage at an unmasked position must still fail loudly.
    #[test]
    fn masked_garbage_targets_are_skipped() {
        let dir = std::env::temp_dir().join("c3a_interp_test_mlm");
        let manifest = catalog::synthesize(&dir).unwrap();
        let spec = manifest.artifact("enc_tiny__full__mlm__train").unwrap().clone();
        let meta = manifest.model("enc_tiny").unwrap().clone();
        let exe = InterpExecutable::new(&spec, &meta).unwrap();

        let mut lits = catalog::synth_inputs(&spec, &meta);
        let (b, s) = (spec.batch, spec.seq);
        let tgt_idx = spec.inputs.iter().position(|i| i.name == "data.targets").unwrap();
        let mask_idx = spec.inputs.iter().position(|i| i.name == "data.loss_mask").unwrap();
        // mask: even positions supervised, odd positions padding
        let mask: Vec<f32> = (0..b * s).map(|p| if p % 2 == 0 { 1.0 } else { 0.0 }).collect();
        // targets: valid ids where supervised, garbage where masked out
        let targets: Vec<i32> =
            (0..b * s).map(|p| if p % 2 == 0 { (p % 4) as i32 + 4 } else { 9_999_999 }).collect();
        lits[mask_idx] = xla::Literal::from_f32(&[b, s], mask.clone());
        lits[tgt_idx] = xla::Literal::from_i32(&[b, s], targets.clone());
        let refs: Vec<&xla::Literal> = lits.iter().collect();
        let outs = exe.execute(&refs).expect("masked garbage targets must not abort");
        let nt = spec.trainable_order.len();
        let loss = outs[3 * nt].get_first_element::<f32>().unwrap();
        assert!(loss.is_finite() && loss > 0.0, "loss {loss}");

        // garbage at a *supervised* position is real corruption: fail
        let mut bad = targets;
        bad[0] = 9_999_999;
        lits[tgt_idx] = xla::Literal::from_i32(&[b, s], bad);
        let refs: Vec<&xla::Literal> = lits.iter().collect();
        assert!(exe.execute(&refs).is_err(), "unmasked garbage target must error");
    }
}
