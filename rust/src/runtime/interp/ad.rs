//! Reverse-mode tape autodiff over dense f32 host tensors — the numeric
//! core of the substrate fallback backend.
//!
//! The op set is exactly what the C3A model zoo needs (matmul with
//! optional rhs transpose, numpy-style broadcast add/mul, fused layer/rms
//! norm, last-dim softmax, embedding gather, attention head split/merge,
//! the FFT block-circular C3A operator, and BOFT block rotation).  Each op
//! stores only its input node ids; values live on the tape, gradients are
//! materialized during [`Tape::backward`].
//!
//! Gradients only flow into nodes marked `needs` (trainable leaves and
//! anything downstream of one), so frozen-backbone runs skip the dominant
//! backward matmuls automatically.

use crate::substrate::fft::{self, Plan, C};
use crate::substrate::parallel;
use std::cell::RefCell;
use std::rc::Rc;

/// Flop floor below which matmuls stay on one thread.
const PAR_MIN_WORK: usize = 64 * 1024;

/// Fixed row-chunk for the C3A kernel-gradient reduction.  Partial spectra
/// are produced per chunk and combined in chunk order, so the reduction is
/// bit-for-bit identical at any thread count (boundaries never depend on
/// the pool size).
const C3A_GW_CHUNK: usize = 16;

/// Element floor (rows·m·n·b) below which the C3A loops skip the pool —
/// FFT work is heavier per element than a matmul flop, so the floor is
/// lower than [`PAR_MIN_WORK`].  Scheduling only: the chunk decomposition
/// of the gw reduction is the same either way.
const C3A_PAR_MIN_WORK: usize = 8 * 1024;

/// Dense row-major f32 array.  Scalars have an empty shape.
#[derive(Clone, Debug)]
pub struct Arr {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

/// Precomputed forward spectra of a C3A kernel — the session-cacheable
/// half of the operator (analogous to `circulant::PreparedBlockCirculant`).
/// Shared between the forward op and its backward pass, and across steps
/// through the interpreter's session cache while the kernel is unchanged.
pub struct C3aSpectra {
    pub plan: Rc<Plan>,
    /// [m*n] kernel spectra, each of length b
    pub wf: Vec<Vec<C>>,
}

impl C3aSpectra {
    /// FFT every kernel of a [m,n,b] weight.
    pub fn compute(plan: Rc<Plan>, w: &Arr) -> C3aSpectra {
        let (mn, b) = (w.shape[0] * w.shape[1], w.shape[2]);
        let wf = (0..mn)
            .map(|ij| {
                let k: Vec<f64> = w.data[ij * b..(ij + 1) * b].iter().map(|&v| v as f64).collect();
                fft::rfft(&plan, &k)
            })
            .collect();
        C3aSpectra { plan, wf }
    }
}

impl Arr {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Arr {
        assert_eq!(shape.iter().product::<usize>().max(1), data.len());
        Arr { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Arr {
        let n = shape.iter().product::<usize>().max(1);
        Arr { shape, data: vec![0.0; n] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// Product of all dims but the last (row count for last-dim ops).
    fn rows(&self) -> usize {
        let w = self.width();
        if w == 0 {
            0
        } else {
            self.data.len() / w
        }
    }

    /// Last dim.
    fn width(&self) -> usize {
        *self.shape.last().unwrap_or(&1)
    }
}

/// Node id on the tape.
pub type V = usize;

#[derive(Clone, Copy, Debug)]
pub enum Act {
    Gelu,
    Silu,
    Relu,
}

/// How a leaf gets its value on a plan replay (see `runtime::plan`).
/// Recorded at graph-build time by whoever creates the leaf; `Input`
/// leaves are bound positionally by the executor, the rest are
/// model-internal (data tensors, token-derived masks, true constants).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LeafTag {
    /// Externally bound parameter leaf (trainable or frozen); the plan
    /// refills trainables from the input literals and leaves frozen
    /// parses untouched.
    Input,
    /// Dense data leaf refilled from the `data.x` literal each replay.
    DataX,
    /// Encoder pad-key attention mask `[b,1,1,s]`, recomputed from tokens.
    MaskEncPad,
    /// Decoder causal+pad attention mask `[b,1,s,s]`, recomputed from
    /// tokens.
    MaskDecCausal,
    /// Recorded constant (e.g. the BOFT identity block); never refilled.
    Const,
}

/// Reusable small-buffer scratch for [`eval_op`]: broadcast strides, the
/// odometer coordinates, and the matmul transpose staging buffer.  One
/// lives on every [`Tape`] (eager path) and one on every recorded plan
/// (replay path), so steady-state op evaluation performs no heap
/// allocation.
#[derive(Default)]
pub struct Scratch {
    sa: Vec<usize>,
    sb: Vec<usize>,
    coords: Vec<usize>,
    tb: Vec<f32>,
}

enum Op {
    Leaf(LeafTag),
    Add(V, V),
    Mul(V, V),
    Scale(V, f32),
    Matmul { a: V, b: V, trans_b: bool },
    Activation { x: V, kind: Act },
    SoftmaxLast(V),
    LayerNorm { x: V, g: V, b: V },
    RmsNorm { x: V, g: V },
    Gather { table: V, ids: Vec<usize>, prefix: Vec<usize> },
    SliceFirst(V),
    SplitHeads { x: V, heads: usize },
    MergeHeads(V),
    Transpose2(V),
    SumAxis0(V),
    Rsqrt { x: V, eps: f32 },
    Reshape(V),
    C3a { x: V, w: V, spectra: Rc<C3aSpectra> },
    BlockRotate { x: V, r: V },
}

struct Node {
    /// Rc so leaves can share session-cached parses (frozen backbone
    /// params are uploaded once per session, not cloned per step).
    val: Rc<Arr>,
    op: Op,
    needs: bool,
}

pub struct Tape {
    nodes: Vec<Node>,
    /// op-evaluation scratch, reused across every eager record and every
    /// in-place replay on this tape
    scratch: Scratch,
    /// shared placeholder installed wherever a buffer has been moved out
    /// (donated to another node or taken as an output); reads of a
    /// sentinel value indicate a liveness bug and fail loudly on the
    /// shape asserts
    sentinel: Rc<Arr>,
}

// ---------------------------------------------------------------------------
// Dense helpers
// ---------------------------------------------------------------------------

/// C[m,n] = A[m,k] · B[k,n] into a caller-owned buffer, row-major.
/// Output rows are sharded across the substrate pool above a work floor;
/// each row keeps its sequential accumulation order, so results are
/// identical at any thread count.  The SIMD microkernel vectorizes
/// across j only (p stays sequential per element, same `av == 0.0`
/// whole-row skip), so it is additionally bitwise identical to the
/// scalar loop — docs/DETERMINISM.md § SIMD.
fn mm_into(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    c.fill(0.0);
    if m == 0 || n == 0 {
        return;
    }
    #[cfg(feature = "simd")]
    if crate::substrate::simd::enabled() {
        parallel::for_rows(c, n, m * k * n >= PAR_MIN_WORK, |i, crow| {
            crate::substrate::simd::mm_row_f32(crow, &a[i * k..(i + 1) * k], b, n)
        });
        return;
    }
    let row_mul = |i: usize, crow: &mut [f32]| {
        let arow = &a[i * k..(i + 1) * k];
        for (p, &av) in arow.iter().enumerate() {
            if av != 0.0 {
                let brow = &b[p * n..(p + 1) * n];
                for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                    *cv += av * bv;
                }
            }
        }
    };
    parallel::for_rows(c, n, m * k * n >= PAR_MIN_WORK, row_mul);
}

/// Allocating wrapper over [`mm_into`] (backward-pass convenience).
fn mm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0f32; m * n];
    mm_into(&mut c, a, b, m, k, n);
    c
}

/// Transpose `x` ([r,c] -> [c,r]) into a caller-owned staging buffer.
fn transpose_into(out: &mut Vec<f32>, x: &[f32], r: usize, c: usize) {
    out.clear();
    out.resize(r * c, 0.0);
    for i in 0..r {
        for j in 0..c {
            out[j * r + i] = x[i * c + j];
        }
    }
}

fn transpose(x: &[f32], r: usize, c: usize) -> Vec<f32> {
    let mut out = Vec::new();
    transpose_into(&mut out, x, r, c);
    out
}

/// Numpy-style (align-right) broadcast shape of two shapes.
fn broadcast_shape(a: &[usize], b: &[usize]) -> Vec<usize> {
    let rank = a.len().max(b.len());
    let mut out = vec![0usize; rank];
    for i in 0..rank {
        let da = if i < rank - a.len() { 1 } else { a[i - (rank - a.len())] };
        let db = if i < rank - b.len() { 1 } else { b[i - (rank - b.len())] };
        assert!(da == db || da == 1 || db == 1, "broadcast mismatch {a:?} vs {b:?}");
        out[i] = da.max(db);
    }
    out
}

/// Element strides of `shape` as seen from broadcast result `out`
/// (0 where the dim is broadcast), into a caller-owned buffer.
fn bcast_strides_into(shape: &[usize], out: &[usize], s: &mut Vec<usize>) {
    let rank = out.len();
    let off = rank - shape.len();
    s.clear();
    s.resize(rank, 0);
    let mut acc = 1usize;
    for i in (0..shape.len()).rev() {
        if shape[i] != 1 {
            s[off + i] = acc;
        }
        acc *= shape[i];
    }
}

/// Allocating wrapper over [`bcast_strides_into`] (backward-pass use).
fn bcast_strides(shape: &[usize], out: &[usize]) -> Vec<usize> {
    let mut s = Vec::new();
    bcast_strides_into(shape, out, &mut s);
    s
}

/// Iterate a broadcast result, yielding (out_idx, a_idx, b_idx); the
/// odometer coordinates live in a caller-owned buffer.
fn bcast_apply_with(
    out_shape: &[usize],
    sa: &[usize],
    sb: &[usize],
    coords: &mut Vec<usize>,
    mut f: impl FnMut(usize, usize, usize),
) {
    let n: usize = out_shape.iter().product::<usize>().max(1);
    let rank = out_shape.len();
    coords.clear();
    coords.resize(rank, 0);
    let mut ia = 0usize;
    let mut ib = 0usize;
    for i in 0..n {
        f(i, ia, ib);
        // odometer increment
        for d in (0..rank).rev() {
            coords[d] += 1;
            ia += sa[d];
            ib += sb[d];
            if coords[d] < out_shape[d] {
                break;
            }
            ia -= sa[d] * out_shape[d];
            ib -= sb[d] * out_shape[d];
            coords[d] = 0;
        }
    }
}

/// Allocating wrapper over [`bcast_apply_with`] (backward-pass use).
fn bcast_apply(
    out_shape: &[usize],
    sa: &[usize],
    sb: &[usize],
    f: impl FnMut(usize, usize, usize),
) {
    let mut coords = Vec::new();
    bcast_apply_with(out_shape, sa, sb, &mut coords, f)
}

fn act_fwd(kind: Act, x: f32) -> f32 {
    match kind {
        Act::Relu => x.max(0.0),
        Act::Silu => x / (1.0 + (-x).exp()),
        Act::Gelu => {
            // tanh approximation (jax.nn.gelu default)
            let c = (2.0f32 / std::f32::consts::PI).sqrt();
            let u = c * (x + 0.044715 * x * x * x);
            0.5 * x * (1.0 + u.tanh())
        }
    }
}

fn act_bwd(kind: Act, x: f32) -> f32 {
    match kind {
        Act::Relu => {
            if x > 0.0 {
                1.0
            } else {
                0.0
            }
        }
        Act::Silu => {
            let s = 1.0 / (1.0 + (-x).exp());
            s * (1.0 + x * (1.0 - s))
        }
        Act::Gelu => {
            let c = (2.0f32 / std::f32::consts::PI).sqrt();
            let u = c * (x + 0.044715 * x * x * x);
            let t = u.tanh();
            let du = c * (1.0 + 3.0 * 0.044715 * x * x);
            0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * du
        }
    }
}

// ---------------------------------------------------------------------------
// Forward op evaluation (shared by eager record and plan replay)
// ---------------------------------------------------------------------------

/// Per-thread scratch for the C3A forward rows: input-block spectra, the
/// frequency-domain accumulator, and the inverse-transform buffer.
/// Thread-local because rows are sharded across the substrate pool.
#[derive(Default)]
struct C3aScratch {
    xf: Vec<Vec<C>>,
    acc: Vec<C>,
    time: Vec<C>,
}

thread_local! {
    static C3A_SCRATCH: RefCell<C3aScratch> = RefCell::new(C3aScratch::default());
}

/// Append an op's input node ids to `buf` (empty for leaves).  The ONE
/// per-variant input table: `Tape::op_input_ids` (plan liveness) and
/// [`op_needs`] both route through it, so they cannot drift.
fn op_inputs(op: &Op, buf: &mut Vec<V>) {
    match op {
        Op::Leaf(_) => {}
        Op::Add(a, b) | Op::Mul(a, b) => buf.extend([*a, *b]),
        Op::Scale(a, _) => buf.push(*a),
        Op::Matmul { a, b, .. } => buf.extend([*a, *b]),
        Op::Activation { x, .. }
        | Op::SoftmaxLast(x)
        | Op::SliceFirst(x)
        | Op::SplitHeads { x, .. }
        | Op::MergeHeads(x)
        | Op::Transpose2(x)
        | Op::SumAxis0(x)
        | Op::Rsqrt { x, .. }
        | Op::Reshape(x) => buf.push(*x),
        Op::LayerNorm { x, g, b } => buf.extend([*x, *g, *b]),
        Op::RmsNorm { x, g } => buf.extend([*x, *g]),
        Op::Gather { table, .. } => buf.push(*table),
        Op::C3a { x, w, .. } => buf.extend([*x, *w]),
        Op::BlockRotate { x, r } => buf.extend([*x, *r]),
    }
}

/// Whether an op's output participates in gradient flow: the OR of its
/// inputs' `needs` flags (leaves are set explicitly at creation).
fn op_needs(nodes: &[Node], op: &Op) -> bool {
    let mut ids = Vec::with_capacity(3);
    op_inputs(op, &mut ids);
    ids.iter().any(|&u| nodes[u].needs)
}

/// Evaluate one op into `out` (shape already set by the caller), reading
/// inputs from `nodes`.  This is the single source of forward numerics:
/// the eager tape methods and the plan replay both route through it, so
/// a replayed step is bit-for-bit identical to a freshly recorded one by
/// construction.  Every branch fully overwrites `out.data` (accumulating
/// ops zero-fill first), so dirty reused arena buffers are safe.
fn eval_op(nodes: &[Node], op: &Op, out: &mut Arr, scratch: &mut Scratch) {
    match op {
        Op::Leaf(_) => unreachable!("leaves are filled, not computed"),
        Op::Add(a, b) => {
            let (va, vb) = (&*nodes[*a].val, &*nodes[*b].val);
            bcast_strides_into(&va.shape, &out.shape, &mut scratch.sa);
            bcast_strides_into(&vb.shape, &out.shape, &mut scratch.sb);
            let data = &mut out.data;
            let coords = &mut scratch.coords;
            bcast_apply_with(&out.shape, &scratch.sa, &scratch.sb, coords, |o, ia, ib| {
                data[o] = va.data[ia] + vb.data[ib]
            });
        }
        Op::Mul(a, b) => {
            let (va, vb) = (&*nodes[*a].val, &*nodes[*b].val);
            bcast_strides_into(&va.shape, &out.shape, &mut scratch.sa);
            bcast_strides_into(&vb.shape, &out.shape, &mut scratch.sb);
            let data = &mut out.data;
            let coords = &mut scratch.coords;
            bcast_apply_with(&out.shape, &scratch.sa, &scratch.sb, coords, |o, ia, ib| {
                data[o] = va.data[ia] * vb.data[ib]
            });
        }
        Op::Scale(a, c) => {
            let va = &*nodes[*a].val;
            for (o, &x) in out.data.iter_mut().zip(va.data.iter()) {
                *o = x * c;
            }
        }
        Op::Matmul { a, b, trans_b } => {
            let (va, vb) = (&*nodes[*a].val, &*nodes[*b].val);
            let ra = va.shape.len();
            let k = va.shape[ra - 1];
            if vb.shape.len() == 2 {
                let (r0, c0) = (vb.shape[0], vb.shape[1]);
                let bn = if *trans_b { r0 } else { c0 };
                let rows = va.data.len() / k;
                if *trans_b {
                    transpose_into(&mut scratch.tb, &vb.data, r0, c0);
                    mm_into(&mut out.data, &va.data, &scratch.tb, rows, k, bn);
                } else {
                    mm_into(&mut out.data, &va.data, &vb.data, rows, k, bn);
                }
            } else {
                let m = va.shape[ra - 2];
                let (bm2, bn2) = (vb.shape[ra - 2], vb.shape[ra - 1]);
                let bn = if *trans_b { bm2 } else { bn2 };
                let batches: usize = va.shape[..ra - 2].iter().product();
                for t in 0..batches {
                    let asl = &va.data[t * m * k..(t + 1) * m * k];
                    let bsl = &vb.data[t * bm2 * bn2..(t + 1) * bm2 * bn2];
                    let osl = &mut out.data[t * m * bn..(t + 1) * m * bn];
                    if *trans_b {
                        transpose_into(&mut scratch.tb, bsl, bm2, bn2);
                        mm_into(osl, asl, &scratch.tb, m, k, bn);
                    } else {
                        mm_into(osl, asl, bsl, m, k, bn);
                    }
                }
            }
        }
        Op::Activation { x, kind } => {
            let vx = &*nodes[*x].val;
            for (o, &v) in out.data.iter_mut().zip(vx.data.iter()) {
                *o = act_fwd(*kind, v);
            }
        }
        Op::SoftmaxLast(x) => {
            let vx = &*nodes[*x].val;
            out.data.copy_from_slice(&vx.data);
            let w = vx.width();
            for row in out.data.chunks_mut(w) {
                let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let mut sum = 0f32;
                for v in row.iter_mut() {
                    *v = (*v - m).exp();
                    sum += *v;
                }
                for v in row.iter_mut() {
                    *v /= sum;
                }
            }
        }
        Op::LayerNorm { x, g, b } => {
            let (vx, vg, vb) = (&*nodes[*x].val, &*nodes[*g].val, &*nodes[*b].val);
            let d = vx.width();
            for (r, row) in vx.data.chunks(d).enumerate() {
                let mu = row.iter().sum::<f32>() / d as f32;
                let var = row.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
                let inv = 1.0 / (var + 1e-5).sqrt();
                for j in 0..d {
                    out.data[r * d + j] = (row[j] - mu) * inv * vg.data[j] + vb.data[j];
                }
            }
        }
        Op::RmsNorm { x, g } => {
            let (vx, vg) = (&*nodes[*x].val, &*nodes[*g].val);
            let d = vx.width();
            for (r, row) in vx.data.chunks(d).enumerate() {
                let ms = row.iter().map(|&v| v * v).sum::<f32>() / d as f32;
                let inv = 1.0 / (ms + 1e-6).sqrt();
                for j in 0..d {
                    out.data[r * d + j] = row[j] * inv * vg.data[j];
                }
            }
        }
        Op::Gather { table, ids, prefix: _ } => {
            let vt = &*nodes[*table].val;
            let cols = vt.shape[1];
            let rows_v = vt.shape[0];
            for (r, &id) in ids.iter().enumerate() {
                assert!(id < rows_v, "gather id {id} out of range {rows_v}");
                out.data[r * cols..(r + 1) * cols]
                    .copy_from_slice(&vt.data[id * cols..(id + 1) * cols]);
            }
        }
        Op::SliceFirst(x) => {
            let vx = &*nodes[*x].val;
            let (bsz, s, d) = (vx.shape[0], vx.shape[1], vx.shape[2]);
            for bi in 0..bsz {
                out.data[bi * d..(bi + 1) * d]
                    .copy_from_slice(&vx.data[bi * s * d..bi * s * d + d]);
            }
        }
        Op::SplitHeads { x, heads } => {
            let vx = &*nodes[*x].val;
            let (bsz, s, d) = (vx.shape[0], vx.shape[1], vx.shape[2]);
            let hd = d / heads;
            for bi in 0..bsz {
                for si in 0..s {
                    for h in 0..*heads {
                        let src = (bi * s + si) * d + h * hd;
                        let dst = ((bi * heads + h) * s + si) * hd;
                        out.data[dst..dst + hd].copy_from_slice(&vx.data[src..src + hd]);
                    }
                }
            }
        }
        Op::MergeHeads(x) => {
            let vx = &*nodes[*x].val;
            let (bsz, heads, s, hd) = (vx.shape[0], vx.shape[1], vx.shape[2], vx.shape[3]);
            let d = heads * hd;
            for bi in 0..bsz {
                for h in 0..heads {
                    for si in 0..s {
                        let src = ((bi * heads + h) * s + si) * hd;
                        let dst = (bi * s + si) * d + h * hd;
                        out.data[dst..dst + hd].copy_from_slice(&vx.data[src..src + hd]);
                    }
                }
            }
        }
        Op::Transpose2(x) => {
            let vx = &*nodes[*x].val;
            let rank = vx.shape.len();
            let (r, c) = (vx.shape[rank - 2], vx.shape[rank - 1]);
            let batches: usize = vx.shape[..rank - 2].iter().product();
            for t in 0..batches {
                let src = &vx.data[t * r * c..(t + 1) * r * c];
                let dst = &mut out.data[t * r * c..(t + 1) * r * c];
                for i in 0..r {
                    for j in 0..c {
                        dst[j * r + i] = src[i * c + j];
                    }
                }
            }
        }
        Op::SumAxis0(x) => {
            let vx = &*nodes[*x].val;
            let (r, c) = (vx.shape[0], vx.shape[1]);
            out.data.fill(0.0);
            for i in 0..r {
                for j in 0..c {
                    out.data[j] += vx.data[i * c + j];
                }
            }
        }
        Op::Rsqrt { x, eps } => {
            let vx = &*nodes[*x].val;
            for (o, &v) in out.data.iter_mut().zip(vx.data.iter()) {
                *o = 1.0 / (v + eps).sqrt();
            }
        }
        Op::Reshape(x) => {
            out.data.copy_from_slice(&nodes[*x].val.data);
        }
        Op::C3a { x, w, spectra } => {
            let (vx, vw) = (&*nodes[*x].val, &*nodes[*w].val);
            let (m, n, b) = (vw.shape[0], vw.shape[1], vw.shape[2]);
            let rows = vx.rows();
            // deref out of the Rc: &Plan is Sync (Rc is not), so the
            // row closure can cross the pool
            let plan: &Plan = &spectra.plan;
            let wf = &spectra.wf;
            let xdata = &vx.data;
            let row_fwd = |r: usize, orow: &mut [f32]| {
                C3A_SCRATCH.with(|cell| {
                    let s = &mut *cell.borrow_mut();
                    if s.xf.len() < n {
                        s.xf.resize_with(n, Vec::new);
                    }
                    let xrow = &xdata[r * n * b..(r + 1) * n * b];
                    for j in 0..n {
                        fft::rfft_f32_into(plan, &xrow[j * b..(j + 1) * b], &mut s.xf[j]);
                    }
                    for i in 0..m {
                        s.acc.clear();
                        s.acc.resize(b, (0f64, 0f64));
                        for j in 0..n {
                            fft::cmul_acc(&mut s.acc, &wf[i * n + j], &s.xf[j]);
                        }
                        fft::irfft_into(plan, &s.acc, &mut s.time);
                        for k in 0..b {
                            orow[i * b + k] = s.time[k].0 as f32;
                        }
                    }
                });
            };
            parallel::for_rows(&mut out.data, m * b, rows * m * n * b >= C3A_PAR_MIN_WORK, row_fwd);
        }
        Op::BlockRotate { x, r } => {
            let (vx, vr) = (&*nodes[*x].val, &*nodes[*r].val);
            let (nb, bb) = (vr.shape[0], vr.shape[1]);
            let rows = vx.rows();
            for row in 0..rows {
                let xrow = &vx.data[row * nb * bb..(row + 1) * nb * bb];
                let orow = &mut out.data[row * nb * bb..(row + 1) * nb * bb];
                for nbi in 0..nb {
                    let rblk = &vr.data[nbi * bb * bb..(nbi + 1) * bb * bb];
                    for c in 0..bb {
                        let mut acc = 0f32;
                        for bi in 0..bb {
                            acc += xrow[nbi * bb + bi] * rblk[bi * bb + c];
                        }
                        orow[nbi * bb + c] = acc;
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Tape
// ---------------------------------------------------------------------------

impl Default for Tape {
    fn default() -> Self {
        Self::new()
    }
}

impl Tape {
    pub fn new() -> Tape {
        Tape {
            nodes: Vec::new(),
            scratch: Scratch::default(),
            sentinel: Rc::new(Arr { shape: vec![0], data: Vec::new() }),
        }
    }

    pub fn leaf(&mut self, arr: Arr, needs: bool) -> V {
        self.leaf_shared(Rc::new(arr), needs)
    }

    /// Zero-copy leaf from a session-cached parse (frozen params are held
    /// as `Rc<Arr>` across steps; cloning the Rc is O(1)).
    pub fn leaf_shared(&mut self, arr: Rc<Arr>, needs: bool) -> V {
        self.nodes.push(Node { val: arr, op: Op::Leaf(LeafTag::Input), needs });
        self.nodes.len() - 1
    }

    /// Leaf with an explicit replay tag (model-internal leaves: data
    /// tensors, token-derived masks, constants).  See [`LeafTag`].
    pub fn leaf_tagged(&mut self, arr: Arr, needs: bool, tag: LeafTag) -> V {
        self.nodes.push(Node { val: Rc::new(arr), op: Op::Leaf(tag), needs });
        self.nodes.len() - 1
    }

    pub fn val(&self, v: V) -> &Arr {
        &self.nodes[v].val
    }

    pub fn needs(&self, v: V) -> bool {
        self.nodes[v].needs
    }

    fn push(&mut self, val: Arr, op: Op, needs: bool) -> V {
        self.nodes.push(Node { val: Rc::new(val), op, needs });
        self.nodes.len() - 1
    }

    /// Record one op: allocate the (zeroed) output, evaluate it through
    /// [`eval_op`] — the same code a plan replay runs — and push the node.
    fn record(&mut self, shape: Vec<usize>, op: Op) -> V {
        let n = shape.iter().product::<usize>().max(1);
        let mut out = Arr { shape, data: vec![0.0; n] };
        let mut scratch = std::mem::take(&mut self.scratch);
        eval_op(&self.nodes, &op, &mut out, &mut scratch);
        self.scratch = scratch;
        let needs = op_needs(&self.nodes, &op);
        self.push(out, op, needs)
    }

    // -- binary broadcast ops ------------------------------------------------

    pub fn add(&mut self, a: V, b: V) -> V {
        let shape = broadcast_shape(&self.val(a).shape, &self.val(b).shape);
        self.record(shape, Op::Add(a, b))
    }

    pub fn mul(&mut self, a: V, b: V) -> V {
        let shape = broadcast_shape(&self.val(a).shape, &self.val(b).shape);
        self.record(shape, Op::Mul(a, b))
    }

    pub fn scale(&mut self, a: V, c: f32) -> V {
        let shape = self.val(a).shape.clone();
        self.record(shape, Op::Scale(a, c))
    }

    /// a - b (broadcast).
    pub fn sub(&mut self, a: V, b: V) -> V {
        let nb = self.scale(b, -1.0);
        self.add(a, nb)
    }

    // -- matmul --------------------------------------------------------------

    /// Batched matmul over the last two dims of `a`.
    ///
    /// * rhs rank 2: shared weight `[k,n]` (`[n,k]` with `trans_b`); `a` is
    ///   collapsed to `[·, k]`.
    /// * rhs rank > 2: leading dims must equal `a`'s; per-batch matmul.
    pub fn matmul(&mut self, a: V, b: V, trans_b: bool) -> V {
        let (va, vb) = (self.val(a), self.val(b));
        let ra = va.shape.len();
        assert!(ra >= 2, "matmul lhs rank {ra}");
        let k = va.shape[ra - 1];
        let shape = if vb.shape.len() == 2 {
            let (bk, bn) = if trans_b {
                (vb.shape[1], vb.shape[0])
            } else {
                (vb.shape[0], vb.shape[1])
            };
            assert_eq!(k, bk, "matmul inner dim {k} vs {bk}");
            let mut shape = va.shape.clone();
            *shape.last_mut().unwrap() = bn;
            shape
        } else {
            assert_eq!(vb.shape.len(), ra, "batched matmul rank mismatch");
            assert_eq!(&vb.shape[..ra - 2], &va.shape[..ra - 2], "batch dims differ");
            let (bk, bn) = if trans_b {
                (vb.shape[ra - 1], vb.shape[ra - 2])
            } else {
                (vb.shape[ra - 2], vb.shape[ra - 1])
            };
            assert_eq!(k, bk, "batched matmul inner dim {k} vs {bk}");
            let mut shape = va.shape.clone();
            shape[ra - 1] = bn;
            shape
        };
        self.record(shape, Op::Matmul { a, b, trans_b })
    }

    // -- unary / fused ops ---------------------------------------------------

    pub fn activation(&mut self, x: V, kind: Act) -> V {
        let shape = self.val(x).shape.clone();
        self.record(shape, Op::Activation { x, kind })
    }

    pub fn softmax_last(&mut self, x: V) -> V {
        let shape = self.val(x).shape.clone();
        self.record(shape, Op::SoftmaxLast(x))
    }

    pub fn layernorm(&mut self, x: V, g: V, b: V) -> V {
        let (vx, vg, vb) = (self.val(x), self.val(g), self.val(b));
        let d = vx.width();
        assert_eq!(vg.data.len(), d);
        assert_eq!(vb.data.len(), d);
        let shape = vx.shape.clone();
        self.record(shape, Op::LayerNorm { x, g, b })
    }

    pub fn rmsnorm(&mut self, x: V, g: V) -> V {
        let (vx, vg) = (self.val(x), self.val(g));
        let d = vx.width();
        assert_eq!(vg.data.len(), d);
        let shape = vx.shape.clone();
        self.record(shape, Op::RmsNorm { x, g })
    }

    /// Row gather: out[prefix.., :] = table[ids[r], :].
    pub fn gather(&mut self, table: V, ids: &[usize], prefix: &[usize]) -> V {
        let vt = self.val(table);
        assert_eq!(vt.shape.len(), 2);
        assert_eq!(prefix.iter().product::<usize>().max(1), ids.len());
        let cols = vt.shape[1];
        let mut shape = prefix.to_vec();
        shape.push(cols);
        self.record(shape, Op::Gather { table, ids: ids.to_vec(), prefix: prefix.to_vec() })
    }

    /// [B,S,D] -> [B,D] (token 0 pooling).
    pub fn slice_first(&mut self, x: V) -> V {
        let vx = self.val(x);
        assert_eq!(vx.shape.len(), 3);
        let (bsz, d) = (vx.shape[0], vx.shape[2]);
        self.record(vec![bsz, d], Op::SliceFirst(x))
    }

    /// [B,S,H*hd] -> [B,H,S,hd].
    pub fn split_heads(&mut self, x: V, heads: usize) -> V {
        let vx = self.val(x);
        assert_eq!(vx.shape.len(), 3);
        let (bsz, s, d) = (vx.shape[0], vx.shape[1], vx.shape[2]);
        assert_eq!(d % heads, 0);
        let hd = d / heads;
        self.record(vec![bsz, heads, s, hd], Op::SplitHeads { x, heads })
    }

    /// [B,H,S,hd] -> [B,S,H*hd].
    pub fn merge_heads(&mut self, x: V) -> V {
        let vx = self.val(x);
        assert_eq!(vx.shape.len(), 4);
        let (bsz, heads, s, hd) = (vx.shape[0], vx.shape[1], vx.shape[2], vx.shape[3]);
        self.record(vec![bsz, s, heads * hd], Op::MergeHeads(x))
    }

    /// Swap the last two dims (any leading batch).
    pub fn transpose2(&mut self, x: V) -> V {
        let vx = self.val(x);
        let rank = vx.shape.len();
        assert!(rank >= 2);
        let mut shape = vx.shape.clone();
        shape.swap(rank - 2, rank - 1);
        self.record(shape, Op::Transpose2(x))
    }

    /// 2-D [r,c] -> [c] column sums.
    pub fn sum_axis0(&mut self, x: V) -> V {
        let vx = self.val(x);
        assert_eq!(vx.shape.len(), 2);
        let c = vx.shape[1];
        self.record(vec![c], Op::SumAxis0(x))
    }

    /// 1/sqrt(x + eps), elementwise.
    pub fn rsqrt(&mut self, x: V, eps: f32) -> V {
        let shape = self.val(x).shape.clone();
        self.record(shape, Op::Rsqrt { x, eps })
    }

    pub fn reshape(&mut self, x: V, shape: Vec<usize>) -> V {
        let vx = self.val(x);
        assert_eq!(shape.iter().product::<usize>().max(1), vx.data.len());
        self.record(shape, Op::Reshape(x))
    }

    /// C3A block-circular conv: x [..., n*b] ⋆ w [m,n,b] -> [..., m*b]
    /// (per-block FFT; same convention as `substrate::circulant`).
    /// Kernel spectra are computed once per call.
    pub fn c3a(&mut self, x: V, w: V) -> V {
        self.c3a_with(x, w, None)
    }

    /// C3A with optionally precomputed kernel spectra (session cache).
    /// When `spectra` is None they are computed here; either way the op
    /// stores them so the backward pass never re-runs the kernel FFTs
    /// (and a plan replay can refresh them through the same cache).
    pub fn c3a_with(&mut self, x: V, w: V, spectra: Option<Rc<C3aSpectra>>) -> V {
        let (vx, vw) = (self.val(x), self.val(w));
        assert_eq!(vw.shape.len(), 3);
        let (m, n, b) = (vw.shape[0], vw.shape[1], vw.shape[2]);
        assert_eq!(vx.width(), n * b, "c3a input width");
        let spectra = match spectra {
            Some(s) => {
                assert_eq!(s.plan.n, b, "cached spectra plan size");
                assert_eq!(s.wf.len(), m * n, "cached spectra block count");
                s
            }
            None => Rc::new(C3aSpectra::compute(Rc::new(Plan::new(b)), vw)),
        };
        let mut shape = vx.shape.clone();
        *shape.last_mut().unwrap() = m * b;
        self.record(shape, Op::C3a { x, w, spectra })
    }

    /// BOFT rotation: out[..., n, c] = Σ_b x[..., n, b] · r[n, b, c]
    /// with x [..., nb*bb] viewed blockwise and r [nb, bb, bb].
    pub fn block_rotate(&mut self, x: V, r: V) -> V {
        let (vx, vr) = (self.val(x), self.val(r));
        assert_eq!(vr.shape.len(), 3);
        let (nb, bb, bb2) = (vr.shape[0], vr.shape[1], vr.shape[2]);
        assert_eq!(bb, bb2);
        assert_eq!(vx.width(), nb * bb, "block_rotate width");
        let shape = vx.shape.clone();
        self.record(shape, Op::BlockRotate { x, r })
    }

    // -- plan replay primitives (see `runtime::plan`) ------------------------

    /// Number of nodes on the tape (the plan's op-list length).
    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_leaf(&self, v: V) -> bool {
        matches!(self.nodes[v].op, Op::Leaf(_))
    }

    /// Replay tag of a leaf node (None for op nodes).
    pub fn leaf_tag(&self, v: V) -> Option<LeafTag> {
        match self.nodes[v].op {
            Op::Leaf(tag) => Some(tag),
            _ => None,
        }
    }

    /// Append the input node ids of `v` to `buf` (empty for leaves).
    pub fn op_input_ids(&self, v: V, buf: &mut Vec<V>) {
        op_inputs(&self.nodes[v].op, buf)
    }

    /// Node ids of every embedding-gather op (replay refreshes their row
    /// ids from the request's tokens).
    pub fn gather_nodes(&self) -> Vec<V> {
        (0..self.nodes.len())
            .filter(|&v| matches!(self.nodes[v].op, Op::Gather { .. }))
            .collect()
    }

    /// (op node, kernel leaf) pairs of every C3A op (replay refreshes
    /// their cached spectra through the session cache).
    pub fn c3a_nodes(&self) -> Vec<(V, V)> {
        (0..self.nodes.len())
            .filter_map(|v| match self.nodes[v].op {
                Op::C3a { w, .. } => Some((v, w)),
                _ => None,
            })
            .collect()
    }

    /// Whether gather `v`'s recorded ids are exactly the `t.max(0)`
    /// mapping of `toks` — the plan builder's fail-closed check that a
    /// recorded gather really is a token-embedding gather before replay
    /// starts rewriting its ids from request tokens.
    pub fn gather_ids_match_tokens(&self, v: V, toks: &[i32]) -> bool {
        match &self.nodes[v].op {
            Op::Gather { ids, .. } => {
                ids.len() == toks.len()
                    && ids.iter().zip(toks.iter()).all(|(&id, &t)| id == t.max(0) as usize)
            }
            _ => false,
        }
    }

    /// Rewrite a gather op's row ids from raw token ids (the same
    /// `t.max(0)` clamp the model applies when recording).
    pub fn set_gather_tokens(&mut self, v: V, toks: &[i32]) {
        match &mut self.nodes[v].op {
            Op::Gather { ids, .. } => {
                assert_eq!(ids.len(), toks.len(), "gather arity changed between record and replay");
                for (slot, &t) in ids.iter_mut().zip(toks.iter()) {
                    *slot = t.max(0) as usize;
                }
            }
            _ => panic!("node {v} is not a gather op"),
        }
    }

    /// Swap a C3A op's kernel spectra (replay path, after the kernel leaf
    /// has been refilled; the session cache recomputes on kernel change).
    pub fn refresh_c3a_spectra(&mut self, v: V, spectra: Rc<C3aSpectra>) {
        match &mut self.nodes[v].op {
            Op::C3a { spectra: slot, .. } => {
                debug_assert_eq!(slot.plan.n, spectra.plan.n, "spectra plan size changed");
                debug_assert_eq!(slot.wf.len(), spectra.wf.len(), "spectra block count changed");
                *slot = spectra;
            }
            _ => panic!("node {v} is not a c3a op"),
        }
    }

    /// Whether leaf `v` currently holds exactly `data` — bitwise, NaN
    /// payloads included (`to_bits`, not `==`).  This is the plan's
    /// hoist-epoch fingerprint: a version-invariant op may be skipped on
    /// replay only while every trainable leaf feeding it still matches
    /// the incoming literal bit-for-bit (the same equality-invalidation
    /// rule the spectra and upload caches apply).
    pub fn leaf_bits_match(&self, v: V, data: &[f32]) -> bool {
        debug_assert!(matches!(self.nodes[v].op, Op::Leaf(_)), "leaf_bits_match on op node {v}");
        let cur = &self.nodes[v].val.data;
        cur.len() == data.len()
            && cur.iter().zip(data.iter()).all(|(a, b)| a.to_bits() == b.to_bits())
    }

    /// Overwrite a leaf's payload in place (replay of trainable / data
    /// leaves).  Falls back to a fresh buffer if the old one is still
    /// shared (only possible transiently right after recording).
    pub fn copy_into_leaf(&mut self, v: V, data: &[f32]) {
        let node = &mut self.nodes[v];
        debug_assert!(matches!(node.op, Op::Leaf(_)), "copy_into_leaf on op node {v}");
        assert_eq!(node.val.data.len(), data.len(), "leaf {v} payload length changed");
        match Rc::get_mut(&mut node.val) {
            Some(arr) => arr.data.copy_from_slice(data),
            None => {
                node.val = Rc::new(Arr { shape: node.val.shape.clone(), data: data.to_vec() });
            }
        }
    }

    /// Mutate a leaf's payload via closure (replay of token-derived
    /// masks); same clone-on-shared fallback as [`Tape::copy_into_leaf`].
    pub fn write_leaf_with(&mut self, v: V, f: impl FnOnce(&mut [f32])) {
        let node = &mut self.nodes[v];
        debug_assert!(matches!(node.op, Op::Leaf(_)), "write_leaf_with on op node {v}");
        if Rc::get_mut(&mut node.val).is_none() {
            node.val = Rc::new(node.val.as_ref().clone());
        }
        f(&mut Rc::get_mut(&mut node.val).expect("unique after clone").data);
    }

    /// Move `donor`'s value buffer onto node `v` (arena slot reuse: the
    /// plan's liveness analysis guarantees the donor is dead).  The donor
    /// is left holding the sentinel.
    pub fn steal_buffer(&mut self, donor: V, v: V) {
        if donor == v {
            return;
        }
        let rc = std::mem::replace(&mut self.nodes[donor].val, self.sentinel.clone());
        self.nodes[v].val = rc;
    }

    /// Recompute op node `v` in place into its (possibly donated) buffer
    /// through [`eval_op`] — the replay workhorse.  `shape` is the static
    /// shape recorded by the plan; a node whose buffer was taken (e.g.
    /// the logits output) transparently reallocates.
    pub fn recompute(&mut self, v: V, shape: &[usize]) {
        let mut scratch = std::mem::take(&mut self.scratch);
        let n = shape.iter().product::<usize>().max(1);
        let (prev, rest) = self.nodes.split_at_mut(v);
        let node = &mut rest[0];
        debug_assert!(!matches!(node.op, Op::Leaf(_)), "recompute on leaf {v}");
        if Rc::get_mut(&mut node.val).is_none() {
            node.val = Rc::new(Arr { shape: shape.to_vec(), data: vec![0.0; n] });
        }
        let arr = Rc::get_mut(&mut node.val).expect("unique after replacement");
        arr.shape.clear();
        arr.shape.extend_from_slice(shape);
        arr.data.resize(n, 0.0);
        eval_op(prev, &node.op, arr, &mut scratch);
        self.scratch = scratch;
    }

    /// Move a node's value out of the tape (zero-copy eval output).  The
    /// node is left holding the sentinel and reallocates on the next
    /// replay; a still-shared value is cloned instead (defensive).
    pub fn take_val(&mut self, v: V) -> Arr {
        let rc = std::mem::replace(&mut self.nodes[v].val, self.sentinel.clone());
        match Rc::try_unwrap(rc) {
            Ok(arr) => arr,
            Err(rc) => {
                let arr = rc.as_ref().clone();
                self.nodes[v].val = rc;
                arr
            }
        }
    }

    // -- backward ------------------------------------------------------------

    /// Reverse pass from `root` seeded with `seed` (same length as the
    /// root's value).  Returns per-node gradients (None where not needed).
    pub fn backward(&self, root: V, seed: Vec<f32>) -> Vec<Option<Vec<f32>>> {
        assert_eq!(seed.len(), self.val(root).len());
        let mut grads: Vec<Option<Vec<f32>>> = vec![None; self.nodes.len()];
        grads[root] = Some(seed);
        for id in (0..self.nodes.len()).rev() {
            if grads[id].is_none() || !self.nodes[id].needs {
                continue;
            }
            let go = grads[id].take().unwrap();
            let contributions = self.op_backward(id, &go);
            grads[id] = Some(go);
            for (v, g) in contributions {
                if !self.nodes[v].needs {
                    continue;
                }
                match &mut grads[v] {
                    Some(acc) => {
                        for (a, b) in acc.iter_mut().zip(g.iter()) {
                            *a += b;
                        }
                    }
                    slot => *slot = Some(g),
                }
            }
        }
        grads
    }

    /// Gradient contributions of node `id` into its inputs.
    fn op_backward(&self, id: V, go: &[f32]) -> Vec<(V, Vec<f32>)> {
        let out_val = &self.nodes[id].val;
        match &self.nodes[id].op {
            Op::Leaf(_) => Vec::new(),
            Op::Scale(a, c) => {
                vec![(*a, go.iter().map(|&g| g * c).collect())]
            }
            Op::Add(a, b) => {
                let mut outs = Vec::new();
                for &(v, _other) in &[(*a, *b), (*b, *a)] {
                    if !self.nodes[v].needs {
                        continue;
                    }
                    let sv = bcast_strides(&self.val(v).shape, &out_val.shape);
                    let s0 = vec![0usize; out_val.shape.len()];
                    let mut g = vec![0f32; self.val(v).len()];
                    bcast_apply(&out_val.shape, &sv, &s0, |o, iv, _| g[iv] += go[o]);
                    outs.push((v, g));
                }
                outs
            }
            Op::Mul(a, b) => {
                let mut outs = Vec::new();
                for &(v, other) in &[(*a, *b), (*b, *a)] {
                    if !self.nodes[v].needs {
                        continue;
                    }
                    let sv = bcast_strides(&self.val(v).shape, &out_val.shape);
                    let so = bcast_strides(&self.val(other).shape, &out_val.shape);
                    let ov = &self.val(other).data;
                    let mut g = vec![0f32; self.val(v).len()];
                    bcast_apply(&out_val.shape, &sv, &so, |o, iv, io| g[iv] += go[o] * ov[io]);
                    outs.push((v, g));
                }
                outs
            }
            Op::Matmul { a, b, trans_b } => self.matmul_backward(*a, *b, *trans_b, go),
            Op::Activation { x, kind } => {
                let vx = &self.val(*x).data;
                let g =
                    vx.iter().zip(go.iter()).map(|(&xv, &gv)| gv * act_bwd(*kind, xv)).collect();
                vec![(*x, g)]
            }
            Op::SoftmaxLast(x) => {
                let y = &out_val.data;
                let w = out_val.width();
                let mut g = vec![0f32; y.len()];
                for r in 0..y.len() / w {
                    let yr = &y[r * w..(r + 1) * w];
                    let gr = &go[r * w..(r + 1) * w];
                    let dot: f32 = yr.iter().zip(gr.iter()).map(|(&a, &b)| a * b).sum();
                    for j in 0..w {
                        g[r * w + j] = yr[j] * (gr[j] - dot);
                    }
                }
                vec![(*x, g)]
            }
            Op::LayerNorm { x, g, b } => self.layernorm_backward(*x, *g, *b, go),
            Op::RmsNorm { x, g } => self.rmsnorm_backward(*x, *g, go),
            Op::Gather { table, ids, prefix: _ } => {
                let vt = self.val(*table);
                let cols = vt.shape[1];
                let mut g = vec![0f32; vt.len()];
                for (r, &idx) in ids.iter().enumerate() {
                    for j in 0..cols {
                        g[idx * cols + j] += go[r * cols + j];
                    }
                }
                vec![(*table, g)]
            }
            Op::SliceFirst(x) => {
                let vx = self.val(*x);
                let (bsz, s, d) = (vx.shape[0], vx.shape[1], vx.shape[2]);
                let mut g = vec![0f32; vx.len()];
                for bi in 0..bsz {
                    g[bi * s * d..bi * s * d + d].copy_from_slice(&go[bi * d..(bi + 1) * d]);
                }
                vec![(*x, g)]
            }
            Op::SplitHeads { x, heads } => {
                let vx = self.val(*x);
                let (bsz, s, d) = (vx.shape[0], vx.shape[1], vx.shape[2]);
                let hd = d / heads;
                let mut g = vec![0f32; vx.len()];
                for bi in 0..bsz {
                    for si in 0..s {
                        for h in 0..*heads {
                            let dst = (bi * s + si) * d + h * hd;
                            let src = ((bi * heads + h) * s + si) * hd;
                            g[dst..dst + hd].copy_from_slice(&go[src..src + hd]);
                        }
                    }
                }
                vec![(*x, g)]
            }
            Op::MergeHeads(x) => {
                let vx = self.val(*x);
                let (bsz, heads, s, hd) = (vx.shape[0], vx.shape[1], vx.shape[2], vx.shape[3]);
                let d = heads * hd;
                let mut g = vec![0f32; vx.len()];
                for bi in 0..bsz {
                    for h in 0..heads {
                        for si in 0..s {
                            let dst = ((bi * heads + h) * s + si) * hd;
                            let src = (bi * s + si) * d + h * hd;
                            g[dst..dst + hd].copy_from_slice(&go[src..src + hd]);
                        }
                    }
                }
                vec![(*x, g)]
            }
            Op::Transpose2(x) => {
                let vx = self.val(*x);
                let rank = vx.shape.len();
                let (r, c) = (vx.shape[rank - 2], vx.shape[rank - 1]);
                let batches: usize = vx.shape[..rank - 2].iter().product();
                let mut g = vec![0f32; vx.len()];
                for t in 0..batches {
                    // out is [c,r] per batch; transpose back to [r,c]
                    let src = &go[t * r * c..(t + 1) * r * c];
                    g[t * r * c..(t + 1) * r * c].copy_from_slice(&transpose(src, c, r));
                }
                vec![(*x, g)]
            }
            Op::SumAxis0(x) => {
                let vx = self.val(*x);
                let (r, c) = (vx.shape[0], vx.shape[1]);
                let mut g = vec![0f32; r * c];
                for i in 0..r {
                    g[i * c..(i + 1) * c].copy_from_slice(go);
                }
                vec![(*x, g)]
            }
            Op::Rsqrt { x, eps: _ } => {
                // y = (x+eps)^-1/2 -> dy/dx = -y^3 / 2
                let y = &out_val.data;
                let g =
                    y.iter().zip(go.iter()).map(|(&yv, &gv)| -0.5 * yv * yv * yv * gv).collect();
                vec![(*x, g)]
            }
            Op::Reshape(x) => vec![(*x, go.to_vec())],
            Op::C3a { x, w, spectra } => self.c3a_backward(*x, *w, spectra, go),
            Op::BlockRotate { x, r } => {
                let (vx, vr) = (self.val(*x), self.val(*r));
                let (nb, bb) = (vr.shape[0], vr.shape[1]);
                let rows = vx.rows();
                let mut outs = Vec::new();
                if self.nodes[*x].needs {
                    let mut gx = vec![0f32; vx.len()];
                    for row in 0..rows {
                        for nbi in 0..nb {
                            let rblk = &vr.data[nbi * bb * bb..(nbi + 1) * bb * bb];
                            for bi in 0..bb {
                                let mut acc = 0f32;
                                for c in 0..bb {
                                    acc += go[row * nb * bb + nbi * bb + c] * rblk[bi * bb + c];
                                }
                                gx[row * nb * bb + nbi * bb + bi] = acc;
                            }
                        }
                    }
                    outs.push((*x, gx));
                }
                if self.nodes[*r].needs {
                    let mut gr = vec![0f32; vr.len()];
                    for row in 0..rows {
                        for nbi in 0..nb {
                            for bi in 0..bb {
                                let xv = vx.data[row * nb * bb + nbi * bb + bi];
                                if xv == 0.0 {
                                    continue;
                                }
                                for c in 0..bb {
                                    gr[nbi * bb * bb + bi * bb + c] +=
                                        xv * go[row * nb * bb + nbi * bb + c];
                                }
                            }
                        }
                    }
                    outs.push((*r, gr));
                }
                outs
            }
        }
    }

    fn matmul_backward(&self, a: V, b: V, trans_b: bool, go: &[f32]) -> Vec<(V, Vec<f32>)> {
        let (va, vb) = (self.val(a), self.val(b));
        let ra = va.shape.len();
        let k = va.shape[ra - 1];
        let mut outs = Vec::new();
        if vb.shape.len() == 2 {
            let (r0, c0) = (vb.shape[0], vb.shape[1]);
            let n = if trans_b { r0 } else { c0 };
            let rows = va.data.len() / k;
            if self.nodes[a].needs {
                // da = dY · B_eff^T; B_eff^T is [n,k]
                let b_eff_t = if trans_b {
                    vb.data.clone() // stored [n,k] already
                } else {
                    transpose(&vb.data, r0, c0)
                };
                let da = mm(go, &b_eff_t, rows, n, k);
                outs.push((a, da));
            }
            if self.nodes[b].needs {
                // dB_eff = A^T · dY  ([k,n]); transpose back if stored [n,k]
                let at = transpose(&va.data, rows, k);
                let db_eff = mm(&at, go, k, rows, n);
                let db = if trans_b { transpose(&db_eff, k, n) } else { db_eff };
                outs.push((b, db));
            }
        } else {
            let m = va.shape[ra - 2];
            let (bm, bn) = (vb.shape[ra - 2], vb.shape[ra - 1]);
            let n = if trans_b { bm } else { bn };
            let batches: usize = va.shape[..ra - 2].iter().product();
            let mut da = vec![0f32; va.len()];
            let mut db = vec![0f32; vb.len()];
            for t in 0..batches {
                let gsl = &go[t * m * n..(t + 1) * m * n];
                let asl = &va.data[t * m * k..(t + 1) * m * k];
                let bsl = &vb.data[t * bm * bn..(t + 1) * bm * bn];
                if self.nodes[a].needs {
                    let b_eff_t = if trans_b { bsl.to_vec() } else { transpose(bsl, bm, bn) };
                    let d = mm(gsl, &b_eff_t, m, n, k);
                    da[t * m * k..(t + 1) * m * k].copy_from_slice(&d);
                }
                if self.nodes[b].needs {
                    let at = transpose(asl, m, k);
                    let d_eff = mm(&at, gsl, k, m, n);
                    let d = if trans_b { transpose(&d_eff, k, n) } else { d_eff };
                    db[t * bm * bn..(t + 1) * bm * bn].copy_from_slice(&d);
                }
            }
            if self.nodes[a].needs {
                outs.push((a, da));
            }
            if self.nodes[b].needs {
                outs.push((b, db));
            }
        }
        outs
    }

    fn layernorm_backward(&self, x: V, g: V, b: V, go: &[f32]) -> Vec<(V, Vec<f32>)> {
        let (vx, vg) = (self.val(x), self.val(g));
        let d = vx.width();
        let rows = vx.rows();
        let mut gx = vec![0f32; vx.len()];
        let mut gg = vec![0f32; d];
        let mut gb = vec![0f32; d];
        for r in 0..rows {
            let row = &vx.data[r * d..(r + 1) * d];
            let gor = &go[r * d..(r + 1) * d];
            let mu = row.iter().sum::<f32>() / d as f32;
            let var = row.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
            let inv = 1.0 / (var + 1e-5).sqrt();
            // xhat and dy*g reductions
            let mut mean_dyg = 0f32;
            let mut mean_dyg_xhat = 0f32;
            for j in 0..d {
                let xhat = (row[j] - mu) * inv;
                let dyg = gor[j] * vg.data[j];
                mean_dyg += dyg;
                mean_dyg_xhat += dyg * xhat;
                gg[j] += gor[j] * xhat;
                gb[j] += gor[j];
            }
            mean_dyg /= d as f32;
            mean_dyg_xhat /= d as f32;
            for j in 0..d {
                let xhat = (row[j] - mu) * inv;
                let dyg = gor[j] * vg.data[j];
                gx[r * d + j] = inv * (dyg - mean_dyg - xhat * mean_dyg_xhat);
            }
        }
        let mut outs = Vec::new();
        if self.nodes[x].needs {
            outs.push((x, gx));
        }
        if self.nodes[g].needs {
            outs.push((g, gg));
        }
        if self.nodes[b].needs {
            outs.push((b, gb));
        }
        outs
    }

    fn rmsnorm_backward(&self, x: V, g: V, go: &[f32]) -> Vec<(V, Vec<f32>)> {
        let (vx, vg) = (self.val(x), self.val(g));
        let d = vx.width();
        let rows = vx.rows();
        let mut gx = vec![0f32; vx.len()];
        let mut gg = vec![0f32; d];
        for r in 0..rows {
            let row = &vx.data[r * d..(r + 1) * d];
            let gor = &go[r * d..(r + 1) * d];
            let ms = row.iter().map(|&v| v * v).sum::<f32>() / d as f32;
            let rms = (ms + 1e-6).sqrt();
            let inv = 1.0 / rms;
            let mut dot = 0f32; // Σ x·g·dy
            for j in 0..d {
                dot += row[j] * vg.data[j] * gor[j];
                gg[j] += gor[j] * row[j] * inv;
            }
            let c = dot / (d as f32 * rms * rms * rms);
            for j in 0..d {
                gx[r * d + j] = vg.data[j] * gor[j] * inv - row[j] * c;
            }
        }
        let mut outs = Vec::new();
        if self.nodes[x].needs {
            outs.push((x, gx));
        }
        if self.nodes[g].needs {
            outs.push((g, gg));
        }
        outs
    }

    /// C3A backward.  Kernel spectra come from the forward op (conjugated
    /// on the fly — no kernel FFTs here).  `gx` rows are disjoint and
    /// sharded across the pool; the `gw` reduction over rows uses fixed
    /// [`C3A_GW_CHUNK`] partials combined in chunk order, so it is
    /// bit-for-bit identical at any thread count.
    fn c3a_backward(&self, x: V, w: V, spectra: &Rc<C3aSpectra>, go: &[f32]) -> Vec<(V, Vec<f32>)> {
        let (vx, vw) = (self.val(x), self.val(w));
        let (m, n, b) = (vw.shape[0], vw.shape[1], vw.shape[2]);
        let rows = vx.rows();
        let plan: &Plan = &spectra.plan;
        let conj = |v: &[C]| -> Vec<C> { v.iter().map(|&(re, im)| (re, -im)).collect() };
        let wf_conj: Vec<Vec<C>> = spectra.wf.iter().map(|wf| conj(wf)).collect();
        let need_x = self.nodes[x].needs;
        let need_w = self.nodes[w].needs;
        let xdata = &vx.data;
        // per-row FFT of the upstream gradient (shared by dx and dw)
        let row_dyf = |r: usize| -> Vec<Vec<C>> {
            (0..m)
                .map(|i| {
                    let dyr: Vec<f64> = go[r * m * b + i * b..r * m * b + (i + 1) * b]
                        .iter()
                        .map(|&v| v as f64)
                        .collect();
                    fft::rfft(plan, &dyr)
                })
                .collect()
        };
        let mut outs = Vec::new();
        if need_x {
            let mut gx = vec![0f32; vx.len()];
            let row_gx = |r: usize, grow: &mut [f32]| {
                let dyf = row_dyf(r);
                for j in 0..n {
                    let mut acc = vec![(0f64, 0f64); b];
                    for i in 0..m {
                        fft::cmul_acc(&mut acc, &wf_conj[i * n + j], &dyf[i]);
                    }
                    let z = fft::irfft_real(plan, &acc);
                    for k in 0..b {
                        grow[j * b + k] = z[k] as f32;
                    }
                }
            };
            parallel::for_rows(&mut gx, n * b, rows * m * n * b >= C3A_PAR_MIN_WORK, row_gx);
            outs.push((x, gx));
        }
        if need_w {
            // accumulate conj(X)·dY per fixed row chunk, combine in order.
            // The chunk decomposition is identical on the small-work inline
            // path, so the reduction order never depends on scheduling.
            let gw_chunk = |range: std::ops::Range<usize>| -> Vec<(f64, f64)> {
                let mut part = vec![(0f64, 0f64); m * n * b];
                for r in range {
                    let dyf = row_dyf(r);
                    let xf_conj: Vec<Vec<C>> = (0..n)
                        .map(|j| {
                            let xj: Vec<f64> = xdata[r * n * b + j * b..r * n * b + (j + 1) * b]
                                .iter()
                                .map(|&v| v as f64)
                                .collect();
                            conj(&fft::rfft(plan, &xj))
                        })
                        .collect();
                    for i in 0..m {
                        for j in 0..n {
                            let slot = &mut part[(i * n + j) * b..(i * n + j + 1) * b];
                            fft::cmul_acc(slot, &xf_conj[j], &dyf[i]);
                        }
                    }
                }
                part
            };
            let partials: Vec<Vec<(f64, f64)>> =
                if rows * m * n * b >= C3A_PAR_MIN_WORK && parallel::threads() > 1 {
                    parallel::map_chunks(rows, C3A_GW_CHUNK, &gw_chunk)
                } else {
                    (0..rows.div_ceil(C3A_GW_CHUNK))
                        .map(|ci| gw_chunk(ci * C3A_GW_CHUNK..rows.min((ci + 1) * C3A_GW_CHUNK)))
                        .collect()
                };
            let mut gw_spec = vec![(0f64, 0f64); m * n * b];
            for part in &partials {
                for (acc, p) in gw_spec.iter_mut().zip(part.iter()) {
                    acc.0 += p.0;
                    acc.1 += p.1;
                }
            }
            let mut gw = vec![0f32; vw.len()];
            for ij in 0..m * n {
                let z = fft::irfft_real(plan, &gw_spec[ij * b..(ij + 1) * b]);
                for k in 0..b {
                    gw[ij * b + k] = z[k] as f32;
                }
            }
            outs.push((w, gw));
        }
        outs
    }
}

// ---------------------------------------------------------------------------
// Tests: finite-difference gradient checks for every differentiable op
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::prng::Rng;

    fn rand_arr(rng: &mut Rng, shape: &[usize]) -> Arr {
        let n: usize = shape.iter().product::<usize>().max(1);
        Arr::new(shape.to_vec(), (0..n).map(|_| rng.normal() as f32 * 0.5).collect())
    }

    /// Scalar objective: weighted sum of the graph output, so dL/dout is a
    /// fixed random seed vector.
    fn gradcheck(shapes: &[&[usize]], build: impl Fn(&mut Tape, &[V]) -> V, tol: f32) {
        let mut rng = Rng::seed(0xAD);
        let inputs: Vec<Arr> = shapes.iter().map(|s| rand_arr(&mut rng, s)).collect();
        let mut tape = Tape::new();
        let ids: Vec<V> = inputs.iter().map(|a| tape.leaf(a.clone(), true)).collect();
        let out = build(&mut tape, &ids);
        let w: Vec<f32> = (0..tape.val(out).len()).map(|_| rng.normal() as f32).collect();
        let grads = tape.backward(out, w.clone());

        let loss = |vals: &[Arr]| -> f64 {
            let mut t = Tape::new();
            let ids: Vec<V> = vals.iter().map(|a| t.leaf(a.clone(), false)).collect();
            let o = build(&mut t, &ids);
            t.val(o).data.iter().zip(w.iter()).map(|(&a, &b)| a as f64 * b as f64).sum()
        };
        let eps = 1e-3f32;
        for (vi, id) in ids.iter().enumerate() {
            let g = grads[*id].as_ref().expect("input grad");
            for ei in 0..inputs[vi].len() {
                let mut plus = inputs.clone();
                plus[vi].data[ei] += eps;
                let mut minus = inputs.clone();
                minus[vi].data[ei] -= eps;
                let num = ((loss(&plus) - loss(&minus)) / (2.0 * eps as f64)) as f32;
                let an = g[ei];
                let scale = 1.0f32.max(num.abs()).max(an.abs());
                assert!(
                    (num - an).abs() / scale < tol,
                    "input {vi} elem {ei}: numeric {num} vs analytic {an}"
                );
            }
        }
    }

    #[test]
    fn grad_add_mul_broadcast() {
        gradcheck(&[&[2, 3, 4], &[4]], |t, v| t.add(v[0], v[1]), 1e-2);
        gradcheck(&[&[2, 3, 4], &[1, 1, 4]], |t, v| t.mul(v[0], v[1]), 1e-2);
        gradcheck(&[&[2, 4], &[2, 4]], |t, v| t.mul(v[0], v[1]), 1e-2);
    }

    #[test]
    fn grad_matmul_shared_weight() {
        gradcheck(&[&[2, 3, 4], &[4, 5]], |t, v| t.matmul(v[0], v[1], false), 1e-2);
        gradcheck(&[&[2, 3, 4], &[5, 4]], |t, v| t.matmul(v[0], v[1], true), 1e-2);
    }

    #[test]
    fn grad_matmul_batched() {
        gradcheck(&[&[2, 3, 4], &[2, 4, 5]], |t, v| t.matmul(v[0], v[1], false), 1e-2);
        gradcheck(&[&[2, 3, 4], &[2, 5, 4]], |t, v| t.matmul(v[0], v[1], true), 1e-2);
    }

    #[test]
    fn grad_activations() {
        for kind in [Act::Gelu, Act::Silu, Act::Relu] {
            gradcheck(&[&[3, 5]], |t, v| t.activation(v[0], kind), 2e-2);
        }
    }

    #[test]
    fn grad_softmax_norms() {
        gradcheck(&[&[3, 6]], |t, v| t.softmax_last(v[0]), 1e-2);
        gradcheck(&[&[3, 6], &[6], &[6]], |t, v| t.layernorm(v[0], v[1], v[2]), 2e-2);
        gradcheck(&[&[3, 6], &[6]], |t, v| t.rmsnorm(v[0], v[1]), 2e-2);
    }

    #[test]
    fn grad_structural_ops() {
        gradcheck(&[&[2, 3, 4]], |t, v| t.slice_first(v[0]), 1e-2);
        gradcheck(&[&[2, 3, 4]], |t, v| {
            let h = t.split_heads(v[0], 2);
            t.merge_heads(h)
        }, 1e-2);
        gradcheck(&[&[2, 3, 4]], |t, v| t.transpose2(v[0]), 1e-2);
        gradcheck(&[&[3, 4]], |t, v| t.sum_axis0(v[0]), 1e-2);
        gradcheck(&[&[3, 4]], |t, v| t.reshape(v[0], vec![4, 3]), 1e-2);
    }

    #[test]
    fn grad_rsqrt() {
        // keep inputs positive: square them first via mul
        gradcheck(&[&[2, 3]], |t, v| {
            let sq = t.mul(v[0], v[0]);
            t.rsqrt(sq, 1e-3)
        }, 2e-2);
    }

    #[test]
    fn grad_c3a_operator() {
        gradcheck(&[&[3, 8], &[2, 2, 4]], |t, v| t.c3a(v[0], v[1]), 1e-2);
        gradcheck(&[&[2, 2, 6], &[3, 2, 3]], |t, v| t.c3a(v[0], v[1]), 1e-2);
    }

    #[test]
    fn grad_block_rotate() {
        gradcheck(&[&[3, 8], &[2, 4, 4]], |t, v| t.block_rotate(v[0], v[1]), 1e-2);
    }

    #[test]
    fn grad_gather() {
        let mut rng = Rng::seed(7);
        let table = rand_arr(&mut rng, &[5, 3]);
        let ids = vec![1usize, 4, 1, 0];
        let mut tape = Tape::new();
        let tid = tape.leaf(table.clone(), true);
        let out = tape.gather(tid, &ids, &[2, 2]);
        assert_eq!(tape.val(out).shape, vec![2, 2, 3]);
        let seed = vec![1f32; 12];
        let grads = tape.backward(out, seed);
        let g = grads[tid].as_ref().unwrap();
        // row 1 gathered twice -> grad 2 per column; row 4 and 0 once; rows 2,3 zero
        assert_eq!(g[1 * 3], 2.0);
        assert_eq!(g[4 * 3], 1.0);
        assert_eq!(g[0], 1.0);
        assert_eq!(g[2 * 3], 0.0);
    }

    #[test]
    fn c3a_matches_block_circulant_matvec() {
        use crate::substrate::circulant::BlockCirculant;
        let mut rng = Rng::seed(11);
        let (m, n, b) = (2usize, 3usize, 8usize);
        let w = rand_arr(&mut rng, &[m, n, b]);
        let x = rand_arr(&mut rng, &[1, n * b]);
        let mut tape = Tape::new();
        let xv = tape.leaf(x.clone(), false);
        let wv = tape.leaf(w.clone(), false);
        let out = tape.c3a(xv, wv);
        let bc = BlockCirculant::new(m, n, b, w.data.iter().map(|&v| v as f64).collect());
        let want = bc.matvec(&x.data.iter().map(|&v| v as f64).collect::<Vec<_>>());
        for (got, want) in tape.val(out).data.iter().zip(want.iter()) {
            assert!((*got as f64 - want).abs() < 1e-4);
        }
    }

    /// In-place replay: refill the leaves, refresh the C3A spectra, and
    /// recompute every op node over the dirty buffers — every node value
    /// must be bit-identical to a freshly recorded tape over the new
    /// inputs (the plan subsystem's core invariant).
    #[test]
    fn replay_primitives_match_fresh_record() {
        let mut rng = Rng::seed(0xC0DE);
        let shapes: [&[usize]; 5] = [&[3, 8], &[2, 2, 4], &[8], &[8, 5], &[5]];
        let v0: Vec<Arr> = shapes.iter().map(|s| rand_arr(&mut rng, s)).collect();
        let v1: Vec<Arr> = shapes.iter().map(|s| rand_arr(&mut rng, s)).collect();
        let build = |t: &mut Tape, vals: &[Arr]| -> Vec<V> {
            let x = t.leaf(vals[0].clone(), false);
            let w = t.leaf(vals[1].clone(), true);
            let g = t.leaf(vals[2].clone(), true);
            let wo = t.leaf(vals[3].clone(), true);
            let bias = t.leaf(vals[4].clone(), false);
            let c = t.c3a(x, w);
            let sm = t.softmax_last(c);
            let h = t.rmsnorm(sm, g);
            let y = t.matmul(h, wo, false);
            let ys = t.scale(y, 0.5);
            let out = t.add(ys, bias);
            vec![x, w, g, wo, bias, out]
        };
        let mut tape = Tape::new();
        let ids = build(&mut tape, &v0);
        let out_id = *ids.last().unwrap();
        let node_shapes: Vec<Vec<usize>> =
            (0..tape.node_count()).map(|v| tape.val(v).shape.clone()).collect();
        for (leaf, arr) in ids[..5].iter().zip(v1.iter()) {
            tape.copy_into_leaf(*leaf, &arr.data);
        }
        for (op, w_leaf) in tape.c3a_nodes() {
            let w_arr = tape.val(w_leaf).clone();
            let spectra =
                Rc::new(C3aSpectra::compute(Rc::new(Plan::new(w_arr.shape[2])), &w_arr));
            tape.refresh_c3a_spectra(op, spectra);
        }
        for v in 0..tape.node_count() {
            if !tape.is_leaf(v) {
                tape.recompute(v, &node_shapes[v]);
            }
        }
        let mut fresh = Tape::new();
        let fids = build(&mut fresh, &v1);
        assert_eq!(tape.node_count(), fresh.node_count());
        for v in 0..tape.node_count() {
            assert_eq!(tape.val(v).data, fresh.val(v).data, "node {v} diverged on replay");
        }
        // take_val moves the output out; the next recompute reallocates
        let taken = tape.take_val(out_id);
        assert_eq!(taken.data, fresh.val(*fids.last().unwrap()).data);
        tape.recompute(out_id, &node_shapes[out_id]);
        assert_eq!(tape.val(out_id).data, taken.data);
    }

    #[test]
    fn needs_gating_skips_frozen_inputs() {
        let mut rng = Rng::seed(13);
        let a = rand_arr(&mut rng, &[2, 3]);
        let w = rand_arr(&mut rng, &[3, 4]);
        let mut tape = Tape::new();
        let av = tape.leaf(a, true);
        let wv = tape.leaf(w, false);
        let out = tape.matmul(av, wv, false);
        let grads = tape.backward(out, vec![1.0; 8]);
        assert!(grads[av].is_some());
        assert!(grads[wv].is_none());
    }
}
