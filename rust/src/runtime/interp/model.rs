//! Forward-graph builders for the substrate fallback backend — a faithful
//! Rust port of python/compile/model.py (encoder / decoder / mlp forward
//! passes with every PEFT parameterization attached to the q/v attention
//! projections, LoRA convention).
//!
//! Graphs are built on the [`Tape`](super::ad::Tape); the caller owns loss
//! heads and the optimizer.

use super::ad::{Act, Arr, C3aSpectra, LeafTag, Tape, V};
use super::InterpCache;
use crate::runtime::manifest::{ModelMeta, PeftParams};
use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;

/// Additive attention-mask penalty; shared with the plan replay's mask
/// recomputation (`runtime::plan`), which must reproduce the recorded
/// values bit-for-bit.
pub(crate) const NEG: f32 = -1e9;

/// Model inputs for one batch (exactly one of `tokens` / `x` per kind).
pub struct ModelInput {
    /// [b*s] token ids (tokens mode / decoder)
    pub tokens: Option<Vec<i32>>,
    /// [b,s,patch] patch vectors (vec mode) or [b,in] mlp features
    pub x: Option<Arr>,
    pub b: usize,
    pub s: usize,
}

/// Result of a forward pass.
pub struct Forward {
    /// cls/reg/vec: pooled logits [b,n_out]; lm: token logits [b,s,V];
    /// mlm: vocabulary logits [b,s,V]; mlp: class logits [b,n_out]
    pub logits: V,
}

pub struct Graph<'a> {
    pub tape: &'a mut Tape,
    pub params: &'a BTreeMap<String, V>,
    pub meta: &'a ModelMeta,
    pub peft: &'a PeftParams,
    /// session/executable cache for C3A kernel spectra + FFT plans
    /// (None in unit-test graphs; ops then compute spectra ad hoc)
    pub cache: Option<&'a RefCell<InterpCache>>,
}

impl<'a> Graph<'a> {
    fn p(&self, name: &str) -> Result<V> {
        self.params.get(name).copied().with_context(|| format!("missing parameter {name}"))
    }

    /// Cached (name-keyed, equality-verified) spectra of the C3A kernel
    /// node `w` — hits while the kernel is unchanged (every serve request;
    /// forward+backward within one train step).
    fn c3a_spectra(&mut self, name: &str, w: V) -> Option<Rc<C3aSpectra>> {
        let cache = self.cache?;
        Some(cache.borrow_mut().spectra_for(name, self.tape.val(w)))
    }

    /// y = x @ w0 (+ bias) + delta(x) for the adapted q/v projections.
    fn adapted_linear(&mut self, key: &str, x: V, w0: V, bias: Option<V>) -> Result<V> {
        let method = self.peft.method.clone();
        let mut y = if method == "dora" {
            // DoRA: magnitude * column-normalized (W0 + scale·(BA)^T)
            let a = self.p(&format!("{key}.lora.A"))?; // [r, d_in]
            let bmat = self.p(&format!("{key}.lora.B"))?; // [d_out, r]
            let scale = (self.peft.alpha / self.peft.rank.max(1) as f64) as f32;
            let ba = self.tape.matmul(bmat, a, false); // [d_out, d_in]
            let bat = self.tape.transpose2(ba); // [d_in, d_out]
            let delta = self.tape.scale(bat, scale);
            let w = self.tape.add(w0, delta); // [d_in, d_out]
            let w2 = self.tape.mul(w, w);
            let colsum = self.tape.sum_axis0(w2); // [d_out]
            let inv = self.tape.rsqrt(colsum, 1e-6);
            let wn = self.tape.mul(w, inv); // broadcast over rows
            let mag = self.p(&format!("{key}.dora.mag"))?;
            let wm = self.tape.mul(wn, mag);
            self.tape.matmul(x, wm, false)
        } else {
            let mut y = self.tape.matmul(x, w0, false);
            match method.as_str() {
                "lora" => {
                    let a = self.p(&format!("{key}.lora.A"))?; // [r, d_in]
                    let bmat = self.p(&format!("{key}.lora.B"))?; // [d_out, r]
                    let scale = (self.peft.alpha / self.peft.rank.max(1) as f64) as f32;
                    let xa = self.tape.matmul(x, a, true); // [.., r]
                    let xab = self.tape.matmul(xa, bmat, true); // [.., d_out]
                    let delta = self.tape.scale(xab, scale);
                    y = self.tape.add(y, delta);
                }
                "vera" => {
                    let a = self.p("vera.A")?; // [r_v, d_in] frozen
                    let bmat = self.p("vera.B")?; // [d_out, r_v] frozen
                    let ld = self.p(&format!("{key}.vera.ld"))?;
                    let lb = self.p(&format!("{key}.vera.lb"))?;
                    let xa = self.tape.matmul(x, a, true);
                    let xad = self.tape.mul(xa, ld);
                    let xb = self.tape.matmul(xad, bmat, true);
                    let delta = self.tape.mul(xb, lb);
                    y = self.tape.add(y, delta);
                }
                "boft" => {
                    // Orthogonal-ish rotation via truncated exp(skew), order 4
                    // (same substitution as the JAX side; identity at init).
                    let s = self.p(&format!("{key}.boft.skew"))?; // [nb,bb,bb]
                    let bb = self.tape.val(s).shape[2];
                    let st = self.tape.transpose2(s);
                    let diff = self.tape.sub(s, st);
                    let skew = self.tape.scale(diff, 0.5);
                    let s2 = self.tape.matmul(skew, skew, false);
                    let s3 = self.tape.matmul(s2, skew, false);
                    let s4 = self.tape.matmul(s2, s2, false);
                    let mut eye = Arr::zeros(vec![1, bb, bb]);
                    for i in 0..bb {
                        eye.data[i * bb + i] = 1.0;
                    }
                    let eye = self.tape.leaf_tagged(eye, false, LeafTag::Const);
                    let t2 = self.tape.scale(s2, 0.5);
                    let t3 = self.tape.scale(s3, 1.0 / 6.0);
                    let t4 = self.tape.scale(s4, 1.0 / 24.0);
                    let mut r = self.tape.add(eye, skew);
                    r = self.tape.add(r, t2);
                    r = self.tape.add(r, t3);
                    r = self.tape.add(r, t4);
                    y = self.tape.block_rotate(y, r);
                }
                "c3a" => {
                    let wname = format!("{key}.c3a.w");
                    let w = self.p(&wname)?;
                    let spectra = self.c3a_spectra(&wname, w);
                    let delta = self.tape.c3a_with(x, w, spectra);
                    y = self.tape.add(y, delta);
                }
                "full" | "head" | "bitfit" | "ia3" => {}
                other => bail!("unsupported PEFT method {other} in fallback backend"),
            }
            y
        };
        if let Some(b) = bias {
            y = self.tape.add(y, b);
        }
        Ok(y)
    }

    /// MHA with PEFT deltas on q/v (and IA3 rescales on k/v).
    fn attention(&mut self, i: usize, x: V, mask: V) -> Result<V> {
        let l = format!("L{i}");
        let enc = self.meta.kind != "decoder";
        let heads = self.meta.heads;
        let hd = self.meta.d / heads;
        let wq = self.p(&format!("{l}.attn.wq"))?;
        let wk = self.p(&format!("{l}.attn.wk"))?;
        let wv = self.p(&format!("{l}.attn.wv"))?;
        let wo = self.p(&format!("{l}.attn.wo"))?;
        let bias = |g: &Self, proj: &str| -> Result<Option<V>> {
            if enc {
                Ok(Some(g.p(&format!("{l}.attn.b{proj}"))?))
            } else {
                Ok(None)
            }
        };
        let bq = bias(self, "q")?;
        let bv = bias(self, "v")?;
        let q = self.adapted_linear(&format!("{l}.attn.q"), x, wq, bq)?;
        let mut k = self.tape.matmul(x, wk, false);
        if enc {
            let bk = self.p(&format!("{l}.attn.bk"))?;
            k = self.tape.add(k, bk);
        }
        let mut v = self.adapted_linear(&format!("{l}.attn.v"), x, wv, bv)?;
        if self.peft.method == "ia3" {
            let lk = self.p(&format!("{l}.ia3.lk"))?;
            let lv = self.p(&format!("{l}.ia3.lv"))?;
            k = self.tape.mul(k, lk);
            v = self.tape.mul(v, lv);
        }
        let qh = self.tape.split_heads(q, heads);
        let kh = self.tape.split_heads(k, heads);
        let vh = self.tape.split_heads(v, heads);
        let att = self.tape.matmul(qh, kh, true); // [B,H,S,S]
        let att = self.tape.scale(att, 1.0 / (hd as f32).sqrt());
        let att = self.tape.add(att, mask);
        let att = self.tape.softmax_last(att);
        let out = self.tape.matmul(att, vh, false); // [B,H,S,hd]
        let merged = self.tape.merge_heads(out);
        let mut o = self.tape.matmul(merged, wo, false);
        if enc {
            let bo = self.p(&format!("{l}.attn.bo"))?;
            o = self.tape.add(o, bo);
        }
        Ok(o)
    }

    fn ffn(&mut self, i: usize, x: V) -> Result<V> {
        let l = format!("L{i}");
        if self.meta.kind != "decoder" {
            let w1 = self.p(&format!("{l}.mlp.w1"))?;
            let b1 = self.p(&format!("{l}.mlp.b1"))?;
            let xw = self.tape.matmul(x, w1, false);
            let xb = self.tape.add(xw, b1);
            let mut h = self.tape.activation(xb, Act::Gelu);
            if self.peft.method == "ia3" {
                let lff = self.p(&format!("{l}.ia3.lff"))?;
                h = self.tape.mul(h, lff);
            }
            let w2 = self.p(&format!("{l}.mlp.w2"))?;
            let b2 = self.p(&format!("{l}.mlp.b2"))?;
            let hw = self.tape.matmul(h, w2, false);
            Ok(self.tape.add(hw, b2))
        } else {
            let wg = self.p(&format!("{l}.mlp.wg"))?;
            let wu = self.p(&format!("{l}.mlp.wu"))?;
            let wd = self.p(&format!("{l}.mlp.wd"))?;
            let xg = self.tape.matmul(x, wg, false);
            let g = self.tape.activation(xg, Act::Silu);
            let u = self.tape.matmul(x, wu, false);
            let mut h = self.tape.mul(g, u);
            if self.peft.method == "ia3" {
                let lff = self.p(&format!("{l}.ia3.lff"))?;
                h = self.tape.mul(h, lff);
            }
            Ok(self.tape.matmul(h, wd, false))
        }
    }

    /// Encoder (RoBERTa-sim / ViT-sim) forward.
    /// `voc_head`: emit vocabulary logits from the final hidden state
    /// (MLM pretraining) instead of the pooled classifier head.
    fn encoder_fwd(&mut self, input: &ModelInput, voc_head: bool) -> Result<Forward> {
        let (b, s) = (input.b, input.s);
        let mut pad = vec![false; b * s];
        let mut x = if self.meta.input_mode == "vec" {
            let xv = input.x.as_ref().context("vec-mode encoder needs data.x")?;
            let xleaf = self.tape.leaf_tagged(xv.clone(), false, LeafTag::DataX);
            let patch = self.p("embed.patch")?;
            self.tape.matmul(xleaf, patch, false)
        } else {
            let toks = input.tokens.as_ref().context("token encoder needs data.tokens")?;
            for (i, &t) in toks.iter().enumerate() {
                pad[i] = t == 0;
            }
            let ids: Vec<usize> = toks.iter().map(|&t| t.max(0) as usize).collect();
            let tok = self.p("embed.tok")?;
            self.tape.gather(tok, &ids, &[b, s])
        };
        let pos = self.p("embed.pos")?;
        x = self.tape.add(x, pos); // [S,d] broadcast over batch
        // attention mask [b,1,1,s]: -1e9 at pad keys.  Token-derived, so
        // a plan replay recomputes it; the vec mode has no tokens and the
        // all-zero mask is a recorded constant.
        let mut mask = Arr::zeros(vec![b, 1, 1, s]);
        for bi in 0..b {
            for si in 0..s {
                if pad[bi * s + si] {
                    mask.data[bi * s + si] = NEG;
                }
            }
        }
        let mask_tag =
            if self.meta.input_mode == "vec" { LeafTag::Const } else { LeafTag::MaskEncPad };
        let mask = self.tape.leaf_tagged(mask, false, mask_tag);
        for i in 0..self.meta.layers {
            let att = self.attention(i, x, mask)?;
            let res = self.tape.add(x, att);
            let g1 = self.p(&format!("L{i}.ln1.g"))?;
            let b1 = self.p(&format!("L{i}.ln1.b"))?;
            x = self.tape.layernorm(res, g1, b1);
            let ff = self.ffn(i, x)?;
            let res2 = self.tape.add(x, ff);
            let g2 = self.p(&format!("L{i}.ln2.g"))?;
            let b2 = self.p(&format!("L{i}.ln2.b"))?;
            x = self.tape.layernorm(res2, g2, b2);
        }
        let gf = self.p("final_ln.g")?;
        let bf = self.p("final_ln.b")?;
        x = self.tape.layernorm(x, gf, bf);
        let logits = if voc_head {
            let tok = self.p("embed.tok")?;
            self.tape.matmul(x, tok, true) // [b,s,V] tied
        } else {
            let pooled = self.tape.slice_first(x);
            let hw = self.p("head.w")?;
            let hb = self.p("head.b")?;
            let lw = self.tape.matmul(pooled, hw, false);
            self.tape.add(lw, hb)
        };
        Ok(Forward { logits })
    }

    /// Decoder (LLaMA-sim) forward: causal MHA, RMSNorm, SwiGLU, tied head.
    fn decoder_fwd(&mut self, input: &ModelInput) -> Result<Forward> {
        let (b, s) = (input.b, input.s);
        let toks = input.tokens.as_ref().context("decoder needs data.tokens")?;
        let ids: Vec<usize> = toks.iter().map(|&t| t.max(0) as usize).collect();
        let tok = self.p("embed.tok")?;
        let mut x = self.tape.gather(tok, &ids, &[b, s]);
        let pos = self.p("embed.pos")?;
        x = self.tape.add(x, pos);
        // mask [b,1,s,s]: causal upper triangle + pad keys
        let mut mask = Arr::zeros(vec![b, 1, s, s]);
        for bi in 0..b {
            for qi in 0..s {
                for ki in 0..s {
                    let mut v = 0f32;
                    if ki > qi {
                        v += NEG;
                    }
                    if toks[bi * s + ki] == 0 {
                        v += NEG;
                    }
                    mask.data[(bi * s + qi) * s + ki] = v;
                }
            }
        }
        let mask = self.tape.leaf_tagged(mask, false, LeafTag::MaskDecCausal);
        for i in 0..self.meta.layers {
            let g1 = self.p(&format!("L{i}.rms1.g"))?;
            let h = self.tape.rmsnorm(x, g1);
            let att = self.attention(i, h, mask)?;
            x = self.tape.add(x, att);
            let g2 = self.p(&format!("L{i}.rms2.g"))?;
            let h2 = self.tape.rmsnorm(x, g2);
            let ff = self.ffn(i, h2)?;
            x = self.tape.add(x, ff);
        }
        let gf = self.p("final_rms.g")?;
        x = self.tape.rmsnorm(x, gf);
        let logits = self.tape.matmul(x, tok, true); // [b,s,V] tied head
        Ok(Forward { logits })
    }

    /// Fig. 4 MLP: in -> h -> (middle op) -> h -> classes.
    fn mlp_fwd(&mut self, input: &ModelInput) -> Result<Forward> {
        let xv = input.x.as_ref().context("mlp needs data.x")?;
        let x = self.tape.leaf_tagged(xv.clone(), false, LeafTag::DataX);
        let w0 = self.p("mlp.w0")?;
        let b0 = self.p("mlp.b0")?;
        let xw = self.tape.matmul(x, w0, false);
        let xb = self.tape.add(xw, b0);
        let h = self.tape.activation(xb, Act::Relu);
        let mid = match self.peft.mlp_mid.as_str() {
            "dense" => {
                let w1 = self.p("mlp.w1")?;
                let b1 = self.p("mlp.b1")?;
                let hw = self.tape.matmul(h, w1, false);
                self.tape.add(hw, b1)
            }
            "lora" => {
                let a = self.p("mlp.mid.lora.A")?;
                let bmat = self.p("mlp.mid.lora.B")?;
                let ha = self.tape.matmul(h, a, true);
                self.tape.matmul(ha, bmat, true)
            }
            "c3a" => {
                let w = self.p("mlp.mid.c3a.w")?;
                let spectra = self.c3a_spectra("mlp.mid.c3a.w", w);
                self.tape.c3a_with(h, w, spectra)
            }
            other => bail!("unknown mlp_mid {other}"),
        };
        let h2 = self.tape.activation(mid, Act::Relu);
        let w2 = self.p("mlp.w2")?;
        let b2 = self.p("mlp.b2")?;
        let lw = self.tape.matmul(h2, w2, false);
        let logits = self.tape.add(lw, b2);
        Ok(Forward { logits })
    }

    /// Dispatch on (model kind, artifact head).
    pub fn forward(&mut self, head: &str, input: &ModelInput) -> Result<Forward> {
        match self.meta.kind.as_str() {
            "mlp" => self.mlp_fwd(input),
            "decoder" => self.decoder_fwd(input),
            _ => self.encoder_fwd(input, head == "mlm"),
        }
    }
}
