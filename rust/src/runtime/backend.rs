//! Execution backends — the seam between the coordinator and whatever
//! actually runs an artifact.
//!
//! Two implementations exist:
//!
//! * [`SubstrateBackend`] (default): interprets the artifact spec on CPU
//!   through the tape autodiff + FFT substrate.  Needs no HLO files, no
//!   python, no network — this is what makes tier-1 pass offline.
//! * [`PjrtBackend`] (`--features pjrt`): compiles the artifact's HLO text
//!   through the `xla` PJRT bindings.  With the in-tree shim those entry
//!   points report that real bindings must be vendored; the backend
//!   structure (and the session/coordinator code above it) is identical
//!   either way.

use super::interp::ad::Arr;
use super::interp::InterpExecutable;
use super::manifest::{ArtifactSpec, ModelMeta};
use anyhow::{Context, Result};
use std::rc::Rc;

/// Opaque per-session executor state — the seam that lets a backend
/// persist work across steps (parsed frozen params, kernel spectra, FFT
/// plans, and the recorded execution plan with its buffer arena).
/// Sessions create one via [`Executor::prepare`] and thread it through
/// every [`Executor::execute_stateful`] call.  Backends downcast to their
/// concrete state type; a state they don't recognize must degrade to
/// stateless execution, never to wrong results.
pub trait ExecutorState {
    fn as_any_mut(&mut self) -> &mut dyn std::any::Any;

    /// Stats of this state's recorded execution plan, if the backend
    /// records one (the substrate interpreter does after its first
    /// stateful call; stateless backends return None).
    fn plan_stats(&self) -> Option<crate::runtime::plan::PlanStats> {
        None
    }
}

/// Placeholder state for executors with nothing to persist (e.g. compiled
/// PJRT programs, which keep weights on device anyway).
pub struct NoState;

impl ExecutorState for NoState {
    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }
}

/// Opaque shared frozen-backbone parse.  Produced by
/// [`Executor::parse_frozen`] and consumed by [`Executor::prepare_shared`]
/// so many sessions of one executable (multi-adapter serving: one tenant
/// state each) can sit on a single parse of the frozen parameters.  A
/// backend that does not recognize a handle must fall back to a private
/// parse, never to wrong results.
pub struct FrozenHandle(pub Rc<dyn std::any::Any>);

/// A loaded artifact, ready to execute on host literals.
pub trait Executor {
    /// Execute with positional inputs; returns the flattened outputs.
    fn execute(&self, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>>;

    /// Build per-session state from the session's frozen parameters (in
    /// the artifact's `frozen_order`).  Default: nothing to persist.
    fn prepare(&self, _frozen: &[xla::Literal]) -> Result<Box<dyn ExecutorState>> {
        Ok(Box::new(NoState))
    }

    /// Parse the frozen parameters once for sharing across many sessions
    /// (multi-adapter serving).  Default: nothing backend-side to share.
    fn parse_frozen(&self, _frozen: &[xla::Literal]) -> Result<FrozenHandle> {
        Ok(FrozenHandle(Rc::new(())))
    }

    /// Build per-session state over a shared frozen parse.  `frozen` is
    /// still the full literal list so a backend that does not recognize
    /// the handle can fall back to [`Executor::prepare`].
    fn prepare_shared(
        &self,
        frozen: &[xla::Literal],
        _parse: &FrozenHandle,
    ) -> Result<Box<dyn ExecutorState>> {
        self.prepare(frozen)
    }

    /// Execute with session state.  `inputs` is the *full* positional
    /// list (the PJRT contract is unchanged); stateful backends may skip
    /// re-reading inputs their state already covers.  Must return exactly
    /// what [`Executor::execute`] would.
    fn execute_stateful(
        &self,
        _state: &mut dyn ExecutorState,
        inputs: &[&xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        self.execute(inputs)
    }

    /// Buffer-path execution.  Contract: returns the executable's output
    /// buffers as PJRT hands them back — for this repo's artifacts
    /// (lowered with `return_tuple=True`, see aot.py) that is a single
    /// tuple buffer, which callers unpack via `to_literal_sync().to_tuple()`.
    /// The default round-trips through host literals (correct for the
    /// host-resident fallback backend); HLO executors override it to keep
    /// outputs on device.
    fn execute_b(&self, inputs: &[xla::PjRtBuffer]) -> Result<Vec<xla::PjRtBuffer>> {
        let lits: Vec<xla::Literal> =
            inputs.iter().map(|b| b.to_literal_sync()).collect::<Result<_>>()?;
        let refs: Vec<&xla::Literal> = lits.iter().collect();
        let outs = self.execute(&refs)?;
        Ok(vec![xla::PjRtBuffer::from_literal(xla::Literal::tuple(outs))])
    }
}

/// Loads artifact specs into executors.
pub trait Backend {
    fn name(&self) -> &'static str;
    fn load(&self, spec: &ArtifactSpec, meta: &ModelMeta) -> Result<Box<dyn Executor>>;
}

// ---------------------------------------------------------------------------
// Substrate (pure-Rust) backend
// ---------------------------------------------------------------------------

pub struct SubstrateBackend;

impl Backend for SubstrateBackend {
    fn name(&self) -> &'static str {
        "substrate"
    }

    fn load(&self, spec: &ArtifactSpec, meta: &ModelMeta) -> Result<Box<dyn Executor>> {
        Ok(Box::new(InterpExecutable::new(spec, meta)?))
    }
}

impl Executor for InterpExecutable {
    fn execute(&self, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        InterpExecutable::execute(self, inputs)
    }

    fn prepare(&self, frozen: &[xla::Literal]) -> Result<Box<dyn ExecutorState>> {
        Ok(Box::new(InterpExecutable::prepare(self, frozen)?))
    }

    fn parse_frozen(&self, frozen: &[xla::Literal]) -> Result<FrozenHandle> {
        Ok(FrozenHandle(InterpExecutable::parse_frozen(self, frozen)?))
    }

    fn prepare_shared(
        &self,
        frozen: &[xla::Literal],
        parse: &FrozenHandle,
    ) -> Result<Box<dyn ExecutorState>> {
        match parse.0.clone().downcast::<Vec<(String, Rc<Arr>)>>() {
            Ok(p) => Ok(Box::new(InterpExecutable::prepare_from(self, p)?)),
            // foreign handle (e.g. after a backend swap): parse privately
            Err(_) => Ok(Box::new(InterpExecutable::prepare(self, frozen)?)),
        }
    }

    fn execute_stateful(
        &self,
        state: &mut dyn ExecutorState,
        inputs: &[&xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        match state.as_any_mut().downcast_mut::<super::interp::InterpState>() {
            Some(s) => InterpExecutable::execute_stateful(self, s, inputs),
            // unknown state (e.g. NoState after a backend swap): stay correct
            None => InterpExecutable::execute(self, inputs),
        }
    }
}

// ---------------------------------------------------------------------------
// PJRT (compiled HLO) backend
// ---------------------------------------------------------------------------

/// Executes a compiled HLO module through the `xla` crate.  Also used by
/// `Engine::load_hlo_text` for ad-hoc HLO files.
pub struct HloExecutor {
    pub exe: xla::PjRtLoadedExecutable,
}

impl Executor for HloExecutor {
    fn execute(&self, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let mut out = self.exe.execute(inputs)?;
        let first = out
            .pop()
            .and_then(|mut v| if v.is_empty() { None } else { Some(v.remove(0)) })
            .context("executable returned no outputs")?;
        let lit = first.to_literal_sync()?;
        lit.to_tuple()
    }

    /// Keep outputs on device (the PJRT keep-on-device semantics).
    fn execute_b(&self, inputs: &[xla::PjRtBuffer]) -> Result<Vec<xla::PjRtBuffer>> {
        let mut out = self.exe.execute_b(inputs)?;
        out.pop().context("no outputs")
    }
}

#[cfg(feature = "pjrt")]
pub struct PjrtBackend {
    client: xla::PjRtClient,
}

#[cfg(feature = "pjrt")]
impl PjrtBackend {
    pub fn new(client: xla::PjRtClient) -> PjrtBackend {
        PjrtBackend { client }
    }
}

#[cfg(feature = "pjrt")]
impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn load(&self, spec: &ArtifactSpec, _meta: &ModelMeta) -> Result<Box<dyn Executor>> {
        let path = spec.path.to_str().context("non-utf8 artifact path")?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", spec.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", spec.path.display()))?;
        Ok(Box::new(HloExecutor { exe }))
    }
}
