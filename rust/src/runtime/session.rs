//! Training / evaluation sessions: bind a compiled artifact to live
//! parameter state and drive PJRT execution.
//!
//! A `TrainSession` owns the trainable parameters + AdamW state as XLA
//! literals, rebuilt from each step's tuple output; frozen backbone
//! parameters are uploaded once into a persistent
//! [`ExecutorState`](super::backend::ExecutorState), so stateful backends
//! (the substrate interpreter) never re-parse them per step.  An
//! `EvalSession` borrows the trainable state to produce logits for the
//! rust-side metric computation; repeated calls with an unchanged
//! trainable snapshot (the serving hot path) reuse the uploaded literals.

use super::backend::{ExecutorState, FrozenHandle};
use super::manifest::{ArtifactSpec, Role};
use super::Engine;
use crate::peft::init::C3aScheme;
use crate::substrate::prng::Rng;
use crate::substrate::tensor::{DType, Tensor, TensorMap};
use anyhow::{bail, Context, Result};
use std::cell::{Cell, RefCell};

/// Convert a host tensor to an XLA literal.
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let lit = match t.dtype {
        DType::F32 => {
            let v = t.as_f32();
            if t.shape.is_empty() {
                xla::Literal::scalar(v[0])
            } else {
                xla::Literal::vec1(&v).reshape(&t.dims_i64())?
            }
        }
        DType::I32 => {
            let v = t.as_i32();
            if t.shape.is_empty() {
                xla::Literal::scalar(v[0])
            } else {
                xla::Literal::vec1(&v).reshape(&t.dims_i64())?
            }
        }
    };
    Ok(lit)
}

/// Convert a literal back to a host tensor (f32 only — parameter state).
pub fn literal_to_tensor(lit: &xla::Literal, shape: &[usize]) -> Result<Tensor> {
    let v = lit.to_vec::<f32>()?;
    Ok(Tensor::from_f32(shape.to_vec(), &v))
}

/// Materialized initial state for one artifact.
pub struct SessionInit {
    /// name -> tensor for trainable params
    pub trainable: TensorMap,
    /// name -> tensor for frozen params (backbone + frozen_random)
    pub frozen: TensorMap,
}

/// Build initial state: frozen params from the pretrained checkpoint (or
/// init bin), trainables from manifest init specs (or a warm checkpoint).
pub fn build_init(
    spec: &ArtifactSpec,
    pretrained: &TensorMap,
    warm_trainable: Option<&TensorMap>,
    rng: &mut Rng,
    scheme: C3aScheme,
) -> Result<SessionInit> {
    let mut trainable = TensorMap::new();
    let mut frozen = TensorMap::new();
    for inp in &spec.inputs {
        match inp.role {
            Role::Trainable => {
                let t = if let Some(w) = warm_trainable.and_then(|m| m.get(&inp.name)) {
                    w.clone()
                } else if let Some(p) = pretrained.get(&inp.name) {
                    // e.g. `full` fine-tuning or the always-trainable head
                    p.clone()
                } else {
                    let init = inp
                        .init
                        .as_ref()
                        .with_context(|| format!("no init for {}", inp.name))?;
                    init.materialize(&inp.shape, rng, scheme)
                };
                if t.shape != inp.shape {
                    bail!("{}: shape {:?} != manifest {:?}", inp.name, t.shape, inp.shape);
                }
                trainable.insert(inp.name.clone(), t);
            }
            Role::Frozen | Role::FrozenRandom => {
                let t = if let Some(p) = pretrained.get(&inp.name) {
                    p.clone()
                } else {
                    let init = inp
                        .init
                        .as_ref()
                        .with_context(|| format!("no init for {}", inp.name))?;
                    init.materialize(&inp.shape, rng, scheme)
                };
                frozen.insert(inp.name.clone(), t);
            }
            _ => {}
        }
    }
    Ok(SessionInit { trainable, frozen })
}

/// One batch of data inputs, in the artifact's `data_order`.
pub type Batch = Vec<Tensor>;

pub struct TrainSession {
    spec: ArtifactSpec,
    exe: std::rc::Rc<super::Executable>,
    /// literals for trainable params (manifest order)
    t_state: Vec<xla::Literal>,
    /// AdamW first/second moments
    m_state: Vec<xla::Literal>,
    v_state: Vec<xla::Literal>,
    /// frozen params, uploaded once (manifest order)
    f_state: Vec<xla::Literal>,
    /// trainable shapes for checkpoint extraction
    t_shapes: Vec<Vec<usize>>,
    /// persistent executor state (parsed frozen params, spectra/plan
    /// caches) — lives as long as the session
    exec_state: RefCell<Box<dyn ExecutorState>>,
    pub steps_done: usize,
}

impl TrainSession {
    pub fn new(engine: &Engine, spec: &ArtifactSpec, init: &SessionInit) -> Result<TrainSession> {
        if spec.kind != "train" {
            bail!("{} is not a train artifact", spec.name);
        }
        let exe = engine.load_cached(spec)?;
        let mut t_state = Vec::new();
        let mut t_shapes = Vec::new();
        for name in &spec.trainable_order {
            let t = init.trainable.get(name).with_context(|| format!("missing trainable {name}"))?;
            t_shapes.push(t.shape.clone());
            t_state.push(tensor_to_literal(t)?);
        }
        let zeros = |shapes: &[Vec<usize>]| -> Result<Vec<xla::Literal>> {
            shapes.iter().map(|s| tensor_to_literal(&Tensor::zeros_f32(s.clone()))).collect()
        };
        let m_state = zeros(&t_shapes)?;
        let v_state = zeros(&t_shapes)?;
        let mut f_state = Vec::new();
        for name in &spec.frozen_order {
            let t = init.frozen.get(name).with_context(|| format!("missing frozen {name}"))?;
            f_state.push(tensor_to_literal(t)?);
        }
        let exec_state = RefCell::new(exe.prepare(&f_state)?);
        Ok(TrainSession {
            spec: spec.clone(),
            exe,
            t_state,
            m_state,
            v_state,
            f_state,
            t_shapes,
            exec_state,
            steps_done: 0,
        })
    }

    pub fn spec(&self) -> &ArtifactSpec {
        &self.spec
    }

    /// Execute one optimizer step; returns (loss, metric numerator).
    pub fn step(&mut self, batch: &Batch, lr: f32, wd: f32) -> Result<(f32, f32)> {
        if batch.len() != self.spec.data_order.len() {
            bail!("batch arity {} != {}", batch.len(), self.spec.data_order.len());
        }
        let data_lits: Vec<xla::Literal> =
            batch.iter().map(tensor_to_literal).collect::<Result<_>>()?;
        // scalar inputs are manifest-driven: `wd` is absent from artifacts
        // whose trainables are all decay-exempt (XLA DCE; see aot.py).
        // Unknown scalar names mean manifest drift — fail loudly instead of
        // silently binding them to `wd` and corrupting training.
        let mut scalar_lits: Vec<xla::Literal> = Vec::new();
        for i in self.spec.inputs.iter().filter(|i| i.role == Role::Scalar) {
            let value = match i.name.as_str() {
                "step" => (self.steps_done + 1) as f32,
                "lr" => lr,
                "wd" => wd,
                other => bail!("{}: unknown scalar input {other} (manifest drift)", self.spec.name),
            };
            scalar_lits.push(xla::Literal::scalar(value));
        }

        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(
            3 * self.t_state.len() + self.f_state.len() + data_lits.len() + 3,
        );
        inputs.extend(self.t_state.iter());
        inputs.extend(self.m_state.iter());
        inputs.extend(self.v_state.iter());
        inputs.extend(self.f_state.iter());
        inputs.extend(data_lits.iter());
        inputs.extend(scalar_lits.iter());

        let mut outs = {
            let mut state = self.exec_state.borrow_mut();
            self.exe.run_stateful(&mut **state, &inputs)?
        };
        let nt = self.t_state.len();
        if outs.len() != 3 * nt + 2 {
            bail!("{}: expected {} outputs, got {}", self.spec.name, 3 * nt + 2, outs.len());
        }
        let metric = outs.pop().unwrap().get_first_element::<f32>()?;
        let loss = outs.pop().unwrap().get_first_element::<f32>()?;
        self.v_state = outs.split_off(2 * nt);
        self.m_state = outs.split_off(nt);
        self.t_state = outs;
        self.steps_done += 1;
        Ok((loss, metric))
    }

    /// Snapshot the trainable parameters (checkpoint / merge / eval).
    pub fn trainable_tensors(&self) -> Result<TensorMap> {
        let mut out = TensorMap::new();
        for ((name, lit), shape) in
            self.spec.trainable_order.iter().zip(&self.t_state).zip(&self.t_shapes)
        {
            out.insert(name.clone(), literal_to_tensor(lit, shape)?);
        }
        Ok(out)
    }

    /// Overwrite trainable state (restore from checkpoint).
    pub fn load_trainable(&mut self, t: &TensorMap) -> Result<()> {
        for (i, name) in self.spec.trainable_order.clone().iter().enumerate() {
            let ten = t.get(name).with_context(|| format!("missing {name}"))?;
            self.t_state[i] = tensor_to_literal(ten)?;
        }
        Ok(())
    }

    /// Stats of this session's recorded execution plan (None before the
    /// first step, on non-plan backends, or under `C3A_PLAN=0`).
    pub fn plan_stats(&self) -> Option<crate::runtime::plan::PlanStats> {
        self.exec_state.borrow().plan_stats()
    }
}

/// Cached upload of one trainable snapshot (the serving hot path calls
/// `logits` many times with the same adapter).
struct TrainableUpload {
    /// exact tensors the literals were built from (bitwise identity check)
    snapshot: Vec<Tensor>,
    lits: Vec<xla::Literal>,
}

/// A frozen backbone uploaded and parsed **once**, shareable by many
/// [`EvalSession`]s — the multi-adapter serving substrate.  Every session
/// built via [`SharedBackbone::session`] reuses the same frozen literals
/// and (on stateful backends) the same parsed arrays; only the per-session
/// caches (kernel spectra, trainable uploads) stay private per tenant.
///
/// Sharing is deliberately `Rc`, not `Arc`: a backbone and all of its
/// sessions are affine to one thread.  The sharded serving runtime
/// (`serving::Scheduler`) therefore builds **one backbone parse per
/// shard worker**, each on its own thread, with tenants partitioned
/// across shards by name hash — N shards cost N frozen parses and in
/// exchange never need a `Send`/`Sync` bound (or a lock) anywhere in the
/// session layer.
pub struct SharedBackbone {
    spec: ArtifactSpec,
    exe: std::rc::Rc<super::Executable>,
    f_state: std::rc::Rc<Vec<xla::Literal>>,
    parse: FrozenHandle,
}

impl SharedBackbone {
    pub fn new(engine: &Engine, spec: &ArtifactSpec, init: &SessionInit) -> Result<SharedBackbone> {
        if spec.kind != "eval" {
            bail!("{} is not an eval artifact", spec.name);
        }
        let exe = engine.load_cached(spec)?;
        let mut f_state = Vec::new();
        for name in &spec.frozen_order {
            let t = init.frozen.get(name).with_context(|| format!("missing frozen {name}"))?;
            f_state.push(tensor_to_literal(t)?);
        }
        let parse = exe.parse_frozen(&f_state)?;
        Ok(SharedBackbone {
            spec: spec.clone(),
            exe,
            f_state: std::rc::Rc::new(f_state),
            parse,
        })
    }

    pub fn spec(&self) -> &ArtifactSpec {
        &self.spec
    }

    /// Build one session (one tenant) over this backbone.
    pub fn session(&self) -> Result<EvalSession> {
        let exec_state = RefCell::new(self.exe.prepare_shared(&self.f_state, &self.parse)?);
        Ok(EvalSession {
            spec: self.spec.clone(),
            exe: self.exe.clone(),
            f_state: self.f_state.clone(),
            exec_state,
            t_upload: RefCell::new(None),
            uploads: Cell::new(0),
        })
    }

    /// Live references to the shared frozen-literal upload (this backbone
    /// included): `n_sessions + 1` when every session came from here.
    pub fn session_refs(&self) -> usize {
        std::rc::Rc::strong_count(&self.f_state)
    }

    /// Executor states sharing the frozen *parse* (the handle included).
    /// On the substrate backend this is `n_sessions + 1`; stateless
    /// backends have nothing to share and report 1.
    pub fn parse_refs(&self) -> usize {
        std::rc::Rc::strong_count(&self.parse.0)
    }
}

pub struct EvalSession {
    spec: ArtifactSpec,
    exe: std::rc::Rc<super::Executable>,
    /// frozen literals, possibly shared with sibling sessions
    f_state: std::rc::Rc<Vec<xla::Literal>>,
    /// persistent executor state (parsed frozen params, spectra caches)
    exec_state: RefCell<Box<dyn ExecutorState>>,
    t_upload: RefCell<Option<TrainableUpload>>,
    uploads: Cell<usize>,
}

impl EvalSession {
    pub fn new(engine: &Engine, spec: &ArtifactSpec, init: &SessionInit) -> Result<EvalSession> {
        SharedBackbone::new(engine, spec, init)?.session()
    }

    pub fn spec(&self) -> &ArtifactSpec {
        &self.spec
    }

    /// Per-session spectra-cache accounting (`None` when the executor
    /// state is not the substrate interpreter's).
    pub fn cache_stats(&self) -> Option<crate::runtime::interp::CacheStats> {
        let mut state = self.exec_state.borrow_mut();
        state
            .as_any_mut()
            .downcast_mut::<crate::runtime::interp::InterpState>()
            .map(|s| s.cache_stats())
    }

    /// Distinct kernels in this session's private spectra cache (`None`
    /// for non-interpreter backends).
    pub fn spectra_entries(&self) -> Option<usize> {
        let mut state = self.exec_state.borrow_mut();
        state
            .as_any_mut()
            .downcast_mut::<crate::runtime::interp::InterpState>()
            .map(|s| s.spectra_entries())
    }

    /// How many times a trainable snapshot has been converted to literals
    /// (serving loops with a fixed adapter should see exactly 1).
    pub fn upload_count(&self) -> usize {
        self.uploads.get()
    }

    /// Forward pass: returns flattened logits + their shape.  The
    /// trainable upload is reused across calls while the snapshot is
    /// bit-identical to the previous one.
    pub fn logits(&self, trainable: &TensorMap, batch: &Batch) -> Result<(Vec<f32>, Vec<usize>)> {
        {
            let mut upload = self.t_upload.borrow_mut();
            let reusable = match upload.as_ref() {
                Some(u) => {
                    u.snapshot.len() == self.spec.trainable_order.len()
                        && self
                            .spec
                            .trainable_order
                            .iter()
                            .zip(&u.snapshot)
                            .all(|(name, prev)| trainable.get(name) == Some(prev))
                }
                None => false,
            };
            if !reusable {
                let mut snapshot = Vec::with_capacity(self.spec.trainable_order.len());
                let mut lits = Vec::with_capacity(self.spec.trainable_order.len());
                for name in &self.spec.trainable_order {
                    let t = trainable
                        .get(name)
                        .with_context(|| format!("missing trainable {name}"))?;
                    snapshot.push(t.clone());
                    lits.push(tensor_to_literal(t)?);
                }
                *upload = Some(TrainableUpload { snapshot, lits });
                self.uploads.set(self.uploads.get() + 1);
            }
        }
        let data_lits: Vec<xla::Literal> =
            batch.iter().map(tensor_to_literal).collect::<Result<_>>()?;
        let upload = self.t_upload.borrow();
        let t_lits = &upload.as_ref().expect("trainable upload present").lits;
        let mut inputs: Vec<&xla::Literal> = Vec::new();
        inputs.extend(t_lits.iter());
        inputs.extend(self.f_state.iter());
        inputs.extend(data_lits.iter());
        let mut outs = {
            let mut state = self.exec_state.borrow_mut();
            self.exe.run_stateful(&mut **state, &inputs)?
        };
        if outs.len() != 1 {
            bail!("eval artifact returned {} outputs", outs.len());
        }
        let lit = outs.pop().unwrap();
        let shape: Vec<usize> = lit.array_shape()?.dims().iter().map(|&d| d as usize).collect();
        // move the logits payload out of the literal — with the plan
        // replay path this buffer travelled arena -> literal -> caller
        // without a single full-size copy
        Ok((lit.into_vec_f32()?, shape))
    }

    /// Stats of this session's recorded execution plan (None before the
    /// first `logits` call, on non-plan backends, or under `C3A_PLAN=0`).
    pub fn plan_stats(&self) -> Option<crate::runtime::plan::PlanStats> {
        self.exec_state.borrow().plan_stats()
    }

    /// Cheap estimate of this session's private memory: the plan arena
    /// (`plan_stats().arena_bytes`, 0 before the first request or with
    /// plans off) plus the cached trainable-upload literals.  Shared
    /// state — the frozen literals and the backbone parse — is excluded:
    /// it does not release on eviction.  Drives the serving layer's
    /// `ResidentPolicy::bytes_budget`.
    pub fn resident_bytes(&self) -> usize {
        let arena = self.plan_stats().map(|p| p.arena_bytes).unwrap_or(0);
        let upload = self
            .t_upload
            .borrow()
            .as_ref()
            .map(|u| u.lits.iter().map(|l| l.element_count() * 4).sum::<usize>())
            .unwrap_or(0);
        arena + upload
    }
}
