//! Runtime: load artifacts, bind them to an execution backend, execute.
//!
//! The coordinator talks to [`Engine`] / [`Executable`] only; which
//! backend runs underneath is a build-time choice:
//!
//! * default — [`backend::SubstrateBackend`], the pure-Rust interpreter
//!   over the FFT/circulant substrate (fully offline, no HLO needed);
//! * `--features pjrt` — [`backend::PjrtBackend`], compiled XLA programs
//!   through PJRT (requires vendored real `xla` bindings).
//!
//! Python/JAX runs only at build time (`make artifacts`) and only for the
//! PJRT path; the substrate path synthesizes the same artifact manifest in
//! Rust (see [`catalog`]).

use anyhow::{Context, Result};
use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap};
use std::path::Path;
use std::rc::Rc;

pub mod backend;
pub mod catalog;
pub mod interp;
pub mod manifest;
pub mod plan;
pub mod refbackend;
pub mod session;

use backend::{Backend, Executor, ExecutorState};
use manifest::{ArtifactSpec, Manifest, ModelMeta};

/// An execution backend plus the model registry and a compile/load cache
/// (experiments reuse artifacts heavily).
pub struct Engine {
    client: xla::PjRtClient,
    backend: Box<dyn Backend>,
    models: BTreeMap<String, ModelMeta>,
    /// load cache: artifact name -> loaded executable
    cache: RefCell<HashMap<String, Rc<Executable>>>,
}

impl Engine {
    /// Create a CPU engine with an empty model registry (ad-hoc HLO use).
    pub fn cpu() -> Result<Self> {
        Self::with_models(BTreeMap::new())
    }

    /// Create a CPU engine bound to a manifest's model registry — the
    /// normal construction path (`Ctx::open`).
    pub fn for_manifest(manifest: &Manifest) -> Result<Self> {
        Self::with_models(manifest.models.clone())
    }

    /// Same, but over an explicit backend — the differential-testing hook
    /// (e.g. the naive [`refbackend::RefBackend`] oracle), and the runtime
    /// seam future PJRT bindings plug into for side-by-side cross-checks.
    pub fn for_manifest_with_backend(
        manifest: &Manifest,
        backend: Box<dyn Backend>,
    ) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self::assemble(manifest.models.clone(), backend, client))
    }

    fn with_models(models: BTreeMap<String, ModelMeta>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        #[cfg(feature = "pjrt")]
        let backend: Box<dyn Backend> = Box::new(backend::PjrtBackend::new(client.clone()));
        #[cfg(not(feature = "pjrt"))]
        let backend: Box<dyn Backend> = Box::new(backend::SubstrateBackend);
        Ok(Self::assemble(models, backend, client))
    }

    fn assemble(
        models: BTreeMap<String, ModelMeta>,
        backend: Box<dyn Backend>,
        client: xla::PjRtClient,
    ) -> Engine {
        Engine { client, backend, models, cache: RefCell::new(HashMap::new()) }
    }

    /// Which backend executes artifacts ("substrate" or "pjrt").
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Load an artifact with caching, keyed by artifact name.
    pub fn load_cached(&self, spec: &ArtifactSpec) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(&spec.name) {
            return Ok(e.clone());
        }
        let meta = self
            .models
            .get(&spec.model)
            .with_context(|| format!("model {} not in engine registry", spec.model))?;
        let exec = self.backend.load(spec, meta)?;
        let exe = Rc::new(Executable { exec });
        self.cache.borrow_mut().insert(spec.name.clone(), exe.clone());
        Ok(exe)
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Load an ad-hoc HLO-text file and compile it through PJRT.  Only
    /// meaningful with vendored real bindings; the shim reports a
    /// descriptive error otherwise.
    pub fn load_hlo_text<P: AsRef<Path>>(&self, path: P) -> Result<Executable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { exec: Box::new(backend::HloExecutor { exe }) })
    }
}

/// A loaded, executable artifact (backend-agnostic).
pub struct Executable {
    exec: Box<dyn Executor>,
}

impl Executable {
    /// Execute with host literals; returns the flattened tuple outputs.
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        let refs: Vec<&xla::Literal> = inputs.iter().map(|l| l.borrow()).collect();
        self.exec.execute(&refs)
    }

    /// Execute with device buffers.  On the fallback backend this
    /// round-trips through host literals; HLO executors keep outputs on
    /// device.
    pub fn run_b(&self, inputs: &[xla::PjRtBuffer]) -> Result<Vec<xla::PjRtBuffer>> {
        self.exec.execute_b(inputs)
    }

    /// Build per-session executor state from the session's frozen params
    /// (in `frozen_order`).  Stateless backends return a no-op handle.
    pub fn prepare(&self, frozen: &[xla::Literal]) -> Result<Box<dyn ExecutorState>> {
        self.exec.prepare(frozen)
    }

    /// Parse the frozen params once for sharing across many sessions of
    /// this executable (multi-adapter serving).  Pair with
    /// [`Executable::prepare_shared`].
    pub fn parse_frozen(&self, frozen: &[xla::Literal]) -> Result<backend::FrozenHandle> {
        self.exec.parse_frozen(frozen)
    }

    /// Build per-session executor state over a shared frozen parse.
    pub fn prepare_shared(
        &self,
        frozen: &[xla::Literal],
        parse: &backend::FrozenHandle,
    ) -> Result<Box<dyn ExecutorState>> {
        self.exec.prepare_shared(frozen, parse)
    }

    /// Execute with session state (same outputs as [`Executable::run`];
    /// stateful backends skip re-reading state-covered inputs).
    pub fn run_stateful<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        state: &mut dyn ExecutorState,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        let refs: Vec<&xla::Literal> = inputs.iter().map(|l| l.borrow()).collect();
        self.exec.execute_stateful(state, &refs)
    }
}
