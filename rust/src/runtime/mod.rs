//! PJRT runtime: load AOT HLO-text artifacts, compile, execute.
//!
//! Python/JAX runs only at build time (`make artifacts`); this module is the
//! only bridge between the rust coordinator and the compiled XLA programs.

use anyhow::{Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

pub mod manifest;
pub mod session;

/// A compiled XLA program plus its PJRT client.
pub struct Engine {
    client: xla::PjRtClient,
    /// compile cache: artifact path -> loaded executable
    cache: RefCell<HashMap<String, Rc<Executable>>>,
}

impl Engine {
    /// Create a CPU PJRT engine.
    pub fn cpu() -> Result<Self> {
        Ok(Self {
            client: xla::PjRtClient::cpu().context("creating PJRT CPU client")?,
            cache: RefCell::new(HashMap::new()),
        })
    }

    /// Load + compile with caching (experiments reuse artifacts heavily;
    /// PJRT compilation costs seconds per artifact).
    pub fn load_cached<P: AsRef<Path>>(&self, path: P) -> Result<Rc<Executable>> {
        let key = path.as_ref().to_string_lossy().into_owned();
        if let Some(e) = self.cache.borrow().get(&key) {
            return Ok(e.clone());
        }
        let exe = Rc::new(self.load_hlo_text(path)?);
        self.cache.borrow_mut().insert(key, exe.clone());
        Ok(exe)
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Load an HLO-text artifact and compile it.
    pub fn load_hlo_text<P: AsRef<Path>>(&self, path: P) -> Result<Executable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { exe })
    }
}

/// A compiled, loaded executable.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with host literals; returns the flattened tuple outputs.
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(&self, inputs: &[L]) -> Result<Vec<xla::Literal>> {
        let mut out = self.exe.execute::<L>(inputs)?;
        let first = out
            .pop()
            .and_then(|mut v| if v.is_empty() { None } else { Some(v.remove(0)) })
            .context("executable returned no outputs")?;
        let lit = first.to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// Execute with device buffers, keeping outputs on device.
    pub fn run_b(&self, inputs: &[xla::PjRtBuffer]) -> Result<Vec<xla::PjRtBuffer>> {
        let mut out = self.exe.execute_b::<xla::PjRtBuffer>(inputs)?;
        Ok(out.pop().context("no outputs")?)
    }
}
