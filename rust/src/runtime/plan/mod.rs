//! Replayable execution plans — the record-once / replay-many seam that
//! turns the substrate interpreter's steady state from a rebuild into a
//! replay.
//!
//! The first stateful call on an artifact records the whole step through
//! the eager [`Tape`] exactly as before; the finished tape — already a
//! flat, topologically ordered op list with static shapes — is then
//! promoted into a [`Plan`]: every leaf is classified (trainable /
//! frozen-parse / data / token-derived mask / constant), liveness is
//! analysed over the op list, and eval plans get an arena slot assignment
//! so dead buffers are recycled into later same-size nodes.  Subsequent
//! calls *replay*: leaves are refilled from the input literals, C3A
//! spectra are refreshed through the session cache (equality-invalidated,
//! so training steps stay correct), and every op recomputes in place into
//! its preallocated buffer through the same `eval_op` kernels the
//! recording used — bit-for-bit identity with the legacy rebuild path is
//! structural, not incidental.
//!
//! **Version-invariant prefix hoisting:** eval plans additionally
//! classify every op by whether its transitive leaf set contains a
//! request-varying leaf (`DataX`, token-derived masks, token-gather
//! ids).  Ops fed only by `Input` + `Const` leaves depend on the
//! (frozen backbone, adapter version) pair, not on the request — DoRA's
//! normalized-weight chain, BOFT's rotation construction, weight-side
//! transposes.  Their buffers are pinned in *retained* arena slots
//! (exempt from the circular steal chains) and their recomputation is
//! skipped on every replay whose hoist fingerprint holds: bitwise
//! equality between the incoming trainable literals and the leaf
//! buffers the retained values were computed from.  A hot-swap, train
//! step, or cold-start upload changes those bits and recomputes the
//! prefix on the next replay.  A skipped op would have recomputed
//! identical bits from identical inputs, so bit-identity is by
//! construction; `C3A_HOIST=0` degrades to the full replay.
//!
//! Ownership: one plan per [`InterpState`](super::interp::InterpState),
//! i.e. per session / per serving tenant.  A plan is never invalidated in
//! normal operation (shapes are static per artifact); adapter hot-swaps
//! only invalidate spectra + uploads + the hoist epoch, not the plan.
//! `C3A_PLAN=0` disables recording and falls back to the per-request
//! rebuild.

use super::interp::ad::{LeafTag, Tape, V};
use super::interp::{adamw_update, decay_exempt, loss_head_view, InterpCache, LossView};
use super::manifest::{ArtifactSpec, ModelMeta, Role};
use crate::runtime::interp::model::NEG;
use crate::substrate::env;
use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use std::collections::BTreeMap;

/// Observability snapshot of a recorded plan (exposed through
/// [`ExecutorState::plan_stats`](super::backend::ExecutorState::plan_stats)).
#[derive(Clone, Copy, Debug, Default)]
pub struct PlanStats {
    /// op nodes in the straight-line program
    pub ops: usize,
    /// leaf nodes (parameters, data, masks, constants)
    pub leaves: usize,
    /// completed replays (the recording call is not counted)
    pub replays: u64,
    /// replays that errored and fell back to the rebuild path (e.g. a
    /// cross-dtype literal the strict zero-copy slices reject); nonzero
    /// here means the tentpole speedup is not being realized
    pub replay_fallbacks: u64,
    /// op nodes serviced by a recycled arena buffer (eval plans; liveness
    /// slot sharing is disabled on train plans, whose values must survive
    /// for the backward pass)
    pub shared_buffers: usize,
    /// bytes of distinct op-output buffers the arena holds live
    pub arena_bytes: usize,
    /// op nodes classified version-invariant by the hoisting pass
    /// (computed once per adapter version, retained arena slots)
    pub hoisted_ops: usize,
    /// op recomputations skipped by hoisting, cumulative over replays
    pub hoist_skips: u64,
    /// replays on which the hoist fingerprint changed (hot-swap, train
    /// step, cold-start re-upload) and the invariant prefix recomputed
    pub hoist_invalidations: u64,
}

/// Dtype contract of one positional input, checked up front by
/// [`Plan::validate`] so a cross-dtype literal (which the lenient
/// rebuild path converts but the zero-copy replay slices reject) bails
/// *before* any forward work is spent, making the fallback cheap.
#[derive(Clone, Copy, PartialEq)]
enum DtypeRule {
    MustF32,
    MustI32,
    /// frozen inputs: state-covered, never read on replay
    Any,
}

/// How one node is serviced on a replay.
#[derive(Clone, Copy)]
enum Action {
    /// frozen-parse leaves and recorded constants: nothing to do
    Skip,
    /// trainable leaf `i` (trainable_order): refill from its literal
    FillTrainable(usize),
    /// dense data leaf: refill from input literal at `input`
    FillF32 { input: usize },
    /// encoder pad-key mask `[b,1,1,s]`, recomputed from tokens
    MaskEncPad { tokens: usize },
    /// decoder causal+pad mask `[b,1,s,s]`, recomputed from tokens
    MaskDecCausal { tokens: usize },
    /// op node: recompute in place, optionally stealing a dead donor's
    /// buffer first (arena slot reuse)
    Compute { steal: Option<V> },
}

/// A recorded, replayable step: the tape is both the op-list IR and the
/// buffer arena (every node owns its output slot across replays).
pub struct Plan {
    tape: Tape,
    train: bool,
    logits: V,
    /// trainable leaf node ids, in trainable_order
    t_ids: Vec<V>,
    actions: Vec<Action>,
    /// static output shape per node (replay re-imposes it after steals)
    shapes: Vec<Vec<usize>>,
    /// embedding gathers to re-id from the request tokens
    gathers: Vec<V>,
    /// per-node: version-invariant op (hoisting pass; always false on
    /// train plans and under `C3A_HOIST=0` at build time)
    hoisted: Vec<bool>,
    /// (trainable leaf node, positional input) pairs feeding the hoisted
    /// set — the hoist fingerprint is their bitwise leaf equality
    hoist_feed: Vec<(V, usize)>,
    /// per-node: trainable leaf read *only* by hoisted ops, so its
    /// refill is skipped on replays whose fingerprint holds
    hoist_only: Vec<bool>,
    /// (c3a op node, kernel leaf node, kernel parameter name)
    c3as: Vec<(V, V, String)>,
    /// expected element count per positional input literal
    expected_len: Vec<usize>,
    /// expected dtype per positional input literal
    expected_dtype: Vec<DtypeRule>,
    /// input literal positions by role/name
    t_pos: Vec<usize>,
    m_pos: Vec<usize>,
    v_pos: Vec<usize>,
    tokens_pos: Option<usize>,
    targets_pos: Option<usize>,
    loss_mask_pos: Option<usize>,
    y_pos: Option<usize>,
    y_is_i32: bool,
    step_pos: Option<usize>,
    lr_pos: Option<usize>,
    wd_pos: Option<usize>,
    /// per-trainable AdamW weight-decay exemption (precomputed from the
    /// same `.b/.g/.mag/.lb/.ld` suffix rule the legacy path applies)
    decay_exempt: Vec<bool>,
    stats: PlanStats,
}

/// Liveness + arena slot assignment over the op list: every op node's
/// buffer is free after its last use; a later node of the same element
/// count steals it.  Chains are closed circularly (the first node of a
/// chain steals the final owner's stale buffer from the previous replay),
/// so steady-state replays never allocate.  `exclude` (the logits node)
/// neither donates — its buffer is moved out as the eval output — nor
/// steals.  `retained` marks hoisted nodes, which behave like leaves:
/// their buffers must survive across replays (a skipped op's value is
/// read long after its liveness window), so they never donate into the
/// steal chains and never steal a donor whose bits a skip would then
/// resurrect stale.  Returns (steal_from, shared_count, arena_bytes).
fn assign_slots(tape: &Tape, exclude: V, retained: &[bool]) -> (Vec<Option<V>>, usize, usize) {
    let nn = tape.node_count();
    let mut last_use = vec![0usize; nn];
    let mut ids: Vec<V> = Vec::new();
    for v in 0..nn {
        ids.clear();
        tape.op_input_ids(v, &mut ids);
        for &u in &ids {
            last_use[u] = v;
        }
    }
    let participates = |v: usize| -> bool {
        !tape.is_leaf(v) && v != exclude && !retained[v] && last_use[v] > v
    };
    // donors become free after the op that last reads them
    let mut release_at: Vec<Vec<V>> = vec![Vec::new(); nn];
    for v in 0..nn {
        if participates(v) {
            release_at[last_use[v]].push(v);
        }
    }
    // BTreeMap per lint rule D2 (deterministic order); keyed lookups
    // only, so the container swap cannot change slot assignment anyway.
    let mut free: BTreeMap<usize, Vec<V>> = BTreeMap::new();
    let mut steal_from: Vec<Option<V>> = vec![None; nn];
    for v in 0..nn {
        if v > 0 {
            for &d in &release_at[v - 1] {
                free.entry(tape.val(d).len()).or_default().push(d);
            }
        }
        if tape.is_leaf(v) || v == exclude || retained[v] {
            continue;
        }
        if let Some(list) = free.get_mut(&tape.val(v).len()) {
            if let Some(d) = list.pop() {
                steal_from[v] = Some(d);
            }
        }
    }
    let shared = steal_from.iter().filter(|s| s.is_some()).count();
    // arena bytes: one physical buffer per chain (its start) + every
    // sole-owner op node
    let mut donated = vec![false; nn];
    let mut next: Vec<Option<V>> = vec![None; nn];
    for v in 0..nn {
        if let Some(d) = steal_from[v] {
            donated[d] = true;
            next[d] = Some(v);
        }
    }
    // `exclude` (the logits output) is not arena-resident: its buffer is
    // moved out to the caller on every replay, so it does not count
    // toward steady-state held memory.  Retained (hoisted) nodes never
    // steal, so they are counted here as the sole owners they are.
    let mut arena_bytes = 0usize;
    for v in 0..nn {
        if !tape.is_leaf(v) && v != exclude && steal_from[v].is_none() {
            arena_bytes += tape.val(v).len() * std::mem::size_of::<f32>();
        }
    }
    // circularize: a chain's first node re-steals the final owner's
    // stale buffer on the next replay
    for v in 0..nn {
        if steal_from[v].is_none() && donated[v] {
            let mut e = v;
            while let Some(nx) = next[e] {
                e = nx;
            }
            steal_from[v] = Some(e);
        }
    }
    (steal_from, shared, arena_bytes)
}

impl Plan {
    /// Promote a freshly recorded tape into a replayable plan.
    ///
    /// `logits_shape` is passed explicitly because the eval path moves
    /// the logits buffer out (to the caller) before promotion, leaving
    /// the sentinel behind.  `rec_tokens` is the recording call's token
    /// batch: every recorded gather's ids are verified against the
    /// `t.max(0)` token mapping, so a future non-token gather fails
    /// closed here (the caller degrades to the rebuild path) instead of
    /// being silently mis-replayed.  A build error is always safe to
    /// swallow: the recording call's outputs were computed by the legacy
    /// path, and a plan-less state simply keeps rebuilding.
    pub fn build(
        tape: Tape,
        spec: &ArtifactSpec,
        logits: V,
        logits_shape: &[usize],
        t_ids: &[V],
        f_ids: &[V],
        rec_tokens: Option<&[i32]>,
    ) -> Result<Plan> {
        let train = spec.kind == "train";
        let nn = tape.node_count();
        let mut shapes: Vec<Vec<usize>> = (0..nn).map(|v| tape.val(v).shape.clone()).collect();
        shapes[logits] = logits_shape.to_vec();

        // positional input maps (spec.inputs order == literal order)
        let mut t_pos = Vec::new();
        let mut m_pos = Vec::new();
        let mut v_pos = Vec::new();
        let mut exempt = Vec::new();
        let mut tokens_pos = None;
        let mut targets_pos = None;
        let mut loss_mask_pos = None;
        let mut y_pos = None;
        let mut y_is_i32 = false;
        let mut x_pos = None;
        let (mut step_pos, mut lr_pos, mut wd_pos) = (None, None, None);
        let mut expected_len = Vec::with_capacity(spec.inputs.len());
        let mut expected_dtype = Vec::with_capacity(spec.inputs.len());
        for (i, inp) in spec.inputs.iter().enumerate() {
            expected_len.push(inp.shape.iter().product::<usize>().max(1));
            expected_dtype.push(match inp.role {
                Role::Frozen | Role::FrozenRandom => DtypeRule::Any,
                Role::Data if inp.i32_dtype => DtypeRule::MustI32,
                _ => DtypeRule::MustF32,
            });
            match inp.role {
                Role::Trainable => {
                    exempt.push(decay_exempt(&inp.name));
                    t_pos.push(i);
                }
                Role::OptM => m_pos.push(i),
                Role::OptV => v_pos.push(i),
                Role::Data => match inp.name.as_str() {
                    "data.tokens" => tokens_pos = Some(i),
                    "data.targets" => targets_pos = Some(i),
                    "data.loss_mask" => loss_mask_pos = Some(i),
                    "data.x" => x_pos = Some(i),
                    "data.y" => {
                        y_pos = Some(i);
                        y_is_i32 = inp.i32_dtype;
                    }
                    _ => {}
                },
                Role::Scalar => match inp.name.as_str() {
                    "step" => step_pos = Some(i),
                    "lr" => lr_pos = Some(i),
                    "wd" => wd_pos = Some(i),
                    _ => bail!("{}: unknown scalar input {}", spec.name, inp.name),
                },
                Role::Frozen | Role::FrozenRandom => {}
            }
        }
        if t_pos.len() != t_ids.len() {
            let (got, want) = (t_ids.len(), t_pos.len());
            bail!("{}: recorded {got} trainable leaves, manifest has {want}", spec.name);
        }

        // parameter-name lookup for C3A kernel leaves
        let t_names: Vec<&String> = t_pos.iter().map(|&i| &spec.inputs[i].name).collect();
        let name_of = |leaf: V| -> Option<String> {
            if let Some(i) = t_ids.iter().position(|&v| v == leaf) {
                return Some(t_names[i].clone());
            }
            f_ids
                .iter()
                .position(|&v| v == leaf)
                .map(|i| spec.frozen_order[i].clone())
        };
        let mut c3as = Vec::new();
        for (op, w) in tape.c3a_nodes() {
            let name = name_of(w)
                .with_context(|| format!("{}: c3a kernel leaf {w} is unbound", spec.name))?;
            c3as.push((op, w, name));
        }
        let gathers = tape.gather_nodes();
        if !gathers.is_empty() {
            if tokens_pos.is_none() {
                bail!("{}: recorded a token gather but has no data.tokens input", spec.name);
            }
            // fail closed: replay rewrites gather ids from tokens, which
            // is only sound if that is exactly how they were recorded
            let toks = rec_tokens
                .with_context(|| format!("{}: gather recorded without tokens", spec.name))?;
            for &g in &gathers {
                if !tape.gather_ids_match_tokens(g, toks) {
                    bail!("{}: gather {g} ids are not the token mapping", spec.name);
                }
            }
        }

        // ---- hoisting pass: version-invariant prefix -------------------
        // One forward sweep over the (topologically ordered) op list
        // propagates "request-varying": data leaves and token-derived
        // masks seed it, gathers are forced varying (their recorded ids
        // are token-derived even though the op only lists its table
        // input — see `op_inputs`), and an op is varying iff any input
        // is.  Everything else depends only on `Input` + `Const` leaves,
        // i.e. on the adapter version, and is hoisted.  Train plans
        // never hoist: the backward pass reads every forward value and
        // the trainables advance every step anyway.  The logits node is
        // excluded even when invariant — its buffer moves out to the
        // caller per replay.  `C3A_HOIST=0` at build time disables the
        // pass (classification, retained slots, and skips), restoring
        // the pre-hoist plan exactly.
        let mut hoisted = vec![false; nn];
        if !train && env::hoist_enabled() {
            let mut varying = vec![false; nn];
            let mut ids: Vec<V> = Vec::new();
            for &g in &gathers {
                varying[g] = true;
            }
            for v in 0..nn {
                match tape.leaf_tag(v) {
                    Some(LeafTag::DataX | LeafTag::MaskEncPad | LeafTag::MaskDecCausal) => {
                        varying[v] = true;
                    }
                    Some(LeafTag::Input | LeafTag::Const) => {}
                    None => {
                        if varying[v] {
                            continue; // a gather, forced above
                        }
                        ids.clear();
                        tape.op_input_ids(v, &mut ids);
                        varying[v] = ids.iter().any(|&u| varying[u]);
                    }
                }
            }
            for v in 0..nn {
                hoisted[v] = !tape.is_leaf(v) && !varying[v] && v != logits;
            }
        }
        let hoisted_ops = hoisted.iter().filter(|&&h| h).count();
        // Trainable leaves feeding the hoisted set carry the hoist
        // fingerprint.  Every op ancestor of a hoisted op is itself
        // hoisted (invariance is closed over inputs), so the feeding
        // leaves are exactly those read *directly* by a hoisted op.  A
        // feeding leaf with no non-hoisted consumer is refill-skippable
        // on valid replays: nothing reads its buffer then.
        let mut feeds = vec![false; nn];
        let mut varying_consumer = vec![false; nn];
        {
            let mut ids: Vec<V> = Vec::new();
            for v in 0..nn {
                if tape.is_leaf(v) {
                    continue;
                }
                ids.clear();
                tape.op_input_ids(v, &mut ids);
                for &u in &ids {
                    if hoisted[v] {
                        feeds[u] = true;
                    } else {
                        varying_consumer[u] = true;
                    }
                }
            }
        }
        let mut hoist_feed = Vec::new();
        for (i, &t) in t_ids.iter().enumerate() {
            if feeds[t] {
                hoist_feed.push((t, t_pos[i]));
            }
        }
        let hoist_only: Vec<bool> = (0..nn).map(|v| feeds[v] && !varying_consumer[v]).collect();

        // per-node replay actions; eval plans additionally share buffers
        // (train plans retain every buffer for the backward pass, so
        // their arena is simply the full op set)
        let (steal_from, shared, arena_bytes) = if train {
            let bytes = (0..nn)
                .filter(|&v| !tape.is_leaf(v))
                .map(|v| tape.val(v).len() * std::mem::size_of::<f32>())
                .sum();
            (vec![None; nn], 0, bytes)
        } else {
            assign_slots(&tape, logits, &hoisted)
        };
        let mut leaves = 0usize;
        let mut actions = Vec::with_capacity(nn);
        for v in 0..nn {
            let action = match tape.leaf_tag(v) {
                None => Action::Compute { steal: steal_from[v] },
                Some(tag) => {
                    leaves += 1;
                    match tag {
                        LeafTag::Input => {
                            if let Some(i) = t_ids.iter().position(|&t| t == v) {
                                Action::FillTrainable(i)
                            } else if f_ids.contains(&v) {
                                Action::Skip
                            } else {
                                let sn = &spec.name;
                                bail!("{sn}: input leaf {v} is neither trainable nor frozen");
                            }
                        }
                        LeafTag::Const => Action::Skip,
                        LeafTag::DataX => Action::FillF32 {
                            input: x_pos
                                .with_context(|| format!("{}: no data.x input", spec.name))?,
                        },
                        LeafTag::MaskEncPad => Action::MaskEncPad {
                            tokens: tokens_pos
                                .with_context(|| format!("{}: no data.tokens input", spec.name))?,
                        },
                        LeafTag::MaskDecCausal => Action::MaskDecCausal {
                            tokens: tokens_pos
                                .with_context(|| format!("{}: no data.tokens input", spec.name))?,
                        },
                    }
                }
            };
            actions.push(action);
        }

        let stats = PlanStats {
            ops: nn - leaves,
            leaves,
            replays: 0,
            replay_fallbacks: 0,
            shared_buffers: shared,
            arena_bytes,
            hoisted_ops,
            hoist_skips: 0,
            hoist_invalidations: 0,
        };
        Ok(Plan {
            tape,
            train,
            logits,
            t_ids: t_ids.to_vec(),
            actions,
            shapes,
            gathers,
            hoisted,
            hoist_feed,
            hoist_only,
            c3as,
            expected_len,
            expected_dtype,
            t_pos,
            m_pos,
            v_pos,
            tokens_pos,
            targets_pos,
            loss_mask_pos,
            y_pos,
            y_is_i32,
            step_pos,
            lr_pos,
            wd_pos,
            decay_exempt: exempt,
            stats,
        })
    }

    pub fn stats(&self) -> PlanStats {
        self.stats
    }

    /// Record a replay that errored and was served by the rebuild path
    /// instead (counted so production degradation is diagnosable from
    /// [`PlanStats`] rather than invisible).
    pub fn note_fallback(&mut self) {
        self.stats.replay_fallbacks += 1;
    }

    /// Validate the positional literals against the recorded contract.
    fn validate(&self, spec: &ArtifactSpec, inputs: &[&xla::Literal]) -> Result<()> {
        if inputs.len() != self.expected_len.len() {
            bail!(
                "{}: replay got {} inputs, plan recorded {}",
                spec.name,
                inputs.len(),
                self.expected_len.len()
            );
        }
        for (i, (&want, lit)) in self.expected_len.iter().zip(inputs.iter()).enumerate() {
            if lit.element_count() != want {
                bail!(
                    "{}: input {i} has {} elements, plan recorded {want}",
                    spec.name,
                    lit.element_count()
                );
            }
            let rule = self.expected_dtype[i];
            let bad = (rule == DtypeRule::MustI32 && !lit.is_i32())
                || (rule == DtypeRule::MustF32 && lit.is_i32());
            if bad {
                // bail before any forward work: the caller degrades to
                // the (dtype-lenient) rebuild path cheaply
                bail!("{}: input {i} dtype differs from the recorded contract", spec.name);
            }
        }
        Ok(())
    }

    /// Phase 1 of a replay: refill every variable leaf from the request's
    /// literals, re-id the token gathers, refresh C3A spectra through the
    /// session cache.  Frozen parses and constants are untouched.
    ///
    /// Returns whether this replay may skip the hoisted (version-
    /// invariant) ops: true iff the plan hoisted anything, `C3A_HOIST`
    /// is on, and every trainable leaf feeding the hoisted set still
    /// holds the incoming literal's exact bits.  A mismatch bumps the
    /// invalidation counter and recomputes everything; the full refill
    /// then makes the leaf bits a truthful fingerprint again, so a
    /// hot-swap / train-step / re-upload is detected with no explicit
    /// epoch plumbing from the session layer (the same bitwise
    /// equality-invalidation rule the spectra and upload caches use).
    fn fill(
        &mut self,
        spec: &ArtifactSpec,
        cache: &RefCell<InterpCache>,
        inputs: &[&xla::Literal],
    ) -> Result<bool> {
        self.validate(spec, inputs)?;
        let mut skip_hoisted = false;
        if self.stats.hoisted_ops > 0 {
            let mut same = true;
            for &(leaf, pos) in &self.hoist_feed {
                let data = inputs[pos]
                    .f32_slice()
                    .with_context(|| format!("{}: hoist-feed input is not f32", spec.name))?;
                if !self.tape.leaf_bits_match(leaf, data) {
                    same = false;
                    break;
                }
            }
            if !same {
                self.stats.hoist_invalidations += 1;
            } else if env::hoist_enabled() {
                skip_hoisted = true;
            }
            // same bits with `C3A_HOIST=0`: full recompute from equal
            // inputs — identical bits, no invalidation to count
        }
        let (b, s) = (spec.batch, spec.seq);
        for v in 0..self.actions.len() {
            match self.actions[v] {
                Action::Skip | Action::Compute { .. } => {}
                Action::FillTrainable(i) => {
                    if skip_hoisted && self.hoist_only[v] {
                        // fed only into skipped ops; bits already match
                        continue;
                    }
                    let lit = inputs[self.t_pos[i]];
                    let data = lit
                        .f32_slice()
                        .with_context(|| format!("{}: trainable {i} is not f32", spec.name))?;
                    self.tape.copy_into_leaf(v, data);
                }
                Action::FillF32 { input } => {
                    let data = inputs[input]
                        .f32_slice()
                        .with_context(|| format!("{}: data.x is not f32", spec.name))?;
                    self.tape.copy_into_leaf(v, data);
                }
                Action::MaskEncPad { tokens } => {
                    let toks = inputs[tokens]
                        .i32_slice()
                        .with_context(|| format!("{}: data.tokens is not i32", spec.name))?;
                    self.tape.write_leaf_with(v, |data| {
                        for (slot, &t) in data.iter_mut().zip(toks.iter()) {
                            *slot = if t == 0 { NEG } else { 0.0 };
                        }
                    });
                }
                Action::MaskDecCausal { tokens } => {
                    let toks = inputs[tokens]
                        .i32_slice()
                        .with_context(|| format!("{}: data.tokens is not i32", spec.name))?;
                    self.tape.write_leaf_with(v, |data| {
                        for bi in 0..b {
                            for qi in 0..s {
                                for ki in 0..s {
                                    let mut m = 0f32;
                                    if ki > qi {
                                        m += NEG;
                                    }
                                    if toks[bi * s + ki] == 0 {
                                        m += NEG;
                                    }
                                    data[(bi * s + qi) * s + ki] = m;
                                }
                            }
                        }
                    });
                }
            }
        }
        if let Some(tp) = self.tokens_pos {
            if !self.gathers.is_empty() {
                let toks = inputs[tp]
                    .i32_slice()
                    .with_context(|| format!("{}: data.tokens is not i32", spec.name))?;
                for i in 0..self.gathers.len() {
                    let g = self.gathers[i];
                    self.tape.set_gather_tokens(g, toks);
                }
            }
        }
        for (op, w, name) in &self.c3as {
            let spectra = cache.borrow_mut().spectra_for(name, self.tape.val(*w));
            self.tape.refresh_c3a_spectra(*op, spectra);
        }
        Ok(skip_hoisted)
    }

    /// Phase 2: straight-line recompute of every op into its arena slot.
    /// With `skip_hoisted`, version-invariant ops keep their retained
    /// buffers — they would have recomputed identical bits from
    /// identical inputs (no hoisted node ever steals or donates, so the
    /// skip cannot interact with the circular chains).
    fn compute(&mut self, skip_hoisted: bool) {
        for v in 0..self.actions.len() {
            if let Action::Compute { steal } = self.actions[v] {
                if skip_hoisted && self.hoisted[v] {
                    continue;
                }
                if let Some(d) = steal {
                    self.tape.steal_buffer(d, v);
                }
                self.tape.recompute(v, &self.shapes[v]);
            }
        }
    }

    /// Replay an eval artifact: refill, recompute, move the logits out.
    pub fn replay_eval(
        &mut self,
        spec: &ArtifactSpec,
        cache: &RefCell<InterpCache>,
        inputs: &[&xla::Literal],
    ) -> Result<xla::Literal> {
        debug_assert!(!self.train, "replay_eval on a train plan");
        let skip_hoisted = self.fill(spec, cache, inputs)?;
        self.compute(skip_hoisted);
        if skip_hoisted {
            self.stats.hoist_skips += self.stats.hoisted_ops as u64;
        }
        let out = self.tape.take_val(self.logits);
        self.stats.replays += 1;
        Ok(xla::Literal::from_f32(&out.shape, out.data))
    }

    /// Replay a train artifact: refill, recompute the forward, run the
    /// shared loss head + backward + AdamW over the replayed values.
    pub fn replay_train(
        &mut self,
        spec: &ArtifactSpec,
        meta: &ModelMeta,
        cache: &RefCell<InterpCache>,
        inputs: &[&xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        debug_assert!(self.train, "replay_train on an eval plan");
        // train plans never hoist (hoisted_ops == 0), so fill's skip
        // decision is always false here
        self.fill(spec, cache, inputs)?;
        self.compute(false);

        let view = LossView {
            tokens: self.tokens_pos.map(|p| inputs[p].i32_slice()).transpose()?,
            targets: self.targets_pos.map(|p| inputs[p].i32_slice()).transpose()?,
            loss_mask: self.loss_mask_pos.map(|p| inputs[p].f32_slice()).transpose()?,
            y_i32: match self.y_pos {
                Some(p) if self.y_is_i32 => Some(inputs[p].i32_slice()?),
                _ => None,
            },
            y_f32: match self.y_pos {
                Some(p) if !self.y_is_i32 => Some(inputs[p].f32_slice()?),
                _ => None,
            },
        };
        let lv = self.tape.val(self.logits);
        let (loss, metric, dlogits) = loss_head_view(spec, meta, lv, &view)?;
        let grads = self.tape.backward(self.logits, dlogits);

        let scalar = |pos: Option<usize>, name: &str| -> Result<f32> {
            let p = pos.with_context(|| format!("{}: missing scalar {name}", spec.name))?;
            inputs[p].get_first_element::<f32>()
        };
        let step = scalar(self.step_pos, "step")?;
        let lr = scalar(self.lr_pos, "lr")?;
        let wd = match self.wd_pos {
            Some(p) => inputs[p].get_first_element::<f32>()?,
            None => 0.0,
        };

        let nt = self.t_ids.len();
        let mut new_t = Vec::with_capacity(nt);
        let mut new_m = Vec::with_capacity(nt);
        let mut new_v = Vec::with_capacity(nt);
        for i in 0..nt {
            let p = inputs[self.t_pos[i]].f32_slice()?;
            let m0 = inputs[self.m_pos[i]].f32_slice()?;
            let v0 = inputs[self.v_pos[i]].f32_slice()?;
            let g = grads[self.t_ids[i]].as_deref();
            let decay = if self.decay_exempt[i] { 0.0 } else { wd };
            let (pn, mn, vn) = adamw_update(p, g, m0, v0, step, lr, decay);
            let shape = &self.shapes[self.t_ids[i]];
            new_t.push(xla::Literal::from_f32(shape, pn));
            new_m.push(xla::Literal::from_f32(shape, mn));
            new_v.push(xla::Literal::from_f32(shape, vn));
        }
        let mut outs = new_t;
        outs.extend(new_m);
        outs.extend(new_v);
        outs.push(xla::Literal::scalar(loss));
        outs.push(xla::Literal::scalar(metric));
        self.stats.replays += 1;
        Ok(outs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::interp::ad::Arr;

    /// Hand-built chain: x -> a -> b -> c (same sizes) with a dead early
    /// node.  `a` is dead after `b`, so `c` must steal `a`'s buffer, and
    /// the chain closes circularly.
    #[test]
    fn slot_assignment_recycles_dead_same_size_buffers() {
        let mut t = Tape::new();
        let x = t.leaf(Arr::new(vec![4], vec![1.0, 2.0, 3.0, 4.0]), false);
        let a = t.scale(x, 2.0); // node 1
        let b = t.scale(a, 3.0); // node 2: last use of a
        let c = t.scale(b, 4.0); // node 3: can reuse a's buffer
        let d = t.scale(c, 5.0); // node 4 (logits): excluded
        let (steal, shared, bytes) = assign_slots(&t, d, &vec![false; t.node_count()]);
        assert_eq!(steal[c], Some(a), "c must steal a's dead buffer");
        assert_eq!(shared, 1);
        // circular closure: a re-steals the chain's final owner (c)
        assert_eq!(steal[a], Some(c));
        assert_eq!(steal[x], None, "leaves never participate");
        assert_eq!(steal[d], None, "the excluded output never steals");
        // arena-resident physical buffers: a's chain (1) + b.  The
        // excluded output d is moved out per replay, not held.
        assert_eq!(bytes, 2 * 4 * std::mem::size_of::<f32>());
        let _ = b;
    }

    /// Different sizes never share a slot.
    #[test]
    fn slot_assignment_is_size_exact() {
        let mut t = Tape::new();
        let x = t.leaf(Arr::new(vec![2, 3], vec![0.5; 6]), false);
        let a = t.transpose2(x); // [3,2], 6 elems
        let s = t.sum_axis0(a); // [2]: last use of a, but 2 != 6
        let out = t.scale(s, 1.0);
        let (steal, shared, _) = assign_slots(&t, out, &vec![false; t.node_count()]);
        assert_eq!(shared, 0);
        assert!(steal.iter().all(|s| s.is_none()));
    }

    /// A retained (hoisted) node must neither donate its buffer into a
    /// steal chain nor steal one — its value is read on replays where it
    /// is skipped, long after its liveness window closes.
    #[test]
    fn retained_nodes_are_exempt_from_steal_chains() {
        let mut t = Tape::new();
        let x = t.leaf(Arr::new(vec![4], vec![1.0, 2.0, 3.0, 4.0]), false);
        let a = t.scale(x, 2.0); // node 1: would donate to c, but retained
        let b = t.scale(a, 3.0); // node 2: last use of a
        let c = t.scale(b, 4.0); // node 3: would steal a's buffer
        let d = t.scale(c, 5.0); // node 4 (logits): excluded
        let mut retained = vec![false; t.node_count()];
        retained[a] = true;
        let (steal, shared, bytes) = assign_slots(&t, d, &retained);
        assert_eq!(shared, 0, "retained donor must break the steal");
        assert!(steal.iter().all(|s| s.is_none()));
        // all three op buffers (a, b, c) are now sole-owned arena bytes
        assert_eq!(bytes, 3 * 4 * std::mem::size_of::<f32>());
        let _ = (b, c);
    }
}
