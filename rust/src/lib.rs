//! C3A — Parameter-Efficient Fine-Tuning via Circular Convolution.
#![cfg_attr(feature = "simd", feature(portable_simd))]

/// Re-export of the execution-literal crate (the in-tree shim by default,
/// real PJRT bindings when vendored) so tests and downstream tools can
/// construct `xla::Literal`s without a direct dependency.
pub use xla;

pub mod runtime;
pub mod config;
pub mod coordinator;
pub mod exp;
pub mod data;
pub mod metrics;
pub mod peft;
pub mod serving;
#[deny(missing_docs)]
pub mod substrate;
