//! C3A — Parameter-Efficient Fine-Tuning via Circular Convolution.
pub mod runtime;
pub mod config;
pub mod coordinator;
pub mod exp;
pub mod data;
pub mod metrics;
pub mod peft;
pub mod substrate;
