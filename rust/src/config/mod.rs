//! Typed run configuration, loadable from TOML-subset files (configs/).
//!
//! A config file fully describes one fine-tuning run — the `c3a train
//! --config <file>` path used for scripted/reproducible runs, mirroring
//! the flags of the ad-hoc CLI.

use crate::coordinator::lr::Schedule;
use crate::coordinator::trainer::TrainCfg;
use crate::substrate::toml::{self, Value};
use anyhow::{bail, Context, Result};
use std::path::Path;

/// One declarative fine-tuning run.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub model: String,
    pub method: String,
    /// task spec, e.g. "glue:sst2", "mc:boolq", "gen:gsm_sim", "vision:pets"
    pub task: String,
    pub seed: u64,
    pub init_scheme: String,
    pub train: TrainCfg,
}

impl RunConfig {
    pub fn load<P: AsRef<Path>>(path: P) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<RunConfig> {
        let doc = toml::parse(text)?;
        let top = doc.get("").cloned().unwrap_or_default();
        let gets = |m: &std::collections::BTreeMap<String, Value>, k: &str| -> Option<String> {
            m.get(k).and_then(|v| v.as_str().map(str::to_string))
        };
        let model = gets(&top, "model").context("config: `model` required")?;
        let method = gets(&top, "method").context("config: `method` required")?;
        let task = gets(&top, "task").context("config: `task` required")?;
        let seed = top.get("seed").and_then(|v| v.as_i64()).unwrap_or(0) as u64;
        let init_scheme = gets(&top, "init").unwrap_or_else(|| "xavier".into());

        let t = doc.get("train").cloned().unwrap_or_default();
        let mut train = crate::coordinator::run::default_cfg(&method, 100);
        if let Some(v) = t.get("steps").and_then(|v| v.as_i64()) {
            train.steps = v as usize;
        }
        if let Some(v) = t.get("lr").and_then(|v| v.as_f64()) {
            train.lr = v;
        }
        if let Some(v) = t.get("weight_decay").and_then(|v| v.as_f64()) {
            train.weight_decay = v;
        }
        if let Some(v) = t.get("eval_every").and_then(|v| v.as_i64()) {
            train.eval_every = v as usize;
        }
        if let Some(v) = t.get("patience").and_then(|v| v.as_i64()) {
            train.patience = v as usize;
        }
        if let Some(sched) = t.get("schedule").and_then(|v| v.as_str()) {
            let warmup = t.get("warmup_frac").and_then(|v| v.as_f64()).unwrap_or(0.06);
            train.schedule = Schedule::parse(sched, warmup)
                .with_context(|| format!("unknown schedule {sched}"))?;
        }
        if train.steps == 0 {
            bail!("config: steps must be > 0");
        }
        Ok(RunConfig { model, method, task, seed, init_scheme, train })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
model = "enc_tiny"
method = "c3a_d8"
task = "glue:sst2"
seed = 3
init = "kaiming"

[train]
steps = 120
lr = 0.05
weight_decay = 0.01
schedule = "cosine"
warmup_frac = 0.1
eval_every = 40
patience = 2
"#;

    #[test]
    fn parses_full_config() {
        let c = RunConfig::parse(SAMPLE).unwrap();
        assert_eq!(c.model, "enc_tiny");
        assert_eq!(c.method, "c3a_d8");
        assert_eq!(c.task, "glue:sst2");
        assert_eq!(c.seed, 3);
        assert_eq!(c.init_scheme, "kaiming");
        assert_eq!(c.train.steps, 120);
        assert_eq!(c.train.lr, 0.05);
        assert_eq!(c.train.patience, 2);
        assert_eq!(c.train.schedule, Schedule::Cosine { warmup_frac: 0.1 });
    }

    #[test]
    fn defaults_fill_in() {
        let c = RunConfig::parse("model = \"m\"\nmethod = \"lora\"\ntask = \"glue:rte\"").unwrap();
        assert_eq!(c.seed, 0);
        assert_eq!(c.init_scheme, "xavier");
        assert!(c.train.steps > 0);
        assert_eq!(c.train.lr, crate::coordinator::run::default_lr("lora"));
    }

    #[test]
    fn missing_fields_rejected() {
        assert!(RunConfig::parse("model = \"m\"").is_err());
        assert!(
            RunConfig::parse("model = \"m\"\nmethod = \"x\"\ntask = \"t\"\n[train]\nsteps = 0")
                .is_err()
        );
    }

    #[test]
    fn bad_schedule_rejected() {
        let bad = "model=\"m\"\nmethod=\"lora\"\ntask=\"glue:rte\"\n[train]\nschedule = \"warp\"";
        assert!(RunConfig::parse(bad).is_err());
    }
}
