//! Generation-sim: math-reasoning and code-synthesis proxies for the
//! paper's Table 4 (GSM8K / MATH, HumanEval / MBPP ± Plus).
//!
//! Each example is a prompt followed by a multi-token answer region; the
//! LM is fine-tuned with loss over the answer tokens and evaluated by
//! greedy decoding + exact match — the same protocol shape as the paper's
//! chain-of-thought / Pass@1 evaluation.
//!
//! Digits are tokens DIGIT0..DIGIT0+9; operators come after.

use super::{Splits, CLS, PAD, SEP};
use crate::substrate::prng::Rng;
use crate::substrate::tensor::Tensor;

pub const DIGIT0: i32 = 4; // digits 0-9 at ids 4..14
pub const OP_ADD: i32 = 14;
pub const OP_MUL: i32 = 15;
pub const OP_EQ: i32 = 16;
pub const SYM0: i32 = 20; // code-sim symbol band

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum GenTask {
    /// GSM8K-sim: a + b*c, two-digit operands
    Gsm,
    /// MATH-sim: (a + b*c) mod 10 chain, longer
    Math,
    /// HumanEval-sim: reverse the symbol sequence
    HumanEval,
    /// HumanEval+-sim: reverse, longer sequences (stricter)
    HumanEvalPlus,
    /// MBPP-sim: duplicate each symbol
    Mbpp,
    /// MBPP+-sim: duplicate, longer
    MbppPlus,
}

impl GenTask {
    pub const MATH_ALL: [GenTask; 2] = [GenTask::Gsm, GenTask::Math];
    pub const CODE_ALL: [GenTask; 4] =
        [GenTask::HumanEval, GenTask::HumanEvalPlus, GenTask::Mbpp, GenTask::MbppPlus];

    pub fn name(self) -> &'static str {
        match self {
            GenTask::Gsm => "gsm_sim",
            GenTask::Math => "math_sim",
            GenTask::HumanEval => "humaneval_sim",
            GenTask::HumanEvalPlus => "humaneval_plus_sim",
            GenTask::Mbpp => "mbpp_sim",
            GenTask::MbppPlus => "mbpp_plus_sim",
        }
    }
}

/// Prompt + gold answer tokens.
#[derive(Clone, Debug)]
pub struct GenExample {
    /// prompt tokens, ending with OP_EQ / SEP
    pub prompt: Vec<i32>,
    /// gold answer tokens (not part of the prompt)
    pub answer: Vec<i32>,
}

#[derive(Clone, Debug, Default)]
pub struct GenDataset {
    pub examples: Vec<GenExample>,
}

impl GenDataset {
    pub fn len(&self) -> usize {
        self.examples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.examples.is_empty()
    }

    /// Train batch: (tokens [B,S] = prompt ++ answer, loss_mask over the
    /// positions that *predict* answer tokens).
    pub fn batch(&self, idx: &[usize], b: usize, s: usize) -> Vec<Tensor> {
        let mut toks = vec![PAD; b * s];
        let mut mask = vec![0f32; b * s];
        for slot in 0..b {
            let &i = idx.get(slot).unwrap_or(&idx[0]);
            let ex = &self.examples[i];
            let mut seqv = ex.prompt.clone();
            seqv.extend(&ex.answer);
            let n = seqv.len().min(s);
            toks[slot * s..slot * s + n].copy_from_slice(&seqv[..n]);
            let a0 = ex.prompt.len();
            for j in a0..n {
                mask[slot * s + j - 1] = 1.0; // predicting token j from j-1
            }
        }
        vec![Tensor::from_i32(vec![b, s], &toks), Tensor::from_f32(vec![b, s], &mask)]
    }
}

fn digits_of(mut v: i64) -> Vec<i32> {
    if v == 0 {
        return vec![DIGIT0];
    }
    let mut out = Vec::new();
    while v > 0 {
        out.push(DIGIT0 + (v % 10) as i32);
        v /= 10;
    }
    out.reverse();
    out
}

fn generate(task: GenTask, rng: &mut Rng) -> GenExample {
    match task {
        GenTask::Gsm | GenTask::Math => {
            let hi = if task == GenTask::Gsm { 50 } else { 90 };
            let a = rng.below(hi) as i64;
            let b = rng.below(9) as i64 + 1;
            let c = rng.below(9) as i64 + 1;
            let mut prompt = vec![CLS];
            prompt.extend(digits_of(a));
            prompt.push(OP_ADD);
            prompt.extend(digits_of(b));
            prompt.push(OP_MUL);
            prompt.extend(digits_of(c));
            let mut result = a + b * c;
            if task == GenTask::Math {
                // extra step: multiply by d then keep mod 100 (harder carry)
                let d = rng.below(9) as i64 + 1;
                prompt.push(OP_MUL);
                prompt.extend(digits_of(d));
                result = (result * d) % 100;
            }
            prompt.push(OP_EQ);
            GenExample { prompt, answer: digits_of(result) }
        }
        GenTask::HumanEval | GenTask::HumanEvalPlus | GenTask::Mbpp | GenTask::MbppPlus => {
            let plus = matches!(task, GenTask::HumanEvalPlus | GenTask::MbppPlus);
            let len = if plus { 6 + rng.below(5) } else { 3 + rng.below(4) };
            let sym: Vec<i32> = (0..len).map(|_| SYM0 + rng.below(12) as i32).collect();
            let answer: Vec<i32> = match task {
                GenTask::HumanEval | GenTask::HumanEvalPlus => sym.iter().rev().copied().collect(),
                _ => sym.iter().flat_map(|&t| [t, t]).collect(),
            };
            let mut prompt = vec![CLS];
            prompt.extend(&sym);
            prompt.push(SEP);
            GenExample { prompt, answer }
        }
    }
}

pub fn splits(task: GenTask, seed: u64, n_train: usize) -> Splits<GenDataset> {
    let mut rng = Rng::seed(seed ^ (task as u64).wrapping_mul(0x2545F4914F6CDD1D));
    let gen = |n: usize, rng: &mut Rng| GenDataset {
        examples: (0..n).map(|_| generate(task, rng)).collect(),
    };
    Splits {
        train: gen(n_train, &mut rng),
        val: gen(96, &mut rng),
        test: gen(192, &mut rng),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gsm_answers_are_correct_arithmetic() {
        let s = splits(GenTask::Gsm, 0, 100);
        for ex in &s.train.examples {
            // parse back: CLS d+ OP_ADD d+ OP_MUL d+ OP_EQ
            let body = &ex.prompt[1..ex.prompt.len() - 1];
            let parts: Vec<Vec<i64>> = body
                .split(|&t| t == OP_ADD || t == OP_MUL)
                .map(|p| p.iter().map(|&d| (d - DIGIT0) as i64).collect())
                .collect();
            let num = |ds: &Vec<i64>| ds.iter().fold(0i64, |a, &d| a * 10 + d);
            let want = num(&parts[0]) + num(&parts[1]) * num(&parts[2]);
            let got = ex.answer.iter().fold(0i64, |a, &d| a * 10 + (d - DIGIT0) as i64);
            assert_eq!(got, want);
        }
    }

    #[test]
    fn code_tasks_transform_correctly() {
        let s = splits(GenTask::HumanEval, 1, 50);
        for ex in &s.train.examples {
            let sym = &ex.prompt[1..ex.prompt.len() - 1];
            let want: Vec<i32> = sym.iter().rev().copied().collect();
            assert_eq!(ex.answer, want);
        }
        let s = splits(GenTask::Mbpp, 2, 50);
        for ex in &s.train.examples {
            let sym = &ex.prompt[1..ex.prompt.len() - 1];
            let want: Vec<i32> = sym.iter().flat_map(|&t| [t, t]).collect();
            assert_eq!(ex.answer, want);
        }
    }

    #[test]
    fn plus_variants_are_longer() {
        let a = splits(GenTask::HumanEval, 3, 200);
        let b = splits(GenTask::HumanEvalPlus, 3, 200);
        let mean = |d: &GenDataset| {
            d.examples.iter().map(|e| e.answer.len()).sum::<usize>() as f64 / d.len() as f64
        };
        assert!(mean(&b.train) > mean(&a.train));
    }

    #[test]
    fn batch_mask_covers_answers_only() {
        let s = splits(GenTask::Gsm, 4, 8);
        let b = s.train.batch(&(0..8).collect::<Vec<_>>(), 8, 48);
        let toks = b[0].as_i32();
        let mask = b[1].as_f32();
        for (slot, ex) in s.train.examples.iter().enumerate() {
            let total = ex.prompt.len() + ex.answer.len();
            let masked: usize =
                (0..48).filter(|&j| mask[slot * 48 + j] > 0.0).count();
            assert_eq!(masked, ex.answer.len().min(48 - ex.prompt.len()));
            // mask positions predict answer tokens
            for j in 0..48 {
                if mask[slot * 48 + j] > 0.0 {
                    assert!(j + 1 >= ex.prompt.len() && j + 1 < total);
                    assert_ne!(toks[slot * 48 + j + 1], PAD);
                }
            }
        }
    }
}
