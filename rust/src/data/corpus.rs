//! Procedural pretraining corpus — the stand-in for the paper's
//! web-scale pretraining data (RoBERTa / LLaMA checkpoints).
//!
//! Sentences are walks of a seeded sparse bigram chain over the content
//! vocabulary: from each token, one of `branch` successors (a deterministic
//! function of the token id) is chosen uniformly.  An LM can reach low
//! perplexity by learning the chain, which gives fine-tuning a genuinely
//! "pretrained" backbone; MLM batches mask 15% and predict originals.

use super::{CLS, CONTENT0, MASK, PAD};
use crate::substrate::prng::Rng;
use crate::substrate::tensor::Tensor;

#[derive(Clone, Debug)]
pub struct Corpus {
    pub vocab: usize,
    pub branch: usize,
    successors: Vec<Vec<i32>>,
}

impl Corpus {
    pub fn new(vocab: usize, branch: usize, seed: u64) -> Self {
        let content = vocab - CONTENT0 as usize;
        let mut rng = Rng::seed(seed ^ 0xB16_0AA);
        let successors = (0..content)
            .map(|_| {
                (0..branch)
                    .map(|_| (CONTENT0 as usize + rng.below(content)) as i32)
                    .collect()
            })
            .collect();
        Self { vocab, branch, successors }
    }

    /// Sample one sentence of exactly `len` tokens (leading CLS/BOS).
    pub fn sentence(&self, rng: &mut Rng, len: usize) -> Vec<i32> {
        let content = self.vocab - CONTENT0 as usize;
        let mut out = Vec::with_capacity(len);
        out.push(CLS);
        let mut cur = (CONTENT0 as usize + rng.below(content)) as i32;
        out.push(cur);
        while out.len() < len {
            let succ = &self.successors[(cur - CONTENT0) as usize];
            cur = succ[rng.below(succ.len())];
            out.push(cur);
        }
        out
    }

    /// Next-token LM batch: (tokens [B,S], loss_mask [B,S]).
    pub fn lm_batch(&self, rng: &mut Rng, b: usize, s: usize) -> Vec<Tensor> {
        let mut toks = vec![PAD; b * s];
        let mut mask = vec![0f32; b * s];
        for i in 0..b {
            let len = s / 2 + rng.below(s / 2);
            let sent = self.sentence(rng, len);
            toks[i * s..i * s + len].copy_from_slice(&sent);
            // predict positions 1..len-1 (targets are shifted inside the graph)
            for j in 0..len - 1 {
                mask[i * s + j] = 1.0;
            }
        }
        vec![Tensor::from_i32(vec![b, s], &toks), Tensor::from_f32(vec![b, s], &mask)]
    }

    /// MLM batch: (tokens-with-MASK [B,S], targets [B,S], loss_mask [B,S]).
    pub fn mlm_batch(&self, rng: &mut Rng, b: usize, s: usize) -> Vec<Tensor> {
        let mut toks = vec![PAD; b * s];
        let mut targets = vec![PAD; b * s];
        let mut mask = vec![0f32; b * s];
        for i in 0..b {
            let len = s / 2 + rng.below(s / 2);
            let sent = self.sentence(rng, len);
            for (j, &t) in sent.iter().enumerate() {
                targets[i * s + j] = t;
                let masked = j > 0 && rng.uniform() < 0.25;
                toks[i * s + j] = if masked { MASK } else { t };
                if masked {
                    mask[i * s + j] = 1.0;
                }
            }
        }
        vec![
            Tensor::from_i32(vec![b, s], &toks),
            Tensor::from_i32(vec![b, s], &targets),
            Tensor::from_f32(vec![b, s], &mask),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sentences_follow_the_chain() {
        let c = Corpus::new(512, 4, 0);
        let mut rng = Rng::seed(1);
        let s = c.sentence(&mut rng, 20);
        assert_eq!(s.len(), 20);
        assert_eq!(s[0], CLS);
        for w in s[1..].windows(2) {
            let succ = &c.successors[(w[0] - CONTENT0) as usize];
            assert!(succ.contains(&w[1]), "{w:?} not a chain edge");
        }
    }

    #[test]
    fn chain_is_low_entropy() {
        // each token has exactly `branch` successors -> learnable
        let c = Corpus::new(512, 4, 0);
        for s in &c.successors {
            assert_eq!(s.len(), 4);
        }
    }

    #[test]
    fn lm_batch_shapes_and_mask() {
        let c = Corpus::new(512, 4, 0);
        let mut rng = Rng::seed(2);
        let b = c.lm_batch(&mut rng, 4, 48);
        assert_eq!(b[0].shape, vec![4, 48]);
        assert_eq!(b[1].shape, vec![4, 48]);
        let toks = b[0].as_i32();
        let mask = b[1].as_f32();
        for (t, m) in toks.iter().zip(&mask) {
            if *m > 0.0 {
                assert_ne!(*t, PAD);
            }
        }
    }

    #[test]
    fn mlm_batch_masks_subset() {
        let c = Corpus::new(512, 4, 0);
        let mut rng = Rng::seed(3);
        let b = c.mlm_batch(&mut rng, 8, 32);
        let toks = b[0].as_i32();
        let targets = b[1].as_i32();
        let mask = b[2].as_f32();
        let mut n_masked = 0;
        for i in 0..toks.len() {
            if mask[i] > 0.0 {
                assert_eq!(toks[i], MASK);
                assert_ne!(targets[i], PAD);
                n_masked += 1;
            }
        }
        assert!(n_masked > 5);
    }
}
