//! Vision-sim: six patch-vector classification datasets standing in for
//! the paper's Table A2 suite (Pets, Cars, DTD, EuroSAT, FGVC, RESISC).
//!
//! An image is a bag of P patch vectors.  Each class has a prototype
//! sequence of patch means; examples are prototypes + Gaussian noise +
//! patch dropout — #classes and noise follow the difficulty ordering of
//! the real datasets (Cars/FGVC hard, EuroSAT easy).

use super::Splits;
use crate::substrate::prng::Rng;
use crate::substrate::tensor::Tensor;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum VisionTask {
    Pets,
    Cars,
    Dtd,
    EuroSat,
    Fgvc,
    Resisc,
}

impl VisionTask {
    pub const ALL: [VisionTask; 6] = [
        VisionTask::Pets,
        VisionTask::Cars,
        VisionTask::Dtd,
        VisionTask::EuroSat,
        VisionTask::Fgvc,
        VisionTask::Resisc,
    ];

    pub fn name(self) -> &'static str {
        match self {
            VisionTask::Pets => "pets",
            VisionTask::Cars => "cars",
            VisionTask::Dtd => "dtd",
            VisionTask::EuroSat => "eurosat",
            VisionTask::Fgvc => "fgvc",
            VisionTask::Resisc => "resisc",
        }
    }

    /// (#classes, noise σ) — class counts from the paper's Table A1,
    /// capped at the vit-sim head width (200).
    pub fn spec(self) -> (usize, f64) {
        match self {
            VisionTask::Pets => (37, 0.8),
            VisionTask::Cars => (196, 1.2),
            VisionTask::Dtd => (47, 1.0),
            VisionTask::EuroSat => (10, 0.6),
            VisionTask::Fgvc => (100, 1.3),
            VisionTask::Resisc => (45, 0.9),
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct VisionDataset {
    /// flattened [n][P*dp] patch features
    pub x: Vec<Vec<f32>>,
    pub labels: Vec<usize>,
    pub patches: usize,
    pub patch_dim: usize,
    pub n_classes: usize,
}

impl VisionDataset {
    pub fn len(&self) -> usize {
        self.x.len()
    }

    pub fn is_empty(&self) -> bool {
        self.x.is_empty()
    }

    /// Eval batch: x only.
    pub fn eval_batch(&self, idx: &[usize], b: usize) -> Vec<Tensor> {
        let mut full = self.batch(idx, b);
        full.truncate(1);
        full
    }

    /// Batch -> (x [B,P,dp] f32, y [B] i32).
    pub fn batch(&self, idx: &[usize], b: usize) -> Vec<Tensor> {
        let pd = self.patches * self.patch_dim;
        let mut xs = vec![0f32; b * pd];
        let mut ys = vec![0i32; b];
        for slot in 0..b {
            let &i = idx.get(slot).unwrap_or(&idx[0]);
            xs[slot * pd..(slot + 1) * pd].copy_from_slice(&self.x[i]);
            ys[slot] = self.labels[i] as i32;
        }
        vec![
            Tensor::from_f32(vec![b, self.patches, self.patch_dim], &xs),
            Tensor::from_i32(vec![b], &ys),
        ]
    }
}

pub fn splits(
    task: VisionTask,
    patches: usize,
    patch_dim: usize,
    seed: u64,
    n_train: usize,
) -> Splits<VisionDataset> {
    let (n_classes, sigma) = task.spec();
    let mut rng = Rng::seed(seed ^ (task as u64).wrapping_mul(0xA24BAED4963EE407));
    // class prototypes
    let protos: Vec<Vec<f32>> = (0..n_classes)
        .map(|_| rng.normal_vec(patches * patch_dim, 1.0))
        .collect();
    let gen = |n: usize, rng: &mut Rng| {
        let mut ds = VisionDataset {
            patches,
            patch_dim,
            n_classes,
            ..Default::default()
        };
        for _ in 0..n {
            let c = rng.below(n_classes);
            let mut x = protos[c].clone();
            for v in x.iter_mut() {
                *v += (rng.normal() * sigma) as f32;
            }
            // patch dropout: zero out 10% of patches
            for p in 0..patches {
                if rng.uniform() < 0.1 {
                    for v in x[p * patch_dim..(p + 1) * patch_dim].iter_mut() {
                        *v = 0.0;
                    }
                }
            }
            ds.x.push(x);
            ds.labels.push(c);
        }
        ds
    };
    Splits { train: gen(n_train, &mut rng), val: gen(256, &mut rng), test: gen(512, &mut rng) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_counts_match_paper() {
        assert_eq!(VisionTask::Cars.spec().0, 196);
        assert_eq!(VisionTask::EuroSat.spec().0, 10);
    }

    #[test]
    fn generates_separable_data() {
        let s = splits(VisionTask::EuroSat, 16, 16, 0, 512);
        assert_eq!(s.train.len(), 512);
        // nearest-prototype classification on clean stats should beat chance:
        // compute class means from train, classify val by nearest mean
        let pd = 16 * 16;
        let k = s.train.n_classes;
        let mut means = vec![vec![0f64; pd]; k];
        let mut counts = vec![0usize; k];
        for (x, &y) in s.train.x.iter().zip(&s.train.labels) {
            counts[y] += 1;
            for (m, &v) in means[y].iter_mut().zip(x) {
                *m += v as f64;
            }
        }
        for (m, &c) in means.iter_mut().zip(&counts) {
            for v in m.iter_mut() {
                *v /= c.max(1) as f64;
            }
        }
        let mut correct = 0;
        for (x, &y) in s.val.x.iter().zip(&s.val.labels) {
            let mut best = (f64::MAX, 0usize);
            for (c, m) in means.iter().enumerate() {
                let d: f64 = m.iter().zip(x).map(|(a, &b)| (a - b as f64).powi(2)).sum();
                if d < best.0 {
                    best = (d, c);
                }
            }
            if best.1 == y {
                correct += 1;
            }
        }
        let acc = correct as f64 / s.val.len() as f64;
        assert!(acc > 0.5, "nearest-mean acc {acc}");
    }

    #[test]
    fn harder_tasks_have_more_classes_or_noise() {
        let (kc, sc) = VisionTask::Cars.spec();
        let (ke, se) = VisionTask::EuroSat.spec();
        assert!(kc > ke && sc > se);
    }
}
