//! The paper's Fig. 4 / Fig. A1 synthetic dataset: 8 Gaussian clusters on
//! a 2-D plane, 30 points each, classified by a 3-layer MLP whose middle
//! layer is dense / LoRA(r=1) / C3A(b=128/2).

use crate::substrate::prng::Rng;
use crate::substrate::tensor::Tensor;

#[derive(Clone, Debug)]
pub struct ClusterData {
    pub x: Vec<[f32; 2]>,
    pub y: Vec<usize>,
    pub centers: Vec<[f32; 2]>,
}

/// Paper setup: 8 centers, 30 samples each.
pub fn generate(seed: u64) -> ClusterData {
    generate_with(seed, 8, 30, 2.5, 0.35)
}

pub fn generate_with(seed: u64, k: usize, per: usize, radius: f64, sigma: f64) -> ClusterData {
    let mut rng = Rng::seed(seed ^ 0xC1u64);
    let centers: Vec<[f32; 2]> = (0..k)
        .map(|i| {
            let ang = 2.0 * std::f64::consts::PI * i as f64 / k as f64;
            [(radius * ang.cos()) as f32, (radius * ang.sin()) as f32]
        })
        .collect();
    let mut x = Vec::with_capacity(k * per);
    let mut y = Vec::with_capacity(k * per);
    for (c, ctr) in centers.iter().enumerate() {
        for _ in 0..per {
            x.push([
                ctr[0] + (rng.normal() * sigma) as f32,
                ctr[1] + (rng.normal() * sigma) as f32,
            ]);
            y.push(c);
        }
    }
    // shuffle
    let perm = rng.permutation(x.len());
    let x = perm.iter().map(|&i| x[i]).collect();
    let y = perm.iter().map(|&i| y[i]).collect();
    ClusterData { x, y, centers }
}

impl ClusterData {
    pub fn len(&self) -> usize {
        self.x.len()
    }

    pub fn is_empty(&self) -> bool {
        self.x.is_empty()
    }

    /// Batch -> (x [B,2] f32, y [B] i32); wraps indices cyclically.
    pub fn batch(&self, start: usize, b: usize) -> Vec<Tensor> {
        let mut xs = vec![0f32; b * 2];
        let mut ys = vec![0i32; b];
        for s in 0..b {
            let i = (start + s) % self.len();
            xs[2 * s] = self.x[i][0];
            xs[2 * s + 1] = self.x[i][1];
            ys[s] = self.y[i] as i32;
        }
        vec![Tensor::from_f32(vec![b, 2], &xs), Tensor::from_i32(vec![b], &ys)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_shape() {
        let d = generate(0);
        assert_eq!(d.len(), 240);
        assert_eq!(d.centers.len(), 8);
        let mut counts = [0usize; 8];
        for &c in &d.y {
            counts[c] += 1;
        }
        assert!(counts.iter().all(|&c| c == 30));
    }

    #[test]
    fn clusters_are_separated() {
        let d = generate(1);
        // every point is closer to its own center than to the opposite one
        let mut ok = 0;
        for (p, &c) in d.x.iter().zip(&d.y) {
            let own = d.centers[c];
            let opp = d.centers[(c + 4) % 8];
            let d_own = (p[0] - own[0]).powi(2) + (p[1] - own[1]).powi(2);
            let d_opp = (p[0] - opp[0]).powi(2) + (p[1] - opp[1]).powi(2);
            if d_own < d_opp {
                ok += 1;
            }
        }
        assert!(ok as f64 / d.len() as f64 > 0.99);
    }
}
