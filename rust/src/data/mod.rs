//! Synthetic workload generators (DESIGN.md §3 substitutions).
//!
//! The paper fine-tunes on GLUE, Commonsense170K, MetaMathQA, Magicoder,
//! and six vision datasets — none of which are available (or meaningful)
//! on this testbed.  Each generator here builds a *learnable* procedural
//! task with the same type signature, metric, and difficulty knobs as its
//! paper counterpart, so the PEFT-method comparisons exercise identical
//! code paths.
//!
//! Token-id convention (shared with the L2 models):
//!   0 = PAD, 1 = CLS/BOS, 2 = SEP, 3 = MASK, 4.. = content.

pub mod clusters;
pub mod corpus;
pub mod gen_sim;
pub mod glue_sim;
pub mod instr_sim;
pub mod vision_sim;

use crate::substrate::prng::Rng;
use crate::substrate::tensor::Tensor;

pub const PAD: i32 = 0;
pub const CLS: i32 = 1;
pub const SEP: i32 = 2;
pub const MASK: i32 = 3;
pub const CONTENT0: i32 = 4;

/// A sequence-classification / regression dataset (encoder tasks).
#[derive(Clone, Debug, Default)]
pub struct ClsDataset {
    /// token sequences (unpadded, CLS included)
    pub tokens: Vec<Vec<i32>>,
    /// class index (cls) or score (reg)
    pub labels: Vec<f32>,
    pub regression: bool,
    pub n_classes: usize,
}

impl ClsDataset {
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Eval batch: tokens only (eval artifacts carry no label input).
    pub fn eval_batch(&self, idx: &[usize], b: usize, s: usize) -> Vec<Tensor> {
        let mut full = self.batch(idx, b, s);
        full.truncate(1);
        full
    }

    /// Batch `idx` examples into (tokens [B,S] i32, y [B]) tensors.
    /// Short batches are padded by repeating index 0 (callers slice
    /// metrics to the true count).
    pub fn batch(&self, idx: &[usize], b: usize, s: usize) -> Vec<Tensor> {
        let mut toks = vec![PAD; b * s];
        let mut ys = vec![0f32; b];
        for slot in 0..b {
            let &i = idx.get(slot).unwrap_or(&idx[0]);
            let src = &self.tokens[i];
            let n = src.len().min(s);
            toks[slot * s..slot * s + n].copy_from_slice(&src[..n]);
            ys[slot] = self.labels[i];
        }
        let tok_t = Tensor::from_i32(vec![b, s], &toks);
        let y_t = if self.regression {
            Tensor::from_f32(vec![b], &ys)
        } else {
            Tensor::from_i32(vec![b], &ys.iter().map(|&v| v as i32).collect::<Vec<_>>())
        };
        vec![tok_t, y_t]
    }
}

/// Train/validation/test split triple.
#[derive(Clone, Debug)]
pub struct Splits<T> {
    pub train: T,
    pub val: T,
    pub test: T,
}

/// Deterministic sizes used across the GLUE-sim suite.
pub const GLUE_TRAIN: usize = 2048;
pub const GLUE_VAL: usize = 256;
pub const GLUE_TEST: usize = 512;

/// Draw `n` distinct content tokens in [CONTENT0, vocab).
pub fn sample_content(rng: &mut Rng, vocab: usize, n: usize) -> Vec<i32> {
    (0..n).map(|_| (CONTENT0 as usize + rng.below(vocab - CONTENT0 as usize)) as i32).collect()
}

/// An epoch-shuffling batch iterator over example indices.
pub struct BatchIter {
    order: Vec<usize>,
    pos: usize,
    batch: usize,
    rng: Rng,
}

impl BatchIter {
    pub fn new(n: usize, batch: usize, seed: u64) -> Self {
        let mut rng = Rng::seed(seed);
        let order = rng.permutation(n);
        Self { order, pos: 0, batch, rng }
    }

    /// Next batch of indices, reshuffling at epoch boundaries.
    pub fn next_batch(&mut self) -> Vec<usize> {
        if self.pos + self.batch > self.order.len() {
            let n = self.order.len();
            self.order = self.rng.permutation(n);
            self.pos = 0;
        }
        let out = self.order[self.pos..self.pos + self.batch].to_vec();
        self.pos += self.batch;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_pads_and_truncates() {
        let ds = ClsDataset {
            tokens: vec![vec![CLS, 5, 6], vec![CLS, 7, 8, 9, 10, 11]],
            labels: vec![1.0, 0.0],
            regression: false,
            n_classes: 2,
        };
        let b = ds.batch(&[0, 1], 3, 4);
        let toks = b[0].as_i32();
        assert_eq!(toks.len(), 12);
        assert_eq!(&toks[0..4], &[CLS, 5, 6, PAD]); // padded
        assert_eq!(&toks[4..8], &[CLS, 7, 8, 9]); // truncated
        assert_eq!(&toks[8..12], &[CLS, 5, 6, PAD]); // repeat-filled slot
        assert_eq!(b[1].as_i32(), vec![1, 0, 1]);
    }

    #[test]
    fn batch_iter_covers_epoch() {
        let mut it = BatchIter::new(10, 3, 7);
        let mut seen = vec![0usize; 10];
        for _ in 0..3 {
            for i in it.next_batch() {
                seen[i] += 1;
            }
        }
        assert_eq!(seen.iter().sum::<usize>(), 9);
        assert!(seen.iter().all(|&c| c <= 1));
        // epoch rollover reshuffles without panicking
        for _ in 0..10 {
            it.next_batch();
        }
    }

    #[test]
    fn sample_content_in_range() {
        let mut rng = Rng::seed(1);
        for t in sample_content(&mut rng, 64, 100) {
            assert!((CONTENT0..64).contains(&t));
        }
    }
}
