//! Instruction-sim: eight multiple-choice "commonsense" datasets mirroring
//! the paper's Table 3 columns (BoolQ, PIQA, SIQA, HellaSwag, WinoGrande,
//! ARC-e, ARC-c, OBQA).
//!
//! Every example is a prompt ending in an ANSWER slot; the gold answer is
//! one of `n_options` dedicated option tokens.  Fine-tuning minimizes LM
//! cross-entropy at the answer position; evaluation scores the option
//! tokens' logits there (the paper's first-keyword protocol, made exact).

use super::{Splits, CLS, CONTENT0, PAD, SEP};
use crate::substrate::prng::Rng;
use crate::substrate::tensor::Tensor;

/// Option tokens live in a reserved band right after the specials.
pub const OPT0: i32 = CONTENT0; // options = OPT0..OPT0+n_options
pub const ITEM0: i32 = CONTENT0 + 8; // content band for prompts

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum McTask {
    BoolQ,
    Piqa,
    Siqa,
    HellaSwag,
    WinoGrande,
    ArcE,
    ArcC,
    Obqa,
}

impl McTask {
    pub const ALL: [McTask; 8] = [
        McTask::BoolQ,
        McTask::Piqa,
        McTask::Siqa,
        McTask::HellaSwag,
        McTask::WinoGrande,
        McTask::ArcE,
        McTask::ArcC,
        McTask::Obqa,
    ];

    pub fn name(self) -> &'static str {
        match self {
            McTask::BoolQ => "boolq",
            McTask::Piqa => "piqa",
            McTask::Siqa => "siqa",
            McTask::HellaSwag => "hellaswag",
            McTask::WinoGrande => "winogrande",
            McTask::ArcE => "arc_e",
            McTask::ArcC => "arc_c",
            McTask::Obqa => "obqa",
        }
    }

    pub fn n_options(self) -> usize {
        match self {
            McTask::BoolQ | McTask::WinoGrande | McTask::Piqa => 2,
            McTask::Siqa => 3,
            _ => 4,
        }
    }
}

/// One MC example: full token sequence (answer token included at
/// `answer_pos`), LM loss mask selecting only the answer prediction.
#[derive(Clone, Debug)]
pub struct McExample {
    pub tokens: Vec<i32>,
    pub answer_pos: usize,
    pub gold: usize,
    pub n_options: usize,
}

#[derive(Clone, Debug, Default)]
pub struct McDataset {
    pub examples: Vec<McExample>,
}

impl McDataset {
    pub fn len(&self) -> usize {
        self.examples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.examples.is_empty()
    }

    /// LM train batch: (tokens [B,S], loss_mask [B,S]) — mask only at the
    /// position *predicting* the answer token (answer_pos - 1).
    pub fn batch(&self, idx: &[usize], b: usize, s: usize) -> Vec<Tensor> {
        let mut toks = vec![PAD; b * s];
        let mut mask = vec![0f32; b * s];
        for slot in 0..b {
            let &i = idx.get(slot).unwrap_or(&idx[0]);
            let ex = &self.examples[i];
            let n = ex.tokens.len().min(s);
            toks[slot * s..slot * s + n].copy_from_slice(&ex.tokens[..n]);
            if ex.answer_pos < s {
                mask[slot * s + ex.answer_pos - 1] = 1.0;
            }
        }
        vec![Tensor::from_i32(vec![b, s], &toks), Tensor::from_f32(vec![b, s], &mask)]
    }

    /// Eval batch (tokens only — eval artifacts carry no loss mask), with
    /// the answer token *removed* (PAD) so scoring is honest.
    pub fn eval_batch(&self, idx: &[usize], b: usize, s: usize) -> Vec<Tensor> {
        let out = self.batch(idx, b, s);
        let mut toks = out[0].as_i32();
        for slot in 0..b {
            let &i = idx.get(slot).unwrap_or(&idx[0]);
            let ex = &self.examples[i];
            if ex.answer_pos < s {
                toks[slot * s + ex.answer_pos] = PAD;
            }
        }
        vec![Tensor::from_i32(vec![b, s], &toks)]
    }
}

/// Generate one task's splits: `n_train` plus fixed val/test.
pub fn splits(
    task: McTask,
    vocab: usize,
    seq: usize,
    seed: u64,
    n_train: usize,
) -> Splits<McDataset> {
    let mut rng = Rng::seed(seed ^ (task as u64).wrapping_mul(0x9e3779b9));
    let gen = |n: usize, rng: &mut Rng| McDataset {
        examples: (0..n).map(|_| generate(task, vocab, seq, rng)).collect(),
    };
    Splits {
        train: gen(n_train, &mut rng),
        val: gen(128, &mut rng),
        test: gen(256, &mut rng),
    }
}

fn generate(task: McTask, vocab: usize, seq: usize, rng: &mut Rng) -> McExample {
    let k = task.n_options();
    let body_max = seq - 4;
    // difficulty knobs: ARC-c & HellaSwag use longer bodies + more noise
    let (body_len, noise) = match task {
        McTask::ArcC => (10 + rng.below(body_max - 10), 2),
        McTask::HellaSwag => (8 + rng.below(body_max - 8), 1),
        _ => (5 + rng.below((body_max / 2).max(6)), 0),
    };
    let content = |rng: &mut Rng| (ITEM0 as usize + rng.below(vocab - ITEM0 as usize)) as i32;
    let mut body: Vec<i32> = (0..body_len).map(|_| content(rng)).collect();
    let gold = rng.below(k);

    // The latent rule, per task family: the gold option index is a simple
    // deterministic function of the prompt that the model must discover.
    match task {
        McTask::BoolQ => {
            // yes iff marker token present
            let marker = ITEM0 + 1;
            body.retain(|&t| t != marker);
            if gold == 1 {
                let at = rng.below(body.len());
                body.insert(at, marker);
            }
        }
        McTask::Piqa | McTask::WinoGrande => {
            // parity of the first content token selects among 2
            loop {
                if (body[0] % 2) as usize == gold {
                    break;
                }
                body[0] = content(rng);
            }
        }
        McTask::Siqa => {
            // first token's residue mod 3 selects among the options
            let base = body[0] - (body[0] - ITEM0).rem_euclid(3);
            let mut t = base + gold as i32;
            if t >= vocab as i32 {
                t -= 3;
            }
            body[0] = t;
        }
        McTask::HellaSwag | McTask::ArcE | McTask::ArcC | McTask::Obqa => {
            // residue of the *last* content token mod k ("which continuation
            // fits the ending") — positional retrieval, learnable
            let last = body.len() - 1;
            loop {
                if ((body[last] - ITEM0).rem_euclid(k as i32)) as usize == gold {
                    break;
                }
                body[last] = content(rng);
            }
        }
    }
    for _ in 0..noise {
        if body.len() > 2 {
            let at = 1 + rng.below(body.len() - 2); // keep first/last intact
            body[at] = content(rng);
        }
    }
    // re-fix after noise for the positional rules
    match task {
        McTask::Piqa | McTask::WinoGrande => loop {
            if (body[0] % 2) as usize == gold {
                break;
            }
            body[0] = content(rng);
        },
        McTask::HellaSwag | McTask::ArcE | McTask::ArcC | McTask::Obqa => {
            let last = body.len() - 1;
            loop {
                if ((body[last] - ITEM0).rem_euclid(k as i32)) as usize == gold {
                    break;
                }
                body[last] = content(rng);
            }
        }
        _ => {}
    }

    let mut tokens = vec![CLS];
    tokens.extend(&body);
    tokens.push(SEP);
    let answer_pos = tokens.len();
    tokens.push(OPT0 + gold as i32);
    McExample { tokens, answer_pos, gold, n_options: k }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tasks_generate() {
        for task in McTask::ALL {
            let s = splits(task, 512, 48, 0, 64);
            assert_eq!(s.train.len(), 64);
            for ex in &s.train.examples {
                assert!(ex.tokens.len() <= 48);
                assert_eq!(ex.tokens[ex.answer_pos], OPT0 + ex.gold as i32);
                assert!(ex.gold < task.n_options());
            }
        }
    }

    #[test]
    fn boolq_rule_holds() {
        let s = splits(McTask::BoolQ, 512, 48, 1, 200);
        for ex in &s.train.examples {
            let has = ex.tokens[1..ex.answer_pos - 1].contains(&(ITEM0 + 1));
            assert_eq!(has, ex.gold == 1);
        }
    }

    #[test]
    fn parity_rule_holds() {
        let s = splits(McTask::Piqa, 512, 48, 2, 200);
        for ex in &s.train.examples {
            let body = &ex.tokens[1..ex.answer_pos - 1];
            assert_eq!((body[0] % 2) as usize, ex.gold);
        }
    }

    #[test]
    fn last_token_rule_holds() {
        let s = splits(McTask::Obqa, 512, 48, 5, 200);
        for ex in &s.train.examples {
            let body = &ex.tokens[1..ex.answer_pos - 1];
            let last = *body.last().unwrap();
            assert_eq!(((last - ITEM0).rem_euclid(4)) as usize, ex.gold);
        }
    }

    #[test]
    fn eval_batch_hides_answer() {
        let s = splits(McTask::Obqa, 512, 48, 3, 8);
        let idx: Vec<usize> = (0..8).collect();
        let b = s.train.eval_batch(&idx, 8, 48);
        let toks = b[0].as_i32();
        for (slot, ex) in s.train.examples.iter().enumerate() {
            assert_eq!(toks[slot * 48 + ex.answer_pos], PAD);
        }
    }

    #[test]
    fn labels_roughly_balanced() {
        for task in [McTask::BoolQ, McTask::Obqa] {
            let s = splits(task, 512, 48, 4, 512);
            let k = task.n_options();
            let mut counts = vec![0usize; k];
            for ex in &s.train.examples {
                counts[ex.gold] += 1;
            }
            for &c in &counts {
                assert!(c > 512 / k / 2, "{task:?} {counts:?}");
            }
        }
    }
}
