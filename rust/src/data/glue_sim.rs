//! GLUE-sim: six procedural tasks mirroring the paper's Table 2 columns
//! (SST-2, MRPC, CoLA, QNLI, RTE, STS-B) — same task types and metrics.
//!
//! Every task is *learnable from token statistics alone* (the latent rule
//! is a deterministic function of token ids under a seeded permutation),
//! so a small pretrained encoder separates methods by adapter capacity —
//! which is what Table 2 compares.

use super::{sample_content, ClsDataset, Splits, CLS, CONTENT0, SEP};
use crate::substrate::prng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum GlueTask {
    Sst2,
    Mrpc,
    Cola,
    Qnli,
    Rte,
    Stsb,
}

impl GlueTask {
    pub const ALL: [GlueTask; 6] = [
        GlueTask::Sst2,
        GlueTask::Mrpc,
        GlueTask::Cola,
        GlueTask::Qnli,
        GlueTask::Rte,
        GlueTask::Stsb,
    ];

    pub fn name(self) -> &'static str {
        match self {
            GlueTask::Sst2 => "sst2",
            GlueTask::Mrpc => "mrpc",
            GlueTask::Cola => "cola",
            GlueTask::Qnli => "qnli",
            GlueTask::Rte => "rte",
            GlueTask::Stsb => "stsb",
        }
    }

    pub fn parse(s: &str) -> Option<GlueTask> {
        Self::ALL.into_iter().find(|t| t.name() == s)
    }

    /// Paper metric for this task.
    pub fn metric_name(self) -> &'static str {
        match self {
            GlueTask::Cola => "mcc",
            GlueTask::Stsb => "pcc",
            _ => "acc",
        }
    }

    pub fn is_regression(self) -> bool {
        self == GlueTask::Stsb
    }

    /// Artifact head for this task.
    pub fn head(self) -> &'static str {
        if self.is_regression() {
            "reg"
        } else {
            "cls"
        }
    }

    /// Generate the standard splits.
    pub fn splits(self, vocab: usize, seq: usize, seed: u64) -> Splits<ClsDataset> {
        let mut rng = Rng::seed(seed ^ g_hash(self.name()));
        let gen = |rng: &mut Rng, n: usize| generate(self, vocab, seq, n, rng);
        Splits {
            train: gen(&mut rng, super::GLUE_TRAIN),
            val: gen(&mut rng, super::GLUE_VAL),
            test: gen(&mut rng, super::GLUE_TEST),
        }
    }
}

/// FNV-1a over the task name: decorrelates per-task RNG streams.
fn g_hash(s: &str) -> u64 {
    crate::substrate::prng::fnv1a(s)
}

fn generate(task: GlueTask, vocab: usize, seq: usize, n: usize, rng: &mut Rng) -> ClsDataset {
    let mut ds = ClsDataset {
        regression: task.is_regression(),
        n_classes: 2,
        ..Default::default()
    };
    // hidden per-token valence in {-1, 0, +1}: a seeded permutation of ids
    let content = vocab - CONTENT0 as usize;
    let mut val_rng = Rng::seed(0xC3A0 ^ task as u64);
    let valence: Vec<i32> = (0..content).map(|_| val_rng.below(3) as i32 - 1).collect();
    let max_body = seq - 1;

    for _ in 0..n {
        match task {
            GlueTask::Sst2 => {
                // sentiment = sign of summed valence (resample ties)
                loop {
                    let len = 5 + rng.below(max_body - 5);
                    let toks = sample_content(rng, vocab, len);
                    let score: i32 =
                        toks.iter().map(|&t| valence[(t - CONTENT0) as usize]).sum();
                    if score == 0 {
                        continue;
                    }
                    let mut s = vec![CLS];
                    s.extend(toks);
                    ds.tokens.push(s);
                    ds.labels.push(if score > 0 { 1.0 } else { 0.0 });
                    break;
                }
            }
            GlueTask::Mrpc => {
                // paraphrase: B reuses A's tokens (shuffled) vs B drawn fresh —
                // detectable from cross-segment token overlap
                let la = 4 + rng.below((max_body - 3) / 2 - 4);
                let a = sample_content(rng, vocab, la);
                let pos = rng.below(2) == 1;
                let b = if pos {
                    let p = rng.permutation(a.len());
                    p.into_iter().map(|i| a[i]).collect()
                } else {
                    // fresh tokens, guaranteed disjoint from A
                    let mut b = Vec::with_capacity(la);
                    while b.len() < la {
                        let t = sample_content(rng, vocab, 1)[0];
                        if !a.contains(&t) {
                            b.push(t);
                        }
                    }
                    b
                };
                let mut s = vec![CLS];
                s.extend(&a);
                s.push(SEP);
                s.extend(&b);
                ds.tokens.push(s);
                ds.labels.push(pos as i32 as f32);
            }
            GlueTask::Cola => {
                // "grammar": a seeded 12.5% of the vocabulary is ungrammatical
                // ("agreement violations"); a sentence is acceptable iff it
                // contains none of them.  MCC metric as in the paper.
                let banned = |t: i32| (t - CONTENT0) % 8 == 3;
                let len = 6 + rng.below(max_body - 6);
                let ok = rng.below(2) == 1;
                let mut toks = Vec::with_capacity(len);
                while toks.len() < len {
                    let t = sample_content(rng, vocab, 1)[0];
                    if !banned(t) {
                        toks.push(t);
                    }
                }
                if !ok {
                    // inject 1-2 violations
                    for _ in 0..1 + rng.below(2) {
                        let p = rng.below(len);
                        let mut t;
                        loop {
                            t = sample_content(rng, vocab, 1)[0];
                            if banned(t) {
                                break;
                            }
                        }
                        toks[p] = t;
                    }
                }
                let mut s = vec![CLS];
                s.extend(toks);
                ds.tokens.push(s);
                ds.labels.push(ok as i32 as f32);
            }
            GlueTask::Qnli => {
                // question token q; passage "answers" q iff partner(q) present
                let lp = 6 + rng.below(max_body - 3 - 6);
                let mut passage = sample_content(rng, vocab, lp);
                let q = sample_content(rng, vocab, 1)[0];
                let pos = rng.below(2) == 1;
                passage.retain(|&t| t != q);
                if pos {
                    let at = rng.below(passage.len().max(1));
                    passage.insert(at.min(passage.len()), q);
                }
                let mut s = vec![CLS, q, SEP];
                s.extend(passage);
                ds.tokens.push(s);
                ds.labels.push(pos as i32 as f32);
            }
            GlueTask::Rte => {
                // entailment: hypothesis ⊆ premise  vs  hypothesis ⊄ premise
                let lp = 8 + rng.below((max_body - 1) / 2 - 6);
                let premise = sample_content(rng, vocab, lp);
                let lh = 2 + rng.below(2);
                let pos = rng.below(2) == 1;
                let hyp: Vec<i32> = if pos {
                    rng.choose(premise.len(), lh).into_iter().map(|i| premise[i]).collect()
                } else {
                    // every hypothesis token novel — "new information"
                    let mut h = Vec::with_capacity(lh);
                    while h.len() < lh {
                        let t = sample_content(rng, vocab, 1)[0];
                        if !premise.contains(&t) {
                            h.push(t);
                        }
                    }
                    h
                };
                let mut s = vec![CLS];
                s.extend(&premise);
                s.push(SEP);
                s.extend(&hyp);
                ds.tokens.push(s);
                ds.labels.push(pos as i32 as f32);
            }
            GlueTask::Stsb => {
                // similarity score in [0,5]: 5 × |A∩B| / |A∪B| of content sets
                let la = 5 + rng.below((max_body - 1) / 2 - 5);
                let a = sample_content(rng, vocab, la);
                let keep = rng.below(la + 1);
                let kept: Vec<i32> =
                    rng.choose(la, keep).into_iter().map(|i| a[i]).collect();
                let mut b = kept.clone();
                b.extend(sample_content(rng, vocab, la - keep));
                use std::collections::BTreeSet;
                let sa: BTreeSet<i32> = a.iter().copied().collect();
                let sb: BTreeSet<i32> = b.iter().copied().collect();
                let inter = sa.intersection(&sb).count() as f32;
                let union = sa.union(&sb).count() as f32;
                let score = 5.0 * inter / union.max(1.0);
                let mut s = vec![CLS];
                s.extend(&a);
                s.push(SEP);
                s.extend(&b);
                ds.tokens.push(s);
                ds.labels.push(score);
            }
        }
    }
    ds
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tasks_generate_balanced_learnable_data() {
        for task in GlueTask::ALL {
            let s = task.splits(512, 32, 0);
            assert_eq!(s.train.len(), super::super::GLUE_TRAIN);
            assert_eq!(s.val.len(), super::super::GLUE_VAL);
            assert_eq!(s.test.len(), super::super::GLUE_TEST);
            for seq in &s.train.tokens {
                assert!(seq[0] == CLS && seq.len() <= 32, "{task:?}");
            }
            if !task.is_regression() {
                let pos: f32 = s.train.labels.iter().sum::<f32>() / s.train.len() as f32;
                assert!((0.3..0.7).contains(&pos), "{task:?} imbalanced: {pos}");
            } else {
                let lo = s.train.labels.iter().cloned().fold(f32::MAX, f32::min);
                let hi = s.train.labels.iter().cloned().fold(f32::MIN, f32::max);
                assert!(lo >= 0.0 && hi <= 5.0 && hi - lo > 1.0);
            }
        }
    }

    #[test]
    fn rte_negatives_contain_novel_token() {
        let s = GlueTask::Rte.splits(512, 32, 1);
        for (toks, &y) in s.train.tokens.iter().zip(&s.train.labels).take(200) {
            let sep = toks.iter().position(|&t| t == SEP).unwrap();
            let premise: std::collections::BTreeSet<i32> = toks[1..sep].iter().copied().collect();
            let hyp = &toks[sep + 1..];
            let subset = hyp.iter().all(|t| premise.contains(t));
            assert_eq!(subset, y == 1.0);
        }
    }

    #[test]
    fn splits_are_deterministic_per_seed() {
        let a = GlueTask::Sst2.splits(512, 32, 5);
        let b = GlueTask::Sst2.splits(512, 32, 5);
        assert_eq!(a.train.tokens, b.train.tokens);
        let c = GlueTask::Sst2.splits(512, 32, 6);
        assert_ne!(a.train.tokens, c.train.tokens);
    }

    #[test]
    fn task_streams_differ() {
        let a = GlueTask::Sst2.splits(512, 32, 5);
        let b = GlueTask::Cola.splits(512, 32, 5);
        assert_ne!(a.train.tokens, b.train.tokens);
    }
}
