//! PEFT method registry: parameter/memory/FLOP accounting (paper Table 1),
//! adapter initialization schemes (paper Fig. 3), and adapter merging.

pub mod accounting;
pub mod init;
pub mod merge;

/// The PEFT methods the paper evaluates.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    Full,
    Head,
    BitFit,
    Ia3,
    Lora,
    Dora,
    Vera,
    Boft,
    C3a,
}

impl Method {
    pub fn parse(s: &str) -> Option<Method> {
        Some(match s {
            "full" => Method::Full,
            "head" => Method::Head,
            "bitfit" => Method::BitFit,
            "ia3" => Method::Ia3,
            "lora" => Method::Lora,
            "dora" => Method::Dora,
            "vera" => Method::Vera,
            "boft" => Method::Boft,
            s if s.starts_with("c3a") => Method::C3a,
            s if s.starts_with("mlp_") => Method::Full,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Method::Full => "full",
            Method::Head => "head",
            Method::BitFit => "bitfit",
            Method::Ia3 => "ia3",
            Method::Lora => "lora",
            Method::Dora => "dora",
            Method::Vera => "vera",
            Method::Boft => "boft",
            Method::C3a => "c3a",
        }
    }
}
