//! Adapter merging: fold a learned delta into the frozen weight so the
//! deployed model has **zero inference overhead** — the delta-weight
//! family's signature property (paper §2.1).
//!
//! C3A merging uses the paper's Algorithm A2 (convolve identity columns)
//! through the rust FFT substrate; LoRA merging is a rank-r outer-product
//! update.  Weight layout matches the JAX side: W[d_in][d_out], y = x·W.

use crate::substrate::circulant::BlockCirculant;

/// W_merged = W0 + ΔW^T where ΔW = C_blk(w) maps [d_in] -> [d_out].
///
/// `w0` is row-major [d_in][d_out] (JAX layout, y = x·W); the circulant
/// operator computes z = C·x with C [d_out][d_in], so its transpose is
/// added.  `kernels` is [m][n][b] with m·b = d_out, n·b = d_in.
pub fn merge_c3a(
    w0: &[f32],
    d_in: usize,
    d_out: usize,
    kernels: &[f32],
    m: usize,
    n: usize,
    b: usize,
) -> Vec<f32> {
    assert_eq!(w0.len(), d_in * d_out);
    assert_eq!(m * b, d_out);
    assert_eq!(n * b, d_in);
    let bc = BlockCirculant::new(m, n, b, kernels.iter().map(|&v| v as f64).collect());
    let delta = bc.materialize(); // [d_out][d_in]
    let mut out = w0.to_vec();
    for r in 0..d_out {
        for c in 0..d_in {
            out[c * d_out + r] += delta[r * d_in + c] as f32;
        }
    }
    out
}

/// W_merged = W0 + scale·(B·A)^T; A [r][d_in], B [d_out][r].
pub fn merge_lora(
    w0: &[f32],
    d_in: usize,
    d_out: usize,
    a: &[f32],
    bmat: &[f32],
    r: usize,
    scale: f32,
) -> Vec<f32> {
    assert_eq!(w0.len(), d_in * d_out);
    assert_eq!(a.len(), r * d_in);
    assert_eq!(bmat.len(), d_out * r);
    let mut out = w0.to_vec();
    for i in 0..d_out {
        for j in 0..d_in {
            let mut acc = 0.0f32;
            for k in 0..r {
                acc += bmat[i * r + k] * a[k * d_in + j];
            }
            out[j * d_out + i] += scale * acc;
        }
    }
    out
}

/// Unmerged inference check: y = x·W0 + C_blk(w)·x computed two ways.
pub fn c3a_forward_unmerged(
    w0: &[f32],
    d_in: usize,
    d_out: usize,
    kernels: &[f32],
    m: usize,
    n: usize,
    b: usize,
    x: &[f32],
) -> Vec<f32> {
    let xf: Vec<f64> = x.iter().map(|&v| v as f64).collect();
    let w0f: Vec<f64> = w0.iter().map(|&v| v as f64).collect();
    // y = x·W0: treat W0^T as [d_out][d_in]
    let mut y = vec![0.0f64; d_out];
    for c in 0..d_in {
        let xv = xf[c];
        if xv == 0.0 {
            continue;
        }
        for r in 0..d_out {
            y[r] += w0f[c * d_out + r] * xv;
        }
    }
    let bc = BlockCirculant::new(m, n, b, kernels.iter().map(|&v| v as f64).collect());
    let dz = bc.matvec(&xf);
    y.iter().zip(&dz).map(|(a, b)| (a + b) as f32).collect()
}

/// Dense forward through a merged weight (y = x·W).
pub fn dense_forward(w: &[f32], d_in: usize, d_out: usize, x: &[f32]) -> Vec<f32> {
    let wf: Vec<f64> = w.iter().map(|&v| v as f64).collect();
    let xf: Vec<f64> = x.iter().map(|&v| v as f64).collect();
    // y_o = Σ_i x_i W[i][o]
    let mut y = vec![0.0; d_out];
    for i in 0..d_in {
        let xv = xf[i];
        let row = &wf[i * d_out..(i + 1) * d_out];
        for o in 0..d_out {
            y[o] += xv * row[o];
        }
    }
    y.iter().map(|&v| v as f32).collect()
}


#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::linalg;
    use crate::substrate::prng::Rng;

    #[test]
    fn merged_equals_unmerged_c3a() {
        let mut rng = Rng::seed(1);
        let (m, n, b) = (2usize, 3usize, 8usize);
        let (d_out, d_in) = (m * b, n * b);
        let w0: Vec<f32> = (0..d_in * d_out).map(|_| rng.normal() as f32 * 0.1).collect();
        let k: Vec<f32> = (0..m * n * b).map(|_| rng.normal() as f32 * 0.1).collect();
        let x: Vec<f32> = (0..d_in).map(|_| rng.normal() as f32).collect();
        let merged = merge_c3a(&w0, d_in, d_out, &k, m, n, b);
        let y1 = dense_forward(&merged, d_in, d_out, &x);
        let y2 = c3a_forward_unmerged(&w0, d_in, d_out, &k, m, n, b, &x);
        for (a, bv) in y1.iter().zip(&y2) {
            assert!((a - bv).abs() < 1e-4, "{a} vs {bv}");
        }
    }

    #[test]
    fn merged_equals_unmerged_lora() {
        let mut rng = Rng::seed(2);
        let (d_in, d_out, r) = (12usize, 10usize, 3usize);
        let w0: Vec<f32> = (0..d_in * d_out).map(|_| rng.normal() as f32 * 0.1).collect();
        let a: Vec<f32> = (0..r * d_in).map(|_| rng.normal() as f32 * 0.1).collect();
        let bm: Vec<f32> = (0..d_out * r).map(|_| rng.normal() as f32 * 0.1).collect();
        let x: Vec<f32> = (0..d_in).map(|_| rng.normal() as f32).collect();
        let scale = 2.0f32;
        let merged = merge_lora(&w0, d_in, d_out, &a, &bm, r, scale);
        let y1 = dense_forward(&merged, d_in, d_out, &x);
        // reference: x·W0 + scale·B(Ax)
        let delta = linalg::LoRaDelta {
            a: a.iter().map(|&v| v as f64).collect(),
            b: bm.iter().map(|&v| v as f64).collect(),
            r,
            d_in,
            d_out,
            scale: scale as f64,
        };
        let base = dense_forward(&w0, d_in, d_out, &x);
        let dz = delta.matvec(&x.iter().map(|&v| v as f64).collect::<Vec<_>>());
        for o in 0..d_out {
            let want = base[o] + dz[o] as f32;
            assert!((y1[o] - want).abs() < 1e-4);
        }
    }

    #[test]
    fn zero_kernel_merge_is_identity() {
        let mut rng = Rng::seed(3);
        let (m, n, b) = (2usize, 2usize, 4usize);
        let (d_out, d_in) = (m * b, n * b);
        let w0: Vec<f32> = (0..d_in * d_out).map(|_| rng.normal() as f32).collect();
        let k = vec![0.0f32; m * n * b];
        assert_eq!(merge_c3a(&w0, d_in, d_out, &k, m, n, b), w0);
    }
}
