//! Adapter initialization — executes the declarative init specs from the
//! artifact manifest, plus the paper's Fig. 3 schemes for C3A kernels.

use crate::substrate::prng::Rng;
use crate::substrate::tensor::Tensor;
use anyhow::{bail, Result};

/// The paper's Fig. 3 initialization ablation schemes for C3A kernels.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum C3aScheme {
    Zero,
    Gaussian,
    Kaiming,
    Xavier,
}

impl C3aScheme {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "zero" => C3aScheme::Zero,
            "gaussian" => C3aScheme::Gaussian,
            "kaiming" => C3aScheme::Kaiming,
            "xavier" | "default" => C3aScheme::Xavier,
            _ => return None,
        })
    }

    pub const ALL: [C3aScheme; 4] =
        [C3aScheme::Zero, C3aScheme::Gaussian, C3aScheme::Kaiming, C3aScheme::Xavier];

    pub fn name(self) -> &'static str {
        match self {
            C3aScheme::Zero => "zero",
            C3aScheme::Gaussian => "gaussian",
            C3aScheme::Kaiming => "kaiming",
            C3aScheme::Xavier => "xavier",
        }
    }
}

/// A declarative init spec (mirrors python/compile/aot.py `init_spec`).
#[derive(Clone, Debug)]
pub enum InitSpec {
    Zeros,
    Ones,
    Const(f64),
    /// N(0, 1/√fan)
    NormalFanin { fan: usize, seed: Option<u64> },
    /// C3A kernel — scheme selected at run time (Fig. 3)
    C3a { fan_in: usize, fan_out: usize },
}

impl InitSpec {
    pub fn from_json(v: &crate::substrate::json::Json) -> Result<InitSpec> {
        let kind = v.get("kind").and_then(|k| k.as_str()).unwrap_or("zeros");
        Ok(match kind {
            "zeros" => InitSpec::Zeros,
            "ones" => InitSpec::Ones,
            "const" => InitSpec::Const(v.get("value").and_then(|x| x.as_f64()).unwrap_or(0.0)),
            "normal_fanin" => InitSpec::NormalFanin {
                fan: v.get("fan").and_then(|x| x.as_usize()).unwrap_or(1),
                seed: v.get("seed").and_then(|x| x.as_f64()).map(|s| s as u64),
            },
            "c3a" => InitSpec::C3a {
                fan_in: v.get("fan_in").and_then(|x| x.as_usize()).unwrap_or(1),
                fan_out: v.get("fan_out").and_then(|x| x.as_usize()).unwrap_or(1),
            },
            other => bail!("unknown init kind {other}"),
        })
    }

    /// Materialize a tensor for this spec.
    pub fn materialize(
        &self,
        shape: &[usize],
        rng: &mut Rng,
        scheme: C3aScheme,
    ) -> Tensor {
        let n = shape.iter().product::<usize>().max(1);
        let values = match self {
            InitSpec::Zeros => vec![0.0f32; n],
            InitSpec::Ones => vec![1.0f32; n],
            InitSpec::Const(v) => vec![*v as f32; n],
            InitSpec::NormalFanin { fan, seed } => {
                let mut local;
                let r = match seed {
                    Some(s) => {
                        local = Rng::seed(*s);
                        &mut local
                    }
                    None => rng,
                };
                r.normal_vec(n, 1.0 / (*fan as f64).sqrt())
            }
            InitSpec::C3a { fan_in, fan_out } => match scheme {
                C3aScheme::Zero => vec![0.0f32; n],
                C3aScheme::Gaussian => rng.normal_vec(n, 0.02),
                C3aScheme::Kaiming => {
                    let lim = (3.0 / *fan_in as f64).sqrt() * std::f64::consts::SQRT_2;
                    rng.uniform_vec(n, -lim, lim)
                }
                C3aScheme::Xavier => {
                    let lim = (6.0 / (*fan_in + *fan_out) as f64).sqrt();
                    rng.uniform_vec(n, -lim, lim)
                }
            },
        };
        Tensor::from_f32(shape.to_vec(), &values)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_ones_const() {
        let mut rng = Rng::seed(0);
        let zeros = InitSpec::Zeros.materialize(&[3], &mut rng, C3aScheme::Xavier);
        assert_eq!(zeros.as_f32(), vec![0.0; 3]);
        let ones = InitSpec::Ones.materialize(&[2], &mut rng, C3aScheme::Xavier);
        assert_eq!(ones.as_f32(), vec![1.0; 2]);
        let c = InitSpec::Const(0.1).materialize(&[1], &mut rng, C3aScheme::Xavier);
        assert_eq!(c.as_f32(), vec![0.1]);
    }

    #[test]
    fn seeded_normal_is_reproducible() {
        let spec = InitSpec::NormalFanin { fan: 16, seed: Some(99) };
        let mut r1 = Rng::seed(1);
        let mut r2 = Rng::seed(2);
        let a = spec.materialize(&[8], &mut r1, C3aScheme::Xavier).as_f32();
        let b = spec.materialize(&[8], &mut r2, C3aScheme::Xavier).as_f32();
        assert_eq!(a, b); // pinned seed overrides the stream
    }

    #[test]
    fn xavier_bounds() {
        let spec = InitSpec::C3a { fan_in: 64, fan_out: 64 };
        let mut rng = Rng::seed(3);
        let vals = spec.materialize(&[256], &mut rng, C3aScheme::Xavier).as_f32();
        let lim = (6.0f64 / 128.0).sqrt() as f32;
        assert!(vals.iter().all(|v| v.abs() <= lim));
        assert!(vals.iter().any(|v| v.abs() > 0.5 * lim)); // actually spreads
    }

    #[test]
    fn schemes_differ() {
        let spec = InitSpec::C3a { fan_in: 32, fan_out: 32 };
        let mut rng = Rng::seed(4);
        let z = spec.materialize(&[64], &mut rng, C3aScheme::Zero).as_f32();
        let g = spec.materialize(&[64], &mut rng, C3aScheme::Gaussian).as_f32();
        assert!(z.iter().all(|&v| v == 0.0));
        assert!(g.iter().any(|&v| v != 0.0));
    }

    #[test]
    fn parse_roundtrip() {
        for s in C3aScheme::ALL {
            assert_eq!(C3aScheme::parse(s.name()), Some(s));
        }
    }
}
