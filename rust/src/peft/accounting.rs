//! Analytic complexity accounting — regenerates the paper's Table 1.
//!
//! Per adapted `d1 × d2` projection:
//!
//! | method | time (MACs)                     | # params       | # other (aux) |
//! |--------|---------------------------------|----------------|---------------|
//! | LoRA   | r(d1+d2)                        | r(d1+d2)       | 0             |
//! | VeRA   | r_v(d1+d2)                      | r_v + d1       | r_v(d1+d2)    |
//! | C3A    | (d1+d2)/p·(b/2)log2(b) + d1d2/b | d1·d2/b        | p·b           |
//!
//! The C3A time term is the FFT cost ((b/2)·log2 b butterflies per length-b
//! transform, (d1+d2)/b transforms spread over p lanes) plus the
//! frequency-domain aggregation (d1·d2/b complex MACs).  Memory is modeled
//! in *bytes during training*: params + grads + AdamW (m, v) + frozen aux.

use super::Method;

/// One adapted projection's dimensions + method hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct ProjSpec {
    pub d1: usize, // output dim
    pub d2: usize, // input dim
    pub method: Method,
    pub rank: usize,     // lora/dora r
    pub r_v: usize,      // vera
    pub block: usize,    // c3a b
    pub boft_block: usize,
    pub lanes: usize, // p: FFT parallel lanes (cuFFT batch / thread pool)
}

impl ProjSpec {
    pub fn c3a(d: usize, block: usize) -> Self {
        Self { d1: d, d2: d, method: Method::C3a, rank: 0, r_v: 0, block, boft_block: 8, lanes: 1 }
    }

    pub fn lora(d: usize, rank: usize) -> Self {
        Self { d1: d, d2: d, method: Method::Lora, rank, r_v: 0, block: 0, boft_block: 8, lanes: 1 }
    }

    pub fn vera(d: usize, r_v: usize) -> Self {
        Self { d1: d, d2: d, method: Method::Vera, rank: 0, r_v, block: 0, boft_block: 8, lanes: 1 }
    }

    /// Trainable parameters added by the adapter (paper Table 1 "# Param").
    pub fn params(&self) -> usize {
        match self.method {
            Method::Lora => self.rank * (self.d1 + self.d2),
            Method::Dora => self.rank * (self.d1 + self.d2) + self.d1,
            Method::Vera => self.r_v + self.d1,
            Method::C3a => self.d1 * self.d2 / self.block,
            Method::Boft => {
                let bb = self.boft_block;
                (self.d1 / bb) * bb * bb
            }
            Method::Ia3 => self.d1,
            Method::BitFit => self.d1,
            Method::Head | Method::Full => 0,
        }
    }

    /// Auxiliary (non-trainable, non-delta) floats required at train time
    /// (paper Table 1 "# Other").
    pub fn aux_floats(&self) -> usize {
        match self.method {
            Method::Vera => self.r_v * (self.d1 + self.d2),
            Method::C3a => self.lanes * self.block,
            _ => 0,
        }
    }

    /// Adapter forward MACs for one activation vector (paper Table 1 "Time").
    pub fn time_macs(&self) -> f64 {
        let (d1, d2) = (self.d1 as f64, self.d2 as f64);
        match self.method {
            Method::Lora => self.rank as f64 * (d1 + d2),
            Method::Dora => self.rank as f64 * (d1 + d2) + 2.0 * d1,
            Method::Vera => self.r_v as f64 * (d1 + d2) + self.r_v as f64 + d1,
            Method::C3a => {
                let b = self.block as f64;
                let p = self.lanes as f64;
                // (d1+d2)/p · (1/2)log2 b per element
                let fft = (d1 + d2) / p * 0.5 * b.log2().max(1.0) / b * b;
                let agg = d1 * d2 / b;
                fft + agg
            }
            Method::Boft => d1 * self.boft_block as f64,
            Method::Ia3 | Method::BitFit => d1,
            Method::Head | Method::Full => 0.0,
        }
    }

    /// Bytes held live during training for this adapter:
    /// f32 × (params + grads + adam m + adam v) + aux.
    pub fn train_bytes(&self) -> usize {
        4 * (4 * self.params() + self.aux_floats())
    }
}

/// A whole-model accounting: the paper's "# Params" / "Mem" columns.
#[derive(Clone, Debug)]
pub struct ModelAccount {
    /// adapted projections (q, v per layer)
    pub projections: Vec<ProjSpec>,
    /// frozen backbone parameter count
    pub backbone_params: usize,
    /// activation-memory proxy: batch × seq × d × layers floats
    pub activation_floats: usize,
}

impl ModelAccount {
    pub fn trainable_params(&self) -> usize {
        self.projections.iter().map(|p| p.params()).sum()
    }

    pub fn aux_floats(&self) -> usize {
        self.projections.iter().map(|p| p.aux_floats()).sum()
    }

    /// Modeled training-memory bytes: frozen weights + adapters (w/ AdamW
    /// state + grads) + aux tensors + activations.  Mirrors the structural
    /// differences behind the paper's measured "Mem" column.
    pub fn train_bytes(&self) -> usize {
        let adapters: usize = self.projections.iter().map(|p| p.train_bytes()).sum();
        4 * (self.backbone_params + self.activation_floats) + adapters
    }
}

/// Account for a transformer with `layers` layers, width `d`, adapting q+v.
pub fn transformer_account(
    layers: usize,
    d: usize,
    backbone_params: usize,
    activation_floats: usize,
    mk: impl Fn(usize) -> ProjSpec,
) -> ModelAccount {
    let _ = d;
    ModelAccount {
        projections: (0..2 * layers).map(|_| mk(d)).collect(),
        backbone_params,
        activation_floats,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_paper_table2_param_counts() {
        // RoBERTa-base: 12 layers, d=768, q+v adapted.
        let lora: usize = (0..24).map(|_| ProjSpec::lora(768, 8).params()).sum();
        assert_eq!(lora, 294_912); // paper: 0.295M
        let c3a_d1: usize = (0..24).map(|_| ProjSpec::c3a(768, 768).params()).sum();
        assert_eq!(c3a_d1, 18_432); // paper: 0.018M
        let c3a_d6: usize = (0..24).map(|_| ProjSpec::c3a(768, 128).params()).sum();
        assert_eq!(c3a_d6, 110_592); // paper: 0.111M
        // RoBERTa-large: 24 layers, d=1024
        let c3a_l1: usize = (0..48).map(|_| ProjSpec::c3a(1024, 1024).params()).sum();
        assert_eq!(c3a_l1, 49_152); // paper: 0.049M
        let c3a_l8: usize = (0..48).map(|_| ProjSpec::c3a(1024, 128).params()).sum();
        assert_eq!(c3a_l8, 393_216); // paper: 0.393M
    }

    #[test]
    fn vera_params_tiny_but_aux_huge() {
        let v = ProjSpec::vera(1024, 1024);
        let l = ProjSpec::lora(1024, 8);
        assert!(v.params() < l.params());
        assert!(v.aux_floats() > 100 * l.params()); // the paper's memory critique
    }

    #[test]
    fn c3a_aux_negligible() {
        let c = ProjSpec { lanes: 8, ..ProjSpec::c3a(1024, 128) };
        assert!(c.aux_floats() <= 1024); // pb <= min(d1,d2)
    }

    #[test]
    fn c3a_time_comparable_to_lora() {
        // paper §3.5.1: with b = gcd(d1,d2), C3A time ≈ LoRA time.
        let c = ProjSpec { lanes: 8, ..ProjSpec::c3a(1024, 1024) };
        let l = ProjSpec::lora(1024, 8);
        let ratio = c.time_macs() / l.time_macs();
        assert!(ratio < 4.0, "ratio={ratio}");
        // and VeRA is far worse
        let v = ProjSpec::vera(1024, 1024);
        assert!(v.time_macs() > 10.0 * l.time_macs());
    }

    #[test]
    fn memory_ordering_matches_paper() {
        // Table 2 Mem column ordering: bitfit < c3a < lora < vera(ish)
        let act = 64 * 256 * 768 * 12; // batch=64, seq=256
        let backbone = 124_000_000;
        let mk_acc = |spec: fn(usize) -> ProjSpec| {
            transformer_account(12, 768, backbone, act, spec).train_bytes()
        };
        let c3a = mk_acc(|d| ProjSpec::c3a(d, d));
        let lora = mk_acc(|d| ProjSpec::lora(d, 8));
        let vera = mk_acc(|d| ProjSpec::vera(d, 1024));
        assert!(c3a < lora, "c3a={c3a} lora={lora}");
        assert!(lora < vera, "lora={lora} vera={vera}");
    }

    #[test]
    fn boft_params_match_paper_shape() {
        // params grow with block size but stay << full
        let b = ProjSpec {
            method: Method::Boft,
            ..ProjSpec::lora(768, 0)
        };
        assert!(b.params() > 0 && b.params() < 768 * 768);
    }
}
