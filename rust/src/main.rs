//! `c3a` — the framework launcher (hand-rolled CLI; clap unavailable
//! offline).
//!
//! Subcommands:
//!   info                      manifest / model / artifact inventory
//!   pretrain --model M        (re)build a backbone checkpoint
//!   train ...                 one fine-tuning run (any task family)
//!   exp <id> [--full] ...     regenerate a paper table/figure
//!   rank --block B --dim D    rank analysis demo of random kernels
//!
//! Run `c3a help` for flags.

use anyhow::{bail, Context, Result};
use c3a::coordinator::run::{self, Ctx};
use c3a::data::gen_sim::GenTask;
use c3a::data::glue_sim::GlueTask;
use c3a::data::instr_sim::McTask;
use c3a::data::vision_sim::VisionTask;
use c3a::exp::{self, ExpOpt};
use c3a::peft::init::C3aScheme;
use c3a::substrate::{circulant, polynomial};

/// Tiny flag parser: positional args + `--key value` + `--switch`.
struct Args {
    positional: Vec<String>,
    flags: std::collections::BTreeMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut positional = Vec::new();
        let mut flags = std::collections::BTreeMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                let next_is_value = argv.get(i + 1).map(|n| !n.starts_with("--")).unwrap_or(false);
                if next_is_value {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Args { positional, flags }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn get_usize(&self, key: &str) -> Option<usize> {
        self.get(key).and_then(|v| v.parse().ok())
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

const HELP: &str = "\
c3a — Parameter-Efficient Fine-Tuning via Circular Convolution (reproduction)

USAGE: c3a <command> [flags]

COMMANDS
  info                           list models and artifacts from the manifest
  pretrain --model M [--force]   build/refresh a backbone checkpoint
  train --model M --method X --task T [--steps N] [--seed S] [--lr F]
        [--config F]             one fine-tuning run; tasks: glue:<t>, mc:<t>,
                                 gen:<t>, vision:<t>, mlp:<variant>; or load
                                 a declarative run from configs/*.toml
  exp <id> [--full] [--steps N] [--seeds K] [--only SUBSTR]
                                 regenerate a paper table/figure; ids:
                                 table1 table2 table3 table4 table_a2
                                 fig1 fig3 fig4 fig5 all
  rank --dim D [--block B]       circulant rank analysis (numeric + exact)
  help                           this text

FLAGS
  --artifacts DIR   artifact directory (default: artifacts)
  --results DIR     results directory (default: results)
  --verbose         chatty progress
";

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv);
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "help" | "-h" => {
            print!("{HELP}");
            Ok(())
        }
        "info" => info(&args),
        "pretrain" => pretrain(&args),
        "train" => train(&args),
        "exp" => experiment(&args),
        "rank" => rank_demo(&args),
        other => bail!("unknown command {other} (try `c3a help`)"),
    }
}

fn open_ctx(args: &Args) -> Result<Ctx> {
    let dir = args.get("artifacts").unwrap_or("artifacts");
    let mut ctx = Ctx::open(dir)?;
    ctx.verbose = args.has("verbose");
    Ok(ctx)
}

fn info(args: &Args) -> Result<()> {
    let ctx = open_ctx(args)?;
    println!("models:");
    for (name, m) in &ctx.manifest.models {
        println!(
            "  {name:<12} kind={:<8} d={:<4} L={:<2} vocab={:<4} seq={}",
            m.kind, m.d, m.layers, m.vocab, m.seq
        );
    }
    println!("\nartifacts ({}):", ctx.manifest.artifacts.len());
    for (name, a) in &ctx.manifest.artifacts {
        println!("  {name:<44} {:>9} params  batch={}", a.n_params, a.batch);
    }
    Ok(())
}

fn pretrain(args: &Args) -> Result<()> {
    let model = args.get("model").context("--model required")?;
    let ctx = open_ctx(args)?;
    if args.has("force") {
        let p = c3a::coordinator::checkpoint::pretrained_path(&ctx.artifacts_dir, model);
        let _ = std::fs::remove_file(p);
    }
    let map = run::ensure_pretrained(&ctx, model)?;
    println!("backbone ready: {} tensors", map.len());
    Ok(())
}

fn train(args: &Args) -> Result<()> {
    // --config <file> loads a declarative run; explicit flags override it
    let file_cfg = match args.get("config") {
        Some(p) => Some(c3a::config::RunConfig::load(p)?),
        None => None,
    };
    let model = args
        .get("model")
        .map(str::to_string)
        .or_else(|| file_cfg.as_ref().map(|c| c.model.clone()))
        .context("--model or --config required")?;
    let method = args
        .get("method")
        .map(str::to_string)
        .or_else(|| file_cfg.as_ref().map(|c| c.method.clone()))
        .context("--method or --config required")?;
    let task = args
        .get("task")
        .map(str::to_string)
        .or_else(|| file_cfg.as_ref().map(|c| c.task.clone()))
        .context("--task or --config required (e.g. glue:sst2)")?;
    let seed = args
        .get_usize("seed")
        .map(|s| s as u64)
        .or_else(|| file_cfg.as_ref().map(|c| c.seed))
        .unwrap_or(0);
    let scheme = file_cfg
        .as_ref()
        .and_then(|c| C3aScheme::parse(&c.init_scheme))
        .unwrap_or(C3aScheme::Xavier);
    let mut cfg = file_cfg
        .as_ref()
        .map(|c| c.train.clone())
        .unwrap_or_else(|| run::default_cfg(&method, 100));
    if let Some(steps) = args.get_usize("steps") {
        cfg.steps = steps;
    }
    if let Some(lr) = args.get("lr").and_then(|v| v.parse::<f64>().ok()) {
        cfg.lr = lr;
    }
    cfg.verbose = true;
    let ctx = open_ctx(args)?;
    let (kind, name) = task.split_once(':').unwrap_or(("glue", task.as_str()));
    let r = match kind {
        "glue" => {
            let t = GlueTask::parse(name).context("unknown glue task")?;
            run::glue_run(&ctx, &model, &method, t, seed, &cfg, scheme)?
        }
        "mc" => {
            let t = McTask::ALL.into_iter().find(|t| t.name() == name).context("unknown mc task")?;
            run::mc_run(&ctx, &model, &method, t, seed, &cfg, 512)?
        }
        "gen" => {
            let t = GenTask::MATH_ALL
                .into_iter()
                .chain(GenTask::CODE_ALL)
                .find(|t| t.name() == name)
                .context("unknown gen task")?;
            run::gen_run(&ctx, &model, &method, t, seed, &cfg, 768)?
        }
        "vision" => {
            let t = VisionTask::ALL
                .into_iter()
                .find(|t| t.name() == name)
                .context("unknown vision task")?;
            run::vision_run(&ctx, &model, &method, t, seed, &cfg)?
        }
        "mlp" => run::mlp_run(&ctx, &format!("mlp_{name}"), seed, &cfg)?,
        other => bail!("unknown task kind {other}"),
    };
    println!(
        "test metric {:.4} (val {:.4})  #params {}  step {:.1} ms  wall {} ms",
        r.metric, r.val_metric, r.n_params, r.step_ms, r.wall_ms
    );
    if let Some((frac, mean, dim)) = r.rank {
        println!("C3A delta ranks: {:.0}% full rank, mean {:.1} of {}", 100.0 * frac, mean, dim);
    }
    Ok(())
}

fn experiment(args: &Args) -> Result<()> {
    let id = args.positional.get(1).map(|s| s.as_str()).context("exp id required")?;
    let opt = ExpOpt {
        steps: args.get_usize("steps"),
        seeds: args.get_usize("seeds").unwrap_or(1),
        fast: !args.has("full"),
        filter: args
            .get("only")
            .map(|s| s.split(',').map(str::to_string).collect())
            .unwrap_or_default(),
        results_dir: args.get("results").unwrap_or("results").to_string(),
    };
    let needs_ctx = id != "table1" && id != "fig1";
    let ctx = if needs_ctx || id == "all" { Some(open_ctx(args)?) } else { None };
    let dispatch = |id: &str| -> Result<()> {
        match id {
            "table1" => exp::table1::run(&opt),
            "table2" => exp::table2::run(ctx.as_ref().unwrap(), &opt),
            "table3" => exp::table34::table3(ctx.as_ref().unwrap(), &opt),
            "table4" => exp::table34::table4(ctx.as_ref().unwrap(), &opt),
            "table_a2" => exp::table_a2::run(ctx.as_ref().unwrap(), &opt),
            "fig1" => exp::fig1::run(&opt),
            "fig3" => exp::fig3::run(ctx.as_ref().unwrap(), &opt),
            "fig4" => exp::fig4::run(ctx.as_ref().unwrap(), &opt),
            "fig5" => exp::fig5::run(ctx.as_ref().unwrap(), &opt),
            other => bail!("unknown experiment {other}"),
        }
    };
    if id == "all" {
        for id in
            ["table1", "fig4", "table2", "fig3", "table3", "table4", "fig5", "table_a2", "fig1"]
        {
            println!("\n######## exp {id} ########");
            dispatch(id)?;
        }
        Ok(())
    } else {
        dispatch(id)
    }
}

fn rank_demo(args: &Args) -> Result<()> {
    let d = args.get_usize("dim").unwrap_or(64);
    let b = args.get_usize("block").unwrap_or(d);
    let mut rng = c3a::substrate::prng::Rng::seed(0);
    let m = d / b;
    let w: Vec<f64> = (0..m * m * b).map(|_| rng.normal()).collect();
    let bc = circulant::BlockCirculant::new(m, m, b, w);
    let mat = bc.materialize();
    let rank = circulant::dense_rank(&mat, d, d, 1e-9);
    println!("random C3A kernels: d={d} b={b} params={} -> rank {rank}/{d}", bc.param_count());
    println!("block ranks: {:?}", bc.block_ranks(1e-9));
    // exact cross-check on an integer kernel
    let wi: Vec<i64> = (0..b as i64).map(|i| (i * 7 + 3) % 11 - 5).collect();
    let exact = polynomial::circulant_rank_exact(&wi);
    let wf: Vec<f64> = wi.iter().map(|&v| v as f64).collect();
    let numeric = circulant::circulant_rank(&wf, 1e-9);
    println!("integer kernel len {b}: exact rank {exact}, numeric rank {numeric}");
    println!(
        "LoRA with the same budget ({} params) would cap at rank {}",
        bc.param_count(),
        bc.param_count() / (2 * d)
    );
    Ok(())
}
