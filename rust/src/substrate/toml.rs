//! TOML-subset parser for run configs (no serde/toml crates offline).
//!
//! Supported: `[section]` headers, `key = value` with string / integer /
//! float / bool / homogeneous inline arrays, `#` comments.  That covers
//! every config in configs/ — nested tables and datetimes intentionally
//! out of scope.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// A parsed TOML value (the subset the run configs use).
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// A double-quoted string (basic escapes decoded).
    Str(String),
    /// An integer literal.
    Int(i64),
    /// A float literal.
    Float(f64),
    /// `true` / `false`.
    Bool(bool),
    /// A homogeneous inline array.
    Arr(Vec<Value>),
}

impl Value {
    /// Borrow as a string, if this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Integer value, if this is an `Int`.
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// Float value (`Int` widens), if numeric.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    /// Boolean value, if this is a `Bool`.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// section -> key -> value; top-level keys live in section "".
pub type Doc = BTreeMap<String, BTreeMap<String, Value>>;

/// Parse a TOML-subset document into sections of key/value pairs.
pub fn parse(text: &str) -> Result<Doc> {
    let mut doc = Doc::new();
    let mut section = String::new();
    doc.entry(section.clone()).or_default();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[') {
            let name = name
                .strip_suffix(']')
                .with_context(|| format!("line {}: bad section", lineno + 1))?;
            section = name.trim().to_string();
            doc.entry(section.clone()).or_default();
            continue;
        }
        let eq =
            line.find('=').with_context(|| format!("line {}: expected key = value", lineno + 1))?;
        let key = line[..eq].trim().to_string();
        let val = parse_value(line[eq + 1..].trim())
            .with_context(|| format!("line {}: bad value", lineno + 1))?;
        if key.is_empty() {
            bail!("line {}: empty key", lineno + 1);
        }
        doc.get_mut(&section).unwrap().insert(key, val);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // honor '#' only outside strings
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    if let Some(body) = s.strip_prefix('"') {
        let body = body.strip_suffix('"').context("unterminated string")?;
        let unescaped = body.replace("\\\"", "\"").replace("\\n", "\n").replace("\\\\", "\\");
        return Ok(Value::Str(unescaped));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(body) = s.strip_prefix('[') {
        let body = body.strip_suffix(']').context("unterminated array")?;
        let mut items = Vec::new();
        let trimmed = body.trim();
        if !trimmed.is_empty() {
            // no nested arrays/strings-with-commas needed for our configs
            for part in trimmed.split(',') {
                let p = part.trim();
                if !p.is_empty() {
                    items.push(parse_value(p)?);
                }
            }
        }
        return Ok(Value::Arr(items));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("cannot parse value: {s:?}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_typical_config() {
        let doc = parse(
            r#"
# run config
name = "table2"   # experiment id
seeds = [0, 1, 2]

[train]
steps = 300
lr = 0.02
use_best = true
tasks = ["sst2", "stsb"]
"#,
        )
        .unwrap();
        assert_eq!(doc[""]["name"], Value::Str("table2".into()));
        assert_eq!(doc[""]["seeds"], Value::Arr(vec![Value::Int(0), Value::Int(1), Value::Int(2)]));
        assert_eq!(doc["train"]["steps"], Value::Int(300));
        assert_eq!(doc["train"]["lr"].as_f64(), Some(0.02));
        assert_eq!(doc["train"]["use_best"], Value::Bool(true));
    }

    #[test]
    fn hash_inside_string_is_kept() {
        let doc = parse(r#"k = "a#b" # comment"#).unwrap();
        assert_eq!(doc[""]["k"], Value::Str("a#b".into()));
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(parse("[unclosed").is_err());
        assert!(parse("novalue").is_err());
        assert!(parse("k = @?!").is_err());
    }

    #[test]
    fn int_vs_float() {
        let doc = parse("a = 3\nb = 3.5\nc = -2e-3").unwrap();
        assert_eq!(doc[""]["a"], Value::Int(3));
        assert_eq!(doc[""]["b"], Value::Float(3.5));
        assert_eq!(doc[""]["c"].as_f64(), Some(-0.002));
    }
}
