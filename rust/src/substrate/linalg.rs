//! Minimal dense linear algebra for baselines and the Table 1 benches:
//! LoRA / VeRA delta matvecs, dense matmul, norms.  Row-major f64.
//!
//! # Determinism obligations
//!
//! `matvec`/`matmul` shard their output rows across the substrate thread
//! pool above a work threshold.  Rows are disjoint and each row's
//! accumulation order is unchanged, so results are bit-for-bit identical
//! at any `C3A_THREADS` setting.  The SIMD microkernels (behind the
//! `simd` feature + `C3A_SIMD` switch) vectorize across output columns
//! (matmul) or put one whole row per lane (matvec) — never splitting a
//! row's reduction across lanes — so they are additionally bitwise
//! identical to the scalar loops (docs/DETERMINISM.md is normative).

use super::parallel;
#[cfg(feature = "simd")]
use super::simd;

/// Flop-count floor below which row-sharding is not worth the dispatch.
const PAR_MIN_WORK: usize = 64 * 1024;

/// y = A·x where A is rows×cols row-major.
pub fn matvec(a: &[f64], rows: usize, cols: usize, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.len(), rows * cols);
    assert_eq!(x.len(), cols);
    let mut y = vec![0.0; rows];
    matvec_into(a, rows, cols, x, &mut y);
    y
}

/// Allocation-free matvec for hot loops (row-sharded when large).
pub fn matvec_into(a: &[f64], rows: usize, cols: usize, x: &[f64], y: &mut [f64]) {
    #[cfg(feature = "simd")]
    if simd::enabled() {
        let y = &mut y[..rows];
        if rows * cols >= PAR_MIN_WORK && rows >= 2 && parallel::threads() > 1 {
            // 4-row register tiles: chunk on a multiple of 4 so only the
            // final span carries a sub-tile tail (the tail rows compute
            // the identical c-ascending dot either way)
            let chunk = parallel::row_chunk(rows, 4).next_multiple_of(4);
            parallel::par_chunks_mut(y, chunk, |ci, span| {
                simd::matvec_span_f64(span, a, x, ci * chunk)
            });
        } else {
            simd::matvec_span_f64(y, a, x, 0);
        }
        return;
    }
    let row_dot = |r: usize| -> f64 {
        let row = &a[r * cols..(r + 1) * cols];
        let mut acc = 0.0;
        for (v, xv) in row.iter().zip(x.iter()) {
            acc += v * xv;
        }
        acc
    };
    parallel::for_rows(&mut y[..rows], 1, rows * cols >= PAR_MIN_WORK, |r, out| {
        out[0] = row_dot(r)
    });
}

/// C = A·B, A is m×k, B is k×n (row-major).  Output rows are sharded
/// across the pool; each row keeps its sequential p-loop, so the result
/// does not depend on the thread count (nor on the SIMD switch — the
/// microkernel vectorizes across j only).
pub fn matmul(a: &[f64], b: &[f64], m: usize, k: usize, n: usize) -> Vec<f64> {
    let mut c = vec![0.0; m * n];
    if m == 0 || n == 0 {
        return c;
    }
    #[cfg(feature = "simd")]
    if simd::enabled() {
        parallel::for_rows(&mut c, n, m * k * n >= PAR_MIN_WORK, |i, crow| {
            simd::mm_row_f64(crow, &a[i * k..(i + 1) * k], b, n)
        });
        return c;
    }
    let row_mul = |i: usize, crow: &mut [f64]| {
        for p in 0..k {
            let av = a[i * k + p];
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    };
    parallel::for_rows(&mut c, n, m * k * n >= PAR_MIN_WORK, row_mul);
    c
}

/// LoRA delta matvec: y = B·(A·x); A r×d_in, B d_out×r.
pub struct LoRaDelta {
    /// Down-projection A, row-major r×d_in.
    pub a: Vec<f64>,
    /// Up-projection B, row-major d_out×r.
    pub b: Vec<f64>,
    /// LoRA rank.
    pub r: usize,
    /// Input dimension.
    pub d_in: usize,
    /// Output dimension.
    pub d_out: usize,
    /// Post-scale (α/r in the paper's convention).
    pub scale: f64,
}

impl LoRaDelta {
    /// Δy = scale·B·(A·x).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let hidden = matvec(&self.a, self.r, self.d_in, x);
        let mut y = matvec(&self.b, self.d_out, self.r, &hidden);
        for v in y.iter_mut() {
            *v *= self.scale;
        }
        y
    }

    /// Allocation-free [`Self::matvec`] with caller-owned buffers.
    pub fn matvec_into(&self, x: &[f64], hidden: &mut [f64], y: &mut [f64]) {
        matvec_into(&self.a, self.r, self.d_in, x, hidden);
        matvec_into(&self.b, self.d_out, self.r, hidden, y);
        for v in y.iter_mut() {
            *v *= self.scale;
        }
    }

    /// Materialized ΔW = scale·B·A (d_out × d_in).
    pub fn materialize(&self) -> Vec<f64> {
        let mut m = matmul(&self.b, &self.a, self.d_out, self.r, self.d_in);
        for v in m.iter_mut() {
            *v *= self.scale;
        }
        m
    }
}

/// VeRA delta matvec: y = λb ∘ (B·(λd ∘ (A·x))); frozen A (r_v×d_in), B (d_out×r_v).
pub struct VeraDelta {
    /// Frozen shared down-projection A, row-major r_v×d_in.
    pub a: Vec<f64>,
    /// Frozen shared up-projection B, row-major d_out×r_v.
    pub b: Vec<f64>,
    /// Trainable hidden scaling λd (length r_v).
    pub ld: Vec<f64>,
    /// Trainable output scaling λb (length d_out).
    pub lb: Vec<f64>,
    /// VeRA rank.
    pub r_v: usize,
    /// Input dimension.
    pub d_in: usize,
    /// Output dimension.
    pub d_out: usize,
}

impl VeraDelta {
    /// Δy = λb ∘ (B·(λd ∘ (A·x))).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut h = matvec(&self.a, self.r_v, self.d_in, x);
        for (v, s) in h.iter_mut().zip(&self.ld) {
            *v *= s;
        }
        let mut y = matvec(&self.b, self.d_out, self.r_v, &h);
        for (v, s) in y.iter_mut().zip(&self.lb) {
            *v *= s;
        }
        y
    }
}

/// Sequential dot product (analysis/test use; not SIMD-dispatched).
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Euclidean norm via [`dot`].
pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// Largest elementwise absolute difference between two slices.
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

/// argmax of a slice, skipping NaNs (first max wins among ties).
///
/// NaN entries never win: a diverged row with a NaN logit used to return
/// index 0 (every `>` comparison is false against NaN), silently
/// mispredicting class 0.  An all-NaN (or empty) slice returns 0.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best: Option<usize> = None;
    for (i, &v) in xs.iter().enumerate() {
        if v.is_nan() {
            continue;
        }
        match best {
            Some(b) if v <= xs[b] => {}
            _ => best = Some(i),
        }
    }
    best.unwrap_or(0)
}

/// Numerically-stable softmax in place.
pub fn softmax_inplace(xs: &mut [f64]) {
    let m = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let mut sum = 0.0;
    for v in xs.iter_mut() {
        *v = (*v - m).exp();
        sum += *v;
    }
    for v in xs.iter_mut() {
        *v /= sum;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::circulant::dense_rank;
    use crate::substrate::prng::Rng;

    #[test]
    fn matvec_identity() {
        let d = 4;
        let mut eye = vec![0.0; d * d];
        for i in 0..d {
            eye[i * d + i] = 1.0;
        }
        let x = vec![1.0, -2.0, 3.0, 0.5];
        assert_eq!(matvec(&eye, d, d, &x), x);
    }

    #[test]
    fn matmul_associative_with_matvec() {
        let mut rng = Rng::seed(1);
        let (m, k, n) = (3, 4, 5);
        let a: Vec<f64> = (0..m * k).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..k * n).map(|_| rng.normal()).collect();
        let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let ab = matmul(&a, &b, m, k, n);
        let y1 = matvec(&ab, m, n, &x);
        let y2 = matvec(&a, m, k, &matvec(&b, k, n, &x));
        for (u, v) in y1.iter().zip(&y2) {
            assert!((u - v).abs() < 1e-10);
        }
    }

    #[test]
    fn lora_rank_capped_by_r() {
        // The paper's motivating limitation: rank(BA) <= r.
        let mut rng = Rng::seed(2);
        let (d, r) = (16, 2);
        let delta = LoRaDelta {
            a: (0..r * d).map(|_| rng.normal()).collect(),
            b: (0..d * r).map(|_| rng.normal()).collect(),
            r,
            d_in: d,
            d_out: d,
            scale: 1.0,
        };
        let m = delta.materialize();
        assert_eq!(dense_rank(&m, d, d, 1e-9), r);
    }

    #[test]
    fn lora_matvec_matches_materialized() {
        let mut rng = Rng::seed(3);
        let (d_in, d_out, r) = (6, 8, 3);
        let delta = LoRaDelta {
            a: (0..r * d_in).map(|_| rng.normal()).collect(),
            b: (0..d_out * r).map(|_| rng.normal()).collect(),
            r,
            d_in,
            d_out,
            scale: 0.5,
        };
        let x: Vec<f64> = (0..d_in).map(|_| rng.normal()).collect();
        let y1 = delta.matvec(&x);
        let y2 = matvec(&delta.materialize(), d_out, d_in, &x);
        for (u, v) in y1.iter().zip(&y2) {
            assert!((u - v).abs() < 1e-10);
        }
    }

    #[test]
    fn vera_matvec_shape_and_scaling() {
        let mut rng = Rng::seed(4);
        let (d, rv) = (8, 16);
        let v = VeraDelta {
            a: (0..rv * d).map(|_| rng.normal()).collect(),
            b: (0..d * rv).map(|_| rng.normal()).collect(),
            ld: vec![0.0; rv],
            lb: vec![1.0; d],
            r_v: rv,
            d_in: d,
            d_out: d,
        };
        // zero λd kills the delta
        let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        assert!(norm2(&v.matvec(&x)) < 1e-12);
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut xs = vec![1.0, 2.0, 3.0, -100.0];
        softmax_inplace(&mut xs);
        assert!((xs.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(xs[2] > xs[1] && xs[1] > xs[0]);
    }

    #[test]
    fn argmax_first_max() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
    }

    #[test]
    fn argmax_skips_nans() {
        assert_eq!(argmax(&[f32::NAN, 1.0, 0.5]), 1);
        assert_eq!(argmax(&[1.0, f32::NAN, 2.0]), 2);
        assert_eq!(argmax(&[f32::NAN, f32::NAN]), 0);
        assert_eq!(argmax(&[]), 0);
        assert_eq!(argmax(&[f32::NEG_INFINITY, f32::NAN]), 0);
    }

    /// Scalar vs SIMD microkernels must agree BITWISE — including sizes
    /// with scalar tails (n not a multiple of the lane tile) and a
    /// sparse A exercising the zero-skip.  Vacuous without
    /// `--features simd` (both legs run scalar); the catalog-level pin
    /// lives in tests/simd_parity.rs.
    #[test]
    fn matvec_matmul_simd_bitwise_parity() {
        use crate::substrate::simd;
        let _guard = simd::override_lock();
        let prev = simd::enabled();
        let mut rng = Rng::seed(11);
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (3, 5, 7), (8, 16, 33), (16, 9, 40)] {
            let mut a: Vec<f64> = (0..m * k).map(|_| rng.normal()).collect();
            for v in a.iter_mut().step_by(3) {
                *v = 0.0; // exercise the av == 0.0 skip on both paths
            }
            let b: Vec<f64> = (0..k * n).map(|_| rng.normal()).collect();
            let x: Vec<f64> = (0..k).map(|_| rng.normal()).collect();
            simd::set_enabled(false);
            let c_scalar = matmul(&a, &b, m, k, n);
            let y_scalar = matvec(&a, m, k, &x);
            simd::set_enabled(true);
            let c_simd = matmul(&a, &b, m, k, n);
            let y_simd = matvec(&a, m, k, &x);
            simd::set_enabled(prev);
            assert_eq!(c_scalar, c_simd, "matmul diverged at ({m},{k},{n})");
            assert_eq!(y_scalar, y_simd, "matvec diverged at ({m},{k})");
        }
    }

    #[test]
    fn matvec_matmul_threaded_parity() {
        use crate::substrate::parallel;
        let _lock = parallel::thread_override_lock();
        let mut rng = Rng::seed(42);
        // matmul gate is m*k*n >= PAR_MIN_WORK: 96*48*64 = 294912 crosses it
        let (m, k, n) = (96, 48, 64);
        let a: Vec<f64> = (0..m * k).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..k * n).map(|_| rng.normal()).collect();
        // matvec gate is rows*cols >= PAR_MIN_WORK: 640*128 = 81920 crosses it
        let (mr, mc) = (640, 128);
        let av: Vec<f64> = (0..mr * mc).map(|_| rng.normal()).collect();
        let x: Vec<f64> = (0..mc).map(|_| rng.normal()).collect();
        let prev = parallel::threads();
        parallel::set_threads(1);
        let c1 = matmul(&a, &b, m, k, n);
        let y1 = matvec(&av, mr, mc, &x);
        parallel::set_threads(4);
        let c4 = matmul(&a, &b, m, k, n);
        let y4 = matvec(&av, mr, mc, &x);
        parallel::set_threads(prev);
        assert_eq!(c1, c4, "matmul must be bit-for-bit across thread counts");
        assert_eq!(y1, y4, "matvec must be bit-for-bit across thread counts");
    }
}
